#!/usr/bin/env python3
"""Diff two BENCH_kernels.json files and fail on per-bucket regressions.

Usage: bench_diff.py BASELINE CURRENT [--max-regress 0.20] [--min-us 20]

Compares the per-kernel timing buckets of the current run against the
previous run's artifact. A bucket regresses when its best-observed time
(`min_us` — the least noisy statistic on shared CI runners) grows by more
than --max-regress relative to the baseline. Buckets faster than --min-us
in the baseline are skipped (timer noise dominates). Buckets that exist
on only one side (renamed/new/removed kernels across PRs) are reported
as warnings but never fail the gate — and never KeyError the comparison.

The baseline side is best-effort by design: a missing file, a path that
is a directory (a partially-downloaded artifact), or unreadable /
malformed JSON all mean "no baseline for this bench file yet" — the
first CI run after a new BENCH_*.json is introduced has nothing to diff
against, and must pass with a notice rather than fail the gate. Only a
broken *current* file (the run that just produced it) is an error.

Exit codes: 0 ok / baseline absent or unusable (first run), 1 regression
found, 2 malformed current input.
"""

import argparse
import json
import math
import os
import sys


def load_buckets(path):
    with open(path) as f:
        doc = json.load(f)
    return {row["name"]: row for row in doc.get("kernels", [])}


def usable(v):
    """A timing value the gate can divide by: a finite number > 0.

    NaN (a bench that recorded no samples), 0 (a clock that never ticked)
    and non-numeric junk would otherwise either crash the ratio or —
    worse, for NaN — make every comparison silently false and wave a real
    regression through.
    """
    return isinstance(v, (int, float)) and not isinstance(v, bool) and math.isfinite(v) and v > 0


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("baseline")
    ap.add_argument("current")
    ap.add_argument("--max-regress", type=float, default=0.20,
                    help="fail when min_us grows more than this fraction (default 0.20)")
    ap.add_argument("--min-us", type=float, default=20.0,
                    help="skip buckets whose baseline min_us is below this (noise floor)")
    args = ap.parse_args()

    if not os.path.isfile(args.baseline):
        what = "is a directory" if os.path.isdir(args.baseline) else "is absent"
        print(f"bench-diff: NOTICE baseline {args.baseline} {what} "
              f"(first run for this bench file?) — skipping gate")
        return 0

    try:
        base = load_buckets(args.baseline)
    except (OSError, ValueError, KeyError) as e:
        # an unusable baseline is the first-run case too (e.g. a truncated
        # artifact download) — notice, never a gate failure
        print(f"bench-diff: NOTICE cannot read baseline {args.baseline}: {e} — skipping gate")
        return 0
    try:
        cur = load_buckets(args.current)
    except (OSError, ValueError, KeyError) as e:
        print(f"bench-diff: cannot parse current run {args.current}: {e}", file=sys.stderr)
        return 2

    shared = sorted(set(base) & set(cur))
    # one-sided buckets: a rename/addition/removal is expected across PRs,
    # so warn (visibly, for the reviewer) instead of failing or KeyErroring.
    only_base = sorted(set(base) - set(cur))
    only_cur = sorted(set(cur) - set(base))
    for name in only_base:
        print(f"bench-diff: WARNING bucket {name!r} only in baseline (removed/renamed?) — not gated")
    for name in only_cur:
        print(f"bench-diff: WARNING bucket {name!r} only in current run (new/renamed?) — not gated")
    if not shared:
        print("bench-diff: no shared kernel buckets — skipping gate")
        return 0

    regressions = []
    print(f"bench-diff: {len(shared)} shared buckets "
          f"(gate: >{args.max_regress:.0%} on min_us, noise floor {args.min_us}us)")
    for name in shared:
        b, c = base[name].get("min_us"), cur[name].get("min_us")
        if not usable(b):
            print(f"bench-diff: WARNING bucket {name!r} baseline min_us={b!r} unusable — not gated")
            continue
        if not usable(c):
            print(f"bench-diff: WARNING bucket {name!r} current min_us={c!r} unusable — not gated")
            continue
        if b < args.min_us:
            continue
        ratio = c / b - 1.0
        flag = ""
        if ratio > args.max_regress:
            regressions.append((name, b, c, ratio))
            flag = "  <-- REGRESSION"
        print(f"  {name:<48} {b:>10.1f}us -> {c:>10.1f}us  {ratio:+7.1%}{flag}")

    if regressions:
        print(f"\nbench-diff: {len(regressions)} bucket(s) regressed "
              f"more than {args.max_regress:.0%}:", file=sys.stderr)
        for name, b, c, ratio in regressions:
            print(f"  {name}: {b:.1f}us -> {c:.1f}us ({ratio:+.1%})", file=sys.stderr)
        return 1
    print("bench-diff: ok")
    return 0


if __name__ == "__main__":
    sys.exit(main())
