"""Training / eval / calibration step functions — the AOT artifact bodies.

Every public function here takes and returns *flat lists* of arrays (the
manifest contract with Rust); internally state lives in name-keyed dicts.

``train_step_k`` runs ``cfg.k_steps`` QAT updates in a single execution
via ``lax.scan`` so the training state never leaves the device between
micro-steps — the host round-trip (the only PJRT-level cost the Rust
coordinator pays) is amortized K-fold. This is a §Perf design point, not
an afterthought: the xla crate returns outputs as one tuple buffer that
must be decomposed on the host, so K-step scan is the lever that keeps
L3 off the critical path.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp

from . import losses, model, optim
from .config import ModelConfig


def _partition_scale_keys(cfg: ModelConfig):
    act = [n for n, _ in model.scale_specs(cfg) if "_s_act_" in n]
    wgt = [n for n, _ in model.scale_specs(cfg) if "_s_w_" in n]
    return act, wgt


# ---------------------------------------------------------------------------
# QAT train step (K scanned updates)
# ---------------------------------------------------------------------------

def make_train_step_k(cfg: ModelConfig):
    p_specs, s_specs = model.param_specs(cfg), model.scale_specs(cfg)
    n_p, n_s = len(p_specs), len(s_specs)
    act_keys, wgt_keys = _partition_scale_keys(cfg)

    def loss_fn(params, scales, t_params, ids, mask, labels, bits, mse_flag, alpha, beta):
        s_logits, s_aux = model.forward(cfg, params, scales, ids, mask, bits, mse_flag, quantize=True)
        t_logits, t_aux = model.forward(cfg, t_params, None, ids, mask, bits, mse_flag, quantize=False)
        total, parts = losses.combined_loss(
            s_logits, s_aux, t_logits, t_aux, labels, mask, cfg.d_head, alpha, beta)
        acc = losses.accuracy_count(s_logits, labels)
        return total, (parts, acc)

    grad_fn = jax.value_and_grad(loss_fn, argnums=(0, 1), has_aux=True)

    def train_step_k(*flat):
        i = 0
        def take(n):
            nonlocal i
            out = flat[i:i + n]
            i += n
            return list(out)

        params = model.flat_to_dict(p_specs, take(n_p))
        scales = model.flat_to_dict(s_specs, take(n_s))
        m_p = model.flat_to_dict(p_specs, take(n_p))
        v_p = model.flat_to_dict(p_specs, take(n_p))
        m_s = model.flat_to_dict(s_specs, take(n_s))
        v_s = model.flat_to_dict(s_specs, take(n_s))
        (step,) = take(1)
        t_params = model.flat_to_dict(p_specs, take(n_p))
        ids, mask, labels, lr_w, lr_sa, lr_sw = take(6)      # (K,B,T)/(K,B)/(K,1)
        alpha, beta, mse_flag, lsq_flag, bits = take(5)
        assert i == len(flat), (i, len(flat))

        a, b_, mf, lf = alpha[0], beta[0], mse_flag[0], lsq_flag[0]

        def body(carry, xs):
            params, scales, m_p, v_p, m_s, v_s, step = carry
            ids_t, mask_t, labels_t, lrw_t, lrsa_t, lrsw_t = xs
            (total, (parts, acc)), (g_p, g_s) = grad_fn(
                params, scales, t_params, ids_t, mask_t, labels_t, bits, mf, a, b_)
            # w/o-LSQ ablation: freeze scales by zeroing their gradients.
            g_s = jax.tree.map(lambda g: g * lf, g_s)
            step = step + 1.0
            params, m_p, v_p = optim.adam_update(params, g_p, m_p, v_p, step[0], lrw_t[0])
            # Separate lr for activation vs weight scales (§5.2).
            ga = {k: g_s[k] for k in act_keys}
            gw = {k: g_s[k] for k in wgt_keys}
            sa, ma, va = optim.adam_update(
                {k: scales[k] for k in act_keys}, ga,
                {k: m_s[k] for k in act_keys}, {k: v_s[k] for k in act_keys},
                step[0], lrsa_t[0])
            sw, mw, vw = optim.adam_update(
                {k: scales[k] for k in wgt_keys}, gw,
                {k: m_s[k] for k in wgt_keys}, {k: v_s[k] for k in wgt_keys},
                step[0], lrsw_t[0])
            scales = {**sa, **sw}
            # Scales must stay positive; clamp to a tiny floor.
            scales = jax.tree.map(lambda s: jnp.maximum(s, 1e-6), scales)
            m_s = {**ma, **mw}
            v_s = {**va, **vw}
            stats = jnp.stack([total, parts["train"], parts["output"],
                               parts["attention"], parts["value"], acc])
            return (params, scales, m_p, v_p, m_s, v_s, step), stats

        carry = (params, scales, m_p, v_p, m_s, v_s, step)
        carry, stats = jax.lax.scan(body, carry, (ids, mask, labels, lr_w, lr_sa, lr_sw))
        params, scales, m_p, v_p, m_s, v_s, step = carry

        out = (model.dict_to_flat(p_specs, params) + model.dict_to_flat(s_specs, scales)
               + model.dict_to_flat(p_specs, m_p) + model.dict_to_flat(p_specs, v_p)
               + model.dict_to_flat(s_specs, m_s) + model.dict_to_flat(s_specs, v_s)
               + [step, stats])
        return tuple(out)

    return train_step_k


# ---------------------------------------------------------------------------
# fp32 teacher finetuning step (K scanned updates, CE only)
# ---------------------------------------------------------------------------

def make_train_fp32_k(cfg: ModelConfig):
    p_specs = model.param_specs(cfg)
    n_p = len(p_specs)
    bits0 = jnp.zeros((cfg.n_layers,), jnp.float32)

    def loss_fn(params, ids, mask, labels):
        logits, _ = model.forward(cfg, params, None, ids, mask, bits0, 0.0, quantize=False)
        return losses.cross_entropy(logits, labels), losses.accuracy_count(logits, labels)

    grad_fn = jax.value_and_grad(loss_fn, has_aux=True)

    def train_fp32_k(*flat):
        params = model.flat_to_dict(p_specs, list(flat[:n_p]))
        m = model.flat_to_dict(p_specs, list(flat[n_p:2 * n_p]))
        v = model.flat_to_dict(p_specs, list(flat[2 * n_p:3 * n_p]))
        step = flat[3 * n_p]
        ids, mask, labels, lr = flat[3 * n_p + 1:3 * n_p + 5]

        def body(carry, xs):
            params, m, v, step = carry
            ids_t, mask_t, labels_t, lr_t = xs
            (loss, acc), g = grad_fn(params, ids_t, mask_t, labels_t)
            step = step + 1.0
            params, m, v = optim.adam_update(params, g, m, v, step[0], lr_t[0])
            return (params, m, v, step), jnp.stack([loss, acc])

        carry, stats = jax.lax.scan(body, (params, m, v, step), (ids, mask, labels, lr))
        params, m, v, step = carry
        out = (model.dict_to_flat(p_specs, params) + model.dict_to_flat(p_specs, m)
               + model.dict_to_flat(p_specs, v) + [step, stats])
        return tuple(out)

    return train_fp32_k


# ---------------------------------------------------------------------------
# Eval / calibration / serving / init
# ---------------------------------------------------------------------------

def make_eval_step(cfg: ModelConfig):
    p_specs, s_specs = model.param_specs(cfg), model.scale_specs(cfg)
    n_p, n_s = len(p_specs), len(s_specs)

    def eval_step(*flat):
        params = model.flat_to_dict(p_specs, list(flat[:n_p]))
        scales = model.flat_to_dict(s_specs, list(flat[n_p:n_p + n_s]))
        bits, ids, mask, labels = flat[n_p + n_s:]
        logits, _ = model.forward(cfg, params, scales, ids, mask, bits, jnp.float32(1.0), quantize=True)
        correct = losses.accuracy_count(logits, labels)
        loss = losses.cross_entropy(logits, labels)
        return correct.reshape(1), loss.reshape(1), logits

    return eval_step


def make_teacher_eval(cfg: ModelConfig):
    p_specs = model.param_specs(cfg)
    n_p = len(p_specs)
    bits0 = jnp.zeros((cfg.n_layers,), jnp.float32)

    def teacher_eval(*flat):
        params = model.flat_to_dict(p_specs, list(flat[:n_p]))
        ids, mask, labels = flat[n_p:]
        logits, _ = model.forward(cfg, params, None, ids, mask, bits0, 0.0, quantize=False)
        return losses.accuracy_count(logits, labels).reshape(1), losses.cross_entropy(logits, labels).reshape(1), logits

    return teacher_eval


def make_calibrate(cfg: ModelConfig):
    p_specs = model.param_specs(cfg)
    n_p = len(p_specs)

    def calibrate(*flat):
        params = model.flat_to_dict(p_specs, list(flat[:n_p]))
        ids, mask = flat[n_p:]
        act_q, act_max = model.forward_collect_act_stats(cfg, params, ids, mask)
        w_max = model.weight_abs_max(cfg, params)
        return act_q, act_max, w_max

    return calibrate


def make_serve_fwd(cfg: ModelConfig):
    p_specs, s_specs = model.param_specs(cfg), model.scale_specs(cfg)
    n_p, n_s = len(p_specs), len(s_specs)

    def serve_fwd(*flat):
        params = model.flat_to_dict(p_specs, list(flat[:n_p]))
        scales = model.flat_to_dict(s_specs, list(flat[n_p:n_p + n_s]))
        bits, ids, mask = flat[n_p + n_s:]
        logits, _ = model.forward(cfg, params, scales, ids, mask, bits, jnp.float32(1.0), quantize=True)
        return (logits,)

    return serve_fwd


def make_init(cfg: ModelConfig):
    p_specs, s_specs = model.param_specs(cfg), model.scale_specs(cfg)

    def init(seed):
        key = jax.random.PRNGKey(seed[0])
        params = model.init_params(cfg, key)
        scales = model.init_scales(cfg)
        return tuple(model.dict_to_flat(p_specs, params) + model.dict_to_flat(s_specs, scales))

    return init
