"""Table-2 artifacts: one BERT-base transformer layer at f32 / int8 / int4.

The paper benchmarks *deployed* kernels (real integer MACs), not QAT
fake-quant — so these graphs do the arithmetic the way the CUDA kernels
did:

  f32  : plain dense layer.
  int8 : activations quantized on the fly to int8, weights arrive as int8
         device buffers, MAC in int8→int32 (``preferred_element_type``),
         dequantize, fp32 bias/softmax/GELU/LayerNorm (§5: those stay fp32).
  int4 : weights arrive *nibble-packed* (two codes per byte along K,
         half the bytes of int8 — the 5.3x-bits-reduction storage claim),
         are unpacked in-graph (the register-unpack of the CUDA kernel),
         then take the same int8 MAC path. On TPU this is exactly the
         "int4 rides the int8 MXU path with halved HBM traffic" adaptation
         (DESIGN.md §Hardware-Adaptation).

Per-output-channel weight scales (1, n); per-tensor activation scales (1,).
"""

from __future__ import annotations

import jax
import jax.numpy as jnp

from .kernels import ref

# (weight name, is it the FFN-in (d, d_ff) / FFN-out (d_ff, d) matrix)
W_NAMES = ("wq", "wk", "wv", "wo", "w1", "w2")


def layer_weight_specs(d: int, d_ff: int):
    """[(name, shape)] for one layer's dense weights + biases + LN params."""
    shapes = {
        "wq": (d, d), "wk": (d, d), "wv": (d, d), "wo": (d, d),
        "w1": (d, d_ff), "w2": (d_ff, d),
    }
    specs = []
    for n in W_NAMES:
        specs.append((n, shapes[n]))
        specs.append((f"b{n[1:]}", (shapes[n][1],)))
    specs += [("ln1_g", (d,)), ("ln1_b", (d,)), ("ln2_g", (d,)), ("ln2_b", (d,))]
    return specs


def _ln(x, g, b, eps=1e-12):
    mu = jnp.mean(x, axis=-1, keepdims=True)
    var = jnp.mean(jnp.square(x - mu), axis=-1, keepdims=True)
    return (x - mu) * jax.lax.rsqrt(var + eps) * g + b


def _attention(q, k, v, mask, n_heads):
    B, T, d = q.shape
    dk = d // n_heads

    def split(x):
        return x.reshape(B, T, n_heads, dk).transpose(0, 2, 1, 3)

    q, k, v = split(q), split(k), split(v)
    bias = (1.0 - mask)[:, None, None, :] * (-1e9)
    scores = (q @ k.transpose(0, 1, 3, 2)) / jnp.sqrt(jnp.float32(dk)) + bias
    attn = jax.nn.softmax(scores, axis=-1)
    return (attn @ v).transpose(0, 2, 1, 3).reshape(B, T, d)


def _int_mm(x, s_x, wq, s_w, bits: float):
    """Real integer matmul: quantize x per-tensor, int8 MAC, dequantize.

    x: (..., k) f32;  wq: (k, n) int8;  s_x: (1,);  s_w: (1, n).

    Storage note: the paper's k-bit grid tops out at l_max = 2^{k-1}, which
    for k=8 (+128) does NOT fit two's-complement int8 — the deployed integer
    path therefore clamps to 127 (standard symmetric int8), while QAT
    fake-quant keeps the paper's exact grid. int4 is unaffected (+8 fits the
    offset-nibble encoding)."""
    lmin, lmax = -(2 ** (int(bits) - 1)) + 1, 2 ** (int(bits) - 1)
    if int(bits) == 8:
        lmax = 127
    xq = jnp.clip(jnp.round(x / s_x), lmin, lmax)
    # §Perf iteration 2 (EXPERIMENTS.md): XLA-CPU 0.5.1 lowers s8xs8->s32
    # dot_general to a scalar loop (measured 6-9x SLOWER than f32 GEMM), so
    # the integer codes ride the f32 GEMM fast path instead. Codes are
    # small integers, exactly representable in f32; on TPU/GPU this line is
    # where the int8 MXU/tensor-core path goes (DESIGN.md
    # §Hardware-Adaptation).
    acc = jax.lax.dot_general(
        xq, wq.astype(jnp.float32),
        dimension_numbers=(((xq.ndim - 1,), (0,)), ((), ())),
        preferred_element_type=jnp.float32,
    )
    return acc * s_x * s_w


def _unpack_k(wp, k: int):
    """(k//2, n) packed bytes → (k, n) int8 codes (in-graph unpack)."""
    p = wp.astype(jnp.int32)
    lo = (p & 0xF) - ref.INT4_OFFSET
    hi = ((p >> 4) & 0xF) - ref.INT4_OFFSET
    return jnp.stack([lo, hi], axis=1).reshape(k, p.shape[1]).astype(jnp.int8)


def make_layer_fp32(n_heads: int):
    def layer(h, mask, wq, bq, wk, bk, wv, bv, wo, bo, w1, b1, w2, b2, ln1g, ln1b, ln2g, ln2b):
        q = h @ wq + bq
        k = h @ wk + bk
        v = h @ wv + bv
        oa = _attention(q, k, v, mask, n_heads)
        h = _ln(h + (oa @ wo + bo), ln1g, ln1b)
        f = jax.nn.gelu(h @ w1 + b1, approximate=False)
        h = _ln(h + (f @ w2 + b2), ln2g, ln2b)
        return (h,)

    return layer


def make_layer_int(n_heads: int, bits: float, packed: bool, d: int, d_ff: int):
    """int8 (packed=False) or int4 (packed=True) layer. Weight arguments are
    int8 codes or packed bytes; each dense site gets (act_scale, w_scale)."""

    def layer(h, mask,
              wq, bq, wk, bk, wv, bv, wo, bo, w1, b1, w2, b2,
              ln1g, ln1b, ln2g, ln2b,
              sa_qkv, sa_attn, sa_ffn1, sa_ffn2,
              sw_q, sw_k, sw_v, sw_o, sw_1, sw_2):
        if packed:
            wq_, wk_, wv_, wo_ = (_unpack_k(w, d) for w in (wq, wk, wv, wo))
            w1_ = _unpack_k(w1, d)
            w2_ = _unpack_k(w2, d_ff)
        else:
            wq_, wk_, wv_, wo_, w1_, w2_ = wq, wk, wv, wo, w1, w2
        q = _int_mm(h, sa_qkv, wq_, sw_q, bits) + bq
        k = _int_mm(h, sa_qkv, wk_, sw_k, bits) + bk
        v = _int_mm(h, sa_qkv, wv_, sw_v, bits) + bv
        oa = _attention(q, k, v, mask, n_heads)
        h = _ln(h + (_int_mm(oa, sa_attn, wo_, sw_o, bits) + bo), ln1g, ln1b)
        f = jax.nn.gelu(_int_mm(h, sa_ffn1, w1_, sw_1, bits) + b1, approximate=False)
        h = _ln(h + (_int_mm(f, sa_ffn2, w2_, sw_2, bits) + b2), ln2g, ln2b)
        return (h,)

    return layer
