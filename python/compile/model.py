"""L2: quantization-aware TinyBERT-shaped encoder in JAX.

All parameters and quantization scales travel across the Rust boundary as
*flat, ordered lists* of arrays; ``param_specs`` / ``scale_specs`` define
the canonical order, which ``aot.py`` records in the artifact manifest.

The student forward is traced with *runtime* per-layer bit codes
(f32 vector, values 4/8/32), so a single AOT artifact serves every
bit-allocation row of Tables 1 and 3. The teacher forward is the same
network with quantization statically disabled (``quantize=False``).

Per the paper (§5): LayerNorm, softmax and GELU run in fp32; the
embedding layer is never quantized; the 6 fc matmuls per transformer
layer have their input activations and weights fake-quantized during QAT.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp

from .config import ModelConfig, param_specs, scale_specs
from .kernels.quant import fake_quant
from .kernels import ref

NEG_INF = -1e9


# ---------------------------------------------------------------------------
# Parameter / scale specs — canonical order lives in config.py (jax-free,
# shared with the MKQC checkpoint exporter); re-exported here for the
# existing ``model.param_specs`` / ``model.scale_specs`` call sites.
# ---------------------------------------------------------------------------


def flat_to_dict(specs, flat):
    assert len(specs) == len(flat), (len(specs), len(flat))
    return {name: x for (name, _), x in zip(specs, flat)}


def dict_to_flat(specs, d):
    return [d[name] for name, _ in specs]


def init_params(cfg: ModelConfig, key):
    """Standard BERT-style init: N(0, 0.02) matrices, zero biases, unit LN."""
    out = {}
    for name, shape in param_specs(cfg):
        key, sub = jax.random.split(key)
        if name.endswith(("_g",)):
            out[name] = jnp.ones(shape, jnp.float32)
        elif name.endswith(("_b", "_bq", "_bk", "_bv", "_bo", "_b1", "_b2")) or name in ("pool_b", "cls_b"):
            out[name] = jnp.zeros(shape, jnp.float32)
        elif len(shape) == 1:
            out[name] = jnp.zeros(shape, jnp.float32)
        else:
            out[name] = 0.02 * jax.random.normal(sub, shape, jnp.float32)
    return out


def init_scales(cfg: ModelConfig):
    """Placeholder scales (overwritten by calibration before QAT)."""
    return {name: jnp.full(shape, 0.1, jnp.float32) for name, shape in scale_specs(cfg)}


# ---------------------------------------------------------------------------
# Forward
# ---------------------------------------------------------------------------

def layer_norm(x, g, b, eps=1e-12):
    mu = jnp.mean(x, axis=-1, keepdims=True)
    var = jnp.mean(jnp.square(x - mu), axis=-1, keepdims=True)
    return (x - mu) * jax.lax.rsqrt(var + eps) * g + b


def _mfq(x, s, bits, mse_flag):
    """Fake-quant degrading to identity at bits>=32; the scale receives no
    gradient in the fp32 branch (the select masks the MSE grad too via the
    bits gate inside the custom VJP wrapper below)."""
    q = fake_quant(x, s, bits, mse_flag)
    gate = (bits < 31.5).astype(x.dtype)
    return gate * q + (1.0 - gate) * x


def forward(cfg: ModelConfig, params, scales, ids, mask, bits, mse_flag, *, quantize=True):
    """Encoder forward.

    ids:  (B, T) int32 token ids; mask: (B, T) f32 {0,1} valid-token mask.
    bits: (L,) f32 per-layer bit codes (ignored when quantize=False).
    Returns (logits, aux) where aux carries the last layer's attention
    distribution and value vectors for the MiniLM distillation losses.
    """
    B, T = ids.shape
    d, H, dk = cfg.d_model, cfg.n_heads, cfg.d_head

    h = params["emb_word"][ids] + params["emb_pos"][None, :T, :]
    h = layer_norm(h, params["emb_ln_g"], params["emb_ln_b"])

    # (B, 1, 1, T) additive attention mask.
    attn_bias = (1.0 - mask)[:, None, None, :] * NEG_INF

    def q_act(x, l, site, b):
        if not quantize:
            return x
        return _mfq(x, scales[f"l{l}_s_act_{site}"], b, mse_flag)

    def q_w(l, site, b):
        w = params[f"l{l}_{site}"]
        if not quantize:
            return w
        return _mfq(w, scales[f"l{l}_s_w_{site}"], b, mse_flag)

    aux = {}
    for l in range(cfg.n_layers):
        b = bits[l] if quantize else jnp.float32(32.0)
        hq = q_act(h, l, "qkv_in", b)
        q = hq @ q_w(l, "wq", b) + params[f"l{l}_bq"]
        k = hq @ q_w(l, "wk", b) + params[f"l{l}_bk"]
        v = hq @ q_w(l, "wv", b) + params[f"l{l}_bv"]

        def split(x):
            return x.reshape(B, T, H, dk).transpose(0, 2, 1, 3)  # (B,H,T,dk)

        q, k, v = split(q), split(k), split(v)
        scores = (q @ k.transpose(0, 1, 3, 2)) / jnp.sqrt(jnp.float32(dk)) + attn_bias
        attn_logp = jax.nn.log_softmax(scores, axis=-1)
        attn = jnp.exp(attn_logp)
        oa = (attn @ v).transpose(0, 2, 1, 3).reshape(B, T, d)

        oaq = q_act(oa, l, "attn_out_in", b)
        attn_out = oaq @ q_w(l, "wo", b) + params[f"l{l}_bo"]
        h = layer_norm(h + attn_out, params[f"l{l}_ln1_g"], params[f"l{l}_ln1_b"])

        x1 = q_act(h, l, "ffn1_in", b)
        f = jax.nn.gelu(x1 @ q_w(l, "w1", b) + params[f"l{l}_b1"], approximate=False)
        fq = q_act(f, l, "ffn2_in", b)
        f2 = fq @ q_w(l, "w2", b) + params[f"l{l}_b2"]
        h = layer_norm(h + f2, params[f"l{l}_ln2_g"], params[f"l{l}_ln2_b"])

        if l == cfg.n_layers - 1:
            aux["attn_logp"] = attn_logp      # (B,H,T,T)
            aux["v"] = v                      # (B,H,T,dk)

    pooled = jnp.tanh(h[:, 0, :] @ params["pool_w"] + params["pool_b"])
    logits = pooled @ params["cls_w"] + params["cls_b"]
    return logits, aux


def forward_collect_act_stats(cfg: ModelConfig, params, ids, mask):
    """Unquantized forward that records |activation| statistics at every
    activation quantization site — the calibration pass (§3.1).

    Returns (act_q, act_max): two (L, 4) arrays with the 99.99th percentile
    and the max of |x| at each site (paper: "top 0.01% largest value").
    Weight abs-max is computed by the same artifact from params directly.
    """
    B, T = ids.shape
    d, H, dk = cfg.d_model, cfg.n_heads, cfg.d_head
    h = params["emb_word"][ids] + params["emb_pos"][None, :T, :]
    h = layer_norm(h, params["emb_ln_g"], params["emb_ln_b"])
    attn_bias = (1.0 - mask)[:, None, None, :] * NEG_INF

    qs, ms = [], []

    def record(x):
        a = jnp.abs(x).reshape(-1)
        qs.append(jnp.quantile(a, 0.9999))
        ms.append(jnp.max(a))

    for l in range(cfg.n_layers):
        record(h)
        q = h @ params[f"l{l}_wq"] + params[f"l{l}_bq"]
        k = h @ params[f"l{l}_wk"] + params[f"l{l}_bk"]
        v = h @ params[f"l{l}_wv"] + params[f"l{l}_bv"]

        def split(x):
            return x.reshape(B, T, H, dk).transpose(0, 2, 1, 3)

        q, k, v = split(q), split(k), split(v)
        scores = (q @ k.transpose(0, 1, 3, 2)) / jnp.sqrt(jnp.float32(dk)) + attn_bias
        attn = jax.nn.softmax(scores, axis=-1)
        oa = (attn @ v).transpose(0, 2, 1, 3).reshape(B, T, d)
        record(oa)
        attn_out = oa @ params[f"l{l}_wo"] + params[f"l{l}_bo"]
        h = layer_norm(h + attn_out, params[f"l{l}_ln1_g"], params[f"l{l}_ln1_b"])
        record(h)
        f = jax.nn.gelu(h @ params[f"l{l}_w1"] + params[f"l{l}_b1"], approximate=False)
        record(f)
        f2 = f @ params[f"l{l}_w2"] + params[f"l{l}_b2"]
        h = layer_norm(h + f2, params[f"l{l}_ln2_g"], params[f"l{l}_ln2_b"])

    act_q = jnp.stack(qs).reshape(cfg.n_layers, ModelConfig.N_ACT_SITES)
    act_max = jnp.stack(ms).reshape(cfg.n_layers, ModelConfig.N_ACT_SITES)
    return act_q, act_max


def weight_abs_max(cfg: ModelConfig, params):
    """(L, 6) abs-max of each quantized weight matrix (weight-scale init)."""
    rows = []
    for l in range(cfg.n_layers):
        rows.append(jnp.stack([jnp.max(jnp.abs(params[f"l{l}_{w}"])) for w in ModelConfig.W_SITE_NAMES]))
    return jnp.stack(rows)
