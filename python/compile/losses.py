"""Distillation + task losses (paper §3.3, §4.2, Eq. 6/8/9/10).

  L_final = L_train + alpha * L_output + beta * (L_attention + L_value)

* L_train     — softmax cross-entropy on the student logits.
* L_output    — MSE between student and teacher logits (Eq. 6).
* L_attention — MiniLM-style KL between the *last-layer* attention
                distributions, summed over heads (Eq. 8). Last-layer-only
                distillation is what lets a deeper teacher train a
                shallower student without a layer map (§4.2).
* L_value     — KL between the value-relation distributions
                softmax(v vᵀ / sqrt(d_k)) of student and teacher (Eq. 9).

``alpha`` / ``beta`` arrive as traced scalars: alpha=beta=0 reproduces the
"w/o KD" ablations of Table 3 and the plain-QAT baselines from the same
AOT artifact.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp


def cross_entropy(logits, labels):
    logp = jax.nn.log_softmax(logits, axis=-1)
    nll = -jnp.take_along_axis(logp, labels[:, None], axis=-1)[:, 0]
    return jnp.mean(nll)


def accuracy_count(logits, labels):
    return jnp.sum((jnp.argmax(logits, axis=-1) == labels).astype(jnp.float32))


def output_kd(student_logits, teacher_logits):
    """Eq. 6 with MSE as L: mean squared logit difference."""
    return jnp.mean(jnp.square(student_logits - teacher_logits))


def _masked_kl(logp_s, logp_t, qmask):
    """KL(S || T) for (B, H, Tq, Tk) log-distributions over the last axis,
    summed over heads (Eq. 8's sum_a), averaged over valid query rows."""
    kl = jnp.sum(jnp.exp(logp_s) * (logp_s - logp_t), axis=-1)  # (B,H,Tq)
    kl = jnp.sum(kl, axis=1)                                    # sum over heads
    denom = jnp.maximum(jnp.sum(qmask), 1.0)
    return jnp.sum(kl * qmask[:, None] if kl.ndim == 2 else kl * qmask) / denom


def attention_kd(attn_logp_s, attn_logp_t, mask):
    """Eq. 8: sum_a KL(A_a^S || A_a^T) over the last layer, mask-aware."""
    kl = jnp.sum(jnp.exp(attn_logp_s) * (attn_logp_s - attn_logp_t), axis=-1)  # (B,H,T)
    kl = jnp.sum(kl, axis=1)  # (B,T) summed over heads
    denom = jnp.maximum(jnp.sum(mask), 1.0)
    return jnp.sum(kl * mask) / denom


def value_relation_logp(v, mask, d_head):
    """log softmax(v vᵀ / sqrt(d_k)) with padded keys masked out.

    v: (B, H, T, dk); mask: (B, T)."""
    vr = (v @ v.transpose(0, 1, 3, 2)) / jnp.sqrt(jnp.float32(d_head))
    vr = vr + (1.0 - mask)[:, None, None, :] * (-1e9)
    return jax.nn.log_softmax(vr, axis=-1)


def value_kd(v_s, v_t, mask, d_head):
    """Eq. 9: sum_a KL over the value-relation distributions."""
    logp_s = value_relation_logp(v_s, mask, d_head)
    logp_t = value_relation_logp(v_t, mask, d_head)
    return attention_kd(logp_s, logp_t, mask)


def combined_loss(student_logits, student_aux, teacher_logits, teacher_aux,
                  labels, mask, d_head, alpha, beta):
    """Eq. 10. Returns (total, parts dict)."""
    l_train = cross_entropy(student_logits, labels)
    l_out = output_kd(student_logits, jax.lax.stop_gradient(teacher_logits))
    l_att = attention_kd(student_aux["attn_logp"],
                         jax.lax.stop_gradient(teacher_aux["attn_logp"]), mask)
    l_val = value_kd(student_aux["v"],
                     jax.lax.stop_gradient(teacher_aux["v"]), mask, d_head)
    total = l_train + alpha * l_out + beta * (l_att + l_val)
    parts = {"train": l_train, "output": l_out, "attention": l_att, "value": l_val}
    return total, parts
