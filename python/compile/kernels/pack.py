"""Pallas int4 pack/unpack kernels.

The paper's 5.3x bits-reduction claim rests on int4 *storage*: two weight
codes per byte. These kernels do the (un)packing as tiled Pallas calls so
the same BlockSpec schedule used for the matmul covers the repack path
(weights are packed once offline, unpacked on the fly in qmatmul4's
kernel; this standalone pair exists for the weight-conversion pipeline
and as the unit-test surface for the bit manipulation).

Offset encoding: nibble = code + 7 in [0, 15] — the paper's k-bit grid is
[-2^{k-1}+1, 2^{k-1}] = [-7, 8] for k=4, which does NOT fit a two's-
complement nibble ([-8, 7]).
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from . import ref

BLOCK = 256


def _pack_kernel(q_ref, p_ref):
    q = q_ref[...].astype(jnp.int32) + ref.INT4_OFFSET
    lo = q[..., 0::2]
    hi = q[..., 1::2]
    p_ref[...] = lo | (hi << 4)


@jax.jit
def pack_int4(q):
    """(r, c) int32 codes in [-7, 8], c even → (r, c//2) packed bytes."""
    r, c = q.shape
    br = min(BLOCK, r)
    assert r % br == 0 and c % 2 == 0
    return pl.pallas_call(
        _pack_kernel,
        grid=(r // br,),
        in_specs=[pl.BlockSpec((br, c), lambda i: (i, 0))],
        out_specs=pl.BlockSpec((br, c // 2), lambda i: (i, 0)),
        out_shape=jax.ShapeDtypeStruct((r, c // 2), jnp.int32),
        interpret=True,
    )(q)


def _unpack_kernel(p_ref, q_ref):
    p = p_ref[...]
    lo = (p & 0xF) - ref.INT4_OFFSET
    hi = ((p >> 4) & 0xF) - ref.INT4_OFFSET
    q_ref[...] = jnp.stack([lo, hi], axis=-1).reshape(q_ref.shape)


@functools.partial(jax.jit, static_argnames=("out_dim",))
def unpack_int4(p, out_dim: int):
    """(r, c//2) packed bytes → (r, c) int32 codes."""
    r, cp = p.shape
    assert out_dim == cp * 2
    br = min(BLOCK, r)
    assert r % br == 0
    return pl.pallas_call(
        _unpack_kernel,
        grid=(r // br,),
        in_specs=[pl.BlockSpec((br, cp), lambda i: (i, 0))],
        out_specs=pl.BlockSpec((br, out_dim), lambda i: (i, 0)),
        out_shape=jax.ShapeDtypeStruct((r, out_dim), jnp.int32),
        interpret=True,
    )(p)
