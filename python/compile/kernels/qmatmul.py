"""Pallas quantized-matmul kernel — the paper's contribution-3 hot path.

The paper implements int4 GEMM as CUDA kernels on T4 (threadblock tiles
staged through shared memory, WMMA MACs). Re-expressed for the TPU/Pallas
model (see DESIGN.md §Hardware-Adaptation):

  * threadblock tile      → ``BlockSpec`` tile staged into VMEM,
  * WMMA fragment         → f32/int32 accumulator block held in VMEM
                            across the K grid dimension,
  * shared-memory A/B     → (bm, bk) activation and (bk, bn) weight blocks,
  * int4 storage          → weights arrive as *offset-packed* bytes
                            (two nibbles per byte, see pack.py) and are
                            unpacked in-kernel — in registers, exactly as
                            the CUDA kernel does — halving HBM→VMEM weight
                            traffic.

Kernels MUST run ``interpret=True`` here: real TPU lowering emits a Mosaic
custom-call the CPU PJRT plugin cannot execute. Correctness is pytest vs
``ref.qmatmul``; TPU performance is estimated analytically (DESIGN.md
§Perf) from VMEM footprint and MXU utilization.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from . import ref

# Default tile sizes. Chosen so at paper dims (k=n=768..3072) the VMEM
# working set  bm*bk*4 + bk*bn (int8) + bm*bn*4  stays well under 16 MiB
# while keeping the MXU-shaped (128x128) inner dot.
BM, BK, BN = 64, 128, 128


def _qmm_kernel(x_ref, wq_ref, sx_ref, sw_ref, o_ref, *, nk: int, bits: float):
    """One (i, j, k) grid step: quantize the activation block, integer-MAC
    against the weight block, accumulate; dequantize on the last K step."""
    k = pl.program_id(2)

    @pl.when(k == 0)
    def _init():
        o_ref[...] = jnp.zeros_like(o_ref)

    x = x_ref[...]
    sx = sx_ref[...]                      # (bm, 1) per-row activation scales
    xq = ref.quantize_int(x, sx, bits)    # integer codes, f32 carrier
    w = wq_ref[...].astype(jnp.float32)   # integer codes
    # Integer MAC (exact in f32: |codes| <= 2^7, bk <= 2^11 => acc < 2^22).
    o_ref[...] += jnp.dot(xq, w, preferred_element_type=jnp.float32)

    @pl.when(k == nk - 1)
    def _dequant():
        o_ref[...] = o_ref[...] * sx * sw_ref[...]


@functools.partial(jax.jit, static_argnames=("bits", "bm", "bk", "bn"))
def qmatmul(x, wq, sx, sw, *, bits: float = 8.0, bm: int = BM, bk: int = BK, bn: int = BN):
    """Quantized matmul  out = (round(clamp(x/sx)) @ wq) * sx * sw.

    x:  (m, k) f32 activations.
    wq: (k, n) int8 weight codes (already quantized offline).
    sx: (m, 1) f32 per-row activation scales.
    sw: (1, n) f32 per-output-channel weight scales.
    """
    m, k = x.shape
    k2, n = wq.shape
    assert k == k2, (k, k2)
    assert m % bm == 0 and k % bk == 0 and n % bn == 0, (x.shape, wq.shape, (bm, bk, bn))
    nk = k // bk
    grid = (m // bm, n // bn, nk)
    return pl.pallas_call(
        functools.partial(_qmm_kernel, nk=nk, bits=bits),
        grid=grid,
        in_specs=[
            pl.BlockSpec((bm, bk), lambda i, j, kk: (i, kk)),
            pl.BlockSpec((bk, bn), lambda i, j, kk: (kk, j)),
            pl.BlockSpec((bm, 1), lambda i, j, kk: (i, 0)),
            pl.BlockSpec((1, bn), lambda i, j, kk: (0, j)),
        ],
        out_specs=pl.BlockSpec((bm, bn), lambda i, j, kk: (i, j)),
        out_shape=jax.ShapeDtypeStruct((m, n), jnp.float32),
        interpret=True,
    )(x, wq, sx, sw)


def _qmm4_kernel(x_ref, wp_ref, sx_ref, sw_ref, o_ref, *, nk: int):
    """int4 variant: the weight block arrives *packed* (bk/2 rows of bytes,
    two K-rows per byte) and is unpacked in-kernel — register-level
    unpacking, the direct analogue of the paper's CUDA int4 path. Weight
    HBM→VMEM traffic is halved vs int8."""
    k = pl.program_id(2)

    @pl.when(k == 0)
    def _init():
        o_ref[...] = jnp.zeros_like(o_ref)

    x = x_ref[...]
    sx = sx_ref[...]
    xq = ref.quantize_int(x, sx, 4.0)

    p = wp_ref[...].astype(jnp.int32)           # (bk//2, bn) packed bytes
    lo = (p & 0xF) - ref.INT4_OFFSET            # K rows 0,2,4,...
    hi = ((p >> 4) & 0xF) - ref.INT4_OFFSET     # K rows 1,3,5,...
    w = jnp.stack([lo, hi], axis=1).reshape(p.shape[0] * 2, p.shape[1])
    o_ref[...] += jnp.dot(xq, w.astype(jnp.float32), preferred_element_type=jnp.float32)

    @pl.when(k == nk - 1)
    def _dequant():
        o_ref[...] = o_ref[...] * sx * sw_ref[...]


@functools.partial(jax.jit, static_argnames=("bm", "bk", "bn"))
def qmatmul4(x, wp, sx, sw, *, bm: int = BM, bk: int = BK, bn: int = BN):
    """int4 quantized matmul over nibble-packed weights.

    wp: (k//2, n) int32 byte values — ``ref.pack_int4`` of the (k, n) codes
    along axis 0 (i.e. pack pairs of K rows: byte r holds K rows 2r, 2r+1).
    """
    m, k = x.shape
    kp, n = wp.shape
    assert kp * 2 == k, (kp, k)
    assert m % bm == 0 and k % bk == 0 and n % bn == 0
    nk = k // bk
    grid = (m // bm, n // bn, nk)
    return pl.pallas_call(
        functools.partial(_qmm4_kernel, nk=nk),
        grid=grid,
        in_specs=[
            pl.BlockSpec((bm, bk), lambda i, j, kk: (i, kk)),
            pl.BlockSpec((bk // 2, bn), lambda i, j, kk: (kk, j)),
            pl.BlockSpec((bm, 1), lambda i, j, kk: (i, 0)),
            pl.BlockSpec((1, bn), lambda i, j, kk: (0, j)),
        ],
        out_specs=pl.BlockSpec((bm, bn), lambda i, j, kk: (i, j)),
        out_shape=jax.ShapeDtypeStruct((m, n), jnp.float32),
        interpret=True,
    )(x, wp, sx, sw)


def pack_weights_k(wq):
    """Pack (k, n) int codes along K into (k//2, n) bytes for qmatmul4."""
    k, n = wq.shape
    assert k % 2 == 0
    qo = (wq.astype(jnp.int32) + ref.INT4_OFFSET)
    lo = qo[0::2, :]
    hi = qo[1::2, :]
    return lo | (hi << 4)


def vmem_bytes(bm: int = BM, bk: int = BK, bn: int = BN, int4: bool = False) -> int:
    """Analytic VMEM working set of one grid step (DESIGN.md §Perf)."""
    x_tile = bm * bk * 4           # f32 activations
    w_tile = bk * bn * (1 if not int4 else 1) // (2 if int4 else 1)
    acc = bm * bn * 4
    scales = (bm + bn) * 4
    return x_tile + w_tile + acc + scales
