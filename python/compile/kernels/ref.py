"""Pure-jnp oracles for the L1 kernels.

Everything in this file is the *ground truth* the Pallas kernels and the
custom-VJP quantizers are tested against (pytest + hypothesis). It follows
the paper's notation:

  Q[x] = s * round(clamp(x / s, l_min, l_max))          (Eq. 1)

with, for k-bit quantization,

  l_min = -2^{k-1} + 1,   l_max = 2^{k-1}.

Note the *asymmetric* bound (l_max = 2^{k-1}, not 2^{k-1} - 1) — for k=4
the integer grid is [-7, 8], which is why the int4 packing uses an offset
(nibble = q + 7 in [0, 15]) rather than two's-complement nibbles.
"""

from __future__ import annotations

import jax.numpy as jnp


def qbounds(bits):
    """(l_min, l_max) for k-bit quantization per the paper's convention."""
    lmax = jnp.exp2(bits - 1.0)
    return -lmax + 1.0, lmax


def quantize_int(x, s, bits):
    """Integer codes round(clamp(x/s, l_min, l_max)) as float values."""
    lmin, lmax = qbounds(bits)
    return jnp.round(jnp.clip(x / s, lmin, lmax))


def fake_quant(x, s, bits):
    """Eq. (1): quantize-dequantize (the QAT forward)."""
    return s * quantize_int(x, s, bits)


def quant_error(x, s, bits):
    """||Q[x] - x||^2 — the objective the MSE-based scale gradient descends."""
    d = fake_quant(x, s, bits) - x
    return jnp.sum(d * d)


def _reduce_to_shape(g, shape):
    """Sum-reduce a gradient onto a broadcastable scale shape (per-tensor
    scalar or per-row (r, 1) scales)."""
    g = jnp.asarray(g)
    shape = tuple(shape)
    if g.shape == shape:
        return g
    while g.ndim > len(shape):
        g = jnp.sum(g, axis=0)
    axes = tuple(i for i, (gd, sd) in enumerate(zip(g.shape, shape)) if sd == 1 and gd != 1)
    if axes:
        g = jnp.sum(g, axis=axes, keepdims=True)
    return g.reshape(shape)


def mse_scale_grad(x, s, bits):
    """Paper §4.1.2: Gradient(s) := d||Q[x]-x||^2/ds = 2 (Q[x]-x) * round(x/s),
    summed over the tensor (reduced onto s's shape for per-row scales).

    This gradient deliberately ignores the upstream task-loss cotangent —
    the scale is driven to minimize quantization MSE, which is the paper's
    core algorithmic contribution.
    """
    v = quantize_int(x, s, bits)
    g = 2.0 * (s * v - x) * v
    return _reduce_to_shape(g, jnp.shape(s))


def ste_scale_grad(x, s, bits, upstream=None):
    """§4.1.1 / LSQ (Esser et al. 2019; the KDLSQ baseline): per-element

        d Q[x]/ds = round(x/s) - x/s     for in-range x,
                  = l_min or l_max       for clipped x,

    multiplied by the upstream cotangent and summed onto s's shape."""
    lmin, lmax = qbounds(bits)
    r = x / s
    in_range = (r >= lmin) & (r <= lmax)
    per_elem = jnp.where(in_range, jnp.round(r) - r, jnp.clip(r, lmin, lmax))
    if upstream is None:
        upstream = jnp.ones_like(x)
    return _reduce_to_shape(upstream * per_elem, jnp.shape(s))


def ste_x_grad(x, s, bits, upstream=None):
    """Straight-through gradient for x: pass-through inside the clip range."""
    lmin, lmax = qbounds(bits)
    r = x / s
    mask = ((r >= lmin) & (r <= lmax)).astype(x.dtype)
    if upstream is None:
        upstream = jnp.ones_like(x)
    return upstream * mask


# ---------------------------------------------------------------------------
# Quantized matmul (the inference-path oracle for the Pallas kernel)
# ---------------------------------------------------------------------------

def qmatmul(x, wq, sx, sw, bits):
    """Quantized matmul oracle:

      xq  = round(clamp(x / sx))       per-row activation quantization
      acc = xq @ wq                    integer MAC (exact in f32 here)
      out = acc * sx * sw              dequantize

    x: (m, k) f32; wq: (k, n) integer codes; sx: (m, 1) or scalar;
    sw: (1, n) or scalar (per-output-channel weight scales).
    """
    xq = quantize_int(x, sx, bits)
    acc = jnp.matmul(xq, wq.astype(jnp.float32))
    sw = jnp.reshape(sw, (1, -1)) if jnp.ndim(sw) > 0 else sw
    return acc * sx * sw


# ---------------------------------------------------------------------------
# int4 packing (two offset-nibbles per byte)
# ---------------------------------------------------------------------------

INT4_OFFSET = 7  # maps q in [-7, 8] to nibble in [0, 15]


def pack_int4(q):
    """Pack integer codes q (int32 values in [-7, 8], last dim even) into
    byte values: low nibble = q[..., 0::2], high nibble = q[..., 1::2]."""
    qo = (q + INT4_OFFSET).astype(jnp.int32)
    lo = qo[..., 0::2]
    hi = qo[..., 1::2]
    return lo | (hi << 4)


def unpack_int4(p, out_dim):
    """Inverse of pack_int4; p holds byte values in [0, 255]."""
    lo = (p & 0xF) - INT4_OFFSET
    hi = ((p >> 4) & 0xF) - INT4_OFFSET
    return jnp.stack([lo, hi], axis=-1).reshape(*p.shape[:-1], out_dim)
