"""Learned-step-size quantizers with the paper's custom scale gradients.

``fake_quant(x, s, bits, mse_flag)`` implements Eq. (1) forward and a
``jax.custom_vjp`` backward with BOTH scale-gradient rules, selected by the
*traced* ``mse_flag`` input (1.0 = MKQ-BERT's MSE-based gradient, 0.0 = the
STE/LSQ gradient used by KDLSQ). Keeping the selector traced means a single
AOT artifact serves both the MKQ runs and the KDLSQ baseline rows of
Tables 1 and 3 — the Rust coordinator just feeds a different scalar.

Gradients:
  w.r.t. x  — straight-through inside the clip range (both modes).
  w.r.t. s  — MSE mode (paper §4.1.2):
                 Gradient(s) = 2 (Q[x]-x) * round(clamp(x/s)), summed.
              The upstream cotangent is *ignored*: the scale descends the
              quantization MSE directly (this is the paper's definition
              "∂f/∂s := Gradient(s)").
            — STE mode (§4.1.1 / LSQ):
                 per-element (round(x/s) - x/s) in range, clip bound
                 outside, times the upstream cotangent, summed.
  w.r.t. bits / mse_flag — zero (selector inputs, never trained).

``bits`` is also traced (f32 code: 4.0 / 8.0 / 32.0), so one artifact
serves every per-layer bit configuration of Table 1.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp

from . import ref


@jax.custom_vjp
def fake_quant(x, s, bits, mse_flag):
    return ref.fake_quant(x, s, bits)


def _fq_fwd(x, s, bits, mse_flag):
    return ref.fake_quant(x, s, bits), (x, s, bits, mse_flag)


def _fq_bwd(res, g):
    x, s, bits, mse_flag = res
    gx = ref.ste_x_grad(x, s, bits, upstream=g)
    g_mse = ref.mse_scale_grad(x, s, bits)
    g_ste = ref.ste_scale_grad(x, s, bits, upstream=g)
    gs = mse_flag * g_mse + (1.0 - mse_flag) * g_ste
    # At bits>=32 the caller selects the identity branch; the MSE gradient
    # (which ignores the upstream cotangent by design) must not leak into
    # the scale there.
    gs = gs * jnp.asarray(bits < 31.5, dtype=gs.dtype)
    return gx, gs, jnp.zeros_like(bits), jnp.zeros_like(mse_flag)


fake_quant.defvjp(_fq_fwd, _fq_bwd)


def maybe_fake_quant(x, s, bits, mse_flag):
    """fake_quant that degrades to identity for bits >= 32 (fp32 path).

    Used by the model so the same traced graph can run any row of Table 1;
    the fp32 branch still costs the quant arithmetic but never executes on
    the serving path (serving uses the integer kernels in qmatmul.py).
    """
    q = fake_quant(x, s, bits, mse_flag)
    return jnp.where(bits >= 31.5, x, q)
