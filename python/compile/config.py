"""Model / training configuration shared by the L2 graph and the AOT manifest.

The Rust coordinator never imports this — it reads the emitted
``artifacts/manifest.txt`` which records every dimension below. Changing a
value here and re-running ``make artifacts`` is the only config channel
between the layers.

Presets:
  * ``default`` — scaled-down TinyBERT-shaped encoder used for the QAT
    experiments (Tables 1 & 3). Dims are reduced so a full Table-1 sweep
    runs on CPU in minutes; the quantization pipeline is dimension-
    agnostic (DESIGN.md §Substitutions).
  * ``tinybert`` — the paper's TinyBERT4 dims (L=4, d=312, d_i=1200,
    A_h=12).
  * ``bert_base_layer`` — BERT-base layer dims used by the Table-2
    per-layer latency benchmarks (d=768, d_i=3072, A_h=12).
"""

from __future__ import annotations

import dataclasses


@dataclasses.dataclass(frozen=True)
class ModelConfig:
    vocab: int = 512
    seq: int = 24
    n_layers: int = 4
    d_model: int = 96
    n_heads: int = 4
    d_ff: int = 384
    n_classes: int = 2
    batch: int = 16          # training batch size
    eval_batch: int = 64     # eval batch size
    k_steps: int = 10        # lax.scan steps per train_step execution

    @property
    def d_head(self) -> int:
        assert self.d_model % self.n_heads == 0
        return self.d_model // self.n_heads

    # Quantized matmul sites per transformer layer (DESIGN.md):
    # activations: qkv-in, attn-out-in, ffn1-in, ffn2-in.
    N_ACT_SITES = 4
    ACT_SITE_NAMES = ("qkv_in", "attn_out_in", "ffn1_in", "ffn2_in")
    # weights: Wq, Wk, Wv, Wo, W1, W2.
    N_W_SITES = 6
    W_SITE_NAMES = ("wq", "wk", "wv", "wo", "w1", "w2")


PRESETS = {
    "default": ModelConfig(),
    "tinybert": ModelConfig(vocab=512, seq=32, n_layers=4, d_model=312, n_heads=12, d_ff=1200),
    "bert_base_layer": ModelConfig(vocab=512, seq=43, n_layers=1, d_model=768, n_heads=12, d_ff=3072),
}


# ---------------------------------------------------------------------------
# Parameter / scale specs (the flat ordering contract with Rust)
#
# These live here (not in model.py) so jax-free consumers — the MKQC
# checkpoint exporter ``export_ckpt.py`` — can import them; model.py
# re-exports both names.
# ---------------------------------------------------------------------------

def param_specs(cfg: ModelConfig):
    """[(name, shape)] in canonical order."""
    specs = [
        ("emb_word", (cfg.vocab, cfg.d_model)),
        ("emb_pos", (cfg.seq, cfg.d_model)),
        ("emb_ln_g", (cfg.d_model,)),
        ("emb_ln_b", (cfg.d_model,)),
    ]
    for l in range(cfg.n_layers):
        d, f = cfg.d_model, cfg.d_ff
        specs += [
            (f"l{l}_wq", (d, d)), (f"l{l}_bq", (d,)),
            (f"l{l}_wk", (d, d)), (f"l{l}_bk", (d,)),
            (f"l{l}_wv", (d, d)), (f"l{l}_bv", (d,)),
            (f"l{l}_wo", (d, d)), (f"l{l}_bo", (d,)),
            (f"l{l}_ln1_g", (d,)), (f"l{l}_ln1_b", (d,)),
            (f"l{l}_w1", (d, f)), (f"l{l}_b1", (f,)),
            (f"l{l}_w2", (f, d)), (f"l{l}_b2", (d,)),
            (f"l{l}_ln2_g", (d,)), (f"l{l}_ln2_b", (d,)),
        ]
    specs += [
        ("pool_w", (cfg.d_model, cfg.d_model)),
        ("pool_b", (cfg.d_model,)),
        ("cls_w", (cfg.d_model, cfg.n_classes)),
        ("cls_b", (cfg.n_classes,)),
    ]
    return specs


def scale_specs(cfg: ModelConfig):
    """Quantization scales, all shape (1,): 4 activation sites + 6 weight
    sites per layer, in layer-major order."""
    specs = []
    for l in range(cfg.n_layers):
        for a in ModelConfig.ACT_SITE_NAMES:
            specs.append((f"l{l}_s_act_{a}", (1,)))
        for w in ModelConfig.W_SITE_NAMES:
            specs.append((f"l{l}_s_w_{w}", (1,)))
    return specs
