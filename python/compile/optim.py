"""Adam optimizer over arbitrary pytrees (paper §5.2 uses Adam with
separate learning rates for weights, activation scales and weight scales;
the per-step lr values arrive from the Rust scheduler)."""

from __future__ import annotations

import jax
import jax.numpy as jnp

B1, B2, EPS = 0.9, 0.999, 1e-8


def adam_update(params, grads, m, v, step, lr):
    """One Adam step over a pytree. ``step`` is the 1-based update index
    (f32 scalar); ``lr`` a traced scalar. Returns (params, m, v)."""
    bc1 = 1.0 - jnp.power(B1, step)
    bc2 = 1.0 - jnp.power(B2, step)
    new_m = jax.tree.map(lambda mi, g: B1 * mi + (1.0 - B1) * g, m, grads)
    new_v = jax.tree.map(lambda vi, g: B2 * vi + (1.0 - B2) * jnp.square(g), v, grads)
    new_p = jax.tree.map(
        lambda p, mi, vi: p - lr * (mi / bc1) / (jnp.sqrt(vi / bc2) + EPS),
        params, new_m, new_v,
    )
    return new_p, new_m, new_v


def zeros_like_tree(t):
    return jax.tree.map(jnp.zeros_like, t)
