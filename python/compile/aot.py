"""AOT artifact emitter: lower every L2 step function to HLO text.

Run once at build time (``make artifacts``); the Rust coordinator then
loads ``artifacts/*.hlo.txt`` via ``HloModuleProto::from_text_file`` and
never touches Python again.

Interchange is HLO **text**, not ``.serialize()``: jax>=0.5 emits
HloModuleProtos with 64-bit instruction ids which xla_extension 0.5.1
rejects (``proto.id() <= INT_MAX``); the text parser reassigns ids and
round-trips cleanly (see /opt/xla-example/README.md).

The manifest (``artifacts/manifest.txt``) is a plain line-oriented format
(the vendored crate set has no serde/json):

    config <key> <int>
    artifact <name> <file>
    in <name> <dtype> <d0>x<d1>...      # rank-0 writes "scalar"
    out <name> <dtype> <dims>
    end

Input/output order in the manifest IS the execution order contract.
"""

from __future__ import annotations

import argparse
import os

import jax
import jax.numpy as jnp
import numpy as np
from jax._src.lib import xla_client as xc

from . import bench_layer, model, steps
from .config import PRESETS, ModelConfig
from .kernels import qmatmul


def to_hlo_text(lowered) -> str:
    mlir_mod = lowered.compiler_ir("stablehlo")
    comp = xc._xla.mlir.mlir_module_to_xla_computation(
        str(mlir_mod), use_tuple_args=False, return_tuple=True
    )
    return comp.as_hlo_text()


def _spec(shape, dtype=jnp.float32):
    return jax.ShapeDtypeStruct(tuple(shape), dtype)


def _dims(shape):
    return "x".join(str(d) for d in shape) if len(shape) else "scalar"


class Emitter:
    def __init__(self, out_dir: str):
        self.out_dir = out_dir
        self.lines: list[str] = []
        os.makedirs(out_dir, exist_ok=True)

    def config(self, key: str, val: int):
        self.lines.append(f"config {key} {val}")

    def emit(self, name: str, fn, ins, outs):
        """ins/outs: [(name, ShapeDtypeStruct)]. Lowers fn(*in_specs)."""
        specs = [s for _, s in ins]
        # keep_unused: the manifest promises the full input list even when a
        # graph ignores some tensors (e.g. `calibrate` never touches the
        # classifier head) — without this, jit prunes them and PJRT rejects
        # the execute-time buffer count.
        lowered = jax.jit(fn, keep_unused=True).lower(*specs)
        text = to_hlo_text(lowered)
        fname = f"{name}.hlo.txt"
        with open(os.path.join(self.out_dir, fname), "w") as f:
            f.write(text)
        self.lines.append(f"artifact {name} {fname}")
        for n, s in ins:
            self.lines.append(f"in {n} {s.dtype} {_dims(s.shape)}")
        for n, s in outs:
            self.lines.append(f"out {n} {s.dtype} {_dims(s.shape)}")
        self.lines.append("end")
        print(f"  emitted {name}: {len(text)/1e6:.2f} MB, {len(ins)} in / {len(outs)} out")

    def finish(self):
        with open(os.path.join(self.out_dir, "manifest.txt"), "w") as f:
            f.write("\n".join(self.lines) + "\n")


def _named(prefix, specs, dtype=jnp.float32):
    return [(f"{prefix}{n}", _spec(s, dtype)) for n, s in specs]


def emit_training_artifacts(em: Emitter, cfg: ModelConfig):
    p_specs = model.param_specs(cfg)
    s_specs = model.scale_specs(cfg)
    K, B, T, L = cfg.k_steps, cfg.batch, cfg.seq, cfg.n_layers
    EB = cfg.eval_batch

    for k, v in (("vocab", cfg.vocab), ("seq", T), ("n_layers", L),
                 ("d_model", cfg.d_model), ("n_heads", cfg.n_heads),
                 ("d_ff", cfg.d_ff), ("n_classes", cfg.n_classes),
                 ("batch", B), ("eval_batch", EB), ("k_steps", K),
                 ("n_params", len(p_specs)), ("n_scales", len(s_specs))):
        em.config(k, v)

    params_in = _named("p.", p_specs)
    scales_in = _named("s.", s_specs)

    # --- init -------------------------------------------------------------
    em.emit(
        "init", steps.make_init(cfg),
        ins=[("seed", _spec((1,), jnp.int32))],
        outs=params_in + scales_in,
    )

    # --- fp32 teacher finetuning (K-step scan) -----------------------------
    fp32_ins = (
        params_in
        + _named("m.", p_specs) + _named("v.", p_specs)
        + [("step", _spec((1,)))]
        + [("ids", _spec((K, B, T), jnp.int32)), ("mask", _spec((K, B, T))),
           ("labels", _spec((K, B), jnp.int32)), ("lr", _spec((K, 1)))]
    )
    fp32_outs = (params_in + _named("m.", p_specs) + _named("v.", p_specs)
                 + [("step", _spec((1,))), ("stats", _spec((K, 2)))])
    em.emit("train_fp32", steps.make_train_fp32_k(cfg), fp32_ins, fp32_outs)

    # --- QAT train step (K-step scan) --------------------------------------
    qat_state = (
        params_in + scales_in
        + _named("mp.", p_specs) + _named("vp.", p_specs)
        + _named("ms.", s_specs) + _named("vs.", s_specs)
        + [("step", _spec((1,)))]
    )
    qat_ins = (
        qat_state
        + _named("t.", p_specs)
        + [("ids", _spec((K, B, T), jnp.int32)), ("mask", _spec((K, B, T))),
           ("labels", _spec((K, B), jnp.int32)),
           ("lr_w", _spec((K, 1))), ("lr_sa", _spec((K, 1))), ("lr_sw", _spec((K, 1))),
           ("alpha", _spec((1,))), ("beta", _spec((1,))),
           ("mse_flag", _spec((1,))), ("lsq_flag", _spec((1,))),
           ("bits", _spec((L,)))]
    )
    qat_outs = qat_state + [("stats", _spec((K, 6)))]
    em.emit("train_step", steps.make_train_step_k(cfg), qat_ins, qat_outs)

    # --- eval (quantized student) ------------------------------------------
    em.emit(
        "eval_step", steps.make_eval_step(cfg),
        ins=params_in + scales_in + [
            ("bits", _spec((L,))),
            ("ids", _spec((EB, T), jnp.int32)), ("mask", _spec((EB, T))),
            ("labels", _spec((EB,), jnp.int32))],
        outs=[("correct", _spec((1,))), ("loss", _spec((1,))),
              ("logits", _spec((EB, cfg.n_classes)))],
    )

    # --- eval (fp32 teacher / baseline row) ---------------------------------
    em.emit(
        "teacher_eval", steps.make_teacher_eval(cfg),
        ins=params_in + [
            ("ids", _spec((EB, T), jnp.int32)), ("mask", _spec((EB, T))),
            ("labels", _spec((EB,), jnp.int32))],
        outs=[("correct", _spec((1,))), ("loss", _spec((1,))),
              ("logits", _spec((EB, cfg.n_classes)))],
    )

    # --- calibration ---------------------------------------------------------
    em.emit(
        "calibrate", steps.make_calibrate(cfg),
        ins=params_in + [("ids", _spec((B, T), jnp.int32)), ("mask", _spec((B, T)))],
        outs=[("act_q", _spec((L, 4))), ("act_max", _spec((L, 4))),
              ("w_max", _spec((L, 6)))],
    )

    # --- serving forward ------------------------------------------------------
    for sb in (1, 8, B):
        em.emit(
            f"serve_fwd_b{sb}", steps.make_serve_fwd(cfg),
            ins=params_in + scales_in + [
                ("bits", _spec((L,))),
                ("ids", _spec((sb, T), jnp.int32)), ("mask", _spec((sb, T)))],
            outs=[("logits", _spec((sb, cfg.n_classes)))],
        )


# Table-2 shape buckets: (batch, tokens-per-seq) chosen so batch*T matches
# the paper's "valid tokens" column (440/537/681 @ bs16; 1691/2011/2298 @ bs64).
TABLE2_BUCKETS = [(16, 28), (16, 34), (16, 43), (64, 27), (64, 32), (64, 36)]


def emit_table2_artifacts(em: Emitter, d: int = 768, d_ff: int = 3072, n_heads: int = 12):
    em.config("t2_d_model", d)
    em.config("t2_d_ff", d_ff)
    em.config("t2_n_heads", n_heads)
    w_specs = bench_layer.layer_weight_specs(d, d_ff)

    for (bs, t) in TABLE2_BUCKETS:
        h_in = [("h", _spec((bs, t, d))), ("mask", _spec((bs, t)))]
        out = [("h_out", _spec((bs, t, d)))]

        # fp32
        ins = h_in + _named("w.", w_specs)
        em.emit(f"layer_f32_b{bs}_t{t}", bench_layer.make_layer_fp32(n_heads), ins, out)

        # int8 / int4 share the scale tail.
        scale_tail = ([(f"sa_{n}", _spec((1,))) for n in ("qkv", "attn", "ffn1", "ffn2")]
                      + [(f"sw_{n}", _spec((1, s[1]))) for n, s in
                         [("q", (d, d)), ("k", (d, d)), ("v", (d, d)), ("o", (d, d)),
                          ("1", (d, d_ff)), ("2", (d_ff, d))]])

        int8_w = []
        for n, s in w_specs:
            dt = jnp.int8 if n.startswith("w") and len(s) == 2 else jnp.float32
            int8_w.append((f"w.{n}", _spec(s, dt)))
        em.emit(f"layer_int8_b{bs}_t{t}",
                bench_layer.make_layer_int(n_heads, 8.0, False, d, d_ff),
                h_in + int8_w + scale_tail, out)

        int4_w = []
        for n, s in w_specs:
            if n.startswith("w") and len(s) == 2:
                int4_w.append((f"w.{n}", _spec((s[0] // 2, s[1]), jnp.int32)))
            else:
                int4_w.append((f"w.{n}", _spec(s, jnp.float32)))
        em.emit(f"layer_int4_b{bs}_t{t}",
                bench_layer.make_layer_int(n_heads, 4.0, True, d, d_ff),
                h_in + int4_w + scale_tail, out)


def emit_kernel_artifacts(em: Emitter):
    """Standalone Pallas qmatmul artifacts (Rust-side numeric cross-check)."""
    m, k, n = 64, 128, 128
    em.emit(
        "qmatmul_pallas_int8",
        lambda x, wq, sx, sw: (qmatmul.qmatmul(x, wq, sx, sw, bits=8.0),),
        ins=[("x", _spec((m, k))), ("wq", _spec((k, n), jnp.int8)),
             ("sx", _spec((m, 1))), ("sw", _spec((1, n)))],
        outs=[("out", _spec((m, n)))],
    )
    em.emit(
        "qmatmul_pallas_int4",
        lambda x, wp, sx, sw: (qmatmul.qmatmul4(x, wp, sx, sw),),
        ins=[("x", _spec((m, k))), ("wp", _spec((k // 2, n), jnp.int32)),
             ("sx", _spec((m, 1))), ("sw", _spec((1, n)))],
        outs=[("out", _spec((m, n)))],
    )


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--out", default="../artifacts")
    ap.add_argument("--preset", default="default", choices=sorted(PRESETS))
    ap.add_argument("--skip-table2", action="store_true")
    args = ap.parse_args()

    jax.config.update("jax_platform_name", "cpu")
    cfg = PRESETS[args.preset]
    em = Emitter(args.out)
    print(f"emitting artifacts (preset={args.preset}) to {args.out}")
    emit_training_artifacts(em, cfg)
    if not args.skip_table2:
        emit_table2_artifacts(em)
    emit_kernel_artifacts(em)
    em.finish()
    print("manifest written")


if __name__ == "__main__":
    main()
