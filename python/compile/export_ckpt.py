"""MKQC checkpoint exporter — pure numpy, no jax.

Writes the flat-tensor binary format defined in
``rust/src/checkpoint/mod.rs`` (the authoritative byte-level spec) out of
the compile path's parameter flattening, so a model trained or
initialized on the Python side serves natively through
``mkq-bert serve-native --checkpoint FILE.mkqc``.

Layout recap (all little-endian): magic ``MKQC`` + u32 version +
7 x u32 dims (vocab, seq, n_layers, d_model, n_heads, d_ff, n_classes) +
u32 n_tensors + n_layers x u32 bits + n_layers x 4 x f32 activation
scales, then the tensor directory (u16 name_len, name, u8 dtype=0 (f32),
[v2: u8 panel-layout=0,] u8 rank, rank x u32 dims, u64 offset, u64 len),
[v2: u32 CRC-32 over all bytes so far + zero padding to a 16-byte-aligned
payload start,] then the raw payload bytes, then a u32 CRC-32 (zlib)
over the payload.

``--format`` selects version 1 (default — the long-standing
cross-language CI contract) or 2. This exporter always writes fp32
masters; the prepacked-panel dtypes of v2 are produced by
``mkq-bert ckpt migrate`` on the Rust side, whose reader loads either
version from either language unchanged.

Tensor names/shapes come from ``config.param_specs`` — the same flat
ordering contract the AOT manifest records — so the Rust reader's spec
check passes by construction.

Usage:
    python -m compile.export_ckpt --out model.mkqc [--preset default]
        [--bits 8,8,4,4 | --n-int4 N] [--seed 0]
        [--params params.npz] [--act-scales s.npz]

Without ``--params`` the exporter writes a BERT-style random init
(N(0, 0.02) matrices, unit LN gains, zero biases) — the smoke-test path
CI drives end to end. ``--params`` loads an ``.npz`` whose keys are the
spec names (e.g. a dump of QAT'd weights); ``--act-scales`` an ``.npz``
with key ``act_scales`` of shape (n_layers, 4).
"""

from __future__ import annotations

import argparse
import struct
import zlib

import numpy as np

from .config import PRESETS, ModelConfig, param_specs

MAGIC = b"MKQC"
VERSION = 1
VERSION_V2 = 2
DTYPE_F32 = 0
PAYLOAD_ALIGN = 16


def qmax(bits: int) -> float:
    """Paper grid l_max = 2^{k-1} (int8 grid for fp32 layers)."""
    b = 8 if bits == 32 else bits
    return float(1 << (b - 1))


def parse_bits(spec: str, n_layers: int) -> list[int]:
    bits = [int(p) for p in spec.split(",")]
    if len(bits) != n_layers:
        raise ValueError(f"bits spec {spec!r} has {len(bits)} entries, model has {n_layers} layers")
    for b in bits:
        if b not in (4, 8, 32):
            raise ValueError(f"unsupported bit width {b} (use 4, 8 or 32)")
    return bits


def bits_last_n_int4(n_layers: int, n_int4: int) -> list[int]:
    n_int4 = min(n_int4, n_layers)
    return [4 if l >= n_layers - n_int4 else 8 for l in range(n_layers)]


def default_act_scales(bits: list[int]) -> np.ndarray:
    """|act| ~ 6 after LayerNorm over the grid l_max — the uncalibrated
    fallback (mirrors ``runtime::native::default_act_scales``)."""
    return np.array([[6.0 / qmax(b)] * 4 for b in bits], dtype=np.float32)


def random_params(cfg: ModelConfig, seed: int) -> dict[str, np.ndarray]:
    """BERT-style init matching ``model.init_params`` distributions
    (numpy RNG — the values differ from the jax init, the shapes and
    statistics do not)."""
    rng = np.random.default_rng(seed)
    out = {}
    for name, shape in param_specs(cfg):
        if name.endswith("_g"):
            out[name] = np.ones(shape, np.float32)
        elif len(shape) == 2:
            out[name] = (0.02 * rng.standard_normal(shape)).astype(np.float32)
        else:
            out[name] = np.zeros(shape, np.float32)
    return out


def validate_header(cfg: ModelConfig, bits: list[int], act_scales: np.ndarray):
    """Mirror ``CkptHeader::validate`` on the Rust side, so a file the
    reader would reject is never produced (errors surface at export time,
    not at deploy time)."""
    if len(bits) != cfg.n_layers:
        raise ValueError(f"{len(bits)} bit entries for {cfg.n_layers} layers")
    if act_scales.shape != (cfg.n_layers, 4):
        raise ValueError(f"act_scales shape {act_scales.shape} != ({cfg.n_layers}, 4)")
    if cfg.d_model % cfg.n_heads != 0:
        raise ValueError(f"n_heads {cfg.n_heads} does not divide d_model {cfg.d_model}")
    for l, b in enumerate(bits):
        if b not in (4, 8, 32):
            raise ValueError(f"layer {l}: unsupported bit width {b} (use 4, 8 or 32)")
        if b == 4 and (cfg.d_model % 2 or cfg.d_ff % 2):
            raise ValueError(
                f"layer {l} is int4 but d_model {cfg.d_model} / d_ff {cfg.d_ff} "
                "are not both even (K-nibble packing)")
        row = act_scales[l]
        if b != 32 and not (np.all(np.isfinite(row)) and np.all(row > 0)):
            raise ValueError(f"layer {l}: act scales {row} must be finite and positive")


def write_checkpoint(path: str, cfg: ModelConfig, bits: list[int],
                     act_scales: np.ndarray, params: dict[str, np.ndarray],
                     version: int = VERSION) -> int:
    """Serialize one MKQC file (format ``version``, 1 or 2); returns the
    byte count written."""
    if version not in (VERSION, VERSION_V2):
        raise ValueError(f"unsupported checkpoint version {version} (use 1 or 2)")
    act_scales = np.asarray(act_scales, np.float32)
    validate_header(cfg, bits, act_scales)

    specs = param_specs(cfg)
    directory = bytearray()
    payload = bytearray()
    for name, shape in specs:
        if name not in params:
            raise KeyError(f"params missing spec tensor {name!r}")
        arr = np.ascontiguousarray(params[name], dtype="<f4")
        if arr.shape != tuple(shape):
            raise ValueError(f"{name}: shape {arr.shape} != spec {tuple(shape)}")
        raw = arr.tobytes()
        nb = name.encode("utf-8")
        directory += struct.pack("<H", len(nb)) + nb
        if version >= VERSION_V2:
            directory += struct.pack("<BBB", DTYPE_F32, 0, arr.ndim)  # dtype, layout, rank
        else:
            directory += struct.pack("<BB", DTYPE_F32, arr.ndim)
        directory += struct.pack(f"<{arr.ndim}I", *arr.shape)
        directory += struct.pack("<QQ", len(payload), len(raw))
        payload += raw

    header = MAGIC + struct.pack("<I", version)
    header += struct.pack("<7I", cfg.vocab, cfg.seq, cfg.n_layers,
                          cfg.d_model, cfg.n_heads, cfg.d_ff, cfg.n_classes)
    header += struct.pack("<I", len(specs))
    header += struct.pack(f"<{cfg.n_layers}I", *bits)
    header += act_scales.astype("<f4").tobytes()

    prefix = header + bytes(directory)
    if version >= VERSION_V2:
        # header/directory CRC, then zero padding to a 16-byte-aligned
        # payload start (recomputed by the reader, not stored)
        prefix += struct.pack("<I", zlib.crc32(prefix) & 0xFFFFFFFF)
        prefix += b"\x00" * ((PAYLOAD_ALIGN - len(prefix) % PAYLOAD_ALIGN) % PAYLOAD_ALIGN)

    crc = zlib.crc32(bytes(payload)) & 0xFFFFFFFF
    blob = prefix + bytes(payload) + struct.pack("<I", crc)
    with open(path, "wb") as f:
        f.write(blob)
    return len(blob)


def main():
    ap = argparse.ArgumentParser(description=__doc__.splitlines()[0])
    ap.add_argument("--out", required=True, help="output .mkqc path")
    ap.add_argument("--preset", default="default", choices=sorted(PRESETS))
    ap.add_argument("--bits", default=None, help="per-layer bits, e.g. 8,8,4,4")
    ap.add_argument("--n-int4", type=int, default=4,
                    help="last-N-layers-int4 rule when --bits is absent (default 4)")
    ap.add_argument("--seed", type=int, default=17)
    ap.add_argument("--params", default=None,
                    help=".npz of spec-named fp32 tensors (default: random init)")
    ap.add_argument("--act-scales", default=None,
                    help=".npz with key act_scales, shape (n_layers, 4)")
    ap.add_argument("--format", type=int, default=VERSION, choices=(VERSION, VERSION_V2),
                    help="MKQC format version to emit (default 1)")
    args = ap.parse_args()

    cfg = PRESETS[args.preset]
    bits = (parse_bits(args.bits, cfg.n_layers) if args.bits
            else bits_last_n_int4(cfg.n_layers, args.n_int4))
    if args.params:
        with np.load(args.params) as z:
            params = {k: z[k] for k in z.files}
    else:
        params = random_params(cfg, args.seed)
    if args.act_scales:
        with np.load(args.act_scales) as z:
            act = z["act_scales"]
    else:
        act = default_act_scales(bits)

    n = write_checkpoint(args.out, cfg, bits, act, params, version=args.format)
    print(f"wrote {args.out}: {n} bytes, MKQC v{args.format}, L={cfg.n_layers} "
          f"d={cfg.d_model} bits={bits} ({len(param_specs(cfg))} tensors)")


if __name__ == "__main__":
    main()
