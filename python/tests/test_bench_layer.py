"""Table-2 layer graphs: integer paths vs fp32 reference, int4 vs int8 packing."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from compile import bench_layer
from compile.kernels import ref

jax.config.update("jax_platform_name", "cpu")

D, DFF, H = 64, 128, 4
BS, T = 2, 8


def _weights(seed=0):
    rng = np.random.default_rng(seed)
    out = {}
    for name, shape in bench_layer.layer_weight_specs(D, DFF):
        if name.startswith("w") and len(shape) == 2:
            out[name] = rng.normal(scale=0.08, size=shape).astype(np.float32)
        elif name.endswith("_g"):
            out[name] = np.ones(shape, np.float32)
        else:
            out[name] = np.zeros(shape, np.float32)
    return out


def _quantize_weights(w, bits):
    """Per-output-channel symmetric quantization, exactly what Rust does."""
    lmax = 2 ** (bits - 1)
    lmax_store = 127 if bits == 8 else lmax   # int8 storage can't hold +128
    codes, scales = {}, {}
    for name, val in w.items():
        if name.startswith("w") and val.ndim == 2:
            s = np.abs(val).max(axis=0, keepdims=True) / lmax   # (1, n)
            q = np.clip(np.round(val / s), -lmax + 1, lmax_store)
            codes[name] = q.astype(np.int8)
            scales[name] = s.astype(np.float32)
    return codes, scales


def _inputs(seed=1):
    rng = np.random.default_rng(seed)
    h = rng.normal(scale=1.0, size=(BS, T, D)).astype(np.float32)
    mask = np.ones((BS, T), np.float32)
    return jnp.asarray(h), jnp.asarray(mask)


def _flat_w(w):
    return [jnp.asarray(w[n]) for n, _ in bench_layer.layer_weight_specs(D, DFF)]


def test_fp32_layer_shapes():
    h, mask = _inputs()
    layer = bench_layer.make_layer_fp32(H)
    (out,) = layer(h, mask, *_flat_w(_weights()))
    assert out.shape == (BS, T, D)
    assert np.all(np.isfinite(np.asarray(out)))


def _run_int(bits, packed):
    w = _weights()
    h, mask = _inputs()
    codes, wscales = _quantize_weights(w, bits)
    act_scale = 4.0 / (2 ** (bits - 1))
    flat = []
    for n, shape in bench_layer.layer_weight_specs(D, DFF):
        if n in codes:
            if packed:
                q = jnp.asarray(codes[n], jnp.int32)
                flat.append(ref.pack_int4(q.T).T if False else _pack_k(codes[n]))
            else:
                flat.append(jnp.asarray(codes[n]))
        else:
            flat.append(jnp.asarray(w[n]))
    sa = [jnp.asarray([act_scale], jnp.float32)] * 4
    sw = [jnp.asarray(wscales[n]) for n in ("wq", "wk", "wv", "wo", "w1", "w2")]
    layer = bench_layer.make_layer_int(H, float(bits), packed, D, DFF)
    (out,) = layer(h, mask, *flat, *sa, *sw)
    # fp32 oracle
    (want,) = bench_layer.make_layer_fp32(H)(h, mask, *_flat_w(w))
    return np.asarray(out), np.asarray(want)


def _pack_k(codes):
    """Pack (k, n) int8 codes along K into (k//2, n) bytes (offset nibbles)."""
    q = jnp.asarray(codes, jnp.int32) + ref.INT4_OFFSET
    return q[0::2, :] | (q[1::2, :] << 4)


def test_int8_layer_close_to_fp32():
    out, want = _run_int(8, packed=False)
    err = np.abs(out - want).mean() / (np.abs(want).mean() + 1e-9)
    assert err < 0.15, err


def test_int4_layer_close_but_worse_than_int8():
    out8, want = _run_int(8, packed=False)
    out4, _ = _run_int(4, packed=True)
    e8 = np.abs(out8 - want).mean()
    e4 = np.abs(out4 - want).mean()
    assert np.all(np.isfinite(out4))
    assert e4 > e8, (e4, e8)           # fewer bits -> strictly coarser
    assert e4 < 20 * e8 + 1.0          # ...but still in the same ballpark


def test_int4_unpack_matches_codes():
    codes = np.random.default_rng(2).integers(-7, 9, size=(D, DFF)).astype(np.int8)
    packed = _pack_k(codes)
    un = bench_layer._unpack_k(packed, D)
    np.testing.assert_array_equal(np.asarray(un), codes)


def test_int_mm_matches_ref_qmatmul():
    rng = np.random.default_rng(4)
    x = jnp.asarray(rng.normal(size=(8, 16)).astype(np.float32))
    wq = rng.integers(-127, 128, size=(16, 12)).astype(np.int8)
    sx = jnp.asarray([0.05], jnp.float32)
    sw = jnp.asarray(rng.uniform(0.01, 0.1, (1, 12)).astype(np.float32))
    out = bench_layer._int_mm(x, sx, jnp.asarray(wq), sw, 8.0)
    want = ref.qmatmul(x, jnp.asarray(wq, jnp.float32), sx, sw, 8.0)
    np.testing.assert_allclose(np.asarray(out), np.asarray(want), rtol=1e-5, atol=1e-4)
