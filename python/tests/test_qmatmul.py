"""Pallas quantized-matmul kernel vs the pure-jnp oracle (hypothesis sweeps)."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from compile.kernels import pack, qmatmul, ref

jax.config.update("jax_platform_name", "cpu")


def _mk(m, k, n, seed, bits):
    rng = np.random.default_rng(seed)
    x = rng.normal(size=(m, k)).astype(np.float32)
    lmin, lmax = -(2 ** (bits - 1)) + 1, 2 ** (bits - 1)
    wq = rng.integers(lmin, lmax + 1, size=(k, n)).astype(np.int8)
    sx = rng.uniform(0.05, 0.3, size=(m, 1)).astype(np.float32)
    sw = rng.uniform(0.01, 0.1, size=(1, n)).astype(np.float32)
    return jnp.asarray(x), jnp.asarray(wq), jnp.asarray(sx), jnp.asarray(sw)


def test_qmatmul_int8_matches_ref():
    x, wq, sx, sw = _mk(64, 128, 128, 0, 8)
    out = qmatmul.qmatmul(x, wq, sx, sw, bits=8.0)
    want = ref.qmatmul(x, wq, sx, sw, 8.0)
    np.testing.assert_allclose(np.asarray(out), np.asarray(want), rtol=1e-5, atol=1e-4)


def test_qmatmul_multiblock_grid():
    # Exercises K-accumulation across grid steps and multiple (i, j) tiles.
    x, wq, sx, sw = _mk(128, 256, 256, 1, 8)
    out = qmatmul.qmatmul(x, wq, sx, sw, bits=8.0, bm=64, bk=128, bn=128)
    want = ref.qmatmul(x, wq, sx, sw, 8.0)
    np.testing.assert_allclose(np.asarray(out), np.asarray(want), rtol=1e-5, atol=1e-4)


def test_qmatmul4_packed_matches_ref():
    x, wq, sx, sw = _mk(64, 128, 128, 2, 4)
    wp = qmatmul.pack_weights_k(jnp.asarray(wq, jnp.int32))
    out = qmatmul.qmatmul4(x, wp, sx, sw)
    want = ref.qmatmul(x, wq, sx, sw, 4.0)
    np.testing.assert_allclose(np.asarray(out), np.asarray(want), rtol=1e-5, atol=1e-4)


def test_pack_weights_k_roundtrip():
    rng = np.random.default_rng(3)
    wq = jnp.asarray(rng.integers(-7, 9, size=(256, 64)), jnp.int32)
    wp = qmatmul.pack_weights_k(wq)
    assert wp.shape == (128, 64)
    lo = (wp & 0xF) - ref.INT4_OFFSET
    hi = ((wp >> 4) & 0xF) - ref.INT4_OFFSET
    np.testing.assert_array_equal(np.asarray(lo), np.asarray(wq[0::2]))
    np.testing.assert_array_equal(np.asarray(hi), np.asarray(wq[1::2]))


@settings(max_examples=12, deadline=None)
@given(
    mi=st.integers(1, 3),
    ki=st.integers(1, 3),
    ni=st.integers(1, 2),
    bits=st.sampled_from([4, 8]),
    seed=st.integers(0, 2**31 - 1),
)
def test_qmatmul_shape_sweep(mi, ki, ni, bits, seed):
    """Hypothesis sweep over grid multiples and bit-widths vs the oracle."""
    bm, bk, bn = 32, 64, 64
    m, k, n = mi * bm, ki * bk, ni * bn
    x, wq, sx, sw = _mk(m, k, n, seed, bits)
    if bits == 4:
        wp = qmatmul.pack_weights_k(jnp.asarray(wq, jnp.int32))
        out = qmatmul.qmatmul4(x, wp, sx, sw, bm=bm, bk=bk, bn=bn)
    else:
        out = qmatmul.qmatmul(x, wq, sx, sw, bits=float(bits), bm=bm, bk=bk, bn=bn)
    want = ref.qmatmul(x, wq, sx, sw, float(bits))
    np.testing.assert_allclose(np.asarray(out), np.asarray(want), rtol=1e-5, atol=1e-4)


def test_vmem_budget():
    """DESIGN.md §Perf: default tiles fit comfortably in a 16 MiB VMEM."""
    assert qmatmul.vmem_bytes() < 16 * 2**20
    assert qmatmul.vmem_bytes(int4=True) < qmatmul.vmem_bytes()


class TestPackKernels:
    def test_roundtrip(self):
        rng = np.random.default_rng(0)
        q = jnp.asarray(rng.integers(-7, 9, size=(256, 128)), jnp.int32)
        p = pack.pack_int4(q)
        assert p.shape == (256, 64)
        back = pack.unpack_int4(p, 128)
        np.testing.assert_array_equal(np.asarray(back), np.asarray(q))

    def test_matches_ref(self):
        rng = np.random.default_rng(1)
        q = jnp.asarray(rng.integers(-7, 9, size=(256, 64)), jnp.int32)
        np.testing.assert_array_equal(np.asarray(pack.pack_int4(q)), np.asarray(ref.pack_int4(q)))
        p = ref.pack_int4(q)
        np.testing.assert_array_equal(
            np.asarray(pack.unpack_int4(p, 64)), np.asarray(ref.unpack_int4(p, 64))
        )

    def test_byte_range(self):
        rng = np.random.default_rng(2)
        q = jnp.asarray(rng.integers(-7, 9, size=(256, 32)), jnp.int32)
        p = np.asarray(pack.pack_int4(q))
        assert p.min() >= 0 and p.max() <= 255

    @settings(max_examples=20, deadline=None)
    @given(rows=st.sampled_from([256, 512]), cols=st.sampled_from([2, 8, 64]), seed=st.integers(0, 2**31 - 1))
    def test_roundtrip_sweep(self, rows, cols, seed):
        rng = np.random.default_rng(seed)
        q = jnp.asarray(rng.integers(-7, 9, size=(rows, cols)), jnp.int32)
        np.testing.assert_array_equal(np.asarray(pack.unpack_int4(pack.pack_int4(q), cols)), np.asarray(q))
