"""Quantizer unit + property tests, including the paper's §4.1 worked example."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from compile.kernels import quant, ref

jax.config.update("jax_platform_name", "cpu")


def test_qbounds_paper_convention():
    # k-bit grid is [-2^{k-1}+1, 2^{k-1}] — asymmetric (8 included for k=4).
    lmin, lmax = ref.qbounds(4.0)
    assert float(lmin) == -7.0 and float(lmax) == 8.0
    lmin, lmax = ref.qbounds(8.0)
    assert float(lmin) == -127.0 and float(lmax) == 128.0


def test_fake_quant_basic():
    x = jnp.array([0.2, 0.9])
    out = ref.fake_quant(x, jnp.array(1.0), 4.0)
    np.testing.assert_allclose(np.asarray(out), [0.0, 1.0])


def test_fake_quant_clamps():
    x = jnp.array([100.0, -100.0])
    out = ref.fake_quant(x, jnp.array(1.0), 4.0)
    np.testing.assert_allclose(np.asarray(out), [8.0, -7.0])


def test_paper_worked_example_mse_vs_ste():
    """§4.1: x=(0.2, 0.9), s=1, 4-bit. STE gradient is -0.1 (wrong sign:
    would *increase* s); MSE gradient is +0.2 (decreases s, shrinking the
    quantization error) — the paper's motivating example."""
    x = jnp.array([0.2, 0.9])
    s = jnp.array(1.0)
    g_ste = ref.ste_scale_grad(x, s, 4.0)
    g_mse = ref.mse_scale_grad(x, s, 4.0)
    np.testing.assert_allclose(float(g_ste), -0.1, atol=1e-6)
    np.testing.assert_allclose(float(g_mse), 0.2, atol=1e-6)
    assert float(g_ste) < 0.0 < float(g_mse)


def test_mse_grad_descends_quant_error():
    """One gradient step on s along -mse_scale_grad must not increase
    ||Q[x]-x||^2 (for a small enough step) — the property §4.1.2 claims."""
    rng = np.random.default_rng(0)
    for _ in range(20):
        x = jnp.asarray(rng.normal(size=(64,)).astype(np.float32))
        s = jnp.array(float(rng.uniform(0.05, 0.5)))
        g = ref.mse_scale_grad(x, s, 4.0)
        e0 = float(ref.quant_error(x, s, 4.0))
        e1 = float(ref.quant_error(x, s - 1e-4 * jnp.sign(g), 4.0))
        assert e1 <= e0 + 1e-5


def test_custom_vjp_selects_gradient_by_flag():
    x = jnp.array([0.2, 0.9])
    s = jnp.array(1.0)

    def loss(s_, flag):
        return jnp.sum(quant.fake_quant(x, s_, 4.0, flag))

    g_mse = jax.grad(loss)(s, jnp.array(1.0))
    g_ste = jax.grad(loss)(s, jnp.array(0.0))
    np.testing.assert_allclose(float(g_mse), float(ref.mse_scale_grad(x, s, 4.0)), rtol=1e-6)
    np.testing.assert_allclose(float(g_ste), float(ref.ste_scale_grad(x, s, 4.0)), rtol=1e-6)


def test_x_gradient_is_masked_ste():
    x = jnp.array([0.5, 100.0, -100.0])  # 2nd/3rd are clipped at s=1
    s = jnp.array(1.0)

    def loss(x_):
        return jnp.sum(quant.fake_quant(x_, s, 4.0, jnp.array(1.0)))

    gx = jax.grad(loss)(x)
    np.testing.assert_allclose(np.asarray(gx), [1.0, 0.0, 0.0])


def test_maybe_fake_quant_fp32_identity():
    x = jnp.array([0.123, -4.56, 7.89])
    out = quant.maybe_fake_quant(x, jnp.array(0.1), jnp.array(32.0), jnp.array(1.0))
    np.testing.assert_allclose(np.asarray(out), np.asarray(x))


def test_per_row_scales():
    x = jnp.array([[0.2, 0.9], [2.0, 9.0]])
    s = jnp.array([[0.1], [1.0]])
    out = ref.fake_quant(x, s, 4.0)
    np.testing.assert_allclose(np.asarray(out), [[0.2, 0.8], [2.0, 8.0]], atol=1e-6)
    g = ref.mse_scale_grad(x, s, 4.0)
    assert g.shape == (2, 1)
    # row 1: codes (2, 8); err = (0, -1); grad = 2*(0*2 + (-1)*8) = -16
    np.testing.assert_allclose(float(g[1, 0]), 2.0 * ((2.0 - 2.0) * 2 + (8.0 - 9.0) * 8), atol=1e-5)


@settings(max_examples=60, deadline=None)
@given(
    n=st.integers(1, 128),
    s=st.floats(0.01, 2.0),
    bits=st.sampled_from([4.0, 8.0]),
    seed=st.integers(0, 2**31 - 1),
)
def test_fake_quant_properties(n, s, bits, seed):
    """Invariants of Eq. (1): output on the s-grid, within clamp range,
    error bounded by s/2 for in-range inputs, idempotence."""
    rng = np.random.default_rng(seed)
    x = jnp.asarray(rng.normal(scale=2.0, size=(n,)).astype(np.float32))
    sj = jnp.array(np.float32(s))
    q = ref.fake_quant(x, sj, bits)
    codes = np.asarray(q) / s
    np.testing.assert_allclose(codes, np.round(codes), atol=1e-3)
    lmin, lmax = -(2 ** (int(bits) - 1)) + 1, 2 ** (int(bits) - 1)
    assert codes.min() >= lmin - 1e-3 and codes.max() <= lmax + 1e-3
    in_range = (np.asarray(x) / s >= lmin) & (np.asarray(x) / s <= lmax)
    err = np.abs(np.asarray(q) - np.asarray(x))
    assert np.all(err[in_range] <= s / 2 + 1e-4)
    q2 = ref.fake_quant(q, sj, bits)
    np.testing.assert_allclose(np.asarray(q2), np.asarray(q), atol=s * 1e-3)


@settings(max_examples=40, deadline=None)
@given(
    n=st.integers(2, 64),
    s=st.floats(0.05, 1.0),
    seed=st.integers(0, 2**31 - 1),
)
def test_mse_grad_matches_finite_difference(n, s, seed):
    """Away from rounding-boundary discontinuities the MSE scale gradient
    equals the finite difference of ||Q[x]-x||^2."""
    rng = np.random.default_rng(seed)
    # Build x strictly inside rounding intervals: x = (code + delta) * s with
    # |delta| <= 0.3, so the round() result is locally constant around s.
    codes = rng.integers(-30, 31, size=(n,))
    delta = rng.uniform(-0.3, 0.3, size=(n,))
    x = ((codes + delta) * s).astype(np.float32)
    xj, sj = jnp.asarray(x), jnp.array(np.float64(s), dtype=jnp.float32)
    g = float(ref.mse_scale_grad(xj, sj, 8.0))
    eps = 1e-4 * s
    e_plus = float(ref.quant_error(xj, sj + eps, 8.0))
    e_minus = float(ref.quant_error(xj, sj - eps, 8.0))
    fd = (e_plus - e_minus) / (2 * eps)
    np.testing.assert_allclose(g, fd, rtol=0.05, atol=0.2)
