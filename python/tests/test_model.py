"""L2 model tests: shapes, quantization wiring, distillation losses, QAT dynamics."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from compile import losses, model, optim, steps
from compile.config import ModelConfig

jax.config.update("jax_platform_name", "cpu")

CFG = ModelConfig(vocab=64, seq=8, n_layers=2, d_model=32, n_heads=2, d_ff=64,
                  batch=4, eval_batch=4, k_steps=3)


@pytest.fixture(scope="module")
def setup():
    key = jax.random.PRNGKey(0)
    params = model.init_params(CFG, key)
    scales = model.init_scales(CFG)
    rng = np.random.default_rng(0)
    ids = jnp.asarray(rng.integers(0, CFG.vocab, (4, CFG.seq)), jnp.int32)
    mask = jnp.ones((4, CFG.seq), jnp.float32)
    labels = jnp.asarray(rng.integers(0, 2, (4,)), jnp.int32)
    return params, scales, ids, mask, labels


def test_param_scale_counts():
    assert len(model.param_specs(CFG)) == 4 + 16 * CFG.n_layers + 4
    assert len(model.scale_specs(CFG)) == 10 * CFG.n_layers


def test_forward_shapes(setup):
    params, scales, ids, mask, _ = setup
    bits = jnp.full((CFG.n_layers,), 8.0)
    logits, aux = model.forward(CFG, params, scales, ids, mask, bits, jnp.float32(1.0))
    assert logits.shape == (4, CFG.n_classes)
    assert aux["attn_logp"].shape == (4, CFG.n_heads, CFG.seq, CFG.seq)
    assert aux["v"].shape == (4, CFG.n_heads, CFG.seq, CFG.d_head)


def test_teacher_equals_student_at_32_bits(setup):
    """With bits=32 the quantized forward must equal the fp32 forward."""
    params, scales, ids, mask, _ = setup
    bits = jnp.full((CFG.n_layers,), 32.0)
    lq, _ = model.forward(CFG, params, scales, ids, mask, bits, jnp.float32(1.0), quantize=True)
    lt, _ = model.forward(CFG, params, None, ids, mask, bits, jnp.float32(0.0), quantize=False)
    np.testing.assert_allclose(np.asarray(lq), np.asarray(lt), rtol=1e-5, atol=1e-5)


def test_quantization_perturbs_but_preserves(setup):
    """4-bit quantization changes the logits but not catastrophically
    (calibrated scales keep Q[x] ≈ x)."""
    params, scales, ids, mask, _ = setup
    lt, _ = model.forward(CFG, params, None, ids, mask,
                          jnp.full((CFG.n_layers,), 32.0), jnp.float32(0.0), quantize=False)
    # crude calibration: scale = max|W| / 8 for weights, 6/128 for acts
    cal = dict(scales)
    for l in range(CFG.n_layers):
        for w in ModelConfig.W_SITE_NAMES:
            cal[f"l{l}_s_w_{w}"] = (jnp.max(jnp.abs(params[f"l{l}_{w}"])) / 8.0).reshape(1)
        for a in ModelConfig.ACT_SITE_NAMES:
            cal[f"l{l}_s_act_{a}"] = jnp.asarray([6.0 / 128.0])
    l8, _ = model.forward(CFG, params, cal, ids, mask,
                          jnp.full((CFG.n_layers,), 8.0), jnp.float32(1.0))
    l4, _ = model.forward(CFG, params, cal, ids, mask,
                          jnp.full((CFG.n_layers,), 4.0), jnp.float32(1.0))
    d8 = float(jnp.mean(jnp.abs(l8 - lt)))
    d4 = float(jnp.mean(jnp.abs(l4 - lt)))
    assert d8 > 0.0 and d4 > 0.0
    assert d8 < d4  # int8 must be a strictly better approximation
    assert d4 < 10.0 * (float(jnp.mean(jnp.abs(lt))) + 1.0)


def test_mixed_bits_per_layer(setup):
    """Per-layer bit codes actually take effect: quantizing only layer 1
    differs from quantizing only layer 0."""
    params, scales, ids, mask, _ = setup
    b_a = jnp.asarray([4.0, 32.0])
    b_b = jnp.asarray([32.0, 4.0])
    la, _ = model.forward(CFG, params, scales, ids, mask, b_a, jnp.float32(1.0))
    lb, _ = model.forward(CFG, params, scales, ids, mask, b_b, jnp.float32(1.0))
    assert not np.allclose(np.asarray(la), np.asarray(lb))


def test_mask_blocks_padding(setup):
    """Changing tokens at masked positions must not change the logits."""
    params, scales, ids, mask, _ = setup
    mask2 = mask.at[:, -3:].set(0.0)
    ids_a = ids
    ids_b = ids.at[:, -3:].set(7)
    bits = jnp.full((CFG.n_layers,), 8.0)
    la, _ = model.forward(CFG, params, scales, ids_a, mask2, bits, jnp.float32(1.0))
    lb, _ = model.forward(CFG, params, scales, ids_b, mask2, bits, jnp.float32(1.0))
    # CLS attends only to unmasked positions; padded token embeddings still
    # enter residuals at their own positions but not position 0's pooling.
    np.testing.assert_allclose(np.asarray(la), np.asarray(lb), rtol=1e-4, atol=1e-4)


def test_losses_zero_for_identical_models(setup):
    params, scales, ids, mask, labels = setup
    bits = jnp.full((CFG.n_layers,), 32.0)
    ls, axs = model.forward(CFG, params, scales, ids, mask, bits, jnp.float32(1.0))
    lt, axt = model.forward(CFG, params, None, ids, mask, bits, jnp.float32(0.0), quantize=False)
    total, parts = losses.combined_loss(ls, axs, lt, axt, labels, mask, CFG.d_head,
                                        jnp.float32(10.0), jnp.float32(1.0))
    assert float(parts["output"]) < 1e-8
    assert float(parts["attention"]) < 1e-6
    assert float(parts["value"]) < 1e-6
    np.testing.assert_allclose(float(total), float(parts["train"]), rtol=1e-4)


def test_kl_nonnegative_and_asymmetric(setup):
    params, scales, ids, mask, labels = setup
    key = jax.random.PRNGKey(1)
    params2 = model.init_params(CFG, key)
    bits = jnp.full((CFG.n_layers,), 32.0)
    _, axs = model.forward(CFG, params, scales, ids, mask, bits, jnp.float32(1.0))
    _, axt = model.forward(CFG, params2, None, ids, mask, bits, jnp.float32(0.0), quantize=False)
    att = losses.attention_kd(axs["attn_logp"], axt["attn_logp"], mask)
    val = losses.value_kd(axs["v"], axt["v"], mask, CFG.d_head)
    assert float(att) > 0.0 and float(val) > 0.0


def test_calibration_stats(setup):
    params, _, ids, mask, _ = setup
    aq, am = model.forward_collect_act_stats(CFG, params, ids, mask)
    assert aq.shape == (CFG.n_layers, 4) and am.shape == (CFG.n_layers, 4)
    assert np.all(np.asarray(aq) <= np.asarray(am) + 1e-6)
    assert np.all(np.asarray(aq) > 0)
    wm = model.weight_abs_max(CFG, params)
    assert wm.shape == (CFG.n_layers, 6)
    assert np.all(np.asarray(wm) > 0)


class TestTrainStep:
    def _flat_state(self):
        p_specs, s_specs = model.param_specs(CFG), model.scale_specs(CFG)
        params = model.init_params(CFG, jax.random.PRNGKey(0))
        scales = model.init_scales(CFG)
        P = model.dict_to_flat(p_specs, params)
        S = model.dict_to_flat(s_specs, scales)
        Z = [jnp.zeros_like(x) for x in P]
        ZS = [jnp.zeros_like(x) for x in S]
        return P, S, Z, ZS

    def _batch(self, seed=0):
        K, B, T = CFG.k_steps, CFG.batch, CFG.seq
        rng = np.random.default_rng(seed)
        ids = jnp.asarray(rng.integers(0, CFG.vocab, (K, B, T)), jnp.int32)
        mask = jnp.ones((K, B, T), jnp.float32)
        labels = jnp.asarray(rng.integers(0, 2, (K, B)), jnp.int32)
        return ids, mask, labels

    def _run(self, lsq=1.0, mse=1.0, alpha=10.0, beta=1.0):
        P, S, Z, ZS = self._flat_state()
        ids, mask, labels = self._batch()
        K, L = CFG.k_steps, CFG.n_layers
        one = jnp.ones((1,), jnp.float32)
        lr = jnp.full((K, 1), 1e-3)
        flat = (P + S + Z + Z + ZS + ZS + [jnp.zeros((1,))] + P
                + [ids, mask, labels, lr, lr, lr]
                + [one * alpha, one * beta, one * mse, one * lsq, jnp.full((L,), 4.0)])
        fn = jax.jit(steps.make_train_step_k(CFG))
        return fn(*flat), len(P), len(S)

    def test_runs_and_updates(self):
        out, n_p, n_s = self._run()
        stats = out[-1]
        assert stats.shape == (CFG.k_steps, 6)
        assert np.all(np.isfinite(np.asarray(stats)))
        step = out[-2]
        assert float(step[0]) == CFG.k_steps

    def test_lsq_flag_freezes_scales(self):
        out_frozen, n_p, n_s = self._run(lsq=0.0)
        scales_after = out_frozen[n_p:n_p + n_s]
        for s in scales_after:
            np.testing.assert_allclose(np.asarray(s), 0.1, rtol=1e-6)

    def test_lsq_updates_scales(self):
        out, n_p, n_s = self._run(lsq=1.0)
        scales_after = np.concatenate([np.asarray(s) for s in out[n_p:n_p + n_s]])
        assert np.any(np.abs(scales_after - 0.1) > 1e-6)
        assert np.all(scales_after > 0)

    def test_mse_vs_ste_differ(self):
        out_mse, n_p, n_s = self._run(mse=1.0)
        out_ste, _, _ = self._run(mse=0.0)
        s_mse = np.concatenate([np.asarray(s) for s in out_mse[n_p:n_p + n_s]])
        s_ste = np.concatenate([np.asarray(s) for s in out_ste[n_p:n_p + n_s]])
        assert not np.allclose(s_mse, s_ste)

    def test_loss_decreases_over_epoch(self):
        """A few K-step executions on a *learnable* rule must reduce CE."""
        P, S, Z, ZS = self._flat_state()
        K, B, T, L = CFG.k_steps, CFG.batch, CFG.seq, CFG.n_layers
        rng = np.random.default_rng(7)
        fn = jax.jit(steps.make_train_step_k(CFG))
        one = jnp.ones((1,), jnp.float32)
        lr = jnp.full((K, 1), 5e-3)
        state = P + S + Z + Z + ZS + ZS + [jnp.zeros((1,))]
        n_state = len(state)
        first, last = None, None
        for it in range(8):
            ids = rng.integers(0, CFG.vocab, (K, B, T))
            labels = (ids[:, :, 0] > CFG.vocab // 2).astype(np.int32)  # learnable rule
            flat = (state + P[:len(model.param_specs(CFG))]
                    + [jnp.asarray(ids, jnp.int32), jnp.ones((K, B, T), jnp.float32),
                       jnp.asarray(labels, jnp.int32), lr, lr * 0.1, lr * 0.01]
                    + [one * 0.0, one * 0.0, one, one, jnp.full((L,), 8.0)])
            out = fn(*flat)
            state = list(out[:n_state])
            ce = float(np.mean(np.asarray(out[-1])[:, 1]))
            if it == 0:
                first = ce
            last = ce
        assert last < first, (first, last)


def test_fp32_train_step_learns():
    p_specs = model.param_specs(CFG)
    params = model.init_params(CFG, jax.random.PRNGKey(0))
    P = model.dict_to_flat(p_specs, params)
    Z = [jnp.zeros_like(x) for x in P]
    K, B, T = CFG.k_steps, CFG.batch, CFG.seq
    fn = jax.jit(steps.make_train_fp32_k(CFG))
    rng = np.random.default_rng(3)
    state = P + Z + Z + [jnp.zeros((1,))]
    n_state = len(state)
    first = last = None
    for it in range(8):
        ids = rng.integers(0, CFG.vocab, (K, B, T))
        labels = (ids[:, :, 0] > CFG.vocab // 2).astype(np.int32)
        flat = state + [jnp.asarray(ids, jnp.int32), jnp.ones((K, B, T), jnp.float32),
                        jnp.asarray(labels, jnp.int32), jnp.full((K, 1), 5e-3)]
        out = fn(*flat)
        state = list(out[:n_state])
        ce = float(np.mean(np.asarray(out[-1])[:, 0]))
        if it == 0:
            first = ce
        last = ce
    assert last < first


def test_eval_and_serve_steps(setup):
    params, scales, ids, mask, labels = setup
    p_specs, s_specs = model.param_specs(CFG), model.scale_specs(CFG)
    P = model.dict_to_flat(p_specs, params)
    S = model.dict_to_flat(s_specs, scales)
    bits = jnp.full((CFG.n_layers,), 8.0)
    ev = jax.jit(steps.make_eval_step(CFG))
    correct, loss, logits = ev(*(P + S + [bits, ids, mask, labels]))
    assert 0 <= float(correct[0]) <= 4
    te = jax.jit(steps.make_teacher_eval(CFG))
    c2, l2, lg2 = te(*(P + [ids, mask, labels]))
    assert 0 <= float(c2[0]) <= 4
    sv = jax.jit(steps.make_serve_fwd(CFG))
    (lgs,) = sv(*(P + S + [bits, ids, mask]))
    assert lgs.shape == (4, CFG.n_classes)
