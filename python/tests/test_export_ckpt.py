"""MKQC exporter header/layout unit tests (pure numpy — no jax).

Parses the bytes the exporter writes against the byte-level spec in
``rust/src/checkpoint/mod.rs``: fixed header fields, directory entry
structure, contiguous non-overlapping payload ranges, and the trailing
payload CRC-32.
"""

import struct
import zlib

import numpy as np
import pytest

from compile.config import ModelConfig, param_specs
from compile import export_ckpt


@pytest.fixture
def tiny_cfg():
    return ModelConfig(vocab=16, seq=4, n_layers=2, d_model=8, n_heads=2,
                       d_ff=16, n_classes=2)


def write_tiny(tmp_path, cfg, bits=None, seed=3):
    bits = bits or [8, 4]
    path = tmp_path / "tiny.mkqc"
    params = export_ckpt.random_params(cfg, seed)
    act = export_ckpt.default_act_scales(bits)
    n = export_ckpt.write_checkpoint(str(path), cfg, bits, act, params)
    blob = path.read_bytes()
    assert len(blob) == n
    return blob, bits, act, params


def test_header_layout(tmp_path, tiny_cfg):
    blob, bits, act, _ = write_tiny(tmp_path, tiny_cfg)
    assert blob[:4] == b"MKQC"
    (version,) = struct.unpack_from("<I", blob, 4)
    assert version == 1
    dims = struct.unpack_from("<7I", blob, 8)
    assert dims == (16, 4, 2, 8, 2, 16, 2)
    (n_tensors,) = struct.unpack_from("<I", blob, 36)
    assert n_tensors == len(param_specs(tiny_cfg))
    got_bits = struct.unpack_from("<2I", blob, 40)
    assert list(got_bits) == bits
    got_scales = np.frombuffer(blob, dtype="<f4", count=2 * 4, offset=48).reshape(2, 4)
    np.testing.assert_array_equal(got_scales, act)


def parse_directory(blob, cfg):
    """Walk the directory; returns (entries, payload_start)."""
    n_layers = cfg.n_layers
    pos = 40 + 4 * n_layers + 16 * n_layers
    (n_tensors,) = struct.unpack_from("<I", blob, 36)
    entries = []
    for _ in range(n_tensors):
        (name_len,) = struct.unpack_from("<H", blob, pos)
        pos += 2
        name = blob[pos:pos + name_len].decode("utf-8")
        pos += name_len
        dtype, rank = struct.unpack_from("<BB", blob, pos)
        pos += 2
        shape = struct.unpack_from(f"<{rank}I", blob, pos)
        pos += 4 * rank
        offset, length = struct.unpack_from("<QQ", blob, pos)
        pos += 16
        entries.append((name, dtype, shape, offset, length))
    return entries, pos


def test_directory_matches_spec_and_payload_tiles(tmp_path, tiny_cfg):
    blob, _, _, params = write_tiny(tmp_path, tiny_cfg)
    entries, payload_start = parse_directory(blob, tiny_cfg)
    specs = param_specs(tiny_cfg)
    assert [e[0] for e in entries] == [n for n, _ in specs]
    payload_len = len(blob) - payload_start - 4
    expect_off = 0
    for (name, dtype, shape, offset, length), (sname, sshape) in zip(entries, specs):
        assert dtype == 0, name
        assert shape == tuple(sshape), name
        assert length == 4 * int(np.prod(sshape)), name
        # writer emits spec order with a gap-free, non-overlapping payload
        assert offset == expect_off, name
        expect_off += length
    assert expect_off == payload_len
    # spot-check one tensor's bytes decode back to the source values
    name, _, shape, offset, length = entries[0]
    got = np.frombuffer(
        blob, dtype="<f4", count=length // 4, offset=payload_start + offset
    ).reshape(shape)
    np.testing.assert_array_equal(got, params[name])


def test_trailing_crc_covers_payload(tmp_path, tiny_cfg):
    blob, _, _, _ = write_tiny(tmp_path, tiny_cfg)
    _, payload_start = parse_directory(blob, tiny_cfg)
    payload = blob[payload_start:-4]
    (stored,) = struct.unpack_from("<I", blob, len(blob) - 4)
    assert stored == (zlib.crc32(payload) & 0xFFFFFFFF)


def test_v2_layout_header_crc_and_alignment(tmp_path, tiny_cfg):
    cfg = tiny_cfg
    bits = [8, 4]
    params = export_ckpt.random_params(cfg, 7)
    act = export_ckpt.default_act_scales(bits)
    path = str(tmp_path / "v2.mkqc")
    n = export_ckpt.write_checkpoint(path, cfg, bits, act, params, version=2)
    blob = open(path, "rb").read()
    assert len(blob) == n
    (version,) = struct.unpack_from("<I", blob, 4)
    assert version == 2

    # walk the v2 directory (extra layout byte per entry)
    pos = 40 + 4 * cfg.n_layers + 16 * cfg.n_layers
    (n_tensors,) = struct.unpack_from("<I", blob, 36)
    for _ in range(n_tensors):
        (name_len,) = struct.unpack_from("<H", blob, pos)
        pos += 2 + name_len
        dtype, layout, rank = struct.unpack_from("<BBB", blob, pos)
        assert (dtype, layout) == (0, 0), "f32 entries carry layout 0"
        pos += 3 + 4 * rank + 16

    # header/directory CRC over everything before it
    (stored_hcrc,) = struct.unpack_from("<I", blob, pos)
    assert stored_hcrc == (zlib.crc32(blob[:pos]) & 0xFFFFFFFF)
    pos += 4
    pad = (export_ckpt.PAYLOAD_ALIGN - pos % export_ckpt.PAYLOAD_ALIGN) \
        % export_ckpt.PAYLOAD_ALIGN
    assert blob[pos:pos + pad] == b"\x00" * pad
    payload_start = pos + pad
    assert payload_start % export_ckpt.PAYLOAD_ALIGN == 0

    # payload identical to the v1 encoding of the same params, CRC intact
    (stored,) = struct.unpack_from("<I", blob, len(blob) - 4)
    payload = blob[payload_start:-4]
    assert stored == (zlib.crc32(payload) & 0xFFFFFFFF)
    v1_path = str(tmp_path / "v1.mkqc")
    export_ckpt.write_checkpoint(v1_path, cfg, bits, act, params, version=1)
    v1_blob = open(v1_path, "rb").read()
    _, v1_payload_start = parse_directory(v1_blob, cfg)
    assert v1_blob[v1_payload_start:-4] == payload

    with pytest.raises(ValueError):
        export_ckpt.write_checkpoint(path, cfg, bits, act, params, version=3)


def test_writer_validates_inputs(tmp_path, tiny_cfg):
    cfg = tiny_cfg
    params = export_ckpt.random_params(cfg, 0)
    act = export_ckpt.default_act_scales([8, 8])
    out = str(tmp_path / "x.mkqc")

    with pytest.raises(ValueError):
        export_ckpt.write_checkpoint(out, cfg, [8], act, params)  # bits len
    with pytest.raises(ValueError):
        export_ckpt.write_checkpoint(out, cfg, [8, 8], act[:1], params)  # scales shape
    bad = dict(params)
    del bad["cls_b"]
    with pytest.raises(KeyError):
        export_ckpt.write_checkpoint(out, cfg, [8, 8], act, bad)  # missing tensor
    bad = dict(params)
    bad["cls_b"] = np.zeros((3,), np.float32)
    with pytest.raises(ValueError):
        export_ckpt.write_checkpoint(out, cfg, [8, 8], act, bad)  # wrong shape
    with pytest.raises(ValueError):
        export_ckpt.write_checkpoint(out, cfg, [8, 3], act, params)  # bad bit width
    bad_act = act.copy()
    bad_act[1, 2] = 0.0
    with pytest.raises(ValueError):
        export_ckpt.write_checkpoint(out, cfg, [8, 8], bad_act, params)  # zero scale
    bad_act = act.copy()
    bad_act[0, 0] = np.nan
    with pytest.raises(ValueError):
        export_ckpt.write_checkpoint(out, cfg, [8, 8], bad_act, params)  # NaN scale


def test_bits_helpers():
    assert export_ckpt.bits_last_n_int4(4, 0) == [8, 8, 8, 8]
    assert export_ckpt.bits_last_n_int4(4, 2) == [8, 8, 4, 4]
    assert export_ckpt.bits_last_n_int4(4, 9) == [4, 4, 4, 4]
    assert export_ckpt.parse_bits("8,8,4,4", 4) == [8, 8, 4, 4]
    with pytest.raises(ValueError):
        export_ckpt.parse_bits("8,8", 4)
    with pytest.raises(ValueError):
        export_ckpt.parse_bits("8,8,3,4", 4)
    assert export_ckpt.qmax(4) == 8.0
    assert export_ckpt.qmax(8) == 128.0
    assert export_ckpt.qmax(32) == 128.0
