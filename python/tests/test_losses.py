"""Distillation-loss and optimizer unit tests (Eq. 6/8/9/10 semantics)."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from compile import losses, optim

jax.config.update("jax_platform_name", "cpu")


def _rand_logp(rng, shape):
    x = jnp.asarray(rng.normal(size=shape).astype(np.float32))
    return jax.nn.log_softmax(x, axis=-1)


class TestKL:
    def test_zero_for_identical(self):
        rng = np.random.default_rng(0)
        lp = _rand_logp(rng, (2, 3, 5, 5))
        mask = jnp.ones((2, 5))
        assert float(losses.attention_kd(lp, lp, mask)) < 1e-7

    def test_positive_for_different(self):
        rng = np.random.default_rng(1)
        a = _rand_logp(rng, (2, 3, 5, 5))
        b = _rand_logp(rng, (2, 3, 5, 5))
        mask = jnp.ones((2, 5))
        assert float(losses.attention_kd(a, b, mask)) > 0.0

    def test_masked_rows_ignored(self):
        rng = np.random.default_rng(2)
        a = _rand_logp(rng, (1, 2, 4, 4))
        b = _rand_logp(rng, (1, 2, 4, 4))
        mask_full = jnp.ones((1, 4))
        mask_half = jnp.asarray([[1.0, 1.0, 0.0, 0.0]])
        # Changing the student's masked-row values must not change the loss.
        a2 = a.at[:, :, 2:, :].set(_rand_logp(rng, (1, 2, 2, 4)))
        v1 = float(losses.attention_kd(a, b, mask_half))
        v2 = float(losses.attention_kd(a2, b, mask_half))
        np.testing.assert_allclose(v1, v2, rtol=1e-5)
        assert v1 != pytest.approx(float(losses.attention_kd(a, b, mask_full)), rel=1e-3)

    @settings(max_examples=25, deadline=None)
    @given(seed=st.integers(0, 2**31 - 1), heads=st.integers(1, 4), t=st.integers(2, 8))
    def test_kl_nonnegative_property(self, seed, heads, t):
        rng = np.random.default_rng(seed)
        a = _rand_logp(rng, (1, heads, t, t))
        b = _rand_logp(rng, (1, heads, t, t))
        mask = jnp.ones((1, t))
        assert float(losses.attention_kd(a, b, mask)) >= -1e-6


class TestValueKD:
    def test_zero_for_identical_values(self):
        rng = np.random.default_rng(3)
        v = jnp.asarray(rng.normal(size=(2, 2, 6, 8)).astype(np.float32))
        mask = jnp.ones((2, 6))
        assert float(losses.value_kd(v, v, mask, 8)) < 1e-7

    def test_scale_invariance_breaks(self):
        # value relations are NOT invariant to per-token scaling -> loss > 0
        rng = np.random.default_rng(4)
        v = jnp.asarray(rng.normal(size=(1, 1, 6, 8)).astype(np.float32))
        v2 = v * jnp.linspace(0.5, 2.0, 6)[None, None, :, None]
        mask = jnp.ones((1, 6))
        assert float(losses.value_kd(v, v2, mask, 8)) > 1e-5


class TestCombined:
    def test_alpha_beta_scaling(self):
        rng = np.random.default_rng(5)
        sl = jnp.asarray(rng.normal(size=(4, 2)).astype(np.float32))
        tl = jnp.asarray(rng.normal(size=(4, 2)).astype(np.float32))
        aux_s = {
            "attn_logp": _rand_logp(rng, (4, 2, 5, 5)),
            "v": jnp.asarray(rng.normal(size=(4, 2, 5, 3)).astype(np.float32)),
        }
        aux_t = {
            "attn_logp": _rand_logp(rng, (4, 2, 5, 5)),
            "v": jnp.asarray(rng.normal(size=(4, 2, 5, 3)).astype(np.float32)),
        }
        labels = jnp.asarray([0, 1, 0, 1], jnp.int32)
        mask = jnp.ones((4, 5))
        t0, p0 = losses.combined_loss(sl, aux_s, tl, aux_t, labels, mask, 3,
                                      jnp.float32(0.0), jnp.float32(0.0))
        np.testing.assert_allclose(float(t0), float(p0["train"]), rtol=1e-6)
        t1, p1 = losses.combined_loss(sl, aux_s, tl, aux_t, labels, mask, 3,
                                      jnp.float32(10.0), jnp.float32(0.5))
        expect = float(p1["train"]) + 10.0 * float(p1["output"]) + 0.5 * (
            float(p1["attention"]) + float(p1["value"]))
        np.testing.assert_allclose(float(t1), expect, rtol=1e-5)

    def test_teacher_gets_no_gradient(self):
        rng = np.random.default_rng(6)
        sl = jnp.asarray(rng.normal(size=(2, 2)).astype(np.float32))
        labels = jnp.asarray([0, 1], jnp.int32)
        mask = jnp.ones((2, 3))
        aux = lambda: {
            "attn_logp": _rand_logp(rng, (2, 1, 3, 3)),
            "v": jnp.asarray(rng.normal(size=(2, 1, 3, 4)).astype(np.float32)),
        }

        def f(tl):
            total, _ = losses.combined_loss(sl, aux(), tl, aux(), labels, mask, 4,
                                            jnp.float32(10.0), jnp.float32(1.0))
            return total

        g = jax.grad(f)(jnp.asarray(rng.normal(size=(2, 2)).astype(np.float32)))
        np.testing.assert_allclose(np.asarray(g), 0.0, atol=1e-7)


class TestAdam:
    def test_descends_quadratic(self):
        p = {"x": jnp.asarray([5.0]), "y": jnp.asarray([-3.0])}
        m = optim.zeros_like_tree(p)
        v = optim.zeros_like_tree(p)
        loss = lambda p: jnp.sum(p["x"] ** 2) + jnp.sum(p["y"] ** 2)
        l0 = float(loss(p))
        for step in range(1, 200):
            g = jax.grad(loss)(p)
            p, m, v = optim.adam_update(p, g, m, v, jnp.float32(step), jnp.float32(0.1))
        assert float(loss(p)) < 1e-2 * l0

    def test_bias_correction_first_step(self):
        # After one step from zero state, update magnitude ~ lr regardless of g scale.
        for scale in [1e-3, 1.0, 1e3]:
            p = {"x": jnp.asarray([0.0])}
            g = {"x": jnp.asarray([scale])}
            m = optim.zeros_like_tree(p)
            v = optim.zeros_like_tree(p)
            p2, _, _ = optim.adam_update(p, g, m, v, jnp.float32(1.0), jnp.float32(0.01))
            np.testing.assert_allclose(float(p2["x"][0]), -0.01, rtol=1e-3)

    @settings(max_examples=20, deadline=None)
    @given(seed=st.integers(0, 2**31 - 1))
    def test_zero_grad_is_fixpoint(self, seed):
        rng = np.random.default_rng(seed)
        p = {"w": jnp.asarray(rng.normal(size=(3, 3)).astype(np.float32))}
        z = optim.zeros_like_tree(p)
        p2, m2, v2 = optim.adam_update(p, z, z, z, jnp.float32(1.0), jnp.float32(0.1))
        np.testing.assert_allclose(np.asarray(p2["w"]), np.asarray(p["w"]), atol=1e-7)
