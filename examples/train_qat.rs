//! End-to-end training driver (the repo's E2E validation run — see
//! EXPERIMENTS.md): finetune an fp32 teacher on a synthetic task, calibrate
//! quantization scales, run MKQ-BERT QAT with the last two layers at int4,
//! and log the full loss curve + dev accuracy trajectory.
//!
//! Run: cargo run --release --example train_qat -- [--task sst2]
//!          [--steps 300] [--teacher-steps 200] [--log run_logs/qat.tsv]

use anyhow::Result;
use mkq::coordinator::{bits_last_n_int4, QatConfig, Trainer};
use mkq::data::{Suite, TaskKind};
use mkq::runtime::Engine;
use mkq::util::cli::Args;

fn main() -> Result<()> {
    let args = Args::parse();
    let eng = Engine::load(&mkq::artifacts_dir())?;
    let mut tr = Trainer::new(&eng)?;
    tr.verbose = true;
    let d = tr.dims;

    let kind = TaskKind::parse(&args.str("task", "sst2")).expect("unknown task");
    let steps = args.usize("steps", 300);
    let teacher_steps = args.usize("teacher-steps", 200);

    let suite = Suite::new(42, d.vocab, d.seq);
    let task = suite.task(kind, 1);
    println!(
        "task {}: {} train / {} dev, mean valid tokens {:.1}",
        kind.name(),
        task.train.len(),
        task.dev.len(),
        task.train.mean_valid_tokens()
    );

    println!("\n== phase 1: fp32 teacher finetune ({teacher_steps} steps) ==");
    let t0 = std::time::Instant::now();
    // Breakthrough-style convergence is bimodal in seed (DESIGN.md): retry
    // like the paper's best-over-sweep protocol.
    let (teacher, teacher_acc) =
        tr.finetune_teacher_best(&task, teacher_steps, args.f64("teacher-lr", 1e-3), 11, 0.62, 4)?;
    let teacher_curve = mkq::coordinator::trainer::TrainCurve { points: vec![] };
    println!("teacher dev acc {:.4} ({:.1}s)", teacher_acc, t0.elapsed().as_secs_f64());

    println!("\n== phase 2: calibration (8 batches) ==");
    let (act, wmax) = tr.calibrate(&teacher, &task.train, 8, 11)?;
    println!("act stats (L x 4 sites): {:?}", &act[..4.min(act.len())]);

    println!("\n== phase 3: QAT, bits 8,8,4,4 MKQ ({steps} steps) ==");
    let bits = bits_last_n_int4(d.n_layers, 2);
    let scales = tr.make_scales(&act, &wmax, &bits)?;
    let cfg = QatConfig { bits, steps, eval_every: 50, ..Default::default() };
    let t0 = std::time::Instant::now();
    let res = tr.qat(&teacher, scales, &task, &cfg)?;
    let qat_secs = t0.elapsed().as_secs_f64();

    println!("\n== results ==");
    println!("teacher fp32       : {teacher_acc:.4}");
    println!("QAT best / final   : {:.4} / {:.4}", res.best_dev_acc, res.final_dev_acc);
    println!("QAT wall time      : {:.1}s ({:.0} ms/step)", qat_secs, qat_secs * 1e3 / steps as f64);
    println!("loss curve (every 25 steps):");
    for p in res.curve.points.iter().step_by(25) {
        println!(
            "  step {:>4}: total {:.4}  ce {:.4}  kd_out {:.4}  kd_att {:.4}  kd_val {:.4}  acc {:.3}",
            p.0, p.1, p.2, p.3, p.4, p.5, p.6
        );
    }

    // TSV log for plotting / EXPERIMENTS.md.
    let log_path = args.str("log", "run_logs/train_qat.tsv");
    if let Some(parent) = std::path::Path::new(&log_path).parent() {
        std::fs::create_dir_all(parent)?;
    }
    let mut tsv = String::from("phase\tstep\ttotal\tce\tkd_out\tkd_att\tkd_val\ttrain_acc\n");
    for p in &teacher_curve.points {
        tsv.push_str(&format!("teacher\t{}\t{}\t{}\t{}\t{}\t{}\t{}\n", p.0, p.1, p.2, p.3, p.4, p.5, p.6));
    }
    for p in &res.curve.points {
        tsv.push_str(&format!("qat\t{}\t{}\t{}\t{}\t{}\t{}\t{}\n", p.0, p.1, p.2, p.3, p.4, p.5, p.6));
    }
    for (step, acc) in &res.evals {
        tsv.push_str(&format!("eval\t{step}\t{acc}\t\t\t\t\t\n"));
    }
    std::fs::write(&log_path, tsv)?;
    println!("\nlogged to {log_path}");

    // engine telemetry: where the time went
    println!("\nengine telemetry (compile ms | execs | exec ms):");
    for (name, c, n, e) in eng.telemetry() {
        println!("  {name:<16} {c:>8.0} | {n:>4} | {e:>9.0}");
    }
    Ok(())
}
