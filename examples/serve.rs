//! Serving example: deploy a calibrated quantized model behind the
//! dynamic-batching server and replay a Poisson request trace, reporting
//! queue/execute/total latency percentiles and effective throughput —
//! the paper's deployment story (§5.4) as a runnable scenario.
//!
//! Run: cargo run --release --example serve -- [--rate 200] [--requests 400]
//!          [--window-us 500] [--bits 8,8,4,4]

use anyhow::Result;
use mkq::coordinator::{parse_bits, ServeModel, Server, ServerConfig, Trainer};
use mkq::data::{Suite, TaskKind};
use mkq::runtime::{Engine, HostTensor};
use mkq::util::cli::Args;
use mkq::util::rng::Rng;
use xla::Literal;

fn main() -> Result<()> {
    let args = Args::parse();
    let eng = Engine::load(&mkq::artifacts_dir())?;
    let tr = Trainer::new(&eng)?;
    let d = tr.dims;

    let bits = match args.get("bits") {
        Some(s) => parse_bits(s, d.n_layers)?,
        None => vec![8, 8, 4, 4],
    };
    let rate = args.f64("rate", 200.0);
    let n_req = args.usize("requests", 400);
    let window_us = args.usize("window-us", 500);

    // Prepare a deployed model: quick teacher + calibration (QAT quality is
    // exercised by train_qat; serving latency is the point here).
    println!("preparing model (bits {bits:?})...");
    let suite = Suite::new(42, d.vocab, d.seq);
    let task = suite.task(TaskKind::Qnli, 1);
    let (teacher, _) = tr.finetune_teacher(&task, 60, 1e-3, 7)?;
    let (act, wmax) = tr.calibrate(&teacher, &task.train, 4, 7)?;
    let scales = tr.make_scales(&act, &wmax, &bits)?;

    let mut ps: Vec<Literal> = Vec::new();
    for p in &teacher {
        ps.push(HostTensor::from_literal(p)?.to_literal()?);
    }
    ps.extend(scales);
    let bits_f: Vec<f32> = bits.iter().map(|&b| b as f32).collect();
    let model = ServeModel::new(ps, &bits_f, &format!("bits={bits:?}"))?;

    let mut server = Server::new(
        &eng,
        model,
        ServerConfig {
            buckets: vec![1, 8, 16],
            batch_window: std::time::Duration::from_micros(window_us as u64),
        },
    )?;

    // Warm the executables so compile time doesn't pollute the trace.
    for b in [1usize, 8, 16] {
        eng.compile(&format!("serve_fwd_b{b}"))?;
    }

    println!("replaying Poisson trace: {n_req} requests @ {rate} rps, window {window_us}us");
    let mut rng = Rng::new(99);
    let trace_start = std::time::Instant::now();
    let mut sent = 0usize;
    let mut next_arrival = std::time::Instant::now();
    let mut responses = 0usize;
    while responses < n_req {
        let now = std::time::Instant::now();
        if sent < n_req && now >= next_arrival {
            let row = rng.below(task.dev.len());
            server.submit(task.dev.ids[row].clone(), task.dev.masks[row].clone())?;
            sent += 1;
            next_arrival = now + std::time::Duration::from_secs_f64(rng.exp(rate));
        }
        let out = if sent >= n_req { server.drain()? } else { server.pump()? };
        responses += out.len();
    }
    let wall = trace_start.elapsed().as_secs_f64();

    println!("\n{}", server.summary());
    println!("\nthroughput: {:.1} req/s over {:.2}s wall", n_req as f64 / wall, wall);
    Ok(())
}
