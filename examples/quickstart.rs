//! Quickstart: load the AOT artifacts, initialize a model, tokenize a
//! synthetic sentence, and run one quantized forward pass — the whole
//! three-layer stack (Rust coordinator → JAX-lowered HLO → Pallas-derived
//! quantization) in ~40 lines.
//!
//! Run: make artifacts && cargo run --release --example quickstart

use anyhow::Result;
use mkq::coordinator::Trainer;
use mkq::data::{Suite, TaskKind};
use mkq::runtime::{Engine, HostTensor};
use xla::Literal;

fn main() -> Result<()> {
    // 1. Load + compile artifacts (HLO text -> PJRT CPU executables).
    let eng = Engine::load(&mkq::artifacts_dir())?;
    println!("platform: {}", eng.platform());
    let tr = Trainer::new(&eng)?;
    let d = tr.dims;
    println!("model: {} layers, d_model {}, vocab {}", d.n_layers, d.d_model, d.vocab);

    // 2. Fresh parameters from the `init` artifact.
    let (params, scales) = tr.init(42)?;
    println!("initialized {} param tensors + {} scales", params.len(), scales.len());

    // 3. Tokenize a synthetic sentence with the WordPiece substrate.
    let suite = Suite::new(42, d.vocab, d.seq);
    let task = suite.task(TaskKind::Sst2, 1);
    let words: Vec<&str> = vec![
        suite.lexicon.pos_words[0].as_str(),
        suite.lexicon.neutral[0].as_str(),
        suite.lexicon.pos_words[1].as_str(),
    ];
    let (ids, mask) = suite.tokenizer.encode(&words, None, d.seq);
    println!("tokens: {words:?} -> {:?}...", &ids[..6]);
    let _ = task;

    // 4. One quantized forward (all layers int8) through serve_fwd_b1.
    let bits = HostTensor::f32(&[d.n_layers], vec![8.0; d.n_layers]).to_literal()?;
    let ids_l = HostTensor::i32(&[1, d.seq], ids).to_literal()?;
    let mask_l = HostTensor::f32(&[1, d.seq], mask).to_literal()?;
    let mut inputs: Vec<&Literal> = params.iter().chain(scales.iter()).collect();
    inputs.push(&bits);
    inputs.push(&ids_l);
    inputs.push(&mask_l);
    let out = eng.execute_raw("serve_fwd_b1", &inputs)?;
    let logits = HostTensor::from_literal(&out[0])?;
    println!("logits: {:?}", logits.as_f32()?);
    println!("quickstart OK");
    Ok(())
}
