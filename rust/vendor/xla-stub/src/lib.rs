//! Stub of the `xla` PJRT binding (xla_extension 0.5.1 API surface).
//!
//! The container this repo builds in has no network and no prebuilt
//! xla_extension, so the real binding cannot be fetched. This stub keeps
//! the `--features xla` code *compiling* (trainer, engine, table runners)
//! while making every runtime entry point fail fast with an explanatory
//! error. To run against real artifacts, replace the `xla` path
//! dependency in the root `Cargo.toml` with a checkout of the actual
//! binding — the type and method names here mirror it one-to-one.

use std::fmt;

#[derive(Debug)]
pub struct Error(pub String);

impl fmt::Display for Error {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(f, "xla-stub: {}", self.0)
    }
}

impl std::error::Error for Error {}

pub type Result<T> = std::result::Result<T, Error>;

fn unavailable(what: &str) -> Error {
    Error(format!(
        "{what} requires the real xla/PJRT binding, which is not vendored in this build \
         (see rust/vendor/xla-stub); the native backend (default features) does not need it"
    ))
}

#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum ElementType {
    Pred,
    S8,
    S32,
    S64,
    U8,
    F32,
    F64,
}

pub struct HloModuleProto {
    _private: (),
}

impl HloModuleProto {
    pub fn from_text_file(_path: &str) -> Result<Self> {
        Err(unavailable("HloModuleProto::from_text_file"))
    }
}

pub struct XlaComputation {
    _private: (),
}

impl XlaComputation {
    pub fn from_proto(_proto: &HloModuleProto) -> Self {
        XlaComputation { _private: () }
    }
}

pub struct Literal {
    _private: (),
}

pub struct ArrayShape {
    dims: Vec<i64>,
    ty: ElementType,
}

impl ArrayShape {
    pub fn dims(&self) -> &[i64] {
        &self.dims
    }

    pub fn ty(&self) -> ElementType {
        self.ty
    }
}

impl Literal {
    pub fn create_from_shape_and_untyped_data(
        _ty: ElementType,
        _dims: &[usize],
        _data: &[u8],
    ) -> Result<Self> {
        Err(unavailable("Literal::create_from_shape_and_untyped_data"))
    }

    pub fn array_shape(&self) -> Result<ArrayShape> {
        Err(unavailable("Literal::array_shape"))
    }

    pub fn to_vec<T>(&self) -> Result<Vec<T>> {
        Err(unavailable("Literal::to_vec"))
    }

    pub fn to_tuple(&self) -> Result<Vec<Literal>> {
        Err(unavailable("Literal::to_tuple"))
    }
}

pub struct PjRtBuffer {
    _private: (),
}

impl PjRtBuffer {
    pub fn to_literal_sync(&self) -> Result<Literal> {
        Err(unavailable("PjRtBuffer::to_literal_sync"))
    }
}

pub struct PjRtClient {
    _private: (),
}

impl PjRtClient {
    pub fn cpu() -> Result<Self> {
        Err(unavailable("PjRtClient::cpu"))
    }

    pub fn platform_name(&self) -> String {
        "stub".to_string()
    }

    pub fn compile(&self, _comp: &XlaComputation) -> Result<PjRtLoadedExecutable> {
        Err(unavailable("PjRtClient::compile"))
    }
}

pub struct PjRtLoadedExecutable {
    _private: (),
}

impl PjRtLoadedExecutable {
    pub fn execute<L: std::borrow::Borrow<Literal>>(
        &self,
        _args: &[L],
    ) -> Result<Vec<Vec<PjRtBuffer>>> {
        Err(unavailable("PjRtLoadedExecutable::execute"))
    }
}
