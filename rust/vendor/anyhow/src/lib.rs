//! Vendored minimal `anyhow` — the offline-build substitution for the
//! crates.io crate (the container builds with no network; see the root
//! Cargo.toml). Implements the exact API surface this workspace uses:
//! [`Error`], [`Result`], [`Context`] (on `Result` and `Option`), and the
//! `anyhow!` / `bail!` / `ensure!` macros.
//!
//! Design follows upstream: `Error` deliberately does NOT implement
//! `std::error::Error`, which is what lets the blanket
//! `impl<E: std::error::Error> From<E> for Error` coexist with the
//! reflexive `From<Error> for Error` that `?` needs.

use std::fmt;

/// Error type: an outermost message plus its chain of causes.
pub struct Error {
    chain: Vec<String>,
}

pub type Result<T, E = Error> = std::result::Result<T, E>;

impl Error {
    /// Build an error from any displayable message.
    pub fn msg<M: fmt::Display>(message: M) -> Self {
        Error { chain: vec![message.to_string()] }
    }

    /// Build an error from a typed std error, preserving its source
    /// chain (upstream's `Error::new`).
    pub fn new<E>(error: E) -> Self
    where
        E: std::error::Error + Send + Sync + 'static,
    {
        Self::from(error)
    }

    /// Wrap with an outer context message (innermost cause stays last).
    pub fn context<C: fmt::Display>(mut self, context: C) -> Self {
        self.chain.insert(0, context.to_string());
        self
    }

    /// The cause chain, outermost first.
    pub fn chain(&self) -> impl Iterator<Item = &str> {
        self.chain.iter().map(|s| s.as_str())
    }
}

impl<E> From<E> for Error
where
    E: std::error::Error + Send + Sync + 'static,
{
    fn from(e: E) -> Self {
        let mut chain = vec![e.to_string()];
        let mut src = e.source();
        while let Some(s) = src {
            chain.push(s.to_string());
            src = s.source();
        }
        Error { chain }
    }
}

impl fmt::Display for Error {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        if f.alternate() {
            // `{:#}` prints the whole chain, upstream-style.
            f.write_str(&self.chain.join(": "))
        } else {
            f.write_str(&self.chain[0])
        }
    }
}

impl fmt::Debug for Error {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        writeln!(f, "{}", self.chain[0])?;
        if self.chain.len() > 1 {
            writeln!(f, "\nCaused by:")?;
            for (i, cause) in self.chain[1..].iter().enumerate() {
                writeln!(f, "    {i}: {cause}")?;
            }
        }
        Ok(())
    }
}

/// Context extension, applied to `Result` (any std error or `Error`
/// itself) and `Option`.
pub trait Context<T, E> {
    fn context<C: fmt::Display>(self, context: C) -> Result<T>;
    fn with_context<C: fmt::Display, F: FnOnce() -> C>(self, f: F) -> Result<T>;
}

impl<T, E> Context<T, E> for std::result::Result<T, E>
where
    E: std::error::Error + Send + Sync + 'static,
{
    fn context<C: fmt::Display>(self, context: C) -> Result<T> {
        self.map_err(|e| Error::from(e).context(context))
    }

    fn with_context<C: fmt::Display, F: FnOnce() -> C>(self, f: F) -> Result<T> {
        self.map_err(|e| Error::from(e).context(f()))
    }
}

impl<T> Context<T, Error> for std::result::Result<T, Error> {
    fn context<C: fmt::Display>(self, context: C) -> Result<T> {
        self.map_err(|e| e.context(context))
    }

    fn with_context<C: fmt::Display, F: FnOnce() -> C>(self, f: F) -> Result<T> {
        self.map_err(|e| e.context(f()))
    }
}

impl<T> Context<T, std::convert::Infallible> for Option<T> {
    fn context<C: fmt::Display>(self, context: C) -> Result<T> {
        self.ok_or_else(|| Error::msg(context))
    }

    fn with_context<C: fmt::Display, F: FnOnce() -> C>(self, f: F) -> Result<T> {
        self.ok_or_else(|| Error::msg(f()))
    }
}

#[macro_export]
macro_rules! anyhow {
    ($msg:literal $(,)?) => {
        $crate::Error::msg(format!($msg))
    };
    ($err:expr $(,)?) => {
        $crate::Error::msg($err)
    };
    ($fmt:expr, $($arg:tt)*) => {
        $crate::Error::msg(format!($fmt, $($arg)*))
    };
}

#[macro_export]
macro_rules! bail {
    ($($arg:tt)*) => {
        return Err($crate::anyhow!($($arg)*))
    };
}

#[macro_export]
macro_rules! ensure {
    ($cond:expr, $($arg:tt)*) => {
        if !$cond {
            return Err($crate::anyhow!($($arg)*));
        }
    };
}

#[cfg(test)]
mod tests {
    use super::*;

    fn io_err() -> std::io::Error {
        std::io::Error::new(std::io::ErrorKind::NotFound, "gone")
    }

    #[test]
    fn from_std_error_and_context() {
        let e: Error = io_err().into();
        assert_eq!(format!("{e}"), "gone");
        let r: Result<()> = Err(io_err()).with_context(|| "reading manifest");
        let e = r.unwrap_err();
        assert_eq!(format!("{e}"), "reading manifest");
        assert_eq!(format!("{e:#}"), "reading manifest: gone");
    }

    #[test]
    fn option_context_and_macros() {
        let v: Option<u32> = None;
        assert!(v.context("missing").is_err());

        fn f(x: u32) -> Result<u32> {
            ensure!(x < 10, "x too big: {x}");
            if x == 7 {
                bail!("unlucky {}", x);
            }
            Ok(x)
        }
        assert_eq!(f(3).unwrap(), 3);
        assert_eq!(format!("{}", f(7).unwrap_err()), "unlucky 7");
        assert_eq!(format!("{}", f(11).unwrap_err()), "x too big: 11");
        let e = anyhow!("plain {}", 1);
        assert_eq!(format!("{e}"), "plain 1");
        let e2 = anyhow!(String::from("owned"));
        assert_eq!(format!("{e2}"), "owned");
    }

    #[test]
    fn nested_context_chain() {
        let r: Result<()> = Err(io_err())
            .context("layer 1")
            .context("layer 2");
        let e = r.unwrap_err();
        assert_eq!(format!("{e:#}"), "layer 2: layer 1: gone");
        assert_eq!(e.chain().count(), 3);
    }
}
