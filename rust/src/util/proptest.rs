//! Property-testing harness (`proptest` is not in the vendored crate set —
//! this is the documented substitution, see DESIGN.md).
//!
//! `check` runs a property over N seeded random cases; on failure it
//! re-runs with progressively simpler generators ("shrink by regeneration"
//! — we shrink the *size hint*, not the value, which is enough to get
//! small counterexamples from size-parameterized generators) and panics
//! with the seed so the case is reproducible.

use super::rng::Rng;

pub struct PropConfig {
    pub cases: usize,
    pub seed: u64,
    pub max_size: usize,
}

impl Default for PropConfig {
    fn default() -> Self {
        PropConfig { cases: 64, seed: 0xC0FFEE, max_size: 64 }
    }
}

/// Run `prop(rng, size)` for `cfg.cases` random cases. `prop` returns
/// `Err(msg)` to fail. On failure, retries with smaller `size` values to
/// report the smallest failing size.
pub fn check<F>(name: &str, cfg: PropConfig, mut prop: F)
where
    F: FnMut(&mut Rng, usize) -> Result<(), String>,
{
    let mut root = Rng::new(cfg.seed);
    for case in 0..cfg.cases {
        let case_seed = root.next_u64();
        let size = 1 + (case * cfg.max_size) / cfg.cases.max(1);
        let mut rng = Rng::new(case_seed);
        if let Err(msg) = prop(&mut rng, size) {
            // Shrink: find the smallest size that still fails for this seed.
            let mut smallest = (size, msg.clone());
            for s in 1..size {
                let mut rng = Rng::new(case_seed);
                if let Err(m) = prop(&mut rng, s) {
                    smallest = (s, m);
                    break;
                }
            }
            panic!(
                "property `{name}` failed (case {case}, seed {case_seed:#x}, size {}): {}",
                smallest.0, smallest.1
            );
        }
    }
}

/// Convenience assertion helpers for property bodies.
pub fn ensure(cond: bool, msg: impl Into<String>) -> Result<(), String> {
    if cond {
        Ok(())
    } else {
        Err(msg.into())
    }
}

pub fn ensure_close(a: f64, b: f64, tol: f64, ctx: &str) -> Result<(), String> {
    if (a - b).abs() <= tol * (1.0 + a.abs().max(b.abs())) {
        Ok(())
    } else {
        Err(format!("{ctx}: {a} vs {b} (tol {tol})"))
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn passes_good_property() {
        check("reverse-involutive", PropConfig::default(), |rng, size| {
            let xs: Vec<u64> = (0..size).map(|_| rng.next_u64()).collect();
            let mut ys = xs.clone();
            ys.reverse();
            ys.reverse();
            ensure(xs == ys, "reverse twice != id")
        });
    }

    #[test]
    #[should_panic(expected = "property `always-fails` failed")]
    fn fails_bad_property() {
        check("always-fails", PropConfig { cases: 4, ..Default::default() }, |_, _| {
            Err("nope".into())
        });
    }

    #[test]
    fn ensure_close_tolerates() {
        assert!(ensure_close(1.0, 1.0 + 1e-9, 1e-6, "x").is_ok());
        assert!(ensure_close(1.0, 2.0, 1e-6, "x").is_err());
    }
}
