//! Key-value configuration files (serde/toml are not vendored).
//!
//! Format: `key = value` lines, `[section]` headers flatten to
//! `section.key`, `#` comments. Used by the launcher (`mkq-bert --config
//! serve.conf`) and the experiment runners.

use std::collections::HashMap;

#[derive(Debug, Default, Clone)]
pub struct Config {
    values: HashMap<String, String>,
}

impl Config {
    pub fn parse(text: &str) -> Result<Self, String> {
        let mut values = HashMap::new();
        let mut section = String::new();
        for (lineno, raw) in text.lines().enumerate() {
            let line = raw.split('#').next().unwrap_or("").trim();
            if line.is_empty() {
                continue;
            }
            if let Some(name) = line.strip_prefix('[').and_then(|l| l.strip_suffix(']')) {
                section = name.trim().to_string();
                continue;
            }
            let (k, v) = line
                .split_once('=')
                .ok_or_else(|| format!("line {}: expected `key = value`, got {raw:?}", lineno + 1))?;
            let key = if section.is_empty() {
                k.trim().to_string()
            } else {
                format!("{section}.{}", k.trim())
            };
            values.insert(key, v.trim().to_string());
        }
        Ok(Config { values })
    }

    pub fn load(path: &str) -> Result<Self, String> {
        let text = std::fs::read_to_string(path).map_err(|e| format!("{path}: {e}"))?;
        Self::parse(&text)
    }

    pub fn get(&self, key: &str) -> Option<&str> {
        self.values.get(key).map(|s| s.as_str())
    }

    pub fn str(&self, key: &str, default: &str) -> String {
        self.get(key).unwrap_or(default).to_string()
    }

    pub fn usize(&self, key: &str, default: usize) -> usize {
        self.get(key).and_then(|v| v.parse().ok()).unwrap_or(default)
    }

    pub fn f64(&self, key: &str, default: f64) -> f64 {
        self.get(key).and_then(|v| v.parse().ok()).unwrap_or(default)
    }

    pub fn bool(&self, key: &str, default: bool) -> bool {
        self.get(key)
            .map(|v| matches!(v, "true" | "1" | "yes"))
            .unwrap_or(default)
    }

    pub fn set(&mut self, key: &str, value: &str) {
        self.values.insert(key.to_string(), value.to_string());
    }

    pub fn keys(&self) -> impl Iterator<Item = &String> {
        self.values.keys()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    const SAMPLE: &str = r#"
# top-level
steps = 300
lr = 0.005

[server]
port = 8080
batch_window_us = 500  # inline comment
buckets = 16x28,16x34
"#;

    #[test]
    fn parse_sections_and_types() {
        let c = Config::parse(SAMPLE).unwrap();
        assert_eq!(c.usize("steps", 0), 300);
        assert!((c.f64("lr", 0.0) - 0.005).abs() < 1e-12);
        assert_eq!(c.usize("server.port", 0), 8080);
        assert_eq!(c.usize("server.batch_window_us", 0), 500);
        assert_eq!(c.str("server.buckets", ""), "16x28,16x34");
    }

    #[test]
    fn rejects_garbage() {
        assert!(Config::parse("what is this").is_err());
    }

    #[test]
    fn defaults_and_overrides() {
        let mut c = Config::parse("a = 1").unwrap();
        assert_eq!(c.usize("missing", 9), 9);
        c.set("a", "2");
        assert_eq!(c.usize("a", 0), 2);
    }
}
