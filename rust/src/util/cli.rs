//! Minimal CLI argument parser (clap is not in the vendored crate set).
//!
//! Supports `--key value`, `--key=value`, boolean `--flag`, repeated
//! flags (`--model a=x --model b=y`, read back via [`Args::get_all`]),
//! and positional arguments. Every binary in this workspace parses
//! through here so help text and error behaviour stay uniform.

use std::collections::HashMap;

#[derive(Debug, Default)]
pub struct Args {
    pub positional: Vec<String>,
    /// Every value a key was given, in argv order; single-value accessors
    /// read the last one (last-wins, the usual CLI override convention).
    flags: HashMap<String, Vec<String>>,
    order: Vec<String>,
}

impl Args {
    pub fn parse_from<I: IntoIterator<Item = String>>(iter: I) -> Self {
        let mut out = Args::default();
        let mut it = iter.into_iter().peekable();
        while let Some(a) = it.next() {
            if let Some(rest) = a.strip_prefix("--") {
                if let Some((k, v)) = rest.split_once('=') {
                    out.insert(k.to_string(), v.to_string());
                } else if it.peek().map(|n| !n.starts_with("--")).unwrap_or(false) {
                    let v = it.next().unwrap();
                    out.insert(rest.to_string(), v);
                } else {
                    out.insert(rest.to_string(), "true".to_string());
                }
            } else {
                out.positional.push(a);
            }
        }
        out
    }

    pub fn parse() -> Self {
        Self::parse_from(std::env::args().skip(1))
    }

    fn insert(&mut self, k: String, v: String) {
        if !self.flags.contains_key(&k) {
            self.order.push(k.clone());
        }
        self.flags.entry(k).or_default().push(v);
    }

    pub fn get(&self, key: &str) -> Option<&str> {
        self.flags.get(key).and_then(|v| v.last()).map(|s| s.as_str())
    }

    /// Every occurrence of a repeatable flag, in argv order (empty when
    /// the flag was never given) — e.g. `--model a=x --model b=y`.
    pub fn get_all(&self, key: &str) -> Vec<&str> {
        self.flags.get(key).map(|v| v.iter().map(|s| s.as_str()).collect()).unwrap_or_default()
    }

    pub fn str(&self, key: &str, default: &str) -> String {
        self.get(key).unwrap_or(default).to_string()
    }

    pub fn usize(&self, key: &str, default: usize) -> usize {
        self.get(key)
            .map(|v| v.parse().unwrap_or_else(|_| panic!("--{key} expects an integer, got {v:?}")))
            .unwrap_or(default)
    }

    pub fn f64(&self, key: &str, default: f64) -> f64 {
        self.get(key)
            .map(|v| v.parse().unwrap_or_else(|_| panic!("--{key} expects a number, got {v:?}")))
            .unwrap_or(default)
    }

    pub fn bool(&self, key: &str) -> bool {
        matches!(self.get(key), Some("true") | Some("1") | Some("yes"))
    }

    /// Comma-separated list, e.g. `--tasks rte,mrpc`.
    pub fn list(&self, key: &str) -> Option<Vec<String>> {
        self.get(key)
            .map(|v| v.split(',').map(|s| s.trim().to_string()).filter(|s| !s.is_empty()).collect())
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn parse(s: &str) -> Args {
        Args::parse_from(s.split_whitespace().map(|x| x.to_string()))
    }

    #[test]
    fn key_value_forms() {
        // Positionals come before flags: a bare `--flag` followed by a
        // non-flag token consumes it as a value (documented behaviour).
        let a = parse("run --steps 300 --lr=0.005 --verbose");
        assert_eq!(a.usize("steps", 0), 300);
        assert!((a.f64("lr", 0.0) - 0.005).abs() < 1e-12);
        assert!(a.bool("verbose"));
        assert_eq!(a.positional, vec!["run"]);
    }

    #[test]
    fn defaults() {
        let a = parse("");
        assert_eq!(a.usize("steps", 7), 7);
        assert_eq!(a.str("out", "x"), "x");
        assert!(!a.bool("verbose"));
    }

    #[test]
    fn trailing_flag() {
        let a = parse("--dry-run");
        assert!(a.bool("dry-run"));
    }

    #[test]
    fn repeated_flags_accumulate_and_last_wins() {
        let a = parse("--model a=x.mkqc --model b=y.mkqc --rate 10 --rate 20");
        assert_eq!(a.get_all("model"), vec!["a=x.mkqc", "b=y.mkqc"]);
        assert_eq!(a.f64("rate", 0.0), 20.0, "single-value accessors read the last value");
        assert!(a.get_all("absent").is_empty());
    }

    #[test]
    fn lists() {
        let a = parse("--tasks rte,mrpc, cola");
        assert_eq!(a.list("tasks").unwrap(), vec!["rte", "mrpc"]);
        let b = parse("--tasks=rte,mrpc,cola");
        assert_eq!(b.list("tasks").unwrap(), vec!["rte", "mrpc", "cola"]);
    }
}
