//! Tiny leveled stderr logger (no `log`/`tracing` crates — hermetic
//! build). `MKQ_LOG=error|warn|info|debug` selects the threshold, read
//! once on first use; the default is `info`, so debug lines are
//! off-by-default. A disabled level costs one relaxed atomic load.
//!
//! Use the crate-root macros: `log_error!`, `log_warn!`, `log_info!`,
//! `log_debug!` — same format syntax as `eprintln!`, prefixed with
//! `[mkq <level>]`.

use std::sync::atomic::{AtomicU8, Ordering::Relaxed};

#[derive(Clone, Copy, PartialEq, Eq, PartialOrd, Ord, Debug)]
#[repr(u8)]
pub enum Level {
    Error = 0,
    Warn = 1,
    Info = 2,
    Debug = 3,
}

impl Level {
    pub fn tag(self) -> &'static str {
        match self {
            Level::Error => "error",
            Level::Warn => "warn",
            Level::Info => "info",
            Level::Debug => "debug",
        }
    }
}

const UNSET: u8 = u8::MAX;
static THRESHOLD: AtomicU8 = AtomicU8::new(UNSET);

fn threshold() -> u8 {
    let t = THRESHOLD.load(Relaxed);
    if t != UNSET {
        return t;
    }
    let parsed = match std::env::var("MKQ_LOG") {
        Ok(v) => match v.trim().to_ascii_lowercase().as_str() {
            "error" => Level::Error as u8,
            "warn" => Level::Warn as u8,
            "info" => Level::Info as u8,
            "debug" => Level::Debug as u8,
            "" => Level::Info as u8,
            other => {
                eprintln!("[mkq warn] MKQ_LOG={other:?} not one of error|warn|info|debug; using info");
                Level::Info as u8
            }
        },
        Err(_) => Level::Info as u8,
    };
    THRESHOLD.store(parsed, Relaxed);
    parsed
}

/// Runtime override (tests).
pub fn set_level(l: Level) {
    THRESHOLD.store(l as u8, Relaxed);
}

#[inline]
pub fn enabled(l: Level) -> bool {
    (l as u8) <= threshold()
}

pub fn write(l: Level, args: std::fmt::Arguments<'_>) {
    if enabled(l) {
        eprintln!("[mkq {}] {}", l.tag(), args);
    }
}

#[macro_export]
macro_rules! log_error {
    ($($t:tt)*) => {
        $crate::util::log::write($crate::util::log::Level::Error, format_args!($($t)*))
    };
}

#[macro_export]
macro_rules! log_warn {
    ($($t:tt)*) => {
        $crate::util::log::write($crate::util::log::Level::Warn, format_args!($($t)*))
    };
}

#[macro_export]
macro_rules! log_info {
    ($($t:tt)*) => {
        $crate::util::log::write($crate::util::log::Level::Info, format_args!($($t)*))
    };
}

#[macro_export]
macro_rules! log_debug {
    ($($t:tt)*) => {
        $crate::util::log::write($crate::util::log::Level::Debug, format_args!($($t)*))
    };
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn ordering_is_sane() {
        assert!(Level::Error < Level::Warn);
        assert!(Level::Warn < Level::Info);
        assert!(Level::Info < Level::Debug);
    }

    #[test]
    fn set_level_gates() {
        set_level(Level::Warn);
        assert!(enabled(Level::Error));
        assert!(enabled(Level::Warn));
        assert!(!enabled(Level::Info));
        assert!(!enabled(Level::Debug));
        set_level(Level::Info); // restore the default for other tests
    }
}
