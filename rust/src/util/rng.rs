//! Deterministic PRNG substrate (the vendored crate set has no `rand`).
//!
//! SplitMix64 for seeding + xoshiro256** for the stream — the standard
//! combination; fast, splittable by construction (derive child seeds with
//! `fork`), and reproducible across runs, which the synthetic-GLUE
//! generators rely on (train/dev splits must be stable between the
//! teacher-finetune and QAT phases).

#[derive(Clone, Debug)]
pub struct Rng {
    s: [u64; 4],
}

fn splitmix64(state: &mut u64) -> u64 {
    *state = state.wrapping_add(0x9E3779B97F4A7C15);
    let mut z = *state;
    z = (z ^ (z >> 30)).wrapping_mul(0xBF58476D1CE4E5B9);
    z = (z ^ (z >> 27)).wrapping_mul(0x94D049BB133111EB);
    z ^ (z >> 31)
}

impl Rng {
    pub fn new(seed: u64) -> Self {
        let mut st = seed;
        let s = [
            splitmix64(&mut st),
            splitmix64(&mut st),
            splitmix64(&mut st),
            splitmix64(&mut st),
        ];
        Rng { s }
    }

    /// Derive an independent child stream (used per-task / per-split).
    pub fn fork(&mut self, tag: u64) -> Rng {
        Rng::new(self.next_u64() ^ tag.wrapping_mul(0x9E3779B97F4A7C15))
    }

    pub fn next_u64(&mut self) -> u64 {
        // xoshiro256**
        let r = self.s[1].wrapping_mul(5).rotate_left(7).wrapping_mul(9);
        let t = self.s[1] << 17;
        self.s[2] ^= self.s[0];
        self.s[3] ^= self.s[1];
        self.s[1] ^= self.s[2];
        self.s[0] ^= self.s[3];
        self.s[2] ^= t;
        self.s[3] = self.s[3].rotate_left(45);
        r
    }

    /// Uniform in [0, 1).
    pub fn f64(&mut self) -> f64 {
        (self.next_u64() >> 11) as f64 * (1.0 / (1u64 << 53) as f64)
    }

    pub fn f32(&mut self) -> f32 {
        self.f64() as f32
    }

    /// Uniform integer in [0, n) (Lemire-style rejection-free for our use).
    pub fn below(&mut self, n: usize) -> usize {
        assert!(n > 0);
        (self.f64() * n as f64) as usize % n
    }

    /// Uniform integer in [lo, hi).
    pub fn range(&mut self, lo: usize, hi: usize) -> usize {
        lo + self.below(hi - lo)
    }

    pub fn bool(&mut self, p: f64) -> bool {
        self.f64() < p
    }

    /// Standard normal via Box-Muller.
    pub fn normal(&mut self) -> f64 {
        let u1 = self.f64().max(1e-12);
        let u2 = self.f64();
        (-2.0 * u1.ln()).sqrt() * (2.0 * std::f64::consts::PI * u2).cos()
    }

    /// Poisson sample (Knuth; fine for small lambda — request arrivals).
    pub fn poisson(&mut self, lambda: f64) -> usize {
        let l = (-lambda).exp();
        let mut k = 0usize;
        let mut p = 1.0;
        loop {
            p *= self.f64();
            if p <= l {
                return k;
            }
            k += 1;
        }
    }

    /// Exponential inter-arrival time with the given rate (events/sec).
    pub fn exp(&mut self, rate: f64) -> f64 {
        -self.f64().max(1e-12).ln() / rate
    }

    pub fn shuffle<T>(&mut self, xs: &mut [T]) {
        for i in (1..xs.len()).rev() {
            let j = self.below(i + 1);
            xs.swap(i, j);
        }
    }

    pub fn choose<'a, T>(&mut self, xs: &'a [T]) -> &'a T {
        &xs[self.below(xs.len())]
    }

    /// Sample k distinct indices from [0, n).
    pub fn sample_indices(&mut self, n: usize, k: usize) -> Vec<usize> {
        let mut idx: Vec<usize> = (0..n).collect();
        self.shuffle(&mut idx);
        idx.truncate(k);
        idx
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn deterministic() {
        let mut a = Rng::new(42);
        let mut b = Rng::new(42);
        for _ in 0..100 {
            assert_eq!(a.next_u64(), b.next_u64());
        }
    }

    #[test]
    fn forks_are_independent() {
        let mut root = Rng::new(1);
        let mut a = root.fork(1);
        let mut b = root.fork(2);
        let xs: Vec<u64> = (0..8).map(|_| a.next_u64()).collect();
        let ys: Vec<u64> = (0..8).map(|_| b.next_u64()).collect();
        assert_ne!(xs, ys);
    }

    #[test]
    fn uniform_bounds() {
        let mut r = Rng::new(7);
        for _ in 0..10_000 {
            let x = r.f64();
            assert!((0.0..1.0).contains(&x));
            let n = r.range(3, 17);
            assert!((3..17).contains(&n));
        }
    }

    #[test]
    fn normal_moments() {
        let mut r = Rng::new(9);
        let n = 20_000;
        let xs: Vec<f64> = (0..n).map(|_| r.normal()).collect();
        let mean = xs.iter().sum::<f64>() / n as f64;
        let var = xs.iter().map(|x| (x - mean) * (x - mean)).sum::<f64>() / n as f64;
        assert!(mean.abs() < 0.05, "mean={mean}");
        assert!((var - 1.0).abs() < 0.1, "var={var}");
    }

    #[test]
    fn shuffle_is_permutation() {
        let mut r = Rng::new(3);
        let mut xs: Vec<usize> = (0..50).collect();
        r.shuffle(&mut xs);
        let mut sorted = xs.clone();
        sorted.sort_unstable();
        assert_eq!(sorted, (0..50).collect::<Vec<_>>());
    }

    #[test]
    fn poisson_mean() {
        let mut r = Rng::new(5);
        let n = 5000;
        let m: f64 = (0..n).map(|_| r.poisson(3.0) as f64).sum::<f64>() / n as f64;
        assert!((m - 3.0).abs() < 0.15, "m={m}");
    }
}
