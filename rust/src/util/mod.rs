//! Substrate utilities the vendored crate set lacks (DESIGN.md lists these
//! as deliberate build-everything substitutions): PRNG, CLI parsing,
//! config files, a thread pool, a property-testing harness, summary
//! statistics, a micro-benchmark harness, and a leveled stderr logger.

pub mod benchkit;
pub mod cli;
pub mod config;
pub mod crc32;
pub mod log;
pub mod proptest;
pub mod rng;
pub mod stats;
pub mod threadpool;
