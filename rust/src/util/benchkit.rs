//! Micro-benchmark harness (criterion is not in the vendored crate set —
//! documented substitution, DESIGN.md).
//!
//! Warmup + timed iterations with mean/p50/stddev reporting, matching the
//! paper's methodology for Table 2 ("inference time averaged over 100
//! rounds").

use std::time::Instant;

use super::stats;

#[derive(Debug, Clone, Copy)]
pub struct BenchResult {
    pub iters: usize,
    pub mean_us: f64,
    pub p50_us: f64,
    pub stddev_us: f64,
    pub min_us: f64,
}

impl BenchResult {
    /// A single-observation result (serving summaries gate one statistic
    /// per JSON row): every timing field carries the same value.
    pub fn single(value_us: f64, iters: usize) -> Self {
        BenchResult { iters, mean_us: value_us, p50_us: value_us, stddev_us: 0.0, min_us: value_us }
    }

    /// The one `BENCH_*.json` bucket-row shape `ci/bench_diff.py`
    /// consumes (gates on `min_us`) — shared by `benches/layers.rs` and
    /// `serve-native --bench-trace` so the two emitters cannot drift.
    pub fn json_row(&self, name: &str) -> String {
        format!(
            "{{\"name\": \"{name}\", \"mean_us\": {:.3}, \"p50_us\": {:.3}, \"stddev_us\": {:.3}, \"min_us\": {:.3}, \"iters\": {}}}",
            self.mean_us, self.p50_us, self.stddev_us, self.min_us, self.iters
        )
    }
}

impl std::fmt::Display for BenchResult {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        write!(
            f,
            "mean {:>10.1} us  p50 {:>10.1} us  sd {:>8.1} us  (n={})",
            self.mean_us, self.p50_us, self.stddev_us, self.iters
        )
    }
}

pub struct Bench {
    pub warmup: usize,
    pub iters: usize,
}

impl Default for Bench {
    fn default() -> Self {
        Bench { warmup: 3, iters: 100 }
    }
}

impl Bench {
    pub fn new(warmup: usize, iters: usize) -> Self {
        Bench { warmup, iters }
    }

    pub fn run<F: FnMut()>(&self, mut f: F) -> BenchResult {
        for _ in 0..self.warmup {
            f();
        }
        let mut samples = Vec::with_capacity(self.iters);
        for _ in 0..self.iters {
            let t0 = Instant::now();
            f();
            samples.push(t0.elapsed().as_secs_f64() * 1e6);
        }
        BenchResult {
            iters: self.iters,
            mean_us: stats::mean(&samples),
            p50_us: stats::percentile(&samples, 50.0),
            stddev_us: stats::stddev(&samples),
            min_us: samples.iter().cloned().fold(f64::INFINITY, f64::min),
        }
    }

    /// Run and print a labeled row (the bench binaries' standard output).
    pub fn report<F: FnMut()>(&self, label: &str, f: F) -> BenchResult {
        let r = self.run(f);
        println!("{label:<40} {r}");
        r
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn measures_something() {
        let b = Bench::new(1, 10);
        let r = b.run(|| std::thread::sleep(std::time::Duration::from_micros(200)));
        assert!(r.mean_us >= 150.0, "mean={}", r.mean_us);
        assert_eq!(r.iters, 10);
    }

    #[test]
    fn ordering_detectable() {
        let b = Bench::new(1, 8);
        let fast = b.run(|| {
            std::hint::black_box((0..100).sum::<u64>());
        });
        let slow = b.run(|| std::thread::sleep(std::time::Duration::from_micros(300)));
        assert!(slow.mean_us > fast.mean_us);
    }
}
