//! CRC-32 (IEEE 802.3 / zlib polynomial, reflected) — the checkpoint
//! trailer checksum.
//!
//! Matches Python's `zlib.crc32` / `binascii.crc32` exactly (polynomial
//! 0xEDB88320, init 0xFFFFFFFF, final xor 0xFFFFFFFF), which is what
//! `python/compile/export_ckpt.py` writes — the two sides of the MKQC
//! format must agree bit-for-bit. Table-driven, 256-entry table built at
//! construction (trivial cost next to any payload worth checksumming).

/// Streaming CRC-32 state. Feed bytes with [`update`](Crc32::update),
/// read the digest with [`finish`](Crc32::finish).
pub struct Crc32 {
    table: [u32; 256],
    state: u32,
}

impl Default for Crc32 {
    fn default() -> Self {
        Self::new()
    }
}

impl Crc32 {
    pub fn new() -> Self {
        let mut table = [0u32; 256];
        for (i, e) in table.iter_mut().enumerate() {
            let mut c = i as u32;
            for _ in 0..8 {
                c = if c & 1 != 0 { 0xEDB8_8320 ^ (c >> 1) } else { c >> 1 };
            }
            *e = c;
        }
        Crc32 { table, state: 0xFFFF_FFFF }
    }

    pub fn update(&mut self, bytes: &[u8]) {
        let mut c = self.state;
        for &b in bytes {
            c = self.table[((c ^ b as u32) & 0xFF) as usize] ^ (c >> 8);
        }
        self.state = c;
    }

    pub fn finish(&self) -> u32 {
        self.state ^ 0xFFFF_FFFF
    }
}

/// One-shot CRC-32 of a byte slice.
pub fn crc32(bytes: &[u8]) -> u32 {
    let mut c = Crc32::new();
    c.update(bytes);
    c.finish()
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn known_vectors() {
        // The canonical CRC-32 check value and a few zlib.crc32 cross-checks.
        assert_eq!(crc32(b"123456789"), 0xCBF4_3926);
        assert_eq!(crc32(b""), 0);
        assert_eq!(crc32(b"a"), 0xE8B7_BE43);
        assert_eq!(crc32(b"The quick brown fox jumps over the lazy dog"), 0x414F_A339);
    }

    #[test]
    fn streaming_equals_oneshot() {
        let data: Vec<u8> = (0..1024u32).map(|i| (i * 7 + 3) as u8).collect();
        let mut c = Crc32::new();
        for chunk in data.chunks(13) {
            c.update(chunk);
        }
        assert_eq!(c.finish(), crc32(&data));
    }
}
