//! Fixed-size thread pool over std channels (tokio is not vendored).
//!
//! The serving coordinator uses this for request generation and response
//! post-processing; executions on the PJRT client itself are serialized
//! per-executable (XLA-CPU already parallelizes a single execution across
//! cores, so stacking concurrent executes just thrashes the cache).

use std::sync::atomic::{AtomicUsize, Ordering};
use std::sync::{mpsc, Arc, Mutex};
use std::thread;

type Job = Box<dyn FnOnce() + Send + 'static>;

pub struct ThreadPool {
    workers: Vec<thread::JoinHandle<()>>,
    tx: Option<mpsc::Sender<Job>>,
    in_flight: Arc<AtomicUsize>,
    panicked: Arc<AtomicUsize>,
}

impl ThreadPool {
    pub fn new(n: usize) -> Self {
        assert!(n > 0);
        let (tx, rx) = mpsc::channel::<Job>();
        let rx = Arc::new(Mutex::new(rx));
        let in_flight = Arc::new(AtomicUsize::new(0));
        let panicked = Arc::new(AtomicUsize::new(0));
        let workers = (0..n)
            .map(|i| {
                let rx = Arc::clone(&rx);
                let in_flight = Arc::clone(&in_flight);
                let panicked = Arc::clone(&panicked);
                thread::Builder::new()
                    .name(format!("mkq-pool-{i}"))
                    .spawn(move || loop {
                        let job = {
                            let guard = rx.lock().unwrap();
                            guard.recv()
                        };
                        match job {
                            Ok(job) => {
                                // Contain unwinds so a panicking job can
                                // neither kill the worker nor leave
                                // in_flight stuck (which would hang
                                // wait_idle forever); scoped() re-raises.
                                let r = std::panic::catch_unwind(
                                    std::panic::AssertUnwindSafe(job),
                                );
                                if r.is_err() {
                                    panicked.fetch_add(1, Ordering::SeqCst);
                                }
                                in_flight.fetch_sub(1, Ordering::SeqCst);
                            }
                            Err(_) => break,
                        }
                    })
                    .expect("spawn worker")
            })
            .collect();
        ThreadPool { workers, tx: Some(tx), in_flight, panicked }
    }

    pub fn execute<F: FnOnce() + Send + 'static>(&self, f: F) {
        self.in_flight.fetch_add(1, Ordering::SeqCst);
        self.tx.as_ref().unwrap().send(Box::new(f)).expect("pool closed");
    }

    /// Busy-wait (with yield) until all submitted jobs completed.
    pub fn wait_idle(&self) {
        while self.in_flight.load(Ordering::SeqCst) != 0 {
            thread::yield_now();
        }
    }

    /// Number of jobs that panicked since the last call (the counter
    /// resets). [`execute`](Self::execute)-path jobs have their unwinds
    /// contained in the worker, so callers that need to know must poll
    /// this; [`scoped`](Self::scoped) checks it automatically.
    pub fn take_panics(&self) -> usize {
        self.panicked.swap(0, Ordering::SeqCst)
    }

    /// Run a batch of borrowing jobs to completion on the pool — the
    /// scoped counterpart of [`execute`](Self::execute), used by the
    /// kernels' row-block parallelism so GEMM chunks can borrow the
    /// caller's activation/output slices instead of copying them.
    ///
    /// The last job runs inline on the caller thread (it would otherwise
    /// just spin in `wait_idle`), the rest go to the workers. A panic in
    /// any job is re-raised here, after every job has finished.
    pub fn scoped<'env>(&self, mut jobs: Vec<Box<dyn FnOnce() + Send + 'env>>) {
        /// Blocks until the pool drains, even if the inline job unwinds —
        /// part of the safety argument below.
        struct WaitGuard<'a>(&'a ThreadPool);
        impl Drop for WaitGuard<'_> {
            fn drop(&mut self) {
                self.0.wait_idle();
            }
        }

        // Discard panic counts left over from earlier execute()-path jobs
        // so they are not blamed on this batch (those are surfaced to
        // interested callers via take_panics()).
        self.take_panics();
        let last = jobs.pop();
        let guard = WaitGuard(self);
        for job in jobs {
            // SAFETY: the transmute only erases the `'env` lifetime bound.
            // No job outlives `'env`: this function does not return (or
            // unwind) until every pooled job has completed — workers
            // contain job unwinds via catch_unwind and always decrement
            // in_flight, and `guard` runs wait_idle even when the inline
            // job below panics.
            let job: Box<dyn FnOnce() + Send + 'static> = unsafe { std::mem::transmute(job) };
            self.execute(job);
        }
        if let Some(job) = last {
            job();
        }
        drop(guard);
        if self.take_panics() > 0 {
            panic!("a pooled job panicked (see worker thread output)");
        }
    }
}

impl Drop for ThreadPool {
    fn drop(&mut self) {
        drop(self.tx.take());
        for w in self.workers.drain(..) {
            let _ = w.join();
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::sync::atomic::AtomicU64;

    #[test]
    fn runs_all_jobs() {
        let pool = ThreadPool::new(4);
        let counter = Arc::new(AtomicU64::new(0));
        for _ in 0..100 {
            let c = Arc::clone(&counter);
            pool.execute(move || {
                c.fetch_add(1, Ordering::SeqCst);
            });
        }
        pool.wait_idle();
        assert_eq!(counter.load(Ordering::SeqCst), 100);
    }

    #[test]
    fn scoped_jobs_borrow_stack_data() {
        let pool = ThreadPool::new(3);
        let input: Vec<u64> = (0..100).collect();
        let mut out = vec![0u64; 100];
        {
            let mut jobs: Vec<Box<dyn FnOnce() + Send + '_>> = Vec::new();
            let mut rest = &mut out[..];
            for chunk_idx in 0..4 {
                let tmp = rest;
                let (chunk, tail) = tmp.split_at_mut(25);
                rest = tail;
                let src = &input[chunk_idx * 25..(chunk_idx + 1) * 25];
                jobs.push(Box::new(move || {
                    for (dst, &s) in chunk.iter_mut().zip(src) {
                        *dst = s * 2;
                    }
                }));
            }
            pool.scoped(jobs);
        }
        assert_eq!(out, (0..100).map(|x| x * 2).collect::<Vec<_>>());
    }

    #[test]
    fn scoped_repropagates_job_panics_and_pool_survives() {
        let pool = ThreadPool::new(2);
        let result = std::panic::catch_unwind(std::panic::AssertUnwindSafe(|| {
            let jobs: Vec<Box<dyn FnOnce() + Send + '_>> =
                vec![Box::new(|| panic!("boom")), Box::new(|| {})];
            pool.scoped(jobs);
        }));
        assert!(result.is_err(), "scoped must re-raise pooled panics");
        // the worker survived the unwind and the pool still runs jobs
        let counter = Arc::new(AtomicU64::new(0));
        let c = Arc::clone(&counter);
        pool.execute(move || {
            c.fetch_add(1, Ordering::SeqCst);
        });
        pool.wait_idle();
        assert_eq!(counter.load(Ordering::SeqCst), 1);
    }

    #[test]
    fn drop_joins_cleanly() {
        let pool = ThreadPool::new(2);
        let counter = Arc::new(AtomicU64::new(0));
        for _ in 0..10 {
            let c = Arc::clone(&counter);
            pool.execute(move || {
                std::thread::sleep(std::time::Duration::from_millis(1));
                c.fetch_add(1, Ordering::SeqCst);
            });
        }
        drop(pool);
        assert_eq!(counter.load(Ordering::SeqCst), 10);
    }
}
