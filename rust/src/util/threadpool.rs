//! Fixed-size thread pool over std channels (tokio is not vendored).
//!
//! The serving coordinator uses this for request generation and response
//! post-processing; executions on the PJRT client itself are serialized
//! per-executable (XLA-CPU already parallelizes a single execution across
//! cores, so stacking concurrent executes just thrashes the cache).

use std::sync::atomic::{AtomicUsize, Ordering};
use std::sync::{mpsc, Arc, Mutex};
use std::thread;

type Job = Box<dyn FnOnce() + Send + 'static>;

pub struct ThreadPool {
    workers: Vec<thread::JoinHandle<()>>,
    tx: Option<mpsc::Sender<Job>>,
    in_flight: Arc<AtomicUsize>,
}

impl ThreadPool {
    pub fn new(n: usize) -> Self {
        assert!(n > 0);
        let (tx, rx) = mpsc::channel::<Job>();
        let rx = Arc::new(Mutex::new(rx));
        let in_flight = Arc::new(AtomicUsize::new(0));
        let workers = (0..n)
            .map(|i| {
                let rx = Arc::clone(&rx);
                let in_flight = Arc::clone(&in_flight);
                thread::Builder::new()
                    .name(format!("mkq-pool-{i}"))
                    .spawn(move || loop {
                        let job = {
                            let guard = rx.lock().unwrap();
                            guard.recv()
                        };
                        match job {
                            Ok(job) => {
                                job();
                                in_flight.fetch_sub(1, Ordering::SeqCst);
                            }
                            Err(_) => break,
                        }
                    })
                    .expect("spawn worker")
            })
            .collect();
        ThreadPool { workers, tx: Some(tx), in_flight }
    }

    pub fn execute<F: FnOnce() + Send + 'static>(&self, f: F) {
        self.in_flight.fetch_add(1, Ordering::SeqCst);
        self.tx.as_ref().unwrap().send(Box::new(f)).expect("pool closed");
    }

    /// Busy-wait (with yield) until all submitted jobs completed.
    pub fn wait_idle(&self) {
        while self.in_flight.load(Ordering::SeqCst) != 0 {
            thread::yield_now();
        }
    }
}

impl Drop for ThreadPool {
    fn drop(&mut self) {
        drop(self.tx.take());
        for w in self.workers.drain(..) {
            let _ = w.join();
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::sync::atomic::AtomicU64;

    #[test]
    fn runs_all_jobs() {
        let pool = ThreadPool::new(4);
        let counter = Arc::new(AtomicU64::new(0));
        for _ in 0..100 {
            let c = Arc::clone(&counter);
            pool.execute(move || {
                c.fetch_add(1, Ordering::SeqCst);
            });
        }
        pool.wait_idle();
        assert_eq!(counter.load(Ordering::SeqCst), 100);
    }

    #[test]
    fn drop_joins_cleanly() {
        let pool = ThreadPool::new(2);
        let counter = Arc::new(AtomicU64::new(0));
        for _ in 0..10 {
            let c = Arc::clone(&counter);
            pool.execute(move || {
                std::thread::sleep(std::time::Duration::from_millis(1));
                c.fetch_add(1, Ordering::SeqCst);
            });
        }
        drop(pool);
        assert_eq!(counter.load(Ordering::SeqCst), 10);
    }
}
