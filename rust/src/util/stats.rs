//! Summary statistics for the benchmark harness and serving metrics.

/// Percentile over a sample (nearest-rank on a sorted copy).
pub fn percentile(xs: &[f64], p: f64) -> f64 {
    assert!(!xs.is_empty());
    let mut v = xs.to_vec();
    v.sort_by(|a, b| a.partial_cmp(b).unwrap());
    let rank = ((p / 100.0) * (v.len() as f64 - 1.0)).floor() as usize;
    v[rank.min(v.len() - 1)]
}

pub fn mean(xs: &[f64]) -> f64 {
    if xs.is_empty() {
        return 0.0;
    }
    xs.iter().sum::<f64>() / xs.len() as f64
}

pub fn stddev(xs: &[f64]) -> f64 {
    if xs.len() < 2 {
        return 0.0;
    }
    let m = mean(xs);
    (xs.iter().map(|x| (x - m) * (x - m)).sum::<f64>() / (xs.len() - 1) as f64).sqrt()
}

/// Online latency recorder (microseconds) with summary reporting.
#[derive(Default, Clone)]
pub struct LatencyRecorder {
    samples_us: Vec<f64>,
}

impl LatencyRecorder {
    pub fn new() -> Self {
        Self::default()
    }

    pub fn record(&mut self, us: f64) {
        self.samples_us.push(us);
    }

    pub fn record_duration(&mut self, d: std::time::Duration) {
        self.record(d.as_secs_f64() * 1e6);
    }

    pub fn count(&self) -> usize {
        self.samples_us.len()
    }

    pub fn summary(&self) -> LatencySummary {
        if self.samples_us.is_empty() {
            return LatencySummary::default();
        }
        LatencySummary {
            count: self.samples_us.len(),
            mean_us: mean(&self.samples_us),
            p50_us: percentile(&self.samples_us, 50.0),
            p90_us: percentile(&self.samples_us, 90.0),
            p99_us: percentile(&self.samples_us, 99.0),
            min_us: self.samples_us.iter().cloned().fold(f64::INFINITY, f64::min),
            max_us: self.samples_us.iter().cloned().fold(0.0, f64::max),
        }
    }
}

#[derive(Default, Debug, Clone, Copy)]
pub struct LatencySummary {
    pub count: usize,
    pub mean_us: f64,
    pub p50_us: f64,
    pub p90_us: f64,
    pub p99_us: f64,
    pub min_us: f64,
    pub max_us: f64,
}

impl std::fmt::Display for LatencySummary {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        write!(
            f,
            "n={} mean={:.1}us p50={:.1}us p90={:.1}us p99={:.1}us",
            self.count, self.mean_us, self.p50_us, self.p90_us, self.p99_us
        )
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn percentiles() {
        let xs: Vec<f64> = (1..=100).map(|x| x as f64).collect();
        assert_eq!(percentile(&xs, 50.0), 50.0);
        assert_eq!(percentile(&xs, 100.0), 100.0);
        assert_eq!(percentile(&xs, 0.0), 1.0);
    }

    #[test]
    fn mean_stddev() {
        let xs = [2.0, 4.0, 4.0, 4.0, 5.0, 5.0, 7.0, 9.0];
        assert!((mean(&xs) - 5.0).abs() < 1e-12);
        assert!((stddev(&xs) - 2.138089935299395).abs() < 1e-9);
    }

    #[test]
    fn recorder() {
        let mut r = LatencyRecorder::new();
        for i in 1..=1000 {
            r.record(i as f64);
        }
        let s = r.summary();
        assert_eq!(s.count, 1000);
        assert!((s.p50_us - 500.0).abs() <= 1.0);
        assert!(s.p99_us >= 989.0);
    }
}
