//! Quantization math on the Rust side — the serving-path mirror of
//! `python/compile/kernels/ref.py`.
//!
//! The trainer receives *fp32* weights back from QAT; before serving, the
//! coordinator quantizes them here (codes + scales) and feeds integer
//! buffers to the int8/int4 layer artifacts. The math must match the
//! Python oracle bit-for-bit; `rust/tests/` cross-checks through the
//! `qmatmul_pallas_*` artifacts.
//!
//! Paper conventions (Eq. 1): k-bit grid [l_min, l_max] = [-2^{k-1}+1,
//! 2^{k-1}]. Storage caveat: +128 does not fit int8, so *deployed* int8
//! codes clamp to 127 (fake-quant during QAT keeps the exact grid); int4
//! codes ride offset-nibbles (q+7 in [0,15]), two per byte.

pub const INT4_OFFSET: i32 = 7;

/// (l_min, l_max) for k-bit quantization per the paper's convention.
pub fn qbounds(bits: u32) -> (f32, f32) {
    let lmax = (1i64 << (bits - 1)) as f32;
    (-lmax + 1.0, lmax)
}

/// l_max usable by the *deployed* integer kernels (int8 storage clamp).
pub fn qmax_store(bits: u32) -> f32 {
    match bits {
        8 => 127.0,
        b => qbounds(b).1,
    }
}

/// Quantize one value to its integer code (deployed-storage clamp).
pub fn quantize_code(x: f32, s: f32, bits: u32) -> i32 {
    let (lmin, _) = qbounds(bits);
    let lmax = qmax_store(bits);
    (x / s).round().clamp(lmin, lmax) as i32
}

/// Eq. (1): quantize-dequantize (matches `ref.fake_quant` exactly — the
/// paper grid, including +2^{k-1}).
pub fn fake_quant(x: f32, s: f32, bits: u32) -> f32 {
    let (lmin, lmax) = qbounds(bits);
    s * (x / s).round().clamp(lmin, lmax)
}

/// Symmetric per-output-channel weight quantization of a (k, n)
/// row-major matrix. Returns (codes (k*n, i8), scales (n,)).
///
/// Both passes stream `w` row-major: the abs-max pass keeps the running
/// per-column maxima (an `n`-sized vector, cache-resident) while walking
/// rows sequentially, instead of striding down each column — on a 768x3072
/// matrix the strided version touched a new cache line per element.
pub fn quantize_weight_per_channel(w: &[f32], k: usize, n: usize, bits: u32) -> (Vec<i8>, Vec<f32>) {
    assert_eq!(w.len(), k * n);
    let (_, lmax_grid) = qbounds(bits);
    let mut maxabs = vec![0f32; n];
    for row in 0..k {
        let r = &w[row * n..(row + 1) * n];
        for col in 0..n {
            maxabs[col] = maxabs[col].max(r[col].abs());
        }
    }
    let scales: Vec<f32> =
        maxabs.iter().map(|&m| if m > 0.0 { m / lmax_grid } else { 1e-8 }).collect();
    let mut codes = vec![0i8; k * n];
    for row in 0..k {
        let r = &w[row * n..(row + 1) * n];
        let c = &mut codes[row * n..(row + 1) * n];
        for col in 0..n {
            c[col] = quantize_code(r[col], scales[col], bits) as i8;
        }
    }
    (codes, scales)
}

/// The pre-optimization column-major traversal, kept as the before/after
/// baseline for the kernels bench (`benches/layers.rs`); numerically
/// identical to [`quantize_weight_per_channel`].
pub fn quantize_weight_per_channel_colmajor(
    w: &[f32],
    k: usize,
    n: usize,
    bits: u32,
) -> (Vec<i8>, Vec<f32>) {
    assert_eq!(w.len(), k * n);
    let (_, lmax_grid) = qbounds(bits);
    let mut scales = vec![0f32; n];
    for col in 0..n {
        let mut m = 0f32;
        for row in 0..k {
            m = m.max(w[row * n + col].abs());
        }
        scales[col] = if m > 0.0 { m / lmax_grid } else { 1e-8 };
    }
    let mut codes = vec![0i8; k * n];
    for row in 0..k {
        for col in 0..n {
            codes[row * n + col] = quantize_code(w[row * n + col], scales[col], bits) as i8;
        }
    }
    (codes, scales)
}

/// Activation scale from a calibration statistic (paper §3.1: the top
/// 0.01% |activation| over calibration batches, divided by l_max).
pub fn act_scale_from_stat(stat: f32, bits: u32) -> f32 {
    let (_, lmax) = qbounds(bits);
    (stat / lmax).max(1e-8)
}

/// Pack (k, n) int4 codes along K into (k/2, n) offset-nibble bytes
/// (row 2r in the low nibble, row 2r+1 in the high nibble) — the layout
/// `qmatmul4` and the int4 layer artifacts expect. Output is i32 per the
/// artifact input dtype.
pub fn pack_int4_k(codes: &[i8], k: usize, n: usize) -> Vec<i32> {
    assert_eq!(codes.len(), k * n);
    assert!(k % 2 == 0, "K must be even to nibble-pack");
    let mut out = vec![0i32; (k / 2) * n];
    for r in 0..k / 2 {
        for c in 0..n {
            let lo = codes[(2 * r) * n + c] as i32 + INT4_OFFSET;
            let hi = codes[(2 * r + 1) * n + c] as i32 + INT4_OFFSET;
            debug_assert!((0..16).contains(&lo) && (0..16).contains(&hi), "code out of int4 range");
            out[r * n + c] = lo | (hi << 4);
        }
    }
    out
}

/// Inverse of `pack_int4_k` (test / debugging surface).
pub fn unpack_int4_k(packed: &[i32], k: usize, n: usize) -> Vec<i8> {
    assert_eq!(packed.len(), (k / 2) * n);
    let mut out = vec![0i8; k * n];
    for r in 0..k / 2 {
        for c in 0..n {
            let b = packed[r * n + c];
            out[(2 * r) * n + c] = ((b & 0xF) - INT4_OFFSET) as i8;
            out[(2 * r + 1) * n + c] = (((b >> 4) & 0xF) - INT4_OFFSET) as i8;
        }
    }
    out
}

/// Reference quantized matmul (used by unit tests and the Pallas
/// cross-check): out = (round(clamp(x/sx)) @ codes) * sx * sw.
pub fn qmatmul_ref(
    x: &[f32], m: usize, k: usize,
    codes: &[i8], n: usize,
    sx: &[f32], sw: &[f32], bits: u32,
) -> Vec<f32> {
    assert_eq!(x.len(), m * k);
    assert_eq!(codes.len(), k * n);
    assert_eq!(sx.len(), m);
    assert_eq!(sw.len(), n);
    let (lmin, lmax) = qbounds(bits);
    let mut out = vec![0f32; m * n];
    for i in 0..m {
        let xq: Vec<f32> = (0..k).map(|j| (x[i * k + j] / sx[i]).round().clamp(lmin, lmax)).collect();
        for c in 0..n {
            let mut acc = 0f32;
            for j in 0..k {
                acc += xq[j] * codes[j * n + c] as f32;
            }
            out[i * n + c] = acc * sx[i] * sw[c];
        }
    }
    out
}

/// Uniform random codes over the deployed k-bit storage grid
/// ([-7, 8] for int4, [-127, 127] for int8). The kernel tests and
/// benches all draw through here so the grid definition lives in one
/// place.
pub fn random_codes(rng: &mut crate::util::rng::Rng, len: usize, bits: u32) -> Vec<i8> {
    let (span, off) = if bits == 4 { (16usize, 7i32) } else { (255, 127) };
    (0..len).map(|_| (rng.range(0, span) as i32 - off) as i8).collect()
}

/// Parse "8,8,4,4" (must match n_layers).
pub fn parse_bits(s: &str, n_layers: usize) -> anyhow::Result<Vec<u32>> {
    use anyhow::{bail, Context};
    let bits: Vec<u32> = s
        .split(',')
        .map(|p| p.trim().parse::<u32>())
        .collect::<Result<_, _>>()
        .with_context(|| format!("bad bits spec {s:?}"))?;
    if bits.len() != n_layers {
        bail!("bits spec {s:?} has {} entries, model has {n_layers} layers", bits.len());
    }
    for &b in &bits {
        if !matches!(b, 4 | 8 | 32) {
            bail!("unsupported bit width {b} (use 4, 8 or 32)");
        }
    }
    Ok(bits)
}

/// The paper's layer-selection rule: "higher levels are more robust to
/// quantization therefore we start from the last layer" — n_int4 last
/// layers at 4 bits, the rest at 8.
pub fn bits_last_n_int4(n_layers: usize, n_int4: usize) -> Vec<u32> {
    // clamp instead of underflowing: `--n-int4 99` on a 4-layer model means
    // "all int4", not a debug-build panic / release-build all-int8 wrap
    let n_int4 = n_int4.min(n_layers);
    (0..n_layers).map(|l| if l >= n_layers - n_int4 { 4 } else { 8 }).collect()
}

/// Bits-reduction factor of a mixed-precision configuration relative to
/// fp32 (the paper's "5.3x of bits reduction" headline for the
/// embedding-fp32 + int4-body TinyBERT).
pub fn bits_reduction(layer_bits: &[u32], params_per_layer: usize, fp32_params: usize) -> f64 {
    let body_bits: f64 = layer_bits.iter().map(|&b| b as f64 * params_per_layer as f64).sum();
    let total_fp32 = (fp32_params + layer_bits.len() * params_per_layer) as f64 * 32.0;
    total_fp32 / (fp32_params as f64 * 32.0 + body_bits)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::proptest::{check, ensure, PropConfig};
    use crate::util::rng::Rng;

    #[test]
    fn bounds_match_paper() {
        assert_eq!(qbounds(4), (-7.0, 8.0));
        assert_eq!(qbounds(8), (-127.0, 128.0));
        assert_eq!(qmax_store(8), 127.0);
        assert_eq!(qmax_store(4), 8.0);
    }

    #[test]
    fn fake_quant_worked_example() {
        // Paper §4.1: x=(0.2, 0.9), s=1 -> Q[x]=(0, 1).
        assert_eq!(fake_quant(0.2, 1.0, 4), 0.0);
        assert_eq!(fake_quant(0.9, 1.0, 4), 1.0);
    }

    #[test]
    fn per_channel_quantization_bounds() {
        let mut rng = Rng::new(1);
        let (k, n) = (32, 8);
        let w: Vec<f32> = (0..k * n).map(|_| rng.normal() as f32 * 0.1).collect();
        let (codes, scales) = quantize_weight_per_channel(&w, k, n, 4);
        assert!(codes.iter().all(|&c| (-7..=8).contains(&(c as i32))));
        // max-abs element of each column must map to ±lmax-ish code
        for col in 0..n {
            let max_code = (0..k).map(|r| codes[r * n + col].abs()).max().unwrap();
            assert!(max_code >= 7, "column {col} badly scaled");
            assert!(scales[col] > 0.0);
        }
    }

    #[test]
    fn pack_roundtrip_exhaustive_nibbles() {
        // every (lo, hi) nibble combination survives the roundtrip
        let mut codes = Vec::new();
        for lo in -7..=8i32 {
            for hi in -7..=8i32 {
                codes.push(lo as i8);
                codes.push(hi as i8);
            }
        }
        let k = codes.len();
        let packed = pack_int4_k(&codes, k, 1);
        assert_eq!(unpack_int4_k(&packed, k, 1), codes);
    }

    #[test]
    fn pack_roundtrip_property() {
        check("pack-unpack-int4", PropConfig::default(), |rng, size| {
            let k = 2 * (1 + size);
            let n = 1 + size / 4;
            let codes: Vec<i8> = (0..k * n).map(|_| (rng.range(0, 16) as i32 - 7) as i8).collect();
            let packed = pack_int4_k(&codes, k, n);
            ensure(unpack_int4_k(&packed, k, n) == codes, "roundtrip mismatch")?;
            ensure(packed.iter().all(|&b| (0..256).contains(&b)), "byte out of range")
        });
    }

    #[test]
    fn fake_quant_error_bound_property() {
        check("fq-error-bound", PropConfig::default(), |rng, _| {
            let s = 0.01 + rng.f32() * 0.5;
            let x = (rng.normal() as f32) * 2.0;
            let q = fake_quant(x, s, 8);
            let (lmin, lmax) = qbounds(8);
            if x / s >= lmin && x / s <= lmax {
                ensure((q - x).abs() <= s / 2.0 + 1e-5, format!("err {} > s/2 {}", (q - x).abs(), s))
            } else {
                Ok(())
            }
        });
    }

    #[test]
    fn qmatmul_ref_identity() {
        // 1x1 identity sanity: x=2.0, code=3, sx=1, sw=0.5 -> 2*3*0.5=3
        let out = qmatmul_ref(&[2.0], 1, 1, &[3], 1, &[1.0], &[0.5], 8);
        assert_eq!(out, vec![3.0]);
    }

    #[test]
    fn rowmajor_quantizer_matches_colmajor_baseline() {
        check("quantizer-traversal-equiv", PropConfig::default(), |rng, size| {
            let k = 1 + size;
            let n = 1 + size / 2;
            let w: Vec<f32> = (0..k * n).map(|_| rng.normal() as f32 * 0.1).collect();
            for bits in [4u32, 8] {
                let (c_new, s_new) = quantize_weight_per_channel(&w, k, n, bits);
                let (c_old, s_old) = quantize_weight_per_channel_colmajor(&w, k, n, bits);
                ensure(c_new == c_old, format!("codes diverge (bits={bits})"))?;
                ensure(s_new == s_old, format!("scales diverge (bits={bits})"))?;
            }
            Ok(())
        });
    }

    #[test]
    fn parse_bits_validates() {
        assert_eq!(parse_bits("8,8,4,4", 4).unwrap(), vec![8, 8, 4, 4]);
        assert!(parse_bits("8,8", 4).is_err());
        assert!(parse_bits("8,8,3,4", 4).is_err());
        assert!(parse_bits("x", 1).is_err());
    }

    #[test]
    fn last_n_int4_rule() {
        assert_eq!(bits_last_n_int4(4, 0), vec![8, 8, 8, 8]);
        assert_eq!(bits_last_n_int4(4, 1), vec![8, 8, 8, 4]);
        assert_eq!(bits_last_n_int4(4, 2), vec![8, 8, 4, 4]);
        assert_eq!(bits_last_n_int4(4, 4), vec![4, 4, 4, 4]);
        assert_eq!(bits_last_n_int4(4, 9), vec![4, 4, 4, 4]); // clamped, no underflow
    }

    #[test]
    fn bits_reduction_headline() {
        // TinyBERT4: ~4.7M embedding params fp32, ~9.8M body. All-int4 body:
        // reduction = (14.5M*32) / (4.7M*32 + 9.8M*4) ~ 2.5x; the paper's
        // 5.3x counts its int8 embedding handling — we verify monotonicity
        // and the >5x case with int8 embeddings (see EXPERIMENTS.md).
        let r44 = bits_reduction(&[4, 4, 4, 4], 2_450_000, 4_700_000);
        let r88 = bits_reduction(&[8, 8, 8, 8], 2_450_000, 4_700_000);
        assert!(r44 > r88);
        assert!(r44 > 2.0 && r44 < 32.0);
    }
}
