//! WordPiece-style tokenizer substrate.
//!
//! The paper's pipeline starts from text; our synthetic-GLUE generators
//! emit word strings, and this module turns them into model token ids:
//! vocabulary building (frequency-ranked words + character fallback
//! pieces) and greedy longest-match-first subword splitting with `##`
//! continuation pieces — the BERT tokenization algorithm, scaled to the
//! synthetic lexicon.
//!
//! Special ids are fixed by convention shared with the data generators:
//! 0=[PAD], 1=[CLS], 2=[SEP], 3=[UNK].

use std::collections::HashMap;

pub const PAD: i32 = 0;
pub const CLS: i32 = 1;
pub const SEP: i32 = 2;
pub const UNK: i32 = 3;
pub const N_SPECIAL: usize = 4;

#[derive(Debug, Clone)]
pub struct Tokenizer {
    vocab: HashMap<String, i32>,
    ids_to_tok: Vec<String>,
    max_piece_len: usize,
}

impl Tokenizer {
    /// Build a vocabulary of at most `vocab_size` entries from a corpus of
    /// words: all single characters (as both word-initial and `##`
    /// continuation pieces) are always included so tokenization never
    /// fails, then whole words by descending frequency.
    pub fn build(corpus_words: &[&str], vocab_size: usize) -> Self {
        let mut freq: HashMap<&str, u64> = HashMap::new();
        let mut chars: Vec<char> = Vec::new();
        for w in corpus_words {
            *freq.entry(*w).or_insert(0) += 1;
            for c in w.chars() {
                if !chars.contains(&c) {
                    chars.push(c);
                }
            }
        }
        chars.sort_unstable();

        let mut ids_to_tok: Vec<String> =
            vec!["[PAD]".into(), "[CLS]".into(), "[SEP]".into(), "[UNK]".into()];
        // character fallback pieces
        for &c in &chars {
            ids_to_tok.push(c.to_string());
            ids_to_tok.push(format!("##{c}"));
        }
        // frequency-ranked whole words
        let mut by_freq: Vec<(&str, u64)> = freq.into_iter().collect();
        by_freq.sort_by(|a, b| b.1.cmp(&a.1).then(a.0.cmp(b.0)));
        for (w, _) in by_freq {
            if ids_to_tok.len() >= vocab_size {
                break;
            }
            if w.chars().count() > 1 {
                ids_to_tok.push(w.to_string());
            }
        }
        assert!(
            ids_to_tok.len() <= vocab_size,
            "character set alone exceeds vocab_size ({} > {vocab_size})",
            ids_to_tok.len()
        );

        let vocab: HashMap<String, i32> =
            ids_to_tok.iter().enumerate().map(|(i, t)| (t.clone(), i as i32)).collect();
        let max_piece_len = ids_to_tok.iter().map(|t| t.len()).max().unwrap_or(1);
        Tokenizer { vocab, ids_to_tok, max_piece_len }
    }

    pub fn vocab_size(&self) -> usize {
        self.ids_to_tok.len()
    }

    pub fn id(&self, tok: &str) -> Option<i32> {
        self.vocab.get(tok).copied()
    }

    pub fn token(&self, id: i32) -> &str {
        self.ids_to_tok.get(id as usize).map(|s| s.as_str()).unwrap_or("[UNK]")
    }

    /// WordPiece a single word: greedy longest-match-first; continuation
    /// pieces carry the `##` prefix. Falls back to [UNK] only if some
    /// character is outside the vocabulary alphabet.
    pub fn wordpiece(&self, word: &str) -> Vec<i32> {
        if let Some(&id) = self.vocab.get(word) {
            return vec![id];
        }
        let chars: Vec<char> = word.chars().collect();
        let mut out = Vec::new();
        let mut start = 0;
        while start < chars.len() {
            let mut end = chars.len().min(start + self.max_piece_len);
            let mut found = None;
            while end > start {
                let piece: String = chars[start..end].iter().collect();
                let key = if start == 0 { piece } else { format!("##{piece}") };
                if let Some(&id) = self.vocab.get(&key) {
                    found = Some((id, end));
                    break;
                }
                end -= 1;
            }
            match found {
                Some((id, e)) => {
                    out.push(id);
                    start = e;
                }
                None => return vec![UNK],
            }
        }
        out
    }

    /// Encode a (possibly pair) example: [CLS] a [SEP] (b [SEP])?, truncated
    /// to `max_len`, padded with [PAD]. Returns (ids, mask).
    pub fn encode(&self, text_a: &[&str], text_b: Option<&[&str]>, max_len: usize) -> (Vec<i32>, Vec<f32>) {
        let mut ids = vec![CLS];
        for w in text_a {
            ids.extend(self.wordpiece(w));
        }
        ids.push(SEP);
        if let Some(b) = text_b {
            for w in b {
                ids.extend(self.wordpiece(w));
            }
            ids.push(SEP);
        }
        ids.truncate(max_len);
        let mut mask = vec![1.0; ids.len()];
        while ids.len() < max_len {
            ids.push(PAD);
            mask.push(0.0);
        }
        (ids, mask)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn toy() -> Tokenizer {
        let corpus = ["river", "bank", "riverbank", "run", "running", "bank"];
        Tokenizer::build(&corpus, 128)
    }

    #[test]
    fn whole_words_have_ids() {
        let t = toy();
        assert_eq!(t.wordpiece("bank").len(), 1);
        assert_eq!(t.wordpiece("river").len(), 1);
    }

    #[test]
    fn subword_fallback_covers_unseen() {
        let t = toy();
        let pieces = t.wordpiece("runbank"); // unseen word -> run + ##b ##a ##n ##k
        assert!(pieces.len() >= 2);
        assert_ne!(pieces[0], UNK);
        // Longest-match-first: the first piece should be the whole known word.
        assert_eq!(t.token(pieces[0]), "run");
        assert_eq!(t.token(*pieces.last().unwrap()), "##k");
    }

    #[test]
    fn unknown_alphabet_is_unk() {
        let t = toy();
        assert_eq!(t.wordpiece("日本"), vec![UNK]);
    }

    #[test]
    fn encode_single_and_pair() {
        let t = toy();
        let (ids, mask) = t.encode(&["river", "bank"], None, 8);
        assert_eq!(ids[0], CLS);
        assert_eq!(ids.len(), 8);
        assert_eq!(mask.len(), 8);
        assert_eq!(mask.iter().filter(|&&m| m == 1.0).count(), 4); // CLS r b SEP
        let (ids2, _) = t.encode(&["river"], Some(&["bank"]), 8);
        let seps = ids2.iter().filter(|&&i| i == SEP).count();
        assert_eq!(seps, 2);
    }

    #[test]
    fn encode_truncates() {
        let t = toy();
        let words = vec!["river"; 20];
        let (ids, mask) = t.encode(&words, None, 8);
        assert_eq!(ids.len(), 8);
        assert!(mask.iter().all(|&m| m == 1.0));
    }

    #[test]
    fn deterministic_vocab_order() {
        let corpus = ["b", "a", "ab", "ab", "ba"];
        let t1 = Tokenizer::build(&corpus, 64);
        let t2 = Tokenizer::build(&corpus, 64);
        assert_eq!(t1.ids_to_tok, t2.ids_to_tok);
    }

    #[test]
    fn vocab_size_respected() {
        let words: Vec<String> = (0..500).map(|i| format!("w{i}")).collect();
        let refs: Vec<&str> = words.iter().map(|s| s.as_str()).collect();
        let t = Tokenizer::build(&refs, 128);
        assert!(t.vocab_size() <= 128);
    }
}
