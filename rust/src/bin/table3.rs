//! Table 3 reproduction: ablation studies on TinyBERT4_{3,4} (last two
//! layers int4, rest int8):
//!
//!   full MKQ-BERT        — MSE grad + MINI KD + output KD + LSQ
//!   w/o MINI KD          — β = 0 (no attention/value distillation)
//!   w/o output KD        — α = 0 (no logit distillation)
//!   w/o LSQ              — scales frozen after calibration
//!
//! Usage: cargo run --release --bin table3 -- [--tasks ...] [--steps 300]
//!            [--out results/table3.txt] [--quick]

use anyhow::Result;
use mkq::coordinator::{bits_last_n_int4, QatConfig, Trainer};
use mkq::data::{Suite, TaskKind, ALL_TASKS};
use mkq::runtime::Engine;
use mkq::util::cli::Args;

fn main() -> Result<()> {
    let args = Args::parse();
    let eng = Engine::load(&mkq::artifacts_dir())?;
    let mut tr = Trainer::new(&eng)?;
    tr.verbose = args.bool("verbose");
    let d = tr.dims;

    let quick = args.bool("quick");
    let steps = args.usize("steps", if quick { 60 } else { 300 });
    let teacher_steps = args.usize("teacher-steps", if quick { 80 } else { 200 });
    let eval_every = args.usize("eval-every", if quick { 30 } else { 100 });

    let tasks: Vec<TaskKind> = match args.list("tasks") {
        Some(names) => names
            .iter()
            .map(|n| TaskKind::parse(n).unwrap_or_else(|| panic!("unknown task {n}")))
            .collect(),
        None => ALL_TASKS.to_vec(),
    };

    let base = QatConfig { bits: bits_last_n_int4(d.n_layers, 2), steps, eval_every, ..Default::default() };
    let variants: Vec<(&str, QatConfig)> = vec![
        ("TinyBERT4_{3,4}", base.clone()),
        ("  w/o MINI KD", QatConfig { beta: 0.0, ..base.clone() }),
        ("  w/o output KD", QatConfig { alpha: 0.0, ..base.clone() }),
        ("  w/o LSQ", QatConfig { lsq: false, ..base.clone() }),
    ];

    let suite = Suite::new(42, d.vocab, d.seq);
    let mut table: Vec<(String, Vec<f64>)> =
        variants.iter().map(|(l, _)| (l.to_string(), vec![])).collect();

    for kind in &tasks {
        println!("=== task {} ===", kind.name());
        let task = suite.task(*kind, 1);
        let (teacher, teacher_acc) = tr.finetune_teacher_best(
            &task, teacher_steps, args.f64("teacher-lr", 1e-3), 11, 0.62, 4)?;
        println!("  teacher fp32: {teacher_acc:.4}");
        let (act, wmax) = tr.calibrate(&teacher, &task.train, 8, 11)?;

        for (i, (label, cfg)) in variants.iter().enumerate() {
            let scales = tr.make_scales(&act, &wmax, &cfg.bits)?;
            let res = tr.qat(&teacher, scales, &task, cfg)?;
            println!("  {label:<22} best {:.4}", res.best_dev_acc);
            table[i].1.push(res.best_dev_acc);
        }
    }

    let mut out = String::new();
    out.push_str(&format!("{:<26}", "Model"));
    for k in &tasks {
        out.push_str(&format!("{:>8}", k.name().to_uppercase()));
    }
    out.push('\n');
    for (label, accs) in &table {
        out.push_str(&format!("{label:<26}"));
        for a in accs {
            out.push_str(&format!("{:>8.1}", a * 100.0));
        }
        out.push('\n');
    }
    println!("\nTable 3 (ablations, synthetic-GLUE dev accuracy %)\n{out}");

    if let Some(path) = args.get("out") {
        if let Some(parent) = std::path::Path::new(path).parent() {
            std::fs::create_dir_all(parent)?;
        }
        std::fs::write(path, &out)?;
        println!("written to {path}");
    }
    Ok(())
}
