//! Table 1 reproduction: synthetic-GLUE dev accuracy across quantization
//! configurations, MKQ-BERT vs the KDLSQ baseline.
//!
//! Rows (as in the paper):
//!   TinyBERT4 (original)        — fp32 teacher
//!   TinyBERT4_4        (+KDLSQ) — last layer int4, rest int8
//!   TinyBERT4_{3,4}    (+KDLSQ) — last 2 layers int4
//!   TinyBERT4_{2,3,4}  (+KDLSQ) — last 3 layers int4
//!   TinyBERT4_{1,2,3,4}(+KDLSQ) — all layers int4 (embedding always fp32)
//!
//! Usage:
//!   cargo run --release --bin table1 -- [--tasks rte,mrpc] [--steps 300]
//!       [--teacher-steps 200] [--out results/table1.txt] [--quick]

use anyhow::Result;
use mkq::coordinator::{bits_last_n_int4, QatConfig, Trainer};
use mkq::data::{Suite, TaskKind, ALL_TASKS};
use mkq::runtime::Engine;
use mkq::util::cli::Args;

fn main() -> Result<()> {
    let args = Args::parse();
    let eng = Engine::load(&mkq::artifacts_dir())?;
    let mut tr = Trainer::new(&eng)?;
    tr.verbose = args.bool("verbose");
    let d = tr.dims;

    let quick = args.bool("quick");
    let steps = args.usize("steps", if quick { 60 } else { 300 });
    let teacher_steps = args.usize("teacher-steps", if quick { 80 } else { 200 });
    let eval_every = args.usize("eval-every", if quick { 30 } else { 100 });

    let tasks: Vec<TaskKind> = match args.list("tasks") {
        Some(names) => names
            .iter()
            .map(|n| TaskKind::parse(n).unwrap_or_else(|| panic!("unknown task {n}")))
            .collect(),
        None => ALL_TASKS.to_vec(),
    };

    let suite = Suite::new(42, d.vocab, d.seq);
    // row label -> (n_int4, method)
    let rows: Vec<(String, usize, bool)> = (1..=d.n_layers)
        .flat_map(|n| {
            let subscript: Vec<String> =
                ((d.n_layers - n + 1)..=d.n_layers).map(|i| i.to_string()).collect();
            let sub = subscript.join(",");
            vec![
                (format!("TinyBERT4_{{{sub}}}"), n, true),
                (format!("TinyBERT4_{{{sub}}}(KDLSQ)"), n, false),
            ]
        })
        .collect();

    let mut table: Vec<(String, Vec<f64>)> =
        vec![("TinyBERT4 (original)".to_string(), vec![])];
    for (label, _, _) in &rows {
        table.push((label.clone(), vec![]));
    }

    for kind in &tasks {
        println!("=== task {} ===", kind.name());
        let task = suite.task(*kind, 1);
        let (teacher, teacher_acc) = tr.finetune_teacher_best(
            &task, teacher_steps, args.f64("teacher-lr", 1e-3), 11, 0.62, 4)?;
        println!("  teacher fp32: {teacher_acc:.4}");
        table[0].1.push(teacher_acc);

        let (act, wmax) = tr.calibrate(&teacher, &task.train, 8, 11)?;

        for (i, (label, n_int4, mse)) in rows.iter().enumerate() {
            let bits = bits_last_n_int4(d.n_layers, *n_int4);
            let scales = tr.make_scales(&act, &wmax, &bits)?;
            let cfg = QatConfig {
                bits,
                mse_grad: *mse,
                steps,
                eval_every,
                ..Default::default()
            };
            let res = tr.qat(&teacher, scales, &task, &cfg)?;
            println!("  {label:<28} best {:.4}", res.best_dev_acc);
            table[i + 1].1.push(res.best_dev_acc);
        }
    }

    // Print the table in the paper's format.
    let mut out = String::new();
    out.push_str(&format!("{:<30}", "Model"));
    for k in &tasks {
        out.push_str(&format!("{:>8}", k.name().to_uppercase()));
    }
    out.push('\n');
    for (label, accs) in &table {
        out.push_str(&format!("{label:<30}"));
        for a in accs {
            out.push_str(&format!("{:>8.1}", a * 100.0));
        }
        out.push('\n');
    }
    println!("\nTable 1 (synthetic-GLUE dev accuracy, %)\n{out}");

    if let Some(path) = args.get("out") {
        if let Some(parent) = std::path::Path::new(path).parent() {
            std::fs::create_dir_all(parent)?;
        }
        std::fs::write(path, &out)?;
        println!("written to {path}");
    }
    Ok(())
}
