//! §5.4 narrative reproduction: end-to-end inference time of a full
//! 12-layer BERT-base-depth encoder as a function of how many layers run
//! int4 ("the overall inference time depends on the number of int4 layers
//! in the model"), plus the bits-reduction accounting behind the paper's
//! 5.3x storage-compression headline.
//!
//! Runs through the [`Backend`] trait: the native kernel path always, and
//! the AOT-artifact path side by side when built with `--features xla`
//! and artifacts are present. Each configuration chains single-layer
//! forwards (the same code path the serving stack uses); the remaining
//! layers run int8.
//!
//! Usage: cargo run --release --bin e2e_speedup -- [--layers 12]
//!            [--iters 10] [--bucket 16x28]

use anyhow::Result;
use mkq::bench_support as bs;
use mkq::quant;
use mkq::runtime::{Backend, NativeBackend, Precision};
use mkq::util::benchkit::Bench;
use mkq::util::cli::Args;

fn run_stack<B: Backend>(
    backend: &B,
    bench: &Bench,
    n_layers: usize,
    bsz: usize,
    t: usize,
    h0: &[f32],
    mask: &[f32],
) -> Result<()> {
    println!("\n== backend: {} ==", backend.name());
    println!("{:>10} {:>14} {:>12} {:>10}", "int4", "total (us)", "vs all-f32", "vs all-int8");

    let chain = |n_int4: usize, all_f32: bool| -> Result<f64> {
        let prec_for = |l: usize| {
            if all_f32 {
                Precision::F32
            } else if l >= n_layers - n_int4 {
                Precision::Int4
            } else {
                Precision::Int8
            }
        };
        // verify once outside timing that the chain executes
        let mut h = h0.to_vec();
        for l in 0..n_layers {
            h = backend.layer_forward(prec_for(l), bsz, t, &h, mask)?;
        }
        let r = bench.run(|| {
            let mut h = h0.to_vec();
            for l in 0..n_layers {
                h = backend.layer_forward(prec_for(l), bsz, t, &h, mask).expect("layer exec");
            }
        });
        Ok(r.mean_us)
    };

    let all_f32 = chain(0, true)?;
    let mut all_int8 = 0.0;
    let mut sweep = vec![0usize, n_layers / 4, n_layers / 2, 3 * n_layers / 4, n_layers];
    sweep.dedup(); // already ascending; duplicates appear when layers % 4 != 0
    for n_int4 in sweep {
        let us = chain(n_int4, false)?;
        if n_int4 == 0 {
            all_int8 = us;
        }
        println!(
            "{:>10} {:>14.1} {:>11.2}x {:>9.2}x",
            n_int4,
            us,
            all_f32 / us,
            all_int8 / us
        );
    }
    println!("{:>10} {:>14.1} {:>11.2}x {:>10}", "(f32)", all_f32, 1.0, "-");
    Ok(())
}

fn main() -> Result<()> {
    let args = Args::parse();
    let n_layers = args.usize("layers", 12);
    let iters = args.usize("iters", 10);
    let bucket = args.str("bucket", "16x28");
    let (bsz, t) = bucket
        .split_once('x')
        .map(|(a, b)| (a.parse().unwrap(), b.parse().unwrap()))
        .expect("--bucket BSxT");
    let bench = Bench::new(2, iters);

    println!("§5.4: end-to-end encoder time vs #int4 layers ({n_layers} layers, bucket {bucket})");
    let weights = bs::make_weights(1);
    let (h, mask) = bs::make_hidden(bsz, t, 2);
    let h0 = h.as_f32()?;
    let mask_v = mask.as_f32()?;

    let mut native = NativeBackend::new();
    let (l32, l8, l4) = bs::native_bench_layers(&weights);
    native.set_bench_layers(l32, l8, l4);
    println!("{}", native.disp.describe());
    run_stack(&native, &bench, n_layers, bsz, t, h0, mask_v)?;

    #[cfg(feature = "xla")]
    {
        use mkq::runtime::{ArtifactBackend, Engine};
        match Engine::load(&mkq::artifacts_dir()) {
            Ok(eng) => {
                let backend = ArtifactBackend::new(&eng).with_bench_weights(&weights)?;
                run_stack(&backend, &bench, n_layers, bsz, t, h0, mask_v)?;
            }
            Err(e) => eprintln!("(artifact backend skipped: {e})"),
        }
    }
    #[cfg(not(feature = "xla"))]
    println!("\n(artifact backend skipped — build with --features xla + make artifacts)");

    // Bits-reduction accounting (paper: "5.3x of bits reduction").
    println!("\nbits-reduction vs fp32 (TinyBERT4 shapes, embedding kept fp32):");
    let params_per_layer = 4 * 312 * 312 + 2 * 312 * 1200; // attention + FFN
    let emb = 30522 * 312; // wordpiece embedding
    for (label, bits) in [
        ("all int8", vec![8u32; 4]),
        ("int4 x2 + int8 x2", vec![8, 8, 4, 4]),
        ("all int4", vec![4u32; 4]),
    ] {
        let r = quant::bits_reduction(&bits, params_per_layer, emb);
        println!("  {label:<20} {r:.2}x");
    }
    println!("  (with int8 embedding, all-int4 body: {:.2}x — the paper's 5.3x regime)", {
        // embedding at 8 bits instead of 32
        let body: f64 = 4.0 * 4.0 * params_per_layer as f64;
        let total_fp32 = (emb + 4 * params_per_layer) as f64 * 32.0;
        total_fp32 / (emb as f64 * 8.0 + body)
    });
    Ok(())
}
