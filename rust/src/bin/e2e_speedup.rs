//! §5.4 narrative reproduction: end-to-end inference time of a full
//! 12-layer BERT-base-depth encoder as a function of how many layers run
//! int4 ("the overall inference time depends on the number of int4 layers
//! in the model"), plus the bits-reduction accounting behind the paper's
//! 5.3x storage-compression headline.
//!
//! Each configuration chains single-layer artifact executions (the same
//! executables the serving path uses); the remaining layers run int8.
//!
//! Usage: cargo run --release --bin e2e_speedup -- [--layers 12]
//!            [--iters 10] [--bucket 16x28]

use anyhow::Result;
use mkq::bench_support as bs;
use mkq::quant;
use mkq::runtime::Engine;
use mkq::util::benchkit::Bench;
use mkq::util::cli::Args;

fn main() -> Result<()> {
    let args = Args::parse();
    let eng = Engine::load(&mkq::artifacts_dir())?;
    let n_layers = args.usize("layers", 12);
    let iters = args.usize("iters", 10);
    let bucket = args.str("bucket", "16x28");
    let (bsz, t) = bucket
        .split_once('x')
        .map(|(a, b)| (a.parse().unwrap(), b.parse().unwrap()))
        .expect("--bucket BSxT");
    let bench = Bench::new(2, iters);

    let weights = bs::make_weights(1);
    let (h, mask) = bs::make_hidden(bsz, t, 2);
    let f32_l: Vec<xla::Literal> =
        bs::f32_inputs(&weights, &h, &mask).iter().map(|t| t.to_literal().unwrap()).collect();
    let int8_l: Vec<xla::Literal> =
        bs::int_inputs(&weights, &h, &mask, 8)?.iter().map(|t| t.to_literal().unwrap()).collect();
    let int4_l: Vec<xla::Literal> =
        bs::int_inputs(&weights, &h, &mask, 4)?.iter().map(|t| t.to_literal().unwrap()).collect();

    let names = [
        format!("layer_f32_b{bsz}_t{t}"),
        format!("layer_int8_b{bsz}_t{t}"),
        format!("layer_int4_b{bsz}_t{t}"),
    ];
    for n in &names {
        eng.compile(n)?;
    }
    fn refs(v: &[xla::Literal]) -> Vec<&xla::Literal> {
        v.iter().collect()
    }
    let f32_r = refs(&f32_l);
    let int8_r = refs(&int8_l);
    let int4_r = refs(&int4_l);

    println!("§5.4: end-to-end encoder time vs #int4 layers ({n_layers} layers, bucket {bucket})");
    println!("{:>10} {:>14} {:>12} {:>10}", "int4", "total (us)", "vs all-f32", "vs all-int8");

    // all-f32 reference
    let all_f32 = bench
        .run(|| {
            for _ in 0..n_layers {
                eng.execute_raw(&names[0], &f32_r).expect("exec");
            }
        })
        .mean_us;
    let mut all_int8 = 0.0;

    for n_int4 in [0usize, n_layers / 4, n_layers / 2, 3 * n_layers / 4, n_layers] {
        let r = bench.run(|| {
            for l in 0..n_layers {
                let (nm, inp) = if l >= n_layers - n_int4 { (&names[2], &int4_r) } else { (&names[1], &int8_r) };
                eng.execute_raw(nm, inp).expect("exec");
            }
        });
        if n_int4 == 0 {
            all_int8 = r.mean_us;
        }
        println!(
            "{:>10} {:>14.1} {:>11.2}x {:>9.2}x",
            n_int4,
            r.mean_us,
            all_f32 / r.mean_us,
            all_int8 / r.mean_us
        );
    }
    println!("{:>10} {:>14.1} {:>11.2}x {:>10}", "(f32)", all_f32, 1.0, "-");

    // Bits-reduction accounting (paper: "5.3x of bits reduction").
    println!("\nbits-reduction vs fp32 (TinyBERT4 shapes, embedding kept fp32):");
    let params_per_layer = 4 * 312 * 312 + 2 * 312 * 1200; // attention + FFN
    let emb = 30522 * 312; // wordpiece embedding
    for (label, bits) in [
        ("all int8", vec![8u32; 4]),
        ("int4 x2 + int8 x2", vec![8, 8, 4, 4]),
        ("all int4", vec![4u32; 4]),
    ] {
        let r = quant::bits_reduction(&bits, params_per_layer, emb);
        println!("  {label:<20} {r:.2}x");
    }
    println!("  (with int8 embedding, all-int4 body: {:.2}x — the paper's 5.3x regime)", {
        // embedding at 8 bits instead of 32
        let body: f64 = 4.0 * 4.0 * params_per_layer as f64;
        let total_fp32 = (emb + 4 * params_per_layer) as f64 * 32.0;
        total_fp32 / (emb as f64 * 8.0 + body)
    });
    Ok(())
}
