//! §5.4 narrative reproduction: end-to-end inference time of a full
//! 12-layer BERT-base-depth encoder as a function of how many layers run
//! int4 ("the overall inference time depends on the number of int4 layers
//! in the model"), plus the bits-reduction accounting behind the paper's
//! 5.3x storage-compression headline.
//!
//! Runs through the [`Backend`] trait: the native kernel path always, and
//! the AOT-artifact path side by side when built with `--features xla`
//! and artifacts are present. Each configuration chains single-layer
//! forwards (the same code path the serving stack uses); the remaining
//! layers run int8.
//!
//! Usage: cargo run --release --bin e2e_speedup -- [--layers 12]
//!            [--iters 10] [--bucket 16x28,16x12] [--checkpoint FILE.mkqc]
//!
//! `--bucket` takes a comma-separated list of `BSxT` shapes; mixed `T`s
//! measure exactly what the 2-D seq-bucket batcher serves (short buckets
//! ride the same sequence-length-generic forward, through the backend's
//! reusable workspace).
//!
//! With `--checkpoint`, the three bench layers (f32/int8/int4) are built
//! from layer 0 of an MKQC checkpoint (its dims and calibrated activation
//! scales) instead of random BERT-base-dim weights, so the sweep measures
//! the model actually being deployed.

use anyhow::Result;
use mkq::bench_support as bs;
use mkq::quant;
use mkq::runtime::{Backend, NativeBackend, Precision};
use mkq::util::benchkit::Bench;
use mkq::util::cli::Args;

fn run_stack<B: Backend>(
    backend: &B,
    bench: &Bench,
    n_layers: usize,
    bsz: usize,
    t: usize,
    h0: &[f32],
    mask: &[f32],
) -> Result<()> {
    println!("\n== backend: {} ==", backend.name());
    println!("{:>10} {:>14} {:>12} {:>10}", "int4", "total (us)", "vs all-f32", "vs all-int8");

    let chain = |n_int4: usize, all_f32: bool| -> Result<f64> {
        let prec_for = |l: usize| {
            if all_f32 {
                Precision::F32
            } else if l >= n_layers - n_int4 {
                Precision::Int4
            } else {
                Precision::Int8
            }
        };
        // verify once outside timing that the chain executes
        let mut h = h0.to_vec();
        for l in 0..n_layers {
            h = backend.layer_forward(prec_for(l), bsz, t, &h, mask)?;
        }
        let r = bench.run(|| {
            let mut h = h0.to_vec();
            for l in 0..n_layers {
                h = backend.layer_forward(prec_for(l), bsz, t, &h, mask).expect("layer exec");
            }
        });
        Ok(r.mean_us)
    };

    let all_f32 = chain(0, true)?;
    let mut all_int8 = 0.0;
    let mut sweep = vec![0usize, n_layers / 4, n_layers / 2, 3 * n_layers / 4, n_layers];
    sweep.dedup(); // already ascending; duplicates appear when layers % 4 != 0
    for n_int4 in sweep {
        let us = chain(n_int4, false)?;
        if n_int4 == 0 {
            all_int8 = us;
        }
        println!(
            "{:>10} {:>14.1} {:>11.2}x {:>9.2}x",
            n_int4,
            us,
            all_f32 / us,
            all_int8 / us
        );
    }
    println!("{:>10} {:>14.1} {:>11.2}x {:>10}", "(f32)", all_f32, 1.0, "-");
    Ok(())
}

fn main() -> Result<()> {
    let args = Args::parse();
    let n_layers = args.usize("layers", 12);
    let iters = args.usize("iters", 10);
    let bucket = args.str("bucket", "16x28");
    let buckets: Vec<(usize, usize)> = bucket
        .split(',')
        .map(|b| {
            b.trim()
                .split_once('x')
                .map(|(a, t)| (a.parse().unwrap(), t.parse().unwrap()))
                .expect("--bucket BSxT[,BSxT...]")
        })
        .collect();
    let bench = Bench::new(2, iters);

    println!("§5.4: end-to-end encoder time vs #int4 layers ({n_layers} layers, buckets {bucket})");
    let mut native = NativeBackend::new();
    #[cfg_attr(not(feature = "xla"), allow(unused))]
    let mut bench_weights: Option<bs::LayerWeights> = None;
    // hidden-state width of the installed bench layers (checkpoint dims
    // or BERT-base), for generating per-bucket inputs below
    let d_model: usize = if let Some(ck_path) = args.get("checkpoint") {
        use mkq::checkpoint::Checkpoint;
        use mkq::runtime::NativeLayer;
        let ck = Checkpoint::read(std::path::Path::new(ck_path)).map_err(anyhow::Error::new)?;
        let hd = ck.header().clone();
        let (d, dff, heads) = (hd.dims.d_model, hd.dims.d_ff, hd.dims.n_heads);
        anyhow::ensure!(
            d % 2 == 0 && dff % 2 == 0,
            "checkpoint dims d_model={d} / d_ff={dff} must be even for the int4 bench row"
        );
        println!(
            "bench layers from checkpoint {ck_path} (MKQC v{}): d={d} d_ff={dff} heads={heads} \
             (layer 0 weights; header act scales as the quantization fallback)",
            ck.version()
        );
        // layer-0 tensor set: fp32 masters where stored, dequantized
        // (code × scale) masters where a v2 checkpoint persists prepacked
        // panels instead — the f32/int8/int4 bench rows then re-quantize
        // from that grid, so the sweep stays runnable on prepacked files.
        let mut dequantized = false;
        let mut tensors: Vec<(String, Vec<usize>, Vec<f32>)> = Vec::new();
        for (name, dims) in mkq::checkpoint::param_specs(&hd.dims) {
            let Some(suffix) = name.strip_prefix("l0_") else { continue };
            let e = ck
                .entry(&name)
                .ok_or_else(|| anyhow::anyhow!("checkpoint layer-0 tensor {name} is missing"))?;
            anyhow::ensure!(
                e.dims == dims,
                "checkpoint layer-0 tensor {name} is mis-shaped ({:?} != {dims:?})",
                e.dims
            );
            dequantized |= e.dtype != mkq::checkpoint::DTYPE_F32;
            let (td, v) = ck.f32_or_dequant(&name).map_err(anyhow::Error::new)?;
            tensors.push((suffix.to_string(), td, v));
        }
        if dequantized {
            println!(
                "(layer 0 is stored prepacked — bench masters are dequantized codes, so the \
                 f32 row measures the quantization grid, not the original fp32 weights)"
            );
        }
        let mk = |bits: u32| {
            let act = if bits == 32 {
                [0.0; 4]
            } else {
                // header scales are the all-zero-row fallback only; when
                // layer 0 is fp32 its stored scales are unvalidated (may
                // be 0/NaN) and in any case calibrated for its own grid —
                // substitute the grid default wherever unusable.
                let default = mkq::runtime::native::default_act_scales(&[bits])[0];
                let mut row = hd.act_scales[0];
                for (v, dflt) in row.iter_mut().zip(default) {
                    if !(v.is_finite() && *v > 0.0) {
                        *v = dflt;
                    }
                }
                row
            };
            NativeLayer::from_tensors(&tensors, heads, bits, act)
        };
        native.set_bench_layers(mk(32), mk(8), mk(4));
        d
    } else {
        let weights = bs::make_weights(1);
        let (l32, l8, l4) = bs::native_bench_layers(&weights);
        native.set_bench_layers(l32, l8, l4);
        bench_weights = Some(weights);
        bs::D
    };
    println!("{}", native.disp.describe());
    for &(bsz, t) in &buckets {
        use mkq::util::rng::Rng;
        println!("\n---- bucket {bsz}x{t} ----");
        let mut rng = Rng::new(2);
        let h0: Vec<f32> = (0..bsz * t * d_model).map(|_| rng.normal() as f32).collect();
        let mask_v = vec![1.0f32; bsz * t];
        run_stack(&native, &bench, n_layers, bsz, t, &h0, &mask_v)?;

        #[cfg(feature = "xla")]
        {
            use mkq::runtime::{ArtifactBackend, Engine};
            match &bench_weights {
                Some(weights) => match Engine::load(&mkq::artifacts_dir()) {
                    Ok(eng) => match ArtifactBackend::new(&eng).with_bench_weights(weights) {
                        // a failure for one bucket (AOT executables exist
                        // only at the emitted shapes) skips that bucket,
                        // not the rest of the sweep
                        Ok(backend) => {
                            if let Err(e) = run_stack(&backend, &bench, n_layers, bsz, t, &h0, &mask_v) {
                                eprintln!("(artifact backend skipped for bucket {bsz}x{t}: {e})");
                            }
                        }
                        Err(e) => eprintln!("(artifact backend skipped: {e})"),
                    },
                    Err(e) => eprintln!("(artifact backend skipped: {e})"),
                },
                None => eprintln!(
                    "(artifact backend skipped under --checkpoint: artifact layer shapes are \
                     fixed at BERT-base dims)"
                ),
            }
        }
        #[cfg(not(feature = "xla"))]
        println!("(artifact backend skipped — build with --features xla + make artifacts)");
    }

    // Bits-reduction accounting (paper: "5.3x of bits reduction").
    println!("\nbits-reduction vs fp32 (TinyBERT4 shapes, embedding kept fp32):");
    let params_per_layer = 4 * 312 * 312 + 2 * 312 * 1200; // attention + FFN
    let emb = 30522 * 312; // wordpiece embedding
    for (label, bits) in [
        ("all int8", vec![8u32; 4]),
        ("int4 x2 + int8 x2", vec![8, 8, 4, 4]),
        ("all int4", vec![4u32; 4]),
    ] {
        let r = quant::bits_reduction(&bits, params_per_layer, emb);
        println!("  {label:<20} {r:.2}x");
    }
    println!("  (with int8 embedding, all-int4 body: {:.2}x — the paper's 5.3x regime)", {
        // embedding at 8 bits instead of 32
        let body: f64 = 4.0 * 4.0 * params_per_layer as f64;
        let total_fp32 = (emb + 4 * params_per_layer) as f64 * 32.0;
        total_fp32 / (emb as f64 * 8.0 + body)
    });
    Ok(())
}
