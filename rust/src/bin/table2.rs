//! Table 2 reproduction: end-to-end inference time of ONE BERT-base
//! transformer layer at f32 / int8 / int4, across the paper's batch-size ×
//! valid-token buckets, averaged over N rounds (paper: 100 on a T4 GPU;
//! here: XLA-CPU via PJRT — see DESIGN.md §Substitutions; the claim under
//! test is the ORDERING f32 ≫ int8 > int4 and the rough ratios, not the
//! absolute microseconds).
//!
//! Usage: cargo run --release --bin table2 -- [--iters 20] [--warmup 3]
//!            [--out results/table2.txt]

use anyhow::Result;
use mkq::bench_support as bs;
use mkq::runtime::Engine;
use mkq::util::benchkit::Bench;
use mkq::util::cli::Args;

fn main() -> Result<()> {
    let args = Args::parse();
    let eng = Engine::load(&mkq::artifacts_dir())?;
    let iters = args.usize("iters", 20);
    let warmup = args.usize("warmup", 3);
    let bench = Bench::new(warmup, iters);

    let weights = bs::make_weights(1);
    let mut rows = Vec::new();

    println!("Table 2: per-layer inference time (BERT-base dims, XLA-CPU)");
    println!(
        "{:>4} {:>12} {:>14} {:>12} {:>12} {:>9} {:>9}",
        "BS", "valid toks", "float32 (us)", "int8 (us)", "int4 (us)", "f32/int8", "int8/int4"
    );

    for (bsz, t) in bs::BUCKETS {
        let (h, mask) = bs::make_hidden(bsz, t, 2);
        let f32_in = bs::f32_inputs(&weights, &h, &mask);
        let int8_in = bs::int_inputs(&weights, &h, &mask, 8)?;
        let int4_in = bs::int_inputs(&weights, &h, &mask, 4)?;

        // Convert to literals once — weights live on the "device" across
        // rounds, as in real serving (§Perf).
        let to_lits = |v: &[mkq::runtime::HostTensor]| -> Result<Vec<xla::Literal>> {
            v.iter().map(|t| t.to_literal()).collect()
        };
        let f32_l = to_lits(&f32_in)?;
        let int8_l = to_lits(&int8_in)?;
        let int4_l = to_lits(&int4_in)?;

        let mut run = |name: String, lits: &[xla::Literal]| -> Result<f64> {
            eng.compile(&name)?; // exclude compile from timing
            let refs: Vec<&xla::Literal> = lits.iter().collect();
            let r = bench.run(|| {
                eng.execute_raw(&name, &refs).expect("exec");
            });
            Ok(r.mean_us)
        };

        let f = run(format!("layer_f32_b{bsz}_t{t}"), &f32_l)?;
        let i8_ = run(format!("layer_int8_b{bsz}_t{t}"), &int8_l)?;
        let i4 = run(format!("layer_int4_b{bsz}_t{t}"), &int4_l)?;
        println!(
            "{:>4} {:>12} {:>14.1} {:>12.1} {:>12.1} {:>9.2} {:>9.2}",
            bsz,
            bsz * t,
            f,
            i8_,
            i4,
            f / i8_,
            i8_ / i4
        );
        rows.push((bsz, bsz * t, f, i8_, i4));
    }

    println!("\nmemory traffic per layer (weights): f32 {:.1} MB | int8 {:.1} MB | int4 {:.1} MB",
        bs::weight_bytes(32) / 1e6, bs::weight_bytes(8) / 1e6, bs::weight_bytes(4) / 1e6);

    if let Some(path) = args.get("out") {
        if let Some(parent) = std::path::Path::new(path).parent() {
            std::fs::create_dir_all(parent)?;
        }
        let mut out = String::from("BS valid_tokens f32_us int8_us int4_us\n");
        for (b, v, f, i8_, i4) in &rows {
            out.push_str(&format!("{b} {v} {f:.1} {i8_:.1} {i4:.1}\n"));
        }
        std::fs::write(path, out)?;
        println!("written to {path}");
    }
    Ok(())
}
