//! mkq-bert — launcher CLI for the MKQ-BERT reproduction.
//!
//! Native subcommands (always available):
//!   serve-native — batching inference server over the native int4/int8
//!                  GEMM backend on a Poisson request trace; with
//!                  `--checkpoint FILE.mkqc` the model (dims, per-layer
//!                  bits, calibrated activation scales, weights) comes
//!                  from an MKQC checkpoint instead of random init; with
//!                  repeated `--model name=PATH` flags one server hosts
//!                  several named checkpoints behind the model-store
//!                  registry and the trace routes across them; with
//!                  `--listen ADDR` the server takes real traffic over a
//!                  TCP socket front door (length-prefixed binary
//!                  protocol) instead of replaying the trace
//!   loadgen      — socket load generator against a `--listen` server:
//!                  closed-loop or open-loop (Poisson) TCP traffic with
//!                  served/shed/p50/p99 reporting into BENCH_serve_net.json;
//!                  connects (and reconnects mid-run) with bounded
//!                  exponential backoff
//!   admin        — drive the model-fleet lifecycle over a serving
//!                  socket: `reload` / `evict` / `status` ADMIN frames
//!                  (reload and evict drain in-flight work first)
//!   kernels      — print kernel-dispatch info and run a quick self-check
//!   ckpt         — MKQC checkpoint tools: `export-random` writes a
//!                  random-init model file, `inspect` dumps the header +
//!                  tensor directory (format version, per-entry dtype /
//!                  panel layout, both CRCs), `verify` fully validates,
//!                  loads the model and runs a forward smoke test,
//!                  `migrate` rewrites any checkpoint as v2 with
//!                  prepacked panels (optionally sharded), `bench-load`
//!                  times cold loads (mmap vs buffered) into
//!                  BENCH_load.json
//!
//! Artifact subcommands (build with `--features xla`, run `make artifacts`):
//!   train        — teacher finetune + calibration + QAT on one synthetic task
//!   serve        — batching inference server over the AOT artifacts
//!   info         — print manifest / model dims / artifact inventory
//!
//! A config file can seed the flags: `mkq-bert serve-native --config run.conf`
//! (CLI flags win).

use anyhow::Result;
use mkq::util::cli::Args;
use mkq::util::config::Config;

fn main() {
    if let Err(e) = run() {
        eprintln!("error: {e:#}");
        std::process::exit(1);
    }
}

fn usage() -> ! {
    eprintln!(
        "usage: mkq-bert <serve-native|loadgen|admin|obs-overhead|kernels|ckpt|train|serve|info> [options]
  common:       --config FILE   --seed N   --verbose
  serve-native: --bits 8,8,4,4 | --n-int4 N   --rate RPS --requests N
                --window-us N   --buckets 1,8,16 (batch buckets)
                --seq-buckets 6,12,24  (seq-length bucket ceilings; the
                model seq is always available; default: quarters of seq)
                --trace mixed|full  (mixed = requests at true length,
                full = padded to seq; default mixed)
                --bench-trace [PATH]  (write serving-latency JSON for the
                CI regression gate; default path BENCH_serve.json)
                --checkpoint FILE.mkqc  (serve a saved model; the file's
                dims/bits/scales are authoritative)
                --model name=PATH  (repeatable: serve several registered
                checkpoints — files or sharded dirs — behind one server;
                the trace round-robins across them)
                --max-pending N  (per-(model x seq-bucket) queue bound,
                0 = unbounded; default 1024)  --deadline-us N  (default
                request deadline, 0 = none)
                --listen HOST:PORT  (serve over the TCP front door
                instead of replaying a trace; --serve-secs N caps wall
                clock, --idle-exit-secs N exits after the last activity;
                SIGTERM/SIGINT and --serve-secs expiry stop gracefully:
                accept no new work, drain in-flight, answer late
                arrivals with a typed shutting-down reject)
                --mem-budget-mb N  (multi-model only: LRU-evict models
                when fleet resident bytes exceed the budget)
                --stats-every-secs N  (--listen only: print a one-line
                [obs] interval-delta summary to stderr every N seconds —
                rates and quantiles cover the interval, not process life)
                --slo p99_us=N,error_pct=X  (--listen only: declare
                latency/error objectives; the server evaluates fast/slow
                burn rates over its snapshot ring each second and exports
                slo_* gauges + per-model slo_state Ok/Warning/Burning —
                observe-only, never sheds load)
                --workers N  (--listen only: execution worker threads;
                default min(4, cores); 1 = classic inline loop; each
                worker owns its workspace + kernel dispatcher replica,
                so batches execute while the front door keeps admitting)
  admin:        mkq-bert admin <reload|evict|status|metrics|flight-dump>
                --addr HOST:PORT [--model-index N]  — reload swaps in a
                freshly loaded version after draining in-flight work
                (old-version pins then get a typed version-gone reject),
                evict drains and frees the model, status reports
                version/health/failure counters/resident bytes/SLO state;
                metrics scrapes the server's metrics registry over a
                METRICS frame (Prometheus text; --json for the flat JSON
                rendering; --window SECS for reset-free windowed rates
                and window-local quantiles from the snapshot ring);
                flight-dump prints the server's flight recorder — the
                last 1024 lifecycle events (admit/reject/dispatch/
                batch-close/reload/evict/health/worker-panic), no drain
  loadgen:      --addr HOST:PORT  --mode closed|open (default closed)
                --conns N (4)  --requests N total (200)  --rate RPS
                aggregate for open mode (2000)  --deadline-us N (0)
                --model-index N (0)  --bench-out [PATH] (loadgen JSON for
                the CI gate; default BENCH_serve_net.json)
                --expect-served / --expect-shed  (fail unless >=1 request
                was served / shed — CI smoke assertions)
                --allow-lost  (tolerate client-side timeouts; default:
                any request without a response is an error)
                --expect-reconcile  (scrape the server's metrics after the
                run and fail unless server-side served/shed/failed counts
                match this client's tally exactly — requires loadgen to be
                the only traffic source since server start)
                --expect-window-rate PCT  (open mode: after the run,
                scrape `admin metrics --window` covering the active span
                and fail unless the server's windowed admit rate matches
                this client's offered rate within PCT percent)
                connects and reconnects with bounded exponential backoff;
                retry counts land in the bench JSON as conn_retries;
                client latency reports p50/p90/p99/p999 from a log-linear
                histogram, and the post-run server metrics scrape lands in
                the bench JSON as srv_* metadata
  obs-overhead: in-process serving replay with metrics recording on vs
                off (MKQ_METRICS=0 equivalent); asserts the on/off p50
                delta stays under --max-overhead (default 0.05) and
                writes --out BENCH_obs.json (--iters N, --requests N)
  kernels:      (no options; prints the dispatch table and runs a
                per-variant self-check)
  ckpt export-random FILE.mkqc  [--bits 8,8,4,4 | --n-int4 N] [--seed N]
                [--format 1|2]  write a random-init MKQC checkpoint
                (tiny preset dims; default format 2, fp32 masters)
  ckpt inspect PATH             print format version, header, bit vector,
                activation scales, both CRCs and the tensor directory
                (per-entry dtype + panel layout); PATH may be a sharded
                checkpoint directory
  ckpt verify PATH              full validation (magic/version/dims/CRCs),
                model load + forward smoke test; reports prepacked vs
                quantized-at-load weight sites
  ckpt migrate SRC DST          rewrite SRC (v1 or v2, file or sharded)
                as format v2 with prepacked int4/int8 panels replacing
                the fp32 masters of quantized layers; --shards N writes
                DST as a sharded directory (manifest + N payload files)
  ckpt bench-load FILE [FILE..] time cold checkpoint->model loads, mmap
                vs buffered, into --out BENCH_load.json (BenchResult
                rows gated by ci/bench_diff.py); --labels a,b names the
                rows, --iters N samples, --expect-prepacked LABEL fails
                unless that file loads with zero quantize+pack work,
                --expect-zero-copy LABEL fails unless that file's panels
                and scales are borrowed from the checkpoint image with
                zero panel bytes copied
  train|serve|info: artifact path — needs --features xla + make artifacts;
                also --artifacts DIR; train also takes --ckpt-out FILE.mkqc
                (export the best-eval QAT state as an MKQC checkpoint)
  env knobs:    MKQ_KERNEL=reference|blocked|parallel|avx2|avx2-parallel|
                  neon|neon-parallel|simd|simd-parallel  (force a kernel;
                  unsupported picks degrade to the scalar blocked kernels)
                MKQ_THREADS=N    cap the kernel thread pool
                MKQ_AUTOTUNE=0   skip the load-time kernel autotune
                MKQ_NO_MMAP=1    force buffered checkpoint reads (skip mmap)
                MKQ_METRICS=0    disable metrics recording (scrapes still
                  answer, with frozen values)
                MKQ_LOG=error|warn|info|debug  stderr log threshold
                  (default info; debug lines are off by default)
  fault injection (chaos testing; inert unless set):
                MKQ_FAULT_FAIL_FORWARD=N|every:N|first:N  fail the Nth
                  (or every Nth, or the first N) backend forwards with a
                  typed error
                MKQ_FAULT_PANIC_FORWARD=N  panic on the Nth forward (once)
                MKQ_FAULT_DELAY_US=N  add latency to every forward"
    );
    std::process::exit(2);
}

fn run() -> Result<()> {
    let args = Args::parse();
    let cmd = args.positional.first().cloned().unwrap_or_default();
    let mut conf = Config::default();
    if let Some(path) = args.get("config") {
        conf = Config::load(path).map_err(anyhow::Error::msg)?;
    }
    match cmd.as_str() {
        "" => usage(),
        "kernels" => kernels_info(),
        "serve-native" => serve_native(&args, &conf),
        "loadgen" => loadgen(&args, &conf),
        "admin" => admin_cmd(&args),
        "obs-overhead" => obs_overhead(&args),
        "ckpt" => ckpt_cmd(&args, &conf),
        other => artifact::run(other, &args, &conf),
    }
}

/// SIGTERM/SIGINT → graceful-stop flag for `serve-native --listen`,
/// installed via `signal(2)` through the C ABI (no libc crate in the
/// dependency tree). The handler does one async-signal-safe atomic
/// store; the front door polls the flag and runs its drain protocol.
#[cfg(unix)]
mod sig {
    use std::sync::atomic::{AtomicBool, Ordering};

    pub static STOP: AtomicBool = AtomicBool::new(false);

    const SIGINT: i32 = 2;
    const SIGTERM: i32 = 15;

    extern "C" {
        fn signal(signum: i32, handler: extern "C" fn(i32)) -> isize;
    }

    extern "C" fn on_signal(_sig: i32) {
        STOP.store(true, Ordering::SeqCst);
    }

    pub fn install() {
        unsafe {
            signal(SIGTERM, on_signal);
            signal(SIGINT, on_signal);
        }
    }
}

/// Connection retries performed across the process (loadgen workers and
/// the admin client share it) — surfaced as ungated bench metadata so
/// chaos runs can see how often clients had to back off.
static CONN_RETRIES: std::sync::atomic::AtomicU64 = std::sync::atomic::AtomicU64::new(0);

/// TCP connect with bounded exponential backoff: 6 attempts, delays
/// 50ms · 2^i capped at 1s (~1.85s worst case). Every retry bumps
/// [`CONN_RETRIES`].
fn connect_with_backoff(addr: &str) -> std::io::Result<std::net::TcpStream> {
    let mut delay = std::time::Duration::from_millis(50);
    let mut last_err: Option<std::io::Error> = None;
    for attempt in 0..6 {
        if attempt > 0 {
            CONN_RETRIES.fetch_add(1, std::sync::atomic::Ordering::Relaxed);
            std::thread::sleep(delay);
            delay = (delay * 2).min(std::time::Duration::from_secs(1));
        }
        match std::net::TcpStream::connect(addr) {
            Ok(s) => return Ok(s),
            Err(e) => last_err = Some(e),
        }
    }
    Err(last_err.unwrap_or_else(|| {
        std::io::Error::new(std::io::ErrorKind::Other, "connect failed with no attempts")
    }))
}

/// Scrape a serving socket's flat-JSON metrics over a METRICS frame.
/// `None` when the server is gone or unreachable — callers decide
/// whether that is fatal (`--expect-reconcile`) or informational.
fn scrape_server_metrics(addr: &str) -> Option<String> {
    use mkq::coordinator::net::{self, ClientReply, METRICS_FMT_JSON};
    let mut s = connect_with_backoff(addr).ok()?;
    let _ = s.set_nodelay(true);
    let _ = s.set_read_timeout(Some(std::time::Duration::from_secs(5)));
    net::send_frame(&mut s, &net::encode_metrics_request(METRICS_FMT_JSON)).ok()?;
    match net::read_reply(&mut s) {
        Ok(ClientReply::Metrics { payload, .. }) => Some(payload),
        _ => None,
    }
}

/// Windowed flavor of [`scrape_server_metrics`]: flat JSON of the
/// server's last-`window_secs` snapshot delta (`win_*` fields).
fn scrape_server_metrics_windowed(addr: &str, window_secs: u32) -> Option<String> {
    use mkq::coordinator::net::{self, ClientReply, METRICS_FMT_JSON};
    let mut s = connect_with_backoff(addr).ok()?;
    let _ = s.set_nodelay(true);
    let _ = s.set_read_timeout(Some(std::time::Duration::from_secs(5)));
    net::send_frame(&mut s, &net::encode_metrics_request_windowed(METRICS_FMT_JSON, window_secs))
        .ok()?;
    match net::read_reply(&mut s) {
        Ok(ClientReply::Metrics { payload, .. }) => Some(payload),
        _ => None,
    }
}

/// `mkq-bert admin`: drive the model-fleet lifecycle over a serving
/// socket's ADMIN frames (reload / evict / status).
fn admin_cmd(args: &Args) -> Result<()> {
    use mkq::coordinator::net::{self, AdminOp, AdminReply, ClientReply};
    use mkq::runtime::ModelHealth;

    let op_s = args.positional.get(1).cloned().unwrap_or_default();
    if op_s == "metrics" {
        return admin_metrics(args);
    }
    let op = match op_s.as_str() {
        "reload" => AdminOp::Reload,
        "evict" => AdminOp::Evict,
        "status" => AdminOp::Status,
        "flight-dump" => AdminOp::FlightDump,
        other => anyhow::bail!(
            "usage: mkq-bert admin <reload|evict|status|metrics|flight-dump> --addr HOST:PORT \
             [--model-index N] (got {other:?})"
        ),
    };
    let addr = match args.get("addr") {
        Some(a) => a.to_string(),
        None => anyhow::bail!("admin needs --addr HOST:PORT"),
    };
    let model_index = args.usize("model-index", 0);
    anyhow::ensure!(model_index <= u16::MAX as usize, "--model-index out of range");

    let mut s = connect_with_backoff(&addr).map_err(|e| anyhow::anyhow!("connect {addr}: {e}"))?;
    let _ = s.set_nodelay(true);
    // reload drains all in-flight batches before answering — give it room
    let _ = s.set_read_timeout(Some(std::time::Duration::from_secs(30)));
    net::send_frame(&mut s, &net::encode_admin(op, model_index as u16))?;
    match net::read_reply(&mut s)? {
        ClientReply::Admin { model, reply } => match reply {
            AdminReply::Reloaded { old_version, new_version } => {
                println!(
                    "model {model}: reloaded v{old_version} -> v{new_version} \
                     (in-flight work drained before the swap)"
                );
                Ok(())
            }
            AdminReply::Evicted { version, freed_bytes } => {
                println!("model {model}: evicted v{version}, freed {freed_bytes} resident bytes");
                Ok(())
            }
            AdminReply::Status { version, health, consec_failures, resident_bytes, slo_state } => {
                let health_s = ModelHealth::from_u8(health).map_or("unknown", |h| h.name());
                let slo_s = mkq::obs::SloState::from_u8(slo_state).name();
                println!(
                    "model {model}: v{version} {health_s}, consec_failures={consec_failures}, \
                     resident_bytes={resident_bytes}, slo={slo_s}"
                );
                Ok(())
            }
            AdminReply::FlightDump { text } => {
                print!("{text}");
                if !text.ends_with('\n') {
                    println!();
                }
                Ok(())
            }
            AdminReply::Err { msg } => anyhow::bail!("admin {op_s} on model {model}: {msg}"),
        },
        other => anyhow::bail!("unexpected reply to ADMIN frame: {other:?}"),
    }
}

/// `mkq-bert admin metrics`: scrape the server's metrics registry over a
/// METRICS frame and print the payload (Prometheus text, or `--json`;
/// `--window SECS` asks for reset-free windowed rates and window-local
/// quantiles computed from the server's snapshot ring).
fn admin_metrics(args: &Args) -> Result<()> {
    use mkq::coordinator::net::{self, ClientReply, METRICS_FMT_JSON, METRICS_FMT_TEXT};

    let addr = match args.get("addr") {
        Some(a) => a.to_string(),
        None => anyhow::bail!("admin metrics needs --addr HOST:PORT"),
    };
    let format = if args.bool("json") { METRICS_FMT_JSON } else { METRICS_FMT_TEXT };
    let window = args.usize("window", 0);
    anyhow::ensure!(window <= u32::MAX as usize, "--window out of range");
    let mut s = connect_with_backoff(&addr).map_err(|e| anyhow::anyhow!("connect {addr}: {e}"))?;
    let _ = s.set_nodelay(true);
    let _ = s.set_read_timeout(Some(std::time::Duration::from_secs(10)));
    let req = if window > 0 {
        net::encode_metrics_request_windowed(format, window as u32)
    } else {
        net::encode_metrics_request(format)
    };
    net::send_frame(&mut s, &req)?;
    match net::read_reply(&mut s)? {
        ClientReply::Metrics { payload, .. } => {
            print!("{payload}");
            if !payload.ends_with('\n') {
                println!();
            }
            Ok(())
        }
        other => anyhow::bail!("unexpected reply to METRICS frame: {other:?}"),
    }
}

/// `mkq-bert obs-overhead`: prove the metrics hot path is cheap. Runs
/// the same in-process serving replay with recording enabled and with
/// the `MKQ_METRICS=0` equivalent (runtime gate), and fails if the
/// enabled replay is more than `--max-overhead` (default 5%) slower on
/// its median-of-`--iters` time. Emits both replays as gated rows in
/// `BENCH_obs.json` so absolute serving perf is regression-gated too.
fn obs_overhead(args: &Args) -> Result<()> {
    use mkq::coordinator::{bits_last_n_int4, Server, ServerConfig};
    use mkq::runtime::{NativeBackend, NativeDims, NativeModel};
    use mkq::util::benchkit::Bench;

    let iters = args.usize("iters", 5);
    let requests = args.usize("requests", 256);
    let max_overhead = args.f64("max-overhead", 0.05);
    let out_path = args.str("out", "BENCH_obs.json");

    let dims = NativeDims::tiny();
    let bits = bits_last_n_int4(dims.n_layers, 4);
    let model = NativeModel::random(dims, &bits, 17);
    let backend = NativeBackend::with_model(model);
    let (seq, vocab) = (dims.seq, dims.vocab);

    let mut replay = || {
        let mut server = Server::new(
            &backend,
            ServerConfig {
                batch_buckets: vec![1, 8, 16],
                seq_buckets: default_seq_buckets(seq),
                batch_window: std::time::Duration::from_micros(200),
                max_pending: 0, // unbounded: every request runs in both modes
                default_deadline: None,
            },
        )
        .expect("obs-overhead server");
        let mut rng = mkq::util::rng::Rng::new(7);
        for i in 0..requests {
            let len = 1 + rng.below(seq);
            let ids: Vec<i32> = (0..len).map(|_| rng.below(vocab) as i32).collect();
            let mask = vec![1.0f32; len];
            server.submit(ids, mask).expect("unbounded queue admits");
            let _ = server.pump().expect("obs-overhead pump");
            // same cadence the front door runs at (~1 capture/s at real
            // rates): the snapshot ring and flight recorder stay armed
            // during the overhead measurement, so the <5% budget covers
            // the full ISSUE-10 observability stack, not just counters
            if i % 64 == 63 {
                mkq::obs::snapshots().capture();
            }
        }
        let _ = server.drain().expect("obs-overhead drain");
    };

    let was_enabled = mkq::obs::metrics_enabled();
    let bench = Bench::new(1, iters);
    mkq::obs::set_metrics_enabled(true);
    let r_on = bench.run(&mut replay);
    mkq::obs::set_metrics_enabled(false);
    let r_off = bench.run(&mut replay);
    mkq::obs::set_metrics_enabled(was_enabled);

    // p50 vs p50 (the ISSUE-8 acceptance statistic): the median replay
    // shrugs off one slow scheduler-preempted iteration on shared runners
    let overhead = (r_on.p50_us - r_off.p50_us) / r_off.p50_us.max(1e-9);
    println!("obs-overhead: {requests} requests/replay, {iters} iters each mode");
    println!("  metrics on : {r_on}");
    println!("  metrics off: {r_off}");
    println!("  overhead (p50 vs p50): {:.2}%", overhead * 100.0);

    let mut out = String::from("{\n  \"kernels\": [\n");
    out.push_str(&format!("    {},\n", r_on.json_row("obs_replay_on")));
    out.push_str(&format!("    {}\n", r_off.json_row("obs_replay_off")));
    out.push_str(&format!(
        "  ],\n  \"ungated\": {{\"requests\": {requests}, \"iters\": {iters}, \
         \"overhead_frac\": {overhead:.6}, \"max_overhead\": {max_overhead}}}\n}}\n"
    ));
    std::fs::write(&out_path, out)
        .map_err(|e| anyhow::anyhow!("failed to write {out_path}: {e}"))?;
    println!("wrote {out_path}");

    anyhow::ensure!(
        overhead <= max_overhead,
        "metrics recording costs {:.2}% on the serve replay — over the {:.1}% budget",
        overhead * 100.0,
        max_overhead * 100.0
    );
    println!(
        "metrics overhead within budget ({:.2}% <= {:.1}%)",
        overhead * 100.0,
        max_overhead * 100.0
    );
    Ok(())
}

fn kernels_info() -> Result<()> {
    use mkq::kernels::{Dispatcher, KernelKind, PackedWeights};
    use mkq::quant;
    use mkq::util::rng::Rng;

    let mut disp = Dispatcher::new();
    disp.autotune();
    println!("mkq-bert {}", mkq::version());
    println!("{}", disp.describe());

    println!("kernel variants (MKQ_KERNEL values):");
    for kind in KernelKind::ALL {
        println!(
            "  {:<18} {}",
            kind.name(),
            if kind.supported() { "available" } else { "unsupported on this machine" }
        );
    }

    // self-check: every dispatchable variant vs the scalar oracle, both
    // bit widths (unsupported variants degrade to scalar and still pass).
    let mut rng = Rng::new(1);
    let (m, k, n) = (32usize, 64usize, 48usize);
    let x: Vec<f32> = (0..m * k).map(|_| rng.normal() as f32).collect();
    let sx: Vec<f32> = (0..m).map(|_| 0.05 + rng.f32() * 0.1).collect();
    for bits in [8u32, 4] {
        let codes = quant::random_codes(&mut rng, k * n, bits);
        let sw: Vec<f32> = (0..n).map(|_| 0.01 + rng.f32() * 0.02).collect();
        let want = quant::qmatmul_ref(&x, m, k, &codes, n, &sx, &sw, bits);
        let pw = PackedWeights::from_codes(&codes, k, n, sw, bits);
        for kind in KernelKind::ALL {
            let forced = Dispatcher::forced(disp.threads(), kind);
            if forced.qmatmul(&x, m, k, &pw, &sx) != want {
                anyhow::bail!(
                    "int{bits} kernel self-check FAILED ({} != qmatmul_ref)",
                    kind.name()
                );
            }
        }
        println!(
            "int{bits} kernel self-check: all {} variants bit-for-bit vs qmatmul_ref ok ({m}x{k}x{n})",
            KernelKind::ALL.len()
        );
    }
    Ok(())
}

/// MKQC checkpoint tools: export-random / inspect / verify / migrate /
/// bench-load.
fn ckpt_cmd(args: &Args, conf: &Config) -> Result<()> {
    use mkq::checkpoint::{self, Checkpoint};
    use mkq::coordinator::{bits_last_n_int4, parse_bits};
    use mkq::kernels::Dispatcher;
    use mkq::runtime::{NativeDims, NativeModel};

    let sub = args.positional.get(1).cloned().unwrap_or_default();
    if sub == "bench-load" {
        return ckpt_bench_load(args);
    }
    let path = match args.positional.get(2) {
        Some(p) => std::path::PathBuf::from(p),
        None => anyhow::bail!(
            "usage: mkq-bert ckpt <export-random|inspect|verify|migrate|bench-load> PATH [..]"
        ),
    };
    match sub.as_str() {
        "export-random" => {
            let dims = NativeDims::tiny();
            let bits = if let Some(spec) = args.get("bits") {
                parse_bits(spec, dims.n_layers)?
            } else {
                bits_last_n_int4(dims.n_layers, args.usize("n-int4", conf.usize("serve.n_int4", 4)))
            };
            let seed = args.usize("seed", 17) as u64;
            let version = args.usize("format", checkpoint::VERSION as usize) as u32;
            checkpoint::export_random_with(&path, dims, &bits, seed, version)
                .map_err(anyhow::Error::new)?;
            println!(
                "wrote {} (MKQC v{version}, L={} d={} heads={} seq={} bits={bits:?} seed={seed})",
                path.display(),
                dims.n_layers,
                dims.d_model,
                dims.n_heads,
                dims.seq
            );
            Ok(())
        }
        "inspect" => {
            let ck = Checkpoint::read(&path).map_err(anyhow::Error::new)?;
            let h = ck.header();
            let d = &h.dims;
            println!(
                "{} — MKQC v{}{}",
                path.display(),
                ck.version(),
                if ck.shard_count() > 1 {
                    format!(" ({} shards)", ck.shard_count())
                } else {
                    String::new()
                }
            );
            println!(
                "dims: vocab={} seq={} L={} d_model={} heads={} d_ff={} classes={}",
                d.vocab, d.seq, d.n_layers, d.d_model, d.n_heads, d.d_ff, d.n_classes
            );
            println!("bits: {:?}", h.bits);
            for (l, s) in h.act_scales.iter().enumerate() {
                println!(
                    "  layer {l} act scales: qkv_in={:.6} attn_out_in={:.6} ffn1_in={:.6} ffn2_in={:.6}",
                    s[0], s[1], s[2], s[3]
                );
            }
            match ck.header_crc() {
                Some(c) => println!("header/directory CRC: {c:#010x}"),
                None => println!("header/directory CRC: none (v1 checksums the payload only)"),
            }
            let crcs: Vec<String> =
                ck.payload_crcs().iter().map(|c| format!("{c:#010x}")).collect();
            println!("payload CRC: {}", crcs.join(" "));
            println!("tensors ({}), payload {} bytes:", ck.entries().len(), ck.payload_bytes());
            for e in ck.entries() {
                let dims_s =
                    e.dims.iter().map(|v| v.to_string()).collect::<Vec<_>>().join("x");
                let layout_s = if e.layout != 0 {
                    format!(" layout={}", e.layout)
                } else {
                    String::new()
                };
                let shard_s = if ck.shard_count() > 1 {
                    format!(" shard={}", e.shard)
                } else {
                    String::new()
                };
                println!(
                    "  {:<16} {:<9} {:<12} @{:<10} {} bytes{layout_s}{shard_s}",
                    e.name,
                    e.dtype_name(),
                    dims_s,
                    e.offset,
                    e.len
                );
            }
            Ok(())
        }
        "verify" => {
            let ck = Checkpoint::read(&path).map_err(anyhow::Error::new)?;
            let (model, stats) =
                NativeModel::from_checkpoint_data_with_stats(&ck).map_err(anyhow::Error::new)?;
            // forward smoke test: one small batch must produce finite logits
            let d = model.dims;
            let disp = Dispatcher::new();
            let bsz = 2usize;
            let ids: Vec<i32> = (0..bsz * d.seq).map(|i| (i % d.vocab) as i32).collect();
            let mask = vec![1.0f32; bsz * d.seq];
            let logits = model.forward(&disp, &ids, &mask, bsz, d.seq);
            anyhow::ensure!(
                logits.len() == bsz * d.n_classes && logits.iter().all(|x| x.is_finite()),
                "forward smoke test produced non-finite logits"
            );
            println!(
                "{}: ok — v{} header/directory/CRC valid, {} tensors ({} shard(s)), model loads \
                 (bits {:?}, {} prepacked / {} quantized-at-load weight sites, {}), forward \
                 smoke test finite",
                path.display(),
                ck.version(),
                ck.entries().len(),
                ck.shard_count(),
                model.bits,
                stats.prepacked_panels,
                stats.quantized_panels,
                if stats.mapped { "mmap" } else { "buffered read" }
            );
            Ok(())
        }
        "migrate" => {
            let dst = match args.positional.get(3) {
                Some(p) => std::path::PathBuf::from(p),
                None => anyhow::bail!("usage: mkq-bert ckpt migrate SRC DST [--shards N]"),
            };
            let shards = args.usize("shards", 1);
            let src = Checkpoint::read(&path).map_err(anyhow::Error::new)?;
            let summary =
                mkq::modelstore::migrate_checkpoint(&src, &dst, shards).map_err(anyhow::Error::new)?;
            println!(
                "migrated {} (v{}) -> {} (v{}): {} tensors, {} weight sites prepacked, {} \
                 shard(s), {} payload bytes",
                path.display(),
                src.version(),
                dst.display(),
                checkpoint::VERSION,
                summary.tensors,
                summary.packed,
                summary.shards,
                summary.payload_bytes
            );
            Ok(())
        }
        other => anyhow::bail!(
            "unknown ckpt subcommand {other:?} (use export-random|inspect|verify|migrate|bench-load)"
        ),
    }
}

/// `ckpt bench-load`: cold checkpoint→model load timings (mmap vs
/// forced-buffered) per input file, in the `BENCH_kernels.json` schema
/// so `ci/bench_diff.py` gates them run over run. Load provenance
/// (prepacked vs quantized-at-load site counts, RSS proxy) is emitted as
/// ungated metadata — `--expect-prepacked LABEL` turns "v2 skips
/// quantize+pack" into a hard check.
fn ckpt_bench_load(args: &Args) -> Result<()> {
    use mkq::checkpoint::Checkpoint;
    use mkq::runtime::NativeModel;
    use mkq::util::benchkit::{Bench, BenchResult};

    let files: Vec<&String> = args.positional.iter().skip(2).collect();
    if files.is_empty() {
        anyhow::bail!("usage: mkq-bert ckpt bench-load FILE [FILE..] [--labels a,b] [--out PATH]");
    }
    let labels: Vec<String> = match args.list("labels") {
        Some(l) => {
            anyhow::ensure!(l.len() == files.len(), "--labels needs one label per file");
            l
        }
        None => files
            .iter()
            .map(|f| {
                std::path::Path::new(f)
                    .file_stem()
                    .map(|s| s.to_string_lossy().into_owned())
                    .unwrap_or_else(|| (*f).clone())
            })
            .collect(),
    };
    // labels become JSON bucket names: enforce a safe charset (no quote
    // breakage in the hand-built JSON) and uniqueness (bench_diff keys
    // rows by name — a duplicate would silently shadow the other file)
    for l in &labels {
        anyhow::ensure!(
            !l.is_empty()
                && l.chars().all(|c| c.is_ascii_alphanumeric() || c == '_' || c == '-' || c == '.'),
            "label {l:?} must be non-empty [A-Za-z0-9_.-] (set explicit --labels)"
        );
    }
    {
        let mut sorted = labels.clone();
        sorted.sort();
        sorted.dedup();
        anyhow::ensure!(
            sorted.len() == labels.len(),
            "duplicate bench labels {labels:?} — rows would shadow each other; set --labels"
        );
    }
    let iters = args.usize("iters", 5);
    let out_path = args.str("out", "BENCH_load.json");
    let bench = Bench::new(1, iters);

    let mut rows: Vec<String> = Vec::new();
    let mut meta: Vec<String> = Vec::new();
    for (file, label) in files.iter().zip(&labels) {
        let path = std::path::PathBuf::from(file);
        // one stats-bearing load of each flavor outside the timing loop
        let (_, stats_m) = NativeModel::from_checkpoint_with_stats(&path)
            .map_err(anyhow::Error::new)?;
        let (_, stats_b) = {
            let ck = Checkpoint::read_buffered(&path).map_err(anyhow::Error::new)?;
            NativeModel::from_checkpoint_data_with_stats(&ck).map_err(anyhow::Error::new)?
        };
        let r_buf = bench.run(|| {
            let ck = Checkpoint::read_buffered(&path).expect("bench buffered read");
            let m = NativeModel::from_checkpoint_data(&ck).expect("bench buffered load");
            std::hint::black_box(&m);
        });
        // emit the mmap row only when the default open actually mapped
        // (MKQ_NO_MMAP=1, non-unix, or an mmap failure would otherwise
        // put buffered timings under the load_*_mmap bucket name and
        // corrupt the cross-run regression gate; an absent row is a
        // bench_diff warning, not a gate)
        if stats_m.mapped {
            let r_mmap = bench.run(|| {
                let m = NativeModel::from_checkpoint(&path).expect("bench mmap load");
                std::hint::black_box(&m);
            });
            println!("{label}: mmap {r_mmap}");
            rows.push(r_mmap.json_row(&format!("load_{label}_mmap")));
        } else {
            println!("{label}: mmap unavailable (buffered fallback) — mmap row not emitted");
        }
        println!(
            "{label}: buffered {r_buf}\n{label}: {} prepacked / {} \
             quantized-at-load sites, mapped={}, rss proxy {} bytes (mmap) / {} (buffered)",
            stats_m.prepacked_panels,
            stats_m.quantized_panels,
            stats_m.mapped,
            stats_m.rss_proxy_bytes(),
            stats_b.rss_proxy_bytes()
        );
        rows.push(r_buf.json_row(&format!("load_{label}_buffered")));
        // resident-bytes as a gated row: deterministic byte counts, so
        // the >20% rule only fires if a change actually grows what one
        // loaded model pins in memory
        rows.push(
            BenchResult::single(stats_m.resident_bytes() as f64, 1)
                .json_row(&format!("load_{label}_resident_bytes")),
        );
        println!(
            "{label}: resident {} bytes ({} panel bytes copied at load, {} borrowed zero-copy)",
            stats_m.resident_bytes(),
            stats_m.panel_copy_bytes,
            stats_m.borrowed_panel_bytes
        );
        meta.push(format!(
            "\"{label}\": {{\"prepacked_panels\": {}, \"quantized_panels\": {}, \"mapped\": {}, \
             \"rss_proxy_bytes_mmap\": {}, \"rss_proxy_bytes_buffered\": {}, \
             \"model_heap_bytes\": {}, \"panel_copy_bytes\": {}, \"borrowed_panel_bytes\": {}, \
             \"resident_bytes\": {}}}",
            stats_m.prepacked_panels,
            stats_m.quantized_panels,
            stats_m.mapped,
            stats_m.rss_proxy_bytes(),
            stats_b.rss_proxy_bytes(),
            stats_m.model_heap_bytes,
            stats_m.panel_copy_bytes,
            stats_m.borrowed_panel_bytes,
            stats_m.resident_bytes()
        ));
        if args.get("expect-prepacked") == Some(label.as_str()) {
            anyhow::ensure!(
                stats_m.quantized_panels == 0 && stats_m.prepacked_panels > 0,
                "{label}: expected a fully prepacked load, got {} prepacked / {} quantized",
                stats_m.prepacked_panels,
                stats_m.quantized_panels
            );
            println!("{label}: prepacked load confirmed — quantize+pack skipped entirely");
        }
        if args.get("expect-zero-copy") == Some(label.as_str()) {
            anyhow::ensure!(
                stats_m.panel_copy_bytes == 0
                    && stats_m.prepacked_panels > 0
                    && stats_m.borrowed_panel_bytes > 0,
                "{label}: expected a zero-copy load, got {} panel bytes copied \
                 ({} prepacked sites, {} borrowed bytes)",
                stats_m.panel_copy_bytes,
                stats_m.prepacked_panels,
                stats_m.borrowed_panel_bytes
            );
            println!(
                "{label}: zero-copy load confirmed — panels and scales served straight from \
                 the checkpoint image ({} borrowed bytes, mapped={})",
                stats_m.borrowed_panel_bytes, stats_m.mapped
            );
        }
    }
    if let Some(want) = args.get("expect-prepacked") {
        anyhow::ensure!(
            labels.iter().any(|l| l == want),
            "--expect-prepacked {want:?} names no benched label {labels:?}"
        );
    }
    if let Some(want) = args.get("expect-zero-copy") {
        anyhow::ensure!(
            labels.iter().any(|l| l == want),
            "--expect-zero-copy {want:?} names no benched label {labels:?}"
        );
    }
    let mut out = String::from("{\n  \"kernels\": [\n");
    for (i, row) in rows.iter().enumerate() {
        out.push_str(&format!("    {row}{}\n", if i + 1 == rows.len() { "" } else { "," }));
    }
    out.push_str("  ],\n  \"ungated\": {");
    out.push_str(&meta.join(", "));
    out.push_str("}\n}\n");
    std::fs::write(&out_path, out)
        .map_err(|e| anyhow::anyhow!("failed to write {out_path}: {e}"))?;
    println!("wrote {out_path}");
    Ok(())
}

/// Default seq-length bucket ceilings: quarters of the model seq (the
/// model seq itself is always appended by the server).
fn default_seq_buckets(seq: usize) -> Vec<usize> {
    let mut v: Vec<usize> = (1..=4).map(|q| q * seq / 4).filter(|&t| t > 0).collect();
    v.dedup();
    v
}

fn serve_native(args: &Args, conf: &Config) -> Result<()> {
    use mkq::coordinator::{bits_last_n_int4, parse_bits};
    use mkq::modelstore::Registry;
    use mkq::runtime::{NativeBackend, NativeDims, NativeModel};

    let model_specs = args.get_all("model");
    if !model_specs.is_empty() {
        // multi-model registry: one server over N named checkpoints
        if args.get("checkpoint").is_some() {
            anyhow::bail!("--checkpoint and --model are mutually exclusive (use --model only)");
        }
        let mut reg = Registry::new();
        for spec in model_specs {
            let (name, path) = spec
                .split_once('=')
                .ok_or_else(|| anyhow::anyhow!("--model expects name=PATH, got {spec:?}"))?;
            let idx = reg.load(name, std::path::Path::new(path))?;
            let m = reg.get(idx).expect("just loaded");
            println!(
                "registered model {name:?} from {path}: L={} d={} seq={} bits={:?} ({} \
                 prepacked / {} quantized-at-load sites, {}, {} panel bytes copied / {} \
                 borrowed zero-copy, resident {} bytes)",
                m.model.dims.n_layers,
                m.model.dims.d_model,
                m.model.dims.seq,
                m.model.bits,
                m.stats.prepacked_panels,
                m.stats.quantized_panels,
                if m.stats.mapped { "mmap" } else { "buffered read" },
                m.stats.panel_copy_bytes,
                m.stats.borrowed_panel_bytes,
                m.stats.resident_bytes()
            );
        }
        let budget_mb = args.usize("mem-budget-mb", conf.usize("serve.mem_budget_mb", 0));
        if budget_mb > 0 {
            reg.set_mem_budget(Some(budget_mb * 1024 * 1024));
            println!(
                "fleet memory budget: {budget_mb} MiB (LRU eviction above it), resident now {} \
                 bytes across {} model(s)",
                reg.resident_bytes(),
                reg.len()
            );
        }
        reg.autotune();
        println!("{}", reg.disp.describe());
        return run_serve_trace(&reg, args, conf);
    }

    let model = if let Some(ck_path) = args.get("checkpoint") {
        if args.get("bits").is_some() || args.get("n-int4").is_some() {
            eprintln!("note: --bits/--n-int4 ignored — the checkpoint's bit vector is authoritative");
        }
        let (m, stats) = NativeModel::from_checkpoint_with_stats(std::path::Path::new(ck_path))
            .map_err(anyhow::Error::new)?;
        println!(
            "loaded checkpoint {ck_path} ({} prepacked / {} quantized-at-load sites, {})",
            stats.prepacked_panels,
            stats.quantized_panels,
            if stats.mapped { "mmap" } else { "buffered read" }
        );
        m
    } else {
        let dims = NativeDims::tiny();
        let bits = if let Some(spec) = args.get("bits") {
            parse_bits(spec, dims.n_layers)?
        } else {
            bits_last_n_int4(dims.n_layers, args.usize("n-int4", conf.usize("serve.n_int4", 4)))
        };
        let seed = args.usize("seed", 17) as u64;
        NativeModel::random(dims, &bits, seed)
    };
    let dims = model.dims;
    println!(
        "native serving demo: L={} d={} heads={} seq={} bits={:?}",
        dims.n_layers, dims.d_model, dims.n_heads, dims.seq, model.bits
    );
    let backend = NativeBackend::with_model(model);
    println!("{}", backend.disp.describe());
    run_serve_trace(&backend, args, conf)
}

/// The Poisson trace replay, generic over single- and multi-model
/// backends: per-model tokenized traffic (each model's own vocab/seq),
/// requests round-robined across registered models, one shared server.
fn run_serve_trace<B: mkq::runtime::Backend>(backend: &B, args: &Args, conf: &Config) -> Result<()> {
    use mkq::coordinator::{Server, ServerConfig, TraceGen, TraceKind};
    use mkq::data::{Suite, TaskKind};

    let n_models = backend.n_models();
    let dims_per: Vec<mkq::runtime::ServeDims> =
        (0..n_models).map(|m| backend.serve_dims_for(m)).collect::<Result<_>>()?;
    // per-model scrape series (slo_state, the batch grid) need a label
    // per served model; registry-backed fleets registered real names at
    // load — this only fills slots that have none (the demo path)
    for m in 0..n_models {
        mkq::obs::ensure_model_label(m, &format!("m{m}"));
    }
    let max_seq = dims_per.iter().map(|d| d.seq).max().expect("at least one model");

    let parse_usize_list = |key: &str| -> Result<Option<Vec<usize>>> {
        match args.list(key) {
            Some(v) => v
                .iter()
                .map(|s| s.parse::<usize>())
                .collect::<Result<Vec<usize>, _>>()
                .map(Some)
                .map_err(|_| anyhow::anyhow!("--{key} expects a comma-separated list of integers")),
            None => Ok(None),
        }
    };
    let batch_buckets = parse_usize_list("buckets")?.unwrap_or_else(|| vec![1, 8, 16]);
    let seq_buckets =
        parse_usize_list("seq-buckets")?.unwrap_or_else(|| default_seq_buckets(max_seq));
    let trace_kind = {
        let s = args.str("trace", &conf.str("serve.trace", "mixed"));
        TraceKind::parse(&s).ok_or_else(|| anyhow::anyhow!("--trace expects mixed|full, got {s:?}"))?
    };
    let window_us = args.usize("window-us", conf.usize("serve.window_us", 500));
    let max_pending = args.usize("max-pending", conf.usize("serve.max_pending", 1024));
    let deadline_us = args.usize("deadline-us", conf.usize("serve.deadline_us", 0));
    let default_deadline = if deadline_us == 0 {
        None
    } else {
        Some(std::time::Duration::from_micros(deadline_us as u64))
    };
    println!(
        "batch buckets {batch_buckets:?}, seq buckets {seq_buckets:?} (+ each model's seq), \
         trace {}",
        trace_kind.name()
    );
    let mut server = Server::new(
        backend,
        ServerConfig {
            batch_buckets,
            seq_buckets,
            batch_window: std::time::Duration::from_micros(window_us as u64),
            max_pending,
            default_deadline,
        },
    )?;

    // socket front door: take real traffic over TCP instead of replaying
    // a synthetic trace (drive it with `mkq-bert loadgen`)
    if let Some(listen) = args.get("listen") {
        use mkq::coordinator::net::{FrontDoor, RunOpts, PROTO_VERSION};
        let mut door = FrontDoor::bind(listen)
            .map_err(|e| anyhow::anyhow!("failed to bind {listen}: {e}"))?;
        let local = door.local_addr().map_err(anyhow::Error::new)?;
        let serve_secs = args.f64("serve-secs", conf.f64("serve.serve_secs", 0.0));
        let idle_exit = args.f64("idle-exit-secs", conf.f64("serve.idle_exit_secs", 0.0));
        let stats_every = args.f64("stats-every-secs", conf.f64("serve.stats_every_secs", 0.0));
        let default_workers = std::thread::available_parallelism().map_or(1, |p| p.get()).min(4);
        let workers =
            args.usize("workers", conf.usize("serve.workers", default_workers)).max(1);
        let slo_spec = args.str("slo", &conf.str("serve.slo", ""));
        let slo = if slo_spec.is_empty() {
            mkq::obs::SloConfig::default()
        } else {
            mkq::obs::SloConfig::parse(&slo_spec).map_err(anyhow::Error::msg)?
        };
        println!(
            "listening on {local} (proto v{PROTO_VERSION}, max_pending {max_pending}, \
             default deadline {deadline_us}us, workers {workers})"
        );
        if slo.armed() {
            println!(
                "SLO armed (observe-only): {} — fast/slow burn over 10s/60s snapshot windows, \
                 states exported as slo_state per model",
                slo.describe()
            );
        }
        let opts = RunOpts {
            for_secs: if serve_secs > 0.0 { Some(serve_secs) } else { None },
            idle_exit_secs: if idle_exit > 0.0 { Some(idle_exit) } else { None },
            stats_every_secs: if stats_every > 0.0 { Some(stats_every) } else { None },
            workers,
            slo,
        };
        // SIGTERM/SIGINT trip the same graceful-stop path as --serve-secs
        // expiry: stop accepting, drain in-flight work, answer late
        // arrivals with a typed shutting-down reject — never a silent drop
        #[cfg(unix)]
        let stop: Option<&std::sync::atomic::AtomicBool> = {
            sig::install();
            println!("graceful stop armed: SIGTERM/SIGINT drain in-flight work before exit");
            Some(&sig::STOP)
        };
        #[cfg(not(unix))]
        let stop: Option<&std::sync::atomic::AtomicBool> = None;
        door.run(&mut server, opts, stop)?;
        println!("{}", door.stats());
        println!("{}", server.summary());
        return Ok(());
    }

    // per-model traffic: the synthetic task is tokenized against that
    // model's vocab/seq, so requests are always admissible where routed
    let tasks: Vec<mkq::data::TaskData> = dims_per
        .iter()
        .enumerate()
        .map(|(m, d)| Suite::new(42, d.vocab, d.seq).task(TaskKind::Sst2, 1 + m as u64))
        .collect();
    let mut gens: Vec<TraceGen> =
        tasks.iter().map(|t| TraceGen::new(&t.dev, trace_kind, 99)).collect();

    let rate = args.f64("rate", conf.f64("serve.rate", 500.0));
    let n_req = args.usize("requests", conf.usize("serve.requests", 400));
    println!("replaying Poisson trace: {n_req} requests at {rate} rps, window {window_us}us");
    let mut arrivals = mkq::util::rng::Rng::new(99);
    let mut sent = 0usize;
    let mut rejected = 0usize;
    let replay_start = std::time::Instant::now();
    let mut next_arrival = replay_start;
    while sent < n_req || server.pending() > 0 {
        let now = std::time::Instant::now();
        if sent < n_req && now >= next_arrival {
            let m = sent % n_models;
            let (ids, mask) = gens[m].next_request();
            // admission rejects (queue full under a saturating trace) are
            // load shedding, not replay failures — count and keep going
            if server.submit_to(m, ids, mask).is_err() {
                rejected += 1;
            }
            sent += 1;
            next_arrival = now + std::time::Duration::from_secs_f64(arrivals.exp(rate));
        }
        server.pump()?;
        if sent >= n_req {
            server.drain()?;
        }
    }
    let replay_s = replay_start.elapsed().as_secs_f64();
    let summary = server.summary();
    println!("{summary}");
    if rejected > 0 {
        println!("trace replay: {rejected} of {sent} submissions rejected at admission");
    }

    if let Some(out) = args.get("bench-trace") {
        let path = if out == "true" { "BENCH_serve.json" } else { out };
        write_bench_serve(path, &summary, replay_s)?;
        println!("wrote {path}");
    }
    Ok(())
}

/// Serving benchmark dump, schema-compatible with `BENCH_kernels.json`
/// so `ci/bench_diff.py` applies the same >20% regression rule.
///
/// Only *compute-bound* statistics are gated (placed in the `kernels`
/// array the differ reads): `serve_batch_exec_p50` (median per-*batch*
/// backend execution — one sample per pump, so batch-size mix doesn't
/// weight it) and `serve_exec_us_per_ktok` (total backend execution
/// time per 1000 valid tokens). Queue/total latencies and tail
/// percentiles are single-replay, arrival-schedule- and scheduler-
/// jitter-dependent — flaky at a 20% threshold on shared runners — so
/// they are emitted as ungated metadata instead.
fn write_bench_serve(path: &str, s: &mkq::coordinator::ServerSummary, replay_s: f64) -> Result<()> {
    use mkq::util::benchkit::BenchResult;
    let gated = [
        ("serve_batch_exec_p50", BenchResult::single(s.batch_exec.p50_us, s.batches as usize)),
        ("serve_exec_us_per_ktok", BenchResult::single(s.exec_us_per_ktok(), s.batches as usize)),
    ];
    let mut out = String::from("{\n  \"kernels\": [\n");
    for (i, (name, r)) in gated.iter().enumerate() {
        out.push_str(&format!(
            "    {}{}\n",
            r.json_row(name),
            if i + 1 == gated.len() { "" } else { "," }
        ));
    }
    out.push_str(&format!(
        "  ],\n  \"ungated\": {{\"exec_p99_us\": {:.3}, \"queue_p50_us\": {:.3}, \
         \"total_p50_us\": {:.3}, \"total_p99_us\": {:.3}, \"replay_s\": {:.3}}},\n",
        s.exec.p99_us, s.queue.p50_us, s.total.p50_us, s.total.p99_us, replay_s
    ));
    out.push_str(&format!(
        "  \"served\": {},\n  \"batches\": {},\n  \"padded_tokens\": {},\n  \
         \"total_tokens\": {},\n  \"padded_token_fraction\": {:.4}\n}}\n",
        s.served,
        s.batches,
        s.padded_tokens,
        s.total_tokens,
        s.padded_token_fraction()
    ));
    std::fs::write(path, out).map_err(|e| anyhow::anyhow!("failed to write {path}: {e}"))
}

/// Socket load generator against a `serve-native --listen` server.
///
/// Closed loop: each connection sends one request and waits for its
/// reply before the next — concurrency is bounded by `--conns`, so it
/// measures latency under polite load. Open loop: each connection emits
/// Poisson arrivals regardless of completions — the overload-honest
/// mode, where admission control and deadline shedding actually fire.
fn loadgen(args: &Args, conf: &Config) -> Result<()> {
    use mkq::coordinator::net::{self, ClientReply};
    use mkq::util::benchkit::BenchResult;

    let addr = match args.get("addr") {
        Some(a) => a.to_string(),
        None => anyhow::bail!("loadgen needs --addr HOST:PORT (see `mkq-bert` usage)"),
    };
    let mode = args.str("mode", &conf.str("loadgen.mode", "closed"));
    anyhow::ensure!(mode == "closed" || mode == "open", "--mode expects closed|open, got {mode:?}");
    let conns = args.usize("conns", conf.usize("loadgen.conns", 4)).max(1);
    let total = args.usize("requests", conf.usize("loadgen.requests", 200));
    let rate = args.f64("rate", conf.f64("loadgen.rate", 2000.0));
    let deadline_us = args.usize("deadline-us", conf.usize("loadgen.deadline_us", 0)) as u32;
    let model_index = args.usize("model-index", 0);
    anyhow::ensure!(model_index <= u16::MAX as usize, "--model-index out of range");

    // INFO probe: self-size requests to the target model's vocab/seq
    // (backoff-connected, so loadgen can be launched before the server
    // finishes binding — the chaos scripts rely on this)
    let models = {
        let mut s =
            connect_with_backoff(&addr).map_err(|e| anyhow::anyhow!("connect {addr}: {e}"))?;
        let _ = s.set_read_timeout(Some(std::time::Duration::from_secs(5)));
        net::send_frame(&mut s, &net::encode_info_request())?;
        match net::read_reply(&mut s)? {
            ClientReply::Info { models } => models,
            other => anyhow::bail!("INFO probe got unexpected reply: {other:?}"),
        }
    };
    anyhow::ensure!(
        model_index < models.len(),
        "--model-index {model_index} out of range ({} models advertised)",
        models.len()
    );
    let m = &models[model_index];
    println!(
        "target {addr}: model {model_index} ({}) vocab={} seq={} n_classes={}",
        m.label, m.vocab, m.seq, m.n_classes
    );
    let (vocab, seq) = (m.vocab as usize, m.seq as usize);

    let per_conn = (total + conns - 1) / conns;
    let rate_per_conn = (rate / conns as f64).max(1.0);
    println!(
        "loadgen: mode {mode}, {conns} conns x {per_conn} requests{}",
        if mode == "open" { format!(", {rate:.0} rps aggregate") } else { String::new() }
    );
    let start = std::time::Instant::now();
    let mut handles = Vec::new();
    for ci in 0..conns {
        let addr = addr.clone();
        let closed = mode == "closed";
        handles.push(std::thread::spawn(move || {
            if closed {
                loadgen_closed_worker(
                    &addr,
                    model_index as u16,
                    deadline_us,
                    per_conn,
                    seq,
                    vocab,
                    ci as u64,
                )
            } else {
                loadgen_open_worker(
                    &addr,
                    model_index as u16,
                    deadline_us,
                    per_conn,
                    rate_per_conn,
                    seq,
                    vocab,
                    ci as u64,
                )
            }
        }));
    }
    let mut tally = LoadTally::default();
    for h in handles {
        match h.join() {
            Ok(Ok(t)) => tally.merge(t),
            Ok(Err(e)) => eprintln!("loadgen connection error: {e}"),
            Err(_) => eprintln!("loadgen worker panicked"),
        }
    }
    let wall_s = start.elapsed().as_secs_f64().max(1e-9);

    let lat = &tally.lat_ok_us;
    let answered = tally.ok
        + tally.shed
        + tally.full
        + tally.invalid
        + tally.failed
        + tally.unavailable
        + tally.other;
    let conn_retries = CONN_RETRIES.load(std::sync::atomic::Ordering::Relaxed);
    println!(
        "sent {} in {:.2}s ({:.0} rps offered), answered {answered}, {conn_retries} connect \
         retr{}",
        tally.sent,
        wall_s,
        tally.sent as f64 / wall_s,
        if conn_retries == 1 { "y" } else { "ies" }
    );
    println!(
        "  served={} shed_deadline={} queue_full={} invalid={} backend_failed={} unavailable={} \
         other={} lost={}",
        tally.ok,
        tally.shed,
        tally.full,
        tally.invalid,
        tally.failed,
        tally.unavailable,
        tally.other,
        tally.lost
    );
    if lat.count() > 0 {
        println!(
            "  served latency: n={} mean {:.1}us p50 {:.1}us p90 {:.1}us p99 {:.1}us \
             p999 {:.1}us max {}us",
            lat.count(),
            lat.mean(),
            lat.quantile(0.5),
            lat.quantile(0.9),
            lat.quantile(0.99),
            lat.quantile(0.999),
            lat.max()
        );
    }
    if tally.slowest_us > 0 {
        println!(
            "  slowest served: {}us, server req_id {} (join key against the server's \
             slow-trace ring in `admin metrics --json`)",
            tally.slowest_us, tally.slowest_req_id
        );
    }

    // post-run server-side scrape: the same run seen from the other end
    // of the socket, so client and server accounting can reconcile
    let srv = scrape_server_metrics(&addr);
    match &srv {
        Some(p) => {
            let g = |n: &str| mkq::obs::json_u64_field(p, n).unwrap_or(0);
            println!(
                "  server view: admitted={} served={} shed_deadline={} failed={} batches={} \
                 frames_in={}",
                g("serve_admitted"),
                g("serve_served"),
                g("serve_shed_deadline"),
                g("serve_failed"),
                g("serve_batches"),
                g("net_frames_in")
            );
        }
        None => println!("  server metrics scrape unavailable (server gone or unreachable)"),
    }

    if let Some(out) = args.get("bench-out") {
        let path = if out == "true" { "BENCH_serve_net.json" } else { out };
        let mut s = String::from("{\n  \"kernels\": [\n");
        // only the served-latency median is gated (tails and shed counts
        // are schedule-dependent — ungated metadata, same split as the
        // trace-replay bench)
        if lat.count() > 0 {
            s.push_str(&format!(
                "    {}\n",
                BenchResult::single(lat.quantile(0.5), lat.count() as usize)
                    .json_row(&format!("net_{mode}_p50"))
            ));
        }
        let srv_meta = match &srv {
            Some(p) => {
                let g = |n: &str| mkq::obs::json_u64_field(p, n).unwrap_or(0);
                format!(
                    ", \"srv_admitted\": {}, \"srv_served\": {}, \"srv_shed_deadline\": {}, \
                     \"srv_failed\": {}, \"srv_batches\": {}, \"srv_frames_in\": {}, \
                     \"srv_frames_out\": {}",
                    g("serve_admitted"),
                    g("serve_served"),
                    g("serve_shed_deadline"),
                    g("serve_failed"),
                    g("serve_batches"),
                    g("net_frames_in"),
                    g("net_frames_out")
                )
            }
            None => String::new(),
        };
        s.push_str(&format!(
            "  ],\n  \"ungated\": {{\"mode\": \"{mode}\", \"conns\": {conns}, \"sent\": {}, \
             \"served\": {}, \"shed_deadline\": {}, \"queue_full\": {}, \"backend_failed\": {}, \
             \"unavailable\": {}, \"lost\": {}, \"conn_retries\": {conn_retries}, \
             \"p90_us\": {:.3}, \"p99_us\": {:.3}, \"p999_us\": {:.3}, \"mean_us\": {:.3}, \
             \"slowest_us\": {}, \"slowest_req_id\": {}, \
             \"wall_s\": {:.3}{srv_meta}}}\n}}\n",
            tally.sent,
            tally.ok,
            tally.shed,
            tally.full,
            tally.failed,
            tally.unavailable,
            tally.lost,
            lat.quantile(0.9),
            lat.quantile(0.99),
            lat.quantile(0.999),
            lat.mean(),
            tally.slowest_us,
            tally.slowest_req_id,
            wall_s
        ));
        std::fs::write(path, s).map_err(|e| anyhow::anyhow!("failed to write {path}: {e}"))?;
        println!("wrote {path}");
    }

    if args.bool("expect-reconcile") {
        let p = srv.as_deref().ok_or_else(|| {
            anyhow::anyhow!("--expect-reconcile: server metrics scrape failed (server unreachable)")
        })?;
        let g = |n: &str| -> Result<u64> {
            mkq::obs::json_u64_field(p, n).ok_or_else(|| {
                anyhow::anyhow!("--expect-reconcile: field {n:?} missing from server metrics")
            })
        };
        anyhow::ensure!(
            tally.lost == 0,
            "--expect-reconcile: {} lost request(s) make exact reconciliation impossible",
            tally.lost
        );
        let (admitted, served) = (g("serve_admitted")?, g("serve_served")?);
        let (shed, failed) = (g("serve_shed_deadline")?, g("serve_failed")?);
        anyhow::ensure!(
            served == tally.ok,
            "--expect-reconcile: server served {served} != client ok {}",
            tally.ok
        );
        anyhow::ensure!(
            shed == tally.shed,
            "--expect-reconcile: server shed_deadline {shed} != client shed {}",
            tally.shed
        );
        anyhow::ensure!(
            failed == tally.failed,
            "--expect-reconcile: server failed {failed} != client backend_failed {}",
            tally.failed
        );
        anyhow::ensure!(
            admitted == served + shed + failed,
            "--expect-reconcile: server admitted {admitted} != served {served} + shed {shed} \
             + failed {failed}"
        );
        println!(
            "reconcile ok: server and client agree — admitted {admitted} == served {served} \
             + shed {shed} + failed {failed}"
        );
    }

    if let Some(pct_s) = args.get("expect-window-rate") {
        let pct: f64 = pct_s
            .parse()
            .map_err(|_| anyhow::anyhow!("--expect-window-rate expects a percent, got {pct_s:?}"))?;
        anyhow::ensure!(pct > 0.0, "--expect-window-rate must be positive");
        // ask for a window generously covering the active span: the
        // server captures ~1/s, so pad for tick alignment. Pre-run idle
        // inside the window contributes zero admits, so the *count* over
        // the window is exact; rates are computed against the client's
        // own wall clock so both sides use the same denominator.
        let window = (wall_s.ceil() as u32).saturating_add(3);
        let p = scrape_server_metrics_windowed(&addr, window).ok_or_else(|| {
            anyhow::anyhow!("--expect-window-rate: windowed metrics scrape failed")
        })?;
        let g = |n: &str| -> Result<u64> {
            mkq::obs::json_u64_field(&p, n).ok_or_else(|| {
                anyhow::anyhow!("--expect-window-rate: field {n:?} missing from windowed scrape")
            })
        };
        // every sent request either got admitted or took a typed
        // admission reject — sum the window's view of all three
        let srv_seen = g("win_serve_admitted")?
            + g("win_serve_rejected_full")?
            + g("win_serve_rejected_invalid")?;
        let offered = tally.sent as f64 / wall_s;
        let srv_rate = srv_seen as f64 / wall_s;
        let dev = (srv_rate - offered).abs() / offered.max(1e-9) * 100.0;
        println!(
            "window-rate reconcile: offered {offered:.1} rps vs server windowed {srv_rate:.1} rps \
             over {window}s window ({dev:.1}% apart, budget {pct}%)"
        );
        anyhow::ensure!(
            dev <= pct,
            "--expect-window-rate: server windowed rate {srv_rate:.1} rps deviates {dev:.1}% \
             from offered {offered:.1} rps (budget {pct}%) — windowed accounting is off"
        );
    }

    anyhow::ensure!(
        tally.sent == answered + tally.lost,
        "loadgen accounting broken: sent {} != answered {answered} + lost {}",
        tally.sent,
        tally.lost
    );
    if args.bool("expect-served") {
        anyhow::ensure!(tally.ok > 0, "--expect-served: no request was served");
    }
    if args.bool("expect-shed") {
        anyhow::ensure!(
            tally.shed + tally.full > 0,
            "--expect-shed: no request was shed (deadline or queue-full)"
        );
    }
    if !args.bool("allow-lost") {
        anyhow::ensure!(
            tally.lost == 0,
            "{} request(s) got no response — every admitted request must be answered \
             (--allow-lost tolerates client-side timeouts)",
            tally.lost
        );
    }
    Ok(())
}

/// Per-connection load-generator outcome counts, merged across workers.
struct LoadTally {
    sent: u64,
    ok: u64,
    /// DeadlineExceeded rejects.
    shed: u64,
    /// QueueFull rejects.
    full: u64,
    invalid: u64,
    /// BackendFailed rejects (the request's batch failed or panicked).
    failed: u64,
    /// Lifecycle rejects: shutting-down, version-gone, quarantined,
    /// evicted — typed sheds, not lost work.
    unavailable: u64,
    other: u64,
    /// Sent but never answered before timeout/disconnect.
    lost: u64,
    /// Served-request latency in µs — the same log-linear histogram the
    /// server uses, so p50/p90/p99/p999 come from bucket walks instead
    /// of a sorted Vec (mergeable across workers, O(1) per record).
    lat_ok_us: mkq::obs::Histogram,
    /// Slowest served request this client saw, with the
    /// **server-assigned** request id echoed in its OK frame — the join
    /// key against the server's slow-trace ring (`admin metrics`).
    slowest_us: u64,
    slowest_req_id: u64,
}

impl Default for LoadTally {
    fn default() -> Self {
        LoadTally {
            sent: 0,
            ok: 0,
            shed: 0,
            full: 0,
            invalid: 0,
            failed: 0,
            unavailable: 0,
            other: 0,
            lost: 0,
            lat_ok_us: mkq::obs::Histogram::new(),
            slowest_us: 0,
            slowest_req_id: 0,
        }
    }
}

impl LoadTally {
    fn record_ok(&mut self, lat: std::time::Duration, req_id: u64) {
        self.ok += 1;
        self.lat_ok_us.record_us(lat);
        let us = lat.as_micros() as u64;
        if us > self.slowest_us {
            self.slowest_us = us;
            self.slowest_req_id = req_id;
        }
    }

    fn absorb_reject(&mut self, code: mkq::coordinator::net::RejectCode) {
        use mkq::coordinator::net::RejectCode as C;
        match code {
            C::DeadlineExceeded => self.shed += 1,
            C::QueueFull => self.full += 1,
            C::InvalidRequest => self.invalid += 1,
            C::BackendFailed => self.failed += 1,
            C::ShuttingDown | C::VersionGone | C::Quarantined | C::Evicted => {
                self.unavailable += 1
            }
            C::BadFrame | C::ServerBusy => self.other += 1,
        }
    }

    fn merge(&mut self, o: LoadTally) {
        self.sent += o.sent;
        self.ok += o.ok;
        self.shed += o.shed;
        self.full += o.full;
        self.invalid += o.invalid;
        self.failed += o.failed;
        self.unavailable += o.unavailable;
        self.other += o.other;
        self.lost += o.lost;
        self.lat_ok_us.merge_from(&o.lat_ok_us);
        if o.slowest_us > self.slowest_us {
            self.slowest_us = o.slowest_us;
            self.slowest_req_id = o.slowest_req_id;
        }
    }
}

fn loadgen_closed_worker(
    addr: &str,
    model: u16,
    deadline_us: u32,
    n: usize,
    seq: usize,
    vocab: usize,
    ci: u64,
) -> std::io::Result<LoadTally> {
    use mkq::coordinator::net::{self, ClientReply};

    let mut t = LoadTally::default();
    let mut stream = connect_with_backoff(addr)?;
    let _ = stream.set_nodelay(true);
    let _ = stream.set_read_timeout(Some(std::time::Duration::from_secs(10)));
    let mut rng = mkq::util::rng::Rng::new(1000 + ci);
    for i in 0..n {
        let len = 1 + rng.below(seq);
        let ids: Vec<i32> = (0..len).map(|_| rng.below(vocab) as i32).collect();
        let mask = vec![1.0f32; len];
        let tag = (ci << 32) | i as u64;
        let sent_at = std::time::Instant::now();
        let frame = net::encode_request(tag, model, deadline_us, &ids, &mask);
        if net::send_frame(&mut stream, &frame).is_err() {
            // the server may be restarting — reconnect with backoff and
            // resend this request; give up only when backoff is exhausted
            match connect_with_backoff(addr) {
                Ok(s) => {
                    stream = s;
                    let _ = stream.set_nodelay(true);
                    let _ = stream.set_read_timeout(Some(std::time::Duration::from_secs(10)));
                    if net::send_frame(&mut stream, &frame).is_err() {
                        break;
                    }
                }
                Err(_) => break,
            }
        }
        t.sent += 1;
        match net::read_reply(&mut stream) {
            Ok(ClientReply::Ok { req_id, .. }) => {
                t.record_ok(sent_at.elapsed(), req_id);
            }
            Ok(ClientReply::Reject { code, .. }) => t.absorb_reject(code),
            Ok(ClientReply::Info { .. }) | Ok(ClientReply::Admin { .. }) => t.other += 1,
            Err(_) => {
                // the in-flight request is lost; reconnect with backoff so
                // the remaining requests still run (a mid-run server swap
                // must not silently end the worker)
                t.lost += 1;
                match connect_with_backoff(addr) {
                    Ok(s) => {
                        stream = s;
                        let _ = stream.set_nodelay(true);
                        let _ =
                            stream.set_read_timeout(Some(std::time::Duration::from_secs(10)));
                    }
                    Err(_) => break,
                }
            }
        }
    }
    Ok(t)
}

fn loadgen_open_worker(
    addr: &str,
    model: u16,
    deadline_us: u32,
    n: usize,
    rate: f64,
    seq: usize,
    vocab: usize,
    ci: u64,
) -> std::io::Result<LoadTally> {
    use mkq::coordinator::net::{self, ClientReply};
    use std::sync::{Arc, Mutex};

    let mut t = LoadTally::default();
    let stream = connect_with_backoff(addr)?;
    let _ = stream.set_nodelay(true);
    let mut wstream = stream.try_clone()?;
    let mut rstream = stream;
    let _ = rstream.set_read_timeout(Some(std::time::Duration::from_secs(5)));

    // send times in a fixed-size ring keyed by per-connection request
    // index (tag low bits), so the reader can compute latency for
    // out-of-order completions. The ring keeps memory flat at
    // million-request trace sizes: a slot overwritten before its reply
    // lands (more than RING in flight) just goes untimed — the stored
    // index disambiguates, and the outcome counts stay exact.
    const RING: usize = 4096;
    let starts: Arc<Mutex<Vec<Option<(u64, std::time::Instant)>>>> =
        Arc::new(Mutex::new(vec![None; RING]));
    let w_starts = Arc::clone(&starts);
    let writer = std::thread::spawn(move || -> u64 {
        let mut rng = mkq::util::rng::Rng::new(2000 + ci);
        let mut sent = 0u64;
        let mut next = std::time::Instant::now();
        for i in 0..n {
            let now = std::time::Instant::now();
            if now < next {
                std::thread::sleep(next - now);
            }
            let len = 1 + rng.below(seq);
            let ids: Vec<i32> = (0..len).map(|_| rng.below(vocab) as i32).collect();
            let mask = vec![1.0f32; len];
            let tag = (ci << 32) | i as u64;
            w_starts.lock().unwrap()[i & (RING - 1)] = Some((i as u64, std::time::Instant::now()));
            let frame = net::encode_request(tag, model, deadline_us, &ids, &mask);
            if net::send_frame(&mut wstream, &frame).is_err() {
                break;
            }
            sent += 1;
            next += std::time::Duration::from_secs_f64(rng.exp(rate));
        }
        sent
    });

    let mut got = 0usize;
    while got < n {
        match net::read_reply(&mut rstream) {
            Ok(ClientReply::Ok { tag, req_id, .. }) => {
                got += 1;
                let i = (tag & 0xffff_ffff) as usize;
                match starts.lock().unwrap()[i & (RING - 1)] {
                    Some((idx, s)) if idx == i as u64 => t.record_ok(s.elapsed(), req_id),
                    _ => t.ok += 1, // slot recycled: counted, untimed
                }
            }
            Ok(ClientReply::Reject { code, .. }) => {
                got += 1;
                t.absorb_reject(code);
            }
            Ok(ClientReply::Info { .. }) | Ok(ClientReply::Admin { .. }) => {
                got += 1;
                t.other += 1;
            }
            Err(_) => break,
        }
    }
    t.sent = writer.join().unwrap_or(0);
    t.lost = t.sent.saturating_sub(got as u64);
    Ok(t)
}

#[cfg(not(feature = "xla"))]
mod artifact {
    use super::*;

    pub fn run(cmd: &str, _args: &Args, _conf: &Config) -> Result<()> {
        match cmd {
            "train" | "serve" | "info" => anyhow::bail!(
                "command `{cmd}` needs the artifact runtime — rebuild with `--features xla` \
                 (native commands: serve-native, kernels)"
            ),
            _ => usage(),
        }
    }
}

#[cfg(feature = "xla")]
mod artifact {
    use super::*;
    use anyhow::bail;
    use mkq::coordinator::{bits_last_n_int4, parse_bits, QatConfig, ServeModel, Server, ServerConfig, Trainer};
    use mkq::data::{Suite, TaskKind};
    use mkq::runtime::{ArtifactBackend, Engine, HostTensor};
    use mkq::util::rng::Rng;
    use xla::Literal;

    pub fn run(cmd: &str, args: &Args, conf: &Config) -> Result<()> {
        let artifacts = args.str("artifacts", &conf.str("artifacts", "artifacts"));
        let eng = Engine::load(std::path::Path::new(&artifacts))?;
        match cmd {
            "info" => info(&eng),
            "train" => train(&eng, args, conf),
            "serve" => serve(&eng, args, conf),
            _ => usage(),
        }
    }

    fn info(eng: &Engine) -> Result<()> {
        println!("mkq-bert {} — platform {}", mkq::version(), eng.platform());
        let d = mkq::coordinator::ModelDims::from_manifest(eng)?;
        println!(
            "model: L={} d={} heads={} d_ff={} vocab={} seq={}",
            d.n_layers, d.d_model, d.n_heads, d.d_ff, d.vocab, d.seq
        );
        println!("training: batch={} eval_batch={} k_steps={}", d.batch, d.eval_batch, d.k_steps);
        let mut names: Vec<&String> = eng.manifest.artifacts.keys().collect();
        names.sort();
        println!("artifacts ({}):", names.len());
        for n in names {
            let a = &eng.manifest.artifacts[n];
            println!("  {n:<24} {} in / {} out", a.inputs.len(), a.outputs.len());
        }
        Ok(())
    }

    pub fn qat_config_from(args: &Args, conf: &Config, n_layers: usize) -> Result<QatConfig> {
        let mut cfg = QatConfig::default();
        cfg.steps = args.usize("steps", conf.usize("train.steps", 300));
        cfg.alpha = args.f64("alpha", conf.f64("train.alpha", 10.0)) as f32;
        cfg.beta = args.f64("beta", conf.f64("train.beta", 1.0)) as f32;
        cfg.lr_w = args.f64("lr-w", conf.f64("train.lr_w", 5e-5));
        cfg.lr_scale_act = args.f64("lr-sa", conf.f64("train.lr_scale_act", 0.01));
        cfg.lr_scale_w = args.f64("lr-sw", conf.f64("train.lr_scale_w", 0.001));
        cfg.eval_every = args.usize("eval-every", conf.usize("train.eval_every", 100));
        cfg.seed = args.usize("seed", 17) as u64;
        cfg.mse_grad = match args.str("method", &conf.str("train.method", "mkq")).as_str() {
            "mkq" => true,
            "kdlsq" => false,
            m => bail!("unknown --method {m} (mkq|kdlsq)"),
        };
        if args.bool("no-lsq") {
            cfg.lsq = false;
        }
        if args.bool("no-kd") {
            cfg.alpha = 0.0;
            cfg.beta = 0.0;
        }
        cfg.bits = if let Some(spec) = args.get("bits") {
            parse_bits(spec, n_layers)?
        } else {
            bits_last_n_int4(n_layers, args.usize("n-int4", 0))
        };
        cfg.ckpt_out = args.get("ckpt-out").map(std::path::PathBuf::from);
        Ok(cfg)
    }

    fn train(eng: &Engine, args: &Args, conf: &Config) -> Result<()> {
        let mut tr = Trainer::new(eng)?;
        tr.verbose = args.bool("verbose");
        let d = tr.dims;
        let task_name = args.str("task", &conf.str("train.task", "sst2"));
        let kind =
            TaskKind::parse(&task_name).ok_or_else(|| anyhow::anyhow!("unknown task {task_name}"))?;
        let suite = Suite::new(42, d.vocab, d.seq);
        let task = suite.task(kind, 1);
        let cfg = qat_config_from(args, conf, d.n_layers)?;
        let teacher_steps = args.usize("teacher-steps", conf.usize("train.teacher_steps", 200));

        println!(
            "[1/4] finetuning fp32 teacher on {} ({} train / {} dev) ...",
            kind.name(),
            task.train.len(),
            task.dev.len()
        );
        let teacher_lr = args.f64("teacher-lr", conf.f64("train.teacher_lr", 1e-3));
        let (teacher, _) = tr.finetune_teacher(&task, teacher_steps, teacher_lr, cfg.seed)?;
        let teacher_acc = tr.eval_teacher(&teacher, &task.dev)?;
        println!("      teacher dev acc: {teacher_acc:.4}");

        println!("[2/4] calibrating scales (8 batches) ...");
        let (act, wmax) = tr.calibrate(&teacher, &task.train, 8, cfg.seed)?;
        let scales = tr.make_scales(&act, &wmax, &cfg.bits)?;

        println!(
            "[3/4] QAT {} steps, bits={:?}, method={} ...",
            cfg.steps,
            cfg.bits,
            if cfg.mse_grad { "mkq" } else { "kdlsq" }
        );
        let res = tr.qat(&teacher, scales, &task, &cfg)?;

        println!("[4/4] results:");
        println!("      teacher (fp32)   : {teacher_acc:.4}");
        println!(
            "      quantized student: best {:.4}, final {:.4}",
            res.best_dev_acc, res.final_dev_acc
        );
        for (step, acc) in &res.evals {
            println!("        step {step:>5}: dev acc {acc:.4}");
        }
        if let Some(p) = &cfg.ckpt_out {
            println!(
                "      best-eval checkpoint exported to {} — serve it natively with \
                 `mkq-bert serve-native --checkpoint {}`",
                p.display(),
                p.display()
            );
        }
        Ok(())
    }

    fn serve(eng: &Engine, args: &Args, conf: &Config) -> Result<()> {
        let mut tr = Trainer::new(eng)?;
        tr.verbose = args.bool("verbose");
        let d = tr.dims;
        let suite = Suite::new(42, d.vocab, d.seq);
        let task = suite.task(TaskKind::Sst2, 1);

        let train_steps = args.usize("train-steps", conf.usize("serve.train_steps", 60));
        let cfg = qat_config_from(args, conf, d.n_layers)?;
        println!("preparing deployed model (teacher {train_steps} steps + calibration)...");
        let (teacher, _) = tr.finetune_teacher(&task, train_steps, 1e-3, 7)?;
        let (act, wmax) = tr.calibrate(&teacher, &task.train, 4, 7)?;
        let scales = tr.make_scales(&act, &wmax, &cfg.bits)?;
        let acc = {
            let ps: Vec<&Literal> = teacher.iter().chain(scales.iter()).collect();
            let owned: Vec<Literal> = ps
                .iter()
                .map(|l| HostTensor::from_literal(l).and_then(|t| t.to_literal()))
                .collect::<Result<_>>()?;
            let bits_f: Vec<f32> = cfg.bits.iter().map(|&b| b as f32).collect();
            tr.eval_student(&owned, &bits_f, &task.dev)?
        };
        println!("deployed (post-calibration, pre-QAT) dev acc: {acc:.4}");

        let bits_f: Vec<f32> = cfg.bits.iter().map(|&b| b as f32).collect();
        let mut ps: Vec<Literal> = Vec::new();
        for p in &teacher {
            ps.push(HostTensor::from_literal(p)?.to_literal()?);
        }
        ps.extend(scales);
        let model = ServeModel::new(ps, &bits_f, "quantized")?;
        let backend = ArtifactBackend::new(eng).with_serve_model(model)?;

        let window_us = args.usize("window-us", conf.usize("serve.window_us", 500));
        // fixed-shape AOT executables: full-seq bucket only (the empty
        // seq_buckets default), requests stay padded to seq
        let mut server = Server::new(
            &backend,
            ServerConfig {
                batch_buckets: vec![1, 8, 16],
                seq_buckets: vec![],
                batch_window: std::time::Duration::from_micros(window_us as u64),
                ..Default::default()
            },
        )?;

        let rate = args.f64("rate", conf.f64("serve.rate", 200.0));
        let n_req = args.usize("requests", conf.usize("serve.requests", 400));
        println!("replaying Poisson trace: {n_req} requests at {rate} rps, window {window_us}us");
        let mut rng = Rng::new(99);
        let mut sent = 0usize;
        let mut next_arrival = std::time::Instant::now();
        while sent < n_req || server.pending() > 0 {
            let now = std::time::Instant::now();
            if sent < n_req && now >= next_arrival {
                let row = rng.below(task.dev.len());
                server.submit(task.dev.ids[row].clone(), task.dev.masks[row].clone())?;
                sent += 1;
                next_arrival = now + std::time::Duration::from_secs_f64(rng.exp(rate));
            }
            server.pump()?;
            if sent >= n_req {
                server.drain()?;
            }
        }
        println!("{}", server.summary());
        Ok(())
    }
}
