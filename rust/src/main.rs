//! mkq-bert — launcher CLI for the MKQ-BERT reproduction.
//!
//! Native subcommands (always available):
//!   serve-native — batching inference server over the native int4/int8
//!                  GEMM backend on a Poisson request trace; with
//!                  `--checkpoint FILE.mkqc` the model (dims, per-layer
//!                  bits, calibrated activation scales, weights) comes
//!                  from an MKQC checkpoint instead of random init
//!   kernels      — print kernel-dispatch info and run a quick self-check
//!   ckpt         — MKQC checkpoint tools: `export-random` writes a
//!                  random-init model file, `inspect` dumps the header +
//!                  tensor directory, `verify` fully validates (magic /
//!                  version / dims / CRC), loads the model and runs a
//!                  forward smoke test
//!
//! Artifact subcommands (build with `--features xla`, run `make artifacts`):
//!   train        — teacher finetune + calibration + QAT on one synthetic task
//!   serve        — batching inference server over the AOT artifacts
//!   info         — print manifest / model dims / artifact inventory
//!
//! A config file can seed the flags: `mkq-bert serve-native --config run.conf`
//! (CLI flags win).

use anyhow::Result;
use mkq::util::cli::Args;
use mkq::util::config::Config;

fn main() {
    if let Err(e) = run() {
        eprintln!("error: {e:#}");
        std::process::exit(1);
    }
}

fn usage() -> ! {
    eprintln!(
        "usage: mkq-bert <serve-native|kernels|ckpt|train|serve|info> [options]
  common:       --config FILE   --seed N   --verbose
  serve-native: --bits 8,8,4,4 | --n-int4 N   --rate RPS --requests N
                --window-us N   --buckets 1,8,16 (batch buckets)
                --seq-buckets 6,12,24  (seq-length bucket ceilings; the
                model seq is always available; default: quarters of seq)
                --trace mixed|full  (mixed = requests at true length,
                full = padded to seq; default mixed)
                --bench-trace [PATH]  (write serving-latency JSON for the
                CI regression gate; default path BENCH_serve.json)
                --checkpoint FILE.mkqc  (serve a saved model; the file's
                dims/bits/scales are authoritative)
  kernels:      (no options; prints the dispatch table and runs a
                per-variant self-check)
  ckpt export-random FILE.mkqc  [--bits 8,8,4,4 | --n-int4 N] [--seed N]
                write a random-init MKQC checkpoint (tiny preset dims)
  ckpt inspect FILE.mkqc        print header, bit vector, activation
                scales and the tensor directory
  ckpt verify FILE.mkqc         full validation (magic/version/dims/CRC),
                model load + forward smoke test
  train|serve|info: artifact path — needs --features xla + make artifacts;
                also --artifacts DIR; train also takes --ckpt-out FILE.mkqc
                (export the best-eval QAT state as an MKQC checkpoint)
  env knobs:    MKQ_KERNEL=reference|blocked|parallel|avx2|avx2-parallel|
                  neon|neon-parallel|simd|simd-parallel  (force a kernel;
                  unsupported picks degrade to the scalar blocked kernels)
                MKQ_THREADS=N    cap the kernel thread pool
                MKQ_AUTOTUNE=0   skip the load-time kernel autotune"
    );
    std::process::exit(2);
}

fn run() -> Result<()> {
    let args = Args::parse();
    let cmd = args.positional.first().cloned().unwrap_or_default();
    let mut conf = Config::default();
    if let Some(path) = args.get("config") {
        conf = Config::load(path).map_err(anyhow::Error::msg)?;
    }
    match cmd.as_str() {
        "" => usage(),
        "kernels" => kernels_info(),
        "serve-native" => serve_native(&args, &conf),
        "ckpt" => ckpt_cmd(&args, &conf),
        other => artifact::run(other, &args, &conf),
    }
}

fn kernels_info() -> Result<()> {
    use mkq::kernels::{Dispatcher, KernelKind, PackedWeights};
    use mkq::quant;
    use mkq::util::rng::Rng;

    let mut disp = Dispatcher::new();
    disp.autotune();
    println!("mkq-bert {}", mkq::version());
    println!("{}", disp.describe());

    println!("kernel variants (MKQ_KERNEL values):");
    for kind in KernelKind::ALL {
        println!(
            "  {:<18} {}",
            kind.name(),
            if kind.supported() { "available" } else { "unsupported on this machine" }
        );
    }

    // self-check: every dispatchable variant vs the scalar oracle, both
    // bit widths (unsupported variants degrade to scalar and still pass).
    let mut rng = Rng::new(1);
    let (m, k, n) = (32usize, 64usize, 48usize);
    let x: Vec<f32> = (0..m * k).map(|_| rng.normal() as f32).collect();
    let sx: Vec<f32> = (0..m).map(|_| 0.05 + rng.f32() * 0.1).collect();
    for bits in [8u32, 4] {
        let codes = quant::random_codes(&mut rng, k * n, bits);
        let sw: Vec<f32> = (0..n).map(|_| 0.01 + rng.f32() * 0.02).collect();
        let want = quant::qmatmul_ref(&x, m, k, &codes, n, &sx, &sw, bits);
        let pw = PackedWeights::from_codes(&codes, k, n, sw, bits);
        for kind in KernelKind::ALL {
            let forced = Dispatcher::forced(disp.threads(), kind);
            if forced.qmatmul(&x, m, k, &pw, &sx) != want {
                anyhow::bail!(
                    "int{bits} kernel self-check FAILED ({} != qmatmul_ref)",
                    kind.name()
                );
            }
        }
        println!(
            "int{bits} kernel self-check: all {} variants bit-for-bit vs qmatmul_ref ok ({m}x{k}x{n})",
            KernelKind::ALL.len()
        );
    }
    Ok(())
}

/// MKQC checkpoint tools: export-random / inspect / verify.
fn ckpt_cmd(args: &Args, conf: &Config) -> Result<()> {
    use mkq::checkpoint::{self, Checkpoint};
    use mkq::coordinator::{bits_last_n_int4, parse_bits};
    use mkq::kernels::Dispatcher;
    use mkq::runtime::{NativeDims, NativeModel};

    let sub = args.positional.get(1).cloned().unwrap_or_default();
    let path = match args.positional.get(2) {
        Some(p) => std::path::PathBuf::from(p),
        None => anyhow::bail!("usage: mkq-bert ckpt <export-random|inspect|verify> FILE.mkqc"),
    };
    match sub.as_str() {
        "export-random" => {
            let dims = NativeDims::tiny();
            let bits = if let Some(spec) = args.get("bits") {
                parse_bits(spec, dims.n_layers)?
            } else {
                bits_last_n_int4(dims.n_layers, args.usize("n-int4", conf.usize("serve.n_int4", 4)))
            };
            let seed = args.usize("seed", 17) as u64;
            checkpoint::export_random(&path, dims, &bits, seed).map_err(anyhow::Error::new)?;
            println!(
                "wrote {} (L={} d={} heads={} seq={} bits={bits:?} seed={seed})",
                path.display(),
                dims.n_layers,
                dims.d_model,
                dims.n_heads,
                dims.seq
            );
            Ok(())
        }
        "inspect" => {
            let ck = Checkpoint::read(&path).map_err(anyhow::Error::new)?;
            let h = ck.header();
            let d = &h.dims;
            println!("{} — MKQC v{}", path.display(), checkpoint::VERSION);
            println!(
                "dims: vocab={} seq={} L={} d_model={} heads={} d_ff={} classes={}",
                d.vocab, d.seq, d.n_layers, d.d_model, d.n_heads, d.d_ff, d.n_classes
            );
            println!("bits: {:?}", h.bits);
            for (l, s) in h.act_scales.iter().enumerate() {
                println!(
                    "  layer {l} act scales: qkv_in={:.6} attn_out_in={:.6} ffn1_in={:.6} ffn2_in={:.6}",
                    s[0], s[1], s[2], s[3]
                );
            }
            println!("tensors ({}), payload {} bytes:", ck.entries().len(), ck.payload_bytes());
            for e in ck.entries() {
                let dims_s =
                    e.dims.iter().map(|v| v.to_string()).collect::<Vec<_>>().join("x");
                println!("  {:<12} f32 {:<12} @{:<10} {} bytes", e.name, dims_s, e.offset, e.len);
            }
            Ok(())
        }
        "verify" => {
            let ck = Checkpoint::read(&path).map_err(anyhow::Error::new)?;
            let model = NativeModel::from_checkpoint_data(&ck).map_err(anyhow::Error::new)?;
            // forward smoke test: one small batch must produce finite logits
            let d = model.dims;
            let disp = Dispatcher::new();
            let bsz = 2usize;
            let ids: Vec<i32> = (0..bsz * d.seq).map(|i| (i % d.vocab) as i32).collect();
            let mask = vec![1.0f32; bsz * d.seq];
            let logits = model.forward(&disp, &ids, &mask, bsz, d.seq);
            anyhow::ensure!(
                logits.len() == bsz * d.n_classes && logits.iter().all(|x| x.is_finite()),
                "forward smoke test produced non-finite logits"
            );
            println!(
                "{}: ok — header/directory/CRC valid, {} tensors, model loads (bits {:?}), \
                 forward smoke test finite",
                path.display(),
                ck.entries().len(),
                model.bits
            );
            Ok(())
        }
        other => anyhow::bail!(
            "unknown ckpt subcommand {other:?} (use export-random|inspect|verify)"
        ),
    }
}

/// Default seq-length bucket ceilings: quarters of the model seq (the
/// model seq itself is always appended by the server).
fn default_seq_buckets(seq: usize) -> Vec<usize> {
    let mut v: Vec<usize> = (1..=4).map(|q| q * seq / 4).filter(|&t| t > 0).collect();
    v.dedup();
    v
}

fn serve_native(args: &Args, conf: &Config) -> Result<()> {
    use mkq::coordinator::{bits_last_n_int4, parse_bits, Server, ServerConfig, TraceGen, TraceKind};
    use mkq::data::{Suite, TaskKind};
    use mkq::runtime::{NativeBackend, NativeDims, NativeModel};

    let model = if let Some(ck_path) = args.get("checkpoint") {
        if args.get("bits").is_some() || args.get("n-int4").is_some() {
            eprintln!("note: --bits/--n-int4 ignored — the checkpoint's bit vector is authoritative");
        }
        let m = NativeModel::from_checkpoint(std::path::Path::new(ck_path))
            .map_err(anyhow::Error::new)?;
        println!("loaded checkpoint {ck_path}");
        m
    } else {
        let dims = NativeDims::tiny();
        let bits = if let Some(spec) = args.get("bits") {
            parse_bits(spec, dims.n_layers)?
        } else {
            bits_last_n_int4(dims.n_layers, args.usize("n-int4", conf.usize("serve.n_int4", 4)))
        };
        let seed = args.usize("seed", 17) as u64;
        NativeModel::random(dims, &bits, seed)
    };
    let dims = model.dims;
    println!(
        "native serving demo: L={} d={} heads={} seq={} bits={:?}",
        dims.n_layers, dims.d_model, dims.n_heads, dims.seq, model.bits
    );
    let backend = NativeBackend::with_model(model);
    println!("{}", backend.disp.describe());

    let parse_usize_list = |key: &str| -> Result<Option<Vec<usize>>> {
        match args.list(key) {
            Some(v) => v
                .iter()
                .map(|s| s.parse::<usize>())
                .collect::<Result<Vec<usize>, _>>()
                .map(Some)
                .map_err(|_| anyhow::anyhow!("--{key} expects a comma-separated list of integers")),
            None => Ok(None),
        }
    };
    let batch_buckets = parse_usize_list("buckets")?.unwrap_or_else(|| vec![1, 8, 16]);
    let seq_buckets =
        parse_usize_list("seq-buckets")?.unwrap_or_else(|| default_seq_buckets(dims.seq));
    let trace_kind = {
        let s = args.str("trace", &conf.str("serve.trace", "mixed"));
        TraceKind::parse(&s).ok_or_else(|| anyhow::anyhow!("--trace expects mixed|full, got {s:?}"))?
    };
    let window_us = args.usize("window-us", conf.usize("serve.window_us", 500));
    println!(
        "batch buckets {batch_buckets:?}, seq buckets {seq_buckets:?} (+{}), trace {}",
        dims.seq,
        trace_kind.name()
    );
    let mut server = Server::new(
        &backend,
        ServerConfig {
            batch_buckets,
            seq_buckets,
            batch_window: std::time::Duration::from_micros(window_us as u64),
        },
    )?;

    let suite = Suite::new(42, dims.vocab, dims.seq);
    let task = suite.task(TaskKind::Sst2, 1);
    let rate = args.f64("rate", conf.f64("serve.rate", 500.0));
    let n_req = args.usize("requests", conf.usize("serve.requests", 400));
    println!("replaying Poisson trace: {n_req} requests at {rate} rps, window {window_us}us");
    let mut tracegen = TraceGen::new(&task.dev, trace_kind, 99);
    let mut arrivals = mkq::util::rng::Rng::new(99);
    let mut sent = 0usize;
    let replay_start = std::time::Instant::now();
    let mut next_arrival = replay_start;
    while sent < n_req || server.pending() > 0 {
        let now = std::time::Instant::now();
        if sent < n_req && now >= next_arrival {
            let (ids, mask) = tracegen.next_request();
            server.submit(ids, mask)?;
            sent += 1;
            next_arrival = now + std::time::Duration::from_secs_f64(arrivals.exp(rate));
        }
        server.pump()?;
        if sent >= n_req {
            server.drain()?;
        }
    }
    let replay_s = replay_start.elapsed().as_secs_f64();
    let summary = server.summary();
    println!("{summary}");

    if let Some(out) = args.get("bench-trace") {
        let path = if out == "true" { "BENCH_serve.json" } else { out };
        write_bench_serve(path, &summary, replay_s)?;
        println!("wrote {path}");
    }
    Ok(())
}

/// Serving benchmark dump, schema-compatible with `BENCH_kernels.json`
/// so `ci/bench_diff.py` applies the same >20% regression rule.
///
/// Only *compute-bound* statistics are gated (placed in the `kernels`
/// array the differ reads): `serve_batch_exec_p50` (median per-*batch*
/// backend execution — one sample per pump, so batch-size mix doesn't
/// weight it) and `serve_exec_us_per_ktok` (total backend execution
/// time per 1000 valid tokens). Queue/total latencies and tail
/// percentiles are single-replay, arrival-schedule- and scheduler-
/// jitter-dependent — flaky at a 20% threshold on shared runners — so
/// they are emitted as ungated metadata instead.
fn write_bench_serve(path: &str, s: &mkq::coordinator::ServerSummary, replay_s: f64) -> Result<()> {
    use mkq::util::benchkit::BenchResult;
    let gated = [
        ("serve_batch_exec_p50", BenchResult::single(s.batch_exec.p50_us, s.batches as usize)),
        ("serve_exec_us_per_ktok", BenchResult::single(s.exec_us_per_ktok(), s.batches as usize)),
    ];
    let mut out = String::from("{\n  \"kernels\": [\n");
    for (i, (name, r)) in gated.iter().enumerate() {
        out.push_str(&format!(
            "    {}{}\n",
            r.json_row(name),
            if i + 1 == gated.len() { "" } else { "," }
        ));
    }
    out.push_str(&format!(
        "  ],\n  \"ungated\": {{\"exec_p99_us\": {:.3}, \"queue_p50_us\": {:.3}, \
         \"total_p50_us\": {:.3}, \"total_p99_us\": {:.3}, \"replay_s\": {:.3}}},\n",
        s.exec.p99_us, s.queue.p50_us, s.total.p50_us, s.total.p99_us, replay_s
    ));
    out.push_str(&format!(
        "  \"served\": {},\n  \"batches\": {},\n  \"padded_tokens\": {},\n  \
         \"total_tokens\": {},\n  \"padded_token_fraction\": {:.4}\n}}\n",
        s.served,
        s.batches,
        s.padded_tokens,
        s.total_tokens,
        s.padded_token_fraction()
    ));
    std::fs::write(path, out).map_err(|e| anyhow::anyhow!("failed to write {path}: {e}"))
}

#[cfg(not(feature = "xla"))]
mod artifact {
    use super::*;

    pub fn run(cmd: &str, _args: &Args, _conf: &Config) -> Result<()> {
        match cmd {
            "train" | "serve" | "info" => anyhow::bail!(
                "command `{cmd}` needs the artifact runtime — rebuild with `--features xla` \
                 (native commands: serve-native, kernels)"
            ),
            _ => usage(),
        }
    }
}

#[cfg(feature = "xla")]
mod artifact {
    use super::*;
    use anyhow::bail;
    use mkq::coordinator::{bits_last_n_int4, parse_bits, QatConfig, ServeModel, Server, ServerConfig, Trainer};
    use mkq::data::{Suite, TaskKind};
    use mkq::runtime::{ArtifactBackend, Engine, HostTensor};
    use mkq::util::rng::Rng;
    use xla::Literal;

    pub fn run(cmd: &str, args: &Args, conf: &Config) -> Result<()> {
        let artifacts = args.str("artifacts", &conf.str("artifacts", "artifacts"));
        let eng = Engine::load(std::path::Path::new(&artifacts))?;
        match cmd {
            "info" => info(&eng),
            "train" => train(&eng, args, conf),
            "serve" => serve(&eng, args, conf),
            _ => usage(),
        }
    }

    fn info(eng: &Engine) -> Result<()> {
        println!("mkq-bert {} — platform {}", mkq::version(), eng.platform());
        let d = mkq::coordinator::ModelDims::from_manifest(eng)?;
        println!(
            "model: L={} d={} heads={} d_ff={} vocab={} seq={}",
            d.n_layers, d.d_model, d.n_heads, d.d_ff, d.vocab, d.seq
        );
        println!("training: batch={} eval_batch={} k_steps={}", d.batch, d.eval_batch, d.k_steps);
        let mut names: Vec<&String> = eng.manifest.artifacts.keys().collect();
        names.sort();
        println!("artifacts ({}):", names.len());
        for n in names {
            let a = &eng.manifest.artifacts[n];
            println!("  {n:<24} {} in / {} out", a.inputs.len(), a.outputs.len());
        }
        Ok(())
    }

    pub fn qat_config_from(args: &Args, conf: &Config, n_layers: usize) -> Result<QatConfig> {
        let mut cfg = QatConfig::default();
        cfg.steps = args.usize("steps", conf.usize("train.steps", 300));
        cfg.alpha = args.f64("alpha", conf.f64("train.alpha", 10.0)) as f32;
        cfg.beta = args.f64("beta", conf.f64("train.beta", 1.0)) as f32;
        cfg.lr_w = args.f64("lr-w", conf.f64("train.lr_w", 5e-5));
        cfg.lr_scale_act = args.f64("lr-sa", conf.f64("train.lr_scale_act", 0.01));
        cfg.lr_scale_w = args.f64("lr-sw", conf.f64("train.lr_scale_w", 0.001));
        cfg.eval_every = args.usize("eval-every", conf.usize("train.eval_every", 100));
        cfg.seed = args.usize("seed", 17) as u64;
        cfg.mse_grad = match args.str("method", &conf.str("train.method", "mkq")).as_str() {
            "mkq" => true,
            "kdlsq" => false,
            m => bail!("unknown --method {m} (mkq|kdlsq)"),
        };
        if args.bool("no-lsq") {
            cfg.lsq = false;
        }
        if args.bool("no-kd") {
            cfg.alpha = 0.0;
            cfg.beta = 0.0;
        }
        cfg.bits = if let Some(spec) = args.get("bits") {
            parse_bits(spec, n_layers)?
        } else {
            bits_last_n_int4(n_layers, args.usize("n-int4", 0))
        };
        cfg.ckpt_out = args.get("ckpt-out").map(std::path::PathBuf::from);
        Ok(cfg)
    }

    fn train(eng: &Engine, args: &Args, conf: &Config) -> Result<()> {
        let mut tr = Trainer::new(eng)?;
        tr.verbose = args.bool("verbose");
        let d = tr.dims;
        let task_name = args.str("task", &conf.str("train.task", "sst2"));
        let kind =
            TaskKind::parse(&task_name).ok_or_else(|| anyhow::anyhow!("unknown task {task_name}"))?;
        let suite = Suite::new(42, d.vocab, d.seq);
        let task = suite.task(kind, 1);
        let cfg = qat_config_from(args, conf, d.n_layers)?;
        let teacher_steps = args.usize("teacher-steps", conf.usize("train.teacher_steps", 200));

        println!(
            "[1/4] finetuning fp32 teacher on {} ({} train / {} dev) ...",
            kind.name(),
            task.train.len(),
            task.dev.len()
        );
        let teacher_lr = args.f64("teacher-lr", conf.f64("train.teacher_lr", 1e-3));
        let (teacher, _) = tr.finetune_teacher(&task, teacher_steps, teacher_lr, cfg.seed)?;
        let teacher_acc = tr.eval_teacher(&teacher, &task.dev)?;
        println!("      teacher dev acc: {teacher_acc:.4}");

        println!("[2/4] calibrating scales (8 batches) ...");
        let (act, wmax) = tr.calibrate(&teacher, &task.train, 8, cfg.seed)?;
        let scales = tr.make_scales(&act, &wmax, &cfg.bits)?;

        println!(
            "[3/4] QAT {} steps, bits={:?}, method={} ...",
            cfg.steps,
            cfg.bits,
            if cfg.mse_grad { "mkq" } else { "kdlsq" }
        );
        let res = tr.qat(&teacher, scales, &task, &cfg)?;

        println!("[4/4] results:");
        println!("      teacher (fp32)   : {teacher_acc:.4}");
        println!(
            "      quantized student: best {:.4}, final {:.4}",
            res.best_dev_acc, res.final_dev_acc
        );
        for (step, acc) in &res.evals {
            println!("        step {step:>5}: dev acc {acc:.4}");
        }
        if let Some(p) = &cfg.ckpt_out {
            println!(
                "      best-eval checkpoint exported to {} — serve it natively with \
                 `mkq-bert serve-native --checkpoint {}`",
                p.display(),
                p.display()
            );
        }
        Ok(())
    }

    fn serve(eng: &Engine, args: &Args, conf: &Config) -> Result<()> {
        let mut tr = Trainer::new(eng)?;
        tr.verbose = args.bool("verbose");
        let d = tr.dims;
        let suite = Suite::new(42, d.vocab, d.seq);
        let task = suite.task(TaskKind::Sst2, 1);

        let train_steps = args.usize("train-steps", conf.usize("serve.train_steps", 60));
        let cfg = qat_config_from(args, conf, d.n_layers)?;
        println!("preparing deployed model (teacher {train_steps} steps + calibration)...");
        let (teacher, _) = tr.finetune_teacher(&task, train_steps, 1e-3, 7)?;
        let (act, wmax) = tr.calibrate(&teacher, &task.train, 4, 7)?;
        let scales = tr.make_scales(&act, &wmax, &cfg.bits)?;
        let acc = {
            let ps: Vec<&Literal> = teacher.iter().chain(scales.iter()).collect();
            let owned: Vec<Literal> = ps
                .iter()
                .map(|l| HostTensor::from_literal(l).and_then(|t| t.to_literal()))
                .collect::<Result<_>>()?;
            let bits_f: Vec<f32> = cfg.bits.iter().map(|&b| b as f32).collect();
            tr.eval_student(&owned, &bits_f, &task.dev)?
        };
        println!("deployed (post-calibration, pre-QAT) dev acc: {acc:.4}");

        let bits_f: Vec<f32> = cfg.bits.iter().map(|&b| b as f32).collect();
        let mut ps: Vec<Literal> = Vec::new();
        for p in &teacher {
            ps.push(HostTensor::from_literal(p)?.to_literal()?);
        }
        ps.extend(scales);
        let model = ServeModel::new(ps, &bits_f, "quantized")?;
        let backend = ArtifactBackend::new(eng).with_serve_model(model)?;

        let window_us = args.usize("window-us", conf.usize("serve.window_us", 500));
        // fixed-shape AOT executables: full-seq bucket only (the empty
        // seq_buckets default), requests stay padded to seq
        let mut server = Server::new(
            &backend,
            ServerConfig {
                batch_buckets: vec![1, 8, 16],
                seq_buckets: vec![],
                batch_window: std::time::Duration::from_micros(window_us as u64),
            },
        )?;

        let rate = args.f64("rate", conf.f64("serve.rate", 200.0));
        let n_req = args.usize("requests", conf.usize("serve.requests", 400));
        println!("replaying Poisson trace: {n_req} requests at {rate} rps, window {window_us}us");
        let mut rng = Rng::new(99);
        let mut sent = 0usize;
        let mut next_arrival = std::time::Instant::now();
        while sent < n_req || server.pending() > 0 {
            let now = std::time::Instant::now();
            if sent < n_req && now >= next_arrival {
                let row = rng.below(task.dev.len());
                server.submit(task.dev.ids[row].clone(), task.dev.masks[row].clone())?;
                sent += 1;
                next_arrival = now + std::time::Duration::from_secs_f64(rng.exp(rate));
            }
            server.pump()?;
            if sent >= n_req {
                server.drain()?;
            }
        }
        println!("{}", server.summary());
        Ok(())
    }
}
