//! Execution worker pool: N threads that run staged batches off the
//! front-door thread (the `--workers N` serving mode).
//!
//! The front door stays single-threaded for everything stateful —
//! accept/read/admit, the batching policy, reply routing, lifecycle
//! admin — and hands each ready [`WorkItem`] to this pool over a
//! bounded MPMC channel (the [`crate::util::threadpool`] idiom:
//! `sync_channel` + `Arc<Mutex<Receiver>>`). Each worker owns:
//!
//!   * its own [`Workspace`] arena, so the zero-alloc steady state
//!     holds per worker instead of being serialized through one shared
//!     scratch buffer;
//!   * its own kernel [`Dispatcher`] replica
//!     ([`Dispatcher::replicate`]) — same thread count, same forced
//!     kernel, same autotuned thresholds, so every worker makes
//!     identical kernel selections (bit-for-bit determinism with the
//!     inline path) without contending for one shared kernel pool.
//!
//! A batch carries everything it needs ([`WorkItem`] is fully owned:
//! requests, staging buffers, and the dispatch-pinned
//! `Arc<ModelVersion>` + sampled fault), so workers never touch the
//! server, the registry, or each other. Worker panics are caught per
//! batch — the batch fails typed, the worker thread survives, and
//! siblings never notice. Completions flow back over an unbounded
//! channel and ring the front door's wake handle so a `poll(2)`-parked
//! loop learns about them immediately.
//!
//! Shutdown is drop-driven: dropping the pool closes the dispatch
//! channel, each worker drains what it already holds and exits, and
//! `Drop` joins every thread.

use std::panic::{catch_unwind, AssertUnwindSafe};
use std::sync::atomic::{AtomicUsize, Ordering};
use std::sync::mpsc::{self, Receiver, Sender, SyncSender};
use std::sync::{Arc, Mutex};
use std::thread::JoinHandle;
use std::time::{Duration, Instant};

use crate::coordinator::net::WakeHandle;
use crate::coordinator::server::{panic_message, WorkDone, WorkItem};
use crate::kernels::Dispatcher;
use crate::runtime::Workspace;

/// Dispatch-channel bound per worker: deep enough to keep every worker
/// busy with one batch queued behind it, shallow enough that admission
/// backpressure (queue bounds, deadlines) stays at the front door
/// instead of hiding work in the channel.
const CHANNEL_DEPTH_PER_WORKER: usize = 2;

pub struct WorkerPool {
    /// `None` after shutdown begins; dropping it disconnects the
    /// receiver and lets workers drain out.
    tx: Option<SyncSender<WorkItem>>,
    /// Kept so a failed dispatch (all workers gone) can still settle its
    /// batch through the completion path instead of losing it.
    done_tx: Sender<WorkDone>,
    done_rx: Receiver<WorkDone>,
    handles: Vec<JoinHandle<()>>,
    /// Batches sitting in the dispatch channel (dispatched, not yet
    /// picked up) — the `worker_queue_depth` gauge.
    queue_depth: Arc<AtomicUsize>,
    n: usize,
}

impl WorkerPool {
    /// Spawn one worker per dispatcher (the caller replicates via
    /// [`crate::runtime::Backend::worker_dispatcher`]). `wake` is rung
    /// on every completion; pass [`WakeHandle::none`] when the caller
    /// polls completions itself (tests, non-unix fallback).
    pub fn new(dispatchers: Vec<Dispatcher>, wake: WakeHandle) -> Self {
        let n = dispatchers.len();
        assert!(n > 0, "worker pool needs at least one worker");
        let (tx, rx) = mpsc::sync_channel::<WorkItem>(n * CHANNEL_DEPTH_PER_WORKER);
        let rx = Arc::new(Mutex::new(rx));
        let (done_tx, done_rx) = mpsc::channel::<WorkDone>();
        let queue_depth = Arc::new(AtomicUsize::new(0));
        let mut handles = Vec::with_capacity(n);
        for (w, disp) in dispatchers.into_iter().enumerate() {
            let rx = Arc::clone(&rx);
            let done = done_tx.clone();
            let depth = Arc::clone(&queue_depth);
            handles.push(
                std::thread::Builder::new()
                    .name(format!("mkq-worker-{w}"))
                    .spawn(move || worker_loop(w, disp, rx, done, depth, wake))
                    .expect("failed to spawn execution worker"),
            );
        }
        WorkerPool { tx: Some(tx), done_tx, done_rx, handles, queue_depth, n }
    }

    pub fn len(&self) -> usize {
        self.n
    }

    pub fn is_empty(&self) -> bool {
        self.n == 0
    }

    /// Batches dispatched and not yet picked up by a worker.
    pub fn queue_depth(&self) -> usize {
        self.queue_depth.load(Ordering::SeqCst)
    }

    /// Hand one staged batch to the pool. Blocks only when the bounded
    /// channel is full — real backpressure, bounded by
    /// `workers * CHANNEL_DEPTH_PER_WORKER` batches. If every worker is
    /// gone (cannot happen while per-batch panic containment holds),
    /// the batch settles as a failed [`WorkDone`] instead of being lost.
    pub fn dispatch(&self, item: WorkItem) {
        let tx = self.tx.as_ref().expect("dispatch after shutdown");
        self.queue_depth.fetch_add(1, Ordering::SeqCst);
        if let Err(mpsc::SendError(item)) = tx.send(item) {
            self.queue_depth.fetch_sub(1, Ordering::SeqCst);
            let _ = self.done_tx.send(undispatched(item));
        }
    }

    /// Non-blocking completion poll.
    pub fn try_recv(&self) -> Option<WorkDone> {
        self.done_rx.try_recv().ok()
    }

    /// Bounded-wait completion poll (the non-`poll(2)` idle path).
    pub fn recv_timeout(&self, timeout: Duration) -> Option<WorkDone> {
        self.done_rx.recv_timeout(timeout).ok()
    }
}

impl Drop for WorkerPool {
    fn drop(&mut self) {
        self.tx = None; // disconnect: workers drain and exit
        for h in self.handles.drain(..) {
            let _ = h.join();
        }
    }
}

/// Settle a batch the pool could not hand to any worker.
fn undispatched(item: WorkItem) -> WorkDone {
    let WorkItem { model, bucket, tcap, reqs, ids, mask, handle: _, staged_at } = item;
    WorkDone {
        model,
        bucket,
        tcap,
        reqs,
        ids,
        mask,
        result: Err("worker pool is gone — batch was never executed".to_string()),
        panicked: false,
        exec_us: 0.0,
        dispatch_wait_us: staged_at.elapsed().as_secs_f64() * 1e6,
        worker: 0,
    }
}

fn worker_loop(
    w: usize,
    disp: Dispatcher,
    rx: Arc<Mutex<Receiver<WorkItem>>>,
    done_tx: Sender<WorkDone>,
    depth: Arc<AtomicUsize>,
    wake: WakeHandle,
) {
    let mut ws = Workspace::new();
    loop {
        // the guard is a statement temporary: held across recv only,
        // never across execution, so idle workers contend fairly
        let msg = rx.lock().unwrap().recv();
        let item = match msg {
            Ok(i) => i,
            Err(_) => return, // pool dropped its sender: shutdown
        };
        depth.fetch_sub(1, Ordering::SeqCst);
        if w < crate::obs::MAX_WORKER_SLOTS {
            if let Some(o) = crate::obs::metrics() {
                o.worker_busy[w].set(1);
            }
        }
        let done = execute(w, &disp, &mut ws, item);
        if w < crate::obs::MAX_WORKER_SLOTS {
            if let Some(o) = crate::obs::metrics() {
                o.worker_busy[w].set(0);
            }
        }
        if done_tx.send(done).is_err() {
            return; // front door gone mid-flight (hard teardown)
        }
        wake.wake();
    }
}

/// Run one batch: apply the dispatch-sampled fault, then the native
/// forward against the dispatch-pinned model version, with the same
/// per-batch panic containment as the inline `pump()` path.
fn execute(w: usize, disp: &Dispatcher, ws: &mut Workspace, item: WorkItem) -> WorkDone {
    let WorkItem { model, bucket, tcap, reqs, ids, mask, handle, staged_at } = item;
    let dispatch_wait_us = staged_at.elapsed().as_secs_f64() * 1e6;
    let fault = handle.fault;
    let version = &handle.version;
    let exec_start = Instant::now();
    // AssertUnwindSafe: the only state across the catch boundary is this
    // worker's own workspace arena, fully overwritten per shape by every
    // forward — same argument as the inline pump's catch_unwind.
    let caught = catch_unwind(AssertUnwindSafe(|| {
        if let Some(f) = fault {
            f.apply()?;
        }
        crate::runtime::backend::native_serve_forward(
            "worker backend",
            &version.model,
            disp,
            ws,
            bucket,
            tcap,
            &ids,
            &mask,
        )
    }));
    let exec_us = exec_start.elapsed().as_secs_f64() * 1e6;
    let (result, panicked) = match caught {
        Ok(Ok(logits)) => (Ok(logits), false),
        Ok(Err(e)) => (Err(format!("{e:#}")), false),
        Err(payload) => (Err(format!("backend panicked: {}", panic_message(payload))), true),
    };
    if panicked {
        // the settle path (complete_work) records the batch-close; this
        // pins *which worker thread* caught the unwind
        crate::obs::flight().record(
            crate::obs::FlightKind::WorkerPanic,
            0,
            model as u16,
            w.min(u16::MAX as usize) as u16,
            tcap.min(u16::MAX as usize) as u16,
            0,
        );
    }
    WorkDone {
        model,
        bucket,
        tcap,
        reqs,
        ids,
        mask,
        result,
        panicked,
        exec_us,
        dispatch_wait_us,
        worker: w,
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::coordinator::faults::FaultPlan;
    use crate::coordinator::server::{Server, ServerConfig};
    use crate::runtime::{Backend, NativeBackend, NativeDims, NativeModel};

    fn tiny_backend() -> NativeBackend {
        let dims = NativeDims {
            vocab: 64,
            seq: 8,
            n_layers: 1,
            d_model: 16,
            n_heads: 2,
            d_ff: 32,
            n_classes: 2,
        };
        NativeBackend::with_model(NativeModel::random(dims, &[4], 7))
    }

    fn mk_server(be: &NativeBackend) -> Server<'_, NativeBackend> {
        Server::new(
            be,
            ServerConfig {
                batch_buckets: vec![1, 4],
                seq_buckets: vec![],
                batch_window: std::time::Duration::from_secs(60),
                ..Default::default()
            },
        )
        .unwrap()
    }

    /// Drive a server's queues through the pool to empty — the
    /// in-process harness the determinism and chaos tests reuse.
    fn drain_through_pool(
        s: &mut Server<'_, NativeBackend>,
        pool: &WorkerPool,
    ) -> Vec<crate::coordinator::server::Response> {
        let mut out = Vec::new();
        while s.pending() > 0 || s.in_flight() > 0 {
            while let Some(item) = s.dequeue_work(true, &mut out) {
                pool.dispatch(item);
            }
            if s.in_flight() > 0 {
                let done = pool
                    .recv_timeout(Duration::from_secs(10))
                    .expect("a dispatched batch must complete");
                out.extend(s.complete_work(done));
            }
        }
        out
    }

    #[test]
    fn pool_serves_a_server_drain_completely() {
        let be = tiny_backend();
        let mut s = mk_server(&be);
        for i in 0..13usize {
            let ids: Vec<i32> = (0..8).map(|j| ((i + j) % 64) as i32).collect();
            s.submit(ids, vec![1.0; 8]).unwrap();
        }
        let pool = WorkerPool::new(
            (0..4).map(|_| be.worker_dispatcher().unwrap()).collect(),
            WakeHandle::none(),
        );
        assert_eq!(pool.len(), 4);
        let out = drain_through_pool(&mut s, &pool);
        assert_eq!(out.len(), 13);
        assert!(out.iter().all(|r| r.is_ok()));
        let mut ids: Vec<u64> = out.iter().map(|r| r.id).collect();
        ids.sort_unstable();
        ids.dedup();
        assert_eq!(ids.len(), 13, "exactly one response per admitted request");
        assert_eq!(s.served, 13);
        assert_eq!(s.in_flight(), 0);
        assert_eq!(pool.queue_depth(), 0);
    }

    #[test]
    fn worker_panic_fails_one_batch_and_the_pool_survives() {
        let mut be = tiny_backend();
        be.set_faults(FaultPlan::panic_nth(1));
        let mut s = mk_server(&be);
        for i in 0..6usize {
            let ids: Vec<i32> = (0..8).map(|j| ((i + j) % 64) as i32).collect();
            s.submit(ids, vec![1.0; 8]).unwrap();
        }
        let pool = WorkerPool::new(
            (0..2).map(|_| be.worker_dispatcher().unwrap()).collect(),
            WakeHandle::none(),
        );
        let out = drain_through_pool(&mut s, &pool);
        assert_eq!(out.len(), 6, "every request settles despite the panic");
        let failed: Vec<_> = out.iter().filter(|r| !r.is_ok()).collect();
        assert_eq!(failed.len(), 4, "the first dispatched batch (of 4) fails");
        assert!(out.iter().filter(|r| r.is_ok()).count() == 2);
        assert_eq!(s.admitted, s.served + s.failed);
        // the panicked worker thread is still alive and serving: push
        // another full round through the same pool
        for i in 0..4usize {
            let ids: Vec<i32> = (0..8).map(|j| ((i + j) % 64) as i32).collect();
            s.submit(ids, vec![1.0; 8]).unwrap();
        }
        let out = drain_through_pool(&mut s, &pool);
        assert_eq!(out.len(), 4);
        assert!(out.iter().all(|r| r.is_ok()), "the pool keeps serving after a contained panic");
    }

    #[test]
    fn pool_results_match_inline_bit_for_bit() {
        let be = tiny_backend();
        let mut s = mk_server(&be);
        let reqs: Vec<Vec<i32>> =
            (0..9).map(|i| (0..8).map(|j| ((i * 5 + j) % 64) as i32).collect()).collect();
        for ids in &reqs {
            s.submit(ids.clone(), vec![1.0; 8]).unwrap();
        }
        let pool = WorkerPool::new(
            (0..3).map(|_| be.worker_dispatcher().unwrap()).collect(),
            WakeHandle::none(),
        );
        let mut got = drain_through_pool(&mut s, &pool);
        got.sort_by_key(|r| r.id);

        let be2 = tiny_backend();
        let mut s2 = mk_server(&be2);
        for ids in &reqs {
            s2.submit(ids.clone(), vec![1.0; 8]).unwrap();
        }
        let mut want = s2.drain().unwrap();
        want.sort_by_key(|r| r.id);
        assert_eq!(got.len(), want.len());
        for (g, w) in got.iter().zip(want.iter()) {
            assert_eq!(g.logits(), w.logits(), "pool logits must match inline bit-for-bit");
        }
    }
}
