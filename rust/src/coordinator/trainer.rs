//! QAT training orchestration — the coordinator side of Tables 1 & 3.
//!
//! Pipeline per (task, quantization config), mirroring the paper §4/§5.2:
//!
//!   1. `init`           — fresh fp32 parameters (AOT `init` artifact).
//!   2. teacher finetune — fp32 CE training on the task (`train_fp32`).
//!   3. calibration      — run `calibrate` over training batches; set
//!                         initial scales from the |activation| quantile
//!                         and weight abs-max (§3.1).
//!   4. QAT              — `train_step` K-step chunks with the per-layer
//!                         bit vector, the MSE/STE gradient flag, the
//!                         distillation weights α/β and the LSQ flag —
//!                         every Table-1/Table-3 row is a flag setting.
//!   5. eval             — periodic dev evaluation; report best accuracy
//!                         (paper reports best over the sweep).
//!
//! Training state lives as XLA `Literal`s between steps (no host copies
//! on the chunk loop — §Perf).

use anyhow::Result;
use xla::Literal;

use crate::data::{stack_k, BatchIter, Dataset, TaskData};
use crate::runtime::{Engine, HostTensor};
use crate::util::rng::Rng;

use super::scheduler::LrSchedule;

/// Model dimensions read from the artifact manifest (the only config
/// channel from the Python compile path).
#[derive(Debug, Clone, Copy)]
pub struct ModelDims {
    pub vocab: usize,
    pub seq: usize,
    pub n_layers: usize,
    pub d_model: usize,
    pub n_heads: usize,
    pub d_ff: usize,
    pub n_classes: usize,
    pub batch: usize,
    pub eval_batch: usize,
    pub k_steps: usize,
    pub n_params: usize,
    pub n_scales: usize,
}

impl ModelDims {
    pub fn from_manifest(eng: &Engine) -> Result<Self> {
        let m = &eng.manifest;
        Ok(ModelDims {
            vocab: m.cfg("vocab")?,
            seq: m.cfg("seq")?,
            n_layers: m.cfg("n_layers")?,
            d_model: m.cfg("d_model")?,
            n_heads: m.cfg("n_heads")?,
            d_ff: m.cfg("d_ff")?,
            n_classes: m.cfg("n_classes")?,
            batch: m.cfg("batch")?,
            eval_batch: m.cfg("eval_batch")?,
            k_steps: m.cfg("k_steps")?,
            n_params: m.cfg("n_params")?,
            n_scales: m.cfg("n_scales")?,
        })
    }

    /// Length of the QAT state section (train_step inputs/outputs prefix):
    /// params + scales + m_p + v_p + m_s + v_s + step.
    pub fn qat_state_len(&self) -> usize {
        3 * self.n_params + 3 * self.n_scales + 1
    }

    /// fp32 state section: params + m + v + step.
    pub fn fp32_state_len(&self) -> usize {
        3 * self.n_params + 1
    }
}

/// Per-run QAT configuration — one Table-1/Table-3 cell.
#[derive(Debug, Clone)]
pub struct QatConfig {
    /// Per-layer bit codes, e.g. [8, 8, 4, 4] for TinyBERT4_{3,4}.
    pub bits: Vec<u32>,
    /// true = MKQ-BERT MSE-based scale gradient; false = STE/LSQ (KDLSQ).
    pub mse_grad: bool,
    /// Eq. 10 loss weights (paper sets α=10, β=1).
    pub alpha: f32,
    pub beta: f32,
    /// false freezes scales (the "w/o LSQ" ablation).
    pub lsq: bool,
    pub steps: usize,
    pub lr_w: f64,
    pub lr_scale_act: f64,
    pub lr_scale_w: f64,
    /// Evaluate the dev set every N steps (and at the end).
    pub eval_every: usize,
    pub seed: u64,
    /// Export the best-eval QAT state as an MKQC checkpoint after
    /// training (served natively via `serve-native --checkpoint`).
    pub ckpt_out: Option<std::path::PathBuf>,
}

impl Default for QatConfig {
    fn default() -> Self {
        QatConfig {
            bits: vec![8, 8, 8, 8],
            mse_grad: true,
            alpha: 10.0,
            beta: 1.0,
            lsq: true,
            steps: 300,
            lr_w: 5e-5,
            lr_scale_act: 0.01,
            lr_scale_w: 0.001,
            eval_every: 100,
            seed: 17,
            ckpt_out: None,
        }
    }
}

#[derive(Debug, Clone)]
pub struct TrainCurve {
    /// (step, total, ce, kd_out, kd_att, kd_val, train_acc)
    pub points: Vec<(usize, f32, f32, f32, f32, f32, f32)>,
}

#[derive(Debug, Clone)]
pub struct QatResult {
    pub best_dev_acc: f64,
    pub final_dev_acc: f64,
    pub evals: Vec<(usize, f64)>,
    pub curve: TrainCurve,
}

pub struct Trainer<'e> {
    pub eng: &'e Engine,
    pub dims: ModelDims,
    pub verbose: bool,
}

impl<'e> Trainer<'e> {
    pub fn new(eng: &'e Engine) -> Result<Self> {
        Ok(Trainer { eng, dims: ModelDims::from_manifest(eng)?, verbose: false })
    }

    // -- phase 1: init ------------------------------------------------------

    /// Fresh fp32 params + placeholder scales (manifest order).
    pub fn init(&self, seed: i32) -> Result<(Vec<Literal>, Vec<Literal>)> {
        let seed_t = HostTensor::i32(&[1], vec![seed]);
        let out = self.eng.execute_raw("init", &[&seed_t.to_literal()?])?;
        let mut params = out;
        let scales = params.split_off(self.dims.n_params);
        Ok((params, scales))
    }

    // -- phase 2: teacher finetune -------------------------------------------

    /// fp32 CE finetuning; returns final params and the loss curve.
    pub fn finetune_teacher(
        &self,
        task: &TaskData,
        steps: usize,
        peak_lr: f64,
        seed: u64,
    ) -> Result<(Vec<Literal>, TrainCurve)> {
        let d = &self.dims;
        let (params, _) = self.init(seed as i32)?;
        let zeros: Vec<Literal> = params
            .iter()
            .map(|p| {
                let t = HostTensor::from_literal(p)?;
                HostTensor::f32(&t.dims, vec![0.0; t.elem_count()]).to_literal()
            })
            .collect::<Result<_>>()?;
        let zeros2: Vec<Literal> = zeros.iter().map(clone_literal).collect::<Result<_>>()?;
        let mut state: Vec<Literal> = params;
        state.extend(zeros);
        state.extend(zeros2);
        state.push(HostTensor::scalar_f32(0.0).to_literal()?);

        let sched = LrSchedule::new(peak_lr, steps);
        let mut it = BatchIter::new(task.train.len(), d.batch, Rng::new(seed));
        let mut curve = TrainCurve { points: vec![] };
        let n_state = d.fp32_state_len();
        let mut done = 0usize;
        while done < steps {
            let k = d.k_steps;
            let (ids, mask, labels) = stack_k(&task.train, &mut it, k, d.batch);
            let lr = HostTensor::f32(&[k, 1], sched.slice(done, k));
            let batch_lits = [ids.to_literal()?, mask.to_literal()?, labels.to_literal()?, lr.to_literal()?];
            let mut inputs: Vec<&Literal> = state.iter().collect();
            inputs.extend(batch_lits.iter());
            let out = self.eng.execute_raw("train_fp32", &inputs)?;
            let stats = HostTensor::from_literal(&out[n_state])?;
            state = out;
            state.truncate(n_state);
            let sv = stats.as_f32()?;
            for i in 0..k {
                curve.points.push((
                    done + i,
                    sv[i * 2],
                    sv[i * 2],
                    0.0,
                    0.0,
                    0.0,
                    sv[i * 2 + 1] / d.batch as f32,
                ));
            }
            done += k;
            if self.verbose && done % 100 < k {
                println!("  [teacher] step {done}: ce={:.4}", sv[(k - 1) * 2]);
            }
        }
        state.truncate(d.n_params);
        Ok((state, curve))
    }

    /// Teacher finetune with restart-on-failure: small-transformer training
    /// on compositional tasks converges breakthrough-style (bimodal in
    /// seed), so — like the paper's "best result over all hyper
    /// parameters" (§5.2) — retry with fresh seeds until the dev accuracy
    /// clears `threshold` (or attempts run out; the best run is returned).
    pub fn finetune_teacher_best(
        &self,
        task: &TaskData,
        steps: usize,
        peak_lr: f64,
        seed: u64,
        threshold: f64,
        max_attempts: usize,
    ) -> Result<(Vec<Literal>, f64)> {
        let mut best: Option<(Vec<Literal>, f64)> = None;
        for attempt in 0..max_attempts.max(1) {
            let (params, _) = self.finetune_teacher(task, steps, peak_lr, seed + 1000 * attempt as u64)?;
            let acc = self.eval_teacher(&params, &task.dev)?;
            if self.verbose {
                println!("  [teacher] attempt {attempt}: dev acc {acc:.4}");
            }
            if best.as_ref().map(|(_, b)| acc > *b).unwrap_or(true) {
                best = Some((params, acc));
            }
            if acc >= threshold {
                break;
            }
        }
        Ok(best.unwrap())
    }

    // -- phase 3: calibration --------------------------------------------------

    /// Run the `calibrate` artifact over `n_batches` training batches and
    /// aggregate: activation stat = max over batches of the per-batch
    /// 99.99% |activation| quantile (§3.1's "top 0.01%"), weight stat =
    /// abs-max.
    pub fn calibrate(
        &self,
        params: &[Literal],
        train: &Dataset,
        n_batches: usize,
        seed: u64,
    ) -> Result<(Vec<f32>, Vec<f32>)> {
        let d = &self.dims;
        let mut it = BatchIter::new(train.len(), d.batch, Rng::new(seed ^ 0xCA11B));
        let mut act_stat = vec![0f32; d.n_layers * 4];
        let mut w_max = vec![0f32; d.n_layers * 6];
        for _ in 0..n_batches {
            let rows = it.next_rows();
            let (ids, mask, _, _) = train.gather(&rows, d.batch);
            let mut inputs: Vec<&Literal> = params.iter().collect();
            let ids_l = ids.to_literal()?;
            let mask_l = mask.to_literal()?;
            inputs.push(&ids_l);
            inputs.push(&mask_l);
            let out = self.eng.execute_raw("calibrate", &inputs)?;
            let aq = HostTensor::from_literal(&out[0])?;
            let wm = HostTensor::from_literal(&out[2])?;
            for (dst, src) in act_stat.iter_mut().zip(aq.as_f32()?.iter()) {
                *dst = dst.max(*src);
            }
            for (dst, src) in w_max.iter_mut().zip(wm.as_f32()?.iter()) {
                *dst = dst.max(*src);
            }
        }
        Ok((act_stat, w_max))
    }

    /// Initial scales in manifest order (per layer: 4 act, then 6 weight),
    /// each divided by that layer's l_max (paper Eq. 1 bounds).
    pub fn make_scales(&self, act_stat: &[f32], w_max: &[f32], bits: &[u32]) -> Result<Vec<Literal>> {
        let d = &self.dims;
        assert_eq!(bits.len(), d.n_layers);
        let mut out = Vec::with_capacity(d.n_scales);
        for l in 0..d.n_layers {
            let lmax = crate::quant::qbounds(bits[l]).1;
            for a in 0..4 {
                let s = (act_stat[l * 4 + a] / lmax).max(1e-6);
                out.push(HostTensor::f32(&[1], vec![s]).to_literal()?);
            }
            for w in 0..6 {
                let s = (w_max[l * 6 + w] / lmax).max(1e-6);
                out.push(HostTensor::f32(&[1], vec![s]).to_literal()?);
            }
        }
        Ok(out)
    }

    // -- phase 4+5: QAT + eval ---------------------------------------------------

    pub fn qat(
        &self,
        teacher: &[Literal],
        init_scales: Vec<Literal>,
        task: &TaskData,
        cfg: &QatConfig,
    ) -> Result<QatResult> {
        let d = &self.dims;
        assert_eq!(cfg.bits.len(), d.n_layers);

        // state = student params (start at teacher ckpt) + scales + zeros.
        let mut state: Vec<Literal> = teacher.iter().map(clone_literal).collect::<Result<_>>()?;
        state.extend(init_scales);
        // zeros for m_p, v_p (param-shaped) and m_s, v_s (scale-shaped)
        let zeros_p: Vec<Literal> = (0..d.n_params)
            .map(|i| {
                let t = HostTensor::from_literal(&state[i])?;
                HostTensor::f32(&t.dims, vec![0.0; t.elem_count()]).to_literal()
            })
            .collect::<Result<_>>()?;
        let zeros_p2: Vec<Literal> = zeros_p.iter().map(clone_literal).collect::<Result<_>>()?;
        let zeros_s: Vec<Literal> =
            (0..d.n_scales).map(|_| HostTensor::f32(&[1], vec![0.0]).to_literal()).collect::<Result<_>>()?;
        let zeros_s2: Vec<Literal> = zeros_s.iter().map(clone_literal).collect::<Result<_>>()?;
        state.extend(zeros_p);
        state.extend(zeros_p2);
        state.extend(zeros_s);
        state.extend(zeros_s2);
        state.push(HostTensor::scalar_f32(0.0).to_literal()?);
        let n_state = d.qat_state_len();
        assert_eq!(state.len(), n_state);

        // static inputs
        let flags = [
            HostTensor::scalar_f32(cfg.alpha).to_literal()?,
            HostTensor::scalar_f32(cfg.beta).to_literal()?,
            HostTensor::scalar_f32(if cfg.mse_grad { 1.0 } else { 0.0 }).to_literal()?,
            HostTensor::scalar_f32(if cfg.lsq { 1.0 } else { 0.0 }).to_literal()?,
            HostTensor::f32(&[d.n_layers], cfg.bits.iter().map(|&b| b as f32).collect()).to_literal()?,
        ];
        let bits_f: Vec<f32> = cfg.bits.iter().map(|&b| b as f32).collect();

        let sched_w = LrSchedule::new(cfg.lr_w, cfg.steps);
        let sched_sa = LrSchedule::new(cfg.lr_scale_act, cfg.steps);
        let sched_sw = LrSchedule::new(cfg.lr_scale_w, cfg.steps);
        let mut it = BatchIter::new(task.train.len(), d.batch, Rng::new(cfg.seed));

        let mut curve = TrainCurve { points: vec![] };
        let mut evals: Vec<(usize, f64)> = vec![];
        let mut best = 0f64;
        // best-eval params+scales snapshot, kept for the checkpoint export
        let mut best_state: Option<Vec<Literal>> = None;
        let mut done = 0usize;
        while done < cfg.steps {
            let k = d.k_steps;
            let (ids, mask, labels) = stack_k(&task.train, &mut it, k, d.batch);
            let chunk = [
                ids.to_literal()?,
                mask.to_literal()?,
                labels.to_literal()?,
                HostTensor::f32(&[k, 1], sched_w.slice(done, k)).to_literal()?,
                HostTensor::f32(&[k, 1], sched_sa.slice(done, k)).to_literal()?,
                HostTensor::f32(&[k, 1], sched_sw.slice(done, k)).to_literal()?,
            ];
            let mut inputs: Vec<&Literal> = state.iter().collect();
            inputs.extend(teacher.iter());
            inputs.extend(chunk.iter());
            inputs.extend(flags.iter());
            let out = self.eng.execute_raw("train_step", &inputs)?;
            let stats = HostTensor::from_literal(&out[n_state])?;
            state = out;
            state.truncate(n_state);
            let sv = stats.as_f32()?;
            for i in 0..k {
                curve.points.push((
                    done + i,
                    sv[i * 6],
                    sv[i * 6 + 1],
                    sv[i * 6 + 2],
                    sv[i * 6 + 3],
                    sv[i * 6 + 4],
                    sv[i * 6 + 5] / d.batch as f32,
                ));
            }
            done += k;

            if done % cfg.eval_every < k || done >= cfg.steps {
                let acc = self.eval_student(&state[..d.n_params + d.n_scales], &bits_f, &task.dev)?;
                evals.push((done, acc));
                // snapshot only when an export will actually consume it
                if cfg.ckpt_out.is_some() && (acc > best || best_state.is_none()) {
                    best_state = Some(
                        state[..d.n_params + d.n_scales]
                            .iter()
                            .map(clone_literal)
                            .collect::<Result<_>>()?,
                    );
                }
                best = best.max(acc);
                if self.verbose {
                    println!(
                        "  [qat] step {done}: total={:.4} ce={:.4} dev_acc={:.4}",
                        sv[(k - 1) * 6],
                        sv[(k - 1) * 6 + 1],
                        acc
                    );
                }
            }
        }
        let final_acc = evals.last().map(|&(_, a)| a).unwrap_or(0.0);
        if let Some(path) = &cfg.ckpt_out {
            let snap = best_state
                .as_ref()
                .map(|s| &s[..])
                .unwrap_or(&state[..d.n_params + d.n_scales]);
            self.export_checkpoint(snap, &cfg.bits, path)?;
            if self.verbose {
                println!("  [qat] exported best-eval checkpoint to {}", path.display());
            }
        }
        Ok(QatResult { best_dev_acc: best, final_dev_acc: final_acc, evals, curve })
    }

    /// Export a QAT state (params + scales, manifest order) as an MKQC
    /// checkpoint: fp32 master weights under the `param_specs` naming
    /// contract, the per-layer bit vector, and the 4 learned activation
    /// scales per layer in the header. `serve-native --checkpoint` then
    /// prepacks and serves it without Python or XLA.
    pub fn export_checkpoint(
        &self,
        params_scales: &[Literal],
        bits: &[u32],
        path: &std::path::Path,
    ) -> Result<()> {
        use crate::checkpoint::{write_model_checkpoint, CkptHeader};
        use crate::runtime::NativeDims;

        let d = &self.dims;
        anyhow::ensure!(
            params_scales.len() == d.n_params + d.n_scales,
            "export_checkpoint wants {} params + {} scales, got {}",
            d.n_params,
            d.n_scales,
            params_scales.len()
        );
        // tensor names come from the manifest's eval_step input list
        // ("p.<name>" params then "s.<name>" scales) — the same flat
        // ordering contract the Python compile path emits.
        let spec = self.eng.spec("eval_step")?;
        let mut tensors: Vec<(String, Vec<usize>, Vec<f32>)> = Vec::with_capacity(d.n_params);
        for (lit, inp) in params_scales[..d.n_params].iter().zip(&spec.inputs) {
            let name = inp
                .name
                .strip_prefix("p.")
                .ok_or_else(|| anyhow::anyhow!("manifest input {} is not a p.* param", inp.name))?;
            let t = HostTensor::from_literal(lit)?;
            tensors.push((name.to_string(), t.dims.clone(), t.as_f32()?.to_vec()));
        }
        // per layer: 4 activation-site scales, then 6 weight scales (the
        // weight scales are re-derived at load from the fp32 weights).
        let per_layer = d.n_scales / d.n_layers;
        anyhow::ensure!(
            per_layer * d.n_layers == d.n_scales && per_layer >= 4,
            "manifest n_scales {} is not a per-layer multiple >= 4 of n_layers {}",
            d.n_scales,
            d.n_layers
        );
        let mut act_scales = Vec::with_capacity(d.n_layers);
        for l in 0..d.n_layers {
            let mut row = [0f32; 4];
            for (a, slot) in row.iter_mut().enumerate() {
                let lit = &params_scales[d.n_params + l * per_layer + a];
                *slot = HostTensor::from_literal(lit)?.as_f32()?[0];
            }
            act_scales.push(row);
        }
        let header = CkptHeader {
            dims: NativeDims {
                vocab: d.vocab,
                seq: d.seq,
                n_layers: d.n_layers,
                d_model: d.d_model,
                n_heads: d.n_heads,
                d_ff: d.d_ff,
                n_classes: d.n_classes,
            },
            bits: bits.to_vec(),
            act_scales,
        };
        write_model_checkpoint(path, &header, &tensors).map_err(anyhow::Error::new)
    }

    /// Dev-set accuracy of the quantized student (argmax over logits,
    /// counted on the Rust side so padded tail rows are excluded).
    pub fn eval_student(&self, params_scales: &[Literal], bits_f: &[f32], dev: &Dataset) -> Result<f64> {
        let d = &self.dims;
        let bits_l = HostTensor::f32(&[d.n_layers], bits_f.to_vec()).to_literal()?;
        let mut correct = 0usize;
        let mut total = 0usize;
        let mut row = 0usize;
        while row < dev.len() {
            let rows: Vec<usize> = (row..(row + d.eval_batch).min(dev.len())).collect();
            let (ids, mask, labels, _) = dev.gather(&rows, d.eval_batch);
            let lits = [ids.to_literal()?, mask.to_literal()?, labels.to_literal()?];
            let mut inputs: Vec<&Literal> = params_scales.iter().collect();
            inputs.push(&bits_l);
            inputs.push(&lits[0]);
            inputs.push(&lits[1]);
            inputs.push(&lits[2]);
            let out = self.eng.execute_raw("eval_step", &inputs)?;
            let logits = HostTensor::from_literal(&out[2])?;
            let (c, t) = count_correct(logits.as_f32()?, labels.as_i32()?, rows.len(), d.n_classes);
            correct += c;
            total += t;
            row += d.eval_batch;
        }
        Ok(correct as f64 / total.max(1) as f64)
    }

    /// Dev-set accuracy of the fp32 model (Table 1's "original" row).
    pub fn eval_teacher(&self, params: &[Literal], dev: &Dataset) -> Result<f64> {
        let d = &self.dims;
        let mut correct = 0usize;
        let mut total = 0usize;
        let mut row = 0usize;
        while row < dev.len() {
            let rows: Vec<usize> = (row..(row + d.eval_batch).min(dev.len())).collect();
            let (ids, mask, labels, _) = dev.gather(&rows, d.eval_batch);
            let lits = [ids.to_literal()?, mask.to_literal()?, labels.to_literal()?];
            let mut inputs: Vec<&Literal> = params.iter().collect();
            inputs.push(&lits[0]);
            inputs.push(&lits[1]);
            inputs.push(&lits[2]);
            let out = self.eng.execute_raw("teacher_eval", &inputs)?;
            let logits = HostTensor::from_literal(&out[2])?;
            let (c, t) = count_correct(logits.as_f32()?, labels.as_i32()?, rows.len(), d.n_classes);
            correct += c;
            total += t;
            row += d.eval_batch;
        }
        Ok(correct as f64 / total.max(1) as f64)
    }
}

fn count_correct(logits: &[f32], labels: &[i32], n_valid: usize, n_classes: usize) -> (usize, usize) {
    let mut correct = 0;
    for i in 0..n_valid {
        let row = &logits[i * n_classes..(i + 1) * n_classes];
        let pred = row
            .iter()
            .enumerate()
            .max_by(|a, b| a.1.partial_cmp(b.1).unwrap())
            .map(|(j, _)| j as i32)
            .unwrap();
        if pred == labels[i] {
            correct += 1;
        }
    }
    (correct, n_valid)
}

/// Literal has no Clone; round-trip through host bytes.
fn clone_literal(l: &Literal) -> Result<Literal> {
    HostTensor::from_literal(l)?.to_literal()
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn count_correct_excludes_padding() {
        // 3 valid rows of 2 classes; 4th row would be padding.
        let logits = vec![0.1, 0.9, 0.8, 0.2, 0.3, 0.7, 9.0, -9.0];
        let labels = vec![1, 0, 0, 1];
        let (c, t) = count_correct(&logits, &labels, 3, 2);
        assert_eq!(t, 3);
        assert_eq!(c, 2); // rows 0,1 right; row 2 predicts 1 vs label 0
    }
}
