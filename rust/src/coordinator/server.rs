//! Inference serving coordinator: request router + dynamic batcher +
//! executor over the quantized `serve_fwd_*` artifacts.
//!
//! The paper's contribution-3 story is *deployment*: int4 layers behind a
//! batched inference service (Table 2 reports per-layer latency at
//! serving batch shapes). This module is the vLLM-router-shaped L3 piece:
//!
//!   * requests arrive with variable valid-token counts;
//!   * the dynamic batcher groups them into the largest available batch
//!     bucket (compiled executables exist per batch size) within a
//!     bounded batching window;
//!   * the executor runs the AOT artifact and the router fans responses
//!     back out, recording queue/execute/total latency.
//!
//! Single-threaded event loop by design: the PJRT CPU client already
//! parallelizes one execution across cores, so concurrent executes only
//! thrash; the loop instead overlaps batching with execution completion.

use std::collections::VecDeque;
use std::time::{Duration, Instant};

use anyhow::{bail, Result};
use xla::Literal;

use crate::runtime::{Engine, HostTensor};
use crate::util::stats::{LatencyRecorder, LatencySummary};

use super::trainer::ModelDims;

#[derive(Debug, Clone)]
pub struct Request {
    pub id: u64,
    pub ids: Vec<i32>,
    pub mask: Vec<f32>,
    pub enqueued: Instant,
}

#[derive(Debug, Clone)]
pub struct Response {
    pub id: u64,
    pub logits: Vec<f32>,
    pub queue_us: f64,
    pub exec_us: f64,
    pub batch_size: usize,
}

/// Deployed model: parameters + scales + per-layer bit codes, kept as
/// literals so the hot loop never re-converts them.
pub struct ServeModel {
    pub params_scales: Vec<Literal>,
    pub bits: Literal,
    pub label: String,
}

impl ServeModel {
    pub fn new(params_scales: Vec<Literal>, bits_f: &[f32], label: &str) -> Result<Self> {
        Ok(ServeModel {
            params_scales,
            bits: HostTensor::f32(&[bits_f.len()], bits_f.to_vec()).to_literal()?,
            label: label.to_string(),
        })
    }
}

pub struct ServerConfig {
    /// Available serve_fwd batch buckets (must match emitted artifacts).
    pub buckets: Vec<usize>,
    /// Max time a request may wait for batchmates.
    pub batch_window: Duration,
}

impl Default for ServerConfig {
    fn default() -> Self {
        ServerConfig { buckets: vec![1, 8, 16], batch_window: Duration::from_micros(500) }
    }
}

pub struct Server<'e> {
    eng: &'e Engine,
    dims: ModelDims,
    model: ServeModel,
    cfg: ServerConfig,
    queue: VecDeque<Request>,
    next_id: u64,
    pub queue_lat: LatencyRecorder,
    pub exec_lat: LatencyRecorder,
    pub total_lat: LatencyRecorder,
    pub served: u64,
    pub batches: u64,
    pub padded_slots: u64,
}

impl<'e> Server<'e> {
    pub fn new(eng: &'e Engine, model: ServeModel, cfg: ServerConfig) -> Result<Self> {
        let dims = ModelDims::from_manifest(eng)?;
        let mut buckets = cfg.buckets.clone();
        buckets.sort_unstable();
        for &b in &buckets {
            // fail fast if an artifact is missing
            eng.spec(&format!("serve_fwd_b{b}"))?;
        }
        Ok(Server {
            eng,
            dims,
            model,
            cfg: ServerConfig { buckets, ..cfg },
            queue: VecDeque::new(),
            next_id: 0,
            queue_lat: LatencyRecorder::new(),
            exec_lat: LatencyRecorder::new(),
            total_lat: LatencyRecorder::new(),
            served: 0,
            batches: 0,
            padded_slots: 0,
        })
    }

    /// Enqueue a tokenized request; returns its id.
    pub fn submit(&mut self, ids: Vec<i32>, mask: Vec<f32>) -> Result<u64> {
        if ids.len() != self.dims.seq || mask.len() != self.dims.seq {
            bail!("request must be padded to seq={} (got {})", self.dims.seq, ids.len());
        }
        let id = self.next_id;
        self.next_id += 1;
        self.queue.push_back(Request { id, ids, mask, enqueued: Instant::now() });
        Ok(id)
    }

    pub fn pending(&self) -> usize {
        self.queue.len()
    }

    /// Batching policy: the largest bucket that is full, or — once the
    /// oldest request has waited past the batching window — the largest
    /// bucket ≤ queue length (padding if even the smallest is short).
    fn pick_bucket(&self) -> Option<usize> {
        let n = self.queue.len();
        if n == 0 {
            return None;
        }
        let largest = *self.cfg.buckets.last().unwrap();
        if n >= largest {
            return Some(largest);
        }
        let waited = self.queue.front().unwrap().enqueued.elapsed();
        if waited < self.cfg.batch_window {
            return None; // keep accumulating batchmates
        }
        Some(
            self.cfg
                .buckets
                .iter()
                .copied()
                .filter(|&b| b <= n)
                .max()
                .unwrap_or(self.cfg.buckets[0]),
        )
    }

    /// One event-loop turn: batch + execute if the policy fires.
    pub fn pump(&mut self) -> Result<Vec<Response>> {
        let Some(bucket) = self.pick_bucket() else {
            return Ok(vec![]);
        };
        let take = bucket.min(self.queue.len());
        let reqs: Vec<Request> = (0..take).map(|_| self.queue.pop_front().unwrap()).collect();
        self.padded_slots += (bucket - take) as u64;

        let t = self.dims.seq;
        let mut ids = Vec::with_capacity(bucket * t);
        let mut mask = Vec::with_capacity(bucket * t);
        for i in 0..bucket {
            let r = reqs.get(i).unwrap_or(&reqs[0]); // pad with first request
            ids.extend_from_slice(&r.ids);
            mask.extend_from_slice(&r.mask);
        }
        let ids_l = HostTensor::i32(&[bucket, t], ids).to_literal()?;
        let mask_l = HostTensor::f32(&[bucket, t], mask).to_literal()?;

        let exec_start = Instant::now();
        let mut inputs: Vec<&Literal> = self.model.params_scales.iter().collect();
        inputs.push(&self.model.bits);
        inputs.push(&ids_l);
        inputs.push(&mask_l);
        let out = self.eng.execute_raw(&format!("serve_fwd_b{bucket}"), &inputs)?;
        let exec_us = exec_start.elapsed().as_secs_f64() * 1e6;
        let logits = HostTensor::from_literal(&out[0])?;
        let lv = logits.as_f32()?;

        self.batches += 1;
        let nc = self.dims.n_classes;
        let mut responses = Vec::with_capacity(take);
        for (i, r) in reqs.into_iter().enumerate() {
            let total_us = r.enqueued.elapsed().as_secs_f64() * 1e6;
            let queue_us = (total_us - exec_us).max(0.0);
            self.queue_lat.record(queue_us);
            self.exec_lat.record(exec_us);
            self.total_lat.record(total_us);
            self.served += 1;
            responses.push(Response {
                id: r.id,
                logits: lv[i * nc..(i + 1) * nc].to_vec(),
                queue_us,
                exec_us,
                batch_size: bucket,
            });
        }
        Ok(responses)
    }

    /// Drain the queue fully (end of trace).
    pub fn drain(&mut self) -> Result<Vec<Response>> {
        let mut all = vec![];
        // Force the window open.
        let win = self.cfg.batch_window;
        self.cfg.batch_window = Duration::ZERO;
        while !self.queue.is_empty() {
            all.extend(self.pump()?);
        }
        self.cfg.batch_window = win;
        Ok(all)
    }

    pub fn summary(&self) -> ServerSummary {
        ServerSummary {
            model: self.model.label.clone(),
            served: self.served,
            batches: self.batches,
            padded_slots: self.padded_slots,
            queue: self.queue_lat.summary(),
            exec: self.exec_lat.summary(),
            total: self.total_lat.summary(),
        }
    }
}

#[derive(Debug, Clone)]
pub struct ServerSummary {
    pub model: String,
    pub served: u64,
    pub batches: u64,
    pub padded_slots: u64,
    pub queue: LatencySummary,
    pub exec: LatencySummary,
    pub total: LatencySummary,
}

impl std::fmt::Display for ServerSummary {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        writeln!(
            f,
            "[{}] served={} batches={} avg_batch={:.1} padded={}",
            self.model,
            self.served,
            self.batches,
            self.served as f64 / self.batches.max(1) as f64,
            self.padded_slots
        )?;
        writeln!(f, "  queue : {}", self.queue)?;
        writeln!(f, "  exec  : {}", self.exec)?;
        write!(f, "  total : {}", self.total)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    // pick_bucket policy is tested through a queue-only shim (no engine).
    fn mk_queue(n: usize, waited: Duration) -> (VecDeque<Request>, ServerConfig) {
        let mut q = VecDeque::new();
        let t0 = Instant::now() - waited;
        for id in 0..n {
            q.push_back(Request { id: id as u64, ids: vec![], mask: vec![], enqueued: t0 });
        }
        (q, ServerConfig::default())
    }

    fn pick(q: &VecDeque<Request>, cfg: &ServerConfig) -> Option<usize> {
        let n = q.len();
        if n == 0 {
            return None;
        }
        let largest = *cfg.buckets.last().unwrap();
        if n >= largest {
            return Some(largest);
        }
        let waited = q.front().unwrap().enqueued.elapsed();
        if waited < cfg.batch_window {
            return None;
        }
        Some(cfg.buckets.iter().copied().filter(|&b| b <= n).max().unwrap_or(cfg.buckets[0]))
    }

    #[test]
    fn full_bucket_fires_immediately() {
        let (q, cfg) = mk_queue(16, Duration::ZERO);
        assert_eq!(pick(&q, &cfg), Some(16));
        let (q, cfg) = mk_queue(40, Duration::ZERO);
        assert_eq!(pick(&q, &cfg), Some(16));
    }

    #[test]
    fn short_queue_waits_for_window() {
        let (q, cfg) = mk_queue(3, Duration::ZERO);
        assert_eq!(pick(&q, &cfg), None);
        let (q, cfg) = mk_queue(3, Duration::from_millis(10));
        assert_eq!(pick(&q, &cfg), Some(1)); // largest bucket <= 3 is 1 (buckets 1,8,16)
    }

    #[test]
    fn window_expiry_picks_fitting_bucket() {
        let (q, cfg) = mk_queue(9, Duration::from_millis(10));
        assert_eq!(pick(&q, &cfg), Some(8));
        let (q, cfg) = mk_queue(1, Duration::from_millis(10));
        assert_eq!(pick(&q, &cfg), Some(1));
    }

    #[test]
    fn empty_queue_never_fires() {
        let (q, cfg) = mk_queue(0, Duration::from_secs(1));
        assert_eq!(pick(&q, &cfg), None);
    }
}
