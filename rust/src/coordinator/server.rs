//! Inference serving coordinator: request router + dynamic batcher +
//! executor over any [`Backend`] (native kernels or AOT artifacts).
//!
//! The paper's contribution-3 story is *deployment*: int4 layers behind a
//! batched inference service (Table 2 reports per-layer latency at
//! serving batch shapes). This module is the vLLM-router-shaped L3 piece:
//!
//!   * requests arrive at their **true token length** (no caller-side
//!     padding — `submit` accepts any `1 <= len <= seq`);
//!   * the dynamic batcher groups them into 2-D **(batch × seq-length)
//!     buckets**: each request is admitted to the smallest seq bucket
//!     that fits it, and a batch pads only to that bucket's ceiling, so a
//!     12-token query never pays full-`seq` O(seq²) attention;
//!   * the executor runs the backend forward at the bucket's length and
//!     the router fans responses back out, recording queue/execute/total
//!     latency plus padded-slot *and padded-token* accounting.
//!
//! Fixed-shape backends (the AOT artifact path) keep working: they reject
//! short seq buckets via [`Backend::check_seq_bucket`] at construction,
//! leaving the single full-`seq` bucket — exactly the old 1-D behavior.
//!
//! **Multi-model routing:** when the backend registers several models
//! (`Backend::n_models() > 1` — the model-store
//! [`Registry`](crate::modelstore::Registry)), requests carry a model
//! index ([`Server::submit_to`]) and the bucket grid becomes
//! (model × seq-length): a batch is always one forward through one
//! model, routed via [`Backend::serve_forward_for`], while every model
//! shares this one batcher, its aging policy, and the staging buffers.
//! Seq buckets resolve *per model* (each model's own `seq` is always a
//! bucket; configured ceilings above a model's `seq` don't apply to it),
//! and [`ServerSummary::per_model`] reports routed counts.
//!
//! **Overload and failure semantics** (what makes this servable from a
//! socket, not just from a trace generator):
//!
//!   * *Admission control* — requests are fully validated at `submit`
//!     time (shape, token ids against the target model's vocab, mask
//!     finiteness) and each (model × seq-bucket) queue is bounded by
//!     [`ServerConfig::max_pending`]; a violation returns a typed
//!     [`Rejected`] immediately instead of poisoning a batch later or
//!     growing queues without bound.
//!   * *Deadlines* — a request may carry a deadline
//!     ([`Server::submit_with`] or [`ServerConfig::default_deadline`]);
//!     `pump()` sheds expired requests with
//!     [`Rejected::DeadlineExceeded`] *before* staging a batch, so a
//!     doomed request never wastes a batch slot.
//!   * *Fault isolation* — a failing **or panicking** backend forward
//!     (caught via `catch_unwind`) converts into per-request
//!     [`ResponseBody::Failed`] responses for that one batch; the server
//!     keeps serving and [`Server::drain`] is total: every admitted
//!     request receives exactly one [`Response`], so
//!     `admitted == ok + shed + failed` always reconciles.
//!
//! **Execution model** — two modes over one batcher:
//!
//!   * *Inline* ([`Server::pump`]): batch + execute on the calling
//!     thread. The default for trace replay, the artifact backend, and
//!     `--workers 1`.
//!   * *Off-thread* ([`Server::dequeue_work`] /
//!     [`Server::complete_work`]): the front door stages a ready batch
//!     and pins its execution state at dispatch time — the model's
//!     `Arc<ModelVersion>` handle and the sampled injected fault, via
//!     [`Backend::dispatch_handle`] — then hands the fully-owned
//!     [`WorkItem`] to an execution worker
//!     ([`crate::coordinator::workers`]). While workers execute, the
//!     front door keeps admitting and may dispatch other buckets
//!     concurrently (iteration-level scheduling); `complete_work`
//!     settles accounting and health bookkeeping when results come
//!     back, in completion order. Version pinning at dispatch keeps the
//!     reload/evict/quarantine lifecycle exact: a batch executes the
//!     version it was dispatched against, and lifecycle transitions
//!     apply from the next dispatch on.
//!
//! Per-bucket batch windows are *adaptive*: each (model × seq) slot
//! tracks an EWMA of request inter-arrival gaps, and a bucket closes
//! early when the measured arrival rate says waiting out the rest of
//! the window cannot fill the next batch bucket anyway — sustained slow
//! arrivals stop paying the full window in latency, while burst traffic
//! (unknown or tiny gaps) keeps the exact windowed behavior.
//!
//! §Perf: the batch staging buffers (`ids_stage` / `mask_stage`) persist
//! across pumps — one allocation at server construction, zero on the hot
//! path — and padded positions are zero-filled (a zero-mask position is
//! fully masked, so its logits are well-defined garbage that is never
//! fanned out) instead of cloning a victim request's tokens. Combined
//! with the native backend's [`Workspace`](crate::runtime::Workspace)
//! arena, a steady-state `pump()` performs no per-batch heap allocation
//! inside the native forward.

use std::collections::VecDeque;
use std::panic::{catch_unwind, AssertUnwindSafe};
use std::time::{Duration, Instant};

use anyhow::{bail, Result};

use crate::runtime::{Backend, DispatchHandle, ModelHealth};
use crate::util::stats::{LatencyRecorder, LatencySummary};

/// Typed admission/shed verdicts. `InvalidRequest` and `QueueFull` are
/// returned synchronously from `submit*`; `DeadlineExceeded` arrives
/// asynchronously as a [`ResponseBody::Shed`]. Implements
/// `std::error::Error`, so `?` in `anyhow` contexts keeps working.
#[derive(Debug, Clone, PartialEq, Eq)]
pub enum Rejected {
    /// The target (model × seq-bucket) queue is at `max_pending`.
    QueueFull { pending: usize, max_pending: usize },
    /// The request's deadline passed before a batch slot reached it.
    DeadlineExceeded { waited_us: u64 },
    /// The request can never execute (bad model index, shape mismatch,
    /// out-of-vocab token ids, non-finite mask values).
    InvalidRequest(String),
    /// The server is draining for shutdown: queued work still completes,
    /// but no new admissions.
    ShuttingDown,
    /// The request pinned a model version that is no longer current
    /// (a reload swapped it out). Retrying unpinned routes to `current`.
    VersionGone { pinned: u64, current: u64 },
    /// The target model is quarantined after repeated forward failures;
    /// sibling models keep serving.
    Quarantined { model: String },
    /// The target model was evicted (operator action or memory budget);
    /// reload it to restore serving.
    Evicted { model: String },
}

impl std::fmt::Display for Rejected {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        match self {
            Rejected::QueueFull { pending, max_pending } => {
                write!(f, "queue full ({pending} pending, max_pending {max_pending})")
            }
            Rejected::DeadlineExceeded { waited_us } => {
                write!(f, "deadline exceeded after {waited_us}us in queue")
            }
            Rejected::InvalidRequest(msg) => write!(f, "invalid request: {msg}"),
            Rejected::ShuttingDown => write!(f, "server is shutting down"),
            Rejected::VersionGone { pinned, current } => {
                write!(f, "pinned model version {pinned} is gone (current {current})")
            }
            Rejected::Quarantined { model } => {
                write!(f, "model {model} is quarantined")
            }
            Rejected::Evicted { model } => write!(f, "model {model} is evicted"),
        }
    }
}

impl std::error::Error for Rejected {}

#[derive(Debug, Clone)]
pub struct Request {
    pub id: u64,
    pub ids: Vec<i32>,
    pub mask: Vec<f32>,
    pub enqueued: Instant,
    /// Absolute shed deadline; `None` waits indefinitely.
    pub deadline: Option<Instant>,
}

/// What one admitted request got back: exactly one of these per
/// admission, always — the total-drain contract.
#[derive(Debug, Clone, PartialEq)]
pub enum ResponseBody {
    Logits(Vec<f32>),
    /// Shed before execution (today always `DeadlineExceeded`).
    Shed(Rejected),
    /// The request's batch failed or panicked in the backend; the
    /// message is the rendered error chain.
    Failed(String),
}

#[derive(Debug, Clone)]
pub struct Response {
    pub id: u64,
    /// Model index this request was routed to (0 on single-model
    /// backends).
    pub model: usize,
    pub body: ResponseBody,
    pub queue_us: f64,
    pub exec_us: f64,
    /// Batch bucket this request executed in (0 when shed unexecuted).
    pub batch_size: usize,
    /// Seq-bucket ceiling this request's batch was padded to.
    pub seq_bucket: usize,
}

impl Response {
    pub fn is_ok(&self) -> bool {
        matches!(self.body, ResponseBody::Logits(_))
    }

    pub fn logits(&self) -> Option<&[f32]> {
        match &self.body {
            ResponseBody::Logits(l) => Some(l),
            _ => None,
        }
    }

    pub fn into_logits(self) -> Option<Vec<f32>> {
        match self.body {
            ResponseBody::Logits(l) => Some(l),
            _ => None,
        }
    }
}

/// Serving-facing description of one registered model — what the socket
/// front door's INFO reply advertises so clients can size requests.
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct ModelInfo {
    pub label: String,
    pub vocab: usize,
    pub seq: usize,
    pub n_classes: usize,
    /// Current lifecycle version (bumps on reload; 1 on backends without
    /// a lifecycle).
    pub version: u64,
    pub health: ModelHealth,
    pub consec_failures: u32,
}

/// One staged batch, fully owned and `'static`: everything an execution
/// worker needs to run the forward without touching the server — the
/// requests, the padded staging buffers, and the dispatch-pinned model
/// version + sampled fault ([`Backend::dispatch_handle`]).
pub struct WorkItem {
    pub model: usize,
    /// Batch bucket (rows staged, including padding slots).
    pub bucket: usize,
    /// Seq-length ceiling the batch is padded to.
    pub tcap: usize,
    pub reqs: Vec<Request>,
    /// Staged token ids, `bucket * tcap` long (recycled via the
    /// server's spare-buffer free list on completion).
    pub ids: Vec<i32>,
    pub mask: Vec<f32>,
    pub handle: DispatchHandle,
    /// When the batch left the queue — dispatch-wait accounting.
    pub staged_at: Instant,
}

/// The result of one off-thread batch execution, fed back through
/// [`Server::complete_work`]. Mirrors the three inline `pump()` arms:
/// `Ok(logits)`, `Err(rendered error)`, and `Err(..)` with `panicked`
/// set for a caught worker panic.
pub struct WorkDone {
    pub model: usize,
    pub bucket: usize,
    pub tcap: usize,
    pub reqs: Vec<Request>,
    /// Staging buffers riding back for recycling.
    pub ids: Vec<i32>,
    pub mask: Vec<f32>,
    pub result: Result<Vec<f32>, String>,
    /// The failure was a caught panic (feeds
    /// [`Backend::record_forward_panic`] instead of the error path).
    pub panicked: bool,
    pub exec_us: f64,
    /// Queue-exit to execution-start latency on the worker.
    pub dispatch_wait_us: f64,
    /// Executing worker index (per-worker obs attribution).
    pub worker: usize,
}

/// One (model × seq-bucket) FIFO.
struct Slot {
    model: usize,
    /// Seq-length ceiling batches from this slot pad to.
    tcap: usize,
    q: VecDeque<Request>,
    /// Previous admission into this slot — feeds the inter-arrival EWMA.
    last_arrival: Option<Instant>,
    /// EWMA of inter-arrival gaps in µs; `0.0` means unknown (fewer than
    /// two arrivals, or a same-instant burst) and disables the adaptive
    /// early close for this slot.
    ewma_gap_us: f64,
}

pub struct ServerConfig {
    /// Available batch-size buckets (for the artifact backend these must
    /// match emitted `serve_fwd_b*` executables; the native backend
    /// accepts any).
    pub batch_buckets: Vec<usize>,
    /// Sequence-length bucket ceilings. Empty means "full model seq
    /// only" (the fixed-shape default every backend supports); the model
    /// seq is always appended so any admissible request has a bucket.
    /// Each bucket must pass [`Backend::check_seq_bucket`].
    pub seq_buckets: Vec<usize>,
    /// Max time a request may wait for batchmates.
    pub batch_window: Duration,
    /// Per-(model × seq-bucket) queue bound; `submit*` returns
    /// [`Rejected::QueueFull`] at the bound. 0 disables (unbounded — the
    /// pre-admission-control behavior, for offline trace replay).
    pub max_pending: usize,
    /// Deadline applied to requests submitted without an explicit one
    /// ([`Server::submit_with`] overrides per request). `None` waits
    /// indefinitely.
    pub default_deadline: Option<Duration>,
}

impl Default for ServerConfig {
    fn default() -> Self {
        ServerConfig {
            batch_buckets: vec![1, 8, 16],
            seq_buckets: vec![],
            batch_window: Duration::from_micros(500),
            max_pending: 1024,
            default_deadline: None,
        }
    }
}

pub struct Server<'b, B: Backend> {
    backend: &'b B,
    /// Per-model full sequence length (index = model).
    seqs: Vec<usize>,
    /// Per-model vocab size — admission rejects out-of-vocab ids before
    /// they can poison a whole batch in the backend.
    vocabs: Vec<usize>,
    /// Per-model logits width.
    n_classes: Vec<usize>,
    /// Per-model display labels (the registry names).
    labels: Vec<String>,
    /// Config with the *resolved* batch-bucket list (sorted/deduped);
    /// `seq_buckets` keeps the caller's request — the operative
    /// per-model resolution lives in `slots`.
    cfg: ServerConfig,
    /// The (model × seq-bucket) FIFO grid, grouped by model, ascending
    /// `tcap` within a model; every model's own `seq` is its last slot.
    slots: Vec<Slot>,
    /// Requests served per model (parallel to `labels`).
    served_by_model: Vec<u64>,
    next_id: u64,
    ids_stage: Vec<i32>,
    mask_stage: Vec<f32>,
    /// Recycled off-thread staging buffers: [`Server::complete_work`]
    /// returns each [`WorkItem`]'s `ids`/`mask` here and
    /// [`Server::dequeue_work`] pops them, so steady-state off-thread
    /// staging allocates nothing once the fleet of in-flight batches has
    /// warmed up.
    spare: Vec<(Vec<i32>, Vec<f32>)>,
    /// Batches dequeued via [`Server::dequeue_work`] and not yet settled
    /// via [`Server::complete_work`].
    in_flight: usize,
    pub queue_lat: LatencyRecorder,
    pub exec_lat: LatencyRecorder,
    /// Per-*batch* execution latency (one sample per pump, unlike
    /// `exec_lat`'s one per request) — batch-size-unweighted, the stat
    /// the serving bench gates.
    pub batch_exec_lat: LatencyRecorder,
    pub total_lat: LatencyRecorder,
    /// Requests accepted past admission. Reconciliation invariant:
    /// `admitted == served + shed_deadline + failed + pending()`.
    pub admitted: u64,
    pub served: u64,
    /// Successfully executed batches (failed batches count separately).
    pub batches: u64,
    /// Requests shed with [`Rejected::DeadlineExceeded`] before staging.
    pub shed_deadline: u64,
    /// Requests answered [`ResponseBody::Failed`] (backend error/panic).
    pub failed: u64,
    /// Batches whose forward failed or panicked.
    pub failed_batches: u64,
    /// Synchronous [`Rejected::QueueFull`] rejections (never admitted).
    pub rejected_full: u64,
    /// Synchronous [`Rejected::InvalidRequest`] rejections (never
    /// admitted).
    pub rejected_invalid: u64,
    /// Synchronous [`Rejected::ShuttingDown`] rejections (never
    /// admitted) — arrivals during the drain phase of a graceful stop.
    pub rejected_shutdown: u64,
    /// Synchronous model-unavailability rejections
    /// ([`Rejected::Quarantined`] / [`Rejected::Evicted`] /
    /// [`Rejected::VersionGone`]) — the target exists but cannot serve
    /// this request right now.
    pub rejected_unavailable: u64,
    /// When set, `submit*` rejects everything with
    /// [`Rejected::ShuttingDown`]; queued work still drains.
    draining: bool,
    /// Empty batch slots executed (bucket minus actual requests).
    pub padded_slots: u64,
    /// Padded tokens executed: `bucket * ceiling - valid tokens`, summed
    /// over batches — the waste the 2-D bucket policy exists to shrink.
    pub padded_tokens: u64,
    /// All tokens executed (`bucket * ceiling` summed over batches).
    pub total_tokens: u64,
    /// Total backend execution time, summed once per *batch* (unlike
    /// `exec_lat`, which records once per request) — the compute-bound
    /// numerator for throughput metrics.
    pub exec_us_total: f64,
}

impl<'b, B: Backend> Server<'b, B> {
    pub fn new(backend: &'b B, cfg: ServerConfig) -> Result<Self> {
        let n_models = backend.n_models();
        if n_models == 0 {
            bail!("backend registers no models");
        }
        let mut batch_buckets = cfg.batch_buckets.clone();
        batch_buckets.sort_unstable();
        batch_buckets.dedup();
        if batch_buckets.is_empty() {
            bail!("server needs at least one batch bucket");
        }
        let mut seq_req = cfg.seq_buckets.clone();
        seq_req.sort_unstable();
        seq_req.dedup();
        if seq_req.first() == Some(&0) {
            bail!("seq bucket 0");
        }

        let mut seqs = Vec::with_capacity(n_models);
        let mut vocabs = Vec::with_capacity(n_models);
        let mut n_classes = Vec::with_capacity(n_models);
        let mut labels = Vec::with_capacity(n_models);
        let mut slots: Vec<Slot> = Vec::new();
        for m in 0..n_models {
            let dims = backend.serve_dims_for(m)?;
            for &b in &batch_buckets {
                backend.check_bucket_for(m, b)?; // fail fast if a bucket can't execute
            }
            // per-model seq buckets: the configured ceilings that fit this
            // model, plus the model's own seq so every admissible request
            // has a bucket
            let mut buckets: Vec<usize> =
                seq_req.iter().copied().filter(|&t| t <= dims.seq).collect();
            if buckets.last() != Some(&dims.seq) {
                buckets.push(dims.seq);
            }
            for &t in &buckets {
                backend.check_seq_bucket_for(m, t)?;
            }
            for t in buckets {
                slots.push(Slot {
                    model: m,
                    tcap: t,
                    q: VecDeque::new(),
                    last_arrival: None,
                    ewma_gap_us: 0.0,
                });
            }
            seqs.push(dims.seq);
            vocabs.push(dims.vocab);
            n_classes.push(dims.n_classes);
            labels.push(backend.model_label(m));
        }
        let max_seq = *seqs.iter().max().unwrap();
        // preserve the single-model contract: a configured ceiling no
        // model can serve is a config error, not a silent drop
        if let Some(&too_big) = seq_req.iter().find(|&&t| t > max_seq) {
            bail!("seq bucket {too_big} exceeds every model's seq (max {max_seq})");
        }
        let largest = *batch_buckets.last().unwrap();
        Ok(Server {
            backend,
            seqs,
            vocabs,
            n_classes,
            labels,
            // the stored config carries the *resolved* batch buckets —
            // the single source of truth the policy reads
            cfg: ServerConfig { batch_buckets, seq_buckets: seq_req, ..cfg },
            slots,
            served_by_model: vec![0; n_models],
            next_id: 0,
            // staging sized once for the largest batch at the largest
            // model seq — every slot slices a prefix, so pumps never
            // reallocate
            ids_stage: vec![0; largest * max_seq],
            mask_stage: vec![0.0; largest * max_seq],
            spare: Vec::new(),
            in_flight: 0,
            queue_lat: LatencyRecorder::new(),
            exec_lat: LatencyRecorder::new(),
            batch_exec_lat: LatencyRecorder::new(),
            total_lat: LatencyRecorder::new(),
            admitted: 0,
            served: 0,
            batches: 0,
            shed_deadline: 0,
            failed: 0,
            failed_batches: 0,
            rejected_full: 0,
            rejected_invalid: 0,
            rejected_shutdown: 0,
            rejected_unavailable: 0,
            draining: false,
            padded_slots: 0,
            padded_tokens: 0,
            total_tokens: 0,
            exec_us_total: 0.0,
        })
    }

    /// Enqueue a tokenized request *at its true length* — `ids`/`mask`
    /// may be any `1..=seq` tokens long (full-`seq` padded submissions
    /// keep working and land in the full-length bucket). Routes to model
    /// 0; multi-model callers use [`Server::submit_to`]. Returns its id.
    pub fn submit(&mut self, ids: Vec<i32>, mask: Vec<f32>) -> Result<u64, Rejected> {
        self.submit_with(0, ids, mask, None)
    }

    /// Enqueue a request for one registered model (index from
    /// [`Server::find_model`] or the registry). Returns its id.
    pub fn submit_to(&mut self, model: usize, ids: Vec<i32>, mask: Vec<f32>) -> Result<u64, Rejected> {
        self.submit_with(model, ids, mask, None)
    }

    /// Full-control admission: route to `model` with an optional
    /// per-request deadline (overrides
    /// [`ServerConfig::default_deadline`]). Validates everything the
    /// backend would otherwise trip on mid-batch — shape, token ids
    /// against the model's vocab, mask finiteness — and enforces the
    /// per-slot queue bound, so an accepted id is guaranteed exactly one
    /// eventual [`Response`].
    pub fn submit_with(
        &mut self,
        model: usize,
        ids: Vec<i32>,
        mask: Vec<f32>,
        deadline: Option<Duration>,
    ) -> Result<u64, Rejected> {
        self.submit_pinned_to(model, None, ids, mask, deadline)
    }

    /// [`Server::submit_with`] plus an optional **version pin**: the
    /// request is admitted only while `pin` is the model's current
    /// lifecycle version, otherwise it rejects with
    /// [`Rejected::VersionGone`]. Valid at admission time only — the
    /// ADMIN reload handler drains the server before swapping versions,
    /// so an admitted pin can never execute against a different version.
    pub fn submit_pinned_to(
        &mut self,
        model: usize,
        pin: Option<u64>,
        ids: Vec<i32>,
        mask: Vec<f32>,
        deadline: Option<Duration>,
    ) -> Result<u64, Rejected> {
        let res = self.admit(model, pin, ids, mask, deadline);
        let obs = crate::obs::metrics();
        match &res {
            Ok(_) => {
                self.admitted += 1;
                if let Some(o) = obs {
                    o.serve_admitted.inc();
                }
            }
            // the flight recorder keeps every reject edge (typed, with
            // the wire code) even when the counters below collapse them
            Err(rej) => {
                crate::obs::flight().record(
                    crate::obs::FlightKind::Reject,
                    crate::coordinator::net::code_of(rej).as_u8(),
                    model.min(u16::MAX as usize) as u16,
                    0,
                    0,
                    0,
                );
            }
        }
        match &res {
            Ok(_) => {}
            Err(Rejected::QueueFull { .. }) => {
                self.rejected_full += 1;
                if let Some(o) = obs {
                    o.serve_rejected_full.inc();
                }
            }
            Err(Rejected::ShuttingDown) => {
                self.rejected_shutdown += 1;
                if let Some(o) = obs {
                    o.serve_rejected_shutdown.inc();
                }
            }
            Err(
                Rejected::Quarantined { .. }
                | Rejected::Evicted { .. }
                | Rejected::VersionGone { .. },
            ) => {
                self.rejected_unavailable += 1;
                if let Some(o) = obs {
                    o.serve_rejected_unavailable.inc();
                }
            }
            Err(_) => {
                self.rejected_invalid += 1;
                if let Some(o) = obs {
                    o.serve_rejected_invalid.inc();
                }
            }
        }
        res
    }

    fn admit(
        &mut self,
        model: usize,
        pin: Option<u64>,
        ids: Vec<i32>,
        mask: Vec<f32>,
        deadline: Option<Duration>,
    ) -> Result<u64, Rejected> {
        if self.draining {
            return Err(Rejected::ShuttingDown);
        }
        if model >= self.seqs.len() {
            return Err(Rejected::InvalidRequest(format!(
                "model index {model} out of range ({} registered)",
                self.seqs.len()
            )));
        }
        // lifecycle gate: shed quarantined/evicted targets (and stale
        // version pins) here, where the caller gets a typed verdict,
        // instead of admitting work the backend will only fail later
        if let Ok(st) = self.backend.model_status(model) {
            match st.health {
                ModelHealth::Quarantined => {
                    return Err(Rejected::Quarantined { model: self.labels[model].clone() })
                }
                ModelHealth::Evicted => {
                    return Err(Rejected::Evicted { model: self.labels[model].clone() })
                }
                _ => {}
            }
            if let Some(pinned) = pin {
                if pinned != st.version {
                    return Err(Rejected::VersionGone { pinned, current: st.version });
                }
            }
        }
        if ids.len() != mask.len() {
            return Err(Rejected::InvalidRequest(format!(
                "ids/mask length mismatch ({} vs {})",
                ids.len(),
                mask.len()
            )));
        }
        let len = ids.len();
        if len == 0 || len > self.seqs[model] {
            return Err(Rejected::InvalidRequest(format!(
                "request length {len} out of range 1..={} for model {}",
                self.seqs[model], self.labels[model]
            )));
        }
        // mask finiteness: a NaN/Inf mask row would otherwise surface as
        // a NaN-scale fallback deep in the quantized GEMM path — reject
        // it here, where the caller can be told which request was bad
        if let Some(&bad) = mask.iter().find(|&&m| !m.is_finite()) {
            return Err(Rejected::InvalidRequest(format!(
                "mask contains non-finite value {bad}"
            )));
        }
        let vocab = self.vocabs[model];
        if let Some(&bad) = ids.iter().find(|&&id| id < 0 || id as usize >= vocab) {
            return Err(Rejected::InvalidRequest(format!(
                "token id {bad} out of range for model {} vocab {vocab}",
                self.labels[model]
            )));
        }
        // smallest seq bucket of this model that fits (its last bucket ==
        // its seq, so always found)
        let si = self
            .slots
            .iter()
            .position(|s| s.model == model && s.tcap >= len)
            .expect("every model ends with a full-seq slot");
        let max_pending = self.cfg.max_pending;
        if max_pending > 0 && self.slots[si].q.len() >= max_pending {
            return Err(Rejected::QueueFull { pending: self.slots[si].q.len(), max_pending });
        }
        let now = Instant::now();
        let deadline = deadline.or(self.cfg.default_deadline).map(|d| now + d);
        let id = self.next_id;
        self.next_id += 1;
        let slot = &mut self.slots[si];
        // inter-arrival EWMA feeding the adaptive window close; burst
        // arrivals contribute ~0 gaps that drag the EWMA toward 0, i.e.
        // toward the pure windowed behavior (fast traffic fills buckets,
        // so keep waiting)
        if let Some(prev) = slot.last_arrival {
            let gap_us = now.saturating_duration_since(prev).as_secs_f64() * 1e6;
            slot.ewma_gap_us = if slot.ewma_gap_us > 0.0 {
                0.8 * slot.ewma_gap_us + 0.2 * gap_us
            } else {
                gap_us
            };
        }
        slot.last_arrival = Some(now);
        slot.q.push_back(Request { id, ids, mask, enqueued: now, deadline });
        crate::obs::flight().record(
            crate::obs::FlightKind::Admit,
            0,
            model as u16,
            slot.tcap.min(u16::MAX as usize) as u16,
            *self.cfg.batch_buckets.last().unwrap() as u16,
            id,
        );
        Ok(id)
    }

    /// Model index for a backend label (registry name), if any.
    pub fn find_model(&self, label: &str) -> Option<usize> {
        self.labels.iter().position(|l| l == label)
    }

    /// Serving description of every registered model (what the socket
    /// front door advertises on INFO).
    pub fn model_infos(&self) -> Vec<ModelInfo> {
        (0..self.labels.len())
            .map(|m| {
                let st = self.backend.model_status(m).ok();
                ModelInfo {
                    label: self.labels[m].clone(),
                    vocab: self.vocabs[m],
                    seq: self.seqs[m],
                    n_classes: self.n_classes[m],
                    version: st.as_ref().map_or(0, |s| s.version),
                    health: st.as_ref().map_or(ModelHealth::Serving, |s| s.health),
                    consec_failures: st.as_ref().map_or(0, |s| s.consec_failures),
                }
            })
            .collect()
    }

    /// The backend this server routes to — the lifecycle surface (ADMIN
    /// frame handlers call `reload_model`/`evict_model` through this,
    /// after draining).
    pub fn backend(&self) -> &'b B {
        self.backend
    }

    /// Enter the drain phase of a graceful stop: every subsequent
    /// `submit*` rejects with [`Rejected::ShuttingDown`], while already-
    /// admitted work keeps batching and executing. Irreversible for this
    /// server instance.
    pub fn begin_shutdown(&mut self) {
        self.draining = true;
    }

    pub fn is_draining(&self) -> bool {
        self.draining
    }

    pub fn pending(&self) -> usize {
        self.slots.iter().map(|s| s.q.len()).sum()
    }

    /// Time until the oldest queued request's batch window closes (or
    /// its shed deadline passes, whichever is sooner); `None` when every
    /// queue is empty. This is the front door's `poll(2)` park timeout —
    /// a wakeup heuristic, not a correctness surface: adaptive early
    /// closes may fire sooner, and the event loop re-evaluates the full
    /// policy on every turn.
    pub fn next_fire_in(&self) -> Option<Duration> {
        let now = Instant::now();
        let mut best: Option<Duration> = None;
        for s in &self.slots {
            if let Some(front) = s.q.front() {
                let mut t =
                    (front.enqueued + self.cfg.batch_window).saturating_duration_since(now);
                if let Some(d) = front.deadline {
                    t = t.min(d.saturating_duration_since(now));
                }
                best = Some(best.map_or(t, |b: Duration| b.min(t)));
            }
        }
        best
    }

    /// Shed every queued request whose deadline has passed — *before*
    /// batching, so an expired request never occupies a batch slot. Each
    /// shed request still gets its one `Response`
    /// ([`ResponseBody::Shed`]).
    fn shed_expired(&mut self, now: Instant, out: &mut Vec<Response>) {
        for s in &mut self.slots {
            if !s.q.iter().any(|r| r.deadline.map_or(false, |d| d <= now)) {
                continue;
            }
            let q = std::mem::take(&mut s.q);
            for r in q {
                match r.deadline {
                    Some(d) if d <= now => {
                        let waited_us =
                            now.saturating_duration_since(r.enqueued).as_micros() as u64;
                        self.shed_deadline += 1;
                        if let Some(o) = crate::obs::metrics() {
                            o.serve_shed_deadline.inc();
                        }
                        crate::obs::flight().record(
                            crate::obs::FlightKind::Reject,
                            crate::coordinator::net::RejectCode::DeadlineExceeded.as_u8(),
                            s.model as u16,
                            s.tcap.min(u16::MAX as usize) as u16,
                            0,
                            r.id,
                        );
                        out.push(Response {
                            id: r.id,
                            model: s.model,
                            body: ResponseBody::Shed(Rejected::DeadlineExceeded { waited_us }),
                            queue_us: waited_us as f64,
                            exec_us: 0.0,
                            batch_size: 0,
                            seq_bucket: s.tcap,
                        });
                    }
                    _ => s.q.push_back(r),
                }
            }
        }
    }

    /// Batching policy over the (model × seq) bucket grid. Fires, in
    /// priority order:
    ///   1. **aging**: if any slot's front has waited past the batching
    ///      window, the slot with the globally-oldest expired front, at
    ///      the largest batch bucket `<=` its queue length (padding slots
    ///      if even the smallest batch bucket is short). Expiry outranks
    ///      fullness so a continuously-full bucket under sustained
    ///      short traffic can never starve a long request — or one
    ///      model's traffic another, lightly-loaded model's — every
    ///      admitted request waits at most ~window + one execution. A
    ///      slot's window also closes *early* when its arrival-rate EWMA
    ///      says the remaining window cannot fill the next batch bucket
    ///      (see [`Server::adaptive_expired`]);
    ///   2. otherwise, any slot whose queue fills the largest batch
    ///      bucket (oldest front wins among several), at the largest
    ///      batch — the no-waiting fast path.
    fn pick(&self) -> Option<(usize, usize)> {
        self.pick_with(self.cfg.batch_window)
    }

    /// [`Server::pick`] with an explicit window — `Duration::ZERO`
    /// treats every non-empty slot as expired (the drain/force path).
    fn pick_with(&self, window: Duration) -> Option<(usize, usize)> {
        let mut expired: Option<(usize, Instant)> = None;
        for (si, s) in self.slots.iter().enumerate() {
            if let Some(front) = s.q.front() {
                let fires = front.enqueued.elapsed() >= window
                    || self.adaptive_expired(s, front.enqueued, window);
                if fires && expired.map(|(_, e)| front.enqueued < e).unwrap_or(true) {
                    expired = Some((si, front.enqueued));
                }
            }
        }
        if let Some((si, _)) = expired {
            let n = self.slots[si].q.len();
            let bucket = self
                .cfg
                .batch_buckets
                .iter()
                .copied()
                .filter(|&b| b <= n)
                .max()
                .unwrap_or(self.cfg.batch_buckets[0]);
            return Some((si, bucket));
        }
        let largest = *self.cfg.batch_buckets.last().unwrap();
        let mut full: Option<(usize, Instant)> = None;
        for (si, s) in self.slots.iter().enumerate() {
            if s.q.len() >= largest {
                let front = s.q.front().unwrap().enqueued;
                if full.map(|(_, e)| front < e).unwrap_or(true) {
                    full = Some((si, front));
                }
            }
        }
        full.map(|(si, _)| (si, largest))
    }

    /// Adaptive early close: with `q.len()` requests queued and a
    /// measured inter-arrival EWMA, firing now beats waiting when
    /// `waited + ewma * (next_bucket - q.len()) > window` — the arrivals
    /// needed to reach the next batch bucket won't land before the
    /// window expires anyway, so the extra wait buys only latency. A
    /// slot with an unknown EWMA (or one already at the largest bucket,
    /// which the full fast path handles) never closes early, so burst
    /// and offline-replay traffic keep the exact windowed semantics.
    fn adaptive_expired(&self, s: &Slot, front_enqueued: Instant, window: Duration) -> bool {
        if s.ewma_gap_us <= 0.0 {
            return false;
        }
        let Some(&next) = self.cfg.batch_buckets.iter().find(|&&b| b > s.q.len()) else {
            return false;
        };
        let missing = (next - s.q.len()) as f64;
        let waited_us = front_enqueued.elapsed().as_secs_f64() * 1e6;
        waited_us + s.ewma_gap_us * missing > window.as_secs_f64() * 1e6
    }

    /// One event-loop turn: shed expired requests, then batch + execute
    /// if the policy fires. A backend error **or panic** is isolated to
    /// the one staged batch — its requests get [`ResponseBody::Failed`]
    /// responses and the server keeps serving — so `pump` only errors on
    /// conditions that poison the server itself (currently none).
    pub fn pump(&mut self) -> Result<Vec<Response>> {
        let mut responses = Vec::new();
        self.shed_expired(Instant::now(), &mut responses);
        let Some((si, bucket)) = self.pick() else {
            if let Some(o) = crate::obs::metrics() {
                o.serve_queue_depth.set(self.pending() as u64);
            }
            return Ok(responses);
        };
        let (model, tcap) = (self.slots[si].model, self.slots[si].tcap);
        let take = bucket.min(self.slots[si].q.len());
        let reqs: Vec<Request> =
            (0..take).map(|_| self.slots[si].q.pop_front().unwrap()).collect();

        let stage = bucket * tcap;
        self.ids_stage[..stage].fill(0);
        self.mask_stage[..stage].fill(0.0);
        let mut valid_tokens = 0u64;
        for (i, r) in reqs.iter().enumerate() {
            let len = r.ids.len();
            self.ids_stage[i * tcap..i * tcap + len].copy_from_slice(&r.ids);
            self.mask_stage[i * tcap..i * tcap + len].copy_from_slice(&r.mask);
            valid_tokens += r.mask.iter().filter(|&&m| m == 1.0).count() as u64;
        }

        let exec_start = Instant::now();
        let backend = self.backend;
        let ids = &self.ids_stage[..stage];
        let mask = &self.mask_stage[..stage];
        // AssertUnwindSafe: on unwind the only shared state a forward can
        // leave behind is scratch content in the backend's workspace
        // arena, which every forward fully overwrites for its shape — no
        // logical invariant spans the catch boundary.
        let result = catch_unwind(AssertUnwindSafe(|| {
            backend.serve_forward_for(model, bucket, tcap, ids, mask)
        }));
        let exec_us = exec_start.elapsed().as_secs_f64() * 1e6;

        match result {
            Ok(Ok(logits)) => {
                self.exec_us_total += exec_us;
                self.batch_exec_lat.record(exec_us);
                self.batches += 1;
                self.padded_slots += (bucket - take) as u64;
                self.total_tokens += stage as u64;
                self.padded_tokens += stage as u64 - valid_tokens;
                let obs = crate::obs::metrics();
                if let Some(o) = obs {
                    o.serve_batches.inc();
                    o.serve_total_tokens.add(stage as u64);
                    o.serve_padded_tokens.add(stage as u64 - valid_tokens);
                    o.serve_batch.record(model, tcap, (take * 100 / bucket) as u64, exec_us as u64);
                    o.model_served[model.min(crate::obs::MAX_MODEL_SLOTS - 1)].add(take as u64);
                    o.serve_queue_depth.set(self.pending() as u64);
                }
                crate::obs::flight().record(
                    crate::obs::FlightKind::BatchClose,
                    crate::obs::flight::CLOSE_OK,
                    model as u16,
                    tcap.min(u16::MAX as usize) as u16,
                    take as u16,
                    0,
                );
                let nc = self.n_classes[model];
                for (i, r) in reqs.into_iter().enumerate() {
                    let total_us = r.enqueued.elapsed().as_secs_f64() * 1e6;
                    let queue_us = (total_us - exec_us).max(0.0);
                    self.queue_lat.record(queue_us);
                    self.exec_lat.record(exec_us);
                    self.total_lat.record(total_us);
                    self.served += 1;
                    self.served_by_model[model] += 1;
                    if let Some(o) = obs {
                        o.serve_served.inc();
                        o.stage_queue_us.record(queue_us as u64);
                        o.stage_exec_us.record(exec_us as u64);
                        o.slow_traces.offer(crate::obs::TraceEntry {
                            id: r.id.max(1), // 0 marks an empty ring slot
                            model: model as u16,
                            seq_bucket: tcap as u16,
                            batch_size: bucket as u16,
                            queue_us: queue_us as u64,
                            exec_us: exec_us as u64,
                            total_us: total_us as u64,
                        });
                    }
                    responses.push(Response {
                        id: r.id,
                        model,
                        body: ResponseBody::Logits(logits[i * nc..(i + 1) * nc].to_vec()),
                        queue_us,
                        exec_us,
                        batch_size: bucket,
                        seq_bucket: tcap,
                    });
                }
            }
            Ok(Err(e)) => {
                crate::obs::flight().record(
                    crate::obs::FlightKind::BatchClose,
                    crate::obs::flight::CLOSE_FAILED,
                    model as u16,
                    tcap.min(u16::MAX as usize) as u16,
                    take as u16,
                    0,
                );
                self.fail_batch(&mut responses, reqs, model, bucket, tcap, exec_us, format!("{e:#}"));
            }
            Err(payload) => {
                // errors returned by the forward already count inside the
                // backend; a caught panic bypasses it, so feed the health
                // machine here
                self.backend.record_forward_panic(model);
                crate::obs::flight().record(
                    crate::obs::FlightKind::BatchClose,
                    crate::obs::flight::CLOSE_PANICKED,
                    model as u16,
                    tcap.min(u16::MAX as usize) as u16,
                    take as u16,
                    0,
                );
                self.fail_batch(
                    &mut responses,
                    reqs,
                    model,
                    bucket,
                    tcap,
                    exec_us,
                    format!("backend panicked: {}", panic_message(payload)),
                );
            }
        }
        Ok(responses)
    }

    /// Fan a failed/panicked batch out as per-request error responses —
    /// the batch dies, the server does not.
    #[allow(clippy::too_many_arguments)]
    fn fail_batch(
        &mut self,
        out: &mut Vec<Response>,
        reqs: Vec<Request>,
        model: usize,
        bucket: usize,
        tcap: usize,
        exec_us: f64,
        msg: String,
    ) {
        self.failed_batches += 1;
        for r in reqs {
            let total_us = r.enqueued.elapsed().as_secs_f64() * 1e6;
            self.failed += 1;
            if let Some(o) = crate::obs::metrics() {
                o.serve_failed.inc();
            }
            out.push(Response {
                id: r.id,
                model,
                body: ResponseBody::Failed(msg.clone()),
                queue_us: (total_us - exec_us).max(0.0),
                exec_us,
                batch_size: bucket,
                seq_bucket: tcap,
            });
        }
    }

    /// Stage the next ready batch for off-thread execution, without
    /// executing it. Sheds expired requests into `out` first (exactly
    /// like `pump`), then runs the batching policy (`force` treats every
    /// window as expired — the graceful-stop drain path) and pins the
    /// batch's execution state via [`Backend::dispatch_handle`]. A model
    /// that cannot serve at dispatch time (quarantined/evicted between
    /// admission and staging) fails its batch typed into `out` and the
    /// policy moves on to the next ready bucket. Returns `None` when
    /// nothing is ready — or when the backend does not support
    /// off-thread execution (callers gate on
    /// [`Backend::supports_offthread`]; nothing is dequeued either way).
    pub fn dequeue_work(&mut self, force: bool, out: &mut Vec<Response>) -> Option<WorkItem> {
        self.shed_expired(Instant::now(), out);
        loop {
            let window = if force { Duration::ZERO } else { self.cfg.batch_window };
            let Some((si, bucket)) = self.pick_with(window) else {
                if let Some(o) = crate::obs::metrics() {
                    o.serve_queue_depth.set(self.pending() as u64);
                }
                return None;
            };
            let (model, tcap) = (self.slots[si].model, self.slots[si].tcap);
            let handle = match self.backend.dispatch_handle(model) {
                None => return None,
                Some(h) => h,
            };
            let take = bucket.min(self.slots[si].q.len());
            let reqs: Vec<Request> =
                (0..take).map(|_| self.slots[si].q.pop_front().unwrap()).collect();
            let handle = match handle {
                Ok(h) => h,
                Err(e) => {
                    // shed-at-dispatch: same per-request Failed fan-out as
                    // an inline health-gate error, then try the next bucket
                    crate::obs::flight().record(
                        crate::obs::FlightKind::BatchClose,
                        crate::obs::flight::CLOSE_FAILED,
                        model as u16,
                        tcap.min(u16::MAX as usize) as u16,
                        take as u16,
                        0,
                    );
                    self.fail_batch(out, reqs, model, bucket, tcap, 0.0, format!("{e:#}"));
                    continue;
                }
            };
            let stage = bucket * tcap;
            let (mut ids, mut mask) = self.spare.pop().unwrap_or_default();
            ids.clear();
            ids.resize(stage, 0);
            mask.clear();
            mask.resize(stage, 0.0);
            for (i, r) in reqs.iter().enumerate() {
                let len = r.ids.len();
                ids[i * tcap..i * tcap + len].copy_from_slice(&r.ids);
                mask[i * tcap..i * tcap + len].copy_from_slice(&r.mask);
            }
            self.in_flight += 1;
            if let Some(o) = crate::obs::metrics() {
                o.serve_queue_depth.set(self.pending() as u64);
            }
            crate::obs::flight().record(
                crate::obs::FlightKind::Dispatch,
                0,
                model as u16,
                tcap.min(u16::MAX as usize) as u16,
                take as u16,
                0,
            );
            return Some(WorkItem {
                model,
                bucket,
                tcap,
                reqs,
                ids,
                mask,
                handle,
                staged_at: Instant::now(),
            });
        }
    }

    /// Settle one off-thread batch: the accounting mirror of the three
    /// inline `pump()` outcome arms, plus per-worker observability and
    /// the backend's off-thread health bookkeeping
    /// ([`Backend::record_offthread_outcome`] /
    /// [`Backend::record_forward_panic`]). Staging buffers return to the
    /// spare free list, so steady-state dispatch allocates nothing.
    pub fn complete_work(&mut self, done: WorkDone) -> Vec<Response> {
        let WorkDone {
            model,
            bucket,
            tcap,
            reqs,
            ids,
            mask,
            result,
            panicked,
            exec_us,
            dispatch_wait_us,
            worker,
        } = done;
        self.in_flight -= 1;
        self.spare.push((ids, mask));
        if panicked {
            self.backend.record_forward_panic(model);
        } else {
            self.backend.record_offthread_outcome(model, result.is_ok());
        }
        let mut responses = Vec::new();
        match result {
            Ok(logits) => {
                let take = reqs.len();
                let stage = bucket * tcap;
                let valid_tokens: u64 = reqs
                    .iter()
                    .map(|r| r.mask.iter().filter(|&&m| m == 1.0).count() as u64)
                    .sum();
                self.exec_us_total += exec_us;
                self.batch_exec_lat.record(exec_us);
                self.batches += 1;
                self.padded_slots += (bucket - take) as u64;
                self.total_tokens += stage as u64;
                self.padded_tokens += stage as u64 - valid_tokens;
                let obs = crate::obs::metrics();
                if let Some(o) = obs {
                    o.serve_batches.inc();
                    o.serve_total_tokens.add(stage as u64);
                    o.serve_padded_tokens.add(stage as u64 - valid_tokens);
                    o.serve_batch.record(model, tcap, (take * 100 / bucket) as u64, exec_us as u64);
                    o.model_served[model.min(crate::obs::MAX_MODEL_SLOTS - 1)].add(take as u64);
                    o.serve_queue_depth.set(self.pending() as u64);
                    o.worker_dispatch_wait_us.record(dispatch_wait_us as u64);
                    if worker < crate::obs::MAX_WORKER_SLOTS {
                        o.worker_batches[worker].inc();
                        o.worker_exec_us[worker].record(exec_us as u64);
                    }
                }
                crate::obs::flight().record(
                    crate::obs::FlightKind::BatchClose,
                    crate::obs::flight::CLOSE_OK,
                    model as u16,
                    tcap.min(u16::MAX as usize) as u16,
                    take as u16,
                    0,
                );
                let nc = self.n_classes[model];
                for (i, r) in reqs.into_iter().enumerate() {
                    let total_us = r.enqueued.elapsed().as_secs_f64() * 1e6;
                    let queue_us = (total_us - exec_us).max(0.0);
                    self.queue_lat.record(queue_us);
                    self.exec_lat.record(exec_us);
                    self.total_lat.record(total_us);
                    self.served += 1;
                    self.served_by_model[model] += 1;
                    if let Some(o) = obs {
                        o.serve_served.inc();
                        o.stage_queue_us.record(queue_us as u64);
                        o.stage_exec_us.record(exec_us as u64);
                        o.slow_traces.offer(crate::obs::TraceEntry {
                            id: r.id.max(1), // 0 marks an empty ring slot
                            model: model as u16,
                            seq_bucket: tcap as u16,
                            batch_size: bucket as u16,
                            queue_us: queue_us as u64,
                            exec_us: exec_us as u64,
                            total_us: total_us as u64,
                        });
                    }
                    responses.push(Response {
                        id: r.id,
                        model,
                        body: ResponseBody::Logits(logits[i * nc..(i + 1) * nc].to_vec()),
                        queue_us,
                        exec_us,
                        batch_size: bucket,
                        seq_bucket: tcap,
                    });
                }
            }
            Err(msg) => {
                if let Some(o) = crate::obs::metrics() {
                    o.worker_dispatch_wait_us.record(dispatch_wait_us as u64);
                    if worker < crate::obs::MAX_WORKER_SLOTS {
                        o.worker_batches[worker].inc();
                        o.worker_exec_us[worker].record(exec_us as u64);
                    }
                }
                crate::obs::flight().record(
                    crate::obs::FlightKind::BatchClose,
                    if panicked {
                        crate::obs::flight::CLOSE_PANICKED
                    } else {
                        crate::obs::flight::CLOSE_FAILED
                    },
                    model as u16,
                    tcap.min(u16::MAX as usize) as u16,
                    reqs.len() as u16,
                    0,
                );
                self.fail_batch(&mut responses, reqs, model, bucket, tcap, exec_us, msg);
            }
        }
        responses
    }

    /// Batches dispatched off-thread and not yet settled — the
    /// graceful-stop drain loop waits for this to reach zero.
    pub fn in_flight(&self) -> usize {
        self.in_flight
    }

    /// Drain the queues fully (end of trace). **Total**: every pending
    /// request gets exactly one response — ok, shed, or failed — because
    /// backend faults are isolated per batch inside `pump()`. The
    /// batching window is forced open for the duration and restored
    /// afterwards even if a pump errors.
    pub fn drain(&mut self) -> Result<Vec<Response>> {
        let win = std::mem::replace(&mut self.cfg.batch_window, Duration::ZERO);
        let mut all = vec![];
        while self.pending() > 0 {
            match self.pump() {
                Ok(rs) => all.extend(rs),
                Err(e) => {
                    self.cfg.batch_window = win;
                    return Err(e);
                }
            }
        }
        self.cfg.batch_window = win;
        Ok(all)
    }

    pub fn summary(&self) -> ServerSummary {
        ServerSummary {
            model: self.backend.name(),
            per_model: (0..self.labels.len())
                .map(|m| {
                    let st = self.backend.model_status(m).ok();
                    PerModelSummary {
                        label: self.labels[m].clone(),
                        served: self.served_by_model[m],
                        version: st.as_ref().map_or(0, |s| s.version),
                        health: st.as_ref().map_or(ModelHealth::Serving, |s| s.health),
                        consec_failures: st.as_ref().map_or(0, |s| s.consec_failures),
                    }
                })
                .collect(),
            admitted: self.admitted,
            served: self.served,
            batches: self.batches,
            shed_deadline: self.shed_deadline,
            failed: self.failed,
            failed_batches: self.failed_batches,
            rejected_full: self.rejected_full,
            rejected_invalid: self.rejected_invalid,
            rejected_shutdown: self.rejected_shutdown,
            rejected_unavailable: self.rejected_unavailable,
            padded_slots: self.padded_slots,
            padded_tokens: self.padded_tokens,
            total_tokens: self.total_tokens,
            exec_us_total: self.exec_us_total,
            queue: self.queue_lat.summary(),
            exec: self.exec_lat.summary(),
            batch_exec: self.batch_exec_lat.summary(),
            total: self.total_lat.summary(),
        }
    }
}

/// Render a `catch_unwind` payload (panics carry `&str` or `String`).
pub(crate) fn panic_message(payload: Box<dyn std::any::Any + Send>) -> String {
    if let Some(s) = payload.downcast_ref::<&str>() {
        (*s).to_string()
    } else if let Some(s) = payload.downcast_ref::<String>() {
        s.clone()
    } else {
        "non-string panic payload".to_string()
    }
}

/// Per-model routing + lifecycle snapshot inside a [`ServerSummary`].
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct PerModelSummary {
    pub label: String,
    /// Requests served through this model.
    pub served: u64,
    /// Current lifecycle version (0 if the backend can't report one).
    pub version: u64,
    pub health: ModelHealth,
    pub consec_failures: u32,
}

#[derive(Debug, Clone)]
pub struct ServerSummary {
    pub model: String,
    /// Routing + health per registered model — one entry on
    /// single-model backends.
    pub per_model: Vec<PerModelSummary>,
    pub admitted: u64,
    pub served: u64,
    pub batches: u64,
    pub shed_deadline: u64,
    pub failed: u64,
    pub failed_batches: u64,
    pub rejected_full: u64,
    pub rejected_invalid: u64,
    pub rejected_shutdown: u64,
    pub rejected_unavailable: u64,
    pub padded_slots: u64,
    pub padded_tokens: u64,
    pub total_tokens: u64,
    pub exec_us_total: f64,
    pub queue: LatencySummary,
    pub exec: LatencySummary,
    /// Per-batch execution latency (one sample per executed batch).
    pub batch_exec: LatencySummary,
    pub total: LatencySummary,
}

impl ServerSummary {
    /// Fraction of executed tokens that were padding (slot padding plus
    /// in-sequence padding up to the bucket ceiling).
    pub fn padded_token_fraction(&self) -> f64 {
        self.padded_tokens as f64 / self.total_tokens.max(1) as f64
    }

    /// Backend execution microseconds per 1000 *valid* tokens — a
    /// compute-bound throughput stat (arrival-schedule idle time is
    /// excluded, so "grows = serving got slower" actually holds).
    pub fn exec_us_per_ktok(&self) -> f64 {
        let valid = self.total_tokens.saturating_sub(self.padded_tokens).max(1);
        self.exec_us_total / (valid as f64 / 1000.0)
    }
}

impl std::fmt::Display for ServerSummary {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        writeln!(
            f,
            "[{}] served={} batches={} avg_batch={:.1} padded_slots={} padded_tokens={}/{} ({:.1}%)",
            self.model,
            self.served,
            self.batches,
            self.served as f64 / self.batches.max(1) as f64,
            self.padded_slots,
            self.padded_tokens,
            self.total_tokens,
            100.0 * self.padded_token_fraction(),
        )?;
        if self.shed_deadline
            + self.failed
            + self.rejected_full
            + self.rejected_invalid
            + self.rejected_shutdown
            + self.rejected_unavailable
            > 0
            || self.admitted != self.served
        {
            writeln!(
                f,
                "  robust: admitted={} shed_deadline={} failed={} failed_batches={} rejected_full={} rejected_invalid={} rejected_shutdown={} rejected_unavailable={}",
                self.admitted,
                self.shed_deadline,
                self.failed,
                self.failed_batches,
                self.rejected_full,
                self.rejected_invalid,
                self.rejected_shutdown,
                self.rejected_unavailable,
            )?;
        }
        if self.per_model.len() > 1 {
            let routed: Vec<String> = self
                .per_model
                .iter()
                .map(|pm| format!("{}={} (v{} {})", pm.label, pm.served, pm.version, pm.health.name()))
                .collect();
            writeln!(f, "  routed: {}", routed.join(" "))?;
        }
        writeln!(f, "  queue : {}", self.queue)?;
        writeln!(f, "  exec  : {}", self.exec)?;
        write!(f, "  total : {}", self.total)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::coordinator::faults::FaultPlan;
    use crate::runtime::{NativeBackend, NativeDims, NativeModel};

    fn tiny_backend() -> NativeBackend {
        let dims = NativeDims {
            vocab: 64,
            seq: 8,
            n_layers: 1,
            d_model: 16,
            n_heads: 2,
            d_ff: 32,
            n_classes: 2,
        };
        NativeBackend::with_model(NativeModel::random(dims, &[4], 1))
    }

    fn mk_server(backend: &NativeBackend, batch_buckets: Vec<usize>, window: Duration) -> Server<'_, NativeBackend> {
        Server::new(
            backend,
            ServerConfig {
                batch_buckets,
                seq_buckets: vec![],
                batch_window: window,
                ..Default::default()
            },
        )
        .unwrap()
    }

    fn submit_n(server: &mut Server<'_, NativeBackend>, n: usize) {
        for i in 0..n {
            let ids: Vec<i32> = (0..8).map(|j| ((i + j) % 64) as i32).collect();
            server.submit(ids, vec![1.0; 8]).unwrap();
        }
    }

    #[test]
    fn full_bucket_fires_immediately() {
        let be = tiny_backend();
        let mut s = mk_server(&be, vec![1, 4, 8], Duration::from_secs(60));
        submit_n(&mut s, 8);
        let out = s.pump().unwrap();
        assert_eq!(out.len(), 8);
        assert_eq!(s.padded_slots, 0);
        assert_eq!(s.padded_tokens, 0);
        assert_eq!(s.total_tokens, 64);
        let summary = s.summary();
        assert!(summary.exec_us_total > 0.0);
        assert_eq!(summary.batch_exec.count, 1);
        assert!(summary.exec_us_per_ktok() > 0.0);
        assert!(out.iter().all(|r| r.batch_size == 8 && r.seq_bucket == 8));
        assert!(out.iter().all(|r| {
            r.logits().map_or(false, |l| l.len() == 2 && l.iter().all(|x| x.is_finite()))
        }));
    }

    #[test]
    fn short_queue_waits_for_window() {
        let be = tiny_backend();
        let mut s = mk_server(&be, vec![1, 4, 8], Duration::from_secs(60));
        submit_n(&mut s, 3);
        assert!(s.pump().unwrap().is_empty()); // window still open
        assert_eq!(s.pending(), 3);
    }

    #[test]
    fn window_expiry_pads_to_fitting_bucket() {
        let be = tiny_backend();
        // smallest bucket is 4: three requests + zero-filled padding slot
        let mut s = mk_server(&be, vec![4, 8], Duration::ZERO);
        submit_n(&mut s, 3);
        let out = s.pump().unwrap();
        assert_eq!(out.len(), 3);
        assert_eq!(s.padded_slots, 1);
        assert_eq!(s.padded_tokens, 8); // the empty slot's 8 tokens
        assert!(out.iter().all(|r| r.batch_size == 4));
    }

    #[test]
    fn seq_buckets_group_by_length() {
        let be = tiny_backend();
        let mut s = Server::new(
            &be,
            ServerConfig {
                batch_buckets: vec![2],
                seq_buckets: vec![4, 8],
                batch_window: Duration::from_secs(60),
                ..Default::default()
            },
        )
        .unwrap();
        // two short requests fill the t<=4 bucket; a long one waits alone
        s.submit(vec![1, 2, 3], vec![1.0; 3]).unwrap();
        s.submit(vec![5; 7], vec![1.0; 7]).unwrap();
        s.submit(vec![4, 5], vec![1.0; 2]).unwrap();
        let out = s.pump().unwrap();
        assert_eq!(out.len(), 2, "the short bucket fires full");
        assert!(out.iter().all(|r| r.seq_bucket == 4 && r.batch_size == 2));
        // 2 slots * 4 tokens, 3 + 2 valid
        assert_eq!(s.total_tokens, 8);
        assert_eq!(s.padded_tokens, 3);
        assert_eq!(s.pending(), 1);
        assert!(s.pump().unwrap().is_empty(), "long request still inside its window");
        let rest = s.drain().unwrap();
        assert_eq!(rest.len(), 1);
        assert_eq!(rest[0].seq_bucket, 8);
    }

    #[test]
    fn expired_request_beats_full_bucket_no_starvation() {
        // a continuously-full short bucket must not starve a long request
        // whose batching window has expired: aging outranks fullness.
        let be = tiny_backend();
        let mut s = Server::new(
            &be,
            ServerConfig {
                batch_buckets: vec![1, 2],
                seq_buckets: vec![4, 8],
                batch_window: Duration::from_millis(40),
                ..Default::default()
            },
        )
        .unwrap();
        s.submit(vec![1; 7], vec![1.0; 7]).unwrap(); // long, t<=8 bucket
        std::thread::sleep(Duration::from_millis(60)); // expire its window
        // the short bucket is now full (>= largest batch bucket) but fresh
        s.submit(vec![1, 2], vec![1.0; 2]).unwrap();
        s.submit(vec![3, 4], vec![1.0; 2]).unwrap();
        let out = s.pump().unwrap();
        assert_eq!(out.len(), 1, "the expired long request must fire first");
        assert_eq!(out[0].seq_bucket, 8);
        // next pump serves the full short bucket
        let out = s.pump().unwrap();
        assert_eq!(out.len(), 2);
        assert!(out.iter().all(|r| r.seq_bucket == 4));
    }

    #[test]
    fn short_request_in_full_seq_bucket_pads_to_seq() {
        // without explicit seq buckets, a 3-token request pads to seq=8
        // (the old 1-D behavior) and the padded tokens are accounted
        let be = tiny_backend();
        let mut s = mk_server(&be, vec![1], Duration::ZERO);
        s.submit(vec![1, 2, 3], vec![1.0; 3]).unwrap();
        let out = s.pump().unwrap();
        assert_eq!(out.len(), 1);
        assert_eq!(out[0].seq_bucket, 8);
        assert_eq!(s.padded_tokens, 5);
    }

    #[test]
    fn drain_empties_queue() {
        let be = tiny_backend();
        let mut s = mk_server(&be, vec![1, 4, 8], Duration::from_secs(60));
        submit_n(&mut s, 6);
        let out = s.drain().unwrap();
        assert_eq!(out.len(), 6);
        assert!(out.iter().all(|r| r.is_ok()));
        assert_eq!(s.pending(), 0);
        assert_eq!(s.served, 6);
        // distinct request ids fan back out
        let mut ids: Vec<u64> = out.iter().map(|r| r.id).collect();
        ids.sort_unstable();
        assert_eq!(ids, (0..6).collect::<Vec<_>>());
    }

    #[test]
    fn failed_drain_is_total_and_restores_batch_window() {
        // one poisoned batch must not wedge the drain: every admitted
        // request still gets exactly one response, pending reaches 0, and
        // the batch window comes back.
        let mut be = tiny_backend();
        be.set_faults(FaultPlan::fail_nth(1));
        let mut s = mk_server(&be, vec![1, 4, 8], Duration::from_secs(60));
        submit_n(&mut s, 2);
        let out = s.drain().unwrap();
        assert_eq!(out.len(), 2, "drain is total: one response per admitted request");
        assert_eq!(s.pending(), 0);
        let failed: Vec<&Response> = out.iter().filter(|r| !r.is_ok()).collect();
        assert_eq!(failed.len(), 1, "exactly the first (faulted) batch fails");
        assert!(matches!(&failed[0].body, ResponseBody::Failed(m) if m.contains("injected fault")));
        assert_eq!(s.failed, 1);
        assert_eq!(s.failed_batches, 1);
        assert_eq!(s.served, 1);
        assert_eq!(s.admitted, s.served + s.failed);
        // the window must be back to 60s: a short queue may not fire
        submit_n(&mut s, 3);
        assert!(s.pump().unwrap().is_empty(), "drain failure leaked batch_window = ZERO");
        assert_eq!(s.pending(), 3);
    }

    #[test]
    fn panicking_backend_is_isolated_to_its_batch() {
        let mut be = tiny_backend();
        be.set_faults(FaultPlan::panic_nth(1));
        let mut s = mk_server(&be, vec![1], Duration::ZERO);
        submit_n(&mut s, 2);
        let out = s.pump().unwrap();
        assert_eq!(out.len(), 1);
        assert!(matches!(&out[0].body, ResponseBody::Failed(m) if m.contains("backend panicked")));
        // the server survives and the next batch serves normally
        let out = s.pump().unwrap();
        assert_eq!(out.len(), 1);
        assert!(out[0].is_ok(), "pump after a panic must serve");
        assert_eq!(s.pending(), 0);
        assert_eq!((s.served, s.failed, s.failed_batches), (1, 1, 1));
    }

    #[test]
    fn empty_queue_never_fires() {
        let be = tiny_backend();
        let mut s = mk_server(&be, vec![1, 4, 8], Duration::ZERO);
        assert!(s.pump().unwrap().is_empty());
    }

    #[test]
    fn rejects_misshapen_requests() {
        let be = tiny_backend();
        let mut s = mk_server(&be, vec![1], Duration::ZERO);
        assert!(s.submit(vec![], vec![]).is_err(), "empty request");
        assert!(s.submit(vec![0; 9], vec![1.0; 9]).is_err(), "longer than model seq");
        assert!(s.submit(vec![0; 5], vec![1.0; 4]).is_err(), "ids/mask mismatch");
        assert_eq!(s.rejected_invalid, 3);
        assert_eq!(s.admitted, 0);
        // true-length submission is legal now
        assert!(s.submit(vec![0; 5], vec![1.0; 5]).is_ok());
        assert_eq!(s.admitted, 1);
    }

    #[test]
    fn rejects_non_finite_mask_at_admission() {
        let be = tiny_backend();
        let mut s = mk_server(&be, vec![1], Duration::ZERO);
        for bad in [f32::NAN, f32::INFINITY, f32::NEG_INFINITY] {
            let r = s.submit(vec![1, 2, 3], vec![1.0, bad, 1.0]);
            assert!(
                matches!(r, Err(Rejected::InvalidRequest(ref m)) if m.contains("non-finite")),
                "mask value {bad} must be rejected, got {r:?}"
            );
        }
        assert_eq!(s.rejected_invalid, 3);
        assert_eq!(s.pending(), 0, "rejected requests must not enqueue");
    }

    #[test]
    fn rejects_out_of_range_seq_buckets() {
        let be = tiny_backend();
        for bad in [vec![0usize, 8], vec![4, 9]] {
            let r = Server::new(
                &be,
                ServerConfig {
                    batch_buckets: vec![1],
                    seq_buckets: bad.clone(),
                    batch_window: Duration::ZERO,
                    ..Default::default()
                },
            );
            assert!(r.is_err(), "seq_buckets {bad:?} must be rejected");
        }
    }

    #[test]
    fn rejects_out_of_vocab_ids_at_admission() {
        // vocab violations are an admission-time typed reject now — they
        // never reach (and can never poison) a staged batch.
        let be = tiny_backend();
        let mut s = mk_server(&be, vec![1], Duration::ZERO);
        for ids in [vec![-1; 8], vec![64; 8]] {
            let r = s.submit(ids, vec![1.0; 8]);
            assert!(matches!(r, Err(Rejected::InvalidRequest(ref m)) if m.contains("out of range")));
        }
        assert_eq!(s.pending(), 0);
        assert!(s.pump().unwrap().is_empty());
    }

    #[test]
    fn queue_full_sheds_at_admission() {
        let be = tiny_backend();
        let mut s = Server::new(
            &be,
            ServerConfig {
                batch_buckets: vec![8],
                seq_buckets: vec![],
                batch_window: Duration::from_secs(60),
                max_pending: 2,
                ..Default::default()
            },
        )
        .unwrap();
        submit_n(&mut s, 2);
        let r = s.submit(vec![1; 8], vec![1.0; 8]);
        assert_eq!(r, Err(Rejected::QueueFull { pending: 2, max_pending: 2 }));
        assert_eq!((s.admitted, s.rejected_full), (2, 1));
        assert_eq!(s.pending(), 2, "the bound holds");
        // draining frees the queue: admission works again
        assert_eq!(s.drain().unwrap().len(), 2);
        assert!(s.submit(vec![1; 8], vec![1.0; 8]).is_ok());
    }

    #[test]
    fn expired_deadline_sheds_before_execution() {
        let be = tiny_backend();
        let mut s = mk_server(&be, vec![1], Duration::ZERO);
        s.submit_with(0, vec![1; 8], vec![1.0; 8], Some(Duration::ZERO)).unwrap();
        std::thread::sleep(Duration::from_millis(2));
        let out = s.pump().unwrap();
        assert_eq!(out.len(), 1);
        assert!(
            matches!(out[0].body, ResponseBody::Shed(Rejected::DeadlineExceeded { .. })),
            "expired request must shed, got {:?}",
            out[0].body
        );
        assert_eq!(out[0].batch_size, 0, "a shed request must not occupy a batch slot");
        assert_eq!((s.served, s.shed_deadline), (0, 1));
        assert_eq!(s.batches, 0, "no batch may execute for a fully-shed queue");
        // a fresh deadline-free request still serves
        s.submit(vec![1; 8], vec![1.0; 8]).unwrap();
        let out = s.pump().unwrap();
        assert_eq!(out.len(), 1);
        assert!(out[0].is_ok());
        assert_eq!(s.admitted, s.served + s.shed_deadline);
    }

    #[test]
    fn default_deadline_applies_to_plain_submit() {
        let be = tiny_backend();
        let mut s = Server::new(
            &be,
            ServerConfig {
                batch_buckets: vec![1],
                seq_buckets: vec![],
                batch_window: Duration::ZERO,
                default_deadline: Some(Duration::ZERO),
                ..Default::default()
            },
        )
        .unwrap();
        s.submit(vec![1; 8], vec![1.0; 8]).unwrap();
        std::thread::sleep(Duration::from_millis(2));
        let out = s.pump().unwrap();
        assert_eq!(out.len(), 1);
        assert!(matches!(out[0].body, ResponseBody::Shed(_)));
    }

    #[test]
    fn model_infos_describe_the_backend() {
        let be = tiny_backend();
        let s = mk_server(&be, vec![1], Duration::ZERO);
        let infos = s.model_infos();
        assert_eq!(infos.len(), 1);
        assert_eq!((infos[0].vocab, infos[0].seq, infos[0].n_classes), (64, 8, 2));
        // lifecycle fields come from the backend's status surface: the
        // plain NativeBackend reports a static version-1 Serving model
        assert_eq!(infos[0].version, 1);
        assert_eq!(infos[0].health, ModelHealth::Serving);
        assert_eq!(infos[0].consec_failures, 0);
    }

    #[test]
    fn shutdown_rejects_new_work_but_drains_queued() {
        let be = tiny_backend();
        let mut s = mk_server(&be, vec![1, 4, 8], Duration::from_secs(60));
        submit_n(&mut s, 3);
        s.begin_shutdown();
        assert!(s.is_draining());
        let r = s.submit(vec![1; 8], vec![1.0; 8]);
        assert_eq!(r, Err(Rejected::ShuttingDown));
        assert_eq!(s.rejected_shutdown, 1);
        // already-admitted work still completes — the never-drop contract
        let out = s.drain().unwrap();
        assert_eq!(out.len(), 3);
        assert!(out.iter().all(|r| r.is_ok()));
        assert_eq!(s.admitted, s.served);
        assert_eq!(s.pending(), 0);
    }

    #[test]
    fn version_pins_reject_on_mismatch_only() {
        let be = tiny_backend();
        let mut s = mk_server(&be, vec![1], Duration::ZERO);
        // NativeBackend's lifecycle version is always 1: a matching pin
        // admits, a stale pin is a typed VersionGone
        assert!(s.submit_pinned_to(0, Some(1), vec![1; 8], vec![1.0; 8], None).is_ok());
        let r = s.submit_pinned_to(0, Some(7), vec![1; 8], vec![1.0; 8], None);
        assert_eq!(r, Err(Rejected::VersionGone { pinned: 7, current: 1 }));
        assert_eq!(s.rejected_unavailable, 1);
        assert_eq!(s.admitted, 1);
        let out = s.drain().unwrap();
        assert_eq!(out.len(), 1);
        assert!(out[0].is_ok());
    }

    #[test]
    fn multi_model_server_routes_bit_for_bit() {
        // Two models of different shapes behind one registry-backed
        // server: every response must equal the same request served
        // through a dedicated single-model server, and model indices
        // must fan back out correctly.
        use crate::modelstore::Registry;
        let dims_a = NativeDims {
            vocab: 64, seq: 8, n_layers: 1, d_model: 16, n_heads: 2, d_ff: 32, n_classes: 2,
        };
        let dims_b = NativeDims {
            vocab: 48, seq: 6, n_layers: 2, d_model: 24, n_heads: 3, d_ff: 48, n_classes: 3,
        };
        let mut reg = Registry::new();
        reg.register("a", NativeModel::random(dims_a, &[4], 21)).unwrap();
        reg.register("b", NativeModel::random(dims_b, &[8, 4], 22)).unwrap();
        let cfg = || ServerConfig {
            batch_buckets: vec![1, 2],
            seq_buckets: vec![4],
            batch_window: Duration::ZERO,
            ..Default::default()
        };
        let mut s = Server::new(&reg, cfg()).unwrap();
        assert_eq!(s.find_model("b"), Some(1));
        let reqs: [(usize, Vec<i32>); 4] = [
            (0, vec![1, 2, 3]),
            (1, vec![4, 5]),
            (0, (0..8).collect()),
            (1, vec![7; 6]),
        ];
        for (m, ids) in &reqs {
            let mask = vec![1.0f32; ids.len()];
            s.submit_to(*m, ids.clone(), mask).unwrap();
        }
        // a request longer than the target model's seq is rejected up front
        assert!(s.submit_to(1, vec![0; 7], vec![1.0; 7]).is_err());
        assert!(s.submit_to(9, vec![0; 2], vec![1.0; 2]).is_err());
        let mut out = s.drain().unwrap();
        out.sort_by_key(|r| r.id);
        assert_eq!(out.len(), 4);
        let summary = s.summary();
        let routed: Vec<(&str, u64)> =
            summary.per_model.iter().map(|pm| (pm.label.as_str(), pm.served)).collect();
        assert_eq!(routed, vec![("a", 2u64), ("b", 2u64)]);
        assert!(summary
            .per_model
            .iter()
            .all(|pm| pm.version == 1 && pm.health == ModelHealth::Serving));

        for (i, (m, ids)) in reqs.iter().enumerate() {
            assert_eq!(out[i].model, *m, "response {i} routed to the wrong model");
            // reference: a dedicated single-model server over the same model
            let solo_model = if *m == 0 {
                NativeModel::random(dims_a, &[4], 21)
            } else {
                NativeModel::random(dims_b, &[8, 4], 22)
            };
            let mut solo_reg = Registry::new();
            solo_reg.register("solo", solo_model).unwrap();
            let mut solo = Server::new(&solo_reg, cfg()).unwrap();
            solo.submit(ids.clone(), vec![1.0; ids.len()]).unwrap();
            let want = solo.drain().unwrap().remove(0);
            assert_eq!(out[i].logits(), want.logits(), "request {i}: multi-model logits diverge");
        }
    }

    #[test]
    fn offthread_dequeue_complete_matches_inline_pump() {
        let be = tiny_backend();
        let mut s = mk_server(&be, vec![1, 4, 8], Duration::from_secs(60));
        submit_n(&mut s, 8);
        let mut out = Vec::new();
        let item = s.dequeue_work(false, &mut out).expect("full bucket is ready");
        assert!(out.is_empty());
        assert_eq!(s.in_flight(), 1);
        assert_eq!((item.bucket, item.tcap, item.reqs.len()), (8, 8, 8));
        assert!(item.handle.fault.is_none());
        assert!(s.dequeue_work(false, &mut out).is_none(), "queue is empty while in flight");
        // execute exactly as a worker would: replicated dispatcher, own
        // workspace, the dispatch-pinned version handle
        let disp = be.worker_dispatcher().unwrap();
        let mut ws = crate::runtime::Workspace::new();
        let logits = crate::runtime::backend::native_serve_forward(
            "test-worker",
            &item.handle.version.model,
            &disp,
            &mut ws,
            item.bucket,
            item.tcap,
            &item.ids,
            &item.mask,
        )
        .unwrap();
        let mut got = s.complete_work(WorkDone {
            model: item.model,
            bucket: item.bucket,
            tcap: item.tcap,
            reqs: item.reqs,
            ids: item.ids,
            mask: item.mask,
            result: Ok(logits),
            panicked: false,
            exec_us: 5.0,
            dispatch_wait_us: 1.0,
            worker: 0,
        });
        assert_eq!(s.in_flight(), 0);
        got.sort_by_key(|r| r.id);
        assert_eq!(got.len(), 8);
        assert_eq!((s.served, s.batches), (8, 1));
        assert_eq!(s.admitted, s.served);
        // reference: the same 8 requests through the inline pump
        let be2 = tiny_backend();
        let mut s2 = mk_server(&be2, vec![1, 4, 8], Duration::from_secs(60));
        submit_n(&mut s2, 8);
        let mut want = s2.pump().unwrap();
        want.sort_by_key(|r| r.id);
        for (g, w) in got.iter().zip(want.iter()) {
            assert_eq!(g.logits(), w.logits(), "off-thread logits must match inline bit-for-bit");
        }
    }

    #[test]
    fn offthread_panic_and_error_settle_like_inline() {
        let be = tiny_backend();
        let mut s = mk_server(&be, vec![1], Duration::ZERO);
        submit_n(&mut s, 2);
        let mut out = Vec::new();
        let item = s.dequeue_work(false, &mut out).unwrap();
        let done = s.complete_work(WorkDone {
            model: item.model,
            bucket: item.bucket,
            tcap: item.tcap,
            reqs: item.reqs,
            ids: item.ids,
            mask: item.mask,
            result: Err("backend panicked: injected".into()),
            panicked: true,
            exec_us: 3.0,
            dispatch_wait_us: 1.0,
            worker: 1,
        });
        assert_eq!(done.len(), 1);
        assert!(matches!(&done[0].body, ResponseBody::Failed(m) if m.contains("panicked")));
        assert_eq!((s.failed, s.failed_batches), (1, 1));
        // the second request still serves through the off-thread path
        let item = s.dequeue_work(false, &mut out).unwrap();
        let disp = be.worker_dispatcher().unwrap();
        let mut ws = crate::runtime::Workspace::new();
        let logits = crate::runtime::backend::native_serve_forward(
            "test-worker",
            &item.handle.version.model,
            &disp,
            &mut ws,
            item.bucket,
            item.tcap,
            &item.ids,
            &item.mask,
        )
        .unwrap();
        let done = s.complete_work(WorkDone {
            model: item.model,
            bucket: item.bucket,
            tcap: item.tcap,
            reqs: item.reqs,
            ids: item.ids,
            mask: item.mask,
            result: Ok(logits),
            panicked: false,
            exec_us: 3.0,
            dispatch_wait_us: 1.0,
            worker: 0,
        });
        assert_eq!(done.len(), 1);
        assert!(done[0].is_ok());
        assert_eq!(s.admitted, s.served + s.failed);
        assert_eq!(s.in_flight(), 0);
    }

    #[test]
    fn dispatch_time_unavailability_fails_the_batch_typed() {
        // a model evicted between admission and staging sheds its batch
        // at dispatch with the registry's typed message — no lost
        // requests, no worker round trip
        use crate::modelstore::Registry;
        let dims = NativeDims {
            vocab: 64, seq: 8, n_layers: 1, d_model: 16, n_heads: 2, d_ff: 32, n_classes: 2,
        };
        let mut reg = Registry::new();
        reg.register("m", NativeModel::random(dims, &[4], 3)).unwrap();
        let mut s = Server::new(
            &reg,
            ServerConfig {
                batch_buckets: vec![1],
                seq_buckets: vec![],
                batch_window: Duration::ZERO,
                ..Default::default()
            },
        )
        .unwrap();
        s.submit(vec![1; 8], vec![1.0; 8]).unwrap();
        reg.evict_model_idx(0).unwrap();
        let mut out = Vec::new();
        assert!(s.dequeue_work(false, &mut out).is_none(), "nothing dispatchable remains");
        assert_eq!(out.len(), 1);
        assert!(matches!(&out[0].body, ResponseBody::Failed(m) if m.contains("evicted")));
        assert_eq!((s.failed, s.in_flight()), (1, 0));
        assert_eq!(s.admitted, s.failed);
    }

    #[test]
    fn adaptive_window_closes_early_when_arrivals_lag() {
        let be = tiny_backend();
        let mut s = mk_server(&be, vec![2, 8], Duration::from_millis(10));
        s.submit((0..8).collect(), vec![1.0; 8]).unwrap();
        std::thread::sleep(Duration::from_millis(5));
        s.submit((0..8).collect(), vec![1.0; 8]).unwrap();
        // EWMA says ~5ms/arrival: the 6 more requests the next bucket (8)
        // needs are ~30ms away, far past the 10ms window — close at 2 now
        let out = s.pump().unwrap();
        assert_eq!(out.len(), 2, "the bucket must close early on measured arrival rate");
        assert!(out.iter().all(|r| r.batch_size == 2));
    }

    #[test]
    fn deterministic_given_same_batch() {
        // padding must not perturb real rows: same request alone vs padded
        let be = tiny_backend();
        let mut s1 = mk_server(&be, vec![1], Duration::ZERO);
        submit_n(&mut s1, 1);
        let alone = s1.pump().unwrap().remove(0);
        let mut s4 = mk_server(&be, vec![4], Duration::ZERO);
        submit_n(&mut s4, 1);
        let padded = s4.pump().unwrap().remove(0);
        let (a_l, p_l) = (alone.logits().unwrap(), padded.logits().unwrap());
        for (a, b) in a_l.iter().zip(p_l.iter()) {
            assert!((a - b).abs() < 1e-4, "{a} vs {b}");
        }
    }
}
