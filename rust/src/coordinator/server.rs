//! Inference serving coordinator: request router + dynamic batcher +
//! executor over any [`Backend`] (native kernels or AOT artifacts).
//!
//! The paper's contribution-3 story is *deployment*: int4 layers behind a
//! batched inference service (Table 2 reports per-layer latency at
//! serving batch shapes). This module is the vLLM-router-shaped L3 piece:
//!
//!   * requests arrive with variable valid-token counts;
//!   * the dynamic batcher groups them into the largest available batch
//!     bucket within a bounded batching window;
//!   * the executor runs the backend forward and the router fans
//!     responses back out, recording queue/execute/total latency.
//!
//! Single-threaded event loop by design: both backends already
//! parallelize one execution across cores (the native path via the kernel
//! dispatcher's row-block fan-out), so concurrent executes only thrash;
//! the loop instead overlaps batching with execution completion.
//!
//! §Perf: the batch staging buffers (`ids_stage` / `mask_stage`) persist
//! across pumps — one allocation at server construction, zero on the hot
//! path — and padded slots are zero-filled (an all-zero mask row is fully
//! masked, so its logits are well-defined garbage that is never fanned
//! out) instead of cloning a victim request's tokens.

use std::collections::VecDeque;
use std::time::{Duration, Instant};

use anyhow::{bail, Result};

use crate::runtime::Backend;
use crate::util::stats::{LatencyRecorder, LatencySummary};

#[derive(Debug, Clone)]
pub struct Request {
    pub id: u64,
    pub ids: Vec<i32>,
    pub mask: Vec<f32>,
    pub enqueued: Instant,
}

#[derive(Debug, Clone)]
pub struct Response {
    pub id: u64,
    pub logits: Vec<f32>,
    pub queue_us: f64,
    pub exec_us: f64,
    pub batch_size: usize,
}

pub struct ServerConfig {
    /// Available batch buckets (for the artifact backend these must match
    /// emitted `serve_fwd_b*` executables; the native backend accepts any).
    pub buckets: Vec<usize>,
    /// Max time a request may wait for batchmates.
    pub batch_window: Duration,
}

impl Default for ServerConfig {
    fn default() -> Self {
        ServerConfig { buckets: vec![1, 8, 16], batch_window: Duration::from_micros(500) }
    }
}

pub struct Server<'b, B: Backend> {
    backend: &'b B,
    seq: usize,
    n_classes: usize,
    cfg: ServerConfig,
    queue: VecDeque<Request>,
    next_id: u64,
    ids_stage: Vec<i32>,
    mask_stage: Vec<f32>,
    pub queue_lat: LatencyRecorder,
    pub exec_lat: LatencyRecorder,
    pub total_lat: LatencyRecorder,
    pub served: u64,
    pub batches: u64,
    pub padded_slots: u64,
}

impl<'b, B: Backend> Server<'b, B> {
    pub fn new(backend: &'b B, cfg: ServerConfig) -> Result<Self> {
        let dims = backend.serve_dims()?;
        let mut buckets = cfg.buckets.clone();
        buckets.sort_unstable();
        if buckets.is_empty() {
            bail!("server needs at least one batch bucket");
        }
        for &b in &buckets {
            backend.check_bucket(b)?; // fail fast if a bucket can't execute
        }
        let largest = *buckets.last().unwrap();
        Ok(Server {
            backend,
            seq: dims.seq,
            n_classes: dims.n_classes,
            cfg: ServerConfig { buckets, ..cfg },
            queue: VecDeque::new(),
            next_id: 0,
            ids_stage: Vec::with_capacity(largest * dims.seq),
            mask_stage: Vec::with_capacity(largest * dims.seq),
            queue_lat: LatencyRecorder::new(),
            exec_lat: LatencyRecorder::new(),
            total_lat: LatencyRecorder::new(),
            served: 0,
            batches: 0,
            padded_slots: 0,
        })
    }

    /// Enqueue a tokenized request; returns its id.
    pub fn submit(&mut self, ids: Vec<i32>, mask: Vec<f32>) -> Result<u64> {
        if ids.len() != self.seq || mask.len() != self.seq {
            bail!("request must be padded to seq={} (got {})", self.seq, ids.len());
        }
        let id = self.next_id;
        self.next_id += 1;
        self.queue.push_back(Request { id, ids, mask, enqueued: Instant::now() });
        Ok(id)
    }

    pub fn pending(&self) -> usize {
        self.queue.len()
    }

    /// Batching policy: the largest bucket that is full, or — once the
    /// oldest request has waited past the batching window — the largest
    /// bucket ≤ queue length (padding if even the smallest is short).
    fn pick_bucket(&self) -> Option<usize> {
        let n = self.queue.len();
        if n == 0 {
            return None;
        }
        let largest = *self.cfg.buckets.last().unwrap();
        if n >= largest {
            return Some(largest);
        }
        let waited = self.queue.front().unwrap().enqueued.elapsed();
        if waited < self.cfg.batch_window {
            return None; // keep accumulating batchmates
        }
        Some(
            self.cfg
                .buckets
                .iter()
                .copied()
                .filter(|&b| b <= n)
                .max()
                .unwrap_or(self.cfg.buckets[0]),
        )
    }

    /// One event-loop turn: batch + execute if the policy fires.
    pub fn pump(&mut self) -> Result<Vec<Response>> {
        let Some(bucket) = self.pick_bucket() else {
            return Ok(vec![]);
        };
        let take = bucket.min(self.queue.len());
        let reqs: Vec<Request> = (0..take).map(|_| self.queue.pop_front().unwrap()).collect();
        self.padded_slots += (bucket - take) as u64;

        let t = self.seq;
        self.ids_stage.clear();
        self.ids_stage.resize(bucket * t, 0);
        self.mask_stage.clear();
        self.mask_stage.resize(bucket * t, 0.0);
        for (i, r) in reqs.iter().enumerate() {
            self.ids_stage[i * t..(i + 1) * t].copy_from_slice(&r.ids);
            self.mask_stage[i * t..(i + 1) * t].copy_from_slice(&r.mask);
        }

        let exec_start = Instant::now();
        let logits = self.backend.serve_forward(bucket, &self.ids_stage, &self.mask_stage)?;
        let exec_us = exec_start.elapsed().as_secs_f64() * 1e6;

        self.batches += 1;
        let nc = self.n_classes;
        let mut responses = Vec::with_capacity(take);
        for (i, r) in reqs.into_iter().enumerate() {
            let total_us = r.enqueued.elapsed().as_secs_f64() * 1e6;
            let queue_us = (total_us - exec_us).max(0.0);
            self.queue_lat.record(queue_us);
            self.exec_lat.record(exec_us);
            self.total_lat.record(total_us);
            self.served += 1;
            responses.push(Response {
                id: r.id,
                logits: logits[i * nc..(i + 1) * nc].to_vec(),
                queue_us,
                exec_us,
                batch_size: bucket,
            });
        }
        Ok(responses)
    }

    /// Drain the queue fully (end of trace).
    pub fn drain(&mut self) -> Result<Vec<Response>> {
        let mut all = vec![];
        // Force the window open.
        let win = self.cfg.batch_window;
        self.cfg.batch_window = Duration::ZERO;
        while !self.queue.is_empty() {
            all.extend(self.pump()?);
        }
        self.cfg.batch_window = win;
        Ok(all)
    }

    pub fn summary(&self) -> ServerSummary {
        ServerSummary {
            model: self.backend.name(),
            served: self.served,
            batches: self.batches,
            padded_slots: self.padded_slots,
            queue: self.queue_lat.summary(),
            exec: self.exec_lat.summary(),
            total: self.total_lat.summary(),
        }
    }
}

#[derive(Debug, Clone)]
pub struct ServerSummary {
    pub model: String,
    pub served: u64,
    pub batches: u64,
    pub padded_slots: u64,
    pub queue: LatencySummary,
    pub exec: LatencySummary,
    pub total: LatencySummary,
}

impl std::fmt::Display for ServerSummary {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        writeln!(
            f,
            "[{}] served={} batches={} avg_batch={:.1} padded={}",
            self.model,
            self.served,
            self.batches,
            self.served as f64 / self.batches.max(1) as f64,
            self.padded_slots
        )?;
        writeln!(f, "  queue : {}", self.queue)?;
        writeln!(f, "  exec  : {}", self.exec)?;
        write!(f, "  total : {}", self.total)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::runtime::{NativeBackend, NativeDims, NativeModel};

    fn tiny_backend() -> NativeBackend {
        let dims = NativeDims {
            vocab: 64,
            seq: 8,
            n_layers: 1,
            d_model: 16,
            n_heads: 2,
            d_ff: 32,
            n_classes: 2,
        };
        NativeBackend::with_model(NativeModel::random(dims, &[4], 1))
    }

    fn mk_server(backend: &NativeBackend, buckets: Vec<usize>, window: Duration) -> Server<'_, NativeBackend> {
        Server::new(backend, ServerConfig { buckets, batch_window: window }).unwrap()
    }

    fn submit_n(server: &mut Server<'_, NativeBackend>, n: usize) {
        for i in 0..n {
            let ids: Vec<i32> = (0..8).map(|j| ((i + j) % 64) as i32).collect();
            server.submit(ids, vec![1.0; 8]).unwrap();
        }
    }

    #[test]
    fn full_bucket_fires_immediately() {
        let be = tiny_backend();
        let mut s = mk_server(&be, vec![1, 4, 8], Duration::from_secs(60));
        submit_n(&mut s, 8);
        let out = s.pump().unwrap();
        assert_eq!(out.len(), 8);
        assert_eq!(s.padded_slots, 0);
        assert!(out.iter().all(|r| r.batch_size == 8));
        assert!(out.iter().all(|r| r.logits.len() == 2 && r.logits.iter().all(|x| x.is_finite())));
    }

    #[test]
    fn short_queue_waits_for_window() {
        let be = tiny_backend();
        let mut s = mk_server(&be, vec![1, 4, 8], Duration::from_secs(60));
        submit_n(&mut s, 3);
        assert!(s.pump().unwrap().is_empty()); // window still open
        assert_eq!(s.pending(), 3);
    }

    #[test]
    fn window_expiry_pads_to_fitting_bucket() {
        let be = tiny_backend();
        // smallest bucket is 4: three requests + zero-filled padding slot
        let mut s = mk_server(&be, vec![4, 8], Duration::ZERO);
        submit_n(&mut s, 3);
        let out = s.pump().unwrap();
        assert_eq!(out.len(), 3);
        assert_eq!(s.padded_slots, 1);
        assert!(out.iter().all(|r| r.batch_size == 4));
    }

    #[test]
    fn drain_empties_queue() {
        let be = tiny_backend();
        let mut s = mk_server(&be, vec![1, 4, 8], Duration::from_secs(60));
        submit_n(&mut s, 6);
        let out = s.drain().unwrap();
        assert_eq!(out.len(), 6);
        assert_eq!(s.pending(), 0);
        assert_eq!(s.served, 6);
        // distinct request ids fan back out
        let mut ids: Vec<u64> = out.iter().map(|r| r.id).collect();
        ids.sort_unstable();
        assert_eq!(ids, (0..6).collect::<Vec<_>>());
    }

    #[test]
    fn empty_queue_never_fires() {
        let be = tiny_backend();
        let mut s = mk_server(&be, vec![1, 4, 8], Duration::ZERO);
        assert!(s.pump().unwrap().is_empty());
    }

    #[test]
    fn rejects_misshapen_requests() {
        let be = tiny_backend();
        let mut s = mk_server(&be, vec![1], Duration::ZERO);
        assert!(s.submit(vec![0; 5], vec![1.0; 5]).is_err());
    }

    #[test]
    fn rejects_out_of_vocab_ids() {
        let be = tiny_backend();
        let mut s = mk_server(&be, vec![1], Duration::ZERO);
        s.submit(vec![-1; 8], vec![1.0; 8]).unwrap();
        assert!(s.pump().is_err(), "negative token ids must not serve silently");
    }

    #[test]
    fn deterministic_given_same_batch() {
        // padding must not perturb real rows: same request alone vs padded
        let be = tiny_backend();
        let mut s1 = mk_server(&be, vec![1], Duration::ZERO);
        submit_n(&mut s1, 1);
        let alone = s1.pump().unwrap().remove(0);
        let mut s4 = mk_server(&be, vec![4], Duration::ZERO);
        submit_n(&mut s4, 1);
        let padded = s4.pump().unwrap().remove(0);
        for (a, b) in alone.logits.iter().zip(padded.logits.iter()) {
            assert!((a - b).abs() < 1e-4, "{a} vs {b}");
        }
    }
}
