//! Socket front door: a length-prefixed binary protocol over
//! nonblocking `std::net` TCP (no new crates), feeding the in-process
//! [`Server`] batcher.
//!
//! # Wire protocol (version 1, all integers little-endian)
//!
//! Every message is one frame: a `u32` byte length, then the body. Body
//! byte 0 is the protocol version, byte 1 the message kind:
//!
//! | kind   | code | layout after the 2-byte header                         |
//! |--------|------|--------------------------------------------------------|
//! | REQUEST| 0x01 | tag `u64`, model `u16`, deadline_us `u32` (0 = none), n `u16`, n×`i32` ids, n×`f32` mask, optional version pin `u64` (absent or 0 = unpinned) |
//! | INFO   | 0x02 | (empty)                                                |
//! | ADMIN  | 0x03 | op `u8` ([`AdminOp`]), model `u16`                     |
//! | METRICS| 0x04 | format `u8` (0 = Prometheus text, 1 = JSON), optional window `u32` seconds (absent or 0 = since-start totals, else windowed rates/quantiles from the snapshot ring) |
//! | OK     | 0x81 | tag `u64`, model `u16`, nc `u16`, nc×`f32` logits, req_id `u64` |
//! | REJECT | 0x82 | tag `u64`, code `u8` ([`RejectCode`]), UTF-8 message   |
//! | INFO_RESP | 0x83 | n_models `u16`, then per model: vocab `u32`, seq `u16`, nc `u16`, version `u64`, health `u8`, consec_failures `u32`, label_len `u8`, label bytes; then an optional trailer of n_models `u8` SLO states ([`crate::obs::SloState`]) |
//! | ADMIN_RESP | 0x84 | op `u8`, ok `u8`, model `u16`, then op-specific payload (see [`AdminReply`]) |
//! | METRICS_RESP | 0x85 | format `u8`, len `u32`, len UTF-8 payload bytes  |
//!
//! `tag` is an opaque client-chosen correlation id echoed back verbatim
//! — replies are **not** ordered across in-flight requests on one
//! connection, because the dynamic batcher reorders freely (aging,
//! seq-buckets). Every REQUEST gets exactly one OK or REJECT.
//!
//! ADMIN frames drive the model-fleet lifecycle over the same socket:
//! `RELOAD` and `EVICT` first **drain** the batcher (every admitted
//! request is answered — no batch ever straddles a version swap), then
//! call into the backend's lifecycle surface; `STATUS` is a cheap
//! point-read of one model's version/health/failure counters (plus its
//! SLO state when the server runs with `--slo`); `FLIGHT_DUMP` returns
//! the flight recorder's retained event ring as rendered text — a pure
//! read, no drain barrier.
//!
//! # Failure semantics
//!
//! * Unknown version or undecodable length ⇒ one BadFrame REJECT, then
//!   the read side closes (the stream offset is unrecoverable).
//! * A well-framed but unknown kind ⇒ BadFrame REJECT, connection keeps
//!   going (framing is intact).
//! * Admission rejects ([`Rejected`]) map to typed [`RejectCode`]s and
//!   are sent immediately; deadline sheds and backend failures arrive
//!   asynchronously as REJECTs carrying the same tag.
//! * A client that disconnects with requests in flight just has its
//!   responses dropped (`dropped_responses`); the server never blocks on
//!   a dead peer — writes are nonblocking with per-connection buffers.
//!
//! # Threading
//!
//! The socket plane is single-threaded: [`FrontDoor::poll`] is one turn
//! — accept, read, admit, pump, dispatch, flush, reap — and
//! [`FrontDoor::run`] wraps it with wall-clock/idle exits plus a
//! graceful wind-down that drains the batcher and flushes every reply
//! before closing. With `RunOpts::workers > 1` (and a backend that
//! supports off-thread execution) the *execution* plane moves to a
//! [`crate::coordinator::WorkerPool`]: the front door keeps
//! accept/read/admit/reply but hands ready batches to workers via
//! [`Server::dequeue_work`] and settles them via
//! [`Server::complete_work`], so it keeps admitting and dispatching
//! independent buckets while batches execute. Idle parking uses real
//! `poll(2)` readiness over the listener, every live connection, and a
//! self-pipe ([`WakeHandle`]) that workers ring on batch completion —
//! no fixed sleep on the hot path.

use std::collections::HashMap;
use std::io::{self, Read, Write};
use std::net::{SocketAddr, TcpListener, TcpStream, ToSocketAddrs};
use std::sync::atomic::{AtomicBool, Ordering};
use std::time::{Duration, Instant};

use anyhow::Result;

use crate::coordinator::server::{ModelInfo, Rejected, Response, ResponseBody, Server};
use crate::coordinator::workers::WorkerPool;
use crate::runtime::Backend;

// ---------------------------------------------------------------------
// poll(2) + pipe(2) readiness (raw FFI, no new crates — same idiom as
// the mmap shim in `modelstore::mapped`)
// ---------------------------------------------------------------------

#[cfg(unix)]
mod sys {
    use std::os::raw::{c_int, c_short, c_void};

    #[repr(C)]
    pub struct PollFd {
        pub fd: c_int,
        pub events: c_short,
        pub revents: c_short,
    }

    pub const POLLIN: c_short = 0x001;
    pub const POLLOUT: c_short = 0x004;

    // nfds_t is `unsigned long` on Linux and `unsigned int` on macOS;
    // matching it exactly keeps the ABI honest on both
    #[cfg(target_os = "macos")]
    pub type NfdsT = u32;
    #[cfg(not(target_os = "macos"))]
    pub type NfdsT = std::os::raw::c_ulong;

    pub const F_GETFL: c_int = 3;
    pub const F_SETFL: c_int = 4;
    #[cfg(target_os = "macos")]
    pub const O_NONBLOCK: c_int = 0x0004;
    #[cfg(not(target_os = "macos"))]
    pub const O_NONBLOCK: c_int = 0o4000;

    extern "C" {
        pub fn poll(fds: *mut PollFd, nfds: NfdsT, timeout: c_int) -> c_int;
        pub fn pipe(fds: *mut c_int) -> c_int;
        pub fn fcntl(fd: c_int, cmd: c_int, arg: c_int) -> c_int;
        pub fn read(fd: c_int, buf: *mut c_void, count: usize) -> isize;
        pub fn write(fd: c_int, buf: *const c_void, count: usize) -> isize;
        pub fn close(fd: c_int) -> c_int;
    }

    /// Best-effort `O_NONBLOCK` on an fd (a blocking wake pipe could
    /// stall a worker if the pipe ever filled).
    pub fn set_nonblocking(fd: c_int) {
        // SAFETY: fcntl on an owned, open fd; F_GETFL/F_SETFL take no
        // pointers
        unsafe {
            let flags = fcntl(fd, F_GETFL, 0);
            if flags >= 0 {
                let _ = fcntl(fd, F_SETFL, flags | O_NONBLOCK);
            }
        }
    }
}

/// A self-pipe: execution workers ring it when a batch completes so a
/// `poll(2)`-parked front door wakes immediately instead of waiting out
/// its timeout. Owns both pipe ends; dropping closes them.
struct WakePipe {
    #[cfg(unix)]
    read_fd: i32,
    #[cfg(unix)]
    write_fd: i32,
}

impl WakePipe {
    /// `None` if the pipe can't be created (or on non-unix, where the
    /// run loop falls back to a bounded sleep).
    fn new() -> Option<WakePipe> {
        #[cfg(unix)]
        {
            let mut fds = [-1i32; 2];
            // SAFETY: pipe(2) writes exactly two fds into the array
            let rc = unsafe { sys::pipe(fds.as_mut_ptr()) };
            if rc != 0 {
                return None;
            }
            sys::set_nonblocking(fds[0]);
            sys::set_nonblocking(fds[1]);
            Some(WakePipe { read_fd: fds[0], write_fd: fds[1] })
        }
        #[cfg(not(unix))]
        {
            None
        }
    }

    fn handle(&self) -> WakeHandle {
        #[cfg(unix)]
        {
            WakeHandle { fd: self.write_fd }
        }
        #[cfg(not(unix))]
        {
            WakeHandle::none()
        }
    }

    /// Swallow every queued wake byte (level-triggered poll would
    /// otherwise spin on a non-empty pipe).
    fn drain(&self) {
        #[cfg(unix)]
        {
            let mut buf = [0u8; 64];
            loop {
                // SAFETY: reading into a stack buffer of the stated size
                // from an fd this struct owns
                let n = unsafe {
                    sys::read(self.read_fd, buf.as_mut_ptr() as *mut std::os::raw::c_void, buf.len())
                };
                if n <= 0 {
                    break;
                }
            }
        }
    }
}

impl Drop for WakePipe {
    fn drop(&mut self) {
        #[cfg(unix)]
        // SAFETY: closing fds owned by this struct, exactly once
        unsafe {
            let _ = sys::close(self.read_fd);
            let _ = sys::close(self.write_fd);
        }
    }
}

/// The worker-side end of a [`WakePipe`]: `Copy`, cheap, and safe to
/// ring from any thread. [`WakeHandle::none`] is an inert handle for
/// pools running without a poll-parked front door (tests, non-unix).
#[derive(Debug, Clone, Copy)]
pub struct WakeHandle {
    #[cfg(unix)]
    fd: i32,
}

impl WakeHandle {
    pub fn none() -> WakeHandle {
        #[cfg(unix)]
        {
            WakeHandle { fd: -1 }
        }
        #[cfg(not(unix))]
        {
            WakeHandle {}
        }
    }

    /// Best-effort single-byte write; an error (pipe full, handle gone)
    /// just means the front door wakes on its timeout instead.
    pub fn wake(&self) {
        #[cfg(unix)]
        {
            if self.fd >= 0 {
                let b = [1u8];
                // SAFETY: writing one byte from a stack buffer to a
                // nonblocking fd; failure is ignored by design
                let _ = unsafe {
                    sys::write(self.fd, b.as_ptr() as *const std::os::raw::c_void, 1)
                };
            }
        }
    }
}

pub const PROTO_VERSION: u8 = 1;
/// Largest accepted frame body; anything longer is protocol-fatal.
pub const MAX_FRAME: usize = 1 << 20;

pub const MSG_REQUEST: u8 = 0x01;
pub const MSG_INFO: u8 = 0x02;
pub const MSG_ADMIN: u8 = 0x03;
pub const MSG_METRICS: u8 = 0x04;
pub const MSG_OK: u8 = 0x81;
pub const MSG_REJECT: u8 = 0x82;
pub const MSG_INFO_RESP: u8 = 0x83;
pub const MSG_ADMIN_RESP: u8 = 0x84;
pub const MSG_METRICS_RESP: u8 = 0x85;

/// METRICS format byte: Prometheus text exposition.
pub const METRICS_FMT_TEXT: u8 = 0;
/// METRICS format byte: flat JSON (machine-mergeable; see [`crate::obs`]).
pub const METRICS_FMT_JSON: u8 = 1;

/// Typed reject reasons on the wire.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum RejectCode {
    QueueFull = 1,
    DeadlineExceeded = 2,
    InvalidRequest = 3,
    /// The request's batch failed or panicked in the backend.
    BackendFailed = 4,
    /// Undecodable or protocol-violating frame.
    BadFrame = 5,
    /// Connection limit reached; retry later.
    ServerBusy = 6,
    /// Server is draining for shutdown; no new admissions.
    ShuttingDown = 7,
    /// The pinned model version was swapped out by a reload.
    VersionGone = 8,
    /// Target model is quarantined after repeated forward failures.
    Quarantined = 9,
    /// Target model was evicted; reload to restore it.
    Evicted = 10,
}

impl RejectCode {
    pub fn as_u8(self) -> u8 {
        self as u8
    }

    pub fn from_u8(v: u8) -> Option<Self> {
        match v {
            1 => Some(RejectCode::QueueFull),
            2 => Some(RejectCode::DeadlineExceeded),
            3 => Some(RejectCode::InvalidRequest),
            4 => Some(RejectCode::BackendFailed),
            5 => Some(RejectCode::BadFrame),
            6 => Some(RejectCode::ServerBusy),
            7 => Some(RejectCode::ShuttingDown),
            8 => Some(RejectCode::VersionGone),
            9 => Some(RejectCode::Quarantined),
            10 => Some(RejectCode::Evicted),
            _ => None,
        }
    }
}

/// Mirror one outgoing reject into the per-code metrics series.
fn note_reject(code: RejectCode) {
    if let Some(o) = crate::obs::metrics() {
        o.net_rejects[code.as_u8() as usize].inc();
    }
}

/// Wire reject code for a typed admission verdict (also the `code` of a
/// flight-recorder reject event).
pub(crate) fn code_of(rej: &Rejected) -> RejectCode {
    match rej {
        Rejected::QueueFull { .. } => RejectCode::QueueFull,
        Rejected::DeadlineExceeded { .. } => RejectCode::DeadlineExceeded,
        Rejected::InvalidRequest(_) => RejectCode::InvalidRequest,
        Rejected::ShuttingDown => RejectCode::ShuttingDown,
        Rejected::VersionGone { .. } => RejectCode::VersionGone,
        Rejected::Quarantined { .. } => RejectCode::Quarantined,
        Rejected::Evicted { .. } => RejectCode::Evicted,
    }
}

/// Lifecycle operations carried by ADMIN frames.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum AdminOp {
    /// Drain, then reload the model from its checkpoint source and swap
    /// the new version in.
    Reload = 1,
    /// Drain, then drop the model's loaded weights (name stays
    /// registered; requests shed typed until a reload).
    Evict = 2,
    /// Read one model's version/health/failure counters.
    Status = 3,
    /// Dump the flight recorder's retained event ring (rendered text).
    /// The `model` field is ignored; no drain barrier — a pure read.
    FlightDump = 4,
}

impl AdminOp {
    pub fn as_u8(self) -> u8 {
        self as u8
    }

    pub fn from_u8(v: u8) -> Option<Self> {
        match v {
            1 => Some(AdminOp::Reload),
            2 => Some(AdminOp::Evict),
            3 => Some(AdminOp::Status),
            4 => Some(AdminOp::FlightDump),
            _ => None,
        }
    }
}

// ---------------------------------------------------------------------
// Frame bodies (length prefix is added at send time)
// ---------------------------------------------------------------------

/// Encode a REQUEST body. `deadline_us == 0` means no deadline.
pub fn encode_request(tag: u64, model: u16, deadline_us: u32, ids: &[i32], mask: &[f32]) -> Vec<u8> {
    assert_eq!(ids.len(), mask.len(), "ids/mask length mismatch");
    assert!(ids.len() <= u16::MAX as usize, "request too long for the wire (n is u16)");
    let mut b = Vec::with_capacity(18 + 8 * ids.len());
    b.push(PROTO_VERSION);
    b.push(MSG_REQUEST);
    b.extend_from_slice(&tag.to_le_bytes());
    b.extend_from_slice(&model.to_le_bytes());
    b.extend_from_slice(&deadline_us.to_le_bytes());
    b.extend_from_slice(&(ids.len() as u16).to_le_bytes());
    for &id in ids {
        b.extend_from_slice(&id.to_le_bytes());
    }
    for &m in mask {
        b.extend_from_slice(&m.to_le_bytes());
    }
    b
}

/// [`encode_request`] with a trailing **version pin**: admit only while
/// the target model's lifecycle version is still `pin` (a reload makes
/// it reject with [`RejectCode::VersionGone`]). `pin == 0` is unpinned.
pub fn encode_request_pinned(
    tag: u64,
    model: u16,
    deadline_us: u32,
    pin: u64,
    ids: &[i32],
    mask: &[f32],
) -> Vec<u8> {
    let mut b = encode_request(tag, model, deadline_us, ids, mask);
    b.extend_from_slice(&pin.to_le_bytes());
    b
}

pub fn encode_info_request() -> Vec<u8> {
    vec![PROTO_VERSION, MSG_INFO]
}

/// Encode an ADMIN body targeting one model index.
pub fn encode_admin(op: AdminOp, model: u16) -> Vec<u8> {
    let mut b = vec![PROTO_VERSION, MSG_ADMIN, op.as_u8()];
    b.extend_from_slice(&model.to_le_bytes());
    b
}

/// Encode a METRICS scrape request ([`METRICS_FMT_TEXT`] or
/// [`METRICS_FMT_JSON`]).
pub fn encode_metrics_request(format: u8) -> Vec<u8> {
    vec![PROTO_VERSION, MSG_METRICS, format]
}

/// [`encode_metrics_request`] with a trailing **window** in seconds: the
/// server answers with rates and window-local quantiles over the last
/// `window_secs` from its snapshot ring instead of since-start totals
/// (same old-server-tolerant trailing-field pattern as the REQUEST
/// version pin; `window_secs == 0` is identical to the plain request).
pub fn encode_metrics_request_windowed(format: u8, window_secs: u32) -> Vec<u8> {
    let mut b = encode_metrics_request(format);
    b.extend_from_slice(&window_secs.to_le_bytes());
    b
}

fn encode_metrics_resp(format: u8, payload: &str) -> Vec<u8> {
    let p = payload.as_bytes();
    // MAX_FRAME bounds the reply; a registry render is a few KiB, so a
    // truncation here would mean a protocol-level regression
    let take = p.len().min(MAX_FRAME - 7);
    let mut b = Vec::with_capacity(7 + take);
    b.push(PROTO_VERSION);
    b.push(MSG_METRICS_RESP);
    b.push(format);
    b.extend_from_slice(&(take as u32).to_le_bytes());
    b.extend_from_slice(&p[..take]);
    b
}

fn encode_admin_ok(op: AdminOp, model: u16, payload: &[u8]) -> Vec<u8> {
    let mut b = vec![PROTO_VERSION, MSG_ADMIN_RESP, op.as_u8(), 1];
    b.extend_from_slice(&model.to_le_bytes());
    b.extend_from_slice(payload);
    b
}

fn encode_admin_err(op: u8, model: u16, msg: &str) -> Vec<u8> {
    let msg = msg.as_bytes();
    let take = msg.len().min(512); // bound error payloads like rejects
    let mut b = vec![PROTO_VERSION, MSG_ADMIN_RESP, op, 0];
    b.extend_from_slice(&model.to_le_bytes());
    b.extend_from_slice(&(take as u16).to_le_bytes());
    b.extend_from_slice(&msg[..take]);
    b
}

fn encode_ok(tag: u64, model: u16, logits: &[f32], req_id: u64) -> Vec<u8> {
    let mut b = Vec::with_capacity(22 + 4 * logits.len());
    b.push(PROTO_VERSION);
    b.push(MSG_OK);
    b.extend_from_slice(&tag.to_le_bytes());
    b.extend_from_slice(&model.to_le_bytes());
    b.extend_from_slice(&(logits.len() as u16).to_le_bytes());
    for &l in logits {
        b.extend_from_slice(&l.to_le_bytes());
    }
    // trailing server-assigned request id (same old-client-tolerant
    // pattern as the REQUEST version pin): lets a client join its own
    // latency log against the server's slow-trace ring
    b.extend_from_slice(&req_id.to_le_bytes());
    b
}

fn encode_reject(tag: u64, code: RejectCode, msg: &str) -> Vec<u8> {
    let msg = msg.as_bytes();
    let take = msg.len().min(512); // bound reject payloads
    let mut b = Vec::with_capacity(11 + take);
    b.push(PROTO_VERSION);
    b.push(MSG_REJECT);
    b.extend_from_slice(&tag.to_le_bytes());
    b.push(code.as_u8());
    b.extend_from_slice(&msg[..take]);
    b
}

fn encode_info_resp(models: &[ModelInfo]) -> Vec<u8> {
    let mut b = vec![PROTO_VERSION, MSG_INFO_RESP];
    b.extend_from_slice(&(models.len() as u16).to_le_bytes());
    for m in models {
        b.extend_from_slice(&(m.vocab as u32).to_le_bytes());
        b.extend_from_slice(&(m.seq as u16).to_le_bytes());
        b.extend_from_slice(&(m.n_classes as u16).to_le_bytes());
        b.extend_from_slice(&m.version.to_le_bytes());
        b.push(m.health.as_u8());
        b.extend_from_slice(&m.consec_failures.to_le_bytes());
        let label = m.label.as_bytes();
        let take = label.len().min(u8::MAX as usize);
        b.push(take as u8);
        b.extend_from_slice(&label[..take]);
    }
    // trailing per-model SLO state trailer (one byte each, model order).
    // Old clients parse exactly n records and ignore the tail; new
    // clients read it when present. All zeros unless `--slo` is armed.
    let r = crate::obs::registry();
    for i in 0..models.len() {
        b.push(r.slo_state[i.min(crate::obs::MAX_MODEL_SLOTS - 1)].get() as u8);
    }
    b
}

struct WireRequest {
    tag: u64,
    model: u16,
    deadline_us: u32,
    /// Admission-time version pin (`None` = unpinned).
    pin: Option<u64>,
    ids: Vec<i32>,
    mask: Vec<f32>,
}

fn decode_request(body: &[u8]) -> std::result::Result<WireRequest, String> {
    if body.len() < 18 {
        return Err(format!("request frame too short ({} bytes)", body.len()));
    }
    let tag = u64::from_le_bytes(body[2..10].try_into().unwrap());
    let model = u16::from_le_bytes(body[10..12].try_into().unwrap());
    let deadline_us = u32::from_le_bytes(body[12..16].try_into().unwrap());
    let n = u16::from_le_bytes(body[16..18].try_into().unwrap()) as usize;
    // two accepted layouts: the v1 body, or v1 plus a trailing 8-byte
    // version pin (0 = unpinned) — old clients keep working unchanged
    let pin = match body.len() {
        l if l == 18 + 8 * n => None,
        l if l == 18 + 8 * n + 8 => {
            let off = 18 + 8 * n;
            match u64::from_le_bytes(body[off..off + 8].try_into().unwrap()) {
                0 => None,
                v => Some(v),
            }
        }
        l => {
            return Err(format!(
                "request frame length {l} != {} (or +8 with a version pin) for n={n}",
                18 + 8 * n
            ))
        }
    };
    let mut ids = Vec::with_capacity(n);
    let mut mask = Vec::with_capacity(n);
    let ids_off = 18;
    let mask_off = 18 + 4 * n;
    for i in 0..n {
        let o = ids_off + 4 * i;
        ids.push(i32::from_le_bytes(body[o..o + 4].try_into().unwrap()));
        let o = mask_off + 4 * i;
        mask.push(f32::from_le_bytes(body[o..o + 4].try_into().unwrap()));
    }
    Ok(WireRequest { tag, model, deadline_us, pin, ids, mask })
}

/// One registered model as advertised by INFO_RESP.
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct WireModelInfo {
    pub label: String,
    pub vocab: u32,
    pub seq: u16,
    pub n_classes: u16,
    /// Lifecycle version (bumps on reload).
    pub version: u64,
    /// [`crate::runtime::ModelHealth`] as its wire byte.
    pub health: u8,
    pub consec_failures: u32,
    /// [`crate::obs::SloState`] as its wire byte (0 = Ok; also 0 when
    /// the server predates the trailer or runs without `--slo`).
    pub slo_state: u8,
}

/// Decoded ADMIN_RESP payload.
#[derive(Debug, Clone, PartialEq, Eq)]
pub enum AdminReply {
    Reloaded { old_version: u64, new_version: u64 },
    Evicted { version: u64, freed_bytes: u64 },
    /// `slo_state` is a [`crate::obs::SloState`] wire byte — 0 from
    /// servers that predate it or run without `--slo` (the payload grew
    /// from 21 to 22 bytes; both decode).
    Status { version: u64, health: u8, consec_failures: u32, resident_bytes: u64, slo_state: u8 },
    /// The flight recorder's retained ring, rendered as text.
    FlightDump { text: String },
    /// The operation failed; `msg` is the rendered error chain.
    Err { msg: String },
}

/// A decoded server→client message.
#[derive(Debug, Clone, PartialEq)]
pub enum ClientReply {
    Ok { tag: u64, model: u16, logits: Vec<f32>, req_id: u64 },
    Reject { tag: u64, code: RejectCode, msg: String },
    Info { models: Vec<WireModelInfo> },
    Admin { model: u16, reply: AdminReply },
    /// A METRICS_RESP scrape payload (Prometheus text or JSON).
    Metrics { format: u8, payload: String },
}

fn decode_reply(body: &[u8]) -> std::result::Result<ClientReply, String> {
    if body.len() < 2 {
        return Err("reply frame too short".into());
    }
    if body[0] != PROTO_VERSION {
        return Err(format!("unsupported protocol version {}", body[0]));
    }
    match body[1] {
        MSG_OK => {
            if body.len() < 14 {
                return Err("OK frame too short".into());
            }
            let tag = u64::from_le_bytes(body[2..10].try_into().unwrap());
            let model = u16::from_le_bytes(body[10..12].try_into().unwrap());
            let nc = u16::from_le_bytes(body[12..14].try_into().unwrap()) as usize;
            // two accepted layouts: the v1 body, or v1 plus a trailing
            // 8-byte server request id (0 = unknown) — old servers and
            // captured frames keep decoding unchanged
            let req_id = match body.len() {
                l if l == 14 + 4 * nc => 0,
                l if l == 14 + 4 * nc + 8 => {
                    let off = 14 + 4 * nc;
                    u64::from_le_bytes(body[off..off + 8].try_into().unwrap())
                }
                l => {
                    return Err(format!(
                        "OK frame length {l} != {} (or +8 with a request id) for nc={nc}",
                        14 + 4 * nc
                    ))
                }
            };
            let logits = (0..nc)
                .map(|i| {
                    let o = 14 + 4 * i;
                    f32::from_le_bytes(body[o..o + 4].try_into().unwrap())
                })
                .collect();
            Ok(ClientReply::Ok { tag, model, logits, req_id })
        }
        MSG_REJECT => {
            if body.len() < 11 {
                return Err("REJECT frame too short".into());
            }
            let tag = u64::from_le_bytes(body[2..10].try_into().unwrap());
            let code = RejectCode::from_u8(body[10])
                .ok_or_else(|| format!("unknown reject code {}", body[10]))?;
            let msg = String::from_utf8_lossy(&body[11..]).into_owned();
            Ok(ClientReply::Reject { tag, code, msg })
        }
        MSG_INFO_RESP => {
            if body.len() < 4 {
                return Err("INFO_RESP frame too short".into());
            }
            let n = u16::from_le_bytes(body[2..4].try_into().unwrap()) as usize;
            let mut models = Vec::with_capacity(n);
            let mut off = 4;
            for _ in 0..n {
                if body.len() < off + 22 {
                    return Err("INFO_RESP truncated".into());
                }
                let vocab = u32::from_le_bytes(body[off..off + 4].try_into().unwrap());
                let seq = u16::from_le_bytes(body[off + 4..off + 6].try_into().unwrap());
                let n_classes = u16::from_le_bytes(body[off + 6..off + 8].try_into().unwrap());
                let version = u64::from_le_bytes(body[off + 8..off + 16].try_into().unwrap());
                let health = body[off + 16];
                let consec_failures =
                    u32::from_le_bytes(body[off + 17..off + 21].try_into().unwrap());
                let label_len = body[off + 21] as usize;
                off += 22;
                if body.len() < off + label_len {
                    return Err("INFO_RESP label truncated".into());
                }
                let label = String::from_utf8_lossy(&body[off..off + label_len]).into_owned();
                off += label_len;
                models.push(WireModelInfo {
                    label,
                    vocab,
                    seq,
                    n_classes,
                    version,
                    health,
                    consec_failures,
                    slo_state: 0,
                });
            }
            // optional per-model SLO-state trailer (newer servers)
            if body.len() >= off + n {
                for (i, m) in models.iter_mut().enumerate() {
                    m.slo_state = body[off + i];
                }
            }
            Ok(ClientReply::Info { models })
        }
        MSG_ADMIN_RESP => {
            if body.len() < 6 {
                return Err("ADMIN_RESP frame too short".into());
            }
            let op = body[2];
            let ok = body[3] != 0;
            let model = u16::from_le_bytes(body[4..6].try_into().unwrap());
            let p = &body[6..];
            let reply = if !ok {
                if p.len() < 2 {
                    return Err("ADMIN_RESP error payload truncated".into());
                }
                let take = u16::from_le_bytes(p[..2].try_into().unwrap()) as usize;
                if p.len() != 2 + take {
                    return Err("ADMIN_RESP error message truncated".into());
                }
                AdminReply::Err { msg: String::from_utf8_lossy(&p[2..]).into_owned() }
            } else {
                match AdminOp::from_u8(op) {
                    Some(AdminOp::Reload) if p.len() == 16 => AdminReply::Reloaded {
                        old_version: u64::from_le_bytes(p[..8].try_into().unwrap()),
                        new_version: u64::from_le_bytes(p[8..16].try_into().unwrap()),
                    },
                    Some(AdminOp::Evict) if p.len() == 16 => AdminReply::Evicted {
                        version: u64::from_le_bytes(p[..8].try_into().unwrap()),
                        freed_bytes: u64::from_le_bytes(p[8..16].try_into().unwrap()),
                    },
                    Some(AdminOp::Status) if p.len() == 21 || p.len() == 22 => AdminReply::Status {
                        version: u64::from_le_bytes(p[..8].try_into().unwrap()),
                        health: p[8],
                        consec_failures: u32::from_le_bytes(p[9..13].try_into().unwrap()),
                        resident_bytes: u64::from_le_bytes(p[13..21].try_into().unwrap()),
                        slo_state: if p.len() == 22 { p[21] } else { 0 },
                    },
                    Some(AdminOp::FlightDump) if p.len() >= 4 => {
                        let len = u32::from_le_bytes(p[..4].try_into().unwrap()) as usize;
                        if p.len() != 4 + len {
                            return Err("ADMIN_RESP flight dump truncated".into());
                        }
                        AdminReply::FlightDump {
                            text: String::from_utf8_lossy(&p[4..]).into_owned(),
                        }
                    }
                    _ => {
                        return Err(format!(
                            "ADMIN_RESP op {op} with bad payload length {}",
                            p.len()
                        ))
                    }
                }
            };
            Ok(ClientReply::Admin { model, reply })
        }
        MSG_METRICS_RESP => {
            if body.len() < 7 {
                return Err("METRICS_RESP frame too short".into());
            }
            let format = body[2];
            let len = u32::from_le_bytes(body[3..7].try_into().unwrap()) as usize;
            if body.len() != 7 + len {
                return Err(format!("METRICS_RESP length {} != {}", body.len(), 7 + len));
            }
            let payload = String::from_utf8_lossy(&body[7..]).into_owned();
            Ok(ClientReply::Metrics { format, payload })
        }
        other => Err(format!("unexpected server message kind {other:#04x}")),
    }
}

// ---------------------------------------------------------------------
// Client helpers (blocking; what `mkq-bert loadgen` and tests use)
// ---------------------------------------------------------------------

/// Write one frame (length prefix + body) to a blocking stream.
pub fn send_frame(stream: &mut TcpStream, body: &[u8]) -> io::Result<()> {
    stream.write_all(&(body.len() as u32).to_le_bytes())?;
    stream.write_all(body)
}

/// Read one server reply from a blocking stream.
pub fn read_reply(stream: &mut TcpStream) -> io::Result<ClientReply> {
    let mut len4 = [0u8; 4];
    stream.read_exact(&mut len4)?;
    let len = u32::from_le_bytes(len4) as usize;
    if len == 0 || len > MAX_FRAME {
        return Err(io::Error::new(io::ErrorKind::InvalidData, format!("bad frame length {len}")));
    }
    let mut body = vec![0u8; len];
    stream.read_exact(&mut body)?;
    decode_reply(&body).map_err(|e| io::Error::new(io::ErrorKind::InvalidData, e))
}

// ---------------------------------------------------------------------
// Server side
// ---------------------------------------------------------------------

/// Front-door counters (socket-layer view; the batcher's own accounting
/// lives in [`crate::coordinator::ServerSummary`]).
#[derive(Debug, Clone, Default)]
pub struct NetStats {
    pub accepted: u64,
    /// Connections turned away at the limit (ServerBusy).
    pub rejected_conns: u64,
    pub disconnects: u64,
    pub frames_in: u64,
    pub bad_frames: u64,
    pub ok_out: u64,
    pub reject_out: u64,
    /// Responses whose connection died before dispatch.
    pub dropped_responses: u64,
}

impl std::fmt::Display for NetStats {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        write!(
            f,
            "net: accepted={} rejected_conns={} disconnects={} frames_in={} bad_frames={} ok_out={} reject_out={} dropped={}",
            self.accepted,
            self.rejected_conns,
            self.disconnects,
            self.frames_in,
            self.bad_frames,
            self.ok_out,
            self.reject_out,
            self.dropped_responses,
        )
    }
}

/// One live connection. Two-flag lifecycle: `read_closed` (EOF or
/// protocol-fatal input — stop reading, still flush pending replies),
/// `broken` (write side failed — drop immediately).
struct Conn {
    stream: TcpStream,
    /// Generation counter: slots are reused, so in-flight responses
    /// routed to (slot, gen) can never reach a *different* client that
    /// later landed in the same slot.
    gen: u64,
    rbuf: Vec<u8>,
    wbuf: Vec<u8>,
    wpos: usize,
    read_closed: bool,
    broken: bool,
}

/// Exit conditions for [`FrontDoor::run`].
#[derive(Debug, Clone, Copy, Default)]
pub struct RunOpts {
    /// Stop after this much wall clock (`None` = run until `stop`).
    pub for_secs: Option<f64>,
    /// Stop after this long with no connections, no pending work, and no
    /// socket activity — but only once at least one frame was seen
    /// (smoke tests: "serve one burst, then exit").
    pub idle_exit_secs: Option<f64>,
    /// Print one interval-delta statusline
    /// ([`crate::obs::render_statusline_delta`]) to stderr every this
    /// many seconds (`None` = quiet). Rates and quantiles cover the
    /// interval since the previous line, not since process start.
    pub stats_every_secs: Option<f64>,
    /// Declared SLOs (`--slo p99_us=N,error_pct=X`); evaluated as burn
    /// rates on the ~1 s capture tick when armed. Observe-only.
    pub slo: crate::obs::SloConfig,
    /// Execution worker threads. `0` or `1` keeps the classic inline
    /// single-threaded loop; `N > 1` moves batch execution to a
    /// [`crate::coordinator::WorkerPool`] of `N` threads (each with its
    /// own workspace and dispatcher replica) while the front door keeps
    /// admitting and dispatching concurrently.
    pub workers: usize,
}

/// The nonblocking TCP front door over one [`Server`].
pub struct FrontDoor {
    listener: TcpListener,
    conns: Vec<Option<Conn>>,
    next_gen: u64,
    /// server request id -> (conn slot, conn generation, client tag,
    /// frame-handled instant — the wire-path `stage_total_us` anchor)
    routes: HashMap<u64, (usize, u64, u64, Instant)>,
    stats: NetStats,
    max_conns: usize,
    /// Cleared when a graceful stop begins: existing connections keep
    /// being read (late requests get typed ShuttingDown rejects) but no
    /// new connections are accepted.
    accepting: bool,
    /// Execution workers (`RunOpts::workers > 1`); `None` = inline pump.
    pool: Option<WorkerPool>,
    /// Worker→front-door completion wakeup for `poll(2)` parking.
    wake: Option<WakePipe>,
}

impl FrontDoor {
    pub fn bind<A: ToSocketAddrs>(addr: A) -> io::Result<Self> {
        let listener = TcpListener::bind(addr)?;
        listener.set_nonblocking(true)?;
        Ok(FrontDoor {
            listener,
            conns: Vec::new(),
            next_gen: 0,
            routes: HashMap::new(),
            stats: NetStats::default(),
            max_conns: 256,
            accepting: true,
            pool: None,
            wake: None,
        })
    }

    pub fn local_addr(&self) -> io::Result<SocketAddr> {
        self.listener.local_addr()
    }

    pub fn set_max_conns(&mut self, n: usize) {
        self.max_conns = n.max(1);
    }

    pub fn stats(&self) -> &NetStats {
        &self.stats
    }

    pub fn live_conns(&self) -> usize {
        self.conns.iter().filter(|c| c.is_some()).count()
    }

    /// One event-loop turn: accept, read, admit, pump, dispatch, flush,
    /// reap. Returns whether anything happened (callers sleep briefly on
    /// `false` instead of spinning).
    pub fn poll<B: Backend>(&mut self, server: &mut Server<'_, B>) -> bool {
        let mut progress = self.poll_io(server);
        progress |= self.pump_inline(server);
        progress |= self.flush_and_reap();
        progress
    }

    /// The socket half of one turn — accept, read, admit — with **no**
    /// batch execution. The worker-mode run loop uses this directly and
    /// routes execution through the pool instead of the inline pump.
    fn poll_io<B: Backend>(&mut self, server: &mut Server<'_, B>) -> bool {
        let mut progress = false;

        // accept
        while self.accepting {
            match self.listener.accept() {
                Ok((stream, _peer)) => {
                    progress = true;
                    self.stats.accepted += 1;
                    if let Some(o) = crate::obs::metrics() {
                        o.net_accepted_conns.inc();
                    }
                    if self.live_conns() >= self.max_conns {
                        // best-effort busy notice on the still-blocking
                        // socket, then drop it
                        let mut s = stream;
                        let body = encode_reject(0, RejectCode::ServerBusy, "connection limit reached");
                        let _ = s.write_all(&(body.len() as u32).to_le_bytes());
                        let _ = s.write_all(&body);
                        self.stats.rejected_conns += 1;
                        if let Some(o) = crate::obs::metrics() {
                            o.net_rejected_conns.inc();
                        }
                        note_reject(RejectCode::ServerBusy);
                        continue;
                    }
                    if stream.set_nonblocking(true).is_err() {
                        self.stats.rejected_conns += 1;
                        if let Some(o) = crate::obs::metrics() {
                            o.net_rejected_conns.inc();
                        }
                        continue;
                    }
                    let _ = stream.set_nodelay(true);
                    let gen = self.next_gen;
                    self.next_gen += 1;
                    let conn = Conn {
                        stream,
                        gen,
                        rbuf: Vec::new(),
                        wbuf: Vec::new(),
                        wpos: 0,
                        read_closed: false,
                        broken: false,
                    };
                    match self.conns.iter().position(|c| c.is_none()) {
                        Some(slot) => self.conns[slot] = Some(conn),
                        None => self.conns.push(Some(conn)),
                    }
                }
                Err(e) if e.kind() == io::ErrorKind::WouldBlock => break,
                Err(e) if e.kind() == io::ErrorKind::Interrupted => continue,
                Err(_) => break, // transient accept error; retry next poll
            }
        }

        // read complete frames from every connection first (frame
        // handling needs `&mut server`, reads need `&mut self.conns`)
        let mut frames: Vec<(usize, u64, Vec<u8>)> = Vec::new();
        for slot in 0..self.conns.len() {
            let Some(c) = self.conns[slot].as_mut() else { continue };
            let gen = c.gen;
            let (p, fs) = Self::read_conn(c, &mut self.stats);
            progress |= p;
            for body in fs {
                frames.push((slot, gen, body));
            }
        }

        // handle
        for (slot, gen, body) in frames {
            progress = true;
            self.stats.frames_in += 1;
            if let Some(o) = crate::obs::metrics() {
                o.net_frames_in.inc();
            }
            self.handle_frame(server, slot, gen, &body);
        }

        progress
    }

    /// Pump the batcher until nothing fires, dispatching as we go
    /// (inline execution on the front-door thread).
    fn pump_inline<B: Backend>(&mut self, server: &mut Server<'_, B>) -> bool {
        let mut progress = false;
        loop {
            match server.pump() {
                Ok(rs) => {
                    if rs.is_empty() {
                        break;
                    }
                    progress = true;
                    for r in rs {
                        self.dispatch(r);
                    }
                }
                Err(e) => {
                    // pump() isolates backend faults internally; an error
                    // here is a server-level bug — report and keep the
                    // front door alive
                    crate::log_error!("serve pump error: {e:#}");
                    break;
                }
            }
        }
        progress
    }

    /// Collect finished worker batches and hand newly-ready ones to the
    /// pool (worker mode's counterpart to [`Self::pump_inline`]).
    fn pump_offthread<B: Backend>(&mut self, server: &mut Server<'_, B>) -> bool {
        let Some(pool) = self.pool.as_ref() else { return false };
        let mut progress = false;
        // settle completions first — that frees response routes and may
        // unblock dependent client traffic
        let mut settled = Vec::new();
        while let Some(done) = pool.try_recv() {
            progress = true;
            settled.extend(server.complete_work(done));
        }
        // then dispatch every bucket whose window has closed; sheds
        // (expired deadlines, dispatch-time health gates) come back as
        // immediate responses
        let mut shed = Vec::new();
        while let Some(item) = server.dequeue_work(false, &mut shed) {
            progress = true;
            pool.dispatch(item);
        }
        progress |= !shed.is_empty();
        if let Some(o) = crate::obs::metrics() {
            o.worker_queue_depth.set(pool.queue_depth() as u64);
        }
        for r in settled.into_iter().chain(shed) {
            self.dispatch(r);
        }
        progress
    }

    /// Flush write buffers and reap finished connections.
    fn flush_and_reap(&mut self) -> bool {
        let mut progress = false;
        for slot in 0..self.conns.len() {
            let Some(c) = self.conns[slot].as_mut() else { continue };
            progress |= Self::flush_conn(c);
            let flushed = c.wpos >= c.wbuf.len();
            if c.broken || (c.read_closed && flushed) {
                self.conns[slot] = None;
                self.stats.disconnects += 1;
                if let Some(o) = crate::obs::metrics() {
                    o.net_disconnects.inc();
                }
                progress = true;
            }
        }

        progress
    }

    /// Drive `poll` until a stop/duration/idle condition, then wind down
    /// **gracefully**: stop accepting connections, drain the batcher so
    /// every admitted request is answered, keep reading briefly so
    /// on-the-wire requests get a typed ShuttingDown reject instead of a
    /// silently-closed socket, and flush every reply.
    pub fn run<B: Backend>(
        &mut self,
        server: &mut Server<'_, B>,
        opts: RunOpts,
        stop: Option<&AtomicBool>,
    ) -> Result<()> {
        // grace window: late frames are answered with typed rejects
        const STOP_GRACE: Duration = Duration::from_millis(200);
        // hard cap on the whole stopping phase (a peer that never reads
        // its replies must not hold shutdown hostage)
        const STOP_DEADLINE: Duration = Duration::from_secs(5);

        // spin up the execution pool when asked for and supported; a
        // backend without off-thread execution (the artifact path) just
        // keeps the classic inline loop
        if opts.workers > 1 && self.pool.is_none() && server.backend().supports_offthread() {
            let dispatchers: Vec<_> =
                (0..opts.workers).filter_map(|_| server.backend().worker_dispatcher()).collect();
            if dispatchers.len() == opts.workers {
                self.wake = WakePipe::new();
                let wake = self.wake.as_ref().map_or_else(WakeHandle::none, |w| w.handle());
                crate::log_info!("serving with {} execution workers", dispatchers.len());
                self.pool = Some(WorkerPool::new(dispatchers, wake));
            }
        }
        if let Some(o) = crate::obs::metrics() {
            o.workers_configured.set(self.pool.as_ref().map_or(1, |p| p.len()) as u64);
        }

        // arm declared SLOs so scrapes and wire surfaces can see the
        // objectives even before the first evaluation tick
        if opts.slo.armed() {
            opts.slo.arm();
        }

        let start = Instant::now();
        let mut last_activity = Instant::now();
        let mut had_activity = false;
        let mut stopping_since: Option<Instant> = None;
        let mut last_statusline = Instant::now();
        // unconditional ~1 s snapshot-ring capture tick: windowed scrapes
        // and SLO burns need history whether or not a statusline is on.
        // Seed one capture now so the first windowed scrape has a base.
        const CAPTURE_EVERY: Duration = Duration::from_secs(1);
        crate::obs::snapshots().capture();
        let mut last_capture = Instant::now();
        // statusline deltas are computed against the previous line's
        // snapshot (boxed: SnapData carries three full histogram images)
        let mut statusline_prev: Box<crate::obs::SnapData> = Box::new(crate::obs::live_snapshot());
        loop {
            if last_capture.elapsed() >= CAPTURE_EVERY {
                crate::obs::snapshots().capture();
                if opts.slo.armed() {
                    crate::obs::slo::evaluate(&opts.slo);
                }
                last_capture = Instant::now();
            }
            if let Some(every) = opts.stats_every_secs {
                if last_statusline.elapsed().as_secs_f64() >= every.max(0.01) {
                    let cur = Box::new(crate::obs::live_snapshot());
                    eprintln!("{}", crate::obs::render_statusline_delta(&statusline_prev, &cur));
                    statusline_prev = cur;
                    last_statusline = Instant::now();
                }
            }
            let want_stop = stop.map_or(false, |f| f.load(Ordering::SeqCst))
                || opts.for_secs.map_or(false, |secs| start.elapsed().as_secs_f64() >= secs);
            if want_stop && stopping_since.is_none() {
                stopping_since = Some(Instant::now());
                self.accepting = false;
                server.begin_shutdown();
                // answer everything already admitted; anything arriving
                // past this point rejects with ShuttingDown
                self.drain_through(server);
            }
            let mut progress = self.poll_io(server);
            progress |= if self.pool.is_some() {
                self.pump_offthread(server)
            } else {
                self.pump_inline(server)
            };
            progress |= self.flush_and_reap();
            if progress {
                had_activity = true;
                last_activity = Instant::now();
            }
            match stopping_since {
                Some(t0) => {
                    let flushed = self
                        .conns
                        .iter()
                        .flatten()
                        .all(|c| c.broken || c.wpos >= c.wbuf.len());
                    let settled = server.pending() == 0 && server.in_flight() == 0;
                    if (t0.elapsed() >= STOP_GRACE && settled && flushed)
                        || t0.elapsed() >= STOP_DEADLINE
                    {
                        break;
                    }
                }
                None => {
                    if let Some(idle) = opts.idle_exit_secs {
                        if had_activity
                            && last_activity.elapsed().as_secs_f64() >= idle
                            && server.pending() == 0
                            && server.in_flight() == 0
                            && self.live_conns() == 0
                        {
                            break;
                        }
                    }
                }
            }
            if !progress {
                self.park(server.next_fire_in(), server.in_flight() > 0);
            }
        }
        // wind-down: answer everything still queued or in flight, then
        // flush (a no-op when the stopping phase already drained)
        self.drain_through(server);
        self.flush_all();
        // join the workers so run() returns with no execution threads
        // live (the next run() call re-creates the pool)
        self.pool = None;
        self.wake = None;
        Ok(())
    }

    /// Sleep until socket readiness, a worker-completion wake, or the
    /// next batching deadline — real `poll(2)` on unix, a bounded sleep
    /// elsewhere. `next_fire` is the time until the oldest queued batch
    /// window closes (None = no queued work).
    fn park(&mut self, next_fire: Option<Duration>, in_flight: bool) {
        // sub-millisecond batching deadlines want finer resolution than
        // poll's millisecond timeout: short sleep, re-check
        if let Some(d) = next_fire {
            if d <= Duration::from_millis(1) {
                std::thread::sleep(Duration::from_micros(100));
                return;
            }
        }
        // bounded even with no visible work: the stop flag and
        // wall-clock exits must stay responsive
        let cap: i32 = if next_fire.is_some() || in_flight { 5 } else { 50 };
        let timeout_ms = next_fire.map_or(cap, |d| (d.as_millis() as i32).clamp(1, cap));
        #[cfg(unix)]
        {
            use std::os::unix::io::AsRawFd;
            let mut fds: Vec<sys::PollFd> = Vec::with_capacity(self.conns.len() + 2);
            if self.accepting {
                fds.push(sys::PollFd {
                    fd: self.listener.as_raw_fd(),
                    events: sys::POLLIN,
                    revents: 0,
                });
            }
            if let Some(w) = self.wake.as_ref() {
                fds.push(sys::PollFd { fd: w.read_fd, events: sys::POLLIN, revents: 0 });
            }
            for c in self.conns.iter().flatten() {
                if c.broken {
                    continue;
                }
                let mut ev: std::os::raw::c_short = 0;
                if !c.read_closed {
                    ev |= sys::POLLIN;
                }
                if c.wpos < c.wbuf.len() {
                    ev |= sys::POLLOUT;
                }
                if ev != 0 {
                    fds.push(sys::PollFd { fd: c.stream.as_raw_fd(), events: ev, revents: 0 });
                }
            }
            if fds.is_empty() {
                std::thread::sleep(Duration::from_millis(timeout_ms as u64));
            } else {
                // SAFETY: fds is a live, correctly-typed PollFd array;
                // poll(2) only writes `revents` within its bounds
                let _ =
                    unsafe { sys::poll(fds.as_mut_ptr(), fds.len() as sys::NfdsT, timeout_ms) };
            }
            // swallow queued wake bytes so a level-triggered poll can't
            // spin on a non-empty pipe
            if let Some(w) = self.wake.as_ref() {
                w.drain();
            }
        }
        #[cfg(not(unix))]
        {
            let _ = timeout_ms;
            std::thread::sleep(Duration::from_micros(100));
        }
    }

    fn read_conn(c: &mut Conn, stats: &mut NetStats) -> (bool, Vec<Vec<u8>>) {
        let mut progress = false;
        let mut frames = Vec::new();
        if c.read_closed || c.broken {
            return (progress, frames);
        }
        let mut buf = [0u8; 4096];
        loop {
            match c.stream.read(&mut buf) {
                Ok(0) => {
                    c.read_closed = true;
                    break;
                }
                Ok(n) => {
                    progress = true;
                    if let Some(o) = crate::obs::metrics() {
                        o.net_bytes_in.add(n as u64);
                    }
                    c.rbuf.extend_from_slice(&buf[..n]);
                }
                Err(e) if e.kind() == io::ErrorKind::WouldBlock => break,
                Err(e) if e.kind() == io::ErrorKind::Interrupted => continue,
                Err(_) => {
                    c.broken = true;
                    break;
                }
            }
        }
        loop {
            if c.rbuf.len() < 4 {
                break;
            }
            let len = u32::from_le_bytes(c.rbuf[..4].try_into().unwrap()) as usize;
            if len == 0 || len > MAX_FRAME {
                // undecodable stream offset: protocol-fatal
                stats.bad_frames += 1;
                if let Some(o) = crate::obs::metrics() {
                    o.net_bad_frames.inc();
                }
                c.rbuf.clear();
                c.read_closed = true;
                break;
            }
            if c.rbuf.len() < 4 + len {
                break;
            }
            frames.push(c.rbuf[4..4 + len].to_vec());
            c.rbuf.drain(..4 + len);
        }
        (progress, frames)
    }

    fn handle_frame<B: Backend>(
        &mut self,
        server: &mut Server<'_, B>,
        slot: usize,
        gen: u64,
        body: &[u8],
    ) {
        if body.len() < 2 || body[0] != PROTO_VERSION {
            // version mismatch is unrecoverable for the connection: the
            // peer speaks a different framing dialect
            self.stats.bad_frames += 1;
            if let Some(o) = crate::obs::metrics() {
                o.net_bad_frames.inc();
            }
            let reply = encode_reject(0, RejectCode::BadFrame, "bad or unsupported protocol header");
            if self.push_to(slot, gen, &reply) {
                self.stats.reject_out += 1;
                note_reject(RejectCode::BadFrame);
            }
            self.close_read(slot, gen);
            return;
        }
        match body[1] {
            MSG_REQUEST => match decode_request(body) {
                Ok(w) => {
                    let deadline = if w.deadline_us == 0 {
                        None
                    } else {
                        Some(Duration::from_micros(w.deadline_us as u64))
                    };
                    match server.submit_pinned_to(w.model as usize, w.pin, w.ids, w.mask, deadline)
                    {
                        Ok(id) => {
                            self.routes.insert(id, (slot, gen, w.tag, Instant::now()));
                        }
                        Err(rej) => {
                            let code = code_of(&rej);
                            let reply = encode_reject(w.tag, code, &rej.to_string());
                            if self.push_to(slot, gen, &reply) {
                                self.stats.reject_out += 1;
                                note_reject(code);
                            }
                        }
                    }
                }
                Err(msg) => {
                    self.stats.bad_frames += 1;
                    if let Some(o) = crate::obs::metrics() {
                        o.net_bad_frames.inc();
                    }
                    let tag = if body.len() >= 10 {
                        u64::from_le_bytes(body[2..10].try_into().unwrap())
                    } else {
                        0
                    };
                    let reply = encode_reject(tag, RejectCode::BadFrame, &msg);
                    if self.push_to(slot, gen, &reply) {
                        self.stats.reject_out += 1;
                        note_reject(RejectCode::BadFrame);
                    }
                }
            },
            MSG_INFO => {
                let reply = encode_info_resp(&server.model_infos());
                self.push_to(slot, gen, &reply);
            }
            MSG_ADMIN => self.handle_admin(server, slot, gen, body),
            MSG_METRICS => {
                // scrape: render from the process-wide registry (gating
                // only silences *recording* — a scrape always answers).
                // An optional trailing u32 selects a window in seconds:
                // rates and window-local quantiles from the snapshot
                // ring instead of since-start totals.
                let format = if body.len() >= 3 { body[2] } else { METRICS_FMT_TEXT };
                let window =
                    if body.len() >= 7 { u32::from_le_bytes(body[3..7].try_into().unwrap()) } else { 0 };
                let payload = match (format == METRICS_FMT_JSON, window) {
                    (true, 0) => crate::obs::render_json(),
                    (false, 0) => crate::obs::render_prometheus(),
                    (true, w) => crate::obs::render_window_json(w),
                    (false, w) => crate::obs::render_window(w),
                };
                let reply = encode_metrics_resp(format, &payload);
                self.push_to(slot, gen, &reply);
            }
            other => {
                // framing is intact: reject this message, keep the conn
                self.stats.bad_frames += 1;
                if let Some(o) = crate::obs::metrics() {
                    o.net_bad_frames.inc();
                }
                let reply =
                    encode_reject(0, RejectCode::BadFrame, &format!("unknown message kind {other:#04x}"));
                if self.push_to(slot, gen, &reply) {
                    self.stats.reject_out += 1;
                    note_reject(RejectCode::BadFrame);
                }
            }
        }
    }

    /// One ADMIN frame: the model-fleet lifecycle over the socket.
    /// RELOAD and EVICT **drain first** — every admitted request is
    /// answered under the version it was admitted against before the
    /// swap/drop happens, so in-flight work is never lost and no batch
    /// straddles versions.
    fn handle_admin<B: Backend>(
        &mut self,
        server: &mut Server<'_, B>,
        slot: usize,
        gen: u64,
        body: &[u8],
    ) {
        if body.len() != 5 {
            self.stats.bad_frames += 1;
            if let Some(o) = crate::obs::metrics() {
                o.net_bad_frames.inc();
            }
            let reply = encode_reject(0, RejectCode::BadFrame, "ADMIN frame must be 5 bytes");
            if self.push_to(slot, gen, &reply) {
                self.stats.reject_out += 1;
                note_reject(RejectCode::BadFrame);
            }
            return;
        }
        let op = body[2];
        let model = u16::from_le_bytes(body[3..5].try_into().unwrap());
        let m = model as usize;
        let reply = match AdminOp::from_u8(op) {
            None => encode_admin_err(op, model, &format!("unknown admin op {op}")),
            Some(AdminOp::Status) => match server.backend().model_status(m) {
                Ok(st) => {
                    let mut p = Vec::with_capacity(22);
                    p.extend_from_slice(&st.version.to_le_bytes());
                    p.push(st.health.as_u8());
                    p.extend_from_slice(&st.consec_failures.to_le_bytes());
                    p.extend_from_slice(&(st.resident_bytes as u64).to_le_bytes());
                    // trailing SLO state (0 unless --slo is armed); old
                    // clients decoded exactly 21 bytes and still do
                    let r = crate::obs::registry();
                    p.push(r.slo_state[m.min(crate::obs::MAX_MODEL_SLOTS - 1)].get() as u8);
                    encode_admin_ok(AdminOp::Status, model, &p)
                }
                Err(e) => encode_admin_err(op, model, &format!("{e:#}")),
            },
            Some(AdminOp::FlightDump) => {
                // pure read of the recorder ring — no drain barrier, so a
                // dump mid-incident never perturbs the batcher
                let text = crate::obs::flight::render_text(&crate::obs::flight().snapshot());
                let bytes = text.as_bytes();
                let take = bytes.len().min(MAX_FRAME - 64);
                let mut p = Vec::with_capacity(4 + take);
                p.extend_from_slice(&(take as u32).to_le_bytes());
                p.extend_from_slice(&bytes[..take]);
                encode_admin_ok(AdminOp::FlightDump, model, &p)
            }
            Some(aop) => {
                // Reload/Evict: in-flight barrier first
                self.drain_through(server);
                let res: Result<[u64; 2]> = match aop {
                    AdminOp::Reload => {
                        server.backend().reload_model(m).map(|(old, new)| [old, new])
                    }
                    AdminOp::Evict => {
                        server.backend().evict_model(m).map(|(v, freed)| [v, freed as u64])
                    }
                    AdminOp::Status | AdminOp::FlightDump => unreachable!("handled above"),
                };
                match res {
                    Ok([a, b]) => {
                        let mut p = Vec::with_capacity(16);
                        p.extend_from_slice(&a.to_le_bytes());
                        p.extend_from_slice(&b.to_le_bytes());
                        encode_admin_ok(aop, model, &p)
                    }
                    Err(e) => encode_admin_err(op, model, &format!("{e:#}")),
                }
            }
        };
        self.push_to(slot, gen, &reply);
    }

    /// Drain the batcher and dispatch every response to its connection —
    /// the in-flight-work barrier lifecycle operations run behind. In
    /// worker mode this force-closes every batch window, routes the
    /// batches through the pool, and waits (bounded) for in-flight work
    /// to settle; inline mode executes on this thread as before.
    fn drain_through<B: Backend>(&mut self, server: &mut Server<'_, B>) {
        if self.pool.is_some() {
            let deadline = Instant::now() + Duration::from_secs(5);
            let mut out = Vec::new();
            loop {
                let mut moved = false;
                while let Some(item) = server.dequeue_work(true, &mut out) {
                    moved = true;
                    self.pool.as_ref().expect("pool checked above").dispatch(item);
                }
                if server.in_flight() > 0 {
                    let p = self.pool.as_ref().expect("pool checked above");
                    if let Some(done) = p.recv_timeout(Duration::from_millis(50)) {
                        moved = true;
                        out.extend(server.complete_work(done));
                    }
                }
                if server.pending() == 0 && server.in_flight() == 0 {
                    break;
                }
                if Instant::now() >= deadline {
                    crate::log_error!(
                        "drain barrier timed out with {} batches in flight",
                        server.in_flight()
                    );
                    break;
                }
                if !moved && server.in_flight() == 0 {
                    // nothing dequeues and nothing is in flight — a
                    // server-level bug; don't spin until the deadline
                    crate::log_error!(
                        "drain barrier stuck with {} requests pending",
                        server.pending()
                    );
                    break;
                }
            }
            for r in out {
                self.dispatch(r);
            }
        } else {
            match server.drain() {
                Ok(rs) => {
                    for r in rs {
                        self.dispatch(r);
                    }
                }
                // drain() only errors on server-level bugs; admitted work
                // was still answered per-batch, so report and continue
                Err(e) => crate::log_error!("admin drain error: {e:#}"),
            }
        }
    }

    /// Route one batcher response back to its connection.
    fn dispatch(&mut self, r: Response) {
        let Some((slot, gen, tag, t0)) = self.routes.remove(&r.id) else {
            // not a socket request (locally-submitted trace traffic)
            return;
        };
        if let Some(o) = crate::obs::metrics() {
            // frame-handled → reply-queued: the wire-path total latency
            o.stage_total_us.record(t0.elapsed().as_micros() as u64);
        }
        let is_ok = r.is_ok();
        let mut reject_code = None;
        let reply = match &r.body {
            ResponseBody::Logits(l) => encode_ok(tag, r.model as u16, l, r.id),
            ResponseBody::Shed(rej) => {
                let code = code_of(rej);
                reject_code = Some(code);
                encode_reject(tag, code, &rej.to_string())
            }
            ResponseBody::Failed(msg) => {
                reject_code = Some(RejectCode::BackendFailed);
                encode_reject(tag, RejectCode::BackendFailed, msg)
            }
        };
        if self.push_to(slot, gen, &reply) {
            if is_ok {
                self.stats.ok_out += 1;
            } else {
                self.stats.reject_out += 1;
                if let Some(code) = reject_code {
                    note_reject(code);
                }
            }
        } else {
            self.stats.dropped_responses += 1;
        }
    }

    /// Append one frame to a connection's write buffer if it is still
    /// the same connection and writable.
    fn push_to(&mut self, slot: usize, gen: u64, body: &[u8]) -> bool {
        match self.conns.get_mut(slot).and_then(|c| c.as_mut()) {
            Some(c) if c.gen == gen && !c.broken => {
                c.wbuf.extend_from_slice(&(body.len() as u32).to_le_bytes());
                c.wbuf.extend_from_slice(body);
                if let Some(o) = crate::obs::metrics() {
                    o.net_frames_out.inc();
                    o.net_bytes_out.add(4 + body.len() as u64);
                }
                true
            }
            _ => false,
        }
    }

    fn close_read(&mut self, slot: usize, gen: u64) {
        if let Some(c) = self.conns.get_mut(slot).and_then(|c| c.as_mut()) {
            if c.gen == gen {
                c.read_closed = true;
            }
        }
    }

    fn flush_conn(c: &mut Conn) -> bool {
        let mut progress = false;
        if c.broken {
            return progress;
        }
        while c.wpos < c.wbuf.len() {
            match c.stream.write(&c.wbuf[c.wpos..]) {
                Ok(0) => {
                    c.broken = true;
                    break;
                }
                Ok(n) => {
                    c.wpos += n;
                    progress = true;
                }
                Err(e) if e.kind() == io::ErrorKind::WouldBlock => break,
                Err(e) if e.kind() == io::ErrorKind::Interrupted => continue,
                Err(_) => {
                    c.broken = true;
                    break;
                }
            }
        }
        if c.wpos > 0 && c.wpos >= c.wbuf.len() {
            c.wbuf.clear();
            c.wpos = 0;
        }
        progress
    }

    /// Bounded best-effort flush of every connection (wind-down path).
    fn flush_all(&mut self) {
        let deadline = Instant::now() + Duration::from_millis(250);
        loop {
            let mut pending = false;
            let mut progress = false;
            for slot in 0..self.conns.len() {
                if let Some(c) = self.conns[slot].as_mut() {
                    progress |= Self::flush_conn(c);
                    if !c.broken && c.wpos < c.wbuf.len() {
                        pending = true;
                    }
                }
            }
            if !pending || Instant::now() >= deadline {
                break;
            }
            if !progress {
                std::thread::sleep(Duration::from_millis(1));
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::runtime::ModelHealth;

    #[test]
    fn request_round_trips() {
        let ids = vec![3i32, 1, 4, 1, 5];
        let mask = vec![1.0f32, 1.0, 1.0, 0.5, 0.0];
        let body = encode_request(0xdead_beef_cafe, 2, 1500, &ids, &mask);
        assert_eq!(body.len(), 18 + 8 * ids.len());
        assert_eq!((body[0], body[1]), (PROTO_VERSION, MSG_REQUEST));
        let w = decode_request(&body).unwrap();
        assert_eq!(w.tag, 0xdead_beef_cafe);
        assert_eq!(w.model, 2);
        assert_eq!(w.deadline_us, 1500);
        assert_eq!(w.pin, None);
        assert_eq!(w.ids, ids);
        assert_eq!(w.mask, mask);
    }

    #[test]
    fn pinned_request_round_trips_and_zero_pin_is_unpinned() {
        let ids = vec![1i32, 2];
        let mask = vec![1.0f32, 1.0];
        let body = encode_request_pinned(5, 0, 0, 3, &ids, &mask);
        assert_eq!(body.len(), 18 + 8 * ids.len() + 8);
        let w = decode_request(&body).unwrap();
        assert_eq!(w.pin, Some(3));
        assert_eq!(w.ids, ids);
        // pin 0 decodes as unpinned — old-client semantics
        let body = encode_request_pinned(5, 0, 0, 0, &ids, &mask);
        assert_eq!(decode_request(&body).unwrap().pin, None);
        // a half-written pin is a framing error
        let mut body = encode_request_pinned(5, 0, 0, 3, &ids, &mask);
        body.pop();
        assert!(decode_request(&body).is_err());
    }

    #[test]
    fn request_decode_rejects_bad_lengths() {
        assert!(decode_request(&[PROTO_VERSION, MSG_REQUEST]).is_err(), "short header");
        let mut body = encode_request(1, 0, 0, &[1, 2, 3], &[1.0, 1.0, 1.0]);
        body.pop();
        assert!(decode_request(&body).is_err(), "truncated payload");
        let mut body = encode_request(1, 0, 0, &[1, 2, 3], &[1.0, 1.0, 1.0]);
        body.push(0);
        assert!(decode_request(&body).is_err(), "trailing bytes");
    }

    #[test]
    fn ok_reply_round_trips() {
        let body = encode_ok(77, 1, &[0.25, -1.5], 42);
        match decode_reply(&body).unwrap() {
            ClientReply::Ok { tag, model, logits, req_id } => {
                assert_eq!((tag, model, req_id), (77, 1, 42));
                assert_eq!(logits, vec![0.25, -1.5]);
            }
            other => panic!("expected Ok, got {other:?}"),
        }
    }

    #[test]
    fn ok_reply_without_request_id_still_decodes() {
        // a pre-request-id OK frame (no trailing u64) decodes with
        // req_id 0 — captured traffic and old servers keep working
        let body = encode_ok(5, 0, &[1.0, 2.0, 3.0], 9);
        let legacy = &body[..body.len() - 8];
        match decode_reply(legacy).unwrap() {
            ClientReply::Ok { tag, logits, req_id, .. } => {
                assert_eq!((tag, req_id), (5, 0));
                assert_eq!(logits.len(), 3);
            }
            other => panic!("expected Ok, got {other:?}"),
        }
        // a half-written request id is a framing error
        let mut bad = body.clone();
        bad.pop();
        assert!(decode_reply(&bad).is_err());
    }

    #[cfg(unix)]
    #[test]
    fn wake_pipe_rings_and_drains() {
        let pipe = WakePipe::new().expect("pipe(2) works on unix");
        let h = pipe.handle();
        h.wake();
        h.wake();
        let mut fds =
            [sys::PollFd { fd: pipe.read_fd, events: sys::POLLIN, revents: 0 }];
        // SAFETY: one live PollFd, zero timeout
        let n = unsafe { sys::poll(fds.as_mut_ptr(), 1, 0) };
        assert_eq!(n, 1, "wake byte makes the read end readable");
        pipe.drain();
        let mut fds =
            [sys::PollFd { fd: pipe.read_fd, events: sys::POLLIN, revents: 0 }];
        // SAFETY: as above
        let n = unsafe { sys::poll(fds.as_mut_ptr(), 1, 0) };
        assert_eq!(n, 0, "drained pipe is no longer readable");
        // an inert handle is a no-op, not a crash
        WakeHandle::none().wake();
    }

    #[test]
    fn run_opts_default_is_inline() {
        let opts = RunOpts::default();
        assert!(opts.workers <= 1, "default RunOpts must keep the inline loop");
    }

    #[test]
    fn reject_reply_round_trips_with_code() {
        let rej = Rejected::QueueFull { pending: 8, max_pending: 8 };
        let body = encode_reject(9, code_of(&rej), &rej.to_string());
        match decode_reply(&body).unwrap() {
            ClientReply::Reject { tag, code, msg } => {
                assert_eq!(tag, 9);
                assert_eq!(code, RejectCode::QueueFull);
                assert!(msg.contains("queue full"));
            }
            other => panic!("expected Reject, got {other:?}"),
        }
    }

    #[test]
    fn info_resp_round_trips() {
        let models = vec![
            ModelInfo {
                label: "sst2".into(),
                vocab: 30522,
                seq: 128,
                n_classes: 2,
                version: 3,
                health: ModelHealth::Serving,
                consec_failures: 0,
            },
            ModelInfo {
                label: "mnli".into(),
                vocab: 30522,
                seq: 64,
                n_classes: 3,
                version: 1,
                health: ModelHealth::Quarantined,
                consec_failures: 5,
            },
        ];
        let body = encode_info_resp(&models);
        match decode_reply(&body).unwrap() {
            ClientReply::Info { models: got } => {
                assert_eq!(got.len(), 2);
                assert_eq!(got[0].label, "sst2");
                assert_eq!((got[0].vocab, got[0].seq, got[0].n_classes), (30522, 128, 2));
                assert_eq!(got[0].version, 3);
                assert_eq!(got[0].health, ModelHealth::Serving.as_u8());
                assert_eq!(got[1].label, "mnli");
                assert_eq!(got[1].seq, 64);
                assert_eq!(got[1].health, ModelHealth::Quarantined.as_u8());
                assert_eq!(got[1].consec_failures, 5);
            }
            other => panic!("expected Info, got {other:?}"),
        }
    }

    #[test]
    fn admin_frames_round_trip() {
        let body = encode_admin(AdminOp::Reload, 2);
        assert_eq!(body.len(), 5);
        assert_eq!((body[0], body[1], body[2]), (PROTO_VERSION, MSG_ADMIN, 1));
        assert_eq!(u16::from_le_bytes(body[3..5].try_into().unwrap()), 2);

        let ok = encode_admin_ok(AdminOp::Reload, 2, &{
            let mut p = Vec::new();
            p.extend_from_slice(&4u64.to_le_bytes());
            p.extend_from_slice(&5u64.to_le_bytes());
            p
        });
        assert_eq!(
            decode_reply(&ok).unwrap(),
            ClientReply::Admin {
                model: 2,
                reply: AdminReply::Reloaded { old_version: 4, new_version: 5 }
            }
        );

        let ok = encode_admin_ok(AdminOp::Evict, 0, &{
            let mut p = Vec::new();
            p.extend_from_slice(&7u64.to_le_bytes());
            p.extend_from_slice(&123_456u64.to_le_bytes());
            p
        });
        assert_eq!(
            decode_reply(&ok).unwrap(),
            ClientReply::Admin {
                model: 0,
                reply: AdminReply::Evicted { version: 7, freed_bytes: 123_456 }
            }
        );

        // legacy 21-byte status payload (no SLO trailer) decodes with
        // slo_state 0
        let ok = encode_admin_ok(AdminOp::Status, 1, &{
            let mut p = Vec::new();
            p.extend_from_slice(&2u64.to_le_bytes());
            p.push(ModelHealth::Degraded.as_u8());
            p.extend_from_slice(&3u32.to_le_bytes());
            p.extend_from_slice(&9_000u64.to_le_bytes());
            p
        });
        assert_eq!(
            decode_reply(&ok).unwrap(),
            ClientReply::Admin {
                model: 1,
                reply: AdminReply::Status {
                    version: 2,
                    health: ModelHealth::Degraded.as_u8(),
                    consec_failures: 3,
                    resident_bytes: 9_000,
                    slo_state: 0,
                }
            }
        );

        // current 22-byte status payload carries the SLO state
        let ok = encode_admin_ok(AdminOp::Status, 1, &{
            let mut p = Vec::new();
            p.extend_from_slice(&2u64.to_le_bytes());
            p.push(ModelHealth::Serving.as_u8());
            p.extend_from_slice(&0u32.to_le_bytes());
            p.extend_from_slice(&9_000u64.to_le_bytes());
            p.push(crate::obs::SloState::Burning.as_u8());
            p
        });
        match decode_reply(&ok).unwrap() {
            ClientReply::Admin { reply: AdminReply::Status { slo_state, .. }, .. } => {
                assert_eq!(slo_state, crate::obs::SloState::Burning.as_u8());
            }
            other => panic!("expected Status, got {other:?}"),
        }

        let err = encode_admin_err(AdminOp::Reload.as_u8(), 3, "no checkpoint source");
        match decode_reply(&err).unwrap() {
            ClientReply::Admin { model: 3, reply: AdminReply::Err { msg } } => {
                assert!(msg.contains("no checkpoint source"));
            }
            other => panic!("expected Admin Err, got {other:?}"),
        }

        // truncated payloads are decode errors, not garbage replies
        let mut bad = encode_admin_ok(AdminOp::Reload, 2, &[0u8; 16]);
        bad.pop();
        assert!(decode_reply(&bad).is_err());
    }

    #[test]
    fn flight_dump_frames_round_trip() {
        let req = encode_admin(AdminOp::FlightDump, 0);
        assert_eq!(req.len(), 5, "flight-dump request is a plain 5-byte ADMIN frame");
        assert_eq!(req[2], 4);
        assert_eq!(AdminOp::from_u8(4), Some(AdminOp::FlightDump));

        let text = "[flight] 2 events retained (ring capacity 1024)\n";
        let ok = encode_admin_ok(AdminOp::FlightDump, 0, &{
            let mut p = Vec::new();
            p.extend_from_slice(&(text.len() as u32).to_le_bytes());
            p.extend_from_slice(text.as_bytes());
            p
        });
        assert_eq!(
            decode_reply(&ok).unwrap(),
            ClientReply::Admin { model: 0, reply: AdminReply::FlightDump { text: text.into() } }
        );

        // a truncated dump payload is a decode error
        let mut bad = ok.clone();
        bad.pop();
        assert!(decode_reply(&bad).is_err());
    }

    #[test]
    fn info_resp_slo_trailer_is_old_client_tolerant() {
        let models = vec![ModelInfo {
            label: "sst2".into(),
            vocab: 30522,
            seq: 128,
            n_classes: 2,
            version: 1,
            health: ModelHealth::Serving,
            consec_failures: 0,
        }];
        let body = encode_info_resp(&models);
        // the trailer is exactly n_models bytes past the records; strip
        // it to simulate an old server's frame
        let legacy = &body[..body.len() - models.len()];
        match decode_reply(legacy).unwrap() {
            ClientReply::Info { models: got } => {
                assert_eq!(got[0].label, "sst2");
                assert_eq!(got[0].slo_state, 0, "missing trailer decodes as Ok");
            }
            other => panic!("expected Info, got {other:?}"),
        }
        // the full frame decodes the trailer byte (whatever the shared
        // registry gauge currently holds — a valid wire state)
        match decode_reply(&body).unwrap() {
            ClientReply::Info { models: got } => assert!(got[0].slo_state <= 2),
            other => panic!("expected Info, got {other:?}"),
        }
    }

    #[test]
    fn reply_decode_rejects_garbage() {
        assert!(decode_reply(&[]).is_err());
        assert!(decode_reply(&[9, MSG_OK]).is_err(), "wrong version");
        assert!(decode_reply(&[PROTO_VERSION, 0x7f]).is_err(), "unknown kind");
        assert!(decode_reply(&[PROTO_VERSION, MSG_REJECT, 0, 0]).is_err(), "short reject");
    }

    #[test]
    fn reject_codes_round_trip() {
        for code in [
            RejectCode::QueueFull,
            RejectCode::DeadlineExceeded,
            RejectCode::InvalidRequest,
            RejectCode::BackendFailed,
            RejectCode::BadFrame,
            RejectCode::ServerBusy,
            RejectCode::ShuttingDown,
            RejectCode::VersionGone,
            RejectCode::Quarantined,
            RejectCode::Evicted,
        ] {
            assert_eq!(RejectCode::from_u8(code.as_u8()), Some(code));
        }
        assert_eq!(RejectCode::from_u8(0), None);
        assert_eq!(RejectCode::from_u8(200), None);
    }

    #[test]
    fn metrics_frames_round_trip() {
        let req = encode_metrics_request(METRICS_FMT_JSON);
        assert_eq!(req, vec![PROTO_VERSION, MSG_METRICS, METRICS_FMT_JSON]);

        // the windowed variant appends a little-endian u32 of seconds —
        // old servers that only look at body[2] keep answering totals
        let req = encode_metrics_request_windowed(METRICS_FMT_TEXT, 30);
        assert_eq!(req.len(), 7);
        assert_eq!(&req[..3], &[PROTO_VERSION, MSG_METRICS, METRICS_FMT_TEXT]);
        assert_eq!(u32::from_le_bytes(req[3..7].try_into().unwrap()), 30);
        // window 0 is semantically the plain request
        let req = encode_metrics_request_windowed(METRICS_FMT_JSON, 0);
        assert_eq!(u32::from_le_bytes(req[3..7].try_into().unwrap()), 0);

        let body = encode_metrics_resp(METRICS_FMT_TEXT, "mkq_serve_served 0\n");
        match decode_reply(&body).unwrap() {
            ClientReply::Metrics { format, payload } => {
                assert_eq!(format, METRICS_FMT_TEXT);
                assert_eq!(payload, "mkq_serve_served 0\n");
            }
            other => panic!("expected Metrics, got {other:?}"),
        }

        let body = encode_metrics_resp(METRICS_FMT_JSON, "{\"serve_served\": 3}");
        match decode_reply(&body).unwrap() {
            ClientReply::Metrics { format, payload } => {
                assert_eq!(format, METRICS_FMT_JSON);
                assert_eq!(crate::obs::json_u64_field(&payload, "serve_served"), Some(3));
            }
            other => panic!("expected Metrics, got {other:?}"),
        }

        // truncated payloads are decode errors
        let mut bad = encode_metrics_resp(METRICS_FMT_TEXT, "abc");
        bad.pop();
        assert!(decode_reply(&bad).is_err());
        assert!(decode_reply(&[PROTO_VERSION, MSG_METRICS_RESP, 0]).is_err(), "short header");
    }

    #[test]
    fn long_reject_messages_are_bounded() {
        let long = "x".repeat(10_000);
        let body = encode_reject(1, RejectCode::InvalidRequest, &long);
        assert!(body.len() <= 11 + 512);
        assert!(decode_reply(&body).is_ok());
    }
}
