//! Fault injection for the serving stack — compiled in, inert by default.
//!
//! Production serving is defined as much by what happens under partial
//! failure as by p50 latency, and none of it is testable without a way to
//! *cause* failure on demand. This module is that switch: a [`Faults`]
//! hook threaded through the native backends
//! ([`NativeBackend`](crate::runtime::NativeBackend) and the model-store
//! [`Registry`](crate::modelstore::Registry)), consulted once per
//! `serve_forward`, costing one relaxed atomic load when no plan is
//! armed.
//!
//! A [`FaultPlan`] can:
//!   * **fail** the Nth forward (or every Nth) with a typed
//!     [`InjectedFault`] error — exercises per-batch error fan-out;
//!   * **panic** on the Nth forward (fires once) — exercises
//!     `catch_unwind` isolation in the server's `pump()`;
//!   * **delay** every forward by a fixed duration — a stalled backend,
//!     for deadline-shedding tests.
//!
//! Plans come from the environment at backend construction
//! (`MKQ_FAULT_FAIL_FORWARD=N|every:N|first:N`, `MKQ_FAULT_PANIC_FORWARD=N`,
//! `MKQ_FAULT_DELAY_US=N` — the chaos CI job drives the release binary
//! this way) or programmatically via `set_faults` (the `tests/chaos.rs`
//! suite; per-instance state, so parallel test threads never share a
//! counter).

use std::fmt;
use std::sync::atomic::{AtomicU64, Ordering};
use std::time::Duration;

/// Which forwards of the sequence 1, 2, 3, … fail.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum FailForward {
    /// Exactly the Nth forward fails (1-based), once.
    Nth(u64),
    /// Every Nth forward fails (N, 2N, 3N, …).
    Every(u64),
    /// The first N forwards fail, then every later one succeeds — a
    /// bounded outage (drives a model into quarantine, after which
    /// siblings and reloads serve clean).
    FirstN(u64),
}

/// A declarative fault plan. `Default` is fully inert.
#[derive(Debug, Clone, Copy, Default, PartialEq, Eq)]
pub struct FaultPlan {
    pub fail_forward: Option<FailForward>,
    /// Panic on this (1-based) forward — fires at most once.
    pub panic_forward: Option<u64>,
    /// Added latency before every forward (a stalled backend).
    pub delay: Duration,
}

impl FaultPlan {
    pub fn is_inert(&self) -> bool {
        self.fail_forward.is_none() && self.panic_forward.is_none() && self.delay.is_zero()
    }

    /// Parse the `MKQ_FAULT_*` environment knobs (unset ⇒ inert; an
    /// unparsable value is reported and ignored rather than silently
    /// arming or disarming a fault).
    pub fn from_env() -> Self {
        let mut plan = FaultPlan::default();
        if let Ok(v) = std::env::var("MKQ_FAULT_FAIL_FORWARD") {
            match parse_fail_spec(&v) {
                Some(spec) => plan.fail_forward = Some(spec),
                None => {
                    eprintln!("MKQ_FAULT_FAIL_FORWARD={v:?} is not N, every:N, or first:N — ignored")
                }
            }
        }
        if let Ok(v) = std::env::var("MKQ_FAULT_PANIC_FORWARD") {
            match v.parse::<u64>() {
                Ok(n) if n > 0 => plan.panic_forward = Some(n),
                _ => eprintln!("MKQ_FAULT_PANIC_FORWARD={v:?} is not a positive integer — ignored"),
            }
        }
        if let Ok(v) = std::env::var("MKQ_FAULT_DELAY_US") {
            match v.parse::<u64>() {
                Ok(us) => plan.delay = Duration::from_micros(us),
                _ => eprintln!("MKQ_FAULT_DELAY_US={v:?} is not an integer — ignored"),
            }
        }
        plan
    }

    pub fn fail_nth(n: u64) -> Self {
        FaultPlan { fail_forward: Some(FailForward::Nth(n)), ..Default::default() }
    }

    pub fn fail_every(n: u64) -> Self {
        FaultPlan { fail_forward: Some(FailForward::Every(n)), ..Default::default() }
    }

    pub fn fail_first(n: u64) -> Self {
        FaultPlan { fail_forward: Some(FailForward::FirstN(n)), ..Default::default() }
    }

    pub fn panic_nth(n: u64) -> Self {
        FaultPlan { panic_forward: Some(n), ..Default::default() }
    }

    pub fn delay_us(us: u64) -> Self {
        FaultPlan { delay: Duration::from_micros(us), ..Default::default() }
    }
}

fn parse_fail_spec(v: &str) -> Option<FailForward> {
    if let Some(rest) = v.strip_prefix("every:") {
        rest.parse().ok().filter(|&n| n > 0).map(FailForward::Every)
    } else if let Some(rest) = v.strip_prefix("first:") {
        rest.parse().ok().filter(|&n| n > 0).map(FailForward::FirstN)
    } else {
        v.parse().ok().filter(|&n| n > 0).map(FailForward::Nth)
    }
}

/// The typed error an armed fail-forward plan injects — implements
/// `std::error::Error`, so it converts into `anyhow::Error` via `?` and
/// stays recognizable in chaos-test assertions by message.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct InjectedFault {
    /// 1-based index of the forward that failed.
    pub forward: u64,
}

impl fmt::Display for InjectedFault {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(f, "injected fault: serve_forward #{} failed", self.forward)
    }
}

impl std::error::Error for InjectedFault {}

/// Per-backend fault state: a plan plus the forward counter. Interior
/// mutability via an atomic because `Backend::serve_forward` takes
/// `&self`.
#[derive(Debug)]
pub struct Faults {
    plan: FaultPlan,
    forwards: AtomicU64,
}

impl Default for Faults {
    fn default() -> Self {
        Self::inert()
    }
}

impl Faults {
    pub fn inert() -> Self {
        Self::with_plan(FaultPlan::default())
    }

    pub fn from_env() -> Self {
        Self::with_plan(FaultPlan::from_env())
    }

    pub fn with_plan(plan: FaultPlan) -> Self {
        Faults { plan, forwards: AtomicU64::new(0) }
    }

    pub fn plan(&self) -> &FaultPlan {
        &self.plan
    }

    pub fn is_active(&self) -> bool {
        !self.plan.is_inert()
    }

    /// Forwards attempted so far (only counted while a plan is armed).
    pub fn forwards(&self) -> u64 {
        self.forwards.load(Ordering::Relaxed)
    }

    /// The per-forward hook: sleeps, panics, or fails according to the
    /// plan. A no-op (and no counter increment) when inert, so the
    /// serving hot path pays one relaxed load.
    pub fn before_forward(&self) -> Result<(), InjectedFault> {
        match self.sample_forward() {
            None => Ok(()),
            Some(f) => f.apply(),
        }
    }

    /// Sample the fault decision for the *next* forward without applying
    /// it — the multi-worker dispatch path: the front door consumes the
    /// shared counter here (so fault ordering stays deterministic in
    /// dispatch order regardless of worker count), and the worker thread
    /// later calls [`SampledFault::apply`], landing the delay/panic/error
    /// on the thread that actually executes the batch. `None` when inert
    /// (no counter increment — the hot path pays one relaxed load).
    pub fn sample_forward(&self) -> Option<SampledFault> {
        if !self.is_active() {
            return None;
        }
        let n = self.forwards.fetch_add(1, Ordering::SeqCst) + 1;
        let fail = match self.plan.fail_forward {
            Some(FailForward::Nth(k)) if n == k => Some(InjectedFault { forward: n }),
            Some(FailForward::Every(k)) if n % k == 0 => Some(InjectedFault { forward: n }),
            Some(FailForward::FirstN(k)) if n <= k => Some(InjectedFault { forward: n }),
            _ => None,
        };
        Some(SampledFault {
            delay: self.plan.delay,
            panic_forward: if self.plan.panic_forward == Some(n) { Some(n) } else { None },
            fail,
        })
    }
}

/// One forward's worth of injected misbehavior, sampled off the shared
/// counter at dispatch time and applied on whichever thread runs the
/// batch. `Copy` so dispatch handles stay trivially movable.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct SampledFault {
    delay: Duration,
    panic_forward: Option<u64>,
    fail: Option<InjectedFault>,
}

impl SampledFault {
    /// Sleep, panic, or fail exactly as `before_forward` would have for
    /// the forward this sample was drawn for.
    pub fn apply(self) -> Result<(), InjectedFault> {
        if !self.delay.is_zero() {
            std::thread::sleep(self.delay);
        }
        if let Some(n) = self.panic_forward {
            panic!("injected fault: panicking serve_forward #{n}");
        }
        match self.fail {
            Some(f) => Err(f),
            None => Ok(()),
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn inert_plan_never_fires_or_counts() {
        let f = Faults::inert();
        assert!(!f.is_active());
        for _ in 0..100 {
            assert!(f.before_forward().is_ok());
        }
        assert_eq!(f.forwards(), 0, "inert hook must not pay the counter");
    }

    #[test]
    fn nth_fails_exactly_once() {
        let f = Faults::with_plan(FaultPlan::fail_nth(3));
        let results: Vec<bool> = (0..6).map(|_| f.before_forward().is_ok()).collect();
        assert_eq!(results, vec![true, true, false, true, true, true]);
        assert_eq!(f.forwards(), 6);
    }

    #[test]
    fn every_fails_periodically() {
        let f = Faults::with_plan(FaultPlan::fail_every(2));
        let results: Vec<bool> = (0..6).map(|_| f.before_forward().is_ok()).collect();
        assert_eq!(results, vec![true, false, true, false, true, false]);
    }

    #[test]
    fn panic_fires_on_exactly_the_nth() {
        let f = Faults::with_plan(FaultPlan::panic_nth(2));
        assert!(f.before_forward().is_ok());
        let p = std::panic::catch_unwind(std::panic::AssertUnwindSafe(|| f.before_forward()));
        assert!(p.is_err(), "second forward must panic");
        assert!(f.before_forward().is_ok(), "panic-once: third forward is clean");
    }

    #[test]
    fn first_n_fails_exactly_the_prefix() {
        let f = Faults::with_plan(FaultPlan::fail_first(2));
        let results: Vec<bool> = (0..5).map(|_| f.before_forward().is_ok()).collect();
        assert_eq!(results, vec![false, false, true, true, true]);
    }

    #[test]
    fn fail_spec_parsing() {
        assert_eq!(parse_fail_spec("3"), Some(FailForward::Nth(3)));
        assert_eq!(parse_fail_spec("every:4"), Some(FailForward::Every(4)));
        assert_eq!(parse_fail_spec("first:5"), Some(FailForward::FirstN(5)));
        assert_eq!(parse_fail_spec("0"), None);
        assert_eq!(parse_fail_spec("every:0"), None);
        assert_eq!(parse_fail_spec("first:0"), None);
        assert_eq!(parse_fail_spec("bogus"), None);
    }

    #[test]
    fn sampled_faults_replay_the_before_forward_sequence() {
        // sample-then-apply must consume the same counter with the same
        // outcomes as the inline hook would have
        let f = Faults::with_plan(FaultPlan::fail_every(2));
        let results: Vec<bool> = (0..6)
            .map(|_| f.sample_forward().expect("armed plan samples").apply().is_ok())
            .collect();
        assert_eq!(results, vec![true, false, true, false, true, false]);
        assert_eq!(f.forwards(), 6);
        assert!(Faults::inert().sample_forward().is_none());
    }

    #[test]
    fn sampled_panic_lands_on_apply_not_on_sample() {
        let f = Faults::with_plan(FaultPlan::panic_nth(1));
        let s = f.sample_forward().expect("armed"); // must not panic here
        let p = std::panic::catch_unwind(std::panic::AssertUnwindSafe(|| s.apply()));
        assert!(p.is_err(), "the sampled panic fires at apply time");
        assert!(f.sample_forward().expect("armed").apply().is_ok());
    }

    #[test]
    fn injected_fault_is_a_std_error() {
        let e = InjectedFault { forward: 7 };
        let any: anyhow::Error = e.into();
        assert!(format!("{any}").contains("serve_forward #7"));
    }
}
