//! Learning-rate scheduler (paper §5.2): "all learning rates follow the
//! same scheduler that grows linearly for 10% of the training steps and
//! decays to 0 till the end".

#[derive(Debug, Clone, Copy)]
pub struct LrSchedule {
    pub peak: f64,
    pub total_steps: usize,
    pub warmup_frac: f64,
}

impl LrSchedule {
    pub fn new(peak: f64, total_steps: usize) -> Self {
        LrSchedule { peak, total_steps, warmup_frac: 0.1 }
    }

    /// lr at (0-based) step index.
    pub fn at(&self, step: usize) -> f64 {
        if self.total_steps == 0 {
            return 0.0;
        }
        let warmup = (self.total_steps as f64 * self.warmup_frac).max(1.0);
        let s = step as f64;
        if s < warmup {
            self.peak * (s + 1.0) / warmup
        } else {
            let rest = (self.total_steps as f64 - warmup).max(1.0);
            self.peak * (1.0 - (s - warmup) / rest).max(0.0)
        }
    }

    /// The [K, 1] per-step lr tensor data for steps [start, start+k).
    pub fn slice(&self, start: usize, k: usize) -> Vec<f32> {
        (start..start + k).map(|s| self.at(s) as f32).collect()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn warms_up_then_decays() {
        let s = LrSchedule::new(1.0, 100);
        assert!(s.at(0) < s.at(5));
        assert!(s.at(5) < s.at(9));
        let peak_region = s.at(10);
        assert!((peak_region - 1.0).abs() < 0.12);
        assert!(s.at(50) < peak_region);
        assert!(s.at(99) < 0.03);
    }

    #[test]
    fn never_negative() {
        let s = LrSchedule::new(0.005, 37);
        for i in 0..200 {
            assert!(s.at(i) >= 0.0);
        }
    }

    #[test]
    fn slice_matches_at() {
        let s = LrSchedule::new(0.1, 50);
        let sl = s.slice(10, 5);
        for (i, v) in sl.iter().enumerate() {
            assert!((*v as f64 - s.at(10 + i)).abs() < 1e-7);
        }
    }
}
