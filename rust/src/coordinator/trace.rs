//! Mixed-length request-trace generation for the serving demo and the
//! coordinator benchmarks.
//!
//! Real classification traffic is short and mixed-length; the synthetic
//! GLUE datasets already carry that distribution (every row is tokenized
//! to `seq` with a prefix-of-ones mask over its true tokens). A
//! [`TraceGen`] samples dataset rows and emits them either **trimmed to
//! their valid length** (`mixed` — what the 2-D seq-bucket batcher is
//! for) or **padded to full `seq`** (`full` — the old fixed-shape
//! behavior, kept for A/B comparison and for fixed-shape backends).

use crate::data::Dataset;
use crate::util::rng::Rng;

/// How request lengths are drawn from the dataset.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum TraceKind {
    /// Requests at their true token length (mixed lengths).
    Mixed,
    /// Requests padded to the full model `seq` (fixed shape).
    Full,
}

impl TraceKind {
    pub fn parse(s: &str) -> Option<Self> {
        match s {
            "mixed" => Some(TraceKind::Mixed),
            "full" => Some(TraceKind::Full),
            _ => None,
        }
    }

    pub fn name(self) -> &'static str {
        match self {
            TraceKind::Mixed => "mixed",
            TraceKind::Full => "full",
        }
    }
}

/// Seeded sampler of `(ids, mask)` requests over a tokenized dataset.
pub struct TraceGen<'d> {
    ds: &'d Dataset,
    rng: Rng,
    kind: TraceKind,
}

impl<'d> TraceGen<'d> {
    pub fn new(ds: &'d Dataset, kind: TraceKind, seed: u64) -> Self {
        assert!(!ds.is_empty(), "trace over an empty dataset");
        TraceGen { ds, rng: Rng::new(seed), kind }
    }

    /// Sample the next request. `Mixed` trims to the row's valid-token
    /// count (mask is a prefix of ones by tokenizer construction), `Full`
    /// returns the row as stored (padded to `seq`).
    pub fn next_request(&mut self) -> (Vec<i32>, Vec<f32>) {
        let row = self.rng.below(self.ds.len());
        let ids = &self.ds.ids[row];
        let mask = &self.ds.masks[row];
        match self.kind {
            TraceKind::Full => (ids.clone(), mask.clone()),
            TraceKind::Mixed => {
                let valid = mask.iter().filter(|&&m| m == 1.0).count().max(1);
                (ids[..valid].to_vec(), mask[..valid].to_vec())
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::data::{Suite, TaskKind};

    #[test]
    fn mixed_trims_full_pads() {
        let suite = Suite::new(42, 128, 16);
        let task = suite.task(TaskKind::Sst2, 1);
        let mut mixed = TraceGen::new(&task.dev, TraceKind::Mixed, 7);
        let mut full = TraceGen::new(&task.dev, TraceKind::Full, 7);
        let mut saw_short = false;
        for _ in 0..32 {
            let (ids, mask) = mixed.next_request();
            assert_eq!(ids.len(), mask.len());
            assert!(!ids.is_empty() && ids.len() <= 16);
            assert!(mask.iter().all(|&m| m == 1.0), "mixed requests carry no padding");
            if ids.len() < 16 {
                saw_short = true;
            }
            let (fids, fmask) = full.next_request();
            assert_eq!(fids.len(), 16);
            assert_eq!(fmask.len(), 16);
        }
        assert!(saw_short, "synthetic traffic should contain short requests");
    }

    #[test]
    fn trace_kind_parses() {
        assert_eq!(TraceKind::parse("mixed"), Some(TraceKind::Mixed));
        assert_eq!(TraceKind::parse("full"), Some(TraceKind::Full));
        assert_eq!(TraceKind::parse("bogus"), None);
        for k in [TraceKind::Mixed, TraceKind::Full] {
            assert_eq!(TraceKind::parse(k.name()), Some(k));
        }
    }
}
