//! L3 coordinator — the paper's system contribution in Rust.
//!
//! * [`trainer`]  — calibration → QAT → eval orchestration (Tables 1 & 3).
//! * [`server`]   — request router + valid-token dynamic batcher +
//!                  executor over quantized artifacts (Table 2, §5.4).
//! * [`scheduler`]— the paper's warmup/decay lr schedule (§5.2).

pub mod scheduler;
pub mod server;
pub mod trainer;

pub use scheduler::LrSchedule;
pub use server::{Request, Response, ServeModel, Server, ServerConfig, ServerSummary};
pub use trainer::{bits_last_n_int4, parse_bits, ModelDims, QatConfig, QatResult, Trainer};
