//! L3 coordinator — the paper's system contribution in Rust.
//!
//! * [`trainer`]  — calibration → QAT → eval orchestration (Tables 1 & 3);
//!                  artifact-path only (feature `xla`).
//! * [`server`]   — request router + valid-token dynamic batcher +
//!                  executor over any [`crate::runtime::Backend`]
//!                  (Table 2, §5.4).
//! * [`scheduler`]— the paper's warmup/decay lr schedule (§5.2).

pub mod scheduler;
pub mod server;
#[cfg(feature = "xla")]
pub mod trainer;

pub use crate::quant::{bits_last_n_int4, parse_bits};
pub use scheduler::LrSchedule;
pub use server::{Request, Response, Server, ServerConfig, ServerSummary};

#[cfg(feature = "xla")]
pub use crate::runtime::ServeModel;
#[cfg(feature = "xla")]
pub use trainer::{ModelDims, QatConfig, QatResult, Trainer};
