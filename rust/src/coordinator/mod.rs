//! L3 coordinator — the paper's system contribution in Rust.
//!
//! * [`trainer`]  — calibration → QAT → eval orchestration (Tables 1 & 3);
//!                  artifact-path only (feature `xla`).
//! * [`server`]   — request router + 2-D (batch × seq-length) dynamic
//!                  batcher + executor over any
//!                  [`crate::runtime::Backend`] (Table 2, §5.4), with
//!                  admission control, deadlines, and per-batch fault
//!                  isolation.
//! * [`net`]      — the socket front door: length-prefixed wire protocol
//!                  over nonblocking `std::net` TCP with `poll(2)`-driven
//!                  readiness, plus client-side framing helpers for the
//!                  load generator.
//! * [`workers`]  — the execution worker pool behind `--workers N`:
//!                  per-worker workspaces and dispatcher replicas, batch
//!                  dispatch over a bounded MPMC channel, per-batch panic
//!                  containment.
//! * [`faults`]   — env/config-driven fault injection (fail-Nth-forward,
//!                  added latency, panic-once), inert by default; what
//!                  the chaos suite drives.
//! * [`trace`]    — mixed-length request-trace generation for the
//!                  serving demo and benches.
//! * [`scheduler`]— the paper's warmup/decay lr schedule (§5.2).

pub mod faults;
pub mod net;
pub mod scheduler;
pub mod server;
pub mod trace;
#[cfg(feature = "xla")]
pub mod trainer;
pub mod workers;

pub use crate::quant::{bits_last_n_int4, parse_bits};
pub use faults::{FaultPlan, Faults, InjectedFault};
pub use net::{
    AdminOp, AdminReply, ClientReply, FrontDoor, NetStats, RejectCode, RunOpts, WakeHandle,
    WireModelInfo,
};
pub use scheduler::LrSchedule;
pub use server::{
    ModelInfo, PerModelSummary, Rejected, Request, Response, ResponseBody, Server, ServerConfig,
    ServerSummary, WorkDone, WorkItem,
};
pub use trace::{TraceGen, TraceKind};
pub use workers::WorkerPool;

#[cfg(feature = "xla")]
pub use crate::runtime::ServeModel;
#[cfg(feature = "xla")]
pub use trainer::{ModelDims, QatConfig, QatResult, Trainer};
