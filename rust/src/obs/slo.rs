//! SLO burn-rate engine: declared latency/error objectives evaluated
//! over sliding windows from the snapshot ring.
//!
//! `serve-native --slo p99_us=N,error_pct=X` arms up to two objectives:
//!
//! * **latency** — at most 1% of wire requests may exceed `p99_us`
//!   microseconds end-to-end (`stage_total_us`);
//! * **error**  — at most `error_pct` percent of a model's forwards may
//!   fail.
//!
//! A *burn rate* is the observed bad fraction divided by the budgeted
//! bad fraction: 1.0 means the budget is being consumed exactly as
//! fast as allowed, 10 means ten times too fast. The engine computes a
//! **fast** (10 s) and **slow** (60 s) burn from windowed deltas — the
//! standard multi-window alerting shape: the fast window catches a
//! sudden cliff, the slow window confirms a sustained trend — and maps
//! them to a per-model [`SloState`]:
//!
//! * `Burning` — fast burn ≥ 2.0 (the budget is vanishing *now*);
//! * `Warning` — slow burn ≥ 1.0 (a sustained overspend);
//! * `Ok` — otherwise.
//!
//! The engine is **observe-only**: it sets `slo_*` gauges on the
//! metrics registry (scraped, rendered in INFO_RESP / `admin status` /
//! the statusline) and never couples back into admission. The front
//! door calls [`evaluate`] on its ~1 s capture tick; windows clamp to
//! however much history the snapshot ring actually holds.

use super::metrics::{registry, MAX_MODEL_SLOTS};
use super::snapshot::{window_delta, SnapData};

/// Fast (page-now) burn window, seconds.
pub const FAST_WINDOW_SECS: u32 = 10;
/// Slow (sustained-trend) burn window, seconds.
pub const SLOW_WINDOW_SECS: u32 = 60;
/// Fast burn at or above this is `Burning`.
pub const FAST_BURN_THRESHOLD: f64 = 2.0;
/// Slow burn at or above this is `Warning`.
pub const SLOW_BURN_THRESHOLD: f64 = 1.0;

/// Declared objectives (both optional; `--slo` grammar:
/// `p99_us=N,error_pct=X` in either order, either alone).
#[derive(Clone, Copy, Debug, Default)]
pub struct SloConfig {
    /// End-to-end wire latency target: at most 1% of requests above this.
    pub p99_us: Option<u64>,
    /// Per-model forward failure budget, percent (0 < x ≤ 100).
    pub error_pct: Option<f64>,
}

impl SloConfig {
    pub const fn none() -> SloConfig {
        SloConfig { p99_us: None, error_pct: None }
    }

    pub fn armed(&self) -> bool {
        self.p99_us.is_some() || self.error_pct.is_some()
    }

    /// Parse the `--slo` flag value.
    pub fn parse(s: &str) -> Result<SloConfig, String> {
        let mut cfg = SloConfig::none();
        for part in s.split(',') {
            let part = part.trim();
            if part.is_empty() {
                continue;
            }
            let (k, v) = part
                .split_once('=')
                .ok_or_else(|| format!("bad SLO clause {part:?} (want key=value)"))?;
            match k.trim() {
                "p99_us" => {
                    let n: u64 = v
                        .trim()
                        .parse()
                        .map_err(|_| format!("bad p99_us value {v:?} (want microseconds)"))?;
                    if n == 0 {
                        return Err("p99_us must be positive".into());
                    }
                    cfg.p99_us = Some(n);
                }
                "error_pct" => {
                    let x: f64 = v
                        .trim()
                        .parse()
                        .map_err(|_| format!("bad error_pct value {v:?} (want percent)"))?;
                    if !(x > 0.0 && x <= 100.0) {
                        return Err(format!("error_pct {x} out of range (0, 100]"));
                    }
                    cfg.error_pct = Some(x);
                }
                other => {
                    return Err(format!("unknown SLO key {other:?} (known: p99_us, error_pct)"))
                }
            }
        }
        if !cfg.armed() {
            return Err("empty --slo spec (want p99_us=N and/or error_pct=X)".into());
        }
        Ok(cfg)
    }

    /// Human-readable objective summary for startup banners.
    pub fn describe(&self) -> String {
        let mut parts = Vec::new();
        if let Some(t) = self.p99_us {
            parts.push(format!("p99 <= {t}us (1% budget)"));
        }
        if let Some(p) = self.error_pct {
            parts.push(format!("forward errors <= {p}%"));
        }
        if parts.is_empty() {
            "unarmed".into()
        } else {
            parts.join(", ")
        }
    }

    /// Write the declared objectives into the registry gauges so scrapes
    /// and wire surfaces can see what is armed. Call once at serve start.
    pub fn arm(&self) {
        let r = registry();
        let mut bits = 0u64;
        if let Some(t) = self.p99_us {
            bits |= 1;
            r.slo_latency_target_us.set(t);
        }
        if let Some(p) = self.error_pct {
            bits |= 2;
            r.slo_error_pct_milli.set((p * 1000.0) as u64);
        }
        r.slo_armed.set(bits);
    }
}

/// Tri-state SLO verdict, ordered by severity.
#[derive(Clone, Copy, PartialEq, Eq, PartialOrd, Ord, Debug)]
#[repr(u8)]
pub enum SloState {
    Ok = 0,
    Warning = 1,
    Burning = 2,
}

impl SloState {
    pub fn as_u8(self) -> u8 {
        self as u8
    }

    pub fn from_u8(v: u8) -> SloState {
        match v {
            1 => SloState::Warning,
            2 => SloState::Burning,
            _ => SloState::Ok,
        }
    }

    pub fn name(self) -> &'static str {
        match self {
            SloState::Ok => "ok",
            SloState::Warning => "warn",
            SloState::Burning => "burn",
        }
    }
}

/// One evaluation's outcome (also mirrored into the registry gauges).
#[derive(Clone, Debug)]
pub struct SloReport {
    pub latency_burn_fast: f64,
    pub latency_burn_slow: f64,
    /// Global latency verdict (latency is measured at the wire, not per
    /// model — it applies to every model's state).
    pub latency_state: SloState,
    /// Per registered model: max of the latency verdict and the model's
    /// own error-budget verdict.
    pub model_states: Vec<(usize, SloState)>,
    pub worst: SloState,
}

fn state_of(fast_burn: f64, slow_burn: f64) -> SloState {
    if fast_burn >= FAST_BURN_THRESHOLD {
        SloState::Burning
    } else if slow_burn >= SLOW_BURN_THRESHOLD {
        SloState::Warning
    } else {
        SloState::Ok
    }
}

fn error_burn(d: &SnapData, i: usize, pct: f64) -> f64 {
    let served = d.model_served[i];
    let failed = d.model_failures[i];
    let total = served + failed;
    if total == 0 {
        return 0.0;
    }
    (failed as f64 / total as f64) / (pct / 100.0)
}

/// Evaluate with the standard fast/slow windows. Call on the capture
/// tick (after [`SnapshotRing::capture`](super::snapshot::SnapshotRing::capture)).
pub fn evaluate(cfg: &SloConfig) -> SloReport {
    evaluate_windows(cfg, FAST_WINDOW_SECS, SLOW_WINDOW_SECS)
}

/// Evaluate against explicit windows (tests drive synthetic windows with
/// `fast_secs`/`slow_secs` = 0, meaning "since the latest capture").
pub fn evaluate_windows(cfg: &SloConfig, fast_secs: u32, slow_secs: u32) -> SloReport {
    let r = registry();
    let fast = window_delta(fast_secs);
    let slow = window_delta(slow_secs);

    let (mut lf, mut ls) = (0.0f64, 0.0f64);
    if let Some(target) = cfg.p99_us {
        // budget: 1% of requests may exceed the target
        lf = fast.stage_total_us.frac_above(target) / 0.01;
        ls = slow.stage_total_us.frac_above(target) / 0.01;
    }
    let latency_state = state_of(lf, ls);

    let n = r.model_labels_snapshot().len().min(MAX_MODEL_SLOTS);
    let mut worst = latency_state;
    let mut model_states = Vec::with_capacity(n);
    for i in 0..n {
        let mut st = latency_state;
        if let Some(pct) = cfg.error_pct {
            let ef = error_burn(&fast, i, pct);
            let es = error_burn(&slow, i, pct);
            st = st.max(state_of(ef, es));
            r.slo_error_burn_fast_milli[i].set((ef * 1000.0) as u64);
            r.slo_error_burn_slow_milli[i].set((es * 1000.0) as u64);
        }
        r.slo_state[i].set(st.as_u8() as u64);
        worst = worst.max(st);
        model_states.push((i, st));
    }
    r.slo_latency_burn_fast_milli.set((lf * 1000.0) as u64);
    r.slo_latency_burn_slow_milli.set((ls * 1000.0) as u64);
    r.slo_state_worst.set(worst.as_u8() as u64);
    SloReport { latency_burn_fast: lf, latency_burn_slow: ls, latency_state, model_states, worst }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn parse_grammar() {
        let c = SloConfig::parse("p99_us=5000,error_pct=1").expect("both clauses");
        assert_eq!(c.p99_us, Some(5000));
        assert_eq!(c.error_pct, Some(1.0));
        let c = SloConfig::parse("error_pct=0.5").expect("error alone");
        assert_eq!(c.p99_us, None);
        assert_eq!(c.error_pct, Some(0.5));
        let c = SloConfig::parse(" p99_us = 250 ").expect("whitespace tolerated");
        assert_eq!(c.p99_us, Some(250));
        assert!(SloConfig::parse("").is_err());
        assert!(SloConfig::parse("p99_us=abc").is_err());
        assert!(SloConfig::parse("error_pct=0").is_err());
        assert!(SloConfig::parse("error_pct=150").is_err());
        assert!(SloConfig::parse("p50_us=10").is_err());
    }

    #[test]
    fn state_thresholds() {
        assert_eq!(state_of(0.0, 0.0), SloState::Ok);
        assert_eq!(state_of(0.5, 0.99), SloState::Ok);
        assert_eq!(state_of(0.5, 1.0), SloState::Warning);
        assert_eq!(state_of(2.0, 0.0), SloState::Burning);
        assert_eq!(state_of(50.0, 50.0), SloState::Burning);
    }

    #[test]
    fn state_wire_round_trip() {
        for s in [SloState::Ok, SloState::Warning, SloState::Burning] {
            assert_eq!(SloState::from_u8(s.as_u8()), s);
        }
        assert_eq!(SloState::from_u8(99), SloState::Ok);
    }
}
