//! Process-wide metrics registry: lock-free counters/gauges plus the
//! log-linear histograms from [`super::hist`], all const-initialized in
//! one static so hot-path recording is a relaxed atomic RMW — zero heap
//! allocation, no locks (enforced by `tests/workspace_alloc.rs`).
//!
//! The whole layer is killable: `MKQ_METRICS=0` (read once, overridable
//! at runtime via [`set_metrics_enabled`] for the overhead bench) makes
//! [`metrics()`] return `None`, so every instrumentation site reduces to
//! one relaxed load and a branch. Rendering ([`render_prometheus`],
//! [`render_json`]) always works off the same registry regardless of the
//! gate, so a scrape after a disabled run shows zeros rather than
//! erroring.
//!
//! The full series table (name, type, meaning) is documented in the
//! README "Observability" section; CI greps a scrape for every row.

use std::sync::atomic::{AtomicBool, AtomicU64, Ordering::Relaxed};
use std::sync::{Mutex, Once};

use super::hist::Histogram;
use super::trace::SlowTraces;

/// Monotone event counter.
#[derive(Default)]
pub struct Counter(AtomicU64);

impl Counter {
    pub const fn new() -> Self {
        Counter(AtomicU64::new(0))
    }

    #[inline]
    pub fn inc(&self) {
        self.0.fetch_add(1, Relaxed);
    }

    #[inline]
    pub fn add(&self, n: u64) {
        self.0.fetch_add(n, Relaxed);
    }

    pub fn get(&self) -> u64 {
        self.0.load(Relaxed)
    }
}

/// Last-write-wins instantaneous value.
#[derive(Default)]
pub struct Gauge(AtomicU64);

impl Gauge {
    pub const fn new() -> Self {
        Gauge(AtomicU64::new(0))
    }

    #[inline]
    pub fn set(&self, v: u64) {
        self.0.store(v, Relaxed);
    }

    pub fn get(&self) -> u64 {
        self.0.load(Relaxed)
    }
}

/// Reject codes 1..=10 (see `coordinator::net::RejectCode`); slot 0 is
/// unused so the wire code indexes directly.
pub const N_REJECT_CODES: usize = 11;

pub(crate) const REJECT_NAMES: [&str; N_REJECT_CODES] = [
    "unknown",
    "queue_full",
    "deadline",
    "invalid",
    "backend_failed",
    "bad_frame",
    "busy",
    "shutting_down",
    "version_gone",
    "quarantined",
    "evicted",
];

/// Fixed per-model metric slots; the fleet registry registers a label
/// per loaded model (registration is cold-path and may allocate).
pub const MAX_MODEL_SLOTS: usize = 32;

/// Kernel-kind slots: the 7 `KernelKind` variants plus one for the
/// packed-f32 GEMM (`kernels::dispatch` owns the index mapping).
pub const N_KERNEL_SLOTS: usize = 8;

/// Fixed per-execution-worker metric slots (`--workers N` is clamped
/// well below this; workers past the cap still serve, just unlabeled).
pub const MAX_WORKER_SLOTS: usize = 16;

/// Models with their own (model × seq) batch histogram rows; models past
/// this fold into the shared overflow column of the last row.
pub const MAX_BATCH_MODELS: usize = 8;

/// Seq-bucket columns per model in the batch histogram grid; columns are
/// claimed by the first batch seen at that token capacity, extras fold
/// into the last column.
pub const MAX_SEQ_SLOTS: usize = 8;

/// Per-(model × seq-bucket) batch fill/exec histograms, replacing the
/// PR-8 global-only pair. Columns are claimed lock-free on first sight
/// of a seq-bucket token capacity (CAS from 0); recording stays a
/// relaxed scan over ≤ [`MAX_SEQ_SLOTS`] cells plus the histogram RMWs —
/// zero-alloc, hot-path safe. Rendered with `{model,seq}` labels in
/// Prometheus text; unclaimed cells render nothing.
pub struct BatchHists {
    /// Claimed seq-bucket token capacity per column; 0 = free.
    cols: [[AtomicU64; MAX_SEQ_SLOTS]; MAX_BATCH_MODELS],
    fill_pct: [[Histogram; MAX_SEQ_SLOTS]; MAX_BATCH_MODELS],
    exec_us: [[Histogram; MAX_SEQ_SLOTS]; MAX_BATCH_MODELS],
}

impl BatchHists {
    pub const fn new() -> Self {
        BatchHists {
            cols: [const { [const { AtomicU64::new(0) }; MAX_SEQ_SLOTS] }; MAX_BATCH_MODELS],
            fill_pct: [const { [const { Histogram::new() }; MAX_SEQ_SLOTS] }; MAX_BATCH_MODELS],
            exec_us: [const { [const { Histogram::new() }; MAX_SEQ_SLOTS] }; MAX_BATCH_MODELS],
        }
    }

    fn col_for(&self, model: usize, seq_tcap: u64) -> (usize, usize) {
        let m = model.min(MAX_BATCH_MODELS - 1);
        let cols = &self.cols[m];
        for c in 0..MAX_SEQ_SLOTS {
            let cur = cols[c].load(Relaxed);
            if cur == seq_tcap {
                return (m, c);
            }
            if cur == 0 {
                match cols[c].compare_exchange(0, seq_tcap, Relaxed, Relaxed) {
                    Ok(_) => return (m, c),
                    Err(seen) if seen == seq_tcap => return (m, c),
                    Err(_) => {} // lost the claim to a different bucket; keep scanning
                }
            }
        }
        (m, MAX_SEQ_SLOTS - 1) // grid full for this model: fold into the last column
    }

    /// Record one executed batch for `(model, seq-bucket token capacity)`.
    #[inline]
    pub fn record(&self, model: usize, seq_tcap: usize, fill_pct: u64, exec_us: u64) {
        let (m, c) = self.col_for(model, seq_tcap as u64);
        self.fill_pct[m][c].record(fill_pct);
        self.exec_us[m][c].record(exec_us);
    }

    /// Claimed token capacity of a grid cell (0 = never recorded).
    pub fn col_tcap(&self, model: usize, col: usize) -> u64 {
        self.cols[model][col].load(Relaxed)
    }

    pub fn fill(&self, model: usize, col: usize) -> &Histogram {
        &self.fill_pct[model][col]
    }

    pub fn exec(&self, model: usize, col: usize) -> &Histogram {
        &self.exec_us[model][col]
    }
}

pub struct MetricsRegistry {
    // -- front door (coordinator/net.rs) --------------------------------
    pub net_accepted_conns: Counter,
    pub net_rejected_conns: Counter,
    pub net_disconnects: Counter,
    pub net_frames_in: Counter,
    pub net_frames_out: Counter,
    pub net_bytes_in: Counter,
    pub net_bytes_out: Counter,
    pub net_bad_frames: Counter,
    pub net_rejects: [Counter; N_REJECT_CODES],

    // -- batching server (coordinator/server.rs) ------------------------
    pub serve_admitted: Counter,
    pub serve_served: Counter,
    pub serve_shed_deadline: Counter,
    pub serve_failed: Counter,
    pub serve_rejected_full: Counter,
    pub serve_rejected_invalid: Counter,
    pub serve_rejected_shutdown: Counter,
    pub serve_rejected_unavailable: Counter,
    pub serve_batches: Counter,
    pub serve_padded_tokens: Counter,
    pub serve_total_tokens: Counter,
    pub serve_queue_depth: Gauge,
    /// Per-(model × seq-bucket) batch occupancy / exec histograms.
    pub serve_batch: BatchHists,

    // -- request lifecycle stages ---------------------------------------
    /// admitted → staged into a batch.
    pub stage_queue_us: Histogram,
    /// staged → backend forward complete (per request).
    pub stage_exec_us: Histogram,
    /// wire path only: frame read → reply queued for write.
    pub stage_total_us: Histogram,

    // -- model fleet (modelstore/registry.rs) ---------------------------
    pub model_version: [Gauge; MAX_MODEL_SLOTS],
    pub model_health: [Gauge; MAX_MODEL_SLOTS],
    pub model_resident_bytes: [Gauge; MAX_MODEL_SLOTS],
    pub model_health_transitions: [Counter; MAX_MODEL_SLOTS],
    pub model_reloads: [Counter; MAX_MODEL_SLOTS],
    pub model_evicts: [Counter; MAX_MODEL_SLOTS],
    pub model_forward_failures: [Counter; MAX_MODEL_SLOTS],
    /// Requests answered with logits, per model (the SLO error budget's
    /// denominator alongside `model_forward_failures`).
    pub model_served: [Counter; MAX_MODEL_SLOTS],

    // -- SLO engine (obs/slo.rs; observe-only) --------------------------
    /// Armed objectives bitmask: bit 0 latency, bit 1 error budget.
    pub slo_armed: Gauge,
    pub slo_latency_target_us: Gauge,
    /// Declared error budget, percent × 1000.
    pub slo_error_pct_milli: Gauge,
    /// Latency burn rates × 1000 (burn 1.0 = spending the budget exactly
    /// as fast as allowed).
    pub slo_latency_burn_fast_milli: Gauge,
    pub slo_latency_burn_slow_milli: Gauge,
    /// Worst per-model state: 0 ok, 1 warning, 2 burning.
    pub slo_state_worst: Gauge,
    pub slo_state: [Gauge; MAX_MODEL_SLOTS],
    pub slo_error_burn_fast_milli: [Gauge; MAX_MODEL_SLOTS],
    pub slo_error_burn_slow_milli: [Gauge; MAX_MODEL_SLOTS],

    // -- execution workers (coordinator/workers.rs) ---------------------
    /// Worker threads the front door is running (1 = inline loop).
    pub workers_configured: Gauge,
    /// Batches sitting in the dispatch channel, not yet claimed.
    pub worker_queue_depth: Gauge,
    /// Batch staged by the front door → claimed by a worker.
    pub worker_dispatch_wait_us: Histogram,
    pub worker_batches: [Counter; MAX_WORKER_SLOTS],
    /// 1 while the worker is executing a batch, 0 while parked.
    pub worker_busy: [Gauge; MAX_WORKER_SLOTS],
    pub worker_exec_us: [Histogram; MAX_WORKER_SLOTS],

    // -- kernels (kernels/dispatch.rs) ----------------------------------
    pub kernel_calls: [Counter; N_KERNEL_SLOTS],
    pub kernel_macs: [Counter; N_KERNEL_SLOTS],

    // -- slowest-trace ring ---------------------------------------------
    pub slow_traces: SlowTraces,

    /// Registered model labels (index-aligned with the `model_*` arrays).
    model_labels: Mutex<Vec<String>>,
}

impl MetricsRegistry {
    const fn new() -> Self {
        MetricsRegistry {
            net_accepted_conns: Counter::new(),
            net_rejected_conns: Counter::new(),
            net_disconnects: Counter::new(),
            net_frames_in: Counter::new(),
            net_frames_out: Counter::new(),
            net_bytes_in: Counter::new(),
            net_bytes_out: Counter::new(),
            net_bad_frames: Counter::new(),
            net_rejects: [const { Counter::new() }; N_REJECT_CODES],
            serve_admitted: Counter::new(),
            serve_served: Counter::new(),
            serve_shed_deadline: Counter::new(),
            serve_failed: Counter::new(),
            serve_rejected_full: Counter::new(),
            serve_rejected_invalid: Counter::new(),
            serve_rejected_shutdown: Counter::new(),
            serve_rejected_unavailable: Counter::new(),
            serve_batches: Counter::new(),
            serve_padded_tokens: Counter::new(),
            serve_total_tokens: Counter::new(),
            serve_queue_depth: Gauge::new(),
            serve_batch: BatchHists::new(),
            stage_queue_us: Histogram::new(),
            stage_exec_us: Histogram::new(),
            stage_total_us: Histogram::new(),
            model_version: [const { Gauge::new() }; MAX_MODEL_SLOTS],
            model_health: [const { Gauge::new() }; MAX_MODEL_SLOTS],
            model_resident_bytes: [const { Gauge::new() }; MAX_MODEL_SLOTS],
            model_health_transitions: [const { Counter::new() }; MAX_MODEL_SLOTS],
            model_reloads: [const { Counter::new() }; MAX_MODEL_SLOTS],
            model_evicts: [const { Counter::new() }; MAX_MODEL_SLOTS],
            model_forward_failures: [const { Counter::new() }; MAX_MODEL_SLOTS],
            model_served: [const { Counter::new() }; MAX_MODEL_SLOTS],
            slo_armed: Gauge::new(),
            slo_latency_target_us: Gauge::new(),
            slo_error_pct_milli: Gauge::new(),
            slo_latency_burn_fast_milli: Gauge::new(),
            slo_latency_burn_slow_milli: Gauge::new(),
            slo_state_worst: Gauge::new(),
            slo_state: [const { Gauge::new() }; MAX_MODEL_SLOTS],
            slo_error_burn_fast_milli: [const { Gauge::new() }; MAX_MODEL_SLOTS],
            slo_error_burn_slow_milli: [const { Gauge::new() }; MAX_MODEL_SLOTS],
            workers_configured: Gauge::new(),
            worker_queue_depth: Gauge::new(),
            worker_dispatch_wait_us: Histogram::new(),
            worker_batches: [const { Counter::new() }; MAX_WORKER_SLOTS],
            worker_busy: [const { Gauge::new() }; MAX_WORKER_SLOTS],
            worker_exec_us: [const { Histogram::new() }; MAX_WORKER_SLOTS],
            kernel_calls: [const { Counter::new() }; N_KERNEL_SLOTS],
            kernel_macs: [const { Counter::new() }; N_KERNEL_SLOTS],
            slow_traces: SlowTraces::new(),
            model_labels: Mutex::new(Vec::new()),
        }
    }

    /// Register (or re-register) a model label for slot `idx`. Cold path.
    pub fn register_model_label(&self, idx: usize, label: &str) {
        if idx >= MAX_MODEL_SLOTS {
            return;
        }
        let mut labels = self.model_labels.lock().unwrap();
        while labels.len() <= idx {
            labels.push(String::new());
        }
        labels[idx] = label.to_string();
    }

    /// Register `fallback` for slot `idx` only when the slot has no
    /// label yet — the single-model demo path labels itself without
    /// clobbering names the model store registered at load time.
    pub fn ensure_model_label(&self, idx: usize, fallback: &str) {
        if idx >= MAX_MODEL_SLOTS {
            return;
        }
        let mut labels = self.model_labels.lock().unwrap();
        while labels.len() <= idx {
            labels.push(String::new());
        }
        if labels[idx].is_empty() {
            labels[idx] = fallback.to_string();
        }
    }

    pub(crate) fn model_labels_snapshot(&self) -> Vec<String> {
        self.model_labels.lock().unwrap().clone()
    }
}

static REGISTRY: MetricsRegistry = MetricsRegistry::new();
static ENABLED: AtomicBool = AtomicBool::new(true);
static ENV_INIT: Once = Once::new();

fn init_from_env() {
    ENV_INIT.call_once(|| {
        if let Ok(v) = std::env::var("MKQ_METRICS") {
            let v = v.trim();
            if v == "0" || v.eq_ignore_ascii_case("off") || v.eq_ignore_ascii_case("false") {
                ENABLED.store(false, Relaxed);
            }
        }
    });
}

/// The hot-path accessor: `None` when metrics are disabled
/// (`MKQ_METRICS=0`), so instrumentation sites cost one relaxed load.
#[inline]
pub fn metrics() -> Option<&'static MetricsRegistry> {
    init_from_env();
    if ENABLED.load(Relaxed) { Some(&REGISTRY) } else { None }
}

/// Ungated access for rendering, merging, and tests.
pub fn registry() -> &'static MetricsRegistry {
    init_from_env();
    &REGISTRY
}

/// Runtime override of the `MKQ_METRICS` gate (overhead bench + tests).
pub fn set_metrics_enabled(on: bool) {
    init_from_env();
    ENABLED.store(on, Relaxed);
}

pub fn metrics_enabled() -> bool {
    init_from_env();
    ENABLED.load(Relaxed)
}

/// Register a model label on the process registry (cold path; applied
/// even when recording is gated off so scrapes stay labeled).
pub fn register_model_label(idx: usize, label: &str) {
    registry().register_model_label(idx, label);
}

/// Label slot `idx` with `fallback` only if it is still unlabeled.
pub fn ensure_model_label(idx: usize, fallback: &str) {
    registry().ensure_model_label(idx, fallback);
}

// ---------------------------------------------------------------------
// Rendering
// ---------------------------------------------------------------------

use std::fmt::Write as _;

fn prom_counter(out: &mut String, name: &str, help: &str, v: u64) {
    let _ = writeln!(out, "# HELP mkq_{name} {help}");
    let _ = writeln!(out, "# TYPE mkq_{name} counter");
    let _ = writeln!(out, "mkq_{name} {v}");
}

fn prom_gauge(out: &mut String, name: &str, help: &str, v: u64) {
    let _ = writeln!(out, "# HELP mkq_{name} {help}");
    let _ = writeln!(out, "# TYPE mkq_{name} gauge");
    let _ = writeln!(out, "mkq_{name} {v}");
}

fn prom_hist(out: &mut String, name: &str, help: &str, h: &Histogram) {
    prom_hist_ex(out, name, help, h, None);
}

/// Like [`prom_hist`], with an optional OpenMetrics exemplar appended to
/// the `_count` line (` # {labels} value`) — the slow-trace join surface.
fn prom_hist_ex(out: &mut String, name: &str, help: &str, h: &Histogram, exemplar: Option<String>) {
    let _ = writeln!(out, "# HELP mkq_{name} {help}");
    let _ = writeln!(out, "# TYPE mkq_{name} summary");
    for (q, label) in [(0.5, "0.5"), (0.9, "0.9"), (0.99, "0.99"), (0.999, "0.999")] {
        let _ = writeln!(out, "mkq_{name}{{quantile=\"{label}\"}} {:.1}", h.quantile(q));
    }
    let _ = writeln!(out, "mkq_{name}_sum {}", h.sum());
    let _ = writeln!(out, "mkq_{name}_count {}{}", h.count(), exemplar.unwrap_or_default());
}

fn model_label_for(labels: &[String], i: usize) -> String {
    match labels.get(i) {
        Some(l) if !l.is_empty() => l.clone(),
        _ => format!("{i}"),
    }
}

/// Prometheus text exposition of every registered series.
pub fn render_prometheus() -> String {
    let r = registry();
    let mut out = String::with_capacity(8192);

    prom_counter(&mut out, "net_accepted_conns", "TCP connections accepted", r.net_accepted_conns.get());
    prom_counter(&mut out, "net_rejected_conns", "TCP connections refused at the conn cap", r.net_rejected_conns.get());
    prom_counter(&mut out, "net_disconnects", "client disconnects observed", r.net_disconnects.get());
    prom_counter(&mut out, "net_frames_in", "wire frames decoded", r.net_frames_in.get());
    prom_counter(&mut out, "net_frames_out", "wire frames queued for write", r.net_frames_out.get());
    prom_counter(&mut out, "net_bytes_in", "payload bytes read off sockets", r.net_bytes_in.get());
    prom_counter(&mut out, "net_bytes_out", "payload bytes written to sockets", r.net_bytes_out.get());
    prom_counter(&mut out, "net_bad_frames", "frames rejected as malformed", r.net_bad_frames.get());

    let _ = writeln!(out, "# HELP mkq_net_rejects_total wire REJECT frames sent, by code");
    let _ = writeln!(out, "# TYPE mkq_net_rejects_total counter");
    for (code, name) in REJECT_NAMES.iter().enumerate().skip(1) {
        let _ = writeln!(
            out,
            "mkq_net_rejects_total{{code=\"{code}\",name=\"{name}\"}} {}",
            r.net_rejects[code].get()
        );
    }

    prom_counter(&mut out, "serve_admitted", "requests admitted into a queue", r.serve_admitted.get());
    prom_counter(&mut out, "serve_served", "requests answered with logits", r.serve_served.get());
    prom_counter(&mut out, "serve_shed_deadline", "queued requests shed past deadline", r.serve_shed_deadline.get());
    prom_counter(&mut out, "serve_failed", "requests failed by backend error/panic", r.serve_failed.get());
    prom_counter(&mut out, "serve_rejected_full", "admissions rejected: queue full", r.serve_rejected_full.get());
    prom_counter(&mut out, "serve_rejected_invalid", "admissions rejected: invalid request", r.serve_rejected_invalid.get());
    prom_counter(&mut out, "serve_rejected_shutdown", "admissions rejected: shutting down", r.serve_rejected_shutdown.get());
    prom_counter(&mut out, "serve_rejected_unavailable", "admissions rejected: model unavailable", r.serve_rejected_unavailable.get());
    prom_counter(&mut out, "serve_batches", "batches executed", r.serve_batches.get());
    prom_counter(&mut out, "serve_padded_tokens", "padding tokens staged into batches", r.serve_padded_tokens.get());
    prom_counter(&mut out, "serve_total_tokens", "total token slots staged into batches", r.serve_total_tokens.get());
    prom_gauge(&mut out, "serve_queue_depth", "requests waiting in slot queues", r.serve_queue_depth.get());

    let labels = r.model_labels_snapshot();

    // per-(model × seq-bucket) batch histograms: only claimed grid cells
    // render, each as a {model,seq}-labeled summary
    let claimed: Vec<(usize, usize, u64)> = (0..MAX_BATCH_MODELS)
        .flat_map(|m| (0..MAX_SEQ_SLOTS).map(move |c| (m, c, r.serve_batch.col_tcap(m, c))))
        .filter(|&(_, _, t)| t != 0)
        .collect();
    if !claimed.is_empty() {
        let _ = writeln!(out, "# HELP mkq_serve_batch_fill_pct batch occupancy percent of bucket capacity, per model x seq bucket");
        let _ = writeln!(out, "# TYPE mkq_serve_batch_fill_pct summary");
        for &(m, c, t) in &claimed {
            let l = model_label_for(&labels, m);
            let h = r.serve_batch.fill(m, c);
            for (q, ql) in [(0.5, "0.5"), (0.9, "0.9"), (0.99, "0.99"), (0.999, "0.999")] {
                let _ = writeln!(out, "mkq_serve_batch_fill_pct{{model=\"{l}\",seq=\"{t}\",quantile=\"{ql}\"}} {:.1}", h.quantile(q));
            }
            let _ = writeln!(out, "mkq_serve_batch_fill_pct_sum{{model=\"{l}\",seq=\"{t}\"}} {}", h.sum());
            let _ = writeln!(out, "mkq_serve_batch_fill_pct_count{{model=\"{l}\",seq=\"{t}\"}} {}", h.count());
        }
        let _ = writeln!(out, "# HELP mkq_serve_batch_exec_us backend forward microseconds per batch, per model x seq bucket");
        let _ = writeln!(out, "# TYPE mkq_serve_batch_exec_us summary");
        for &(m, c, t) in &claimed {
            let l = model_label_for(&labels, m);
            let h = r.serve_batch.exec(m, c);
            for (q, ql) in [(0.5, "0.5"), (0.9, "0.9"), (0.99, "0.99"), (0.999, "0.999")] {
                let _ = writeln!(out, "mkq_serve_batch_exec_us{{model=\"{l}\",seq=\"{t}\",quantile=\"{ql}\"}} {:.1}", h.quantile(q));
            }
            let _ = writeln!(out, "mkq_serve_batch_exec_us_sum{{model=\"{l}\",seq=\"{t}\"}} {}", h.sum());
            let _ = writeln!(out, "mkq_serve_batch_exec_us_count{{model=\"{l}\",seq=\"{t}\"}} {}", h.count());
        }
    }

    // exemplars: join each stage histogram to the worst slow-trace entry
    // for that stage by the request id the OK frame carries
    let traces = r.slow_traces.snapshot();
    let exemplar_for = |value_of: &dyn Fn(&super::trace::TraceEntry) -> u64| -> Option<String> {
        traces.iter().max_by_key(|t| value_of(t)).map(|t| {
            format!(
                " # {{req_id=\"{}\",model=\"{}\",seq=\"{}\",batch=\"{}\"}} {}.0",
                t.id,
                model_label_for(&labels, t.model as usize),
                t.seq_bucket,
                t.batch_size,
                value_of(t)
            )
        })
    };
    prom_hist_ex(&mut out, "stage_queue_us", "request stage: admitted to staged", &r.stage_queue_us, exemplar_for(&|t| t.queue_us));
    prom_hist_ex(&mut out, "stage_exec_us", "request stage: staged to forward complete", &r.stage_exec_us, exemplar_for(&|t| t.exec_us));
    prom_hist_ex(&mut out, "stage_total_us", "wire path: frame read to reply queued", &r.stage_total_us, exemplar_for(&|t| t.total_us));

    // the whole slow-trace ring over the wire, exemplar-joined by req_id
    if !traces.is_empty() {
        let _ = writeln!(out, "# HELP mkq_slow_trace_total_us slowest-trace ring, one row per retained trace (exemplar carries the request id)");
        let _ = writeln!(out, "# TYPE mkq_slow_trace_total_us gauge");
        for (rank, t) in traces.iter().enumerate() {
            let _ = writeln!(
                out,
                "mkq_slow_trace_total_us{{rank=\"{rank}\",model=\"{}\",seq=\"{}\",batch=\"{}\"}} {} # {{req_id=\"{}\"}} {}.0",
                model_label_for(&labels, t.model as usize),
                t.seq_bucket,
                t.batch_size,
                t.total_us,
                t.id,
                t.total_us
            );
        }
    }

    if !labels.is_empty() {
        let _ = writeln!(out, "# HELP mkq_model_version active lifecycle version per model");
        let _ = writeln!(out, "# TYPE mkq_model_version gauge");
        for i in 0..labels.len().min(MAX_MODEL_SLOTS) {
            let l = model_label_for(&labels, i);
            let _ = writeln!(out, "mkq_model_version{{model=\"{l}\"}} {}", r.model_version[i].get());
        }
        let _ = writeln!(out, "# HELP mkq_model_health health state (0 loading, 1 serving, 2 degraded, 3 quarantined, 4 evicted)");
        let _ = writeln!(out, "# TYPE mkq_model_health gauge");
        for i in 0..labels.len().min(MAX_MODEL_SLOTS) {
            let l = model_label_for(&labels, i);
            let _ = writeln!(out, "mkq_model_health{{model=\"{l}\"}} {}", r.model_health[i].get());
        }
        let _ = writeln!(out, "# HELP mkq_model_resident_bytes resident bytes an eviction would free");
        let _ = writeln!(out, "# TYPE mkq_model_resident_bytes gauge");
        for i in 0..labels.len().min(MAX_MODEL_SLOTS) {
            let l = model_label_for(&labels, i);
            let _ = writeln!(out, "mkq_model_resident_bytes{{model=\"{l}\"}} {}", r.model_resident_bytes[i].get());
        }
        let _ = writeln!(out, "# HELP mkq_model_health_transitions_total health state changes");
        let _ = writeln!(out, "# TYPE mkq_model_health_transitions_total counter");
        for i in 0..labels.len().min(MAX_MODEL_SLOTS) {
            let l = model_label_for(&labels, i);
            let _ = writeln!(out, "mkq_model_health_transitions_total{{model=\"{l}\"}} {}", r.model_health_transitions[i].get());
        }
        let _ = writeln!(out, "# HELP mkq_model_reloads_total successful hot reloads");
        let _ = writeln!(out, "# TYPE mkq_model_reloads_total counter");
        for i in 0..labels.len().min(MAX_MODEL_SLOTS) {
            let l = model_label_for(&labels, i);
            let _ = writeln!(out, "mkq_model_reloads_total{{model=\"{l}\"}} {}", r.model_reloads[i].get());
        }
        let _ = writeln!(out, "# HELP mkq_model_evicts_total evictions (budget or admin)");
        let _ = writeln!(out, "# TYPE mkq_model_evicts_total counter");
        for i in 0..labels.len().min(MAX_MODEL_SLOTS) {
            let l = model_label_for(&labels, i);
            let _ = writeln!(out, "mkq_model_evicts_total{{model=\"{l}\"}} {}", r.model_evicts[i].get());
        }
        let _ = writeln!(out, "# HELP mkq_model_forward_failures_total forward errors/panics per model");
        let _ = writeln!(out, "# TYPE mkq_model_forward_failures_total counter");
        for i in 0..labels.len().min(MAX_MODEL_SLOTS) {
            let l = model_label_for(&labels, i);
            let _ = writeln!(out, "mkq_model_forward_failures_total{{model=\"{l}\"}} {}", r.model_forward_failures[i].get());
        }
        let _ = writeln!(out, "# HELP mkq_model_served_total requests answered with logits per model");
        let _ = writeln!(out, "# TYPE mkq_model_served_total counter");
        for i in 0..labels.len().min(MAX_MODEL_SLOTS) {
            let l = model_label_for(&labels, i);
            let _ = writeln!(out, "mkq_model_served_total{{model=\"{l}\"}} {}", r.model_served[i].get());
        }
    }

    prom_gauge(&mut out, "slo_armed", "SLO objectives armed (bit 0 latency, bit 1 error budget)", r.slo_armed.get());
    if r.slo_armed.get() != 0 {
        prom_gauge(&mut out, "slo_latency_target_us", "declared p99 latency target, microseconds", r.slo_latency_target_us.get());
        prom_gauge(&mut out, "slo_error_pct_milli", "declared error budget, percent x1000", r.slo_error_pct_milli.get());
        prom_gauge(&mut out, "slo_latency_burn_fast_milli", "fast-window latency burn rate x1000", r.slo_latency_burn_fast_milli.get());
        prom_gauge(&mut out, "slo_latency_burn_slow_milli", "slow-window latency burn rate x1000", r.slo_latency_burn_slow_milli.get());
        prom_gauge(&mut out, "slo_state_worst", "worst per-model SLO state (0 ok, 1 warning, 2 burning)", r.slo_state_worst.get());
        if !labels.is_empty() {
            let _ = writeln!(out, "# HELP mkq_slo_state per-model SLO state (0 ok, 1 warning, 2 burning)");
            let _ = writeln!(out, "# TYPE mkq_slo_state gauge");
            for i in 0..labels.len().min(MAX_MODEL_SLOTS) {
                let l = model_label_for(&labels, i);
                let _ = writeln!(out, "mkq_slo_state{{model=\"{l}\"}} {}", r.slo_state[i].get());
            }
            let _ = writeln!(out, "# HELP mkq_slo_error_burn_fast_milli fast-window error-budget burn rate x1000");
            let _ = writeln!(out, "# TYPE mkq_slo_error_burn_fast_milli gauge");
            for i in 0..labels.len().min(MAX_MODEL_SLOTS) {
                let l = model_label_for(&labels, i);
                let _ = writeln!(out, "mkq_slo_error_burn_fast_milli{{model=\"{l}\"}} {}", r.slo_error_burn_fast_milli[i].get());
            }
            let _ = writeln!(out, "# HELP mkq_slo_error_burn_slow_milli slow-window error-budget burn rate x1000");
            let _ = writeln!(out, "# TYPE mkq_slo_error_burn_slow_milli gauge");
            for i in 0..labels.len().min(MAX_MODEL_SLOTS) {
                let l = model_label_for(&labels, i);
                let _ = writeln!(out, "mkq_slo_error_burn_slow_milli{{model=\"{l}\"}} {}", r.slo_error_burn_slow_milli[i].get());
            }
        }
    }

    prom_gauge(&mut out, "workers_configured", "execution worker threads (1 = inline loop)", r.workers_configured.get());
    prom_gauge(&mut out, "worker_queue_depth", "batches queued for workers, unclaimed", r.worker_queue_depth.get());
    prom_hist(&mut out, "worker_dispatch_wait_us", "batch staged to claimed by a worker", &r.worker_dispatch_wait_us);
    let n_workers = (r.workers_configured.get() as usize).min(MAX_WORKER_SLOTS);
    if n_workers > 1 {
        let _ = writeln!(out, "# HELP mkq_worker_batches_total batches executed per worker");
        let _ = writeln!(out, "# TYPE mkq_worker_batches_total counter");
        for w in 0..n_workers {
            let _ = writeln!(out, "mkq_worker_batches_total{{worker=\"{w}\"}} {}", r.worker_batches[w].get());
        }
        let _ = writeln!(out, "# HELP mkq_worker_busy 1 while the worker is executing a batch");
        let _ = writeln!(out, "# TYPE mkq_worker_busy gauge");
        for w in 0..n_workers {
            let _ = writeln!(out, "mkq_worker_busy{{worker=\"{w}\"}} {}", r.worker_busy[w].get());
        }
        let _ = writeln!(out, "# HELP mkq_worker_exec_us batch forward microseconds per worker");
        let _ = writeln!(out, "# TYPE mkq_worker_exec_us summary");
        for w in 0..n_workers {
            let h = &r.worker_exec_us[w];
            for (q, label) in [(0.5, "0.5"), (0.9, "0.9"), (0.99, "0.99"), (0.999, "0.999")] {
                let _ = writeln!(out, "mkq_worker_exec_us{{worker=\"{w}\",quantile=\"{label}\"}} {:.1}", h.quantile(q));
            }
            let _ = writeln!(out, "mkq_worker_exec_us_sum{{worker=\"{w}\"}} {}", h.sum());
            let _ = writeln!(out, "mkq_worker_exec_us_count{{worker=\"{w}\"}} {}", h.count());
        }
    }

    let _ = writeln!(out, "# HELP mkq_kernel_calls_total GEMM calls by kernel kind");
    let _ = writeln!(out, "# TYPE mkq_kernel_calls_total counter");
    for (i, name) in crate::kernels::dispatch::KERNEL_SLOT_NAMES.iter().enumerate() {
        let _ = writeln!(out, "mkq_kernel_calls_total{{kind=\"{name}\"}} {}", r.kernel_calls[i].get());
    }
    let _ = writeln!(out, "# HELP mkq_kernel_macs_total multiply-accumulates by kernel kind");
    let _ = writeln!(out, "# TYPE mkq_kernel_macs_total counter");
    for (i, name) in crate::kernels::dispatch::KERNEL_SLOT_NAMES.iter().enumerate() {
        let _ = writeln!(out, "mkq_kernel_macs_total{{kind=\"{name}\"}} {}", r.kernel_macs[i].get());
    }

    out
}

fn json_hist(out: &mut String, name: &str, h: &Histogram) {
    let _ = write!(
        out,
        "\"{name}\": {{\"count\": {}, \"sum\": {}, \"p50\": {:.1}, \"p90\": {:.1}, \"p99\": {:.1}, \"p999\": {:.1}, \"max\": {}}}",
        h.count(),
        h.sum(),
        h.quantile(0.5),
        h.quantile(0.9),
        h.quantile(0.99),
        h.quantile(0.999),
        h.max()
    );
}

/// JSON snapshot of the same series (flat scalar keys so the loadgen
/// scrape can extract fields without a JSON parser).
pub fn render_json() -> String {
    let r = registry();
    let mut out = String::with_capacity(4096);
    out.push_str("{\n");
    let scalars: &[(&str, u64)] = &[
        ("net_accepted_conns", r.net_accepted_conns.get()),
        ("net_rejected_conns", r.net_rejected_conns.get()),
        ("net_disconnects", r.net_disconnects.get()),
        ("net_frames_in", r.net_frames_in.get()),
        ("net_frames_out", r.net_frames_out.get()),
        ("net_bytes_in", r.net_bytes_in.get()),
        ("net_bytes_out", r.net_bytes_out.get()),
        ("net_bad_frames", r.net_bad_frames.get()),
        ("serve_admitted", r.serve_admitted.get()),
        ("serve_served", r.serve_served.get()),
        ("serve_shed_deadline", r.serve_shed_deadline.get()),
        ("serve_failed", r.serve_failed.get()),
        ("serve_rejected_full", r.serve_rejected_full.get()),
        ("serve_rejected_invalid", r.serve_rejected_invalid.get()),
        ("serve_rejected_shutdown", r.serve_rejected_shutdown.get()),
        ("serve_rejected_unavailable", r.serve_rejected_unavailable.get()),
        ("serve_batches", r.serve_batches.get()),
        ("serve_padded_tokens", r.serve_padded_tokens.get()),
        ("serve_total_tokens", r.serve_total_tokens.get()),
        ("serve_queue_depth", r.serve_queue_depth.get()),
        ("workers_configured", r.workers_configured.get()),
        ("worker_queue_depth", r.worker_queue_depth.get()),
        ("slo_armed", r.slo_armed.get()),
        ("slo_state_worst", r.slo_state_worst.get()),
        ("slo_latency_burn_fast_milli", r.slo_latency_burn_fast_milli.get()),
        ("slo_latency_burn_slow_milli", r.slo_latency_burn_slow_milli.get()),
    ];
    for (name, v) in scalars {
        let _ = writeln!(out, "  \"{name}\": {v},");
    }
    out.push_str("  \"net_rejects\": {");
    for (code, name) in REJECT_NAMES.iter().enumerate().skip(1) {
        if code > 1 {
            out.push_str(", ");
        }
        let _ = write!(out, "\"{name}\": {}", r.net_rejects[code].get());
    }
    out.push_str("},\n  \"batch_hists\": [");
    let labels = r.model_labels_snapshot();
    let mut first_cell = true;
    for m in 0..MAX_BATCH_MODELS {
        for c in 0..MAX_SEQ_SLOTS {
            let t = r.serve_batch.col_tcap(m, c);
            if t == 0 {
                continue;
            }
            if !first_cell {
                out.push_str(", ");
            }
            first_cell = false;
            let fill = r.serve_batch.fill(m, c);
            let exec = r.serve_batch.exec(m, c);
            let _ = write!(
                out,
                "{{\"model\": \"{}\", \"seq\": {t}, \"batches\": {}, \"fill_p50\": {:.1}, \"exec_p50_us\": {:.1}, \"exec_p99_us\": {:.1}}}",
                model_label_for(&labels, m),
                exec.count(),
                fill.quantile(0.5),
                exec.quantile(0.5),
                exec.quantile(0.99)
            );
        }
    }
    out.push_str("],\n  ");
    json_hist(&mut out, "stage_queue_us", &r.stage_queue_us);
    out.push_str(",\n  ");
    json_hist(&mut out, "stage_exec_us", &r.stage_exec_us);
    out.push_str(",\n  ");
    json_hist(&mut out, "stage_total_us", &r.stage_total_us);
    out.push_str(",\n  ");
    json_hist(&mut out, "worker_dispatch_wait_us", &r.worker_dispatch_wait_us);
    out.push_str(",\n  \"workers\": [");
    let n_workers = (r.workers_configured.get() as usize).min(MAX_WORKER_SLOTS);
    for w in 0..n_workers {
        if w > 0 {
            out.push_str(", ");
        }
        let _ = write!(
            out,
            "{{\"worker\": {w}, \"batches\": {}, \"busy\": {}, \"exec_p50_us\": {:.1}, \"exec_p99_us\": {:.1}}}",
            r.worker_batches[w].get(),
            r.worker_busy[w].get(),
            r.worker_exec_us[w].quantile(0.5),
            r.worker_exec_us[w].quantile(0.99)
        );
    }
    out.push_str("],\n  \"models\": [");
    let labels = r.model_labels_snapshot();
    for i in 0..labels.len().min(MAX_MODEL_SLOTS) {
        if i > 0 {
            out.push_str(", ");
        }
        let _ = write!(
            out,
            "{{\"model\": \"{}\", \"version\": {}, \"health\": {}, \"resident_bytes\": {}, \"transitions\": {}, \"reloads\": {}, \"evicts\": {}, \"forward_failures\": {}, \"served\": {}, \"slo_state\": {}}}",
            model_label_for(&labels, i),
            r.model_version[i].get(),
            r.model_health[i].get(),
            r.model_resident_bytes[i].get(),
            r.model_health_transitions[i].get(),
            r.model_reloads[i].get(),
            r.model_evicts[i].get(),
            r.model_forward_failures[i].get(),
            r.model_served[i].get(),
            r.slo_state[i].get()
        );
    }
    out.push_str("],\n  \"kernels\": [");
    for (i, name) in crate::kernels::dispatch::KERNEL_SLOT_NAMES.iter().enumerate() {
        if i > 0 {
            out.push_str(", ");
        }
        let _ = write!(
            out,
            "{{\"kind\": \"{name}\", \"calls\": {}, \"macs\": {}}}",
            r.kernel_calls[i].get(),
            r.kernel_macs[i].get()
        );
    }
    out.push_str("],\n  \"slow_traces\": ");
    r.slow_traces.render_json(&mut out);
    out.push_str("\n}\n");
    out
}

/// Extract `"name": <u64>` from a flat JSON object (the loadgen-side
/// scrape helper; avoids needing a JSON parser in the client).
pub fn json_u64_field(payload: &str, name: &str) -> Option<u64> {
    let needle = format!("\"{name}\":");
    let at = payload.find(&needle)?;
    let rest = payload[at + needle.len()..].trim_start();
    let end = rest.find(|c: char| !c.is_ascii_digit()).unwrap_or(rest.len());
    rest[..end].parse().ok()
}

/// One-line *cumulative* operator summary (since-start totals). The
/// `--stats-every-secs` loop prints interval deltas instead — see
/// [`super::snapshot::render_statusline_delta`]; this stays for one-shot
/// contexts (end-of-run summaries, tests).
pub fn render_statusline() -> String {
    let r = registry();
    format!(
        "[obs] conns={} admitted={} served={} shed={} failed={} q={} exec_p50={:.0}us queue_p50={:.0}us total_p99={:.0}us",
        r.net_accepted_conns.get(),
        r.serve_admitted.get(),
        r.serve_served.get(),
        r.serve_shed_deadline.get(),
        r.serve_failed.get(),
        r.serve_queue_depth.get(),
        r.stage_exec_us.quantile(0.5),
        r.stage_queue_us.quantile(0.5),
        r.stage_total_us.quantile(0.99),
    )
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn counters_and_gauges() {
        let c = Counter::new();
        c.inc();
        c.add(4);
        assert_eq!(c.get(), 5);
        let g = Gauge::new();
        g.set(42);
        assert_eq!(g.get(), 42);
    }

    #[test]
    fn json_field_extraction() {
        let payload = "{\n  \"serve_served\": 128,\n  \"serve_failed\": 0,\n}";
        assert_eq!(json_u64_field(payload, "serve_served"), Some(128));
        assert_eq!(json_u64_field(payload, "serve_failed"), Some(0));
        assert_eq!(json_u64_field(payload, "missing"), None);
    }

    #[test]
    fn renderers_emit_core_series() {
        let text = render_prometheus();
        for series in [
            "mkq_net_frames_in",
            "mkq_serve_served",
            "mkq_stage_queue_us",
            "mkq_kernel_calls_total",
            "mkq_workers_configured",
            "mkq_worker_queue_depth",
            "mkq_worker_dispatch_wait_us",
        ] {
            assert!(text.contains(series), "missing {series}");
        }
        let json = render_json();
        assert!(json.contains("\"serve_served\""));
        assert!(json.contains("\"slow_traces\""));
        assert!(json.contains("\"workers\""));
    }

    #[test]
    fn batch_grid_claims_and_renders_labeled_cells() {
        let r = registry();
        register_model_label(7, "gridtest");
        r.serve_batch.record(7, 24, 75, 900);
        r.serve_batch.record(7, 24, 50, 700);
        r.serve_batch.record(7, 48, 100, 1800);
        let text = render_prometheus();
        assert!(
            text.contains("mkq_serve_batch_fill_pct{model=\"gridtest\",seq=\"24\""),
            "claimed cell renders with model+seq labels"
        );
        assert!(text.contains("mkq_serve_batch_exec_us{model=\"gridtest\",seq=\"48\""));
        assert!(text.contains("mkq_serve_batch_exec_us_count{model=\"gridtest\",seq=\"24\"} 2"));
        let json = render_json();
        assert!(json.contains("\"batch_hists\""));
        assert!(json.contains("\"seq\": 48"));
    }

    #[test]
    fn stage_exemplars_join_slow_traces_by_req_id() {
        use crate::obs::trace::TraceEntry;
        let r = registry();
        // an unbeatably slow trace so it owns rank 0 and every exemplar
        r.slow_traces.offer(TraceEntry {
            id: 424_242,
            model: 0,
            seq_bucket: 12,
            batch_size: 4,
            queue_us: 1 << 41,
            exec_us: 1 << 42,
            total_us: 1 << 43,
        });
        let text = render_prometheus();
        assert!(text.contains("mkq_slow_trace_total_us{rank=\"0\""), "ring rows render");
        assert!(text.contains("req_id=\"424242\""), "exemplar carries the request id");
        assert!(
            text.contains("mkq_stage_total_us_count") && text.contains(" # {req_id="),
            "stage histogram carries an exemplar"
        );
    }

    #[test]
    fn per_worker_series_render_when_workers_configured() {
        // per-worker rows are gated on the configured count so a
        // single-threaded server's scrape stays compact
        registry().workers_configured.set(3);
        registry().worker_batches[2].inc();
        let text = render_prometheus();
        assert!(text.contains("mkq_worker_batches_total{worker=\"2\"}"));
        assert!(text.contains("mkq_worker_exec_us{worker=\"0\",quantile=\"0.5\"}"));
        let json = render_json();
        assert!(json.contains("\"worker\": 2"));
        registry().workers_configured.set(0);
    }
}
