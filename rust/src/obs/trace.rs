//! Slowest-trace ring: a fixed-size buffer of the slowest recent request
//! traces (stage breakdown per request), kept without heap allocation.
//!
//! Admission is gated by a cached floor (`bar`): a finished request only
//! takes the lock when its total latency beats the slowest set's current
//! minimum, so the steady-state cost is one relaxed load and a compare.
//! Inside, the new trace replaces the current minimum slot (a bounded
//! 32-entry scan) — the buffer always holds the `RING` slowest traces
//! seen since start, newest-wins on ties.

use std::sync::Mutex;
use std::sync::atomic::{AtomicU64, Ordering::Relaxed};

pub const RING: usize = 32;

#[derive(Clone, Copy, Debug, Default)]
pub struct TraceEntry {
    /// Server-assigned request id (0 = empty slot).
    pub id: u64,
    pub model: u16,
    pub seq_bucket: u16,
    pub batch_size: u16,
    pub queue_us: u64,
    pub exec_us: u64,
    pub total_us: u64,
}

impl TraceEntry {
    const EMPTY: TraceEntry =
        TraceEntry { id: 0, model: 0, seq_bucket: 0, batch_size: 0, queue_us: 0, exec_us: 0, total_us: 0 };
}

pub struct SlowTraces {
    entries: Mutex<[TraceEntry; RING]>,
    /// Cached minimum total_us across the ring (0 while not yet full):
    /// the lock-free admission bar.
    bar: AtomicU64,
}

impl Default for SlowTraces {
    fn default() -> Self {
        Self::new()
    }
}

impl SlowTraces {
    pub const fn new() -> Self {
        SlowTraces { entries: Mutex::new([TraceEntry::EMPTY; RING]), bar: AtomicU64::new(0) }
    }

    /// Offer a finished trace; kept only if it beats the current floor.
    #[inline]
    pub fn offer(&self, e: TraceEntry) {
        if e.total_us < self.bar.load(Relaxed) {
            return;
        }
        let mut ring = self.entries.lock().unwrap();
        let mut min_i = 0usize;
        for i in 1..RING {
            if ring[i].total_us < ring[min_i].total_us {
                min_i = i;
            }
        }
        if e.total_us >= ring[min_i].total_us {
            ring[min_i] = e;
            let new_min = ring.iter().map(|t| t.total_us).min().unwrap_or(0);
            self.bar.store(new_min, Relaxed);
        }
    }

    /// Occupied entries, slowest first.
    pub fn snapshot(&self) -> Vec<TraceEntry> {
        let ring = self.entries.lock().unwrap();
        let mut v: Vec<TraceEntry> = ring.iter().copied().filter(|t| t.id != 0).collect();
        v.sort_by(|a, b| b.total_us.cmp(&a.total_us));
        v
    }

    pub fn render_json(&self, out: &mut String) {
        use std::fmt::Write as _;
        out.push('[');
        for (i, t) in self.snapshot().iter().enumerate() {
            if i > 0 {
                out.push_str(", ");
            }
            let _ = write!(
                out,
                "{{\"id\": {}, \"model\": {}, \"seq_bucket\": {}, \"batch_size\": {}, \"queue_us\": {}, \"exec_us\": {}, \"total_us\": {}}}",
                t.id, t.model, t.seq_bucket, t.batch_size, t.queue_us, t.exec_us, t.total_us
            );
        }
        out.push(']');
    }

    pub fn reset(&self) {
        let mut ring = self.entries.lock().unwrap();
        *ring = [TraceEntry::EMPTY; RING];
        self.bar.store(0, Relaxed);
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn entry(id: u64, total_us: u64) -> TraceEntry {
        TraceEntry { id, total_us, ..TraceEntry::default() }
    }

    #[test]
    fn keeps_the_slowest() {
        let s = SlowTraces::new();
        for id in 1..=100u64 {
            s.offer(entry(id, id)); // total_us == id
        }
        let snap = s.snapshot();
        assert_eq!(snap.len(), RING);
        // The 32 slowest of 1..=100 are 69..=100.
        assert!(snap.iter().all(|t| t.total_us >= 69), "floor leaked: {snap:?}");
        assert_eq!(snap[0].total_us, 100);
    }

    #[test]
    fn fast_traces_skip_the_lock_path() {
        let s = SlowTraces::new();
        for id in 1..=RING as u64 {
            s.offer(entry(id, 1000 + id));
        }
        // A fast trace below the bar must not displace anything.
        s.offer(entry(999, 1));
        assert!(s.snapshot().iter().all(|t| t.total_us > 1000));
    }

    #[test]
    fn reset_clears() {
        let s = SlowTraces::new();
        s.offer(entry(1, 10));
        s.reset();
        assert!(s.snapshot().is_empty());
    }
}
