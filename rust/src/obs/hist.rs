//! Lock-free log-linear histogram (HDR-lite).
//!
//! Fixed bucket layout over the `u64` value domain: values below 32 get
//! exact unit-width buckets; every power-of-two octave above that is
//! split into 16 linear sub-buckets, so the relative quantile error from
//! binning is bounded by 1/16 (~6%) plus in-bucket interpolation.
//! Recording is a handful of relaxed atomic RMWs — no locks, no heap —
//! so it is safe on the zero-alloc serve hot path (enforced by
//! `tests/workspace_alloc.rs`). Histograms are mergeable bucket-wise and
//! the running sum saturates instead of wrapping.

use std::sync::atomic::{AtomicU64, Ordering::Relaxed};

/// Sub-bucket resolution: 2^4 = 16 linear slices per octave.
const SUB_BITS: u32 = 4;
const SUB: u64 = 1 << SUB_BITS;
/// Linear region: values `0..2*SUB` map to their own unit bucket.
const LINEAR: u64 = 2 * SUB;
/// 32 linear buckets + 16 per octave for octaves 5..=63.
pub const N_BUCKETS: usize = (LINEAR as usize) + ((63 - SUB_BITS as usize) * SUB as usize);

/// Saturating atomic add (CAS loop; never wraps past `u64::MAX`).
fn sat_add(cell: &AtomicU64, v: u64) {
    let mut cur = cell.load(Relaxed);
    loop {
        let next = cur.saturating_add(v);
        match cell.compare_exchange_weak(cur, next, Relaxed, Relaxed) {
            Ok(_) => return,
            Err(seen) => cur = seen,
        }
    }
}

pub struct Histogram {
    count: AtomicU64,
    sum: AtomicU64,
    min: AtomicU64,
    max: AtomicU64,
    buckets: [AtomicU64; N_BUCKETS],
}

impl Default for Histogram {
    fn default() -> Self {
        Self::new()
    }
}

impl Histogram {
    pub const fn new() -> Self {
        Histogram {
            count: AtomicU64::new(0),
            sum: AtomicU64::new(0),
            min: AtomicU64::new(u64::MAX),
            max: AtomicU64::new(0),
            buckets: [const { AtomicU64::new(0) }; N_BUCKETS],
        }
    }

    /// Bucket index for a value. Total order preserving: monotone in `v`.
    #[inline]
    pub fn bucket_of(v: u64) -> usize {
        if v < LINEAR {
            v as usize
        } else {
            let o = 63 - v.leading_zeros(); // floor(log2 v), >= 5 here
            let sub = (v >> (o - SUB_BITS)) & (SUB - 1);
            LINEAR as usize + ((o - SUB_BITS - 1) as usize) * SUB as usize + sub as usize
        }
    }

    /// Inclusive value range `[lo, hi]` covered by bucket `idx`.
    fn bucket_bounds(idx: usize) -> (u64, u64) {
        if (idx as u64) < LINEAR {
            (idx as u64, idx as u64)
        } else {
            let rel = idx - LINEAR as usize;
            let o = SUB_BITS + 1 + (rel / SUB as usize) as u32;
            let sub = (rel % SUB as usize) as u64;
            let width = 1u64 << (o - SUB_BITS);
            let lo = (1u64 << o) + sub * width;
            (lo, lo + width - 1)
        }
    }

    /// Record one observation. Lock-free, allocation-free, saturating.
    #[inline]
    pub fn record(&self, v: u64) {
        self.buckets[Self::bucket_of(v)].fetch_add(1, Relaxed);
        self.count.fetch_add(1, Relaxed);
        sat_add(&self.sum, v);
        self.min.fetch_min(v, Relaxed);
        self.max.fetch_max(v, Relaxed);
    }

    #[inline]
    pub fn record_us(&self, d: std::time::Duration) {
        self.record(d.as_micros() as u64);
    }

    pub fn count(&self) -> u64 {
        self.count.load(Relaxed)
    }

    pub fn sum(&self) -> u64 {
        self.sum.load(Relaxed)
    }

    pub fn min(&self) -> u64 {
        let m = self.min.load(Relaxed);
        if m == u64::MAX { 0 } else { m }
    }

    pub fn max(&self) -> u64 {
        self.max.load(Relaxed)
    }

    pub fn mean(&self) -> f64 {
        let n = self.count();
        if n == 0 { 0.0 } else { self.sum() as f64 / n as f64 }
    }

    /// Approximate quantile (`q` in `[0, 1]`): nearest-rank bucket walk
    /// with linear interpolation inside the landing bucket, clamped to
    /// the observed min/max.
    pub fn quantile(&self, q: f64) -> f64 {
        let total = self.count();
        if total == 0 {
            return 0.0;
        }
        let q = q.clamp(0.0, 1.0);
        // Nearest-rank: the k-th smallest observation, 1-based.
        let rank = ((q * total as f64).ceil() as u64).clamp(1, total);
        let mut seen = 0u64;
        for (i, b) in self.buckets.iter().enumerate() {
            let c = b.load(Relaxed);
            if c == 0 {
                continue;
            }
            if seen + c >= rank {
                let (lo, hi) = Self::bucket_bounds(i);
                let into = (rank - seen) as f64 / c as f64; // (0, 1]
                let est = lo as f64 + (hi - lo) as f64 * into;
                return est.clamp(self.min() as f64, self.max() as f64);
            }
            seen += c;
        }
        self.max() as f64
    }

    /// Bucket-wise accumulate `other` into `self` (both keep recording).
    pub fn merge_from(&self, other: &Histogram) {
        for (a, b) in self.buckets.iter().zip(other.buckets.iter()) {
            sat_add(a, b.load(Relaxed));
        }
        sat_add(&self.count, other.count.load(Relaxed));
        sat_add(&self.sum, other.sum.load(Relaxed));
        self.min.fetch_min(other.min.load(Relaxed), Relaxed);
        self.max.fetch_max(other.max.load(Relaxed), Relaxed);
    }

    /// Reset every cell to the empty state (not atomic as a whole; callers
    /// must quiesce writers first — used by benches and tests).
    pub fn reset(&self) {
        for b in &self.buckets {
            b.store(0, Relaxed);
        }
        self.count.store(0, Relaxed);
        self.sum.store(0, Relaxed);
        self.min.store(u64::MAX, Relaxed);
        self.max.store(0, Relaxed);
    }

    /// Plain-value image of the current state (relaxed reads; a torn
    /// image across concurrent recording is bucket-consistent enough for
    /// windowed deltas — each cell is individually atomic).
    pub fn snapshot_data(&self) -> HistData {
        let mut out = HistData::new();
        out.count = self.count.load(Relaxed);
        out.sum = self.sum.load(Relaxed);
        for (v, b) in out.buckets.iter_mut().zip(self.buckets.iter()) {
            *v = b.load(Relaxed);
        }
        out
    }
}

/// A plain (non-atomic) image of a [`Histogram`]: the snapshot-ring
/// payload. Two images subtract bucket-wise — the merge operation run in
/// reverse — yielding a window-local histogram that answers quantile
/// queries over just the delta. No min/max: an atomically observed
/// min/max cannot be subtracted, so window quantiles interpolate inside
/// bucket bounds only.
#[derive(Clone)]
pub struct HistData {
    pub count: u64,
    pub sum: u64,
    pub buckets: [u64; N_BUCKETS],
}

impl Default for HistData {
    fn default() -> Self {
        Self::new()
    }
}

impl HistData {
    pub const fn new() -> Self {
        HistData { count: 0, sum: 0, buckets: [0; N_BUCKETS] }
    }

    /// Bucket-wise saturating subtract: `self - earlier`. With `earlier`
    /// captured before `self` from the same monotone histogram, this is
    /// exactly the observations recorded in between.
    pub fn sub(&self, earlier: &HistData) -> HistData {
        let mut out = HistData::new();
        out.count = self.count.saturating_sub(earlier.count);
        out.sum = self.sum.saturating_sub(earlier.sum);
        for i in 0..N_BUCKETS {
            out.buckets[i] = self.buckets[i].saturating_sub(earlier.buckets[i]);
        }
        out
    }

    pub fn mean(&self) -> f64 {
        if self.count == 0 { 0.0 } else { self.sum as f64 / self.count as f64 }
    }

    /// Approximate quantile over the image; same nearest-rank bucket walk
    /// as [`Histogram::quantile`], interpolated inside bucket bounds.
    pub fn quantile(&self, q: f64) -> f64 {
        if self.count == 0 {
            return 0.0;
        }
        let q = q.clamp(0.0, 1.0);
        let rank = ((q * self.count as f64).ceil() as u64).clamp(1, self.count);
        let mut seen = 0u64;
        for (i, &c) in self.buckets.iter().enumerate() {
            if c == 0 {
                continue;
            }
            if seen + c >= rank {
                let (lo, hi) = Histogram::bucket_bounds(i);
                let into = (rank - seen) as f64 / c as f64;
                return lo as f64 + (hi - lo) as f64 * into;
            }
            seen += c;
        }
        0.0
    }

    /// Fraction of observations strictly above `v` (bucket-granular; the
    /// bucket containing `v` contributes its uniform-split share). Drives
    /// the SLO latency burn rate: `frac_above(p99_target) / 0.01`.
    pub fn frac_above(&self, v: u64) -> f64 {
        if self.count == 0 {
            return 0.0;
        }
        let iv = Histogram::bucket_of(v);
        let mut above = 0.0f64;
        for (i, &c) in self.buckets.iter().enumerate().skip(iv) {
            if c == 0 {
                continue;
            }
            if i == iv {
                let (lo, hi) = Histogram::bucket_bounds(i);
                let width = (hi - lo + 1) as f64;
                above += c as f64 * ((hi - v) as f64 / width);
            } else {
                above += c as f64;
            }
        }
        above / self.count as f64
    }
}

/// Atomic image of a histogram: one snapshot-ring slot's copy of a live
/// [`Histogram`]. All cells are relaxed atomics so a seqlock-guarded
/// writer/reader pair never races undefined — a torn read is caught by
/// the slot version, not by the cells.
pub struct HistImage {
    count: AtomicU64,
    sum: AtomicU64,
    buckets: [AtomicU64; N_BUCKETS],
}

impl HistImage {
    pub const fn new() -> Self {
        HistImage {
            count: AtomicU64::new(0),
            sum: AtomicU64::new(0),
            buckets: [const { AtomicU64::new(0) }; N_BUCKETS],
        }
    }

    /// Copy the live histogram's cells into this image (relaxed stores;
    /// zero-alloc, no locks — safe on the capture tick).
    pub fn store_from(&self, h: &Histogram) {
        self.count.store(h.count.load(Relaxed), Relaxed);
        self.sum.store(h.sum.load(Relaxed), Relaxed);
        for (cell, b) in self.buckets.iter().zip(h.buckets.iter()) {
            cell.store(b.load(Relaxed), Relaxed);
        }
    }

    /// Copy this image out into plain values.
    pub fn load_into(&self, out: &mut HistData) {
        out.count = self.count.load(Relaxed);
        out.sum = self.sum.load(Relaxed);
        for (v, cell) in out.buckets.iter_mut().zip(self.buckets.iter()) {
            *v = cell.load(Relaxed);
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn bucket_monotone_and_bounds_consistent() {
        let probes = [
            0u64,
            1,
            2,
            15,
            16,
            31,
            32,
            33,
            47,
            48,
            63,
            64,
            100,
            1000,
            4096,
            65535,
            1 << 20,
            (1 << 40) + 12345,
            u64::MAX,
        ];
        let mut last = 0usize;
        for (n, &v) in probes.iter().enumerate() {
            let i = Histogram::bucket_of(v);
            let (lo, hi) = Histogram::bucket_bounds(i);
            assert!(lo <= v && v <= hi, "v={v} idx={i} lo={lo} hi={hi}");
            if n > 0 {
                assert!(i >= last, "bucket index not monotone at v={v}");
            }
            last = i;
        }
        assert!(Histogram::bucket_of(u64::MAX) < N_BUCKETS);
    }

    #[test]
    fn every_bucket_round_trips() {
        // lo and hi of every bucket must map back to that bucket.
        for i in 0..N_BUCKETS {
            let (lo, hi) = Histogram::bucket_bounds(i);
            assert_eq!(Histogram::bucket_of(lo), i, "lo of bucket {i}");
            assert_eq!(Histogram::bucket_of(hi), i, "hi of bucket {i}");
        }
    }

    #[test]
    fn image_subtract_isolates_the_window() {
        let h = Histogram::new();
        for v in [1u64, 5, 9, 100] {
            h.record(v);
        }
        let base = h.snapshot_data();
        for v in [20u64, 20, 20, 21] {
            h.record(v);
        }
        let delta = h.snapshot_data().sub(&base);
        assert_eq!(delta.count, 4);
        assert_eq!(delta.sum, 81);
        // linear region: exact buckets, exact quantiles
        assert_eq!(delta.quantile(0.5), 20.0);
        assert_eq!(delta.quantile(1.0), 21.0);
        // nothing above 21, everything above 19
        assert_eq!(delta.frac_above(21), 0.0);
        assert_eq!(delta.frac_above(19), 1.0);
    }
}
