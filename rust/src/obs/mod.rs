//! First-class observability, dependency-free (no tracing/prometheus
//! crates — hermetic build).
//!
//! * [`hist`]    — lock-free log-linear histograms (p50/p90/p99/p999,
//!                 mergeable, saturating).
//! * [`metrics`] — the process-wide [`MetricsRegistry`]: one const-init
//!                 static of atomic counters/gauges/histograms, gated by
//!                 `MKQ_METRICS=0`, rendered as Prometheus text or JSON.
//! * [`trace`]   — fixed-size ring of the slowest request traces with
//!                 per-stage breakdown.
//!
//! Hot-path contract: recording into an already-registered series is
//! zero-heap-allocation and lock-free (the slow-trace ring takes a Mutex
//! only when a trace beats the current slowest set — still no
//! allocation). `tests/workspace_alloc.rs` enforces this with a counting
//! global allocator.
//!
//! Scrape surfaces: the METRICS wire frame on the serving port,
//! `mkq-bert admin metrics --addr`, and `--stats-every-secs N` (one-line
//! stderr summary). See README "Observability" for the series table.

pub mod hist;
pub mod metrics;
pub mod trace;

pub use hist::Histogram;
pub use metrics::{
    json_u64_field, metrics, metrics_enabled, register_model_label, registry, render_json,
    render_prometheus, render_statusline, set_metrics_enabled, Counter, Gauge, MetricsRegistry,
    MAX_MODEL_SLOTS, MAX_WORKER_SLOTS, N_KERNEL_SLOTS, N_REJECT_CODES,
};
pub use trace::{SlowTraces, TraceEntry};
