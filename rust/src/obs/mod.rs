//! First-class observability, dependency-free (no tracing/prometheus
//! crates — hermetic build).
//!
//! * [`hist`]     — lock-free log-linear histograms (p50/p90/p99/p999,
//!                  mergeable, saturating) plus plain/atomic images
//!                  ([`HistData`]/[`hist::HistImage`]) whose bucket-wise
//!                  subtract powers windowed deltas.
//! * [`metrics`]  — the process-wide [`MetricsRegistry`]: one const-init
//!                  static of atomic counters/gauges/histograms, gated by
//!                  `MKQ_METRICS=0`, rendered as Prometheus text or JSON
//!                  (with slow-trace exemplars on the stage histograms).
//! * [`trace`]    — fixed-size ring of the slowest request traces with
//!                  per-stage breakdown.
//! * [`snapshot`] — the [`SnapshotRing`]: ~1 s captures of the registry
//!                  serving reset-free windowed rates and window-local
//!                  quantiles (`admin metrics --window`, the METRICS
//!                  frame's trailing `window` field, statusline deltas).
//! * [`slo`]      — declared latency/error objectives evaluated as
//!                  fast/slow burn rates over the snapshot ring
//!                  (`serve-native --slo p99_us=N,error_pct=X`),
//!                  observe-only.
//! * [`flight`]   — the always-on [`FlightRecorder`]: a lock-free ring
//!                  of typed binary lifecycle events, dumped via
//!                  `admin flight-dump` and auto-dumped on quarantine.
//!
//! Hot-path contract: recording into an already-registered series — and
//! into the flight recorder, and the snapshot capture tick — is
//! zero-heap-allocation and lock-free (the slow-trace ring takes a Mutex
//! only when a trace beats the current slowest set — still no
//! allocation). `tests/workspace_alloc.rs` and `tests/obs_window.rs`
//! enforce this with counting global allocators.
//!
//! Scrape surfaces: the METRICS wire frame on the serving port,
//! `mkq-bert admin metrics --addr [--window SECS]`, `admin flight-dump`,
//! and `--stats-every-secs N` (interval-delta statusline). See README
//! "Observability" for the series table.

pub mod flight;
pub mod hist;
pub mod metrics;
pub mod slo;
pub mod snapshot;
pub mod trace;

pub use flight::{auto_dump, flight, FlightEvent, FlightKind, FlightRecorder, FLIGHT_SLOTS};
pub use hist::{HistData, Histogram};
pub use metrics::{
    ensure_model_label, json_u64_field, metrics, metrics_enabled, register_model_label, registry,
    render_json,
    render_prometheus, render_statusline, set_metrics_enabled, BatchHists, Counter, Gauge,
    MetricsRegistry, MAX_BATCH_MODELS, MAX_MODEL_SLOTS, MAX_WORKER_SLOTS, MAX_SEQ_SLOTS,
    N_KERNEL_SLOTS, N_REJECT_CODES,
};
pub use slo::{SloConfig, SloReport, SloState};
pub use snapshot::{
    live_snapshot, render_statusline_delta, render_window, render_window_json, snapshots, unix_us,
    window_delta, SnapData, SnapshotRing, SNAP_SLOTS,
};
pub use trace::{SlowTraces, TraceEntry};
