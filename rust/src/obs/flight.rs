//! Always-on flight recorder: a fixed-size lock-free ring of typed
//! binary events — the serving stack's black box.
//!
//! Every interesting lifecycle edge (admit, reject, batch close with its
//! reason, dispatch, reload, health transition, worker panic) is
//! recorded as three packed `u64` words with zero heap allocation and no
//! locks, so recording rides the serve hot path under the same
//! counting-allocator contract as the metrics registry. The ring holds
//! the last [`FLIGHT_SLOTS`] events; readers get a consistent
//! oldest-first snapshot on demand (`admin flight-dump`) and the ring is
//! auto-dumped to stderr when a model crosses into quarantine — the
//! postmortem for chaos runs.
//!
//! Packing (per event): `w0` = unix µs, `w2` = 64-bit id (request id,
//! model version, or 0), and `w1` = `kind | code<<8 | model<<16 |
//! a<<32 | b<<48` where `a`/`b` are kind-specific u16s:
//!
//! | kind             | code               | a            | b          | id          |
//! |------------------|--------------------|--------------|------------|-------------|
//! | admit            | 0                  | seq bucket   | batch cap  | request id  |
//! | reject           | wire reject code   | seq bucket   | 0          | request id  |
//! | batch-close      | 0 ok/1 failed/2 panicked | seq bucket | batch size | 0       |
//! | dispatch         | 0                  | seq bucket   | batch size | 0           |
//! | reload           | 0                  | 0            | 0          | new version |
//! | health           | 0                  | from state   | to state   | 0           |
//! | worker-panic     | 0                  | worker       | seq bucket | 0           |
//! | evict            | 0                  | 0            | 0          | version     |
//!
//! Consistency: each slot carries a version word equal to `ticket + 1`
//! (0 = never written). A writer zeroes the version, stores the words,
//! then publishes the version with release ordering; readers validate it
//! on both sides of the read. Two writers only share a slot when their
//! tickets are a full ring apart — a torn event under that much
//! wraparound pressure is dropped by the version check with high
//! probability and tolerated as best-effort otherwise (every cell is an
//! atomic, so there is no UB, only a possibly stale line in a dump).

use std::sync::atomic::{fence, AtomicU64, Ordering::Acquire, Ordering::Relaxed, Ordering::Release};

use super::snapshot::unix_us;

/// Ring capacity (events). 1024 × 32 B = 32 KiB of const-init BSS.
pub const FLIGHT_SLOTS: usize = 1024;

#[derive(Clone, Copy, PartialEq, Eq, Debug)]
#[repr(u8)]
pub enum FlightKind {
    Admit = 1,
    Reject = 2,
    BatchClose = 3,
    Dispatch = 4,
    Reload = 5,
    Health = 6,
    WorkerPanic = 7,
    Evict = 8,
}

impl FlightKind {
    pub fn as_u8(self) -> u8 {
        self as u8
    }

    pub fn name(kind: u8) -> &'static str {
        match kind {
            1 => "admit",
            2 => "reject",
            3 => "batch-close",
            4 => "dispatch",
            5 => "reload",
            6 => "health",
            7 => "worker-panic",
            8 => "evict",
            _ => "?",
        }
    }
}

/// Batch-close reasons (the `code` of a `batch-close` event).
pub const CLOSE_OK: u8 = 0;
pub const CLOSE_FAILED: u8 = 1;
pub const CLOSE_PANICKED: u8 = 2;

const CLOSE_NAMES: [&str; 3] = ["ok", "failed", "panicked"];

const HEALTH_NAMES: [&str; 5] = ["loading", "serving", "degraded", "quarantined", "evicted"];

/// One decoded flight event.
#[derive(Clone, Copy, Debug)]
pub struct FlightEvent {
    pub ticket: u64,
    pub at_us: u64,
    pub kind: u8,
    pub code: u8,
    pub model: u16,
    pub a: u16,
    pub b: u16,
    pub id: u64,
}

struct FSlot {
    /// `ticket + 1` once the event is published; 0 while empty/being written.
    ver: AtomicU64,
    w0: AtomicU64,
    w1: AtomicU64,
    w2: AtomicU64,
}

impl FSlot {
    const fn new() -> FSlot {
        FSlot {
            ver: AtomicU64::new(0),
            w0: AtomicU64::new(0),
            w1: AtomicU64::new(0),
            w2: AtomicU64::new(0),
        }
    }
}

pub struct FlightRecorder {
    head: AtomicU64,
    slots: [FSlot; FLIGHT_SLOTS],
}

fn pack(kind: FlightKind, code: u8, model: u16, a: u16, b: u16) -> u64 {
    kind.as_u8() as u64
        | (code as u64) << 8
        | (model as u64) << 16
        | (a as u64) << 32
        | (b as u64) << 48
}

impl FlightRecorder {
    const fn new() -> FlightRecorder {
        FlightRecorder { head: AtomicU64::new(0), slots: [const { FSlot::new() }; FLIGHT_SLOTS] }
    }

    /// Record one event. Lock-free, zero-alloc, multi-writer safe: the
    /// ticket fetch-add gives every writer its own slot unless the ring
    /// wraps a full lap between two racing writers.
    #[inline]
    pub fn record(&self, kind: FlightKind, code: u8, model: u16, a: u16, b: u16, id: u64) {
        let t = self.head.fetch_add(1, Relaxed);
        let s = &self.slots[(t as usize) % FLIGHT_SLOTS];
        s.ver.store(0, Relaxed);
        fence(Release);
        s.w0.store(unix_us(), Relaxed);
        s.w1.store(pack(kind, code, model, a, b), Relaxed);
        s.w2.store(id, Relaxed);
        s.ver.store(t + 1, Release);
    }

    /// Events recorded since process start (not capped by the ring).
    pub fn recorded(&self) -> u64 {
        self.head.load(Acquire)
    }

    /// Consistent oldest-first snapshot of the retained events. Events
    /// overwritten or in flight during the scan are skipped. Allocates
    /// (cold path: dumps and tests only).
    pub fn snapshot(&self) -> Vec<FlightEvent> {
        let head = self.head.load(Acquire);
        let lo = head.saturating_sub(FLIGHT_SLOTS as u64);
        let mut out = Vec::with_capacity((head - lo) as usize);
        for t in lo..head {
            let s = &self.slots[(t as usize) % FLIGHT_SLOTS];
            let v1 = s.ver.load(Acquire);
            if v1 != t + 1 {
                continue;
            }
            let w0 = s.w0.load(Relaxed);
            let w1 = s.w1.load(Relaxed);
            let w2 = s.w2.load(Relaxed);
            fence(Acquire);
            if s.ver.load(Relaxed) != v1 {
                continue;
            }
            out.push(FlightEvent {
                ticket: t,
                at_us: w0,
                kind: (w1 & 0xff) as u8,
                code: ((w1 >> 8) & 0xff) as u8,
                model: ((w1 >> 16) & 0xffff) as u16,
                a: ((w1 >> 32) & 0xffff) as u16,
                b: ((w1 >> 48) & 0xffff) as u16,
                id: w2,
            });
        }
        out
    }
}

static FLIGHT: FlightRecorder = FlightRecorder::new();

/// The process-wide flight recorder.
pub fn flight() -> &'static FlightRecorder {
    &FLIGHT
}

use std::fmt::Write as _;

fn health_name(v: u16) -> &'static str {
    HEALTH_NAMES.get(v as usize).copied().unwrap_or("?")
}

/// Human-readable dump, one line per event, timestamps relative to the
/// oldest retained event.
pub fn render_text(events: &[FlightEvent]) -> String {
    let t0 = events.first().map(|e| e.at_us).unwrap_or(0);
    let mut out = String::with_capacity(events.len() * 72 + 64);
    let _ = writeln!(
        out,
        "[flight] {} events retained (ring capacity {FLIGHT_SLOTS})",
        events.len()
    );
    for e in events {
        let dt = e.at_us.saturating_sub(t0);
        let _ = write!(out, "[flight] +{dt}us {}", FlightKind::name(e.kind));
        match e.kind {
            1 => {
                let _ = write!(out, " model={} seq={} cap={} id={}", e.model, e.a, e.b, e.id);
            }
            2 => {
                let code = super::metrics::REJECT_NAMES
                    .get(e.code as usize)
                    .copied()
                    .unwrap_or("?");
                let _ = write!(out, " code={code} model={} seq={} id={}", e.model, e.a, e.id);
            }
            3 => {
                let reason = CLOSE_NAMES.get(e.code as usize).copied().unwrap_or("?");
                let _ = write!(out, " reason={reason} model={} seq={} n={}", e.model, e.a, e.b);
            }
            4 => {
                let _ = write!(out, " model={} seq={} n={}", e.model, e.a, e.b);
            }
            5 => {
                let _ = write!(out, " model={} v={}", e.model, e.id);
            }
            6 => {
                let _ = write!(
                    out,
                    " model={} {}->{}",
                    e.model,
                    health_name(e.a),
                    health_name(e.b)
                );
            }
            7 => {
                let _ = write!(out, " model={} worker={} seq={}", e.model, e.a, e.b);
            }
            8 => {
                let _ = write!(out, " model={} v={}", e.model, e.id);
            }
            _ => {
                let _ = write!(
                    out,
                    " kind={} code={} model={} a={} b={} id={}",
                    e.kind, e.code, e.model, e.a, e.b, e.id
                );
            }
        }
        out.push('\n');
    }
    out
}

/// Dump the whole retained ring to stderr, tagged with `reason`. Cold
/// path (quarantine transitions, panics) — allocation is fine here.
pub fn auto_dump(reason: &str) {
    let events = flight().snapshot();
    eprintln!("[flight] auto-dump ({reason}):");
    eprint!("{}", render_text(&events));
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn pack_round_trips_all_fields() {
        flight().record(FlightKind::Reject, 9, 31, 512, 77, u64::MAX);
        let evs = flight().snapshot();
        // other unit tests in this binary may record concurrently — find
        // ours by its unmistakable id rather than assuming it is last
        let e = evs
            .iter()
            .rev()
            .find(|e| e.id == u64::MAX && e.a == 512)
            .expect("just recorded");
        assert_eq!(e.kind, FlightKind::Reject.as_u8());
        assert_eq!(e.code, 9);
        assert_eq!(e.model, 31);
        assert_eq!(e.a, 512);
        assert_eq!(e.b, 77);
        assert_eq!(e.id, u64::MAX);
        let text = render_text(&evs);
        assert!(text.contains("reject"), "dump names the kind: {text}");
        assert!(text.contains("code=quarantined"), "reject code is named: {text}");
    }
}
