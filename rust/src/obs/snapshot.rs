//! Windowed telemetry: a lock-free ring of periodic [`MetricsRegistry`]
//! snapshots serving reset-free deltas.
//!
//! PR 8's counters are monotone since process start — a dashboard cannot
//! tell "1M requests ever" from "10k req/s right now". The front door
//! calls [`SnapshotRing::capture`] about once a second; a windowed scrape
//! (`admin metrics --window SECS`, or the trailing `window` field on the
//! METRICS 0x04 frame) then subtracts the newest ring entry at least
//! `window` old from a live read — counters as element-wise saturating
//! subtraction, histograms via [`HistData::sub`] (the `hist.rs`
//! bucket-wise merge run in reverse) — and renders *rates* (req/s,
//! shed/s, bytes/s) plus window-local p50/p99 instead of since-start
//! totals. No counter is ever reset, so concurrent scrapers at different
//! windows never fight.
//!
//! Concurrency contract: **one writer** (the front-door thread owns the
//! capture tick), any readers. Each slot is seqlock-guarded — the
//! version goes odd while the writer copies cells in, readers retry on a
//! torn read. Every cell is an individual relaxed atomic, so a race is a
//! retry, never UB. Capturing is zero-heap-allocation (relaxed stores
//! into const-init statics; enforced by the counting-allocator test in
//! `tests/obs_window.rs`).

use std::sync::atomic::{fence, AtomicU64, Ordering::Acquire, Ordering::Relaxed, Ordering::Release};

use super::hist::{HistData, HistImage};
use super::metrics::{registry, MetricsRegistry, MAX_MODEL_SLOTS};

/// Ring capacity. At the ~1 s capture tick this holds just over a minute
/// of history — enough for the SLO engine's 60 s slow window.
pub const SNAP_SLOTS: usize = 64;

/// Counters captured per snapshot (index-aligned with [`SNAP_NAMES`]).
pub const SNAP_N: usize = 14;

pub const SNAP_NAMES: [&str; SNAP_N] = [
    "net_accepted_conns",
    "net_frames_in",
    "net_frames_out",
    "net_bytes_in",
    "net_bytes_out",
    "serve_admitted",
    "serve_served",
    "serve_shed_deadline",
    "serve_failed",
    "serve_rejected_full",
    "serve_rejected_invalid",
    "serve_batches",
    "serve_total_tokens",
    "serve_padded_tokens",
];

pub const C_ACCEPTED: usize = 0;
pub const C_FRAMES_IN: usize = 1;
pub const C_FRAMES_OUT: usize = 2;
pub const C_BYTES_IN: usize = 3;
pub const C_BYTES_OUT: usize = 4;
pub const C_ADMITTED: usize = 5;
pub const C_SERVED: usize = 6;
pub const C_SHED: usize = 7;
pub const C_FAILED: usize = 8;
pub const C_REJ_FULL: usize = 9;
pub const C_REJ_INVALID: usize = 10;
pub const C_BATCHES: usize = 11;
pub const C_TOTAL_TOKENS: usize = 12;
pub const C_PADDED_TOKENS: usize = 13;

/// Microseconds since the Unix epoch (vDSO clock read — no allocation,
/// safe on any path).
pub fn unix_us() -> u64 {
    std::time::SystemTime::now()
        .duration_since(std::time::UNIX_EPOCH)
        .map(|d| d.as_micros() as u64)
        .unwrap_or(0)
}

fn collect_counters(r: &MetricsRegistry, out: &mut [u64; SNAP_N]) {
    out[C_ACCEPTED] = r.net_accepted_conns.get();
    out[C_FRAMES_IN] = r.net_frames_in.get();
    out[C_FRAMES_OUT] = r.net_frames_out.get();
    out[C_BYTES_IN] = r.net_bytes_in.get();
    out[C_BYTES_OUT] = r.net_bytes_out.get();
    out[C_ADMITTED] = r.serve_admitted.get();
    out[C_SERVED] = r.serve_served.get();
    out[C_SHED] = r.serve_shed_deadline.get();
    out[C_FAILED] = r.serve_failed.get();
    out[C_REJ_FULL] = r.serve_rejected_full.get();
    out[C_REJ_INVALID] = r.serve_rejected_invalid.get();
    out[C_BATCHES] = r.serve_batches.get();
    out[C_TOTAL_TOKENS] = r.serve_total_tokens.get();
    out[C_PADDED_TOKENS] = r.serve_padded_tokens.get();
}

/// One plain-value snapshot (or delta of two snapshots) of the registry.
#[derive(Clone)]
pub struct SnapData {
    /// Capture sequence number (0 for live reads).
    pub ticket: u64,
    /// Unix µs at capture time.
    pub at_us: u64,
    /// Delta span in µs — 0 for absolute captures and unknown bases.
    pub span_us: u64,
    pub counters: [u64; SNAP_N],
    pub model_served: [u64; MAX_MODEL_SLOTS],
    pub model_failures: [u64; MAX_MODEL_SLOTS],
    pub stage_queue_us: HistData,
    pub stage_exec_us: HistData,
    pub stage_total_us: HistData,
}

impl SnapData {
    pub fn new() -> SnapData {
        SnapData {
            ticket: 0,
            at_us: 0,
            span_us: 0,
            counters: [0; SNAP_N],
            model_served: [0; MAX_MODEL_SLOTS],
            model_failures: [0; MAX_MODEL_SLOTS],
            stage_queue_us: HistData::new(),
            stage_exec_us: HistData::new(),
            stage_total_us: HistData::new(),
        }
    }

    /// `self - earlier`, element-wise saturating; `span_us` becomes the
    /// wall-clock distance between the two captures.
    pub fn delta_since(&self, earlier: &SnapData) -> SnapData {
        let mut out = SnapData::new();
        out.ticket = self.ticket;
        out.at_us = self.at_us;
        out.span_us = self.at_us.saturating_sub(earlier.at_us);
        for i in 0..SNAP_N {
            out.counters[i] = self.counters[i].saturating_sub(earlier.counters[i]);
        }
        for i in 0..MAX_MODEL_SLOTS {
            out.model_served[i] = self.model_served[i].saturating_sub(earlier.model_served[i]);
            out.model_failures[i] =
                self.model_failures[i].saturating_sub(earlier.model_failures[i]);
        }
        out.stage_queue_us = self.stage_queue_us.sub(&earlier.stage_queue_us);
        out.stage_exec_us = self.stage_exec_us.sub(&earlier.stage_exec_us);
        out.stage_total_us = self.stage_total_us.sub(&earlier.stage_total_us);
        out
    }

    /// Events per second for one captured counter (0 when the span is
    /// unknown — a delta against nothing is a since-start total, and
    /// rendering it as a rate would lie).
    pub fn rate(&self, idx: usize) -> f64 {
        if self.span_us == 0 {
            0.0
        } else {
            self.counters[idx] as f64 * 1e6 / self.span_us as f64
        }
    }
}

impl Default for SnapData {
    fn default() -> Self {
        Self::new()
    }
}

/// Read the registry directly into a plain snapshot (`ticket` 0,
/// `at_us` now). The minuend of every windowed delta.
pub fn live_snapshot() -> SnapData {
    let r = registry();
    let mut d = SnapData::new();
    d.at_us = unix_us();
    collect_counters(r, &mut d.counters);
    for i in 0..MAX_MODEL_SLOTS {
        d.model_served[i] = r.model_served[i].get();
        d.model_failures[i] = r.model_forward_failures[i].get();
    }
    d.stage_queue_us = r.stage_queue_us.snapshot_data();
    d.stage_exec_us = r.stage_exec_us.snapshot_data();
    d.stage_total_us = r.stage_total_us.snapshot_data();
    d
}

/// One seqlock-guarded ring slot: `ver` goes odd while the writer copies
/// cells, readers retry until they observe the same even version on both
/// sides of the copy.
struct Slot {
    ver: AtomicU64,
    ticket: AtomicU64,
    at_us: AtomicU64,
    counters: [AtomicU64; SNAP_N],
    model_served: [AtomicU64; MAX_MODEL_SLOTS],
    model_failures: [AtomicU64; MAX_MODEL_SLOTS],
    stage_queue_us: HistImage,
    stage_exec_us: HistImage,
    stage_total_us: HistImage,
}

impl Slot {
    const fn new() -> Slot {
        Slot {
            ver: AtomicU64::new(0),
            ticket: AtomicU64::new(0),
            at_us: AtomicU64::new(0),
            counters: [const { AtomicU64::new(0) }; SNAP_N],
            model_served: [const { AtomicU64::new(0) }; MAX_MODEL_SLOTS],
            model_failures: [const { AtomicU64::new(0) }; MAX_MODEL_SLOTS],
            stage_queue_us: HistImage::new(),
            stage_exec_us: HistImage::new(),
            stage_total_us: HistImage::new(),
        }
    }
}

pub struct SnapshotRing {
    /// Tickets issued; capture `t` (1-based) lives at slot `(t-1) % SNAP_SLOTS`.
    head: AtomicU64,
    slots: [Slot; SNAP_SLOTS],
}

impl SnapshotRing {
    const fn new() -> SnapshotRing {
        SnapshotRing { head: AtomicU64::new(0), slots: [const { Slot::new() }; SNAP_SLOTS] }
    }

    /// Capture the registry into the next ring slot. Single-writer (the
    /// front-door capture tick; tests serialize). Zero-alloc.
    pub fn capture(&self) {
        let r = registry();
        let t = self.head.load(Relaxed) + 1;
        let slot = &self.slots[((t - 1) as usize) % SNAP_SLOTS];
        let v0 = slot.ver.load(Relaxed);
        slot.ver.store(v0.wrapping_add(1), Relaxed); // odd: write in progress
        fence(Release);
        slot.ticket.store(t, Relaxed);
        slot.at_us.store(unix_us(), Relaxed);
        let mut c = [0u64; SNAP_N];
        collect_counters(r, &mut c);
        for (cell, v) in slot.counters.iter().zip(c.iter()) {
            cell.store(*v, Relaxed);
        }
        for i in 0..MAX_MODEL_SLOTS {
            slot.model_served[i].store(r.model_served[i].get(), Relaxed);
            slot.model_failures[i].store(r.model_forward_failures[i].get(), Relaxed);
        }
        slot.stage_queue_us.store_from(&r.stage_queue_us);
        slot.stage_exec_us.store_from(&r.stage_exec_us);
        slot.stage_total_us.store_from(&r.stage_total_us);
        slot.ver.store(v0.wrapping_add(2), Release);
        self.head.store(t, Release);
    }

    /// Number of captures taken so far.
    pub fn captures(&self) -> u64 {
        self.head.load(Acquire)
    }

    fn read_ticket(&self, t: u64) -> Option<SnapData> {
        if t == 0 {
            return None;
        }
        let slot = &self.slots[((t - 1) as usize) % SNAP_SLOTS];
        for _ in 0..4 {
            let v1 = slot.ver.load(Acquire);
            if v1 & 1 != 0 {
                std::hint::spin_loop();
                continue;
            }
            let mut d = SnapData::new();
            d.ticket = slot.ticket.load(Relaxed);
            d.at_us = slot.at_us.load(Relaxed);
            for (v, cell) in d.counters.iter_mut().zip(slot.counters.iter()) {
                *v = cell.load(Relaxed);
            }
            for i in 0..MAX_MODEL_SLOTS {
                d.model_served[i] = slot.model_served[i].load(Relaxed);
                d.model_failures[i] = slot.model_failures[i].load(Relaxed);
            }
            slot.stage_queue_us.load_into(&mut d.stage_queue_us);
            slot.stage_exec_us.load_into(&mut d.stage_exec_us);
            slot.stage_total_us.load_into(&mut d.stage_total_us);
            fence(Acquire);
            if slot.ver.load(Relaxed) == v1 && d.ticket == t {
                return Some(d);
            }
        }
        None
    }

    /// Most recent capture, if any.
    pub fn latest(&self) -> Option<SnapData> {
        self.read_ticket(self.head.load(Acquire))
    }

    /// The newest capture at least `window_us` old relative to `now_us`.
    /// Falls back to the *oldest* retained capture when the ring's
    /// history is shorter than the window (the delta then covers the
    /// whole retained span — `span_us` reports what it actually covers).
    pub fn window_base(&self, now_us: u64, window_us: u64) -> Option<SnapData> {
        let head = self.head.load(Acquire);
        if head == 0 {
            return None;
        }
        let cutoff = now_us.saturating_sub(window_us);
        let lo = if head > SNAP_SLOTS as u64 { head - SNAP_SLOTS as u64 + 1 } else { 1 };
        let mut fallback = None;
        let mut t = head;
        loop {
            let Some(s) = self.read_ticket(t) else { break };
            if s.at_us <= cutoff {
                return Some(s);
            }
            fallback = Some(s);
            if t == lo {
                break;
            }
            t -= 1;
        }
        fallback
    }
}

static RING: SnapshotRing = SnapshotRing::new();

/// The process-wide snapshot ring.
pub fn snapshots() -> &'static SnapshotRing {
    &RING
}

/// Live registry minus the best base for a trailing window of
/// `window_secs` (0 = since the most recent capture). When no capture
/// exists at all, the result is the since-start totals with `span_us` 0.
pub fn window_delta(window_secs: u32) -> SnapData {
    let cur = live_snapshot();
    match snapshots().window_base(cur.at_us, (window_secs as u64) * 1_000_000) {
        Some(base) => cur.delta_since(&base),
        None => cur,
    }
}

// ---------------------------------------------------------------------
// Rendering
// ---------------------------------------------------------------------

use std::fmt::Write as _;

const RATE_SERIES: [(usize, &str, &str); 10] = [
    (C_ADMITTED, "admitted", "requests admitted per second over the window"),
    (C_SERVED, "served", "requests served per second over the window"),
    (C_SHED, "shed", "deadline sheds per second over the window"),
    (C_FAILED, "failed", "backend failures per second over the window"),
    (C_REJ_FULL, "rejected_full", "queue-full rejects per second over the window"),
    (C_BATCHES, "batches", "batches executed per second over the window"),
    (C_FRAMES_IN, "frames_in", "wire frames decoded per second over the window"),
    (C_FRAMES_OUT, "frames_out", "wire frames written per second over the window"),
    (C_BYTES_IN, "bytes_in", "payload bytes read per second over the window"),
    (C_BYTES_OUT, "bytes_out", "payload bytes written per second over the window"),
];

fn prom_window_hist(out: &mut String, name: &str, help: &str, h: &HistData) {
    let _ = writeln!(out, "# HELP mkq_window_{name} {help}");
    let _ = writeln!(out, "# TYPE mkq_window_{name} summary");
    for (q, label) in [(0.5, "0.5"), (0.9, "0.9"), (0.99, "0.99"), (0.999, "0.999")] {
        let _ = writeln!(out, "mkq_window_{name}{{quantile=\"{label}\"}} {:.1}", h.quantile(q));
    }
    let _ = writeln!(out, "mkq_window_{name}_sum {}", h.sum);
    let _ = writeln!(out, "mkq_window_{name}_count {}", h.count);
}

/// Prometheus text exposition of one windowed delta: rate gauges plus
/// window-local stage quantiles. Series are `mkq_window_*`, disjoint
/// from the since-start names, so both views coexist on one dashboard.
pub fn render_window(window_secs: u32) -> String {
    let d = window_delta(window_secs);
    let secs = d.span_us as f64 / 1e6;
    let mut out = String::with_capacity(4096);
    let _ = writeln!(
        out,
        "# windowed delta: requested {window_secs}s, actual span {secs:.3}s (ring history caps the window)"
    );
    let _ = writeln!(out, "# HELP mkq_window_seconds actual wall-clock span the delta covers");
    let _ = writeln!(out, "# TYPE mkq_window_seconds gauge");
    let _ = writeln!(out, "mkq_window_seconds {secs:.3}");
    for (idx, name, help) in RATE_SERIES {
        let _ = writeln!(out, "# HELP mkq_window_{name}_per_sec {help}");
        let _ = writeln!(out, "# TYPE mkq_window_{name}_per_sec gauge");
        let _ = writeln!(out, "mkq_window_{name}_per_sec {:.1}", d.rate(idx));
    }
    prom_window_hist(&mut out, "stage_queue_us", "window-local: admitted to staged", &d.stage_queue_us);
    prom_window_hist(&mut out, "stage_exec_us", "window-local: staged to forward complete", &d.stage_exec_us);
    prom_window_hist(&mut out, "stage_total_us", "window-local: frame read to reply queued", &d.stage_total_us);
    out
}

fn json_window_hist(out: &mut String, name: &str, h: &HistData) {
    let _ = write!(
        out,
        "\"{name}\": {{\"count\": {}, \"sum\": {}, \"p50\": {:.1}, \"p90\": {:.1}, \"p99\": {:.1}, \"p999\": {:.1}}}",
        h.count,
        h.sum,
        h.quantile(0.5),
        h.quantile(0.9),
        h.quantile(0.99),
        h.quantile(0.999)
    );
}

/// JSON rendering of the same windowed delta: raw deltas (`win_*`),
/// rates (`win_*_per_sec`), and window-local stage histograms. Flat keys
/// so `json_u64_field` keeps working client-side.
pub fn render_window_json(window_secs: u32) -> String {
    let d = window_delta(window_secs);
    let mut out = String::with_capacity(2048);
    out.push_str("{\n");
    let _ = writeln!(out, "  \"window_requested_secs\": {window_secs},");
    let _ = writeln!(out, "  \"window_span_us\": {},", d.span_us);
    for (idx, name) in SNAP_NAMES.iter().enumerate() {
        let _ = writeln!(out, "  \"win_{name}\": {},", d.counters[idx]);
    }
    for (idx, name, _) in RATE_SERIES {
        let _ = writeln!(out, "  \"win_{name}_per_sec\": {:.2},", d.rate(idx));
    }
    out.push_str("  ");
    json_window_hist(&mut out, "win_stage_queue_us", &d.stage_queue_us);
    out.push_str(",\n  ");
    json_window_hist(&mut out, "win_stage_exec_us", &d.stage_exec_us);
    out.push_str(",\n  ");
    json_window_hist(&mut out, "win_stage_total_us", &d.stage_total_us);
    out.push_str("\n}\n");
    out
}

/// Interval-delta statusline for `--stats-every-secs`: rates since the
/// previous line (not since process start) plus window-local stage
/// quantiles, with the SLO verdict appended when objectives are armed.
pub fn render_statusline_delta(prev: &SnapData, cur: &SnapData) -> String {
    let d = cur.delta_since(prev);
    let r = registry();
    let slo = if r.slo_armed.get() != 0 {
        let state = super::slo::SloState::from_u8(r.slo_state_worst.get() as u8);
        format!(
            " slo={} burn_fast={:.2}",
            state.name(),
            r.slo_latency_burn_fast_milli.get() as f64 / 1000.0
        )
    } else {
        String::new()
    };
    format!(
        "[obs] win={:.1}s admit/s={:.0} served/s={:.0} shed/s={:.0} failed/s={:.0} q={} queue_p50={:.0}us exec_p50={:.0}us total_p99={:.0}us{slo}",
        d.span_us as f64 / 1e6,
        d.rate(C_ADMITTED),
        d.rate(C_SERVED),
        d.rate(C_SHED),
        d.rate(C_FAILED),
        r.serve_queue_depth.get(),
        d.stage_queue_us.quantile(0.5),
        d.stage_exec_us.quantile(0.5),
        d.stage_total_us.quantile(0.99),
    )
}
