//! Synthetic GLUE-analogue task generators (DESIGN.md §Substitutions).
//!
//! Six binary classification tasks mirroring the structure of the GLUE
//! tasks the paper evaluates (Table 1): two single-sentence tasks and
//! four sentence-pair tasks. Each label depends on a *compositional*
//! property a small transformer can learn (sentiment majority, word
//! order, lexical entailment through a synonym map, pair matching), not
//! on a single token — so accuracy degrades smoothly as quantization
//! coarsens the representation, which is the behaviour Table 1 measures.
//!
//! Relative dataset sizes follow GLUE (RTE smallest … QQP/QNLI largest),
//! which matters for the paper's §5.5 observation that LSQ helps most on
//! tasks with more steps (QNLI/QQP).

use super::lexicon::Lexicon;
use crate::util::rng::Rng;

#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash)]
pub enum TaskKind {
    Rte,
    Mrpc,
    Cola,
    Sst2,
    Qnli,
    Qqp,
}

pub const ALL_TASKS: [TaskKind; 6] =
    [TaskKind::Rte, TaskKind::Mrpc, TaskKind::Cola, TaskKind::Sst2, TaskKind::Qnli, TaskKind::Qqp];

impl TaskKind {
    pub fn name(&self) -> &'static str {
        match self {
            TaskKind::Rte => "rte",
            TaskKind::Mrpc => "mrpc",
            TaskKind::Cola => "cola",
            TaskKind::Sst2 => "sst2",
            TaskKind::Qnli => "qnli",
            TaskKind::Qqp => "qqp",
        }
    }

    pub fn parse(s: &str) -> Option<Self> {
        ALL_TASKS.iter().copied().find(|t| t.name() == s)
    }

    /// (train, dev) sizes — GLUE-relative (RTE tiny … QQP large).
    pub fn sizes(&self) -> (usize, usize) {
        match self {
            TaskKind::Rte => (1200, 400),
            TaskKind::Mrpc => (1800, 400),
            TaskKind::Cola => (2000, 400),
            TaskKind::Sst2 => (2500, 400),
            TaskKind::Qnli => (4000, 400),
            TaskKind::Qqp => (4000, 400),
        }
    }

    pub fn is_pair(&self) -> bool {
        !matches!(self, TaskKind::Cola | TaskKind::Sst2)
    }
}

#[derive(Debug, Clone)]
pub struct Example {
    pub text_a: Vec<String>,
    pub text_b: Option<Vec<String>>,
    pub label: i32,
}

/// A fact triple (subject, verb, object) — the semantic unit behind the
/// pair tasks.
fn triple(lex: &Lexicon, rng: &mut Rng) -> (String, String, String) {
    (
        lex.nouns[rng.below(lex.nouns.len())].clone(),
        lex.verbs[rng.below(lex.verbs.len())].clone(),
        lex.nouns[rng.below(lex.nouns.len())].clone(),
    )
}

fn sentence_of(t: &(String, String, String), lex: &Lexicon, rng: &mut Rng) -> Vec<String> {
    let mut s = vec![lex.determiners[rng.below(lex.determiners.len())].clone()];
    if rng.bool(0.4) {
        s.push(lex.adjectives[rng.below(lex.adjectives.len())].clone());
    }
    s.push(t.0.clone());
    s.push(t.1.clone());
    s.push(lex.determiners[rng.below(lex.determiners.len())].clone());
    s.push(t.2.clone());
    s
}

/// Rewrite a triple through the synonym map (preserves meaning by
/// construction) — the "paraphrase"/"entailment" positive transform.
/// Each content slot is rewritten with p=0.7: most positives share little
/// surface form with the source (the model must internalize the synonym
/// pairing — a capacity-bound skill that 4-bit quantization erodes),
/// while the overlap minority provides the bootstrap gradient.
fn synonymize(t: &(String, String, String), lex: &Lexicon, rng: &mut Rng) -> (String, String, String) {
    let mut out = t.clone();
    if rng.bool(0.5) {
        out.0 = lex.synonym(&out.0).to_string();
    }
    if rng.bool(0.5) {
        out.1 = lex.synonym(&out.1).to_string();
    }
    if rng.bool(0.5) {
        out.2 = lex.synonym(&out.2).to_string();
    }
    out
}

/// Corrupt TWO of the three slots with unrelated words (guaranteed not the
/// original or its synonym) — the negative transform. Two corruptions keep
/// a weak surface-overlap gradient for bootstrap (positives overlap more),
/// while fully separating the classes still requires the synonym pairing
/// (the capacity-bound skill 4-bit quantization erodes).
fn corrupt(t: &(String, String, String), lex: &Lexicon, rng: &mut Rng) -> (String, String, String) {
    let mut out = t.clone();
    let keep = rng.below(3);
    let fresh_noun = |orig: &String, rng: &mut Rng| loop {
        let cand = lex.nouns[rng.below(lex.nouns.len())].clone();
        if &cand != orig && lex.synonym(orig) != cand {
            return cand;
        }
    };
    let fresh_verb = |orig: &String, rng: &mut Rng| loop {
        let cand = lex.verbs[rng.below(lex.verbs.len())].clone();
        if &cand != orig && lex.synonym(orig) != cand {
            return cand;
        }
    };
    if keep != 0 {
        out.0 = fresh_noun(&t.0, rng);
    }
    if keep != 1 {
        out.1 = fresh_verb(&t.1, rng);
    }
    if keep != 2 {
        out.2 = fresh_noun(&t.2, rng);
    }
    out
}

pub fn generate(kind: TaskKind, lex: &Lexicon, rng: &mut Rng, count: usize) -> Vec<Example> {
    (0..count)
        .map(|_| match kind {
            TaskKind::Sst2 => gen_sst2(lex, rng),
            TaskKind::Cola => gen_cola(lex, rng),
            TaskKind::Rte => gen_rte(lex, rng),
            TaskKind::Mrpc => gen_mrpc(lex, rng),
            TaskKind::Qnli => gen_qnli(lex, rng),
            TaskKind::Qqp => gen_qqp(lex, rng),
        })
        .collect()
}

/// SST-2 analogue with a compositional twist: base sentiment is the
/// majority sign over pos/neg lexicon words (margin exactly 1 — the hard
/// case), and a negator token, present half the time, FLIPS the label.
/// The model must learn the sign×negation interaction, not a bag-of-words
/// shortcut — this is what makes the task sensitive to 4-bit capacity
/// loss (Table 1's degradation axis).
fn gen_sst2(lex: &Lexicon, rng: &mut Rng) -> Example {
    let base = rng.bool(0.5);
    // 25%: word identity alone gives a 75%-accuracy bootstrap ramp; the
    // remaining 25 points require the negation interaction.
    let negated = rng.bool(0.25);
    let label = (base ^ negated) as i32;
    let (many, few) = if base {
        (&lex.pos_words, &lex.neg_words)
    } else {
        (&lex.neg_words, &lex.pos_words)
    };
    let n_few = rng.range(1, 3);
    let n_many = n_few + 1; // always margin 1
    let mut words: Vec<String> = Vec::new();
    for _ in 0..n_many {
        words.push(many[rng.below(many.len())].clone());
    }
    for _ in 0..n_few {
        words.push(few[rng.below(few.len())].clone());
    }
    if negated {
        words.push(lex.negators[rng.below(lex.negators.len())].clone());
    }
    for _ in 0..rng.range(3, 6) {
        words.push(lex.neutral[rng.below(lex.neutral.len())].clone());
    }
    rng.shuffle(&mut words);
    Example { text_a: words, text_b: None, label }
}

/// CoLA analogue: acceptability = canonical DET (ADJ) N V DET N order;
/// negatives swap ONE adjacent word pair — a minimal, local violation the
/// model can only catch by modelling word order, not word identity.
fn gen_cola(lex: &Lexicon, rng: &mut Rng) -> Example {
    let t = triple(lex, rng);
    let good = sentence_of(&t, lex, rng);
    if rng.bool(0.5) {
        Example { text_a: good, text_b: None, label: 1 }
    } else {
        let mut bad = good.clone();
        while bad == good {
            let i = rng.below(bad.len() - 1);
            bad.swap(i, i + 1);
        }
        Example { text_a: bad, text_b: None, label: 0 }
    }
}

// The four pair tasks all test the same circuit — "does a key token in
// segment A co-occur (mod synonymy) with segment B?" — over closed classes
// of increasing size. Open-class identity matching does not train from
// scratch at this model scale (see DESIGN.md §Substitutions: we measured
// flat CE over 1600 steps), while closed-class co-occurrence conjunctions
// do, and they degrade measurably under 4-bit quantization. Difficulty
// gradient: QNLI (4 keys) < QQP (8) < RTE (40, synonym-closed) < MRPC (60,
// synonym-closed) — mirroring real GLUE where small models post their
// weakest scores on RTE/MRPC (paper Table 1: RTE 67.5).

/// RTE analogue: entailed iff the hypothesis verb is the premise verb or
/// its synonym. The verb is drawn from a 12-verb synonym-closed subclass
/// (6 pairs): matching mod synonymy over a small class is learnable at
/// this scale but still needs the pairing knowledge, unlike QNLI/QQP's
/// pure identity match.
const RTE_VERBS: usize = 12;

fn gen_rte(lex: &Lexicon, rng: &mut Rng) -> Example {
    let mut t = triple(lex, rng);
    t.1 = lex.verbs[rng.below(RTE_VERBS)].clone();
    let premise = sentence_of(&t, lex, rng);
    let label = rng.bool(0.5) as i32;
    let hyp_t = if label == 1 {
        synonymize(&t, lex, rng)
    } else {
        // same structure, unrelated verb (subject/object may survive)
        let mut bad = synonymize(&t, lex, rng);
        loop {
            let cand = lex.verbs[rng.below(RTE_VERBS)].clone();
            if cand != t.1 && lex.synonym(&t.1) != cand {
                bad.1 = cand;
                break;
            }
        }
        bad
    };
    let hypothesis = sentence_of(&hyp_t, lex, rng);
    Example { text_a: premise, text_b: Some(hypothesis), label }
}

/// MRPC analogue: paraphrase iff the subject noun matches mod synonymy
/// over a 16-noun synonym-closed subclass (8 pairs) — the hardest matching
/// task in the suite (larger class than RTE, no identity shortcut).
const MRPC_NOUNS: usize = 16;

fn gen_mrpc(lex: &Lexicon, rng: &mut Rng) -> Example {
    let mut t = triple(lex, rng);
    t.0 = lex.nouns[rng.below(MRPC_NOUNS)].clone();
    let a = sentence_of(&t, lex, rng);
    let label = rng.bool(0.5) as i32;
    let mut t2 = synonymize(&t, lex, rng);
    if label == 0 {
        loop {
            let cand = lex.nouns[rng.below(MRPC_NOUNS)].clone();
            if cand != t.0 && lex.synonym(&t.0) != cand {
                t2.0 = cand;
                break;
            }
        }
    }
    let b = sentence_of(&t2, lex, rng);
    Example { text_a: a, text_b: Some(b), label }
}

/// QNLI analogue: the question opens with a wh-word (4-word closed class);
/// the answer sentence carries an echo marker — answerable iff the echo
/// matches the question's wh-word.
fn gen_qnli(lex: &Lexicon, rng: &mut Rng) -> Example {
    let t = triple(lex, rng);
    let wh = rng.below(lex.wh_words.len());
    let q = vec![lex.wh_words[wh].clone(), t.1.clone(), t.2.clone()];
    let label = rng.bool(0.5) as i32;
    let echo = if label == 1 {
        lex.wh_words[wh].clone()
    } else {
        let mut other = rng.below(lex.wh_words.len());
        while other == wh {
            other = rng.below(lex.wh_words.len());
        }
        lex.wh_words[other].clone()
    };
    let ans_t = if label == 1 { synonymize(&t, lex, rng) } else { corrupt(&t, lex, rng) };
    let mut ans = sentence_of(&ans_t, lex, rng);
    ans.insert(rng.below(ans.len() + 1).min(ans.len()), echo);
    Example { text_a: q, text_b: Some(ans), label }
}

/// QQP analogue: both questions carry a topic token from an 8-word closed
/// class; duplicates share the topic (content synonymized), non-duplicates
/// differ in topic (content corrupted).
fn gen_qqp(lex: &Lexicon, rng: &mut Rng) -> Example {
    let topics = &lex.neutral[..8];
    let t = triple(lex, rng);
    let topic = rng.below(topics.len());
    let mk_q = |topic_w: &str, t: &(String, String, String), rng: &mut Rng| {
        vec![
            lex.wh_words[rng.below(lex.wh_words.len())].clone(),
            topic_w.to_string(),
            t.0.clone(),
            t.1.clone(),
            t.2.clone(),
        ]
    };
    let a = mk_q(&topics[topic], &t, rng);
    let label = rng.bool(0.5) as i32;
    let (topic_b, t2) = if label == 1 {
        (topic, synonymize(&t, lex, rng))
    } else {
        let mut other = rng.below(topics.len());
        while other == topic {
            other = rng.below(topics.len());
        }
        (other, corrupt(&t, lex, rng))
    };
    let b = mk_q(&topics[topic_b], &t2, rng);
    Example { text_a: a, text_b: Some(b), label }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn setup() -> (Lexicon, Rng) {
        (Lexicon::new(11), Rng::new(22))
    }

    #[test]
    fn all_tasks_generate() {
        let (lex, mut rng) = setup();
        for kind in ALL_TASKS {
            let ex = generate(kind, &lex, &mut rng, 50);
            assert_eq!(ex.len(), 50);
            for e in &ex {
                assert!(e.label == 0 || e.label == 1);
                assert!(!e.text_a.is_empty());
                assert_eq!(e.text_b.is_some(), kind.is_pair(), "{kind:?}");
            }
        }
    }

    #[test]
    fn labels_roughly_balanced() {
        let (lex, mut rng) = setup();
        for kind in ALL_TASKS {
            let ex = generate(kind, &lex, &mut rng, 400);
            let pos: usize = ex.iter().filter(|e| e.label == 1).count();
            assert!((120..=280).contains(&pos), "{kind:?}: {pos}/400");
        }
    }

    #[test]
    fn sst2_majority_with_negation_holds() {
        let (lex, mut rng) = setup();
        for _ in 0..200 {
            let e = gen_sst2(&lex, &mut rng);
            let pos = e.text_a.iter().filter(|w| lex.pos_words.contains(w)).count();
            let neg = e.text_a.iter().filter(|w| lex.neg_words.contains(w)).count();
            let base = pos > neg;
            let negated = e.text_a.iter().any(|w| lex.negators.contains(w));
            assert_eq!(e.label == 1, base ^ negated);
            assert_eq!(pos.abs_diff(neg), 1, "margin must be exactly 1");
        }
    }

    #[test]
    fn rte_entailment_is_synonym_consistent() {
        let (lex, mut rng) = setup();
        for _ in 0..200 {
            let e = gen_rte(&lex, &mut rng);
            if e.label == 1 {
                // every content word of the hypothesis must have (a synonym
                // of) itself in the premise
                let hyp = e.text_b.as_ref().unwrap();
                let content: Vec<&String> = hyp
                    .iter()
                    .filter(|w| lex.nouns.contains(w) || lex.verbs.contains(w))
                    .collect();
                assert!(!content.is_empty());
                for w in content {
                    let syn = lex.synonym(w).to_string();
                    assert!(
                        e.text_a.contains(w) || e.text_a.contains(&syn),
                        "hypothesis word {w} unsupported by premise"
                    );
                }
            }
        }
    }

    #[test]
    fn cola_negatives_differ_from_canonical() {
        let (lex, mut rng) = setup();
        for _ in 0..100 {
            let e = gen_cola(&lex, &mut rng);
            if e.label == 0 {
                // first word being a determiner AND later det-noun pattern is
                // unlikely after shuffle; just assert it differs from sorted
                // canonical reconstruction by checking shuffle happened:
                assert!(e.text_a.len() >= 5);
            }
        }
    }

    #[test]
    fn deterministic_given_seed() {
        let lex = Lexicon::new(11);
        let a = generate(TaskKind::Qqp, &lex, &mut Rng::new(5), 20);
        let b = generate(TaskKind::Qqp, &lex, &mut Rng::new(5), 20);
        for (x, y) in a.iter().zip(b.iter()) {
            assert_eq!(x.text_a, y.text_a);
            assert_eq!(x.label, y.label);
        }
    }
}
