//! Tokenized datasets and batch iteration.
//!
//! `Dataset` holds pre-tokenized (ids, mask, label) rows; `BatchIter`
//! yields fixed-size batches with epoch reshuffling, and `stack_k` builds
//! the [K, B, T] stacked tensors the K-step scan artifacts consume.

use crate::runtime::HostTensor;
use crate::tokenizer::Tokenizer;
use crate::util::rng::Rng;

use super::tasks::Example;

#[derive(Debug, Clone)]
pub struct Dataset {
    pub seq_len: usize,
    pub ids: Vec<Vec<i32>>,    // (N, T)
    pub masks: Vec<Vec<f32>>,  // (N, T)
    pub labels: Vec<i32>,      // (N,)
}

impl Dataset {
    pub fn tokenize(examples: &[Example], tok: &Tokenizer, seq_len: usize) -> Self {
        let mut ids = Vec::with_capacity(examples.len());
        let mut masks = Vec::with_capacity(examples.len());
        let mut labels = Vec::with_capacity(examples.len());
        for e in examples {
            let a: Vec<&str> = e.text_a.iter().map(|s| s.as_str()).collect();
            let b: Option<Vec<&str>> = e.text_b.as_ref().map(|v| v.iter().map(|s| s.as_str()).collect());
            let (i, m) = tok.encode(&a, b.as_deref(), seq_len);
            ids.push(i);
            masks.push(m);
            labels.push(e.label);
        }
        Dataset { seq_len, ids, masks, labels }
    }

    pub fn len(&self) -> usize {
        self.labels.len()
    }

    pub fn is_empty(&self) -> bool {
        self.labels.is_empty()
    }

    /// Mean valid tokens per example (the Table-2 x-axis statistic).
    pub fn mean_valid_tokens(&self) -> f64 {
        let total: f64 = self.masks.iter().map(|m| m.iter().sum::<f32>() as f64).sum();
        total / self.len().max(1) as f64
    }

    /// Gather rows into (ids, mask, labels) host tensors of shape
    /// (B, T) / (B, T) / (B,), padding by repeating row 0 if `rows` is
    /// shorter than `batch` (the pad count is returned for eval accounting).
    pub fn gather(&self, rows: &[usize], batch: usize) -> (HostTensor, HostTensor, HostTensor, usize) {
        let t = self.seq_len;
        let mut ids = Vec::with_capacity(batch * t);
        let mut mask = Vec::with_capacity(batch * t);
        let mut labels = Vec::with_capacity(batch);
        for i in 0..batch {
            let r = rows.get(i).copied().unwrap_or(rows[0]);
            ids.extend_from_slice(&self.ids[r]);
            mask.extend_from_slice(&self.masks[r]);
            labels.push(self.labels[r]);
        }
        let padded = batch.saturating_sub(rows.len());
        (
            HostTensor::i32(&[batch, t], ids),
            HostTensor::f32(&[batch, t], mask),
            HostTensor::i32(&[batch], labels),
            padded,
        )
    }
}

/// Epoch-reshuffling batch iterator over row indices.
pub struct BatchIter {
    order: Vec<usize>,
    cursor: usize,
    batch: usize,
    rng: Rng,
}

impl BatchIter {
    pub fn new(n: usize, batch: usize, rng: Rng) -> Self {
        let mut it = BatchIter { order: (0..n).collect(), cursor: 0, batch, rng };
        it.reshuffle();
        it
    }

    fn reshuffle(&mut self) {
        self.rng.shuffle(&mut self.order);
        self.cursor = 0;
    }

    /// Next batch of row indices (wraps epochs, reshuffling at each).
    pub fn next_rows(&mut self) -> Vec<usize> {
        if self.cursor + self.batch > self.order.len() {
            self.reshuffle();
        }
        let rows = self.order[self.cursor..self.cursor + self.batch].to_vec();
        self.cursor += self.batch;
        rows
    }
}

/// Stack K batches into the [K, B, T] tensors the scan artifacts take.
pub fn stack_k(ds: &Dataset, it: &mut BatchIter, k: usize, batch: usize) -> (HostTensor, HostTensor, HostTensor) {
    let t = ds.seq_len;
    let mut ids = Vec::with_capacity(k * batch * t);
    let mut mask = Vec::with_capacity(k * batch * t);
    let mut labels = Vec::with_capacity(k * batch);
    for _ in 0..k {
        let rows = it.next_rows();
        let (i, m, l, _) = ds.gather(&rows, batch);
        ids.extend_from_slice(i.as_i32().unwrap());
        mask.extend_from_slice(m.as_f32().unwrap());
        labels.extend_from_slice(l.as_i32().unwrap());
    }
    (
        HostTensor::i32(&[k, batch, t], ids),
        HostTensor::f32(&[k, batch, t], mask),
        HostTensor::i32(&[k, batch], labels),
    )
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::data::lexicon::Lexicon;
    use crate::data::tasks::{generate, TaskKind};
    use crate::tokenizer::Tokenizer;

    fn mk() -> (Dataset, Tokenizer) {
        let lex = Lexicon::new(3);
        let tok = Tokenizer::build(&lex.all_words(), 512);
        let ex = generate(TaskKind::Sst2, &lex, &mut Rng::new(1), 40);
        (Dataset::tokenize(&ex, &tok, 24), tok)
    }

    #[test]
    fn tokenized_shapes() {
        let (ds, _) = mk();
        assert_eq!(ds.len(), 40);
        for (i, m) in ds.ids.iter().zip(ds.masks.iter()) {
            assert_eq!(i.len(), 24);
            assert_eq!(m.len(), 24);
            // mask is a prefix of ones
            let ones = m.iter().filter(|&&x| x == 1.0).count();
            assert!(m[..ones].iter().all(|&x| x == 1.0));
            assert!(m[ones..].iter().all(|&x| x == 0.0));
        }
        assert!(ds.mean_valid_tokens() > 4.0);
    }

    #[test]
    fn gather_and_pad() {
        let (ds, _) = mk();
        let (ids, mask, labels, padded) = ds.gather(&[0, 1, 2], 5);
        assert_eq!(ids.dims, vec![5, 24]);
        assert_eq!(mask.dims, vec![5, 24]);
        assert_eq!(labels.dims, vec![5]);
        assert_eq!(padded, 2);
        // padding repeats row 0
        let idv = ids.as_i32().unwrap();
        assert_eq!(&idv[3 * 24..4 * 24], &idv[0..24]);
    }

    #[test]
    fn batch_iter_covers_epoch() {
        let mut it = BatchIter::new(10, 3, Rng::new(5));
        let mut seen = std::collections::HashSet::new();
        for _ in 0..3 {
            for r in it.next_rows() {
                seen.insert(r);
            }
        }
        assert_eq!(seen.len(), 9); // 3 batches of 3 distinct rows each epoch
    }

    #[test]
    fn stack_k_shapes() {
        let (ds, _) = mk();
        let mut it = BatchIter::new(ds.len(), 8, Rng::new(2));
        let (ids, mask, labels) = stack_k(&ds, &mut it, 4, 8);
        assert_eq!(ids.dims, vec![4, 8, 24]);
        assert_eq!(mask.dims, vec![4, 8, 24]);
        assert_eq!(labels.dims, vec![4, 8]);
    }
}
