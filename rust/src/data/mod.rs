//! Synthetic-GLUE data substrate: lexicon, task generators, tokenized
//! datasets, batching. See DESIGN.md §Substitutions for why synthetic
//! analogues preserve the paper's Table-1/Table-3 orderings.

pub mod batch;
pub mod lexicon;
pub mod tasks;

pub use batch::{stack_k, BatchIter, Dataset};
pub use lexicon::Lexicon;
pub use tasks::{generate, Example, TaskKind, ALL_TASKS};

use crate::tokenizer::Tokenizer;
use crate::util::rng::Rng;

/// A fully materialized task: tokenizer-shared train/dev splits.
pub struct TaskData {
    pub kind: TaskKind,
    pub train: Dataset,
    pub dev: Dataset,
}

/// Build the entire suite deterministically: one lexicon + tokenizer for
/// all tasks (as with real GLUE, where one pretrained vocab serves every
/// downstream task).
pub struct Suite {
    pub lexicon: Lexicon,
    pub tokenizer: Tokenizer,
    pub seq_len: usize,
}

impl Suite {
    pub fn new(seed: u64, vocab_size: usize, seq_len: usize) -> Self {
        let lexicon = Lexicon::new(seed);
        let tokenizer = Tokenizer::build(&lexicon.all_words(), vocab_size);
        Suite { lexicon, tokenizer, seq_len }
    }

    pub fn task(&self, kind: TaskKind, seed: u64) -> TaskData {
        let (n_train, n_dev) = kind.sizes();
        let mut rng = Rng::new(seed ^ (kind.name().bytes().map(|b| b as u64).sum::<u64>() << 7));
        let train_ex = generate(kind, &self.lexicon, &mut rng, n_train);
        let dev_ex = generate(kind, &self.lexicon, &mut rng, n_dev);
        TaskData {
            kind,
            train: Dataset::tokenize(&train_ex, &self.tokenizer, self.seq_len),
            dev: Dataset::tokenize(&dev_ex, &self.tokenizer, self.seq_len),
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn suite_builds_all_tasks() {
        let suite = Suite::new(42, 512, 24);
        assert!(suite.tokenizer.vocab_size() <= 512);
        for kind in ALL_TASKS {
            let td = suite.task(kind, 1);
            assert_eq!(td.train.len(), kind.sizes().0);
            assert_eq!(td.dev.len(), kind.sizes().1);
            // ids stay inside the model vocabulary
            for row in td.train.ids.iter().take(50) {
                assert!(row.iter().all(|&i| (i as usize) < 512));
            }
        }
    }

    #[test]
    fn splits_are_stable() {
        let suite = Suite::new(42, 512, 24);
        let a = suite.task(TaskKind::Rte, 1);
        let b = suite.task(TaskKind::Rte, 1);
        assert_eq!(a.dev.ids, b.dev.ids);
        assert_eq!(a.dev.labels, b.dev.labels);
    }

    #[test]
    fn train_dev_disjoint_rngs() {
        let suite = Suite::new(42, 512, 24);
        let t = suite.task(TaskKind::Sst2, 1);
        // train prefix and dev prefix should differ (different stream pos)
        assert_ne!(t.train.ids[..5], t.dev.ids[..5]);
    }
}
