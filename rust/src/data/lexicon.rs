//! Procedural lexicon for the synthetic-GLUE suite.
//!
//! Words are consonant-vowel syllable strings, partitioned into parts of
//! speech, with a deterministic synonym pairing inside nouns/verbs (the
//! paraphrase/entailment generators rewrite through it). Everything is
//! seeded, so the corpus — and therefore the tokenizer vocabulary and the
//! train/dev splits — is identical across processes (teacher finetune,
//! QAT runs, and the serving demo all agree).

use crate::util::rng::Rng;
use std::collections::HashMap;

const CONS: &[char] = &['b', 'd', 'f', 'g', 'k', 'l', 'm', 'n', 'p', 'r', 's', 't', 'v', 'z'];
const VOWELS: &[char] = &['a', 'e', 'i', 'o', 'u'];

#[derive(Debug, Clone)]
pub struct Lexicon {
    pub nouns: Vec<String>,
    pub verbs: Vec<String>,
    pub adjectives: Vec<String>,
    pub pos_words: Vec<String>,
    pub neg_words: Vec<String>,
    pub neutral: Vec<String>,
    pub determiners: Vec<String>,
    pub wh_words: Vec<String>,
    /// sentiment-flipping tokens ("not"-words) — SST-2's compositional knob
    pub negators: Vec<String>,
    /// noun/verb -> synonym (bidirectional pairing)
    pub synonyms: HashMap<String, String>,
}

fn syllable(rng: &mut Rng) -> String {
    let mut s = String::new();
    s.push(*rng.choose(CONS));
    s.push(*rng.choose(VOWELS));
    s
}

fn word(rng: &mut Rng, syllables: usize) -> String {
    (0..syllables).map(|_| syllable(rng)).collect()
}

fn unique_words(rng: &mut Rng, count: usize, syllables: usize, taken: &mut Vec<String>) -> Vec<String> {
    let mut out = Vec::with_capacity(count);
    while out.len() < count {
        let w = word(rng, syllables);
        if !taken.contains(&w) {
            taken.push(w.clone());
            out.push(w);
        }
    }
    out
}

impl Lexicon {
    pub fn new(seed: u64) -> Self {
        let mut rng = Rng::new(seed ^ 0x5EED_1E1C);
        let mut taken: Vec<String> = Vec::new();
        let nouns = unique_words(&mut rng, 60, 2, &mut taken);
        let verbs = unique_words(&mut rng, 40, 2, &mut taken);
        let adjectives = unique_words(&mut rng, 24, 2, &mut taken);
        let pos_words = unique_words(&mut rng, 20, 3, &mut taken);
        let neg_words = unique_words(&mut rng, 20, 3, &mut taken);
        let neutral = unique_words(&mut rng, 30, 2, &mut taken);
        let determiners = unique_words(&mut rng, 4, 1, &mut taken);
        let wh_words = unique_words(&mut rng, 4, 1, &mut taken);
        let negators = unique_words(&mut rng, 2, 1, &mut taken);

        // Pair consecutive nouns / verbs as synonyms: (0,1), (2,3), ...
        let mut synonyms = HashMap::new();
        for chunk in nouns.chunks(2).chain(verbs.chunks(2)) {
            if let [a, b] = chunk {
                synonyms.insert(a.clone(), b.clone());
                synonyms.insert(b.clone(), a.clone());
            }
        }
        Lexicon { nouns, verbs, adjectives, pos_words, neg_words, neutral, determiners, wh_words, negators, synonyms }
    }

    /// Every word (for tokenizer vocabulary building).
    pub fn all_words(&self) -> Vec<&str> {
        self.nouns
            .iter()
            .chain(&self.verbs)
            .chain(&self.adjectives)
            .chain(&self.pos_words)
            .chain(&self.neg_words)
            .chain(&self.neutral)
            .chain(&self.determiners)
            .chain(&self.wh_words)
            .chain(&self.negators)
            .map(|s| s.as_str())
            .collect()
    }

    pub fn synonym<'a>(&'a self, w: &'a str) -> &'a str {
        self.synonyms.get(w).map(|s| s.as_str()).unwrap_or(w)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn deterministic() {
        let a = Lexicon::new(7);
        let b = Lexicon::new(7);
        assert_eq!(a.nouns, b.nouns);
        assert_eq!(a.pos_words, b.pos_words);
    }

    #[test]
    fn seeds_differ() {
        let a = Lexicon::new(7);
        let b = Lexicon::new(8);
        assert_ne!(a.nouns, b.nouns);
    }

    #[test]
    fn no_cross_pos_collisions() {
        let lex = Lexicon::new(1);
        let all = lex.all_words();
        let mut set = std::collections::HashSet::new();
        for w in &all {
            assert!(set.insert(*w), "duplicate word {w}");
        }
        assert_eq!(all.len(), 60 + 40 + 24 + 20 + 20 + 30 + 4 + 4 + 2);
    }

    #[test]
    fn synonyms_are_involutive() {
        let lex = Lexicon::new(2);
        for n in &lex.nouns {
            let s = lex.synonym(n);
            assert_eq!(lex.synonym(s), n.as_str());
        }
        // and stay within the same part of speech
        for v in &lex.verbs {
            assert!(lex.verbs.contains(&lex.synonym(v).to_string()));
        }
    }
}
