//! Model store: the checkpoint→serving lifecycle owner.
//!
//! Three pieces close the ROADMAP's deployment follow-ons as one
//! subsystem:
//!
//!   * [`mapped`] — mmap-backed read-only file bytes with a buffered
//!     fallback, the zero-copy substrate under every checkpoint read.
//!   * migration ([`migrate_checkpoint`]) — rewrite any readable
//!     checkpoint (v1 or v2, file or sharded) as MKQC v2 with the
//!     quantized column panels persisted *in place of* the fp32 masters
//!     (plus `.scales` siblings), optionally sharded across N payload
//!     files behind a manifest. A migrated checkpoint loads without any
//!     quantize+pack work — [`crate::runtime::NativeModel::from_checkpoint`]
//!     memcpys panels straight into [`crate::kernels::PackedWeights`].
//!   * [`registry`] — N named models behind one [`crate::runtime::Backend`],
//!     sharing the kernel dispatcher and the serving coordinator's
//!     seq-bucket batcher while each model keeps its own
//!     [`crate::runtime::Workspace`] arena.
//!
//! Quantization is deterministic (`quantize_weight_per_channel` has no
//! data-dependent branching), so a migrated checkpoint's panels are
//! byte-identical to what a v1 load would build in memory — which is the
//! bit-for-bit acceptance contract `rust/tests/modelstore.rs` enforces
//! across every kernel variant.

pub mod mapped;
pub mod registry;

use std::path::Path;

use crate::checkpoint::{Checkpoint, CkptError, CkptHeader, Writer, MANIFEST_NAME, MANIFEST_TAG};
use crate::kernels::PackedWeights;

pub use registry::{
    ModelVersion, Registry, DEGRADE_AFTER_FAILURES, QUARANTINE_AFTER_FAILURES,
};

/// What a model load actually did — the observability behind the
/// `BENCH_load.json` rows and the "v2 skips quantize+pack" acceptance
/// check.
#[derive(Debug, Clone, Copy, Default)]
pub struct LoadStats {
    /// Quantized weight sites loaded straight from stored panels.
    pub prepacked_panels: usize,
    /// Quantized weight sites that had to quantize+pack fp32 masters.
    pub quantized_panels: usize,
    /// Whether the backing file bytes are mmap'd (vs buffered-read).
    pub mapped: bool,
    /// Heap bytes held by the file image itself (0 when mapped).
    pub file_heap_bytes: usize,
    /// Approximate heap bytes owned by the constructed model (packed
    /// panels, scales, embeddings, biases, LN vectors). Borrowed
    /// (zero-copy) panels and scales contribute nothing here.
    pub model_heap_bytes: usize,
    /// Panel bytes memcpy'd out of the checkpoint into model-owned
    /// buffers at load. A fully zero-copy v2 load reports 0 — the
    /// number `ckpt bench-load --expect-zero-copy` gates on.
    pub panel_copy_bytes: usize,
    /// Panel + scale bytes served directly out of the checkpoint image
    /// (they pin the image, so eviction accounting must include it).
    pub borrowed_panel_bytes: usize,
}

impl LoadStats {
    /// Peak-RSS proxy for one resident loaded model: owned model bytes
    /// plus whatever the file image pins on the heap. Mapped loads drop
    /// the I/O term entirely — the pages are reclaimable and shared.
    pub fn rss_proxy_bytes(&self) -> usize {
        self.file_heap_bytes + self.model_heap_bytes
    }

    /// Bytes actually freed by evicting this model: its owned heap, plus
    /// the file image when borrowed panels pin a *buffered* (non-mapped)
    /// image. A mapped image's pages are reclaimable page cache, so they
    /// cost ~nothing while resident and free ~nothing on evict.
    pub fn resident_bytes(&self) -> usize {
        let pinned_image =
            if self.borrowed_panel_bytes > 0 && !self.mapped { self.file_heap_bytes } else { 0 };
        self.model_heap_bytes + pinned_image
    }
}

/// Which weight matrices are quantizable, and at what width: layer
/// tensors `l{i}_{wq|wk|wv|wo|w1|w2}` inherit layer `i`'s bit width.
/// Everything else (embeddings, biases, LN, heads) stays fp32 always.
pub fn weight_bits(header: &CkptHeader, name: &str) -> Option<u32> {
    let rest = name.strip_prefix('l')?;
    let (idx, suffix) = rest.split_once('_')?;
    let l: usize = idx.parse().ok()?;
    if l >= header.bits.len() {
        return None;
    }
    if matches!(suffix, "wq" | "wk" | "wv" | "wo" | "w1" | "w2") {
        Some(header.bits[l])
    } else {
        None
    }
}

/// One tensor headed for a migrated checkpoint.
enum TensorOut {
    F32 { name: String, dims: Vec<usize>, data: Vec<f32> },
    Packed { name: String, pw: PackedWeights },
}

impl TensorOut {
    fn name(&self) -> &str {
        match self {
            TensorOut::F32 { name, .. } | TensorOut::Packed { name, .. } => name,
        }
    }

    /// Payload bytes this tensor will occupy (the shard-balancing key).
    fn payload_bytes(&self) -> usize {
        match self {
            TensorOut::F32 { data, .. } => data.len() * 4,
            TensorOut::Packed { pw, .. } => pw.raw_bytes().len() + pw.scales.len() * 4,
        }
    }

    fn add_to(&self, w: &mut Writer) -> Result<(), CkptError> {
        match self {
            TensorOut::F32 { name, dims, data } => w.add_f32(name, dims, data),
            TensorOut::Packed { name, pw } => w.add_packed(name, pw),
        }
    }
}

/// Result summary of a [`migrate_checkpoint`] run.
#[derive(Debug, Clone, Copy)]
pub struct MigrateSummary {
    /// Directory entries written (packed weights count once; their
    /// `.scales` siblings are extra entries on top of this).
    pub tensors: usize,
    /// Weight sites persisted as prepacked panels.
    pub packed: usize,
    /// Shard files written (1 = single file at `dst`).
    pub shards: usize,
    /// Total payload bytes across shards.
    pub payload_bytes: usize,
}

/// Convert the source tensors to their v2 on-disk form: quantizable
/// weights become prepacked panels at the layer's bit width (already-
/// packed entries carry through byte-identically), everything else stays
/// fp32. `.scales` siblings are regenerated by the writer, so stored
/// ones are skipped here.
fn plan_tensors(src: &Checkpoint) -> Result<Vec<TensorOut>, CkptError> {
    use crate::checkpoint::DTYPE_F32;
    let header = src.header();
    let mut out = Vec::with_capacity(src.entries().len());
    for e in src.entries() {
        if let Some(base) = e.name.strip_suffix(".scales") {
            if src.entry(base).map(|b| b.dtype != DTYPE_F32).unwrap_or(false) {
                continue; // regenerated next to its packed base entry
            }
        }
        if e.dtype != DTYPE_F32 {
            let bits = match e.dtype {
                crate::checkpoint::DTYPE_I8_PANELS => 8,
                _ => 4,
            };
            let (_, scales) = src.f32_tensor(&format!("{}.scales", e.name))?;
            let pw =
                PackedWeights::from_panels(bits, e.dims[0], e.dims[1], scales, src.panel_bytes(&e.name)?)
                    .map_err(CkptError::BadDirectory)?;
            out.push(TensorOut::Packed { name: e.name.clone(), pw });
            continue;
        }
        match weight_bits(header, &e.name) {
            // a quantizable name must be a rank-2 matrix to pack; anything
            // else falls through to the f32 copy (the model loader is the
            // one that enforces spec shapes)
            Some(bits @ (4 | 8)) if e.dims.len() == 2 => {
                let (dims, data) = src.f32_tensor(&e.name)?;
                let (k, n) = (dims[0], dims[1]);
                out.push(TensorOut::Packed {
                    name: e.name.clone(),
                    pw: PackedWeights::from_f32(&data, k, n, bits),
                });
            }
            _ => {
                let (dims, data) = src.f32_tensor(&e.name)?;
                out.push(TensorOut::F32 { name: e.name.clone(), dims: dims.to_vec(), data });
            }
        }
    }
    Ok(out)
}

/// Migrate a checkpoint to MKQC v2 with prepacked panels. `dst` is a
/// single `.mkqc` file when `shards <= 1`, otherwise a directory holding
/// `shards` payload files behind a [`MANIFEST_NAME`] manifest. Tensors
/// are balanced across shards greedily by payload size, keeping the
/// source order within each shard.
pub fn migrate_checkpoint(
    src: &Checkpoint,
    dst: &Path,
    shards: usize,
) -> Result<MigrateSummary, CkptError> {
    let tensors = plan_tensors(src)?;
    let packed = tensors.iter().filter(|t| matches!(t, TensorOut::Packed { .. })).count();
    let payload_bytes: usize = tensors.iter().map(|t| t.payload_bytes()).sum();
    let n_shards = shards.max(1).min(tensors.len().max(1));

    if n_shards <= 1 {
        let mut w = Writer::new(src.header().clone())?;
        for t in &tensors {
            t.add_to(&mut w)?;
        }
        w.write_to(dst)?;
        return Ok(MigrateSummary { tensors: tensors.len(), packed, shards: 1, payload_bytes });
    }

    // greedy balance: each tensor lands on the currently-lightest shard
    let mut writers: Vec<Writer> = (0..n_shards)
        .map(|_| Writer::new(src.header().clone()))
        .collect::<Result<_, _>>()?;
    let mut loads = vec![0usize; n_shards];
    for t in &tensors {
        let si = loads
            .iter()
            .enumerate()
            .min_by_key(|(_, &b)| b)
            .map(|(i, _)| i)
            .expect("at least one shard");
        t.add_to(&mut writers[si])?;
        loads[si] += t.payload_bytes();
    }
    std::fs::create_dir_all(dst)?;
    let mut manifest = String::from(MANIFEST_TAG);
    manifest.push('\n');
    for (i, w) in writers.iter().enumerate() {
        let name = format!("shard_{i:02}.mkqc");
        w.write_to(&dst.join(&name))?;
        manifest.push_str(&name);
        manifest.push('\n');
    }
    // manifest last: a crash mid-write leaves a directory that is not yet
    // a readable checkpoint, never one referencing missing shards
    std::fs::write(dst.join(MANIFEST_NAME), manifest)?;
    Ok(MigrateSummary { tensors: tensors.len(), packed, shards: n_shards, payload_bytes })
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn weight_bits_classifies_names() {
        use crate::runtime::native::NativeDims;
        let dims = NativeDims { vocab: 8, seq: 4, n_layers: 2, d_model: 4, n_heads: 2, d_ff: 8, n_classes: 2 };
        let h = CkptHeader { dims, bits: vec![8, 4], act_scales: vec![[0.1; 4]; 2] };
        assert_eq!(weight_bits(&h, "l0_wq"), Some(8));
        assert_eq!(weight_bits(&h, "l1_w2"), Some(4));
        assert_eq!(weight_bits(&h, "l1_bq"), None, "biases are never quantized");
        assert_eq!(weight_bits(&h, "l1_ln1_g"), None);
        assert_eq!(weight_bits(&h, "emb_word"), None);
        assert_eq!(weight_bits(&h, "pool_w"), None, "heads stay fp32");
        assert_eq!(weight_bits(&h, "l7_wq"), None, "out-of-range layer");
        assert_eq!(weight_bits(&h, "lx_wq"), None);
    }
}
