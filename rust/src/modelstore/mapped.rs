//! Read-only file bytes: mmap'd when the platform allows, buffered
//! otherwise — the zero-copy substrate under checkpoint loads.
//!
//! [`FileBytes::open`] maps the file `PROT_READ`/`MAP_PRIVATE` via direct
//! `extern "C"` declarations of `mmap`/`munmap` (no new crates — the
//! build stays hermetic) and falls back to an ordinary buffered read on
//! non-unix targets, on any mmap failure, on empty files (zero-length
//! mappings are an `EINVAL`), and under `MKQ_NO_MMAP=1` (the knob the
//! mmap-vs-buffered equivalence tests flip). Either way the result
//! derefs to `&[u8]`, so the checkpoint reader is agnostic to where the
//! bytes live.
//!
//! A mapped region is page-aligned by construction, which is what makes
//! the v2 format's 16-byte-aligned payload start yield properly aligned
//! in-place `&[f32]` views (see `checkpoint::reader`). The mapping is
//! private and never written through, so no `msync` story is needed;
//! `munmap` runs on drop.

use std::io::Read;
use std::path::Path;

#[cfg(unix)]
mod sys {
    use std::os::raw::{c_int, c_void};

    pub const PROT_READ: c_int = 1;
    pub const MAP_PRIVATE: c_int = 2;

    extern "C" {
        // off_t is 64-bit on every unix target this repo builds for
        // (linux x86_64 / aarch64, macOS); the offset passed is always 0
        // so a 32-bit off_t target would still read the right bytes.
        pub fn mmap(
            addr: *mut c_void,
            len: usize,
            prot: c_int,
            flags: c_int,
            fd: c_int,
            offset: i64,
        ) -> *mut c_void;
        pub fn munmap(addr: *mut c_void, len: usize) -> c_int;
    }
}

/// An owned read-only memory mapping of a whole file.
#[cfg(unix)]
pub struct Mapped {
    ptr: std::ptr::NonNull<u8>,
    len: usize,
}

// The mapping is private, read-only and exclusively owned: sharing
// &[u8] views across threads is as safe as sharing a Vec<u8>.
#[cfg(unix)]
unsafe impl Send for Mapped {}
#[cfg(unix)]
unsafe impl Sync for Mapped {}

#[cfg(unix)]
impl Mapped {
    /// Map a file read-only; `None` on any failure (caller falls back to
    /// a buffered read).
    fn map(file: &std::fs::File, len: usize) -> Option<Self> {
        use std::os::unix::io::AsRawFd;
        if len == 0 {
            return None; // zero-length mmap is EINVAL
        }
        // SAFETY: requesting a fresh private read-only mapping of `len`
        // bytes backed by an open fd; the kernel picks the address. The
        // only observable states are MAP_FAILED or a valid mapping that
        // stays live until munmap in Drop.
        let ptr = unsafe {
            sys::mmap(std::ptr::null_mut(), len, sys::PROT_READ, sys::MAP_PRIVATE, file.as_raw_fd(), 0)
        };
        if ptr as isize == -1 || ptr.is_null() {
            return None;
        }
        Some(Mapped { ptr: std::ptr::NonNull::new(ptr as *mut u8)?, len })
    }

    pub fn as_slice(&self) -> &[u8] {
        // SAFETY: the mapping covers exactly `len` readable bytes and
        // lives as long as `self`.
        unsafe { std::slice::from_raw_parts(self.ptr.as_ptr(), self.len) }
    }
}

#[cfg(unix)]
impl Drop for Mapped {
    fn drop(&mut self) {
        // SAFETY: undoing exactly the mapping made in `map`.
        unsafe {
            sys::munmap(self.ptr.as_ptr() as *mut std::os::raw::c_void, self.len);
        }
    }
}

/// File contents, either mapped in place or read into an owned buffer.
pub enum FileBytes {
    Owned(Vec<u8>),
    #[cfg(unix)]
    Mapped(Mapped),
}

impl FileBytes {
    /// Prefer a zero-copy mapping; fall back to a buffered read wherever
    /// mapping is unavailable (non-unix, empty file, mmap failure) or
    /// disabled via `MKQ_NO_MMAP=1`.
    pub fn open(path: &Path) -> std::io::Result<Self> {
        #[cfg(unix)]
        {
            let no_mmap = std::env::var("MKQ_NO_MMAP").map(|v| v == "1").unwrap_or(false);
            if !no_mmap {
                if let Ok(file) = std::fs::File::open(path) {
                    let len = file.metadata()?.len();
                    if let Ok(len) = usize::try_from(len) {
                        if let Some(m) = Mapped::map(&file, len) {
                            return Ok(FileBytes::Mapped(m));
                        }
                    }
                }
            }
        }
        Self::read_buffered(path)
    }

    /// Always read into an owned buffer (the fallback path, kept
    /// callable directly so tests can compare it against the mapped path
    /// bit for bit).
    pub fn read_buffered(path: &Path) -> std::io::Result<Self> {
        let mut f = std::fs::File::open(path)?;
        let mut buf = Vec::new();
        f.read_to_end(&mut buf)?;
        Ok(FileBytes::Owned(buf))
    }

    pub fn is_mapped(&self) -> bool {
        match self {
            FileBytes::Owned(_) => false,
            #[cfg(unix)]
            FileBytes::Mapped(_) => true,
        }
    }

    /// Heap bytes this image holds resident by itself — the RSS-proxy
    /// term for the load bench (a mapping's pages are reclaimable and
    /// shared, an owned buffer is not).
    pub fn heap_bytes(&self) -> usize {
        match self {
            FileBytes::Owned(v) => v.len(),
            #[cfg(unix)]
            FileBytes::Mapped(_) => 0,
        }
    }
}

impl std::ops::Deref for FileBytes {
    type Target = [u8];

    fn deref(&self) -> &[u8] {
        match self {
            FileBytes::Owned(v) => v,
            #[cfg(unix)]
            FileBytes::Mapped(m) => m.as_slice(),
        }
    }
}

impl From<Vec<u8>> for FileBytes {
    fn from(v: Vec<u8>) -> Self {
        FileBytes::Owned(v)
    }
}

// The impl `kernels::pack::PanelRef` borrows through: an
// `Arc<FileBytes>` owner hands out stable `&[u8]` views of the image
// for as long as any borrowed panel keeps the Arc alive.
impl AsRef<[u8]> for FileBytes {
    fn as_ref(&self) -> &[u8] {
        self
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn tmp(name: &str, bytes: &[u8]) -> std::path::PathBuf {
        let p = std::env::temp_dir().join(format!("mkq_mapped_{}_{name}", std::process::id()));
        std::fs::write(&p, bytes).unwrap();
        p
    }

    #[test]
    fn mapped_and_buffered_agree() {
        let data: Vec<u8> = (0..4096u32).flat_map(|i| i.to_le_bytes()).collect();
        let p = tmp("agree.bin", &data);
        let mapped = FileBytes::open(&p).unwrap();
        let buffered = FileBytes::read_buffered(&p).unwrap();
        assert_eq!(&mapped[..], &data[..]);
        assert_eq!(&buffered[..], &data[..]);
        assert!(!buffered.is_mapped());
        #[cfg(unix)]
        assert!(mapped.is_mapped(), "unix open() should map");
        assert_eq!(buffered.heap_bytes(), data.len());
        if mapped.is_mapped() {
            assert_eq!(mapped.heap_bytes(), 0);
        }
        std::fs::remove_file(&p).ok();
    }

    #[test]
    fn empty_file_falls_back_to_owned() {
        let p = tmp("empty.bin", &[]);
        let fb = FileBytes::open(&p).unwrap();
        assert!(!fb.is_mapped(), "zero-length files cannot be mapped");
        assert!(fb.is_empty());
        std::fs::remove_file(&p).ok();
    }

    #[test]
    fn missing_file_is_io_error() {
        let p = std::env::temp_dir().join("mkq_mapped_definitely_missing.bin");
        assert!(FileBytes::open(&p).is_err());
        assert!(FileBytes::read_buffered(&p).is_err());
    }
}
