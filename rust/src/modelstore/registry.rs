//! Multi-model serving registry: N named checkpoints behind one
//! [`Backend`].
//!
//! One server process loads any number of MKQC checkpoints (single files
//! or sharded directories), each registered under a caller-chosen name.
//! Requests carry a model index (resolved from the name at submit time),
//! the serving coordinator's 2-D seq-bucket batcher batches *per model*
//! (a batch is one forward through one model), and execution routes
//! through [`Backend::serve_forward_for`]. The kernel [`Dispatcher`]
//! (thread pool + autotuned thresholds) is shared across models; each
//! model keeps its own [`Workspace`] arena so steady-state forwards stay
//! zero-allocation regardless of interleaving — models have different
//! shapes, and sharing one arena would re-grow it on every model switch.

use std::cell::RefCell;
use std::path::Path;

use anyhow::{bail, Result};

use super::LoadStats;
use crate::coordinator::faults::{FaultPlan, Faults};
use crate::kernels::Dispatcher;
use crate::runtime::{Backend, NativeModel, Precision, ServeDims, Workspace};

/// One registered model: name, deployed weights, its load provenance,
/// and a private forward arena.
pub struct RegisteredModel {
    pub name: String,
    pub model: NativeModel,
    pub stats: LoadStats,
    ws: RefCell<Workspace>,
}

/// Named-model registry; implements [`Backend`] with per-model routing.
pub struct Registry {
    pub disp: Dispatcher,
    models: Vec<RegisteredModel>,
    /// Fault-injection hook (`MKQ_FAULT_*` env or [`Registry::set_faults`]);
    /// inert by default. One hook for the whole registry — an injected
    /// fault is a process-level event, not a per-model one.
    faults: Faults,
}

impl Default for Registry {
    fn default() -> Self {
        Self::new()
    }
}

impl Registry {
    pub fn new() -> Self {
        Registry { disp: Dispatcher::new(), models: Vec::new(), faults: Faults::from_env() }
    }

    /// Arm (or disarm, with an inert plan) fault injection on this
    /// registry instance — chaos tests use this instead of the env so
    /// parallel test threads never share fault state.
    pub fn set_faults(&mut self, plan: FaultPlan) {
        self.faults = Faults::with_plan(plan);
    }

    /// Load a checkpoint (file or sharded directory) and register it
    /// under `name`. Returns the model index requests will carry.
    pub fn load(&mut self, name: &str, path: &Path) -> Result<usize> {
        if name.is_empty() {
            bail!("model name must be non-empty");
        }
        if self.find(name).is_some() {
            bail!("model name {name:?} is already registered");
        }
        let (model, stats) = NativeModel::from_checkpoint_with_stats(path)
            .map_err(|e| anyhow::anyhow!("loading {name:?} from {}: {e}", path.display()))?;
        self.models.push(RegisteredModel {
            name: name.to_string(),
            model,
            stats,
            ws: RefCell::new(Workspace::new()),
        });
        Ok(self.models.len() - 1)
    }

    /// Register an already-constructed model (tests, random-init demos).
    pub fn register(&mut self, name: &str, model: NativeModel) -> Result<usize> {
        if name.is_empty() || self.find(name).is_some() {
            bail!("model name {name:?} is empty or already registered");
        }
        self.models.push(RegisteredModel {
            name: name.to_string(),
            model,
            stats: LoadStats::default(),
            ws: RefCell::new(Workspace::new()),
        });
        Ok(self.models.len() - 1)
    }

    /// One-shot kernel autotune, shared by every model (run once after
    /// the last `load`).
    pub fn autotune(&mut self) {
        self.disp.autotune();
    }

    pub fn len(&self) -> usize {
        self.models.len()
    }

    pub fn is_empty(&self) -> bool {
        self.models.is_empty()
    }

    /// Model index for a registered name.
    pub fn find(&self, name: &str) -> Option<usize> {
        self.models.iter().position(|m| m.name == name)
    }

    pub fn get(&self, model: usize) -> Option<&RegisteredModel> {
        self.models.get(model)
    }

    pub fn iter(&self) -> impl Iterator<Item = &RegisteredModel> {
        self.models.iter()
    }

    fn model(&self, idx: usize) -> Result<&RegisteredModel> {
        match self.models.get(idx) {
            Some(m) => Ok(m),
            None => bail!("model index {idx} out of range ({} registered)", self.models.len()),
        }
    }
}

impl Backend for Registry {
    fn name(&self) -> String {
        let names: Vec<&str> = self.models.iter().map(|m| m.name.as_str()).collect();
        format!("registry(threads={}, models=[{}])", self.disp.threads(), names.join(","))
    }

    fn n_models(&self) -> usize {
        self.models.len()
    }

    fn model_label(&self, model: usize) -> String {
        self.models.get(model).map(|m| m.name.clone()).unwrap_or_else(|| format!("#{model}"))
    }

    fn serve_dims(&self) -> Result<ServeDims> {
        self.serve_dims_for(0)
    }

    fn serve_dims_for(&self, model: usize) -> Result<ServeDims> {
        let m = self.model(model)?;
        Ok(ServeDims {
            vocab: m.model.dims.vocab,
            seq: m.model.dims.seq,
            n_classes: m.model.dims.n_classes,
        })
    }

    fn check_bucket(&self, bucket: usize) -> Result<()> {
        self.check_bucket_for(0, bucket)
    }

    fn check_bucket_for(&self, model: usize, bucket: usize) -> Result<()> {
        self.model(model)?;
        if bucket == 0 {
            bail!("bucket size 0");
        }
        Ok(())
    }

    fn check_seq_bucket(&self, t: usize) -> Result<()> {
        self.check_seq_bucket_for(0, t)
    }

    fn check_seq_bucket_for(&self, model: usize, t: usize) -> Result<()> {
        let dims = self.serve_dims_for(model)?;
        if t >= 1 && t <= dims.seq {
            Ok(())
        } else {
            bail!("seq bucket {t} out of range 1..={} for model {}", dims.seq, self.model_label(model))
        }
    }

    fn serve_forward(&self, bucket: usize, t: usize, ids: &[i32], mask: &[f32]) -> Result<Vec<f32>> {
        self.serve_forward_for(0, bucket, t, ids, mask)
    }

    fn serve_forward_for(
        &self,
        model: usize,
        bucket: usize,
        t: usize,
        ids: &[i32],
        mask: &[f32],
    ) -> Result<Vec<f32>> {
        let entry = self.model(model)?;
        self.faults.before_forward()?;
        let mut ws = entry.ws.borrow_mut();
        // the label is borrowed, not formatted — no allocation on the
        // per-batch success path (the zero-alloc serving contract)
        crate::runtime::backend::native_serve_forward(
            &entry.name,
            &entry.model,
            &self.disp,
            &mut ws,
            bucket,
            t,
            ids,
            mask,
        )
    }

    fn layer_forward(
        &self,
        _prec: Precision,
        _bsz: usize,
        _t: usize,
        _h: &[f32],
        _mask: &[f32],
    ) -> Result<Vec<f32>> {
        bail!("registry backend hosts serving models, not bench layers")
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::runtime::NativeDims;

    fn tiny(seed: u64, n_classes: usize) -> NativeModel {
        let dims = NativeDims {
            vocab: 32,
            seq: 6,
            n_layers: 1,
            d_model: 16,
            n_heads: 2,
            d_ff: 32,
            n_classes,
        };
        NativeModel::random(dims, &[8], seed)
    }

    #[test]
    fn registry_routes_by_index_and_rejects_unknown() {
        let mut reg = Registry::new();
        assert!(reg.is_empty());
        let a = reg.register("a", tiny(1, 2)).unwrap();
        let b = reg.register("b", tiny(2, 3)).unwrap();
        assert_eq!((a, b), (0, 1));
        assert!(reg.register("a", tiny(3, 2)).is_err(), "duplicate name");
        assert_eq!(reg.n_models(), 2);
        assert_eq!(reg.find("b"), Some(1));
        assert_eq!(reg.find("zzz"), None);
        assert_eq!(reg.serve_dims_for(0).unwrap().n_classes, 2);
        assert_eq!(reg.serve_dims_for(1).unwrap().n_classes, 3);
        assert!(reg.serve_dims_for(2).is_err());

        let ids: Vec<i32> = (0..6).collect();
        let mask = vec![1.0f32; 6];
        let la = reg.serve_forward_for(0, 1, 6, &ids, &mask).unwrap();
        let lb = reg.serve_forward_for(1, 1, 6, &ids, &mask).unwrap();
        assert_eq!(la.len(), 2);
        assert_eq!(lb.len(), 3);
        // routing is real: the same request through each model agrees with
        // that model served directly
        let direct_a = tiny(1, 2).forward(&reg.disp, &ids, &mask, 1, 6);
        assert_eq!(la, direct_a, "model a must serve model a's weights");
        assert!(reg.serve_forward_for(2, 1, 6, &ids, &mask).is_err());
    }

    #[test]
    fn single_model_surface_is_model_zero() {
        let mut reg = Registry::new();
        reg.register("only", tiny(5, 2)).unwrap();
        assert_eq!(reg.serve_dims().unwrap().seq, 6);
        assert!(reg.check_seq_bucket(3).is_ok());
        assert!(reg.check_seq_bucket(7).is_err());
        assert!(reg.check_bucket(4).is_ok());
        assert!(reg.check_bucket(0).is_err());
    }
}
