//! Multi-model serving registry: a fault-tolerant fleet of named
//! checkpoints behind one [`Backend`].
//!
//! One server process loads any number of MKQC checkpoints (single files
//! or sharded directories), each registered under a caller-chosen name.
//! Requests carry a model index (resolved from the name at submit time),
//! the serving coordinator's 2-D seq-bucket batcher batches *per model*
//! (a batch is one forward through one model), and execution routes
//! through [`Backend::serve_forward_for`]. The kernel [`Dispatcher`]
//! (thread pool + autotuned thresholds) is shared across models; each
//! model keeps its own [`Workspace`] arena so steady-state forwards stay
//! zero-allocation regardless of interleaving.
//!
//! On top of routing, each slot carries a *lifecycle*:
//!
//!   * **Versioned handles** — the loaded weights live in an
//!     `Arc<ModelVersion>` with a monotonic per-slot version.
//!     [`Registry::reload_model_idx`] loads the new version first, then
//!     swaps the handle atomically (single-threaded event loop, one
//!     assignment); the server drains in-flight batches before asking,
//!     so no batch ever straddles versions, and requests pinned to the
//!     old version shed with a typed `VersionGone`.
//!   * **Health state machine** — `Loading → Serving → Degraded →
//!     Quarantined`, driven by consecutive forward failures (errors and
//!     caught panics both count; any success resets). A quarantined
//!     model sheds every request with a typed reject while sibling
//!     models keep serving; a reload recovers it.
//!   * **Eviction under a memory budget** — [`Registry::set_mem_budget`]
//!     caps the fleet's summed [`LoadStats::resident_bytes`] (real
//!     numbers thanks to zero-copy panel borrowing: a mapped v2 model
//!     costs ~page-cache only); least-recently-used slots are evicted
//!     until the fleet fits.

use std::cell::{Cell, RefCell};
use std::path::{Path, PathBuf};
use std::sync::Arc;

use anyhow::{bail, Result};

use super::LoadStats;
use crate::coordinator::faults::{FaultPlan, Faults};
use crate::kernels::Dispatcher;
use crate::runtime::native::NativeDims;
use crate::runtime::{
    Backend, DispatchHandle, ModelHealth, ModelStatus, NativeModel, Precision, ServeDims,
    Workspace,
};

/// Consecutive forward failures before a model is flagged `Degraded`.
pub const DEGRADE_AFTER_FAILURES: u32 = 3;
/// Consecutive forward failures before a model is `Quarantined` (sheds
/// every request until reloaded).
pub const QUARANTINE_AFTER_FAILURES: u32 = 5;

/// One immutable loaded generation of a model. Held by `Arc` so a
/// version can outlive its slot (in-flight observers, `get`): swapping
/// in a reload never invalidates anyone still holding the old handle.
pub struct ModelVersion {
    /// Monotonic per-slot version (1 on first load, +1 per reload).
    pub version: u64,
    pub model: NativeModel,
    pub stats: LoadStats,
}

/// One registry slot: a stable (name, index) identity whose loaded
/// weights come and go across reloads and evictions.
struct ModelSlot {
    name: String,
    /// Checkpoint source — `None` for models registered in-memory
    /// (those cannot be reloaded).
    path: Option<PathBuf>,
    /// Dims captured at first load: admission checks and bucket
    /// bookkeeping stay answerable while the slot is evicted, and a
    /// reload is required to keep them (batches in the queues were
    /// validated against these shapes).
    dims: NativeDims,
    cur: Option<Arc<ModelVersion>>,
    /// Per-slot forward arena (models have different shapes; sharing
    /// one arena would re-grow it on every model switch).
    ws: RefCell<Workspace>,
    version: Cell<u64>,
    health: Cell<ModelHealth>,
    consec_failures: Cell<u32>,
    /// Logical timestamp of the last forward (LRU eviction key).
    last_used: Cell<u64>,
}

/// Named-model registry; implements [`Backend`] with per-model routing
/// and the load/reload/evict/quarantine lifecycle.
pub struct Registry {
    pub disp: Dispatcher,
    /// Interior mutability: the `Backend` trait is `&self` and the
    /// serving event loop is single-threaded by design, so lifecycle
    /// operations (reload/evict) arrive through `&self` too.
    slots: RefCell<Vec<ModelSlot>>,
    /// Fault-injection hook (`MKQ_FAULT_*` env or [`Registry::set_faults`]);
    /// inert by default. One hook for the whole registry — an injected
    /// fault is a process-level event, not a per-model one.
    faults: Faults,
    /// Fleet-wide resident-byte cap (see [`Registry::set_mem_budget`]).
    mem_budget: Cell<Option<usize>>,
    /// Logical clock feeding each slot's `last_used`.
    use_clock: Cell<u64>,
}

impl Default for Registry {
    fn default() -> Self {
        Self::new()
    }
}

impl Registry {
    pub fn new() -> Self {
        Registry {
            disp: Dispatcher::new(),
            slots: RefCell::new(Vec::new()),
            faults: Faults::from_env(),
            mem_budget: Cell::new(None),
            use_clock: Cell::new(0),
        }
    }

    /// Arm (or disarm, with an inert plan) fault injection on this
    /// registry instance — chaos tests use this instead of the env so
    /// parallel test threads never share fault state.
    pub fn set_faults(&mut self, plan: FaultPlan) {
        self.faults = Faults::with_plan(plan);
    }

    /// Cap the fleet's summed resident bytes; setting (or lowering) the
    /// budget evicts least-recently-used models immediately until the
    /// fleet fits. `None` removes the cap.
    pub fn set_mem_budget(&self, budget: Option<usize>) {
        self.mem_budget.set(budget);
        self.enforce_budget(None);
    }

    pub fn mem_budget(&self) -> Option<usize> {
        self.mem_budget.get()
    }

    /// Summed resident bytes across loaded models — what
    /// [`Registry::set_mem_budget`] caps.
    pub fn resident_bytes(&self) -> usize {
        self.slots
            .borrow()
            .iter()
            .filter_map(|s| s.cur.as_ref())
            .map(|c| c.stats.resident_bytes())
            .sum()
    }

    /// Load a checkpoint (file or sharded directory) and register it
    /// under `name`. Returns the model index requests will carry.
    pub fn load(&mut self, name: &str, path: &Path) -> Result<usize> {
        if name.is_empty() {
            bail!("model name must be non-empty");
        }
        if self.find(name).is_some() {
            bail!("model name {name:?} is already registered");
        }
        let (model, stats) = NativeModel::from_checkpoint_with_stats(path)
            .map_err(|e| anyhow::anyhow!("loading {name:?} from {}: {e}", path.display()))?;
        let idx = self.push_slot(name, Some(path.to_path_buf()), model, stats);
        self.enforce_budget(Some(idx));
        Ok(idx)
    }

    /// Register an already-constructed model (tests, random-init demos).
    /// In-memory models have no checkpoint source, so they can be
    /// evicted but never reloaded.
    pub fn register(&mut self, name: &str, model: NativeModel) -> Result<usize> {
        if name.is_empty() || self.find(name).is_some() {
            bail!("model name {name:?} is empty or already registered");
        }
        Ok(self.push_slot(name, None, model, LoadStats::default()))
    }

    fn push_slot(
        &self,
        name: &str,
        path: Option<PathBuf>,
        model: NativeModel,
        stats: LoadStats,
    ) -> usize {
        let mut slots = self.slots.borrow_mut();
        let dims = model.dims;
        let resident = stats.resident_bytes() as u64;
        slots.push(ModelSlot {
            name: name.to_string(),
            path,
            dims,
            cur: Some(Arc::new(ModelVersion { version: 1, model, stats })),
            ws: RefCell::new(Workspace::new()),
            version: Cell::new(1),
            health: Cell::new(ModelHealth::Serving),
            consec_failures: Cell::new(0),
            last_used: Cell::new(0),
        });
        let idx = slots.len() - 1;
        crate::obs::register_model_label(idx, name);
        if idx < crate::obs::MAX_MODEL_SLOTS {
            if let Some(o) = crate::obs::metrics() {
                o.model_version[idx].set(1);
                o.model_health[idx].set(ModelHealth::Serving.as_u8() as u64);
                o.model_resident_bytes[idx].set(resident);
            }
        }
        idx
    }

    /// Reload one slot from its checkpoint source and atomically swap
    /// the new version in, returning `(old_version, new_version)`. The
    /// slot recovers to `Serving` whatever its prior health (this is the
    /// quarantine escape hatch). Callers running a server must drain
    /// in-flight batches first so nothing straddles the swap — the ADMIN
    /// frame handler does.
    pub fn reload_model_idx(&self, idx: usize) -> Result<(u64, u64)> {
        let path = {
            let slots = self.slots.borrow();
            let s = match slots.get(idx) {
                Some(s) => s,
                None => bail!("model index {idx} out of range ({} registered)", slots.len()),
            };
            match &s.path {
                Some(p) => p.clone(),
                None => bail!(
                    "model {:?} was registered in-memory — no checkpoint source to reload from",
                    s.name
                ),
            }
        };
        // load the new generation fully (and fallibly) before touching
        // the slot: a bad checkpoint leaves the old version serving
        let (model, stats) = NativeModel::from_checkpoint_with_stats(&path).map_err(|e| {
            anyhow::anyhow!("reloading model {idx} from {}: {e}", path.display())
        })?;
        {
            let mut slots = self.slots.borrow_mut();
            let s = &mut slots[idx];
            if model.dims != s.dims {
                bail!(
                    "reload of {:?} changed dims — queued work was admitted against the old \
                     shapes; evict and load under a new name instead",
                    s.name
                );
            }
            let old = s.version.get();
            let new = old + 1;
            let prev_health = s.health.get();
            let resident = stats.resident_bytes() as u64;
            s.version.set(new);
            s.cur = Some(Arc::new(ModelVersion { version: new, model, stats }));
            s.health.set(ModelHealth::Serving);
            s.consec_failures.set(0);
            crate::log_info!("model {:?} reloaded: v{old} -> v{new}", s.name);
            if idx < crate::obs::MAX_MODEL_SLOTS {
                if let Some(o) = crate::obs::metrics() {
                    o.model_reloads[idx].inc();
                    o.model_version[idx].set(new);
                    o.model_resident_bytes[idx].set(resident);
                    o.model_health[idx].set(ModelHealth::Serving.as_u8() as u64);
                    if prev_health != ModelHealth::Serving {
                        o.model_health_transitions[idx].inc();
                    }
                }
            }
            crate::obs::flight().record(
                crate::obs::FlightKind::Reload,
                0,
                idx as u16,
                0,
                0,
                new,
            );
            if prev_health != ModelHealth::Serving {
                crate::obs::flight().record(
                    crate::obs::FlightKind::Health,
                    0,
                    idx as u16,
                    prev_health.as_u8() as u16,
                    ModelHealth::Serving.as_u8() as u16,
                    0,
                );
            }
        }
        self.enforce_budget(Some(idx));
        let slots = self.slots.borrow();
        let new = slots[idx].version.get();
        Ok((new - 1, new))
    }

    /// Drop one slot's loaded weights, returning `(version,
    /// freed_bytes)`. The name/index stay registered; requests shed with
    /// a typed reject until a reload restores it.
    pub fn evict_model_idx(&self, idx: usize) -> Result<(u64, usize)> {
        let mut slots = self.slots.borrow_mut();
        let s = match slots.get_mut(idx) {
            Some(s) => s,
            None => bail!("model index {idx} out of range ({} registered)", slots.len()),
        };
        let cur = match s.cur.take() {
            Some(c) => c,
            None => bail!("model {:?} is already evicted", s.name),
        };
        let prev_health = s.health.get();
        s.health.set(ModelHealth::Evicted);
        s.consec_failures.set(0);
        crate::log_info!(
            "model {:?} evicted: v{} freed {} bytes",
            s.name,
            cur.version,
            cur.stats.resident_bytes()
        );
        if idx < crate::obs::MAX_MODEL_SLOTS {
            if let Some(o) = crate::obs::metrics() {
                o.model_evicts[idx].inc();
                o.model_health_transitions[idx].inc();
                o.model_health[idx].set(ModelHealth::Evicted.as_u8() as u64);
                o.model_resident_bytes[idx].set(0);
            }
        }
        crate::obs::flight().record(
            crate::obs::FlightKind::Evict,
            0,
            idx as u16,
            0,
            0,
            cur.version,
        );
        crate::obs::flight().record(
            crate::obs::FlightKind::Health,
            0,
            idx as u16,
            prev_health.as_u8() as u16,
            ModelHealth::Evicted.as_u8() as u16,
            0,
        );
        Ok((cur.version, cur.stats.resident_bytes()))
    }

    /// Evict least-recently-used slots (never `keep`) until the fleet
    /// fits the budget. No-op without a budget.
    fn enforce_budget(&self, keep: Option<usize>) {
        let Some(budget) = self.mem_budget.get() else { return };
        loop {
            let victim = {
                let slots = self.slots.borrow();
                let total: usize = slots
                    .iter()
                    .filter_map(|s| s.cur.as_ref())
                    .map(|c| c.stats.resident_bytes())
                    .sum();
                if total <= budget {
                    return;
                }
                slots
                    .iter()
                    .enumerate()
                    .filter(|(i, s)| s.cur.is_some() && Some(*i) != keep)
                    .min_by_key(|(_, s)| s.last_used.get())
                    .map(|(i, _)| i)
            };
            match victim {
                Some(i) => {
                    let _ = self.evict_model_idx(i);
                }
                None => return, // nothing evictable (only `keep` remains)
            }
        }
    }

    /// One-shot kernel autotune, shared by every model (run once after
    /// the last `load`).
    pub fn autotune(&mut self) {
        self.disp.autotune();
    }

    pub fn len(&self) -> usize {
        self.slots.borrow().len()
    }

    pub fn is_empty(&self) -> bool {
        self.slots.borrow().is_empty()
    }

    /// Model index for a registered name.
    pub fn find(&self, name: &str) -> Option<usize> {
        self.slots.borrow().iter().position(|s| s.name == name)
    }

    /// The current loaded generation of one slot (`None` for unknown
    /// indices and evicted slots). The handle keeps that version's
    /// weights alive across subsequent reloads/evictions.
    pub fn get(&self, model: usize) -> Option<Arc<ModelVersion>> {
        self.slots.borrow().get(model).and_then(|s| s.cur.clone())
    }

    /// Record one forward success: the consecutive-failure counter
    /// resets and a `Degraded`/`Loading` slot heals to `Serving`. Shared
    /// by the inline serve path and off-thread completion bookkeeping so
    /// the two cannot drift.
    fn note_success(&self, idx: usize, s: &ModelSlot) {
        s.consec_failures.set(0);
        let prev = s.health.get();
        if matches!(prev, ModelHealth::Degraded | ModelHealth::Loading) {
            s.health.set(ModelHealth::Serving);
            if idx < crate::obs::MAX_MODEL_SLOTS {
                if let Some(o) = crate::obs::metrics() {
                    o.model_health_transitions[idx].inc();
                    o.model_health[idx].set(ModelHealth::Serving.as_u8() as u64);
                }
            }
            crate::obs::flight().record(
                crate::obs::FlightKind::Health,
                0,
                idx as u16,
                prev.as_u8() as u16,
                ModelHealth::Serving.as_u8() as u16,
                0,
            );
        }
    }

    /// Record one forward failure; crossing the thresholds drives
    /// `Serving → Degraded → Quarantined`.
    fn note_failure(&self, idx: usize, s: &ModelSlot) {
        let n = s.consec_failures.get() + 1;
        s.consec_failures.set(n);
        let prev = s.health.get();
        match prev {
            ModelHealth::Quarantined | ModelHealth::Evicted => {}
            _ => {
                if n >= QUARANTINE_AFTER_FAILURES {
                    s.health.set(ModelHealth::Quarantined);
                } else if n >= DEGRADE_AFTER_FAILURES {
                    s.health.set(ModelHealth::Degraded);
                }
            }
        }
        let now = s.health.get();
        if now != prev {
            crate::log_warn!(
                "model {:?} health {:?} -> {:?} after {n} consecutive forward failures",
                s.name,
                prev,
                now
            );
        }
        if idx < crate::obs::MAX_MODEL_SLOTS {
            if let Some(o) = crate::obs::metrics() {
                o.model_forward_failures[idx].inc();
                if now != prev {
                    o.model_health_transitions[idx].inc();
                    o.model_health[idx].set(now.as_u8() as u64);
                }
            }
        }
        if now != prev {
            crate::obs::flight().record(
                crate::obs::FlightKind::Health,
                0,
                idx as u16,
                prev.as_u8() as u16,
                now.as_u8() as u16,
                0,
            );
            // crossing into quarantine is the black-box moment: dump the
            // whole retained ring while the events leading here are in it
            if now == ModelHealth::Quarantined {
                crate::obs::auto_dump(&format!(
                    "model {:?} quarantined after {n} consecutive forward failures",
                    s.name
                ));
            }
        }
    }
}

impl Backend for Registry {
    fn name(&self) -> String {
        let slots = self.slots.borrow();
        let names: Vec<&str> = slots.iter().map(|s| s.name.as_str()).collect();
        format!("registry(threads={}, models=[{}])", self.disp.threads(), names.join(","))
    }

    fn n_models(&self) -> usize {
        self.len()
    }

    fn model_label(&self, model: usize) -> String {
        self.slots
            .borrow()
            .get(model)
            .map(|s| s.name.clone())
            .unwrap_or_else(|| format!("#{model}"))
    }

    fn serve_dims(&self) -> Result<ServeDims> {
        self.serve_dims_for(0)
    }

    fn serve_dims_for(&self, model: usize) -> Result<ServeDims> {
        let slots = self.slots.borrow();
        match slots.get(model) {
            Some(s) => Ok(ServeDims {
                vocab: s.dims.vocab,
                seq: s.dims.seq,
                n_classes: s.dims.n_classes,
            }),
            None => bail!("model index {model} out of range ({} registered)", slots.len()),
        }
    }

    fn check_bucket(&self, bucket: usize) -> Result<()> {
        self.check_bucket_for(0, bucket)
    }

    fn check_bucket_for(&self, model: usize, bucket: usize) -> Result<()> {
        self.serve_dims_for(model)?;
        if bucket == 0 {
            bail!("bucket size 0");
        }
        Ok(())
    }

    fn check_seq_bucket(&self, t: usize) -> Result<()> {
        self.check_seq_bucket_for(0, t)
    }

    fn check_seq_bucket_for(&self, model: usize, t: usize) -> Result<()> {
        let dims = self.serve_dims_for(model)?;
        if t >= 1 && t <= dims.seq {
            Ok(())
        } else {
            bail!("seq bucket {t} out of range 1..={} for model {}", dims.seq, self.model_label(model))
        }
    }

    fn model_status(&self, model: usize) -> Result<ModelStatus> {
        let slots = self.slots.borrow();
        match slots.get(model) {
            Some(s) => Ok(ModelStatus {
                version: s.version.get(),
                health: s.health.get(),
                consec_failures: s.consec_failures.get(),
                resident_bytes: s.cur.as_ref().map(|c| c.stats.resident_bytes()).unwrap_or(0),
            }),
            None => bail!("model index {model} out of range ({} registered)", slots.len()),
        }
    }

    fn reload_model(&self, model: usize) -> Result<(u64, u64)> {
        self.reload_model_idx(model)
    }

    fn evict_model(&self, model: usize) -> Result<(u64, usize)> {
        self.evict_model_idx(model)
    }

    fn record_forward_panic(&self, model: usize) {
        let slots = self.slots.borrow();
        if let Some(s) = slots.get(model) {
            self.note_failure(model, s);
        }
    }

    fn serve_forward(&self, bucket: usize, t: usize, ids: &[i32], mask: &[f32]) -> Result<Vec<f32>> {
        self.serve_forward_for(0, bucket, t, ids, mask)
    }

    fn serve_forward_for(
        &self,
        model: usize,
        bucket: usize,
        t: usize,
        ids: &[i32],
        mask: &[f32],
    ) -> Result<Vec<f32>> {
        let slots = self.slots.borrow();
        let s = match slots.get(model) {
            Some(s) => s,
            None => bail!("model index {model} out of range ({} registered)", slots.len()),
        };
        // shed without touching failure counters: a quarantined model's
        // refusals are policy, not new evidence against it
        match s.health.get() {
            ModelHealth::Quarantined => bail!(
                "model {:?} is quarantined ({} consecutive forward failures) — reload to recover",
                s.name,
                s.consec_failures.get()
            ),
            ModelHealth::Evicted => bail!("model {:?} is evicted — reload to restore it", s.name),
            _ => {}
        }
        let cur = match &s.cur {
            Some(c) => c,
            None => bail!("model {:?} has no loaded weights", s.name),
        };
        let now = self.use_clock.get() + 1;
        self.use_clock.set(now);
        s.last_used.set(now);
        // the label is borrowed, not formatted, and the version handle is
        // borrowed, not cloned — no allocation on the per-batch success
        // path (the zero-alloc serving contract)
        let r = (|| {
            self.faults.before_forward()?;
            let mut ws = s.ws.borrow_mut();
            crate::runtime::backend::native_serve_forward(
                &s.name, &cur.model, &self.disp, &mut ws, bucket, t, ids, mask,
            )
        })();
        match &r {
            Ok(_) => self.note_success(model, s),
            Err(_) => self.note_failure(model, s),
        }
        r
    }

    fn supports_offthread(&self) -> bool {
        true
    }

    fn worker_dispatcher(&self) -> Option<Dispatcher> {
        Some(self.disp.replicate())
    }

    fn dispatch_handle(&self, model: usize) -> Option<Result<DispatchHandle>> {
        let slots = self.slots.borrow();
        let s = match slots.get(model) {
            Some(s) => s,
            None => {
                return Some(Err(anyhow::anyhow!(
                    "model index {model} out of range ({} registered)",
                    slots.len()
                )))
            }
        };
        // same gate as the inline path: quarantine/eviction sheds are
        // policy, not new evidence against the slot
        match s.health.get() {
            ModelHealth::Quarantined => {
                return Some(Err(anyhow::anyhow!(
                    "model {:?} is quarantined ({} consecutive forward failures) — reload to \
                     recover",
                    s.name,
                    s.consec_failures.get()
                )))
            }
            ModelHealth::Evicted => {
                return Some(Err(anyhow::anyhow!(
                    "model {:?} is evicted — reload to restore it",
                    s.name
                )))
            }
            _ => {}
        }
        let cur = match &s.cur {
            Some(c) => c,
            None => {
                return Some(Err(anyhow::anyhow!("model {:?} has no loaded weights", s.name)))
            }
        };
        let now = self.use_clock.get() + 1;
        self.use_clock.set(now);
        s.last_used.set(now);
        // the fault counter is consumed here, at dispatch, so injected
        // faults land in dispatch order regardless of which worker (or
        // when) the batch executes
        Some(Ok(DispatchHandle { version: Arc::clone(cur), fault: self.faults.sample_forward() }))
    }

    fn record_offthread_outcome(&self, model: usize, ok: bool) {
        let slots = self.slots.borrow();
        if let Some(s) = slots.get(model) {
            if ok {
                self.note_success(model, s);
            } else {
                self.note_failure(model, s);
            }
        }
    }

    fn layer_forward(
        &self,
        _prec: Precision,
        _bsz: usize,
        _t: usize,
        _h: &[f32],
        _mask: &[f32],
    ) -> Result<Vec<f32>> {
        bail!("registry backend hosts serving models, not bench layers")
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::runtime::NativeDims;

    fn tiny(seed: u64, n_classes: usize) -> NativeModel {
        let dims = NativeDims {
            vocab: 32,
            seq: 6,
            n_layers: 1,
            d_model: 16,
            n_heads: 2,
            d_ff: 32,
            n_classes,
        };
        NativeModel::random(dims, &[8], seed)
    }

    #[test]
    fn registry_routes_by_index_and_rejects_unknown() {
        let mut reg = Registry::new();
        assert!(reg.is_empty());
        let a = reg.register("a", tiny(1, 2)).unwrap();
        let b = reg.register("b", tiny(2, 3)).unwrap();
        assert_eq!((a, b), (0, 1));
        assert!(reg.register("a", tiny(3, 2)).is_err(), "duplicate name");
        assert_eq!(reg.n_models(), 2);
        assert_eq!(reg.find("b"), Some(1));
        assert_eq!(reg.find("zzz"), None);
        assert_eq!(reg.serve_dims_for(0).unwrap().n_classes, 2);
        assert_eq!(reg.serve_dims_for(1).unwrap().n_classes, 3);
        assert!(reg.serve_dims_for(2).is_err());

        let ids: Vec<i32> = (0..6).collect();
        let mask = vec![1.0f32; 6];
        let la = reg.serve_forward_for(0, 1, 6, &ids, &mask).unwrap();
        let lb = reg.serve_forward_for(1, 1, 6, &ids, &mask).unwrap();
        assert_eq!(la.len(), 2);
        assert_eq!(lb.len(), 3);
        // routing is real: the same request through each model agrees with
        // that model served directly
        let direct_a = tiny(1, 2).forward(&reg.disp, &ids, &mask, 1, 6);
        assert_eq!(la, direct_a, "model a must serve model a's weights");
        assert!(reg.serve_forward_for(2, 1, 6, &ids, &mask).is_err());
    }

    #[test]
    fn single_model_surface_is_model_zero() {
        let mut reg = Registry::new();
        reg.register("only", tiny(5, 2)).unwrap();
        assert_eq!(reg.serve_dims().unwrap().seq, 6);
        assert!(reg.check_seq_bucket(3).is_ok());
        assert!(reg.check_seq_bucket(7).is_err());
        assert!(reg.check_bucket(4).is_ok());
        assert!(reg.check_bucket(0).is_err());
    }

    #[test]
    fn health_machine_degrades_quarantines_and_recovers_on_success() {
        let mut reg = Registry::new();
        reg.register("m", tiny(3, 2)).unwrap();
        let ids: Vec<i32> = (0..6).collect();
        let mask = vec![1.0f32; 6];

        // every forward fails -> Degraded at 3, Quarantined at 5
        reg.set_faults(FaultPlan::fail_every(1));
        for i in 1..=4u32 {
            assert!(reg.serve_forward_for(0, 1, 6, &ids, &mask).is_err());
            let st = reg.model_status(0).unwrap();
            assert_eq!(st.consec_failures, i);
            let want = if i >= DEGRADE_AFTER_FAILURES {
                ModelHealth::Degraded
            } else {
                ModelHealth::Serving
            };
            assert_eq!(st.health, want, "after {i} failures");
        }
        assert!(reg.serve_forward_for(0, 1, 6, &ids, &mask).is_err());
        assert_eq!(reg.model_status(0).unwrap().health, ModelHealth::Quarantined);

        // quarantined: sheds even with faults disarmed, message is typed
        reg.set_faults(FaultPlan::default());
        let err = reg.serve_forward_for(0, 1, 6, &ids, &mask).unwrap_err();
        assert!(err.to_string().contains("quarantined"), "{err}");
        // shedding is policy, not evidence: the counter froze at 5
        assert_eq!(reg.model_status(0).unwrap().consec_failures, QUARANTINE_AFTER_FAILURES);

        // a Degraded model heals itself on the next success
        let mut reg2 = Registry::new();
        reg2.register("m", tiny(3, 2)).unwrap();
        reg2.set_faults(FaultPlan::fail_every(1));
        for _ in 0..DEGRADE_AFTER_FAILURES {
            assert!(reg2.serve_forward_for(0, 1, 6, &ids, &mask).is_err());
        }
        assert_eq!(reg2.model_status(0).unwrap().health, ModelHealth::Degraded);
        reg2.set_faults(FaultPlan::default());
        assert!(reg2.serve_forward_for(0, 1, 6, &ids, &mask).is_ok());
        let st = reg2.model_status(0).unwrap();
        assert_eq!(st.health, ModelHealth::Serving);
        assert_eq!(st.consec_failures, 0);
    }

    #[test]
    fn quarantine_is_per_slot_siblings_keep_serving() {
        let mut reg = Registry::new();
        reg.register("sick", tiny(1, 2)).unwrap();
        reg.register("healthy", tiny(2, 3)).unwrap();
        let ids: Vec<i32> = (0..6).collect();
        let mask = vec![1.0f32; 6];

        reg.set_faults(FaultPlan::fail_every(1));
        for _ in 0..QUARANTINE_AFTER_FAILURES {
            assert!(reg.serve_forward_for(0, 1, 6, &ids, &mask).is_err());
        }
        reg.set_faults(FaultPlan::default());
        assert_eq!(reg.model_status(0).unwrap().health, ModelHealth::Quarantined);
        assert_eq!(reg.model_status(1).unwrap().health, ModelHealth::Serving);
        assert!(reg.serve_forward_for(0, 1, 6, &ids, &mask).is_err());
        assert_eq!(reg.serve_forward_for(1, 1, 6, &ids, &mask).unwrap().len(), 3);
    }

    #[test]
    fn record_forward_panic_counts_like_a_failure() {
        let mut reg = Registry::new();
        reg.register("m", tiny(9, 2)).unwrap();
        for _ in 0..QUARANTINE_AFTER_FAILURES {
            reg.record_forward_panic(0);
        }
        assert_eq!(reg.model_status(0).unwrap().health, ModelHealth::Quarantined);
    }

    #[test]
    fn evict_sheds_typed_and_in_memory_models_cannot_reload() {
        let mut reg = Registry::new();
        reg.register("m", tiny(4, 2)).unwrap();
        let ids: Vec<i32> = (0..6).collect();
        let mask = vec![1.0f32; 6];
        assert!(reg.serve_forward_for(0, 1, 6, &ids, &mask).is_ok());

        let (version, _freed) = reg.evict_model_idx(0).unwrap();
        assert_eq!(version, 1);
        assert_eq!(reg.model_status(0).unwrap().health, ModelHealth::Evicted);
        assert!(reg.get(0).is_none());
        let err = reg.serve_forward_for(0, 1, 6, &ids, &mask).unwrap_err();
        assert!(err.to_string().contains("evicted"), "{err}");
        // dims stay answerable for bucket bookkeeping while evicted
        assert_eq!(reg.serve_dims_for(0).unwrap().seq, 6);
        assert!(reg.evict_model_idx(0).is_err(), "double evict is typed");
        // no checkpoint source -> reload is a typed error, not a panic
        let err = reg.reload_model_idx(0).unwrap_err();
        assert!(err.to_string().contains("in-memory"), "{err}");
        assert!(reg.reload_model_idx(7).is_err(), "bad index");
    }

    #[test]
    fn dispatch_handle_gates_health_and_outcomes_drive_the_state_machine() {
        let mut reg = Registry::new();
        reg.register("m", tiny(11, 2)).unwrap();
        assert!(reg.supports_offthread());

        // healthy slot: a handle comes back pointing at the live version
        let h = reg.dispatch_handle(0).unwrap().unwrap();
        assert_eq!(h.version.version, 1);
        assert!(h.fault.is_none(), "inert faults sample to None");
        assert!(reg.dispatch_handle(7).unwrap().is_err(), "bad index is typed");

        // off-thread failures walk Serving -> Degraded -> Quarantined,
        // exactly like inline failures
        for _ in 0..QUARANTINE_AFTER_FAILURES {
            reg.record_offthread_outcome(0, false);
        }
        assert_eq!(reg.model_status(0).unwrap().health, ModelHealth::Quarantined);
        let err = reg.dispatch_handle(0).unwrap().unwrap_err();
        assert!(err.to_string().contains("quarantined"), "{err}");

        // eviction sheds typed at dispatch too
        let mut reg2 = Registry::new();
        reg2.register("m", tiny(12, 2)).unwrap();
        reg2.evict_model_idx(0).unwrap();
        let err = reg2.dispatch_handle(0).unwrap().unwrap_err();
        assert!(err.to_string().contains("evicted"), "{err}");

        // a Degraded slot heals on an off-thread success
        let mut reg3 = Registry::new();
        reg3.register("m", tiny(13, 2)).unwrap();
        for _ in 0..DEGRADE_AFTER_FAILURES {
            reg3.record_offthread_outcome(0, false);
        }
        assert_eq!(reg3.model_status(0).unwrap().health, ModelHealth::Degraded);
        reg3.record_offthread_outcome(0, true);
        let st = reg3.model_status(0).unwrap();
        assert_eq!(st.health, ModelHealth::Serving);
        assert_eq!(st.consec_failures, 0);

        // sampled faults come out in dispatch order
        let mut reg4 = Registry::new();
        reg4.register("m", tiny(14, 2)).unwrap();
        reg4.set_faults(FaultPlan::fail_every(2));
        let f1 = reg4.dispatch_handle(0).unwrap().unwrap().fault.unwrap();
        let f2 = reg4.dispatch_handle(0).unwrap().unwrap().fault.unwrap();
        assert!(f1.apply().is_ok(), "forward #1 passes");
        assert!(f2.apply().is_err(), "forward #2 carries the injected failure");
    }

    #[test]
    fn version_handles_survive_eviction() {
        let mut reg = Registry::new();
        reg.register("m", tiny(6, 2)).unwrap();
        let handle = reg.get(0).unwrap();
        assert_eq!(handle.version, 1);
        reg.evict_model_idx(0).unwrap();
        // the held handle still serves its weights (Arc keeps them alive)
        let ids: Vec<i32> = (0..6).collect();
        let mask = vec![1.0f32; 6];
        let logits = handle.model.forward(&reg.disp, &ids, &mask, 1, 6);
        assert_eq!(logits.len(), 2);
    }
}
