//! Cache-tiled, register-blocked integer GEMM microkernels.
//!
//! Shapes follow the serving convention: activations `x` are `(m, k)`
//! row-major fp32, weights are prepacked `(k, n)` panels
//! ([`super::pack`]), output is `(m, n)` row-major fp32.
//!
//! Tiling: rows are processed in `MC`-row cache blocks (the quantized
//! activation block stays L2-resident), columns in `NR`-wide panels (one
//! panel is `k*NR` bytes for int8, `k*NR/2` for int4 — L1-resident and
//! streamed sequentially), and the microkernel holds an `MR x NR` i32
//! accumulator tile in registers across the whole K loop.
//!
//! int4 panels store offset nibbles (`code + INT4_OFFSET`); the
//! microkernel multiplies raw nibbles and folds the offset out *once per
//! output element* via the per-row activation sum:
//! `sum_k x*(code+off) - off*sum_k x == sum_k x*code`. This is exact in
//! i32, so the fused unpack costs one shift+mask per byte and no
//! per-element subtraction.
//!
//! Numerical contract: all kernels here accumulate exactly in i32 and
//! agree with each other bit-for-bit at every shape. They are also
//! bit-for-bit equal to [`crate::quant::qmatmul_ref`] whenever
//! `k * l_max_act * l_max_w < 2^24` — the oracle accumulates
//! integer-valued products in f32, which is exact only below 2^24, so the
//! bound is k <= 1024 for int8 (128*127 per product) and k <= 262144 for
//! int4. BERT-base attention/FFN-up shapes (k = 768) and every test shape
//! sit inside the bound; the FFN down-projection (k = 3072) at int8 is
//! outside it, where the *oracle* rounds and the integer kernels are the
//! exact ones. `rust/tests/kernels.rs` enforces oracle equality across
//! random in-bound shapes and both bit widths.

use crate::quant::{self, INT4_OFFSET};
use crate::util::threadpool::ThreadPool;

use super::pack::{PackedData, PackedF32, PackedWeights, MR, NR};

/// Rows per cache block: `MC * k` quantized activations (i16) stay within
/// L2 while every weight panel streams over them.
pub const MC: usize = 128;

/// Quantize activations exactly as `qmatmul_ref` does: per-row scale,
/// round-to-nearest, clamp to the *paper grid* `[l_min, l_max]`
/// (which includes +2^{b-1}, hence i16 storage).
pub fn quantize_activations(x: &[f32], m: usize, k: usize, sx: &[f32], bits: u32) -> Vec<i16> {
    assert_eq!(x.len(), m * k);
    assert_eq!(sx.len(), m);
    let (lmin, lmax) = quant::qbounds(bits);
    let mut qx = vec![0i16; m * k];
    for i in 0..m {
        let s = sx[i];
        let row = &x[i * k..(i + 1) * k];
        let out = &mut qx[i * k..(i + 1) * k];
        for j in 0..k {
            out[j] = (row[j] / s).round().clamp(lmin, lmax) as i16;
        }
    }
    qx
}

/// Per-row sums of quantized activations — the int4 offset-correction
/// term (cheap: one pass over data already in cache right after
/// quantization).
pub fn act_row_sums(qx: &[i16], m: usize, k: usize) -> Vec<i32> {
    assert_eq!(qx.len(), m * k);
    (0..m)
        .map(|i| qx[i * k..(i + 1) * k].iter().map(|&v| v as i32).sum())
        .collect()
}

/// Shared accumulator→fp32 epilogue (also used by the SIMD microkernels
/// in [`super::simd`] so every variant dequantizes identically).
#[inline(always)]
pub(crate) fn store_row(out: &mut [f32], acc: &[i32; NR], corr: i32, sxi: f32, sw: &[f32], nc: usize) {
    // matches qmatmul_ref's `acc * sx[i] * sw[c]` association exactly
    for c in 0..nc {
        out[c] = ((acc[c] - corr) as f32 * sxi) * sw[c];
    }
}

/// Per-token (per-row) activation scales from each row's abs-max — the
/// ROADMAP "per-token scales" lever: recovers int4 accuracy at zero kernel
/// cost because the kernels already take `sx: &[f32]` per row. All-zero
/// rows and rows containing any non-finite value (NaN/Inf activations)
/// fall back to the calibrated per-tensor scale, so fully padded
/// sequences quantize exactly as before and a poisoned row can never
/// hand the kernels a NaN `sx` (note `f32::max` silently *ignores* NaN,
/// so an abs-max alone would miss NaN elements).
pub fn per_token_scales(x: &[f32], m: usize, k: usize, bits: u32, fallback: f32) -> Vec<f32> {
    assert_eq!(x.len(), m * k);
    let lmax = quant::qbounds(bits).1;
    (0..m)
        .map(|i| {
            let mut amax = 0f32;
            let mut finite = true;
            for &v in &x[i * k..(i + 1) * k] {
                if v.is_finite() {
                    amax = amax.max(v.abs());
                } else {
                    finite = false;
                }
            }
            if finite && amax > 0.0 {
                amax / lmax
            } else {
                fallback
            }
        })
        .collect()
}

/// Fused per-token activation preparation: one traversal of `x` at the
/// memory level computing, per row, the per-token scale (abs-max with
/// the calibrated fallback — exactly [`per_token_scales`]' rule), the
/// quantized codes ([`quantize_activations`]' grid) and the row sum
/// ([`act_row_sums`]), written into caller-provided buffers so the
/// serving hot path allocates nothing. Each row is swept twice (abs-max,
/// then quantize+sum) but stays cache-hot between sweeps, so `x` streams
/// from memory once — versus three full-matrix passes for the unfused
/// composition. Bit-for-bit identical to
/// `per_token_scales` → `quantize_activations` → `act_row_sums`
/// (enforced by `fused_pass_matches_three_pass_composition`).
pub fn quantize_rows_fused(
    x: &[f32],
    m: usize,
    k: usize,
    bits: u32,
    fallback: f32,
    sx: &mut [f32],
    qx: &mut [i16],
    rs: &mut [i32],
) {
    assert_eq!(x.len(), m * k);
    assert_eq!(sx.len(), m);
    assert_eq!(qx.len(), m * k);
    assert_eq!(rs.len(), m);
    let (lmin, lmax) = quant::qbounds(bits);
    for i in 0..m {
        let row = &x[i * k..(i + 1) * k];
        let mut amax = 0f32;
        let mut finite = true;
        for &v in row {
            if v.is_finite() {
                amax = amax.max(v.abs());
            } else {
                finite = false;
            }
        }
        let s = if finite && amax > 0.0 { amax / lmax } else { fallback };
        sx[i] = s;
        let out = &mut qx[i * k..(i + 1) * k];
        let mut sum = 0i32;
        for j in 0..k {
            let q = (row[j] / s).round().clamp(lmin, lmax) as i16;
            out[j] = q;
            sum += q as i32;
        }
        rs[i] = sum;
    }
}

/// Single-threaded tiled GEMM over `m` rows. `rowsums` is only read for
/// int4 weights (pass `&[]`-compatible data for int8 is NOT allowed —
/// callers always provide it; it is one add per row to build).
pub fn gemm_serial(
    qx: &[i16],
    rowsums: &[i32],
    m: usize,
    k: usize,
    pw: &PackedWeights,
    sx: &[f32],
    out: &mut [f32],
) {
    assert_eq!(qx.len(), m * k);
    assert_eq!(rowsums.len(), m);
    assert_eq!(sx.len(), m);
    assert_eq!(pw.k, k);
    assert_eq!(out.len(), m * pw.n);
    let mut ic = 0;
    while ic < m {
        let mc = MC.min(m - ic);
        match &pw.data {
            PackedData::I8(_) | PackedData::I8Borrowed(_) => block_i8(qx, ic, mc, k, pw, sx, out),
            PackedData::I4(_) | PackedData::I4Borrowed(_) => {
                block_i4(qx, rowsums, ic, mc, k, pw, sx, out)
            }
        }
        ic += mc;
    }
}

fn block_i8(qx: &[i16], ic: usize, mc: usize, k: usize, pw: &PackedWeights, sx: &[f32], out: &mut [f32]) {
    let n = pw.n;
    let iend = ic + mc;
    for p in 0..pw.n_panels() {
        let j0 = p * NR;
        let nc = NR.min(n - j0);
        let panel = pw.panel_i8(p);
        let sw = &pw.scales[j0..j0 + nc];
        let mut i = ic;
        while i + MR <= iend {
            let r0 = &qx[i * k..i * k + k];
            let r1 = &qx[(i + 1) * k..(i + 1) * k + k];
            let r2 = &qx[(i + 2) * k..(i + 2) * k + k];
            let r3 = &qx[(i + 3) * k..(i + 3) * k + k];
            let mut a0 = [0i32; NR];
            let mut a1 = [0i32; NR];
            let mut a2 = [0i32; NR];
            let mut a3 = [0i32; NR];
            for kk in 0..k {
                let wr = &panel[kk * NR..kk * NR + NR];
                let x0 = r0[kk] as i32;
                let x1 = r1[kk] as i32;
                let x2 = r2[kk] as i32;
                let x3 = r3[kk] as i32;
                for c in 0..NR {
                    let w = wr[c] as i32;
                    a0[c] += x0 * w;
                    a1[c] += x1 * w;
                    a2[c] += x2 * w;
                    a3[c] += x3 * w;
                }
            }
            store_row(&mut out[i * n + j0..i * n + j0 + nc], &a0, 0, sx[i], sw, nc);
            store_row(&mut out[(i + 1) * n + j0..(i + 1) * n + j0 + nc], &a1, 0, sx[i + 1], sw, nc);
            store_row(&mut out[(i + 2) * n + j0..(i + 2) * n + j0 + nc], &a2, 0, sx[i + 2], sw, nc);
            store_row(&mut out[(i + 3) * n + j0..(i + 3) * n + j0 + nc], &a3, 0, sx[i + 3], sw, nc);
            i += MR;
        }
        while i < iend {
            let r = &qx[i * k..i * k + k];
            let mut acc = [0i32; NR];
            for kk in 0..k {
                let wr = &panel[kk * NR..kk * NR + NR];
                let x = r[kk] as i32;
                for c in 0..NR {
                    acc[c] += x * wr[c] as i32;
                }
            }
            store_row(&mut out[i * n + j0..i * n + j0 + nc], &acc, 0, sx[i], sw, nc);
            i += 1;
        }
    }
}

fn block_i4(
    qx: &[i16],
    rowsums: &[i32],
    ic: usize,
    mc: usize,
    k: usize,
    pw: &PackedWeights,
    sx: &[f32],
    out: &mut [f32],
) {
    let n = pw.n;
    let k2 = k / 2;
    let iend = ic + mc;
    for p in 0..pw.n_panels() {
        let j0 = p * NR;
        let nc = NR.min(n - j0);
        let panel = pw.panel_i4(p);
        let sw = &pw.scales[j0..j0 + nc];
        let mut i = ic;
        while i + MR <= iend {
            let r0 = &qx[i * k..i * k + k];
            let r1 = &qx[(i + 1) * k..(i + 1) * k + k];
            let r2 = &qx[(i + 2) * k..(i + 2) * k + k];
            let r3 = &qx[(i + 3) * k..(i + 3) * k + k];
            let mut a0 = [0i32; NR];
            let mut a1 = [0i32; NR];
            let mut a2 = [0i32; NR];
            let mut a3 = [0i32; NR];
            for kk2 in 0..k2 {
                let wr = &panel[kk2 * NR..kk2 * NR + NR];
                let x0e = r0[2 * kk2] as i32;
                let x0o = r0[2 * kk2 + 1] as i32;
                let x1e = r1[2 * kk2] as i32;
                let x1o = r1[2 * kk2 + 1] as i32;
                let x2e = r2[2 * kk2] as i32;
                let x2o = r2[2 * kk2 + 1] as i32;
                let x3e = r3[2 * kk2] as i32;
                let x3o = r3[2 * kk2 + 1] as i32;
                for c in 0..NR {
                    let b = wr[c] as i32;
                    let lo = b & 0xF;
                    let hi = b >> 4;
                    a0[c] += x0e * lo + x0o * hi;
                    a1[c] += x1e * lo + x1o * hi;
                    a2[c] += x2e * lo + x2o * hi;
                    a3[c] += x3e * lo + x3o * hi;
                }
            }
            let co = INT4_OFFSET;
            store_row(&mut out[i * n + j0..i * n + j0 + nc], &a0, co * rowsums[i], sx[i], sw, nc);
            store_row(&mut out[(i + 1) * n + j0..(i + 1) * n + j0 + nc], &a1, co * rowsums[i + 1], sx[i + 1], sw, nc);
            store_row(&mut out[(i + 2) * n + j0..(i + 2) * n + j0 + nc], &a2, co * rowsums[i + 2], sx[i + 2], sw, nc);
            store_row(&mut out[(i + 3) * n + j0..(i + 3) * n + j0 + nc], &a3, co * rowsums[i + 3], sx[i + 3], sw, nc);
            i += MR;
        }
        while i < iend {
            let r = &qx[i * k..i * k + k];
            let mut acc = [0i32; NR];
            for kk2 in 0..k2 {
                let wr = &panel[kk2 * NR..kk2 * NR + NR];
                let xe = r[2 * kk2] as i32;
                let xo = r[2 * kk2 + 1] as i32;
                for c in 0..NR {
                    let b = wr[c] as i32;
                    acc[c] += xe * (b & 0xF) + xo * (b >> 4);
                }
            }
            store_row(
                &mut out[i * n + j0..i * n + j0 + nc],
                &acc,
                INT4_OFFSET * rowsums[i],
                sx[i],
                sw,
                nc,
            );
            i += 1;
        }
    }
}

/// Signature every serial quantized-GEMM kernel shares ([`gemm_serial`]
/// and the SIMD variants in [`super::simd`]) — what the row-block
/// parallel driver fans out over.
pub type SerialKernel = fn(&[i16], &[i32], usize, usize, &PackedWeights, &[f32], &mut [f32]);

/// Row-block parallel GEMM: contiguous row chunks (one per thread) run
/// [`gemm_serial`] on disjoint output slices via the shared pool.
#[allow(clippy::too_many_arguments)]
pub fn gemm_parallel(
    qx: &[i16],
    rowsums: &[i32],
    m: usize,
    k: usize,
    pw: &PackedWeights,
    sx: &[f32],
    out: &mut [f32],
    pool: &ThreadPool,
    chunks: usize,
) {
    gemm_parallel_with(gemm_serial, qx, rowsums, m, k, pw, sx, out, pool, chunks);
}

/// Row-block parallel driver over any serial kernel (scalar or SIMD):
/// contiguous row chunks run `kernel` on disjoint output slices via the
/// shared pool. Bit-for-bit equal to running `kernel` serially because
/// row blocks are independent.
#[allow(clippy::too_many_arguments)]
pub fn gemm_parallel_with(
    kernel: SerialKernel,
    qx: &[i16],
    rowsums: &[i32],
    m: usize,
    k: usize,
    pw: &PackedWeights,
    sx: &[f32],
    out: &mut [f32],
    pool: &ThreadPool,
    chunks: usize,
) {
    let n = pw.n;
    assert_eq!(out.len(), m * n);
    let chunks = chunks.max(1).min(m.max(1));
    let per = (m + chunks - 1) / chunks;
    let mut jobs: Vec<Box<dyn FnOnce() + Send + '_>> = Vec::with_capacity(chunks);
    let mut rest = out;
    let mut row0 = 0usize;
    while row0 < m {
        let rows = per.min(m - row0);
        let tmp = rest;
        let (chunk_out, tail) = tmp.split_at_mut(rows * n);
        rest = tail;
        let qx_c = &qx[row0 * k..(row0 + rows) * k];
        let rs_c = &rowsums[row0..row0 + rows];
        let sx_c = &sx[row0..row0 + rows];
        jobs.push(Box::new(move || kernel(qx_c, rs_c, rows, k, pw, sx_c, chunk_out)));
        row0 += rows;
    }
    pool.scoped(jobs);
}

/// Reference kernel over *prequantized* activations: the scalar loop
/// structure of [`crate::quant::qmatmul_ref`] (row-major codes,
/// column-strided access, no tiling), but accumulating in i32 so it stays
/// exact — and identical to the blocked kernels — even past the oracle's
/// f32 bound. Used by the `reference` dispatch override and as the bench
/// baseline.
pub fn gemm_reference(
    qx: &[i16],
    m: usize,
    k: usize,
    codes: &[i8],
    n: usize,
    sx: &[f32],
    sw: &[f32],
    out: &mut [f32],
) {
    assert_eq!(codes.len(), k * n);
    for i in 0..m {
        for c in 0..n {
            let mut acc = 0i32;
            for j in 0..k {
                acc += qx[i * k + j] as i32 * codes[j * n + c] as i32;
            }
            out[i * n + c] = (acc as f32 * sx[i]) * sw[c];
        }
    }
}

/// Single-threaded fp32 GEMM over panel-packed weights (native baseline).
pub fn sgemm_serial(x: &[f32], m: usize, k: usize, pf: &PackedF32, out: &mut [f32]) {
    assert_eq!(x.len(), m * k);
    assert_eq!(pf.k, k);
    assert_eq!(out.len(), m * pf.n);
    let n = pf.n;
    let mut ic = 0;
    while ic < m {
        let mc = MC.min(m - ic);
        let iend = ic + mc;
        for p in 0..pf.n_panels() {
            let j0 = p * NR;
            let nc = NR.min(n - j0);
            let panel = pf.panel(p);
            let mut i = ic;
            while i + MR <= iend {
                let r0 = &x[i * k..i * k + k];
                let r1 = &x[(i + 1) * k..(i + 1) * k + k];
                let r2 = &x[(i + 2) * k..(i + 2) * k + k];
                let r3 = &x[(i + 3) * k..(i + 3) * k + k];
                let mut a0 = [0f32; NR];
                let mut a1 = [0f32; NR];
                let mut a2 = [0f32; NR];
                let mut a3 = [0f32; NR];
                for kk in 0..k {
                    let wr = &panel[kk * NR..kk * NR + NR];
                    let x0 = r0[kk];
                    let x1 = r1[kk];
                    let x2 = r2[kk];
                    let x3 = r3[kk];
                    for c in 0..NR {
                        let w = wr[c];
                        a0[c] += x0 * w;
                        a1[c] += x1 * w;
                        a2[c] += x2 * w;
                        a3[c] += x3 * w;
                    }
                }
                out[i * n + j0..i * n + j0 + nc].copy_from_slice(&a0[..nc]);
                out[(i + 1) * n + j0..(i + 1) * n + j0 + nc].copy_from_slice(&a1[..nc]);
                out[(i + 2) * n + j0..(i + 2) * n + j0 + nc].copy_from_slice(&a2[..nc]);
                out[(i + 3) * n + j0..(i + 3) * n + j0 + nc].copy_from_slice(&a3[..nc]);
                i += MR;
            }
            while i < iend {
                let r = &x[i * k..i * k + k];
                let mut acc = [0f32; NR];
                for kk in 0..k {
                    let wr = &panel[kk * NR..kk * NR + NR];
                    let xv = r[kk];
                    for c in 0..NR {
                        acc[c] += xv * wr[c];
                    }
                }
                out[i * n + j0..i * n + j0 + nc].copy_from_slice(&acc[..nc]);
                i += 1;
            }
        }
        ic += mc;
    }
}

/// Row-block parallel fp32 GEMM.
pub fn sgemm_parallel(
    x: &[f32],
    m: usize,
    k: usize,
    pf: &PackedF32,
    out: &mut [f32],
    pool: &ThreadPool,
    chunks: usize,
) {
    let n = pf.n;
    assert_eq!(out.len(), m * n);
    let chunks = chunks.max(1).min(m.max(1));
    let per = (m + chunks - 1) / chunks;
    let mut jobs: Vec<Box<dyn FnOnce() + Send + '_>> = Vec::with_capacity(chunks);
    let mut rest = out;
    let mut row0 = 0usize;
    while row0 < m {
        let rows = per.min(m - row0);
        let tmp = rest;
        let (chunk_out, tail) = tmp.split_at_mut(rows * n);
        rest = tail;
        let x_c = &x[row0 * k..(row0 + rows) * k];
        jobs.push(Box::new(move || sgemm_serial(x_c, rows, k, pf, chunk_out)));
        row0 += rows;
    }
    pool.scoped(jobs);
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::rng::Rng;

    fn setup(m: usize, k: usize, n: usize, bits: u32, seed: u64) -> (Vec<f32>, Vec<i8>, Vec<f32>, Vec<f32>) {
        let mut rng = Rng::new(seed);
        let x: Vec<f32> = (0..m * k).map(|_| rng.normal() as f32).collect();
        let codes = quant::random_codes(&mut rng, k * n, bits);
        let sx: Vec<f32> = (0..m).map(|_| 0.02 + rng.f32() * 0.2).collect();
        let sw: Vec<f32> = (0..n).map(|_| 0.01 + rng.f32() * 0.05).collect();
        (x, codes, sx, sw)
    }

    fn check_exact(m: usize, k: usize, n: usize, bits: u32, seed: u64) {
        let (x, codes, sx, sw) = setup(m, k, n, bits, seed);
        let want = quant::qmatmul_ref(&x, m, k, &codes, n, &sx, &sw, bits);
        let pw = PackedWeights::from_codes(&codes, k, n, sw.clone(), bits);
        let qx = quantize_activations(&x, m, k, &sx, bits);
        let rs = act_row_sums(&qx, m, k);
        let mut got = vec![0f32; m * n];
        gemm_serial(&qx, &rs, m, k, &pw, &sx, &mut got);
        assert_eq!(got, want, "serial m={m} k={k} n={n} bits={bits}");

        let pool = ThreadPool::new(3);
        let mut got_p = vec![0f32; m * n];
        gemm_parallel(&qx, &rs, m, k, &pw, &sx, &mut got_p, &pool, 4);
        assert_eq!(got_p, want, "parallel m={m} k={k} n={n} bits={bits}");

        let mut got_r = vec![0f32; m * n];
        gemm_reference(&qx, m, k, &codes, n, &sx, &sw, &mut got_r);
        assert_eq!(got_r, want, "reference m={m} k={k} n={n} bits={bits}");
    }

    #[test]
    fn matches_ref_int8_shapes() {
        for &(m, k, n) in &[(1usize, 2usize, 1usize), (3, 4, 5), (4, 8, 8), (7, 6, 9), (16, 32, 24), (130, 16, 17)] {
            check_exact(m, k, n, 8, 100 + m as u64);
        }
    }

    #[test]
    fn matches_ref_int4_shapes() {
        for &(m, k, n) in &[(1usize, 2usize, 1usize), (3, 4, 5), (4, 8, 8), (7, 6, 9), (16, 32, 24), (130, 16, 17)] {
            check_exact(m, k, n, 4, 200 + m as u64);
        }
    }

    #[test]
    fn activation_quantization_matches_grid() {
        let (lmin, lmax) = quant::qbounds(8);
        let x = vec![1000.0f32, -1000.0, 0.49, 0.51, -0.5];
        let qx = quantize_activations(&x, 1, 5, &[1.0], 8);
        assert_eq!(qx[0], lmax as i16); // +128: the paper grid exceeds i8
        assert_eq!(qx[1], lmin as i16);
        assert_eq!(qx[2], 0);
        assert_eq!(qx[3], 1);
        assert_eq!(qx[4], -1); // round half away from zero
        assert_eq!(act_row_sums(&qx, 1, 5), vec![128 - 127 + 0 + 1 - 1]);
    }

    #[test]
    fn per_token_scales_from_row_max() {
        let x = vec![1.0f32, -4.0, 2.0, 0.0, 0.0, 0.0, 0.5, 0.25, -0.125];
        let s = per_token_scales(&x, 3, 3, 8, 0.123);
        let lmax = quant::qbounds(8).1;
        assert_eq!(s[0], 4.0 / lmax);
        assert_eq!(s[1], 0.123); // all-zero row falls back to per-tensor
        assert_eq!(s[2], 0.5 / lmax);
        // a positive row max lands exactly on l_max (the paper grid's +2^{b-1})
        let qx = quantize_activations(&x, 3, 3, &s, 8);
        assert_eq!(qx[6], lmax as i16);
    }

    #[test]
    fn per_token_scales_guard_non_finite_rows() {
        // NaN is invisible to f32::max, and Inf would blow the scale up —
        // both rows must fall back to the calibrated per-tensor scale so
        // the kernels never receive a non-finite sx.
        let x = vec![
            1.0f32, f32::NAN, 2.0,          // NaN row
            f32::INFINITY, 0.5, 0.25,       // +Inf row
            0.1, f32::NEG_INFINITY, 0.2,    // -Inf row
            0.5, -0.25, 0.125,              // healthy row
        ];
        let s = per_token_scales(&x, 4, 3, 8, 0.321);
        assert_eq!(s[0], 0.321);
        assert_eq!(s[1], 0.321);
        assert_eq!(s[2], 0.321);
        let lmax = quant::qbounds(8).1;
        assert_eq!(s[3], 0.5 / lmax);
        assert!(s.iter().all(|v| v.is_finite()));
    }

    #[test]
    fn fused_pass_matches_three_pass_composition() {
        // quantize_rows_fused must be bit-for-bit the composition of
        // per_token_scales -> quantize_activations -> act_row_sums,
        // including the all-zero-row and non-finite-row fallbacks.
        let mut rng = Rng::new(41);
        for &(m, k) in &[(1usize, 2usize), (3, 5), (7, 16), (33, 24), (130, 12)] {
            for bits in [4u32, 8] {
                let mut x: Vec<f32> = (0..m * k).map(|_| rng.normal() as f32).collect();
                if m > 2 {
                    // one all-zero row and one poisoned row ride along
                    for v in x[k..2 * k].iter_mut() {
                        *v = 0.0;
                    }
                    x[2 * k] = f32::NAN;
                }
                let fallback = 0.037f32;
                let want_sx = per_token_scales(&x, m, k, bits, fallback);
                let want_qx = quantize_activations(&x, m, k, &want_sx, bits);
                let want_rs = act_row_sums(&want_qx, m, k);
                let mut sx = vec![0f32; m];
                let mut qx = vec![0i16; m * k];
                let mut rs = vec![0i32; m];
                quantize_rows_fused(&x, m, k, bits, fallback, &mut sx, &mut qx, &mut rs);
                assert_eq!(sx, want_sx, "sx m={m} k={k} bits={bits}");
                assert_eq!(qx, want_qx, "qx m={m} k={k} bits={bits}");
                assert_eq!(rs, want_rs, "rs m={m} k={k} bits={bits}");
            }
        }
    }

    #[test]
    fn sgemm_matches_naive() {
        let mut rng = Rng::new(9);
        let (m, k, n) = (13usize, 10usize, 11usize);
        let x: Vec<f32> = (0..m * k).map(|_| rng.normal() as f32).collect();
        let w: Vec<f32> = (0..k * n).map(|_| rng.normal() as f32).collect();
        let pf = PackedF32::from_rowmajor(&w, k, n);
        let mut got = vec![0f32; m * n];
        sgemm_serial(&x, m, k, &pf, &mut got);
        let pool = ThreadPool::new(2);
        let mut got_p = vec![0f32; m * n];
        sgemm_parallel(&x, m, k, &pf, &mut got_p, &pool, 3);
        for i in 0..m {
            for c in 0..n {
                let want: f32 = (0..k).map(|j| x[i * k + j] * w[j * n + c]).sum();
                assert!((got[i * n + c] - want).abs() < 1e-3, "sgemm {i},{c}");
                assert!((got_p[i * n + c] - want).abs() < 1e-3, "sgemm_par {i},{c}");
            }
        }
    }
}
