//! Prepacked weight formats for the native GEMM microkernels.
//!
//! Layout (built once at model-load time, amortized over every forward):
//! weights are stored as `ceil(n / NR)` *column panels*. Panel `p` covers
//! output channels `[p*NR, p*NR + NR)`; within a panel the codes are laid
//! out K-major — `panel[kk*NR + jj]` is the code for reduction index `kk`
//! and channel `p*NR + jj` — so the microkernel streams the panel
//! strictly sequentially while walking K. Channels past `n` in the last
//! panel are padded with the zero code so the kernel never branches on
//! column bounds inside the K loop.
//!
//! int4 packs two *K-consecutive* codes per byte as offset nibbles
//! (`code + INT4_OFFSET` in `[0, 15]`, even `kk` in the low nibble) —
//! the same nibble convention as [`crate::quant::pack_int4_k`], but in
//! panel order. The `+INT4_OFFSET` bias is *not* removed per element:
//! the microkernel accumulates raw nibbles and folds the bias out once
//! per output via the quantized-activation row sum (see
//! [`super::gemm`]). Padded channels hold nibble 7 (code 0) so the same
//! correction zeroes them exactly.
//!
//! This geometry is also what the SIMD microkernels consume directly:
//! with `NR == 8`, two K-consecutive int8 panel rows are 16 contiguous
//! bytes (one `_mm_loadu_si128`) and one int4 packed row is 8 bytes (one
//! `_mm_loadl_epi64` / `vld1_u8`), each filling a full `MR x NR` i32
//! accumulator lane — see [`super::simd`]. Changing `NR`/`MR` means
//! revisiting the interleave schemes there (both modules carry
//! compile-time guards).

use crate::quant;

/// Microkernel register-block width (output channels per panel).
pub const NR: usize = 8;
/// Microkernel register-block height (rows of the activation matrix).
pub const MR: usize = 4;

/// A byte range borrowed out of shared backing storage — typically an
/// mmap'd MKQC checkpoint shard. The `Arc` owner keeps the mapping (or
/// buffered file image) alive for as long as any borrower exists, so a
/// model built on `PanelRef`s can outlive the `Checkpoint` it was loaded
/// from without copying a single panel byte.
#[derive(Clone)]
pub struct PanelRef {
    owner: std::sync::Arc<dyn AsRef<[u8]> + Send + Sync>,
    offset: usize,
    len: usize,
}

impl PanelRef {
    /// `offset..offset+len` must lie inside the owner's byte slice for
    /// the owner's whole lifetime (true for file images, whose length
    /// never changes after open).
    pub fn new(owner: std::sync::Arc<dyn AsRef<[u8]> + Send + Sync>, offset: usize, len: usize) -> Self {
        let total = (*owner).as_ref().len();
        let end = offset.checked_add(len).expect("panel range overflows");
        assert!(end <= total, "panel range {offset}+{len} out of bounds for a {total}-byte owner");
        PanelRef { owner, offset, len }
    }

    pub fn bytes(&self) -> &[u8] {
        &(*self.owner).as_ref()[self.offset..self.offset + self.len]
    }

    pub fn len(&self) -> usize {
        self.len
    }

    pub fn is_empty(&self) -> bool {
        self.len == 0
    }
}

impl std::fmt::Debug for PanelRef {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        f.debug_struct("PanelRef").field("offset", &self.offset).field("len", &self.len).finish()
    }
}

/// Borrowed panel bytes viewed as int8 codes: same width, two's
/// complement on both sides — the inverse of the `raw_bytes` cast.
fn as_i8(bytes: &[u8]) -> &[i8] {
    unsafe { std::slice::from_raw_parts(bytes.as_ptr() as *const i8, bytes.len()) }
}

/// Per-output-channel scale storage: owned, or a zero-copy f32 view of a
/// checkpoint image (requires 4-byte alignment — callers fall back to
/// [`ScaleVec::Owned`] when the stored bytes don't qualify).
#[derive(Clone)]
pub enum ScaleVec {
    Owned(Vec<f32>),
    Borrowed(PanelRef),
}

impl ScaleVec {
    /// Borrow `r` as in-place f32s when legal on this target (little
    /// endian, 4-aligned, whole f32s); decode a copy otherwise.
    pub fn from_ref(r: PanelRef) -> Self {
        let ok = {
            let b = r.bytes();
            cfg!(target_endian = "little") && b.len() % 4 == 0 && (b.as_ptr() as usize) % 4 == 0
        };
        if ok {
            ScaleVec::Borrowed(r)
        } else {
            let b = r.bytes();
            ScaleVec::Owned(
                b.chunks_exact(4).map(|c| f32::from_le_bytes([c[0], c[1], c[2], c[3]])).collect(),
            )
        }
    }

    pub fn is_borrowed(&self) -> bool {
        matches!(self, ScaleVec::Borrowed(_))
    }

    /// Heap bytes resident beyond the (page-cache-backed) owner.
    pub fn heap_bytes(&self) -> usize {
        match self {
            ScaleVec::Owned(v) => v.len() * 4,
            ScaleVec::Borrowed(_) => 0,
        }
    }
}

impl std::ops::Deref for ScaleVec {
    type Target = [f32];

    fn deref(&self) -> &[f32] {
        match self {
            ScaleVec::Owned(v) => v,
            // alignment/endianness validated in from_ref
            ScaleVec::Borrowed(r) => {
                let b = r.bytes();
                unsafe { std::slice::from_raw_parts(b.as_ptr() as *const f32, b.len() / 4) }
            }
        }
    }
}

impl std::fmt::Debug for ScaleVec {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        write!(f, "{:?}", &self[..])
    }
}

#[derive(Debug, Clone)]
pub(crate) enum PackedData {
    I8(Vec<i8>),
    I4(Vec<u8>),
    /// int8 panels borrowed straight from a checkpoint image (zero-copy).
    I8Borrowed(PanelRef),
    /// int4 nibble panels borrowed from a checkpoint image (zero-copy).
    I4Borrowed(PanelRef),
}

/// Per-output-channel quantized weights in panel layout, plus scales.
#[derive(Debug, Clone)]
pub struct PackedWeights {
    pub bits: u32,
    pub k: usize,
    pub n: usize,
    /// Per-output-channel scales, length `n` (derefs to `[f32]`).
    pub scales: ScaleVec,
    pub(crate) data: PackedData,
}

impl PackedWeights {
    /// Pack row-major `(k, n)` integer codes (as produced by
    /// [`crate::quant::quantize_weight_per_channel`]).
    pub fn from_codes(codes: &[i8], k: usize, n: usize, scales: Vec<f32>, bits: u32) -> Self {
        assert_eq!(codes.len(), k * n);
        assert_eq!(scales.len(), n);
        let n_panels = (n + NR - 1) / NR;
        let data = match bits {
            8 => {
                let mut d = vec![0i8; n_panels * k * NR];
                for p in 0..n_panels {
                    let base = p * k * NR;
                    for kk in 0..k {
                        for jj in 0..NR {
                            let col = p * NR + jj;
                            if col < n {
                                d[base + kk * NR + jj] = codes[kk * n + col];
                            }
                        }
                    }
                }
                PackedData::I8(d)
            }
            4 => {
                assert!(k % 2 == 0, "int4 packing needs even K");
                let off = quant::INT4_OFFSET;
                // padded channels: nibble 7 == code 0, cancelled exactly by
                // the row-sum correction.
                let pad = (off | (off << 4)) as u8;
                let mut d = vec![pad; n_panels * (k / 2) * NR];
                for p in 0..n_panels {
                    let base = p * (k / 2) * NR;
                    for kk2 in 0..k / 2 {
                        for jj in 0..NR {
                            let col = p * NR + jj;
                            if col < n {
                                let lo = codes[(2 * kk2) * n + col] as i32 + off;
                                let hi = codes[(2 * kk2 + 1) * n + col] as i32 + off;
                                debug_assert!(
                                    (0..16).contains(&lo) && (0..16).contains(&hi),
                                    "code out of int4 range"
                                );
                                d[base + kk2 * NR + jj] = (lo | (hi << 4)) as u8;
                            }
                        }
                    }
                }
                PackedData::I4(d)
            }
            b => panic!("unsupported packed bit width {b} (use 4 or 8)"),
        };
        PackedWeights { bits, k, n, scales: ScaleVec::Owned(scales), data }
    }

    /// Quantize a row-major `(k, n)` fp32 matrix per-channel and pack it —
    /// the model-load entry point.
    pub fn from_f32(w: &[f32], k: usize, n: usize, bits: u32) -> Self {
        let (codes, scales) = quant::quantize_weight_per_channel(w, k, n, bits);
        Self::from_codes(&codes, k, n, scales, bits)
    }

    /// Packed byte length of a `(k, n)` matrix at the given bit width —
    /// the size contract between this layout and the MKQC v2 checkpoint
    /// format (`None` for unsupported widths or odd-K int4).
    pub fn packed_len(bits: u32, k: usize, n: usize) -> Option<usize> {
        let n_panels = (n + NR - 1) / NR;
        match bits {
            8 => Some(n_panels * k * NR),
            4 if k % 2 == 0 => Some(n_panels * (k / 2) * NR),
            _ => None,
        }
    }

    /// Rebuild from raw panel bytes persisted by a v2 checkpoint — the
    /// load path that skips quantize+pack entirely. The bytes must be
    /// exactly what [`PackedWeights::raw_bytes`] produced for the same
    /// `(bits, k, n)` under the current panel layout; length is the only
    /// thing that can be validated here (every byte pattern is a legal
    /// code stream), so callers gate on the checkpoint's panel-layout
    /// version byte first.
    pub fn from_panels(
        bits: u32,
        k: usize,
        n: usize,
        scales: Vec<f32>,
        bytes: &[u8],
    ) -> Result<Self, String> {
        Self::check_panel_geometry(bits, k, n, scales.len(), bytes.len())?;
        let data = match bits {
            8 => PackedData::I8(bytes.iter().map(|&b| b as i8).collect()),
            _ => PackedData::I4(bytes.to_vec()),
        };
        Ok(PackedWeights { bits, k, n, scales: ScaleVec::Owned(scales), data })
    }

    /// Zero-copy variant of [`PackedWeights::from_panels`]: the panels
    /// (and optionally the scales) stay borrowed from the checkpoint
    /// image behind `PanelRef`s, so building the model copies nothing and
    /// the weights' resident cost is the page cache backing the mapping.
    pub fn from_panel_ref(
        bits: u32,
        k: usize,
        n: usize,
        scales: ScaleVec,
        panels: PanelRef,
    ) -> Result<Self, String> {
        Self::check_panel_geometry(bits, k, n, scales.len(), panels.len())?;
        let data = match bits {
            8 => PackedData::I8Borrowed(panels),
            _ => PackedData::I4Borrowed(panels),
        };
        Ok(PackedWeights { bits, k, n, scales, data })
    }

    fn check_panel_geometry(
        bits: u32,
        k: usize,
        n: usize,
        n_scales: usize,
        n_bytes: usize,
    ) -> Result<(), String> {
        if n_scales != n {
            return Err(format!("panel scales: {n_scales} entries for n={n}"));
        }
        let want = Self::packed_len(bits, k, n)
            .ok_or_else(|| format!("unsupported panel geometry: bits={bits} k={k} n={n}"))?;
        if n_bytes != want {
            return Err(format!("panel bytes: {n_bytes} for bits={bits} k={k} n={n} (want {want})"));
        }
        Ok(())
    }

    /// The raw packed panel bytes, as persisted by the MKQC v2 writer.
    /// int8 codes reinterpret as bytes (same width, two's complement on
    /// both sides of the file boundary).
    pub fn raw_bytes(&self) -> &[u8] {
        match &self.data {
            // i8 -> u8 reinterpret: same size/alignment, every bit
            // pattern valid in both directions.
            PackedData::I8(d) => unsafe {
                std::slice::from_raw_parts(d.as_ptr() as *const u8, d.len())
            },
            PackedData::I4(d) => d,
            PackedData::I8Borrowed(r) | PackedData::I4Borrowed(r) => r.bytes(),
        }
    }

    pub fn n_panels(&self) -> usize {
        (self.n + NR - 1) / NR
    }

    /// int8 panel `p`: `k * NR` codes, K-major.
    pub(crate) fn panel_i8(&self, p: usize) -> &[i8] {
        let span = p * self.k * NR..(p + 1) * self.k * NR;
        match &self.data {
            PackedData::I8(d) => &d[span],
            PackedData::I8Borrowed(r) => &as_i8(r.bytes())[span],
            PackedData::I4(_) | PackedData::I4Borrowed(_) => {
                panic!("int4 weights have no i8 panels")
            }
        }
    }

    /// int4 panel `p`: `(k/2) * NR` offset-nibble bytes, K-major.
    pub(crate) fn panel_i4(&self, p: usize) -> &[u8] {
        let span = p * (self.k / 2) * NR..(p + 1) * (self.k / 2) * NR;
        match &self.data {
            PackedData::I4(d) => &d[span],
            PackedData::I4Borrowed(r) => &r.bytes()[span],
            PackedData::I8(_) | PackedData::I8Borrowed(_) => {
                panic!("int8 weights have no i4 panels")
            }
        }
    }

    /// Reverse the packing back to row-major `(k, n)` codes (testing and
    /// the reference-kernel fallback).
    pub fn unpack_codes(&self) -> Vec<i8> {
        let (k, n) = (self.k, self.n);
        let mut out = vec![0i8; k * n];
        match &self.data {
            PackedData::I8(_) | PackedData::I8Borrowed(_) => {
                for p in 0..self.n_panels() {
                    let panel = self.panel_i8(p);
                    for kk in 0..k {
                        for jj in 0..NR {
                            let col = p * NR + jj;
                            if col < n {
                                out[kk * n + col] = panel[kk * NR + jj];
                            }
                        }
                    }
                }
            }
            PackedData::I4(_) | PackedData::I4Borrowed(_) => {
                let off = quant::INT4_OFFSET;
                for p in 0..self.n_panels() {
                    let panel = self.panel_i4(p);
                    for kk2 in 0..k / 2 {
                        for jj in 0..NR {
                            let col = p * NR + jj;
                            if col < n {
                                let b = panel[kk2 * NR + jj] as i32;
                                out[(2 * kk2) * n + col] = ((b & 0xF) - off) as i8;
                                out[(2 * kk2 + 1) * n + col] = ((b >> 4) - off) as i8;
                            }
                        }
                    }
                }
            }
        }
        out
    }

    /// Bytes of packed weight data actually streamed per full GEMM — the
    /// memory-traffic half of the paper's int4 speedup story.
    pub fn packed_bytes(&self) -> usize {
        match &self.data {
            PackedData::I8(d) => d.len(),
            PackedData::I4(d) => d.len(),
            PackedData::I8Borrowed(r) | PackedData::I4Borrowed(r) => r.len(),
        }
    }

    /// Whether the panel bytes are borrowed from a checkpoint image
    /// rather than owned (the zero-copy load path).
    pub fn is_borrowed(&self) -> bool {
        matches!(
            self.data,
            PackedData::I8Borrowed(_) | PackedData::I4Borrowed(_)
        )
    }

    /// Heap bytes this pack keeps resident beyond shared backing storage:
    /// owned panel/scale buffers count, borrowed views cost nothing here
    /// (their bytes live in the checkpoint image, typically page cache).
    pub fn heap_bytes(&self) -> usize {
        let panels = match &self.data {
            PackedData::I8(d) => d.len(),
            PackedData::I4(d) => d.len(),
            PackedData::I8Borrowed(_) | PackedData::I4Borrowed(_) => 0,
        };
        panels + self.scales.heap_bytes()
    }
}

/// fp32 weights in the same panel layout (the native f32 baseline the
/// quantized kernels are compared against).
#[derive(Debug, Clone, Default)]
pub struct PackedF32 {
    pub k: usize,
    pub n: usize,
    data: Vec<f32>,
}

impl PackedF32 {
    pub fn from_rowmajor(w: &[f32], k: usize, n: usize) -> Self {
        let mut pf = Self::empty();
        pf.repack_rowmajor(w, k, n);
        pf
    }

    /// An empty pack to be filled by [`Self::repack_rowmajor`] — the
    /// workspace slots the attention path re-packs per `(batch, head)`.
    pub fn empty() -> Self {
        PackedF32 { k: 0, n: 0, data: Vec::new() }
    }

    /// Re-pack a row-major `(k, n)` matrix in place, reusing the existing
    /// allocation whenever capacity allows — at a steady serving shape
    /// this never touches the heap (the zero-alloc workspace contract).
    pub fn repack_rowmajor(&mut self, w: &[f32], k: usize, n: usize) {
        assert_eq!(w.len(), k * n);
        let n_panels = (n + NR - 1) / NR;
        self.k = k;
        self.n = n;
        self.data.clear();
        self.data.resize(n_panels * k * NR, 0.0);
        for p in 0..n_panels {
            let base = p * k * NR;
            for kk in 0..k {
                for jj in 0..NR {
                    let col = p * NR + jj;
                    if col < n {
                        self.data[base + kk * NR + jj] = w[kk * n + col];
                    }
                }
            }
        }
    }

    pub fn n_panels(&self) -> usize {
        (self.n + NR - 1) / NR
    }

    pub(crate) fn panel(&self, p: usize) -> &[f32] {
        &self.data[p * self.k * NR..(p + 1) * self.k * NR]
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::rng::Rng;

    fn random_codes(k: usize, n: usize, bits: u32, seed: u64) -> Vec<i8> {
        quant::random_codes(&mut Rng::new(seed), k * n, bits)
    }

    #[test]
    fn pack_unpack_roundtrip_i8() {
        for &(k, n) in &[(2usize, 1usize), (4, 7), (6, 8), (8, 9), (16, 24), (10, 31)] {
            let codes = random_codes(k, n, 8, 42 + n as u64);
            let pw = PackedWeights::from_codes(&codes, k, n, vec![1.0; n], 8);
            assert_eq!(pw.unpack_codes(), codes, "k={k} n={n}");
            assert_eq!(pw.packed_bytes(), pw.n_panels() * k * NR);
        }
    }

    #[test]
    fn pack_unpack_roundtrip_i4() {
        for &(k, n) in &[(2usize, 1usize), (4, 7), (6, 8), (8, 9), (16, 24), (10, 31)] {
            let codes = random_codes(k, n, 4, 7 + n as u64);
            let pw = PackedWeights::from_codes(&codes, k, n, vec![1.0; n], 4);
            assert_eq!(pw.unpack_codes(), codes, "k={k} n={n}");
            assert_eq!(pw.packed_bytes(), pw.n_panels() * (k / 2) * NR);
        }
    }

    #[test]
    #[should_panic(expected = "even K")]
    fn pack_i4_rejects_odd_k() {
        let codes = vec![0i8; 3 * 4];
        let _ = PackedWeights::from_codes(&codes, 3, 4, vec![1.0; 4], 4);
    }

    #[test]
    fn panel_bytes_roundtrip_from_panels() {
        // raw_bytes -> from_panels must reproduce the pack exactly (the
        // MKQC v2 persistence contract), including ragged last panels.
        for bits in [4u32, 8] {
            for &(k, n) in &[(2usize, 1usize), (4, 7), (6, 8), (8, 9), (16, 24)] {
                let codes = random_codes(k, n, bits, 100 + n as u64);
                let scales: Vec<f32> = (0..n).map(|i| 0.01 + i as f32 * 0.001).collect();
                let pw = PackedWeights::from_codes(&codes, k, n, scales.clone(), bits);
                assert_eq!(pw.raw_bytes().len(), PackedWeights::packed_len(bits, k, n).unwrap());
                let back =
                    PackedWeights::from_panels(bits, k, n, scales, pw.raw_bytes()).unwrap();
                assert_eq!(back.unpack_codes(), codes, "bits={bits} k={k} n={n}");
                assert_eq!(back.raw_bytes(), pw.raw_bytes());
            }
        }
        // wrong byte count and odd-K int4 are rejected
        assert!(PackedWeights::from_panels(8, 4, 4, vec![1.0; 4], &[0u8; 3]).is_err());
        assert!(PackedWeights::from_panels(4, 3, 4, vec![1.0; 4], &[0u8; 12]).is_err());
        assert!(PackedWeights::packed_len(32, 4, 4).is_none());
    }

    #[test]
    fn from_f32_matches_quantizer() {
        let mut rng = Rng::new(5);
        let (k, n) = (12, 10);
        let w: Vec<f32> = (0..k * n).map(|_| rng.normal() as f32 * 0.1).collect();
        for bits in [4u32, 8] {
            let (codes, scales) = quant::quantize_weight_per_channel(&w, k, n, bits);
            let pw = PackedWeights::from_f32(&w, k, n, bits);
            assert_eq!(pw.unpack_codes(), codes);
            assert_eq!(&pw.scales[..], &scales[..]);
        }
    }

    #[test]
    fn borrowed_panels_roundtrip_zero_copy() {
        // from_panel_ref over an Arc-owned image must serve the exact
        // same codes/bytes as the owned pack while keeping zero heap
        // bytes resident (the fleet eviction accounting contract).
        for bits in [4u32, 8] {
            for &(k, n) in &[(4usize, 7usize), (6, 8), (16, 24)] {
                let codes = random_codes(k, n, bits, 300 + n as u64);
                let scales: Vec<f32> = (0..n).map(|i| 0.02 + i as f32 * 0.001).collect();
                let pw = PackedWeights::from_codes(&codes, k, n, scales.clone(), bits);

                // build one image: [panel bytes][scale bytes], like a shard payload
                let mut image = pw.raw_bytes().to_vec();
                let scales_off = image.len();
                for s in &scales {
                    image.extend_from_slice(&s.to_le_bytes());
                }
                let owner: std::sync::Arc<dyn AsRef<[u8]> + Send + Sync> =
                    std::sync::Arc::new(image);

                let panels = PanelRef::new(owner.clone(), 0, scales_off);
                let sref = PanelRef::new(owner.clone(), scales_off, n * 4);
                let back =
                    PackedWeights::from_panel_ref(bits, k, n, ScaleVec::from_ref(sref), panels)
                        .unwrap();

                assert!(back.is_borrowed());
                assert_eq!(back.heap_bytes(), back.scales.heap_bytes());
                assert_eq!(back.unpack_codes(), codes, "bits={bits} k={k} n={n}");
                assert_eq!(back.raw_bytes(), pw.raw_bytes());
                assert_eq!(back.packed_bytes(), pw.packed_bytes());
                assert_eq!(&back.scales[..], &scales[..]);

                // geometry violations are rejected just like from_panels
                let bad = PanelRef::new(owner.clone(), 0, scales_off.saturating_sub(1));
                assert!(PackedWeights::from_panel_ref(
                    bits,
                    k,
                    n,
                    ScaleVec::Owned(scales.clone()),
                    bad
                )
                .is_err());
            }
        }
    }

    #[test]
    #[should_panic(expected = "out of bounds")]
    fn panel_ref_rejects_out_of_range() {
        let owner: std::sync::Arc<dyn AsRef<[u8]> + Send + Sync> =
            std::sync::Arc::new(vec![0u8; 8]);
        let _ = PanelRef::new(owner, 4, 8);
    }

    #[test]
    fn repack_reuses_buffer_and_matches_from_rowmajor() {
        // shrinking then re-growing through the same slot must reproduce
        // a fresh pack exactly (stale tail data cleared, zero padding back)
        let mut pf = PackedF32::empty();
        for &(k, n) in &[(3usize, 11usize), (2, 5), (4, 16), (3, 11)] {
            let w: Vec<f32> = (0..k * n).map(|i| i as f32 * 0.5 - 1.0).collect();
            pf.repack_rowmajor(&w, k, n);
            let fresh = PackedF32::from_rowmajor(&w, k, n);
            assert_eq!((pf.k, pf.n), (fresh.k, fresh.n));
            assert_eq!(pf.data, fresh.data, "k={k} n={n}");
        }
    }

    #[test]
    fn packed_f32_panels() {
        let (k, n) = (3usize, 11usize);
        let w: Vec<f32> = (0..k * n).map(|i| i as f32).collect();
        let pf = PackedF32::from_rowmajor(&w, k, n);
        assert_eq!(pf.n_panels(), 2);
        for p in 0..pf.n_panels() {
            let panel = pf.panel(p);
            for kk in 0..k {
                for jj in 0..NR {
                    let col = p * NR + jj;
                    let want = if col < n { w[kk * n + col] } else { 0.0 };
                    assert_eq!(panel[kk * NR + jj], want);
                }
            }
        }
    }
}
