//! Runtime kernel dispatch.
//!
//! One [`Dispatcher`] is built per backend at model-load time. For every
//! GEMM call it selects a kernel variant from the problem shape, the
//! machine (`available_parallelism` + SIMD feature detection), and the
//! recorded [`Tuning`], so the same code path serves tiny eval batches
//! and full serving buckets:
//!
//! | kind               | body                          | picked when |
//! |--------------------|-------------------------------|-------------|
//! | `Reference`        | scalar column-strided oracle  | forced only (correctness debugging; re-unpacks panels per call — don't time it) |
//! | `Blocked`          | scalar cache-tiled `MR x NR`  | no SIMD on this machine, small problems |
//! | `BlockedParallel`  | row-block fan-out of Blocked  | no SIMD, MACs ≥ parallel threshold |
//! | `Avx2`             | `_mm256_madd_epi16` microkernel ([`super::simd`]) | x86_64 with AVX2, small problems |
//! | `Avx2Parallel`     | row-block fan-out of Avx2     | AVX2, MACs ≥ parallel threshold |
//! | `Neon`             | `vmlal_s16` microkernel       | aarch64, small problems |
//! | `NeonParallel`     | row-block fan-out of Neon     | aarch64, MACs ≥ parallel threshold |
//!
//! Every variant obeys the same i32-accumulation contract, so selection
//! never changes results — only latency.
//!
//! Env overrides (serving ops knobs):
//! `MKQ_KERNEL=reference|blocked|parallel|avx2|avx2-parallel|neon|neon-parallel|simd|simd-parallel`
//! forces a variant (unsupported picks degrade to the scalar blocked
//! kernels with a warning — never an illegal instruction),
//! `MKQ_THREADS=N` caps the pool, `MKQ_AUTOTUNE=0` skips the load-time
//! microbenchmark ([`Dispatcher::autotune`]) for deterministic CI.

use crate::util::rng::Rng;
use crate::util::threadpool::ThreadPool;

use super::gemm;
use super::pack::{PackedF32, PackedWeights};
use super::simd;

/// Below this many multiply-accumulates the fork/join cost of the pool
/// outweighs the parallel win (measured on the layers bench; the
/// load-time [`Dispatcher::autotune`] re-measures it per machine).
pub const PARALLEL_MACS_THRESHOLD: usize = 1 << 20;

/// Metric slot names for `mkq_kernel_{calls,macs}_total{kind=...}`:
/// the 7 [`KernelKind`] variants in [`KernelKind::ALL`] order plus the
/// packed-f32 GEMM ([`Dispatcher::matmul_f32_into`]).
pub const KERNEL_SLOT_NAMES: [&str; crate::obs::N_KERNEL_SLOTS] = [
    "reference",
    "blocked",
    "blocked-parallel",
    "avx2",
    "avx2-parallel",
    "neon",
    "neon-parallel",
    "f32",
];

/// Metric slot of the packed-f32 GEMM.
pub const F32_KERNEL_SLOT: usize = 7;

/// Metric slot of a quantized kernel kind (index into
/// [`KERNEL_SLOT_NAMES`] / the registry's `kernel_*` arrays).
pub fn kernel_slot(kind: KernelKind) -> usize {
    match kind {
        KernelKind::Reference => 0,
        KernelKind::Blocked => 1,
        KernelKind::BlockedParallel => 2,
        KernelKind::Avx2 => 3,
        KernelKind::Avx2Parallel => 4,
        KernelKind::Neon => 5,
        KernelKind::NeonParallel => 6,
    }
}

#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum KernelKind {
    Reference,
    Blocked,
    BlockedParallel,
    Avx2,
    Avx2Parallel,
    Neon,
    NeonParallel,
}

impl KernelKind {
    /// Every variant, serial kinds before their parallel twins.
    pub const ALL: [KernelKind; 7] = [
        KernelKind::Reference,
        KernelKind::Blocked,
        KernelKind::BlockedParallel,
        KernelKind::Avx2,
        KernelKind::Avx2Parallel,
        KernelKind::Neon,
        KernelKind::NeonParallel,
    ];

    pub fn name(self) -> &'static str {
        match self {
            KernelKind::Reference => "reference",
            KernelKind::Blocked => "blocked",
            KernelKind::BlockedParallel => "blocked-parallel",
            KernelKind::Avx2 => "avx2",
            KernelKind::Avx2Parallel => "avx2-parallel",
            KernelKind::Neon => "neon",
            KernelKind::NeonParallel => "neon-parallel",
        }
    }

    /// Parse an `MKQ_KERNEL` value. `simd`/`simd-parallel` resolve to the
    /// best SIMD kind on this machine (`None` when there is none — the
    /// caller warns and falls back to auto selection).
    pub fn parse(s: &str) -> Option<KernelKind> {
        match s {
            "reference" => Some(KernelKind::Reference),
            "blocked" => Some(KernelKind::Blocked),
            "parallel" | "blocked-parallel" => Some(KernelKind::BlockedParallel),
            "avx2" => Some(KernelKind::Avx2),
            "avx2-parallel" => Some(KernelKind::Avx2Parallel),
            "neon" => Some(KernelKind::Neon),
            "neon-parallel" => Some(KernelKind::NeonParallel),
            "simd" => simd::best(),
            "simd-parallel" => simd::best().map(KernelKind::parallel_variant),
            _ => None,
        }
    }

    pub fn is_parallel(self) -> bool {
        matches!(
            self,
            KernelKind::BlockedParallel | KernelKind::Avx2Parallel | KernelKind::NeonParallel
        )
    }

    /// The row-block parallel twin of a serial kind (identity for
    /// `Reference` and for kinds that are already parallel).
    pub fn parallel_variant(self) -> KernelKind {
        match self {
            KernelKind::Blocked => KernelKind::BlockedParallel,
            KernelKind::Avx2 => KernelKind::Avx2Parallel,
            KernelKind::Neon => KernelKind::NeonParallel,
            other => other,
        }
    }

    /// The serial twin of a parallel kind (identity otherwise).
    pub fn serial_variant(self) -> KernelKind {
        match self {
            KernelKind::BlockedParallel => KernelKind::Blocked,
            KernelKind::Avx2Parallel => KernelKind::Avx2,
            KernelKind::NeonParallel => KernelKind::Neon,
            other => other,
        }
    }

    /// Can this variant actually run on this machine?
    pub fn supported(self) -> bool {
        match self {
            KernelKind::Reference | KernelKind::Blocked | KernelKind::BlockedParallel => true,
            KernelKind::Avx2 | KernelKind::Avx2Parallel => simd::avx2_available(),
            KernelKind::Neon | KernelKind::NeonParallel => simd::neon_available(),
        }
    }
}

/// Machine-specific selection parameters, either the static defaults or
/// the result of the load-time [`Dispatcher::autotune`] microbenchmark.
#[derive(Debug, Clone, Copy)]
pub struct Tuning {
    /// MACs above which row-block parallelism beats fork/join overhead.
    pub parallel_macs_threshold: usize,
    /// MACs above which the SIMD kernel is preferred over scalar blocked
    /// (`0` = always when available, `usize::MAX` = never).
    pub simd_macs_threshold: usize,
    /// Best SIMD serial kernel on this machine (`None` = scalar only).
    pub simd: Option<KernelKind>,
    /// Whether [`Dispatcher::autotune`] produced these numbers.
    pub autotuned: bool,
}

impl Default for Tuning {
    fn default() -> Self {
        Tuning {
            parallel_macs_threshold: PARALLEL_MACS_THRESHOLD,
            simd_macs_threshold: 0,
            simd: simd::best(),
            autotuned: false,
        }
    }
}

pub struct Dispatcher {
    threads: usize,
    pool: Option<ThreadPool>,
    force: Option<KernelKind>,
    tuning: Tuning,
}

impl Default for Dispatcher {
    fn default() -> Self {
        Self::new()
    }
}

impl Dispatcher {
    pub fn new() -> Self {
        let threads = match std::env::var("MKQ_THREADS") {
            Ok(s) => match s.parse::<usize>() {
                Ok(t) if t >= 1 => Some(t),
                _ => {
                    crate::log_warn!("ignoring MKQ_THREADS={s:?} (want an integer >= 1)");
                    None
                }
            },
            Err(_) => None,
        }
        .unwrap_or_else(|| std::thread::available_parallelism().map(|n| n.get()).unwrap_or(1));
        let force = match std::env::var("MKQ_KERNEL") {
            Ok(s) => match KernelKind::parse(&s) {
                Some(k) => Some(k),
                None if s == "simd" || s == "simd-parallel" => {
                    crate::log_warn!(
                        "MKQ_KERNEL={s} but no SIMD kernel is available on this \
                         machine; auto-selecting"
                    );
                    None
                }
                None => {
                    crate::log_warn!(
                        "ignoring MKQ_KERNEL={s:?} (want reference|blocked|parallel|\
                         avx2|avx2-parallel|neon|neon-parallel|simd|simd-parallel)"
                    );
                    None
                }
            },
            Err(_) => None,
        };
        Self::with_threads_forced(threads, force)
    }

    pub fn with_threads(threads: usize) -> Self {
        Self::with_threads_forced(threads.max(1), None)
    }

    /// A dispatcher pinned to one kernel variant — the forced-`MKQ_KERNEL`
    /// path without the env var (benches and the forced-variant tests).
    /// Unsupported picks degrade to the scalar blocked twin, like the env.
    pub fn forced(threads: usize, kind: KernelKind) -> Self {
        Self::with_threads_forced(threads.max(1), Some(kind))
    }

    fn with_threads_forced(threads: usize, force: Option<KernelKind>) -> Self {
        // The caller thread works too, so spawn threads-1 workers.
        let pool = if threads > 1 { Some(ThreadPool::new(threads - 1)) } else { None };
        // Degrade an unsupported forced SIMD pick to its scalar twin here,
        // once, so select() never has to re-check ISA support per call.
        let force = force.map(|f| {
            if f.supported() {
                f
            } else {
                let fb = if f.is_parallel() { KernelKind::BlockedParallel } else { KernelKind::Blocked };
                crate::log_warn!(
                    "kernel {} is not supported on this machine; using {}",
                    f.name(),
                    fb.name()
                );
                fb
            }
        });
        Dispatcher { threads, pool, force, tuning: Tuning::default() }
    }

    pub fn threads(&self) -> usize {
        self.threads
    }

    /// A new dispatcher making exactly the same kernel selections as this
    /// one — same thread count, forced kind, and recorded [`Tuning`] — but
    /// with its **own** thread pool. Execution workers each replicate the
    /// backend's dispatcher so concurrent batches never contend on (or
    /// cross-attribute panics through) one shared pool, while selection
    /// parity keeps multi-worker logits bit-for-bit equal to single-worker.
    pub fn replicate(&self) -> Dispatcher {
        let pool = if self.threads > 1 { Some(ThreadPool::new(self.threads - 1)) } else { None };
        Dispatcher { threads: self.threads, pool, force: self.force, tuning: self.tuning }
    }

    pub fn tuning(&self) -> Tuning {
        self.tuning
    }

    pub fn describe(&self) -> String {
        let simd = self.tuning.simd.map(|k| k.name()).unwrap_or("none");
        let simd_thr = match self.tuning.simd_macs_threshold {
            0 => "always".to_string(),
            usize::MAX => "never".to_string(),
            t => format!(">={t} MACs"),
        };
        format!(
            "native kernel dispatch: threads={} force={} simd={simd} ({simd_thr}) \
             parallel-threshold={} MACs{}",
            self.threads,
            self.force.map(|k| k.name()).unwrap_or("auto"),
            self.tuning.parallel_macs_threshold,
            if self.tuning.autotuned { " [autotuned]" } else { "" }
        )
    }

    /// Kernel selection for an `(m, k) x (k, n)` problem.
    pub fn select(&self, m: usize, k: usize, n: usize) -> KernelKind {
        let kind = if let Some(f) = self.force {
            f
        } else {
            let macs = m * k * n;
            let base = match self.tuning.simd {
                Some(s) if macs >= self.tuning.simd_macs_threshold => s,
                _ => KernelKind::Blocked,
            };
            if self.pool.is_some() && macs >= self.tuning.parallel_macs_threshold && m >= 2 {
                base.parallel_variant()
            } else {
                base
            }
        };
        // A parallel pick degrades gracefully on 1 thread.
        if kind.is_parallel() && self.pool.is_none() {
            kind.serial_variant()
        } else {
            kind
        }
    }

    /// One-shot load-time autotune: a quick microbenchmark over two shape
    /// buckets (eval-sized and serving-sized) that re-measures the
    /// SIMD-vs-scalar and serial-vs-parallel crossovers on *this* machine
    /// and records them into [`Tuning`] (shown by [`describe`]). Selection
    /// changes latency only — every kernel is bit-for-bit identical — so
    /// this is safe to run by default; `MKQ_AUTOTUNE=0` skips it for
    /// deterministic CI, and a forced `MKQ_KERNEL` makes it a no-op.
    ///
    /// [`describe`]: Self::describe
    pub fn autotune(&mut self) {
        if matches!(std::env::var("MKQ_AUTOTUNE").as_deref(), Ok("0") | Ok("off")) {
            return;
        }
        if self.force.is_some() {
            return;
        }
        // (m, k, n) buckets: small ≈ single-request eval, large ≈ a
        // serving batch at modest model width. Kept small enough that the
        // whole tune is a few milliseconds at model load.
        let buckets: [(usize, usize, usize); 2] = [(8, 192, 192), (64, 512, 512)];
        let mut scalar_t = [f64::INFINITY; 2];
        let mut simd_t = [f64::INFINITY; 2];
        let mut par_t = [f64::INFINITY; 2];
        for (bi, &(m, k, n)) in buckets.iter().enumerate() {
            let mut rng = Rng::new(0x7A11 + bi as u64);
            let codes = crate::quant::random_codes(&mut rng, k * n, 8);
            let pw = PackedWeights::from_codes(&codes, k, n, vec![0.02; n], 8);
            let qx: Vec<i16> = (0..m * k).map(|_| rng.range(0, 255) as i16 - 127).collect();
            let rs = gemm::act_row_sums(&qx, m, k);
            let sx = vec![0.05f32; m];
            let mut out = vec![0f32; m * n];
            // one warm pass + best-of-2 timed passes per variant
            let mut time = |f: &mut dyn FnMut(&mut [f32])| -> f64 {
                f(&mut out);
                let mut best = f64::INFINITY;
                for _ in 0..2 {
                    let t0 = std::time::Instant::now();
                    f(&mut out);
                    best = best.min(t0.elapsed().as_secs_f64());
                }
                best
            };
            scalar_t[bi] = time(&mut |o| gemm::gemm_serial(&qx, &rs, m, k, &pw, &sx, o));
            if let Some(s) = self.tuning.simd {
                let f = simd::serial_fn(s);
                simd_t[bi] = time(&mut |o| f(&qx, &rs, m, k, &pw, &sx, o));
            }
            if let Some(pool) = &self.pool {
                let f = match self.tuning.simd {
                    Some(s) => simd::serial_fn(s),
                    None => gemm::gemm_serial as gemm::SerialKernel,
                };
                let threads = self.threads;
                par_t[bi] =
                    time(&mut |o| gemm::gemm_parallel_with(f, &qx, &rs, m, k, &pw, &sx, o, pool, threads));
            }
        }
        let small_macs = buckets[0].0 * buckets[0].1 * buckets[0].2;
        let large_macs = buckets[1].0 * buckets[1].1 * buckets[1].2;
        let gmean = ((small_macs as f64) * (large_macs as f64)).sqrt() as usize;
        if self.tuning.simd.is_some() {
            self.tuning.simd_macs_threshold = if simd_t[1] < scalar_t[1] {
                if simd_t[0] <= scalar_t[0] {
                    0
                } else {
                    gmean
                }
            } else {
                usize::MAX
            };
        }
        if self.pool.is_some() {
            let serial_small = scalar_t[0].min(simd_t[0]);
            let serial_large = scalar_t[1].min(simd_t[1]);
            self.tuning.parallel_macs_threshold = if par_t[1] < serial_large {
                if par_t[0] < serial_small {
                    small_macs / 2
                } else {
                    gmean
                }
            } else {
                4 * large_macs
            };
        }
        self.tuning.autotuned = true;
    }

    /// Quantized matmul from fp32 activations: quantize rows, then run the
    /// selected integer kernel. Bit-for-bit equal to
    /// [`crate::quant::qmatmul_ref`].
    pub fn qmatmul(&self, x: &[f32], m: usize, k: usize, pw: &PackedWeights, sx: &[f32]) -> Vec<f32> {
        let qx = gemm::quantize_activations(x, m, k, sx, pw.bits);
        let rs = gemm::act_row_sums(&qx, m, k);
        self.qmatmul_prequant(&qx, &rs, m, k, pw, sx)
    }

    /// Quantized matmul over already-quantized activations — lets a layer
    /// quantize one activation site once and feed several matmuls (the
    /// q/k/v fan-out).
    pub fn qmatmul_prequant(
        &self,
        qx: &[i16],
        rowsums: &[i32],
        m: usize,
        k: usize,
        pw: &PackedWeights,
        sx: &[f32],
    ) -> Vec<f32> {
        let mut out = vec![0f32; m * pw.n];
        self.qmatmul_prequant_into(qx, rowsums, m, k, pw, sx, &mut out);
        out
    }

    /// [`Self::qmatmul_prequant`] writing into a caller-provided buffer —
    /// the zero-allocation serving path ([`crate::runtime::Workspace`]).
    /// Every kernel variant is allocation-free here except the forced
    /// `reference` debug kernel, which re-unpacks the weight panels per
    /// call by design.
    #[allow(clippy::too_many_arguments)]
    pub fn qmatmul_prequant_into(
        &self,
        qx: &[i16],
        rowsums: &[i32],
        m: usize,
        k: usize,
        pw: &PackedWeights,
        sx: &[f32],
        out: &mut [f32],
    ) {
        assert_eq!(out.len(), m * pw.n);
        let kind = self.select(m, k, pw.n);
        if let Some(obs) = crate::obs::metrics() {
            let slot = kernel_slot(kind);
            obs.kernel_calls[slot].inc();
            obs.kernel_macs[slot].add((m * k * pw.n) as u64);
        }
        match kind {
            KernelKind::Reference => {
                let codes = pw.unpack_codes();
                gemm::gemm_reference(qx, m, k, &codes, pw.n, sx, &pw.scales, out);
            }
            KernelKind::Blocked => gemm::gemm_serial(qx, rowsums, m, k, pw, sx, out),
            KernelKind::Avx2 | KernelKind::Neon => {
                simd::serial_fn(kind)(qx, rowsums, m, k, pw, sx, out)
            }
            KernelKind::BlockedParallel | KernelKind::Avx2Parallel | KernelKind::NeonParallel => {
                let pool = self.pool.as_ref().expect("parallel kernel without pool");
                gemm::gemm_parallel_with(
                    simd::serial_fn(kind),
                    qx,
                    rowsums,
                    m,
                    k,
                    pw,
                    sx,
                    out,
                    pool,
                    self.threads,
                );
            }
        }
    }

    /// fp32 matmul over panel-packed weights (the unquantized baseline,
    /// the never-quantized model heads, and the attention score/apply
    /// GEMMs). Scalar tiles only — fp32 SIMD is left to autovectorization;
    /// the parallel threshold from [`Tuning`] still applies.
    pub fn matmul_f32(&self, x: &[f32], m: usize, k: usize, pf: &PackedF32) -> Vec<f32> {
        let mut out = vec![0f32; m * pf.n];
        self.matmul_f32_into(x, m, k, pf, &mut out);
        out
    }

    /// [`Self::matmul_f32`] writing into a caller-provided buffer — the
    /// zero-allocation serving path.
    pub fn matmul_f32_into(&self, x: &[f32], m: usize, k: usize, pf: &PackedF32, out: &mut [f32]) {
        assert_eq!(out.len(), m * pf.n);
        if let Some(obs) = crate::obs::metrics() {
            obs.kernel_calls[F32_KERNEL_SLOT].inc();
            obs.kernel_macs[F32_KERNEL_SLOT].add((m * k * pf.n) as u64);
        }
        if self.select(m, k, pf.n).is_parallel() {
            let pool = self.pool.as_ref().expect("parallel kernel without pool");
            gemm::sgemm_parallel(x, m, k, pf, out, pool, self.threads);
        } else {
            gemm::sgemm_serial(x, m, k, pf, out);
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::quant;
    use crate::util::rng::Rng;

    #[test]
    fn selection_scales_with_problem_size() {
        let d = Dispatcher::with_threads(4);
        // tiny problem: never parallel; big problem: parallel twin of the
        // machine's best serial kernel.
        assert!(!d.select(4, 16, 16).is_parallel());
        let big = d.select(512, 768, 768);
        assert!(big.is_parallel());
        assert_eq!(big.serial_variant(), d.select(4, 16, 16));
        let single = Dispatcher::with_threads(1);
        assert!(!single.select(512, 768, 768).is_parallel());
    }

    #[test]
    fn forced_kind_degrades_gracefully() {
        // parallel force on 1 thread degrades to the serial twin
        let d = Dispatcher::forced(1, KernelKind::BlockedParallel);
        assert_eq!(d.select(512, 768, 768), KernelKind::Blocked);
        // an unsupported SIMD force degrades to the scalar twin at
        // construction; a supported one sticks.
        for kind in [KernelKind::Avx2, KernelKind::Neon] {
            let d = Dispatcher::forced(2, kind);
            let got = d.select(64, 64, 64);
            if kind.supported() {
                assert_eq!(got, kind);
            } else {
                assert_eq!(got, KernelKind::Blocked);
            }
        }
    }

    #[test]
    fn parse_covers_every_env_value() {
        assert_eq!(KernelKind::parse("reference"), Some(KernelKind::Reference));
        assert_eq!(KernelKind::parse("blocked"), Some(KernelKind::Blocked));
        assert_eq!(KernelKind::parse("parallel"), Some(KernelKind::BlockedParallel));
        assert_eq!(KernelKind::parse("blocked-parallel"), Some(KernelKind::BlockedParallel));
        assert_eq!(KernelKind::parse("avx2"), Some(KernelKind::Avx2));
        assert_eq!(KernelKind::parse("avx2-parallel"), Some(KernelKind::Avx2Parallel));
        assert_eq!(KernelKind::parse("neon"), Some(KernelKind::Neon));
        assert_eq!(KernelKind::parse("neon-parallel"), Some(KernelKind::NeonParallel));
        assert_eq!(KernelKind::parse("simd"), crate::kernels::simd::best());
        assert_eq!(KernelKind::parse("bogus"), None);
        for k in KernelKind::ALL {
            assert_eq!(KernelKind::parse(k.name()), Some(k), "name/parse roundtrip {k:?}");
            assert_eq!(k.parallel_variant().serial_variant(), k.serial_variant());
        }
    }

    #[test]
    fn qmatmul_matches_oracle_all_kernels() {
        let mut rng = Rng::new(31);
        let (m, k, n) = (9usize, 16usize, 12usize);
        for bits in [4u32, 8] {
            let x: Vec<f32> = (0..m * k).map(|_| rng.normal() as f32).collect();
            let codes = quant::random_codes(&mut rng, k * n, bits);
            let sx: Vec<f32> = (0..m).map(|_| 0.1 + rng.f32() * 0.1).collect();
            let sw: Vec<f32> = (0..n).map(|_| 0.02 + rng.f32() * 0.02).collect();
            let want = quant::qmatmul_ref(&x, m, k, &codes, n, &sx, &sw, bits);
            let pw = super::super::pack::PackedWeights::from_codes(&codes, k, n, sw, bits);
            for d in [Dispatcher::with_threads(1), Dispatcher::with_threads(3)] {
                assert_eq!(d.qmatmul(&x, m, k, &pw, &sx), want, "bits={bits}");
            }
        }
    }

    #[test]
    fn replicate_preserves_selection() {
        let mut d = Dispatcher::with_threads(3);
        d.tuning.simd_macs_threshold = 123;
        let r = d.replicate();
        assert_eq!(r.threads(), d.threads());
        assert_eq!(r.tuning().simd_macs_threshold, 123);
        for (m, k, n) in [(1, 16, 16), (8, 192, 192), (512, 768, 768)] {
            assert_eq!(r.select(m, k, n), d.select(m, k, n), "{m}x{k}x{n}");
        }
        let f = Dispatcher::forced(2, KernelKind::Blocked).replicate();
        assert_eq!(f.select(512, 768, 768), KernelKind::Blocked);
    }

    #[test]
    fn autotune_records_into_tuning() {
        let mut d = Dispatcher::with_threads(2);
        // only exercise the non-skipped path when the env doesn't disable it
        if matches!(std::env::var("MKQ_AUTOTUNE").as_deref(), Ok("0") | Ok("off")) {
            d.autotune();
            assert!(!d.tuning().autotuned);
            return;
        }
        d.autotune();
        assert!(d.tuning().autotuned);
        assert!(d.describe().contains("[autotuned]"));
        assert!(d.tuning().parallel_macs_threshold > 0);
        // forced dispatchers never autotune (nothing to select)
        let mut f = Dispatcher::forced(2, KernelKind::Blocked);
        f.autotune();
        assert!(!f.tuning().autotuned);
    }
}
