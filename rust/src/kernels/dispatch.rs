//! Runtime kernel dispatch.
//!
//! One [`Dispatcher`] is built per backend at model-load time. For every
//! GEMM call it selects a kernel variant from the problem shape and the
//! machine (`available_parallelism`), so the same code path serves tiny
//! eval batches and full serving buckets:
//!
//!   * `Reference`       — the scalar column-strided oracle loop
//!                          (`qmatmul_ref` structure). A *correctness*
//!                          baseline for numeric debugging: it re-unpacks
//!                          the packed panels on every call, so don't time
//!                          it (the benches time `qmatmul_ref` directly
//!                          over row-major codes instead).
//!   * `Blocked`         — single-thread cache-tiled/register-blocked
//!                          microkernel; picked for small problems where
//!                          fork/join overhead dominates.
//!   * `BlockedParallel` — row-block fan-out over the shared
//!                          [`ThreadPool`]; picked when `m*k*n` clears
//!                          [`PARALLEL_MACS_THRESHOLD`].
//!
//! Env overrides (serving ops knobs): `MKQ_KERNEL=reference|blocked|parallel`
//! forces a variant, `MKQ_THREADS=N` caps the pool.

use crate::util::threadpool::ThreadPool;

use super::gemm;
use super::pack::{PackedF32, PackedWeights};

/// Below this many multiply-accumulates the fork/join cost of the pool
/// outweighs the parallel win (measured on the layers bench; revisit with
/// the autotuning lever in ROADMAP).
pub const PARALLEL_MACS_THRESHOLD: usize = 1 << 20;

#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum KernelKind {
    Reference,
    Blocked,
    BlockedParallel,
}

impl KernelKind {
    pub fn name(self) -> &'static str {
        match self {
            KernelKind::Reference => "reference",
            KernelKind::Blocked => "blocked",
            KernelKind::BlockedParallel => "blocked-parallel",
        }
    }
}

pub struct Dispatcher {
    threads: usize,
    pool: Option<ThreadPool>,
    force: Option<KernelKind>,
}

impl Default for Dispatcher {
    fn default() -> Self {
        Self::new()
    }
}

impl Dispatcher {
    pub fn new() -> Self {
        let threads = match std::env::var("MKQ_THREADS") {
            Ok(s) => match s.parse::<usize>() {
                Ok(t) if t >= 1 => Some(t),
                _ => {
                    eprintln!("warning: ignoring MKQ_THREADS={s:?} (want an integer >= 1)");
                    None
                }
            },
            Err(_) => None,
        }
        .unwrap_or_else(|| std::thread::available_parallelism().map(|n| n.get()).unwrap_or(1));
        let force = match std::env::var("MKQ_KERNEL").as_deref() {
            Ok("reference") => Some(KernelKind::Reference),
            Ok("blocked") => Some(KernelKind::Blocked),
            Ok("parallel") | Ok("blocked-parallel") => Some(KernelKind::BlockedParallel),
            Ok(other) => {
                eprintln!(
                    "warning: ignoring MKQ_KERNEL={other:?} \
                     (want reference|blocked|parallel)"
                );
                None
            }
            Err(_) => None,
        };
        Self::with_threads_forced(threads, force)
    }

    pub fn with_threads(threads: usize) -> Self {
        Self::with_threads_forced(threads.max(1), None)
    }

    fn with_threads_forced(threads: usize, force: Option<KernelKind>) -> Self {
        // The caller thread works too, so spawn threads-1 workers.
        let pool = if threads > 1 { Some(ThreadPool::new(threads - 1)) } else { None };
        Dispatcher { threads, pool, force }
    }

    pub fn threads(&self) -> usize {
        self.threads
    }

    pub fn describe(&self) -> String {
        format!(
            "native kernel dispatch: threads={} force={} parallel-threshold={} MACs",
            self.threads,
            self.force.map(|k| k.name()).unwrap_or("auto"),
            PARALLEL_MACS_THRESHOLD
        )
    }

    /// Kernel selection for an `(m, k) x (k, n)` problem.
    pub fn select(&self, m: usize, k: usize, n: usize) -> KernelKind {
        if let Some(f) = self.force {
            // A forced parallel pick degrades gracefully on 1 thread.
            if f == KernelKind::BlockedParallel && self.pool.is_none() {
                return KernelKind::Blocked;
            }
            return f;
        }
        if self.pool.is_some() && m * k * n >= PARALLEL_MACS_THRESHOLD && m >= 2 {
            KernelKind::BlockedParallel
        } else {
            KernelKind::Blocked
        }
    }

    /// Quantized matmul from fp32 activations: quantize rows, then run the
    /// selected integer kernel. Bit-for-bit equal to
    /// [`crate::quant::qmatmul_ref`].
    pub fn qmatmul(&self, x: &[f32], m: usize, k: usize, pw: &PackedWeights, sx: &[f32]) -> Vec<f32> {
        let qx = gemm::quantize_activations(x, m, k, sx, pw.bits);
        let rs = gemm::act_row_sums(&qx, m, k);
        self.qmatmul_prequant(&qx, &rs, m, k, pw, sx)
    }

    /// Quantized matmul over already-quantized activations — lets a layer
    /// quantize one activation site once and feed several matmuls (the
    /// q/k/v fan-out).
    pub fn qmatmul_prequant(
        &self,
        qx: &[i16],
        rowsums: &[i32],
        m: usize,
        k: usize,
        pw: &PackedWeights,
        sx: &[f32],
    ) -> Vec<f32> {
        let mut out = vec![0f32; m * pw.n];
        match self.select(m, k, pw.n) {
            KernelKind::Reference => {
                let codes = pw.unpack_codes();
                gemm::gemm_reference(qx, m, k, &codes, pw.n, sx, &pw.scales, &mut out);
            }
            KernelKind::Blocked => gemm::gemm_serial(qx, rowsums, m, k, pw, sx, &mut out),
            KernelKind::BlockedParallel => {
                let pool = self.pool.as_ref().expect("parallel kernel without pool");
                gemm::gemm_parallel(qx, rowsums, m, k, pw, sx, &mut out, pool, self.threads);
            }
        }
        out
    }

    /// fp32 matmul over panel-packed weights (the unquantized baseline and
    /// the never-quantized model heads).
    pub fn matmul_f32(&self, x: &[f32], m: usize, k: usize, pf: &PackedF32) -> Vec<f32> {
        let mut out = vec![0f32; m * pf.n];
        match self.select(m, k, pf.n) {
            KernelKind::BlockedParallel => {
                let pool = self.pool.as_ref().expect("parallel kernel without pool");
                gemm::sgemm_parallel(x, m, k, pf, &mut out, pool, self.threads);
            }
            _ => gemm::sgemm_serial(x, m, k, pf, &mut out),
        }
        out
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::quant;
    use crate::util::rng::Rng;

    #[test]
    fn selection_scales_with_problem_size() {
        let d = Dispatcher::with_threads(4);
        assert_eq!(d.select(4, 16, 16), KernelKind::Blocked);
        assert_eq!(d.select(512, 768, 768), KernelKind::BlockedParallel);
        let single = Dispatcher::with_threads(1);
        assert_eq!(single.select(512, 768, 768), KernelKind::Blocked);
    }

    #[test]
    fn qmatmul_matches_oracle_all_kernels() {
        let mut rng = Rng::new(31);
        let (m, k, n) = (9usize, 16usize, 12usize);
        for bits in [4u32, 8] {
            let x: Vec<f32> = (0..m * k).map(|_| rng.normal() as f32).collect();
            let codes = quant::random_codes(&mut rng, k * n, bits);
            let sx: Vec<f32> = (0..m).map(|_| 0.1 + rng.f32() * 0.1).collect();
            let sw: Vec<f32> = (0..n).map(|_| 0.02 + rng.f32() * 0.02).collect();
            let want = quant::qmatmul_ref(&x, m, k, &codes, n, &sx, &sw, bits);
            let pw = super::super::pack::PackedWeights::from_codes(&codes, k, n, sw, bits);
            for d in [Dispatcher::with_threads(1), Dispatcher::with_threads(3)] {
                assert_eq!(d.qmatmul(&x, m, k, &pw, &sx), want, "bits={bits}");
            }
        }
    }
}
