//! Explicit SIMD microkernels for the quantized GEMM hot loop — the
//! hand-vectorized half of the paper's deployed-kernel speedup story
//! (MKQ-BERT §5 ships hand-written int4 kernels; Q8BERT attributes its
//! int8 wins to the same).
//!
//! # How the panel layout feeds the vector units
//!
//! The [`super::pack`] panel layout was chosen so one SIMD load fills a
//! full accumulator lane without any shuffling across K iterations:
//!
//!   * `NR == 8` output channels × i32 accumulators = exactly one AVX2
//!     `__m256i` lane (or a NEON `int32x4_t` pair).
//!   * int8 panels are K-major, so rows `kk` and `kk+1` are 16 contiguous
//!     bytes — one `_mm_loadu_si128`, sign-extended to i16 and interleaved
//!     to `(w[kk][c], w[kk+1][c])` pairs. `_mm256_madd_epi16` against the
//!     broadcast activation pair `(x[kk], x[kk+1])` then produces all 8
//!     per-channel partial sums `x[kk]*w[kk][c] + x[kk+1]*w[kk+1][c]` in
//!     one instruction, two K steps at a time.
//!   * int4 panels hold the two K-consecutive offset nibbles of a channel
//!     in one byte, 8 channels per packed row — one 8-byte load, a
//!     shift+mask unpack to `(lo[c], hi[c])` i16 pairs, and the same madd
//!     against `(x[2kk2], x[2kk2+1])`. The `+INT4_OFFSET` bias stays
//!     folded out per output element via the activation row sum, exactly
//!     as in the scalar kernels.
//!
//! # Safety / numerics
//!
//! `_mm256_madd_epi16` (and NEON's widening `vmlal_s16`) computes i16×i16
//! products in i32 and accumulates in i32 — products are bounded by
//! `l_max_act * l_max_w <= 128*127`, far from any i16×i16 edge case, so
//! every variant here is bit-for-bit identical to [`super::gemm`]'s
//! scalar kernels and to `qmatmul_ref` inside its f32 bound (same
//! contract, enforced by `rust/tests/kernels.rs`).
//!
//! The public entry points are safe on every machine: they re-check
//! feature availability and fall back to the scalar blocked kernel when
//! the vector ISA is absent (wrong arch, or AVX2 missing), so a forced
//! `MKQ_KERNEL=avx2` can never execute an illegal instruction.

use super::dispatch::KernelKind;
use super::gemm::{self, SerialKernel};
use super::pack::PackedWeights;

/// AVX2 present at runtime (always `false` off x86_64).
pub fn avx2_available() -> bool {
    #[cfg(target_arch = "x86_64")]
    {
        x86::available()
    }
    #[cfg(not(target_arch = "x86_64"))]
    {
        false
    }
}

/// NEON present at runtime (always `false` off aarch64).
pub fn neon_available() -> bool {
    #[cfg(target_arch = "aarch64")]
    {
        arm::available()
    }
    #[cfg(not(target_arch = "aarch64"))]
    {
        false
    }
}

/// Best SIMD *serial* kernel on this machine, if any — what auto
/// selection and the `MKQ_KERNEL=simd` override resolve to.
pub fn best() -> Option<KernelKind> {
    if avx2_available() {
        Some(KernelKind::Avx2)
    } else if neon_available() {
        Some(KernelKind::Neon)
    } else {
        None
    }
}

/// The serial kernel function for a [`KernelKind`] (parallel kinds map to
/// their serial body — the row-block driver supplies the parallelism).
/// Unsupported SIMD kinds resolve to the scalar blocked kernel.
pub fn serial_fn(kind: KernelKind) -> SerialKernel {
    match kind {
        KernelKind::Avx2 | KernelKind::Avx2Parallel => gemm_serial_avx2,
        KernelKind::Neon | KernelKind::NeonParallel => gemm_serial_neon,
        _ => gemm::gemm_serial,
    }
}

/// AVX2 serial GEMM over prepacked int4/int8 panels. Falls back to the
/// scalar blocked kernel when AVX2 is unavailable (never UB).
pub fn gemm_serial_avx2(
    qx: &[i16],
    rowsums: &[i32],
    m: usize,
    k: usize,
    pw: &PackedWeights,
    sx: &[f32],
    out: &mut [f32],
) {
    #[cfg(target_arch = "x86_64")]
    if x86::available() {
        return x86::gemm_serial(qx, rowsums, m, k, pw, sx, out);
    }
    gemm::gemm_serial(qx, rowsums, m, k, pw, sx, out)
}

/// NEON serial GEMM over prepacked int4/int8 panels. Falls back to the
/// scalar blocked kernel when NEON is unavailable (never UB).
pub fn gemm_serial_neon(
    qx: &[i16],
    rowsums: &[i32],
    m: usize,
    k: usize,
    pw: &PackedWeights,
    sx: &[f32],
    out: &mut [f32],
) {
    #[cfg(target_arch = "aarch64")]
    if arm::available() {
        return arm::gemm_serial(qx, rowsums, m, k, pw, sx, out);
    }
    gemm::gemm_serial(qx, rowsums, m, k, pw, sx, out)
}

#[cfg(target_arch = "x86_64")]
mod x86 {
    use std::arch::x86_64::*;

    use crate::kernels::gemm::{store_row, MC};
    use crate::kernels::pack::{PackedWeights, MR, NR};
    use crate::quant::INT4_OFFSET;

    // The interleave/madd scheme below is written for exactly this tile.
    const _: () = assert!(NR == 8 && MR == 4);

    pub fn available() -> bool {
        is_x86_feature_detected!("avx2")
    }

    pub fn gemm_serial(
        qx: &[i16],
        rowsums: &[i32],
        m: usize,
        k: usize,
        pw: &PackedWeights,
        sx: &[f32],
        out: &mut [f32],
    ) {
        assert!(available(), "AVX2 kernel selected on a machine without AVX2");
        assert_eq!(qx.len(), m * k);
        assert_eq!(rowsums.len(), m);
        assert_eq!(sx.len(), m);
        assert_eq!(pw.k, k);
        assert_eq!(out.len(), m * pw.n);
        unsafe { gemm_avx2(qx, rowsums, m, k, pw, sx, out) }
    }

    #[target_feature(enable = "avx2")]
    unsafe fn gemm_avx2(
        qx: &[i16],
        rowsums: &[i32],
        m: usize,
        k: usize,
        pw: &PackedWeights,
        sx: &[f32],
        out: &mut [f32],
    ) {
        let mut ic = 0;
        while ic < m {
            let mc = MC.min(m - ic);
            if pw.bits == 8 {
                block_i8_avx2(qx, ic, mc, k, pw, sx, out);
            } else {
                block_i4_avx2(qx, rowsums, ic, mc, k, pw, sx, out);
            }
            ic += mc;
        }
    }

    /// Two K-consecutive int8 weight rows (16 contiguous panel bytes) as
    /// interleaved `(w[kk][c], w[kk+1][c])` i16 pairs — one madd operand.
    #[target_feature(enable = "avx2")]
    unsafe fn load_wpair_i8(p: *const i8) -> __m256i {
        let w = _mm256_cvtepi8_epi16(_mm_loadu_si128(p as *const __m128i));
        let wlo = _mm256_castsi256_si128(w);
        let whi = _mm256_extracti128_si256::<1>(w);
        _mm256_set_m128i(_mm_unpackhi_epi16(wlo, whi), _mm_unpacklo_epi16(wlo, whi))
    }

    /// Final odd K row (8 panel bytes) paired with zeros.
    #[target_feature(enable = "avx2")]
    unsafe fn load_wlast_i8(p: *const i8) -> __m256i {
        let w = _mm_cvtepi8_epi16(_mm_loadl_epi64(p as *const __m128i));
        let z = _mm_setzero_si128();
        _mm256_set_m128i(_mm_unpackhi_epi16(w, z), _mm_unpacklo_epi16(w, z))
    }

    /// One packed int4 row (8 bytes = NR channels × two K steps) as
    /// interleaved `(lo[c], hi[c])` offset-nibble i16 pairs.
    #[target_feature(enable = "avx2")]
    unsafe fn load_wpair_i4(p: *const u8) -> __m256i {
        let b = _mm_cvtepu8_epi16(_mm_loadl_epi64(p as *const __m128i));
        let lo = _mm_and_si128(b, _mm_set1_epi16(0x0F));
        let hi = _mm_srli_epi16::<4>(b);
        _mm256_set_m128i(_mm_unpackhi_epi16(lo, hi), _mm_unpacklo_epi16(lo, hi))
    }

    /// Broadcast activation pair `(x_even, x_odd)` across all madd lanes.
    #[target_feature(enable = "avx2")]
    unsafe fn xpair(xe: i16, xo: i16) -> __m256i {
        _mm256_set1_epi32(((xe as u16 as u32) | ((xo as u16 as u32) << 16)) as i32)
    }

    #[target_feature(enable = "avx2")]
    unsafe fn acc_to_array(v: __m256i) -> [i32; NR] {
        let mut a = [0i32; NR];
        _mm256_storeu_si256(a.as_mut_ptr() as *mut __m256i, v);
        a
    }

    #[target_feature(enable = "avx2")]
    unsafe fn block_i8_avx2(
        qx: &[i16],
        ic: usize,
        mc: usize,
        k: usize,
        pw: &PackedWeights,
        sx: &[f32],
        out: &mut [f32],
    ) {
        let n = pw.n;
        let iend = ic + mc;
        let kq = k & !1usize;
        for p in 0..pw.n_panels() {
            let j0 = p * NR;
            let nc = NR.min(n - j0);
            let panel = pw.panel_i8(p);
            let pp = panel.as_ptr();
            let sw = &pw.scales[j0..j0 + nc];
            let mut i = ic;
            while i + MR <= iend {
                let base = [i * k, (i + 1) * k, (i + 2) * k, (i + 3) * k];
                let mut acc = [_mm256_setzero_si256(); MR];
                let mut kk = 0usize;
                while kk < kq {
                    let wv = load_wpair_i8(pp.add(kk * NR));
                    for r in 0..MR {
                        let xe = *qx.get_unchecked(base[r] + kk);
                        let xo = *qx.get_unchecked(base[r] + kk + 1);
                        acc[r] = _mm256_add_epi32(acc[r], _mm256_madd_epi16(xpair(xe, xo), wv));
                    }
                    kk += 2;
                }
                if kk < k {
                    let wv = load_wlast_i8(pp.add(kk * NR));
                    for r in 0..MR {
                        let xe = *qx.get_unchecked(base[r] + kk);
                        acc[r] = _mm256_add_epi32(acc[r], _mm256_madd_epi16(xpair(xe, 0), wv));
                    }
                }
                for r in 0..MR {
                    let a = acc_to_array(acc[r]);
                    let o = (i + r) * n + j0;
                    store_row(&mut out[o..o + nc], &a, 0, sx[i + r], sw, nc);
                }
                i += MR;
            }
            while i < iend {
                let b0 = i * k;
                let mut acc = _mm256_setzero_si256();
                let mut kk = 0usize;
                while kk < kq {
                    let wv = load_wpair_i8(pp.add(kk * NR));
                    let xe = *qx.get_unchecked(b0 + kk);
                    let xo = *qx.get_unchecked(b0 + kk + 1);
                    acc = _mm256_add_epi32(acc, _mm256_madd_epi16(xpair(xe, xo), wv));
                    kk += 2;
                }
                if kk < k {
                    let wv = load_wlast_i8(pp.add(kk * NR));
                    let xe = *qx.get_unchecked(b0 + kk);
                    acc = _mm256_add_epi32(acc, _mm256_madd_epi16(xpair(xe, 0), wv));
                }
                let a = acc_to_array(acc);
                let o = i * n + j0;
                store_row(&mut out[o..o + nc], &a, 0, sx[i], sw, nc);
                i += 1;
            }
        }
    }

    #[target_feature(enable = "avx2")]
    unsafe fn block_i4_avx2(
        qx: &[i16],
        rowsums: &[i32],
        ic: usize,
        mc: usize,
        k: usize,
        pw: &PackedWeights,
        sx: &[f32],
        out: &mut [f32],
    ) {
        let n = pw.n;
        let k2 = k / 2;
        let iend = ic + mc;
        for p in 0..pw.n_panels() {
            let j0 = p * NR;
            let nc = NR.min(n - j0);
            let panel = pw.panel_i4(p);
            let pp = panel.as_ptr();
            let sw = &pw.scales[j0..j0 + nc];
            let mut i = ic;
            while i + MR <= iend {
                let base = [i * k, (i + 1) * k, (i + 2) * k, (i + 3) * k];
                let mut acc = [_mm256_setzero_si256(); MR];
                for kk2 in 0..k2 {
                    let wv = load_wpair_i4(pp.add(kk2 * NR));
                    for r in 0..MR {
                        let xe = *qx.get_unchecked(base[r] + 2 * kk2);
                        let xo = *qx.get_unchecked(base[r] + 2 * kk2 + 1);
                        acc[r] = _mm256_add_epi32(acc[r], _mm256_madd_epi16(xpair(xe, xo), wv));
                    }
                }
                for r in 0..MR {
                    let a = acc_to_array(acc[r]);
                    let o = (i + r) * n + j0;
                    store_row(&mut out[o..o + nc], &a, INT4_OFFSET * rowsums[i + r], sx[i + r], sw, nc);
                }
                i += MR;
            }
            while i < iend {
                let b0 = i * k;
                let mut acc = _mm256_setzero_si256();
                for kk2 in 0..k2 {
                    let wv = load_wpair_i4(pp.add(kk2 * NR));
                    let xe = *qx.get_unchecked(b0 + 2 * kk2);
                    let xo = *qx.get_unchecked(b0 + 2 * kk2 + 1);
                    acc = _mm256_add_epi32(acc, _mm256_madd_epi16(xpair(xe, xo), wv));
                }
                let a = acc_to_array(acc);
                let o = i * n + j0;
                store_row(&mut out[o..o + nc], &a, INT4_OFFSET * rowsums[i], sx[i], sw, nc);
                i += 1;
            }
        }
    }
}

#[cfg(target_arch = "aarch64")]
mod arm {
    use std::arch::aarch64::*;

    use crate::kernels::gemm::{store_row, MC};
    use crate::kernels::pack::{PackedWeights, MR, NR};
    use crate::quant::INT4_OFFSET;

    // The widening-mla scheme below is written for exactly this tile.
    const _: () = assert!(NR == 8 && MR == 4);

    pub fn available() -> bool {
        std::arch::is_aarch64_feature_detected!("neon")
    }

    pub fn gemm_serial(
        qx: &[i16],
        rowsums: &[i32],
        m: usize,
        k: usize,
        pw: &PackedWeights,
        sx: &[f32],
        out: &mut [f32],
    ) {
        assert!(available(), "NEON kernel selected on a machine without NEON");
        assert_eq!(qx.len(), m * k);
        assert_eq!(rowsums.len(), m);
        assert_eq!(sx.len(), m);
        assert_eq!(pw.k, k);
        assert_eq!(out.len(), m * pw.n);
        unsafe { gemm_neon(qx, rowsums, m, k, pw, sx, out) }
    }

    #[target_feature(enable = "neon")]
    unsafe fn gemm_neon(
        qx: &[i16],
        rowsums: &[i32],
        m: usize,
        k: usize,
        pw: &PackedWeights,
        sx: &[f32],
        out: &mut [f32],
    ) {
        let mut ic = 0;
        while ic < m {
            let mc = MC.min(m - ic);
            if pw.bits == 8 {
                block_i8_neon(qx, ic, mc, k, pw, sx, out);
            } else {
                block_i4_neon(qx, rowsums, ic, mc, k, pw, sx, out);
            }
            ic += mc;
        }
    }

    #[target_feature(enable = "neon")]
    unsafe fn acc_to_array(lo: int32x4_t, hi: int32x4_t) -> [i32; NR] {
        let mut a = [0i32; NR];
        vst1q_s32(a.as_mut_ptr(), lo);
        vst1q_s32(a.as_mut_ptr().add(4), hi);
        a
    }

    #[target_feature(enable = "neon")]
    unsafe fn block_i8_neon(
        qx: &[i16],
        ic: usize,
        mc: usize,
        k: usize,
        pw: &PackedWeights,
        sx: &[f32],
        out: &mut [f32],
    ) {
        let n = pw.n;
        let iend = ic + mc;
        for p in 0..pw.n_panels() {
            let j0 = p * NR;
            let nc = NR.min(n - j0);
            let panel = pw.panel_i8(p);
            let pp = panel.as_ptr();
            let sw = &pw.scales[j0..j0 + nc];
            let mut i = ic;
            while i + MR <= iend {
                let base = [i * k, (i + 1) * k, (i + 2) * k, (i + 3) * k];
                // [row][half]: NR=8 channels = two int32x4_t per row.
                let mut acc = [[vdupq_n_s32(0); 2]; MR];
                for kk in 0..k {
                    let w = vmovl_s8(vld1_s8(pp.add(kk * NR)));
                    let wl = vget_low_s16(w);
                    let wh = vget_high_s16(w);
                    for r in 0..MR {
                        let x = vdup_n_s16(*qx.get_unchecked(base[r] + kk));
                        acc[r][0] = vmlal_s16(acc[r][0], wl, x);
                        acc[r][1] = vmlal_s16(acc[r][1], wh, x);
                    }
                }
                for r in 0..MR {
                    let a = acc_to_array(acc[r][0], acc[r][1]);
                    let o = (i + r) * n + j0;
                    store_row(&mut out[o..o + nc], &a, 0, sx[i + r], sw, nc);
                }
                i += MR;
            }
            while i < iend {
                let b0 = i * k;
                let mut a0 = vdupq_n_s32(0);
                let mut a1 = vdupq_n_s32(0);
                for kk in 0..k {
                    let w = vmovl_s8(vld1_s8(pp.add(kk * NR)));
                    let x = vdup_n_s16(*qx.get_unchecked(b0 + kk));
                    a0 = vmlal_s16(a0, vget_low_s16(w), x);
                    a1 = vmlal_s16(a1, vget_high_s16(w), x);
                }
                let a = acc_to_array(a0, a1);
                let o = i * n + j0;
                store_row(&mut out[o..o + nc], &a, 0, sx[i], sw, nc);
                i += 1;
            }
        }
    }

    #[target_feature(enable = "neon")]
    unsafe fn block_i4_neon(
        qx: &[i16],
        rowsums: &[i32],
        ic: usize,
        mc: usize,
        k: usize,
        pw: &PackedWeights,
        sx: &[f32],
        out: &mut [f32],
    ) {
        let n = pw.n;
        let k2 = k / 2;
        let iend = ic + mc;
        let mask = vdup_n_u8(0x0F);
        for p in 0..pw.n_panels() {
            let j0 = p * NR;
            let nc = NR.min(n - j0);
            let panel = pw.panel_i4(p);
            let pp = panel.as_ptr();
            let sw = &pw.scales[j0..j0 + nc];
            let mut i = ic;
            while i + MR <= iend {
                let base = [i * k, (i + 1) * k, (i + 2) * k, (i + 3) * k];
                let mut acc = [[vdupq_n_s32(0); 2]; MR];
                for kk2 in 0..k2 {
                    let b = vld1_u8(pp.add(kk2 * NR));
                    let lo = vreinterpretq_s16_u16(vmovl_u8(vand_u8(b, mask)));
                    let hi = vreinterpretq_s16_u16(vmovl_u8(vshr_n_u8::<4>(b)));
                    let ll = vget_low_s16(lo);
                    let lh = vget_high_s16(lo);
                    let hl = vget_low_s16(hi);
                    let hh = vget_high_s16(hi);
                    for r in 0..MR {
                        let xe = vdup_n_s16(*qx.get_unchecked(base[r] + 2 * kk2));
                        let xo = vdup_n_s16(*qx.get_unchecked(base[r] + 2 * kk2 + 1));
                        acc[r][0] = vmlal_s16(acc[r][0], ll, xe);
                        acc[r][1] = vmlal_s16(acc[r][1], lh, xe);
                        acc[r][0] = vmlal_s16(acc[r][0], hl, xo);
                        acc[r][1] = vmlal_s16(acc[r][1], hh, xo);
                    }
                }
                for r in 0..MR {
                    let a = acc_to_array(acc[r][0], acc[r][1]);
                    let o = (i + r) * n + j0;
                    store_row(&mut out[o..o + nc], &a, INT4_OFFSET * rowsums[i + r], sx[i + r], sw, nc);
                }
                i += MR;
            }
            while i < iend {
                let b0 = i * k;
                let mut a0 = vdupq_n_s32(0);
                let mut a1 = vdupq_n_s32(0);
                for kk2 in 0..k2 {
                    let b = vld1_u8(pp.add(kk2 * NR));
                    let lo = vreinterpretq_s16_u16(vmovl_u8(vand_u8(b, mask)));
                    let hi = vreinterpretq_s16_u16(vmovl_u8(vshr_n_u8::<4>(b)));
                    let xe = vdup_n_s16(*qx.get_unchecked(b0 + 2 * kk2));
                    let xo = vdup_n_s16(*qx.get_unchecked(b0 + 2 * kk2 + 1));
                    a0 = vmlal_s16(a0, vget_low_s16(lo), xe);
                    a1 = vmlal_s16(a1, vget_high_s16(lo), xe);
                    a0 = vmlal_s16(a0, vget_low_s16(hi), xo);
                    a1 = vmlal_s16(a1, vget_high_s16(hi), xo);
                }
                let a = acc_to_array(a0, a1);
                let o = i * n + j0;
                store_row(&mut out[o..o + nc], &a, INT4_OFFSET * rowsums[i], sx[i], sw, nc);
                i += 1;
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::kernels::pack::{MR, NR};
    use crate::quant;
    use crate::util::rng::Rng;
    use crate::util::threadpool::ThreadPool;

    fn check_against_scalar(m: usize, k: usize, n: usize, bits: u32, seed: u64) {
        let mut rng = Rng::new(seed);
        let x: Vec<f32> = (0..m * k).map(|_| rng.normal() as f32).collect();
        let codes = quant::random_codes(&mut rng, k * n, bits);
        let sx: Vec<f32> = (0..m).map(|_| 0.02 + rng.f32() * 0.2).collect();
        let sw: Vec<f32> = (0..n).map(|_| 0.01 + rng.f32() * 0.05).collect();
        let pw = PackedWeights::from_codes(&codes, k, n, sw, bits);
        let qx = gemm::quantize_activations(&x, m, k, &sx, bits);
        let rs = gemm::act_row_sums(&qx, m, k);
        let mut want = vec![0f32; m * n];
        gemm::gemm_serial(&qx, &rs, m, k, &pw, &sx, &mut want);

        for (name, f) in [
            ("avx2", gemm_serial_avx2 as SerialKernel),
            ("neon", gemm_serial_neon as SerialKernel),
        ] {
            let mut got = vec![0f32; m * n];
            f(&qx, &rs, m, k, &pw, &sx, &mut got);
            assert_eq!(got, want, "{name} serial m={m} k={k} n={n} bits={bits}");

            let pool = ThreadPool::new(2);
            let mut got_p = vec![0f32; m * n];
            gemm::gemm_parallel_with(f, &qx, &rs, m, k, &pw, &sx, &mut got_p, &pool, 3);
            assert_eq!(got_p, want, "{name} parallel m={m} k={k} n={n} bits={bits}");
        }
    }

    #[test]
    fn simd_matches_scalar_blocked() {
        // Ragged row/column remainders, odd K (int8 only covers odd K; the
        // packer requires even K for int4), and an m > MC cache-block split.
        for &(m, k, n) in &[
            (1usize, 2usize, 1usize),
            (MR - 1, 6, NR - 1),
            (MR + 1, 8, NR + 1),
            (7, 10, 9),
            (16, 32, 24),
            (130, 16, 17),
        ] {
            check_against_scalar(m, k, n, 8, 400 + m as u64);
            check_against_scalar(m, k, n, 4, 500 + m as u64);
        }
        check_against_scalar(5, 7, 9, 8, 42); // odd K, int8 tail path
    }

    #[test]
    fn best_matches_availability() {
        match best() {
            Some(KernelKind::Avx2) => assert!(avx2_available()),
            Some(KernelKind::Neon) => assert!(neon_available()),
            None => assert!(!avx2_available() && !neon_available()),
            Some(other) => panic!("best() returned non-SIMD kind {other:?}"),
        }
    }
}
