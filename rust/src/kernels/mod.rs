//! Native quantized GEMM backend — the deployed-kernel half of the
//! paper's contribution, in pure Rust (no Python, no XLA on the hot
//! path).
//!
//! # Prepacked weight layout
//!
//! Weights are quantized per output channel
//! ([`crate::quant::quantize_weight_per_channel`]) and repacked **once at
//! model-load time** into column-panel form ([`pack::PackedWeights`]):
//! `ceil(n/NR)` panels, each `k x NR` K-major, so the inner loop streams
//! weights sequentially. int4 panels hold two K-consecutive offset
//! nibbles per byte (`code + INT4_OFFSET`), unpacked by shift+mask
//! *inside* the microkernel; the `+INT4_OFFSET` bias is folded out once
//! per output element through the quantized-activation row sum instead of
//! per nibble. Per-channel scales ride with the panels.
//!
//! The same layout feeds scalar and SIMD kernels alike: `NR == 8` i32
//! accumulators fill exactly one AVX2 `__m256i` (one NEON `int32x4_t`
//! pair), and K-major panel rows make two K steps of all 8 channels — 16
//! int8 bytes, or 8 int4 nibble-pair bytes — one contiguous vector load
//! (see [`simd`] for the interleave/madd scheme).
//!
//! # Microkernels
//!
//! [`gemm`] holds the cache-tiled (MC rows), register-blocked (MR x NR
//! i32 accumulator tile) scalar kernels for int8 and nibble-packed int4,
//! a panel-packed fp32 baseline, and the scalar reference loop. [`simd`]
//! holds the hand-vectorized twins: AVX2 (`_mm256_madd_epi16` i16×i16→i32
//! dot products, two K steps per instruction) and NEON (`vmlal_s16`
//! widening multiply-accumulate), each with a fused nibble unpack for
//! int4 and the same row-sum offset correction.
//!
//! **Numerical contract:** every variant — scalar, AVX2, NEON, serial or
//! row-block parallel — accumulates exactly in i32 and is bit-for-bit
//! identical to the others at every shape, and to
//! [`crate::quant::qmatmul_ref`] inside the oracle's f32 bound (see the
//! contract note in `gemm`); `rust/tests/kernels.rs` enforces this across
//! random shapes, ragged edges, and every dispatchable variant.
//!
//! # Runtime dispatch
//!
//! [`dispatch::Dispatcher`] picks a [`dispatch::KernelKind`] per call
//! from the problem shape, core count, and runtime feature detection
//! (`is_x86_feature_detected!("avx2")` / NEON on aarch64), with optional
//! load-time autotuning of the crossover thresholds
//! ([`dispatch::Dispatcher::autotune`]). `MKQ_KERNEL` forces a variant
//! (degrading to the scalar blocked kernels where the ISA is absent),
//! `MKQ_THREADS` caps the pool, `MKQ_AUTOTUNE=0` keeps CI deterministic.
//!
//! Remaining perf levers are tracked in ROADMAP.md (tile-size autotuning,
//! QAT-checkpoint import).

pub mod dispatch;
pub mod gemm;
pub mod pack;
pub mod simd;

pub use dispatch::{Dispatcher, KernelKind, Tuning};
pub use pack::{PackedF32, PackedWeights, PanelRef, ScaleVec, MR, NR};
