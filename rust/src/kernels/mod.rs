//! Native quantized GEMM backend — the deployed-kernel half of the
//! paper's contribution, in pure Rust (no Python, no XLA on the hot
//! path).
//!
//! # Prepacked weight layout
//!
//! Weights are quantized per output channel
//! ([`crate::quant::quantize_weight_per_channel`]) and repacked **once at
//! model-load time** into column-panel form ([`pack::PackedWeights`]):
//! `ceil(n/NR)` panels, each `k x NR` K-major, so the inner loop streams
//! weights sequentially. int4 panels hold two K-consecutive offset
//! nibbles per byte (`code + INT4_OFFSET`), unpacked by shift+mask
//! *inside* the microkernel; the `+INT4_OFFSET` bias is folded out once
//! per output element through the quantized-activation row sum instead of
//! per nibble. Per-channel scales ride with the panels.
//!
//! # Microkernels
//!
//! [`gemm`] holds the cache-tiled (MC rows), register-blocked (MR x NR
//! i32 accumulator tile) kernels for int8 and int4, a panel-packed fp32
//! baseline, and the scalar reference loop. Outputs are bit-for-bit equal
//! to [`crate::quant::qmatmul_ref`] (see the contract note in `gemm`).
//!
//! # Runtime dispatch
//!
//! [`dispatch::Dispatcher`] picks a kernel variant per call — scalar
//! reference, single-thread blocked, or row-block parallel over
//! [`crate::util::threadpool::ThreadPool`] — from the problem shape and
//! core count, with `MKQ_KERNEL` / `MKQ_THREADS` env overrides.
//!
//! Follow-on perf levers are tracked in ROADMAP.md (SIMD microkernels,
//! per-token activation scales, bucket autotuning).

pub mod dispatch;
pub mod gemm;
pub mod pack;

pub use dispatch::{Dispatcher, KernelKind};
pub use pack::{PackedF32, PackedWeights, MR, NR};
