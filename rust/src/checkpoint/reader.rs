//! MKQC reader: parse + validate a checkpoint (single file or sharded
//! directory), then serve tensors by name — borrowing straight out of
//! the (possibly mmap'd) file image wherever alignment allows.
//!
//! Validation order (each failure is a typed [`CkptError`]):
//! magic → version → header field bounds → directory structure
//! (name/rank/dtype/layout/size bounds) → **v2: header/directory CRC**
//! (before semantic validation, so any plausible header bit flip is
//! caught, not just inconsistent ones) → header semantics
//! ([`CkptHeader::validate`]) → duplicate names → payload bounds (every
//! entry inside the payload, no overlapping entries) → payload CRC-32
//! against the stored trailer. Only a fully validated file hands out
//! tensors.
//!
//! The backing bytes live in a [`FileBytes`] — an mmap when the platform
//! provides one, an owned buffer otherwise — so a v2 checkpoint's
//! 16-byte-aligned payload serves aligned in-place `&[f32]` views
//! ([`Checkpoint::f32_view`]) and raw panel views
//! ([`Checkpoint::panel_bytes`]) with zero payload copies. A sharded
//! checkpoint holds one `FileBytes` per shard and merges the
//! directories; lookup is name-based and shard-transparent.

use std::borrow::Cow;
use std::path::Path;

use crate::modelstore::mapped::FileBytes;
use crate::util::crc32::crc32;

use super::{
    CkptError, CkptHeader, DTYPE_F32, DTYPE_I4_PANELS, DTYPE_I8_PANELS, MAGIC, MANIFEST_NAME,
    MANIFEST_TAG, MAX_LAYERS, MAX_NAME_LEN, MAX_RANK, MAX_TENSORS, PANEL_LAYOUT, PAYLOAD_ALIGN,
    VERSION, VERSION_V1,
};
use crate::kernels::{PackedWeights, PanelRef};
use crate::runtime::native::NativeDims;

/// One parsed directory entry (exposed for `mkq-bert ckpt inspect`).
#[derive(Debug, Clone)]
pub struct Entry {
    pub name: String,
    pub dtype: u8,
    /// Panel-layout version byte (0 for f32 entries and all of v1).
    pub layout: u8,
    pub dims: Vec<usize>,
    /// Byte offset from the owning shard's payload start.
    pub offset: usize,
    /// Byte length.
    pub len: usize,
    /// Index into the checkpoint's shard list (0 for single files).
    pub shard: usize,
}

impl Entry {
    pub fn dtype_name(&self) -> &'static str {
        match self.dtype {
            DTYPE_F32 => "f32",
            DTYPE_I8_PANELS => "i8-panels",
            DTYPE_I4_PANELS => "i4-panels",
            _ => "?",
        }
    }
}

/// One backing file: its bytes plus where the payload lives inside them.
/// The image is `Arc`-shared so zero-copy loads ([`Checkpoint::panel_ref`])
/// can hand out [`PanelRef`]s that keep it alive past the `Checkpoint`.
struct Shard {
    data: std::sync::Arc<FileBytes>,
    payload_start: usize,
    payload_len: usize,
    payload_crc: u32,
    /// v2 only.
    header_crc: Option<u32>,
}

/// A validated checkpoint: one or more shards behind a merged directory.
pub struct Checkpoint {
    header: CkptHeader,
    version: u32,
    entries: Vec<Entry>,
    shards: Vec<Shard>,
}

struct Cur<'a> {
    data: &'a [u8],
    pos: usize,
}

impl<'a> Cur<'a> {
    fn take(&mut self, n: usize, what: &'static str) -> Result<&'a [u8], CkptError> {
        let have = self.data.len() - self.pos;
        if have < n {
            return Err(CkptError::Truncated { what, need: n, have });
        }
        let s = &self.data[self.pos..self.pos + n];
        self.pos += n;
        Ok(s)
    }

    fn u8(&mut self, what: &'static str) -> Result<u8, CkptError> {
        Ok(self.take(1, what)?[0])
    }

    fn u16(&mut self, what: &'static str) -> Result<u16, CkptError> {
        let b = self.take(2, what)?;
        Ok(u16::from_le_bytes([b[0], b[1]]))
    }

    fn u32(&mut self, what: &'static str) -> Result<u32, CkptError> {
        let b = self.take(4, what)?;
        Ok(u32::from_le_bytes([b[0], b[1], b[2], b[3]]))
    }

    fn u64(&mut self, what: &'static str) -> Result<u64, CkptError> {
        let b = self.take(8, what)?;
        Ok(u64::from_le_bytes(b.try_into().unwrap()))
    }

    fn f32(&mut self, what: &'static str) -> Result<f32, CkptError> {
        Ok(f32::from_bits(self.u32(what)?))
    }
}

/// Expected payload byte length for an entry, from dtype + logical dims.
/// `None` means the combination itself is malformed.
fn expected_len(dtype: u8, dims: &[usize]) -> Option<usize> {
    let count = dims.iter().try_fold(1usize, |a, &d| a.checked_mul(d))?;
    match dtype {
        DTYPE_F32 => count.checked_mul(4),
        DTYPE_I8_PANELS | DTYPE_I4_PANELS if dims.len() == 2 => {
            let bits = if dtype == DTYPE_I8_PANELS { 8 } else { 4 };
            PackedWeights::packed_len(bits, dims[0], dims[1])
        }
        _ => None,
    }
}

/// Parse + structurally validate one shard image. Returns the parsed
/// header/entries plus the shard bookkeeping; the caller finishes with
/// cross-shard checks.
fn parse_one(data: FileBytes) -> Result<(CkptHeader, u32, Vec<Entry>, Shard), CkptError> {
    let mut cur = Cur { data: &data[..], pos: 0 };

    let magic = cur.take(4, "magic")?;
    if magic != MAGIC {
        return Err(CkptError::BadMagic { got: magic.try_into().unwrap() });
    }
    let version = cur.u32("version")?;
    if version != VERSION_V1 && version != VERSION {
        return Err(CkptError::BadVersion { got: version });
    }
    let v2 = version >= VERSION;

    let mut dims_v = [0usize; 7];
    for (slot, what) in dims_v.iter_mut().zip([
        "vocab", "seq", "n_layers", "d_model", "n_heads", "d_ff", "n_classes",
    ]) {
        *slot = cur.u32(what)? as usize;
    }
    let dims = NativeDims {
        vocab: dims_v[0],
        seq: dims_v[1],
        n_layers: dims_v[2],
        d_model: dims_v[3],
        n_heads: dims_v[4],
        d_ff: dims_v[5],
        n_classes: dims_v[6],
    };
    let n_tensors = cur.u32("n_tensors")? as usize;
    if n_tensors > MAX_TENSORS {
        return Err(CkptError::BadDirectory(format!(
            "n_tensors {n_tensors} exceeds {MAX_TENSORS}"
        )));
    }
    // bound n_layers BEFORE allocating header tables from it
    if dims.n_layers == 0 || dims.n_layers > MAX_LAYERS {
        return Err(CkptError::BadHeader(format!(
            "n_layers {} out of range 1..={MAX_LAYERS}",
            dims.n_layers
        )));
    }
    let mut bits = Vec::with_capacity(dims.n_layers);
    for _ in 0..dims.n_layers {
        bits.push(cur.u32("bit vector")?);
    }
    let mut act_scales = Vec::with_capacity(dims.n_layers);
    for _ in 0..dims.n_layers {
        let mut row = [0f32; 4];
        for s in row.iter_mut() {
            *s = cur.f32("activation scales")?;
        }
        act_scales.push(row);
    }
    let header = CkptHeader { dims, bits, act_scales };
    if !v2 {
        // v1 has no header CRC: semantic validation is all there is, run
        // it as early as possible.
        header.validate()?;
    }

    // cap the pre-allocation by what the remaining bytes could hold (a
    // directory entry is at least 21 bytes), so a corrupt n_tensors in
    // a tiny file cannot force a large allocation before parsing fails
    const MIN_ENTRY_BYTES: usize = 2 + 1 + 1 + 1 + 8 + 8;
    let cap = n_tensors.min((data.len() - cur.pos) / MIN_ENTRY_BYTES + 1);
    let mut entries = Vec::with_capacity(cap);
    for i in 0..n_tensors {
        let name_len = cur.u16("directory name length")? as usize;
        if name_len == 0 || name_len > MAX_NAME_LEN {
            return Err(CkptError::BadDirectory(format!(
                "entry {i}: name length {name_len} out of range 1..={MAX_NAME_LEN}"
            )));
        }
        let name = std::str::from_utf8(cur.take(name_len, "directory name")?)
            .map_err(|_| CkptError::BadDirectory(format!("entry {i}: name is not UTF-8")))?
            .to_string();
        let dtype = cur.u8("directory dtype")?;
        let layout = if v2 { cur.u8("directory panel layout")? } else { 0 };
        match dtype {
            DTYPE_F32 => {
                if layout != 0 {
                    return Err(CkptError::BadDirectory(format!(
                        "{name}: f32 entries carry panel layout 0, got {layout}"
                    )));
                }
            }
            DTYPE_I8_PANELS | DTYPE_I4_PANELS => {
                if !v2 {
                    return Err(CkptError::BadDirectory(format!(
                        "{name}: packed dtype {dtype} in a version-1 file (v1 payloads are f32)"
                    )));
                }
                if layout != PANEL_LAYOUT {
                    return Err(CkptError::BadDirectory(format!(
                        "{name}: unsupported panel layout {layout} (these kernels consume layout \
                         {PANEL_LAYOUT} — re-run `ckpt migrate` to repack)"
                    )));
                }
            }
            other => {
                return Err(CkptError::BadDirectory(format!(
                    "{name}: unknown dtype {other} (f32, i8-panels or i4-panels)"
                )));
            }
        }
        let rank = cur.u8("directory rank")? as usize;
        if rank > MAX_RANK {
            return Err(CkptError::BadDirectory(format!("{name}: rank {rank} exceeds {MAX_RANK}")));
        }
        let mut dims_t = Vec::with_capacity(rank);
        for _ in 0..rank {
            dims_t.push(cur.u32("directory dims")? as usize);
        }
        let offset = cur.u64("directory offset")?;
        let len = cur.u64("directory length")?;
        let (offset, len) = (
            usize::try_from(offset)
                .map_err(|_| CkptError::BadDirectory(format!("{name}: offset {offset} overflows")))?,
            usize::try_from(len)
                .map_err(|_| CkptError::BadDirectory(format!("{name}: length {len} overflows")))?,
        );
        let expect = expected_len(dtype, &dims_t).ok_or_else(|| {
            CkptError::BadDirectory(format!(
                "{name}: dims {dims_t:?} are invalid for dtype {} (overflow, bad rank, or odd \
                 int4 K)",
                dtype
            ))
        })?;
        if len != expect {
            return Err(CkptError::BadDirectory(format!(
                "{name}: payload length {len} != {expect} implied by dtype {dtype} dims {dims_t:?}"
            )));
        }
        entries.push(Entry { name, dtype, layout, dims: dims_t, offset, len, shard: 0 });
    }

    let mut header_crc = None;
    if v2 {
        // header/directory CRC first — semantic validation below then
        // runs over bytes known to be exactly what the writer emitted.
        let dir_end = cur.pos;
        let stored = cur.u32("header CRC")?;
        let computed = crc32(&data[..dir_end]);
        if stored != computed {
            return Err(CkptError::BadHeaderCrc { stored, computed });
        }
        header_crc = Some(stored);
        header.validate()?;
        let pad = (PAYLOAD_ALIGN - cur.pos % PAYLOAD_ALIGN) % PAYLOAD_ALIGN;
        cur.take(pad, "payload alignment padding")?;
    }

    // duplicate-name detection in O(n log n), not O(n^2) per insert —
    // n_tensors is attacker-controlled up to MAX_TENSORS
    {
        let mut names: Vec<&str> = entries.iter().map(|e| e.name.as_str()).collect();
        names.sort_unstable();
        for w in names.windows(2) {
            if w[0] == w[1] {
                return Err(CkptError::BadDirectory(format!(
                    "duplicate tensor name {:?}",
                    w[0]
                )));
            }
        }
    }

    let payload_start = cur.pos;
    let rest = data.len() - payload_start;
    if rest < 4 {
        return Err(CkptError::Truncated { what: "payload CRC trailer", need: 4, have: rest });
    }
    let payload_len = rest - 4;

    // every entry inside the payload, and no two entries overlapping
    for e in &entries {
        let end = e.offset.checked_add(e.len).ok_or_else(|| {
            CkptError::BadDirectory(format!("{}: offset+len overflows", e.name))
        })?;
        if end > payload_len {
            return Err(CkptError::Truncated {
                what: "tensor payload",
                need: end,
                have: payload_len,
            });
        }
    }
    let mut order: Vec<usize> = (0..entries.len()).collect();
    order.sort_by_key(|&i| entries[i].offset);
    for w in order.windows(2) {
        let (a, b) = (&entries[w[0]], &entries[w[1]]);
        if a.offset + a.len > b.offset {
            return Err(CkptError::Overlap { a: a.name.clone(), b: b.name.clone() });
        }
    }

    let payload = &data[payload_start..payload_start + payload_len];
    let stored = u32::from_le_bytes(data[data.len() - 4..].try_into().unwrap());
    let computed = crc32(payload);
    if stored != computed {
        return Err(CkptError::BadCrc { stored, computed });
    }

    let shard = Shard {
        data: std::sync::Arc::new(data),
        payload_start,
        payload_len,
        payload_crc: stored,
        header_crc,
    };
    Ok((header, version, entries, shard))
}

impl Checkpoint {
    /// Read and fully validate a checkpoint: a single `.mkqc` file, or a
    /// sharded directory containing a [`MANIFEST_NAME`] manifest. File
    /// bytes are mmap'd where possible (see
    /// [`FileBytes::open`](crate::modelstore::mapped::FileBytes::open)).
    pub fn read(path: &Path) -> Result<Self, CkptError> {
        Self::read_with(path, false)
    }

    /// [`Checkpoint::read`] with mmap disabled — the buffered fallback
    /// path, callable directly so equivalence tests (and the load bench)
    /// can compare both paths on any machine.
    pub fn read_buffered(path: &Path) -> Result<Self, CkptError> {
        Self::read_with(path, true)
    }

    fn read_with(path: &Path, buffered: bool) -> Result<Self, CkptError> {
        let load = |p: &Path| -> Result<FileBytes, CkptError> {
            Ok(if buffered { FileBytes::read_buffered(p)? } else { FileBytes::open(p)? })
        };
        if path.is_dir() {
            return Self::read_sharded(path, &load);
        }
        let (header, version, entries, shard) = parse_one(load(path)?)?;
        Ok(Checkpoint { header, version, entries, shards: vec![shard] })
    }

    /// Parse + validate checkpoint bytes (a whole single-file image).
    pub fn from_bytes(data: Vec<u8>) -> Result<Self, CkptError> {
        let (header, version, entries, shard) = parse_one(FileBytes::from(data))?;
        Ok(Checkpoint { header, version, entries, shards: vec![shard] })
    }

    /// Load a sharded checkpoint directory: parse the manifest, load
    /// every shard, demand bit-identical headers and globally unique
    /// tensor names.
    fn read_sharded(
        dir: &Path,
        load: &dyn Fn(&Path) -> Result<FileBytes, CkptError>,
    ) -> Result<Self, CkptError> {
        let manifest_path = dir.join(MANIFEST_NAME);
        if !manifest_path.is_file() {
            return Err(CkptError::BadHeader(format!(
                "{} is a directory without a {MANIFEST_NAME} shard manifest",
                dir.display()
            )));
        }
        let text = std::fs::read_to_string(&manifest_path)?;
        let mut lines =
            text.lines().map(str::trim).filter(|l| !l.is_empty() && !l.starts_with('#'));
        match lines.next() {
            Some(tag) if tag == MANIFEST_TAG => {}
            other => {
                return Err(CkptError::BadHeader(format!(
                    "shard manifest {} starts with {other:?}, want {MANIFEST_TAG:?}",
                    manifest_path.display()
                )))
            }
        }
        let names: Vec<&str> = lines.collect();
        if names.is_empty() {
            return Err(CkptError::BadHeader(format!(
                "shard manifest {} lists no shard files",
                manifest_path.display()
            )));
        }

        let mut merged: Option<(CkptHeader, u32)> = None;
        let mut entries: Vec<Entry> = Vec::new();
        let mut shards: Vec<Shard> = Vec::new();
        for name in names {
            if name.contains('/') || name.contains('\\') || name.contains("..") {
                return Err(CkptError::BadDirectory(format!(
                    "shard name {name:?} must be a plain file name inside the checkpoint directory"
                )));
            }
            let shard_path = dir.join(name);
            if !shard_path.is_file() {
                return Err(CkptError::ShardMissing {
                    manifest: manifest_path.display().to_string(),
                    shard: name.to_string(),
                });
            }
            let (header, version, mut shard_entries, shard) = parse_one(load(&shard_path)?)?;
            if version < VERSION {
                return Err(CkptError::BadHeader(format!(
                    "shard {name:?} is format v{version}; sharded checkpoints are v2"
                )));
            }
            let matches_first = match merged.as_ref() {
                Some((h0, _)) => *h0 == header,
                None => true,
            };
            if !matches_first {
                return Err(CkptError::BadHeader(format!(
                    "shard {name:?} header disagrees with the first shard's"
                )));
            }
            if merged.is_none() {
                merged = Some((header, version));
            }
            let si = shards.len();
            for e in shard_entries.iter_mut() {
                e.shard = si;
            }
            entries.append(&mut shard_entries);
            shards.push(shard);
        }
        // cross-shard duplicate names in O(n log n), same as the
        // within-shard check — entry counts are attacker-controlled
        {
            let mut names: Vec<(&str, usize)> =
                entries.iter().map(|e| (e.name.as_str(), e.shard)).collect();
            names.sort_unstable();
            for w in names.windows(2) {
                if w[0].0 == w[1].0 {
                    return Err(CkptError::BadDirectory(format!(
                        "tensor {:?} appears in more than one shard",
                        w[0].0
                    )));
                }
            }
        }
        let (header, version) = merged.expect("at least one shard");
        Ok(Checkpoint { header, version, entries, shards })
    }

    pub fn header(&self) -> &CkptHeader {
        &self.header
    }

    /// The parsed format version (1 or 2).
    pub fn version(&self) -> u32 {
        self.version
    }

    pub fn entries(&self) -> &[Entry] {
        &self.entries
    }

    pub fn shard_count(&self) -> usize {
        self.shards.len()
    }

    /// Total payload bytes across all shards.
    pub fn payload_bytes(&self) -> usize {
        self.shards.iter().map(|s| s.payload_len).sum()
    }

    /// Stored payload CRC-32 per shard (one value for single files).
    pub fn payload_crcs(&self) -> Vec<u32> {
        self.shards.iter().map(|s| s.payload_crc).collect()
    }

    /// Stored v2 header/directory CRC of shard 0 (`None` for v1).
    pub fn header_crc(&self) -> Option<u32> {
        self.shards.first().and_then(|s| s.header_crc)
    }

    /// File offset where a shard's payload begins (16-aligned in v2).
    pub fn payload_file_offset(&self, shard: usize) -> usize {
        self.shards[shard].payload_start
    }

    /// True when any backing shard is an mmap rather than an owned read.
    pub fn is_mapped(&self) -> bool {
        self.shards.iter().any(|s| s.data.is_mapped())
    }

    /// Heap bytes held by the backing file images (0 for fully mapped
    /// checkpoints) — the I/O term of the load bench's RSS proxy.
    pub fn file_heap_bytes(&self) -> usize {
        self.shards.iter().map(|s| s.data.heap_bytes()).sum()
    }

    pub fn entry(&self, name: &str) -> Option<&Entry> {
        self.entries.iter().find(|e| e.name == name)
    }

    fn entry_required(&self, name: &str) -> Result<&Entry, CkptError> {
        self.entry(name).ok_or_else(|| CkptError::MissingTensor(name.to_string()))
    }

    /// The raw payload bytes of one entry.
    fn raw_slice(&self, e: &Entry) -> &[u8] {
        let s = &self.shards[e.shard];
        &s.data[s.payload_start + e.offset..s.payload_start + e.offset + e.len]
    }

    /// Decode one fp32 tensor by name (owned copy — see
    /// [`Checkpoint::f32_view`] for the zero-copy path).
    pub fn f32_tensor(&self, name: &str) -> Result<(&[usize], Vec<f32>), CkptError> {
        let e = self.entry_required(name)?;
        let data = self.f32_view_entry(e)?.into_owned();
        Ok((&e.dims, data))
    }

    /// Borrow one fp32 tensor *in place* from the file image when the
    /// bytes are 4-aligned on a little-endian target (always true for a
    /// v2 file's aligned payload under mmap), falling back to an owned
    /// decode otherwise — callers just see `&[f32]` either way.
    pub fn f32_view(&self, name: &str) -> Result<Cow<'_, [f32]>, CkptError> {
        let e = self.entry_required(name)?;
        self.f32_view_entry(e)
    }

    /// [`Checkpoint::f32_view`] over an already-found entry (one
    /// directory scan per tensor, not one per accessor hop).
    fn f32_view_entry<'s>(&'s self, e: &Entry) -> Result<Cow<'s, [f32]>, CkptError> {
        if e.dtype != DTYPE_F32 {
            return Err(CkptError::BadDirectory(format!(
                "{} is stored as {} — not an fp32 tensor",
                e.name,
                e.dtype_name()
            )));
        }
        let raw = self.raw_slice(e);
        if cfg!(target_endian = "little")
            && (raw.as_ptr() as usize) % std::mem::align_of::<f32>() == 0
        {
            // SAFETY: the pointer is 4-aligned (checked), the length is a
            // validated multiple of 4, every bit pattern is a valid f32,
            // and on little-endian targets the in-memory representation
            // equals the file's LE encoding. The borrow ties the view's
            // lifetime to the checkpoint (which owns the mapping).
            let s = unsafe {
                std::slice::from_raw_parts(raw.as_ptr() as *const f32, raw.len() / 4)
            };
            Ok(Cow::Borrowed(s))
        } else {
            Ok(Cow::Owned(
                raw.chunks_exact(4)
                    .map(|b| f32::from_le_bytes(b.try_into().unwrap()))
                    .collect(),
            ))
        }
    }

    /// Borrow the raw panel bytes of a prepacked (v2) weight entry.
    pub fn panel_bytes(&self, name: &str) -> Result<&[u8], CkptError> {
        let e = self.entry_required(name)?;
        if e.dtype != DTYPE_I8_PANELS && e.dtype != DTYPE_I4_PANELS {
            return Err(CkptError::BadDirectory(format!(
                "{name} is stored as {} — not prepacked panels",
                e.dtype_name()
            )));
        }
        Ok(self.raw_slice(e))
    }

    /// A shared-ownership view of one entry's payload bytes: the returned
    /// [`PanelRef`] clones the shard image's `Arc`, so it stays valid
    /// after this `Checkpoint` is dropped — the zero-copy load contract.
    fn entry_ref(&self, e: &Entry) -> PanelRef {
        let s = &self.shards[e.shard];
        let owner: std::sync::Arc<dyn AsRef<[u8]> + Send + Sync> = s.data.clone();
        PanelRef::new(owner, s.payload_start + e.offset, e.len)
    }

    /// Zero-copy variant of [`Checkpoint::panel_bytes`]: borrow the panel
    /// bytes of a prepacked (v2) weight entry without tying the borrow to
    /// this checkpoint's lifetime.
    pub fn panel_ref(&self, name: &str) -> Result<PanelRef, CkptError> {
        let e = self.entry_required(name)?;
        if e.dtype != DTYPE_I8_PANELS && e.dtype != DTYPE_I4_PANELS {
            return Err(CkptError::BadDirectory(format!(
                "{name} is stored as {} — not prepacked panels",
                e.dtype_name()
            )));
        }
        Ok(self.entry_ref(e))
    }

    /// Zero-copy raw bytes of an fp32 entry (LE f32 encoding), plus its
    /// dims — the scales side of a zero-copy weight load. Callers decide
    /// whether an in-place view is legal (see `kernels::ScaleVec`).
    pub fn f32_ref(&self, name: &str) -> Result<(&[usize], PanelRef), CkptError> {
        let e = self.entry_required(name)?;
        if e.dtype != DTYPE_F32 {
            return Err(CkptError::BadDirectory(format!(
                "{} is stored as {} — not an fp32 tensor",
                e.name,
                e.dtype_name()
            )));
        }
        Ok((&e.dims, self.entry_ref(e)))
    }

    /// An fp32 master for `name`, dequantizing a prepacked entry through
    /// its `.scales` sibling when no master is stored (v2 replaces
    /// masters with panels). Dequantized values are `code * scale` — the
    /// exact grid the packed weights serve with, not the original
    /// pre-quantization weights.
    pub fn f32_or_dequant(&self, name: &str) -> Result<(Vec<usize>, Vec<f32>), CkptError> {
        let e = self.entry_required(name)?;
        if e.dtype == DTYPE_F32 {
            let (dims, data) = self.f32_tensor(name)?;
            return Ok((dims.to_vec(), data));
        }
        let bits = if e.dtype == DTYPE_I8_PANELS { 8 } else { 4 };
        let (k, n) = (e.dims[0], e.dims[1]);
        let (_, scales) = self.f32_tensor(&format!("{name}.scales"))?;
        let pw = PackedWeights::from_panels(bits, k, n, scales, self.raw_slice(e))
            .map_err(CkptError::BadDirectory)?;
        let codes = pw.unpack_codes();
        let mut w = vec![0f32; k * n];
        for kk in 0..k {
            for c in 0..n {
                w[kk * n + c] = codes[kk * n + c] as f32 * pw.scales[c];
            }
        }
        Ok((e.dims.clone(), w))
    }

    /// Decode every **fp32** tensor into the `(name, dims, data)` form
    /// the native model constructors consume. Prepacked panel entries are
    /// skipped (their `.scales` siblings, being f32, are included).
    pub fn named_tensors(&self) -> Vec<(String, Vec<usize>, Vec<f32>)> {
        self.entries
            .iter()
            .filter(|e| e.dtype == DTYPE_F32)
            .map(|e| {
                let (dims, data) = self.f32_tensor(&e.name).expect("entry self-lookup");
                (e.name.clone(), dims.to_vec(), data)
            })
            .collect()
    }
}

#[cfg(test)]
mod tests {
    use super::super::Writer;
    use super::*;

    fn tiny_bytes() -> Vec<u8> {
        let dims = NativeDims { vocab: 8, seq: 4, n_layers: 1, d_model: 4, n_heads: 2, d_ff: 8, n_classes: 2 };
        let header = CkptHeader { dims, bits: vec![4], act_scales: vec![[0.25; 4]] };
        let mut w = Writer::new(header).unwrap();
        w.add_f32("t0", &[2, 3], &[1.0, 2.0, 3.0, 4.0, 5.0, 6.0]).unwrap();
        w.add_f32("t1", &[2], &[-1.0, 1.0]).unwrap();
        w.to_bytes()
    }

    #[test]
    fn parses_valid_bytes() {
        let ck = Checkpoint::from_bytes(tiny_bytes()).unwrap();
        assert_eq!(ck.version(), VERSION);
        assert_eq!(ck.header().bits, vec![4]);
        assert_eq!(ck.entries().len(), 2);
        assert_eq!(ck.payload_bytes(), 4 * 8);
        assert_eq!(ck.shard_count(), 1);
        let named = ck.named_tensors();
        assert_eq!(named[0].0, "t0");
        assert_eq!(named[0].1, vec![2, 3]);
        assert_eq!(named[1].2, vec![-1.0, 1.0]);
        // the view decodes correctly whichever side of the alignment
        // check it lands on (a Vec<u8>-backed image only guarantees
        // 1-byte alignment, so Borrowed-ness is allocator-dependent here;
        // the guaranteed-aligned case is the mmap'd-file path, covered by
        // rust/tests/modelstore.rs)
        let view = ck.f32_view("t0").unwrap();
        assert_eq!(&view[..2], &[1.0, 2.0]);
        assert!(matches!(ck.f32_view("missing"), Err(CkptError::MissingTensor(_))));
    }

    #[test]
    fn rejects_bad_magic_version_crc_truncation() {
        let good = tiny_bytes();

        let mut bad = good.clone();
        bad[0] = b'X';
        assert!(matches!(Checkpoint::from_bytes(bad), Err(CkptError::BadMagic { .. })));

        let mut bad = good.clone();
        bad[4] = 99; // version LE byte 0
        assert!(matches!(
            Checkpoint::from_bytes(bad),
            Err(CkptError::BadVersion { got: 99 })
        ));

        let mut bad = good.clone();
        let flip = good.len() - 10; // inside the payload
        bad[flip] ^= 0xFF;
        assert!(matches!(Checkpoint::from_bytes(bad), Err(CkptError::BadCrc { .. })));

        for cut in [2usize, 30, good.len() - 5, good.len() - 1] {
            let bad = good[..cut].to_vec();
            assert!(
                matches!(Checkpoint::from_bytes(bad), Err(CkptError::Truncated { .. })),
                "cut at {cut} must be Truncated"
            );
        }
        assert!(matches!(
            Checkpoint::from_bytes(Vec::new()),
            Err(CkptError::Truncated { .. })
        ));
    }

    #[test]
    fn v2_header_bit_flip_fails_header_crc() {
        // flip one bit inside a stored activation scale: structurally the
        // header still parses (finite positive scale), so only the v2
        // header/directory CRC can catch it.
        let good = tiny_bytes();
        let mut bad = good.clone();
        bad[44] ^= 0x01; // act_scales[0][0] mantissa LSB (offset 40 + 4·L bits, L=1)
        assert!(matches!(
            Checkpoint::from_bytes(bad),
            Err(CkptError::BadHeaderCrc { .. })
        ));
    }

    #[test]
    fn rejects_garbage_after_header() {
        // valid header, then directory bytes that cannot parse
        let good = tiny_bytes();
        let mut bad = good[..60].to_vec(); // fixed header is exactly 60 bytes for L=1
        bad.extend_from_slice(&[0xFF; 3]);
        assert!(Checkpoint::from_bytes(bad).is_err());
    }
}
