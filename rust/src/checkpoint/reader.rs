//! MKQC reader: parse + validate a checkpoint file, then serve tensors
//! by name.
//!
//! Validation order (each failure is a typed [`CkptError`]):
//! magic → version → header fields ([`CkptHeader::validate`]) → directory
//! structure (name/rank/dtype/size bounds) → payload bounds (every entry
//! inside the payload, no overlapping entries) → payload CRC-32 against
//! the stored trailer. Only a fully validated file hands out tensors.

use std::path::Path;

use crate::util::crc32::crc32;

use super::{
    CkptError, CkptHeader, DTYPE_F32, MAGIC, MAX_LAYERS, MAX_NAME_LEN, MAX_RANK, MAX_TENSORS,
    VERSION,
};
use crate::runtime::native::NativeDims;

/// One parsed directory entry (exposed for `mkq-bert ckpt inspect`).
#[derive(Debug, Clone)]
pub struct Entry {
    pub name: String,
    pub dtype: u8,
    pub dims: Vec<usize>,
    /// Byte offset from payload start.
    pub offset: usize,
    /// Byte length.
    pub len: usize,
}

/// A validated, in-memory checkpoint.
pub struct Checkpoint {
    header: CkptHeader,
    entries: Vec<Entry>,
    data: Vec<u8>,
    payload_start: usize,
    payload_len: usize,
}

struct Cur<'a> {
    data: &'a [u8],
    pos: usize,
}

impl<'a> Cur<'a> {
    fn take(&mut self, n: usize, what: &'static str) -> Result<&'a [u8], CkptError> {
        let have = self.data.len() - self.pos;
        if have < n {
            return Err(CkptError::Truncated { what, need: n, have });
        }
        let s = &self.data[self.pos..self.pos + n];
        self.pos += n;
        Ok(s)
    }

    fn u8(&mut self, what: &'static str) -> Result<u8, CkptError> {
        Ok(self.take(1, what)?[0])
    }

    fn u16(&mut self, what: &'static str) -> Result<u16, CkptError> {
        let b = self.take(2, what)?;
        Ok(u16::from_le_bytes([b[0], b[1]]))
    }

    fn u32(&mut self, what: &'static str) -> Result<u32, CkptError> {
        let b = self.take(4, what)?;
        Ok(u32::from_le_bytes([b[0], b[1], b[2], b[3]]))
    }

    fn u64(&mut self, what: &'static str) -> Result<u64, CkptError> {
        let b = self.take(8, what)?;
        Ok(u64::from_le_bytes(b.try_into().unwrap()))
    }

    fn f32(&mut self, what: &'static str) -> Result<f32, CkptError> {
        Ok(f32::from_bits(self.u32(what)?))
    }
}

impl Checkpoint {
    /// Read and fully validate a checkpoint file.
    pub fn read(path: &Path) -> Result<Self, CkptError> {
        Self::from_bytes(std::fs::read(path)?)
    }

    /// Parse + validate checkpoint bytes (the whole file image).
    pub fn from_bytes(data: Vec<u8>) -> Result<Self, CkptError> {
        let mut cur = Cur { data: &data[..], pos: 0 };

        let magic = cur.take(4, "magic")?;
        if magic != MAGIC {
            return Err(CkptError::BadMagic { got: magic.try_into().unwrap() });
        }
        let version = cur.u32("version")?;
        if version != VERSION {
            return Err(CkptError::BadVersion { got: version });
        }

        let mut dims_v = [0usize; 7];
        for (slot, what) in dims_v.iter_mut().zip([
            "vocab", "seq", "n_layers", "d_model", "n_heads", "d_ff", "n_classes",
        ]) {
            *slot = cur.u32(what)? as usize;
        }
        let dims = NativeDims {
            vocab: dims_v[0],
            seq: dims_v[1],
            n_layers: dims_v[2],
            d_model: dims_v[3],
            n_heads: dims_v[4],
            d_ff: dims_v[5],
            n_classes: dims_v[6],
        };
        let n_tensors = cur.u32("n_tensors")? as usize;
        if n_tensors > MAX_TENSORS {
            return Err(CkptError::BadDirectory(format!(
                "n_tensors {n_tensors} exceeds {MAX_TENSORS}"
            )));
        }
        // bound n_layers BEFORE allocating header tables from it
        if dims.n_layers == 0 || dims.n_layers > MAX_LAYERS {
            return Err(CkptError::BadHeader(format!(
                "n_layers {} out of range 1..={MAX_LAYERS}",
                dims.n_layers
            )));
        }
        let mut bits = Vec::with_capacity(dims.n_layers);
        for _ in 0..dims.n_layers {
            bits.push(cur.u32("bit vector")?);
        }
        let mut act_scales = Vec::with_capacity(dims.n_layers);
        for _ in 0..dims.n_layers {
            let mut row = [0f32; 4];
            for s in row.iter_mut() {
                *s = cur.f32("activation scales")?;
            }
            act_scales.push(row);
        }
        let header = CkptHeader { dims, bits, act_scales };
        header.validate()?;

        // cap the pre-allocation by what the remaining bytes could hold (a
        // directory entry is at least 21 bytes), so a corrupt n_tensors in
        // a tiny file cannot force a large allocation before parsing fails
        const MIN_ENTRY_BYTES: usize = 2 + 1 + 1 + 1 + 8 + 8;
        let cap = n_tensors.min((data.len() - cur.pos) / MIN_ENTRY_BYTES + 1);
        let mut entries = Vec::with_capacity(cap);
        for i in 0..n_tensors {
            let name_len = cur.u16("directory name length")? as usize;
            if name_len == 0 || name_len > MAX_NAME_LEN {
                return Err(CkptError::BadDirectory(format!(
                    "entry {i}: name length {name_len} out of range 1..={MAX_NAME_LEN}"
                )));
            }
            let name = std::str::from_utf8(cur.take(name_len, "directory name")?)
                .map_err(|_| CkptError::BadDirectory(format!("entry {i}: name is not UTF-8")))?
                .to_string();
            let dtype = cur.u8("directory dtype")?;
            if dtype != DTYPE_F32 {
                return Err(CkptError::BadDirectory(format!(
                    "{name}: unknown dtype {dtype} (version-1 payloads are f32)"
                )));
            }
            let rank = cur.u8("directory rank")? as usize;
            if rank > MAX_RANK {
                return Err(CkptError::BadDirectory(format!("{name}: rank {rank} exceeds {MAX_RANK}")));
            }
            let mut dims_t = Vec::with_capacity(rank);
            for _ in 0..rank {
                dims_t.push(cur.u32("directory dims")? as usize);
            }
            let offset = cur.u64("directory offset")?;
            let len = cur.u64("directory length")?;
            let (offset, len) = (
                usize::try_from(offset)
                    .map_err(|_| CkptError::BadDirectory(format!("{name}: offset {offset} overflows")))?,
                usize::try_from(len)
                    .map_err(|_| CkptError::BadDirectory(format!("{name}: length {len} overflows")))?,
            );
            let count = dims_t
                .iter()
                .try_fold(1usize, |a, &d| a.checked_mul(d))
                .ok_or_else(|| CkptError::BadDirectory(format!("{name}: dims {dims_t:?} overflow")))?;
            let expect = count
                .checked_mul(4)
                .ok_or_else(|| CkptError::BadDirectory(format!("{name}: byte size overflows")))?;
            if len != expect {
                return Err(CkptError::BadDirectory(format!(
                    "{name}: payload length {len} != dims {dims_t:?} x 4 = {expect}"
                )));
            }
            entries.push(Entry { name, dtype, dims: dims_t, offset, len });
        }
        // duplicate-name detection in O(n log n), not O(n^2) per insert —
        // n_tensors is attacker-controlled up to MAX_TENSORS
        {
            let mut names: Vec<&str> = entries.iter().map(|e| e.name.as_str()).collect();
            names.sort_unstable();
            for w in names.windows(2) {
                if w[0] == w[1] {
                    return Err(CkptError::BadDirectory(format!(
                        "duplicate tensor name {:?}",
                        w[0]
                    )));
                }
            }
        }

        let payload_start = cur.pos;
        let rest = data.len() - payload_start;
        if rest < 4 {
            return Err(CkptError::Truncated { what: "payload CRC trailer", need: 4, have: rest });
        }
        let payload_len = rest - 4;

        // every entry inside the payload, and no two entries overlapping
        for e in &entries {
            let end = e.offset.checked_add(e.len).ok_or_else(|| {
                CkptError::BadDirectory(format!("{}: offset+len overflows", e.name))
            })?;
            if end > payload_len {
                return Err(CkptError::Truncated {
                    what: "tensor payload",
                    need: end,
                    have: payload_len,
                });
            }
        }
        let mut order: Vec<usize> = (0..entries.len()).collect();
        order.sort_by_key(|&i| entries[i].offset);
        for w in order.windows(2) {
            let (a, b) = (&entries[w[0]], &entries[w[1]]);
            if a.offset + a.len > b.offset {
                return Err(CkptError::Overlap { a: a.name.clone(), b: b.name.clone() });
            }
        }

        let payload = &data[payload_start..payload_start + payload_len];
        let stored = u32::from_le_bytes(data[data.len() - 4..].try_into().unwrap());
        let computed = crc32(payload);
        if stored != computed {
            return Err(CkptError::BadCrc { stored, computed });
        }

        Ok(Checkpoint { header, entries, data, payload_start, payload_len })
    }

    pub fn header(&self) -> &CkptHeader {
        &self.header
    }

    pub fn entries(&self) -> &[Entry] {
        &self.entries
    }

    pub fn payload_bytes(&self) -> usize {
        self.payload_len
    }

    /// Decode one fp32 tensor by name.
    pub fn f32_tensor(&self, name: &str) -> Result<(&[usize], Vec<f32>), CkptError> {
        let e = self
            .entries
            .iter()
            .find(|e| e.name == name)
            .ok_or_else(|| CkptError::MissingTensor(name.to_string()))?;
        let raw = &self.data[self.payload_start + e.offset..self.payload_start + e.offset + e.len];
        let data = raw
            .chunks_exact(4)
            .map(|b| f32::from_le_bytes(b.try_into().unwrap()))
            .collect();
        Ok((&e.dims, data))
    }

    /// Decode every tensor into the `(name, dims, data)` form the native
    /// model constructors consume.
    pub fn named_tensors(&self) -> Vec<(String, Vec<usize>, Vec<f32>)> {
        self.entries
            .iter()
            .map(|e| {
                let (dims, data) = self.f32_tensor(&e.name).expect("entry self-lookup");
                (e.name.clone(), dims.to_vec(), data)
            })
            .collect()
    }
}

#[cfg(test)]
mod tests {
    use super::super::Writer;
    use super::*;

    fn tiny_bytes() -> Vec<u8> {
        let dims = NativeDims { vocab: 8, seq: 4, n_layers: 1, d_model: 4, n_heads: 2, d_ff: 8, n_classes: 2 };
        let header = CkptHeader { dims, bits: vec![4], act_scales: vec![[0.25; 4]] };
        let mut w = Writer::new(header).unwrap();
        w.add_f32("t0", &[2, 3], &[1.0, 2.0, 3.0, 4.0, 5.0, 6.0]).unwrap();
        w.add_f32("t1", &[2], &[-1.0, 1.0]).unwrap();
        w.to_bytes()
    }

    #[test]
    fn parses_valid_bytes() {
        let ck = Checkpoint::from_bytes(tiny_bytes()).unwrap();
        assert_eq!(ck.header().bits, vec![4]);
        assert_eq!(ck.entries().len(), 2);
        assert_eq!(ck.payload_bytes(), 4 * 8);
        let named = ck.named_tensors();
        assert_eq!(named[0].0, "t0");
        assert_eq!(named[0].1, vec![2, 3]);
        assert_eq!(named[1].2, vec![-1.0, 1.0]);
    }

    #[test]
    fn rejects_bad_magic_version_crc_truncation() {
        let good = tiny_bytes();

        let mut bad = good.clone();
        bad[0] = b'X';
        assert!(matches!(Checkpoint::from_bytes(bad), Err(CkptError::BadMagic { .. })));

        let mut bad = good.clone();
        bad[4] = 99; // version LE byte 0
        assert!(matches!(
            Checkpoint::from_bytes(bad),
            Err(CkptError::BadVersion { got: 99 })
        ));

        let mut bad = good.clone();
        let flip = good.len() - 10; // inside the payload
        bad[flip] ^= 0xFF;
        assert!(matches!(Checkpoint::from_bytes(bad), Err(CkptError::BadCrc { .. })));

        for cut in [2usize, 30, good.len() - 5, good.len() - 1] {
            let bad = good[..cut].to_vec();
            assert!(
                matches!(Checkpoint::from_bytes(bad), Err(CkptError::Truncated { .. })),
                "cut at {cut} must be Truncated"
            );
        }
        assert!(matches!(
            Checkpoint::from_bytes(Vec::new()),
            Err(CkptError::Truncated { .. })
        ));
    }

    #[test]
    fn rejects_garbage_after_header() {
        // valid header, then directory bytes that cannot parse
        let good = tiny_bytes();
        let mut bad = good[..60].to_vec(); // fixed header is exactly 60 bytes for L=1
        bad.extend_from_slice(&[0xFF; 3]);
        assert!(Checkpoint::from_bytes(bad).is_err());
    }
}
