//! MKQC writer: stream tensors in, emit header + directory (+ v2
//! header/directory CRC + alignment pad) + payload + trailing payload
//! CRC-32 in one pass at [`Writer::write_to`].
//!
//! Tensor bytes are accumulated into the payload buffer (and the CRC) as
//! they are added, so each tensor is converted to little-endian exactly
//! once; the header and directory are serialized last, when every offset
//! is known. `write_to` writes to a `.tmp` sibling and renames, so a
//! crash mid-export never leaves a half-written checkpoint at the target
//! path. (Follow-on, see ROADMAP: spill the payload to disk instead of
//! RAM for checkpoints that approach memory size.)
//!
//! [`Writer::new`] emits the current format ([`VERSION`] = 2: per-entry
//! panel-layout byte, header/directory CRC, 16-byte-aligned payload
//! start, packed-panel dtypes). [`Writer::v1`] keeps emitting the
//! original fp32-masters-only v1 — the compatibility surface the
//! v1→migrate tests and the (deliberately v1) Python exporter cross-check
//! exercise.

use std::path::Path;

use crate::kernels::PackedWeights;
use crate::util::crc32::{crc32, Crc32};

use super::{
    CkptError, CkptHeader, DTYPE_F32, DTYPE_I4_PANELS, DTYPE_I8_PANELS, MAGIC, MAX_NAME_LEN,
    MAX_RANK, PANEL_LAYOUT, PAYLOAD_ALIGN, VERSION, VERSION_V1,
};

pub(crate) struct DirEntry {
    pub name: String,
    pub dtype: u8,
    pub layout: u8,
    pub dims: Vec<usize>,
    pub offset: u64,
    pub len: u64,
}

/// Serializer for one checkpoint file. Add every tensor, then call
/// [`write_to`](Writer::write_to) (or [`to_bytes`](Writer::to_bytes)).
pub struct Writer {
    header: CkptHeader,
    version: u32,
    entries: Vec<DirEntry>,
    payload: Vec<u8>,
    crc: Crc32,
}

impl Writer {
    /// A current-version (v2) writer. Validates the header up front so a
    /// structurally broken checkpoint can never be produced.
    pub fn new(header: CkptHeader) -> Result<Self, CkptError> {
        Self::with_version(header, VERSION)
    }

    /// A legacy v1 writer (fp32 masters only, payload CRC only).
    pub fn v1(header: CkptHeader) -> Result<Self, CkptError> {
        Self::with_version(header, VERSION_V1)
    }

    fn with_version(header: CkptHeader, version: u32) -> Result<Self, CkptError> {
        assert!(version == VERSION_V1 || version == VERSION, "writer supports v1/v2");
        header.validate()?;
        Ok(Writer { header, version, entries: Vec::new(), payload: Vec::new(), crc: Crc32::new() })
    }

    pub fn header(&self) -> &CkptHeader {
        &self.header
    }

    pub fn version(&self) -> u32 {
        self.version
    }

    fn check_name(&self, name: &str) -> Result<(), CkptError> {
        if name.is_empty() || name.len() > MAX_NAME_LEN {
            return Err(CkptError::BadDirectory(format!(
                "tensor name {name:?} length {} out of range 1..={MAX_NAME_LEN}",
                name.len()
            )));
        }
        if self.entries.iter().any(|e| e.name == name) {
            return Err(CkptError::BadDirectory(format!("duplicate tensor name {name:?}")));
        }
        Ok(())
    }

    /// Append one fp32 tensor. Rejects duplicate names, over-long names,
    /// rank > [`MAX_RANK`] and dims/data length mismatches.
    pub fn add_f32(&mut self, name: &str, dims: &[usize], data: &[f32]) -> Result<(), CkptError> {
        self.check_name(name)?;
        if dims.len() > MAX_RANK {
            return Err(CkptError::BadDirectory(format!(
                "{name}: rank {} exceeds {MAX_RANK}",
                dims.len()
            )));
        }
        let count: usize = dims.iter().product();
        if count != data.len() {
            return Err(CkptError::DimsMismatch(format!(
                "{name}: dims {dims:?} imply {count} elements, got {}",
                data.len()
            )));
        }
        let offset = self.payload.len() as u64;
        self.payload.reserve(data.len() * 4);
        for &v in data {
            let b = v.to_le_bytes();
            self.crc.update(&b);
            self.payload.extend_from_slice(&b);
        }
        self.entries.push(DirEntry {
            name: name.to_string(),
            dtype: DTYPE_F32,
            layout: 0,
            dims: dims.to_vec(),
            offset,
            len: (data.len() * 4) as u64,
        });
        Ok(())
    }

    /// Append one prepacked weight under the master tensor's name plus
    /// its `{name}.scales` f32 sibling — the v2 persistence of a
    /// quantized [`PackedWeights`]. The entry's dims stay the *logical*
    /// `[k, n]`; the byte length is the panel-layout size. v1 writers
    /// reject this (v1 has no packed dtypes).
    pub fn add_packed(&mut self, name: &str, pw: &PackedWeights) -> Result<(), CkptError> {
        if self.version < VERSION {
            return Err(CkptError::BadDirectory(format!(
                "{name}: packed panels need format v2 (writer is v{})",
                self.version
            )));
        }
        self.check_name(name)?;
        let dtype = match pw.bits {
            8 => DTYPE_I8_PANELS,
            4 => DTYPE_I4_PANELS,
            b => {
                return Err(CkptError::BadDirectory(format!(
                    "{name}: no packed dtype for {b}-bit weights"
                )))
            }
        };
        let raw = pw.raw_bytes();
        let offset = self.payload.len() as u64;
        self.crc.update(raw);
        self.payload.extend_from_slice(raw);
        self.entries.push(DirEntry {
            name: name.to_string(),
            dtype,
            layout: PANEL_LAYOUT,
            dims: vec![pw.k, pw.n],
            offset,
            len: raw.len() as u64,
        });
        self.add_f32(&format!("{name}.scales"), &[pw.n], &pw.scales)
    }

    pub fn tensor_count(&self) -> usize {
        self.entries.len()
    }

    pub fn payload_bytes(&self) -> usize {
        self.payload.len()
    }

    /// Serialize the whole checkpoint to a byte vector.
    pub fn to_bytes(&self) -> Vec<u8> {
        let d = &self.header.dims;
        let entry_fixed = if self.version >= VERSION { 2 + 1 + 1 + 1 + 16 } else { 2 + 1 + 1 + 16 };
        let dir_len: usize =
            self.entries.iter().map(|e| entry_fixed + e.name.len() + 4 * e.dims.len()).sum();
        let header_len = 4 + 4 + 7 * 4 + 4 + 4 * d.n_layers + 16 * d.n_layers;
        let mut out =
            Vec::with_capacity(header_len + dir_len + PAYLOAD_ALIGN + self.payload.len() + 8);

        out.extend_from_slice(&MAGIC);
        out.extend_from_slice(&self.version.to_le_bytes());
        for v in [d.vocab, d.seq, d.n_layers, d.d_model, d.n_heads, d.d_ff, d.n_classes] {
            out.extend_from_slice(&(v as u32).to_le_bytes());
        }
        out.extend_from_slice(&(self.entries.len() as u32).to_le_bytes());
        for &b in &self.header.bits {
            out.extend_from_slice(&b.to_le_bytes());
        }
        for row in &self.header.act_scales {
            for &s in row {
                out.extend_from_slice(&s.to_le_bytes());
            }
        }
        for e in &self.entries {
            out.extend_from_slice(&(e.name.len() as u16).to_le_bytes());
            out.extend_from_slice(e.name.as_bytes());
            out.push(e.dtype);
            if self.version >= VERSION {
                out.push(e.layout);
            }
            out.push(e.dims.len() as u8);
            for &dim in &e.dims {
                out.extend_from_slice(&(dim as u32).to_le_bytes());
            }
            out.extend_from_slice(&e.offset.to_le_bytes());
            out.extend_from_slice(&e.len.to_le_bytes());
        }
        if self.version >= VERSION {
            // header/directory CRC over everything serialized so far,
            // then zero padding to a PAYLOAD_ALIGN'd payload start (the
            // reader recomputes the pad, it is not stored).
            let hcrc = crc32(&out);
            out.extend_from_slice(&hcrc.to_le_bytes());
            let pad = (PAYLOAD_ALIGN - out.len() % PAYLOAD_ALIGN) % PAYLOAD_ALIGN;
            out.extend(std::iter::repeat(0u8).take(pad));
        }
        out.extend_from_slice(&self.payload);
        out.extend_from_slice(&self.crc.finish().to_le_bytes());
        out
    }

    /// Write atomically: serialize to `<path>.tmp`, then rename over the
    /// target. The suffix is appended to the full file name (not swapped
    /// for the extension) so concurrent exports to distinct targets never
    /// share a temp file.
    pub fn write_to(&self, path: &Path) -> Result<(), CkptError> {
        let bytes = self.to_bytes();
        let mut tmp_name = path.as_os_str().to_os_string();
        tmp_name.push(".tmp");
        let tmp = std::path::PathBuf::from(tmp_name);
        std::fs::write(&tmp, &bytes)?;
        std::fs::rename(&tmp, path)?;
        Ok(())
    }
}

#[cfg(test)]
mod tests {
    use super::super::Checkpoint;
    use super::*;
    use crate::runtime::native::NativeDims;

    fn header() -> CkptHeader {
        let dims = NativeDims { vocab: 8, seq: 4, n_layers: 1, d_model: 4, n_heads: 2, d_ff: 8, n_classes: 2 };
        CkptHeader { dims, bits: vec![8], act_scales: vec![[0.1; 4]] }
    }

    #[test]
    fn writer_rejects_bad_tensors() {
        let mut w = Writer::new(header()).unwrap();
        w.add_f32("a", &[2, 2], &[1.0, 2.0, 3.0, 4.0]).unwrap();
        assert!(matches!(
            w.add_f32("a", &[1], &[0.0]),
            Err(CkptError::BadDirectory(_))
        ));
        assert!(matches!(
            w.add_f32("b", &[3], &[0.0]),
            Err(CkptError::DimsMismatch(_))
        ));
        assert!(matches!(
            w.add_f32("", &[1], &[0.0]),
            Err(CkptError::BadDirectory(_))
        ));
        let long = "x".repeat(MAX_NAME_LEN + 1);
        assert!(matches!(
            w.add_f32(&long, &[1], &[0.0]),
            Err(CkptError::BadDirectory(_))
        ));
    }

    #[test]
    fn writer_rejects_bad_header() {
        let mut h = header();
        h.bits = vec![5];
        assert!(matches!(Writer::new(h), Err(CkptError::BadHeader(_))));
    }

    #[test]
    fn bytes_roundtrip_through_reader() {
        type Mk = fn(CkptHeader) -> Result<Writer, CkptError>;
        for mk in [Writer::new as Mk, Writer::v1 as Mk] {
            let mut w = mk(header()).unwrap();
            let a = vec![1.0f32, -2.5, 3.25, 0.0];
            let b = vec![9.0f32; 8];
            w.add_f32("a", &[2, 2], &a).unwrap();
            w.add_f32("b", &[8], &b).unwrap();
            assert_eq!(w.tensor_count(), 2);
            assert_eq!(w.payload_bytes(), 4 * (4 + 8));
            let ck = Checkpoint::from_bytes(w.to_bytes()).unwrap();
            assert_eq!(ck.header(), w.header());
            assert_eq!(ck.version(), w.version());
            let (dims_a, data_a) = ck.f32_tensor("a").unwrap();
            assert_eq!(dims_a, &[2, 2]);
            assert_eq!(data_a, a);
            let (dims_b, data_b) = ck.f32_tensor("b").unwrap();
            assert_eq!(dims_b, &[8]);
            assert_eq!(data_b, b);
            assert!(matches!(ck.f32_tensor("zzz"), Err(CkptError::MissingTensor(_))));
        }
    }

    #[test]
    fn v2_payload_is_aligned_and_header_crc_present() {
        let mut w = Writer::new(header()).unwrap();
        w.add_f32("a", &[3], &[1.0, 2.0, 3.0]).unwrap();
        let bytes = w.to_bytes();
        let ck = Checkpoint::from_bytes(bytes).unwrap();
        assert_eq!(ck.version(), VERSION);
        assert!(ck.header_crc().is_some());
        assert_eq!(ck.payload_file_offset(0) % PAYLOAD_ALIGN, 0);
    }

    #[test]
    fn packed_entries_roundtrip_and_v1_rejects_them() {
        use crate::quant;
        use crate::util::rng::Rng;
        let (k, n) = (4usize, 10usize);
        let mut rng = Rng::new(8);
        let codes = quant::random_codes(&mut rng, k * n, 4);
        let scales: Vec<f32> = (0..n).map(|i| 0.02 + i as f32 * 0.003).collect();
        let pw = PackedWeights::from_codes(&codes, k, n, scales.clone(), 4);

        let mut w1 = Writer::v1(header()).unwrap();
        assert!(matches!(w1.add_packed("w", &pw), Err(CkptError::BadDirectory(_))));

        let mut w = Writer::new(header()).unwrap();
        w.add_packed("w", &pw).unwrap();
        assert_eq!(w.tensor_count(), 2, "packed entry + scales sibling");
        let ck = Checkpoint::from_bytes(w.to_bytes()).unwrap();
        let e = ck.entries().iter().find(|e| e.name == "w").unwrap();
        assert_eq!(e.dtype, DTYPE_I4_PANELS);
        assert_eq!(e.layout, PANEL_LAYOUT);
        assert_eq!(e.dims, vec![k, n]);
        let bytes = ck.panel_bytes("w").unwrap();
        let back = PackedWeights::from_panels(4, k, n, scales, bytes).unwrap();
        assert_eq!(back.unpack_codes(), codes);
        let (sdims, sdata) = ck.f32_tensor("w.scales").unwrap();
        assert_eq!(sdims, &[n]);
        assert_eq!(&sdata[..], &back.scales[..]);
    }
}
