//! MKQC writer: stream tensors in, emit header + directory + payload +
//! trailing payload CRC-32 in one pass at [`Writer::write_to`].
//!
//! Tensor bytes are accumulated into the payload buffer (and the CRC) as
//! they are added, so each tensor is converted to little-endian exactly
//! once; the header and directory are serialized last, when every offset
//! is known. `write_to` writes to a `.tmp` sibling and renames, so a
//! crash mid-export never leaves a half-written checkpoint at the target
//! path. (Follow-on, see ROADMAP: spill the payload to disk instead of
//! RAM for checkpoints that approach memory size.)

use std::path::Path;

use crate::util::crc32::Crc32;

use super::{CkptError, CkptHeader, DTYPE_F32, MAGIC, MAX_NAME_LEN, MAX_RANK, VERSION};

pub(crate) struct DirEntry {
    pub name: String,
    pub dtype: u8,
    pub dims: Vec<usize>,
    pub offset: u64,
    pub len: u64,
}

/// Serializer for one checkpoint file. Add every tensor, then call
/// [`write_to`](Writer::write_to) (or [`to_bytes`](Writer::to_bytes)).
pub struct Writer {
    header: CkptHeader,
    entries: Vec<DirEntry>,
    payload: Vec<u8>,
    crc: Crc32,
}

impl Writer {
    /// Validates the header up front so a structurally broken checkpoint
    /// can never be produced.
    pub fn new(header: CkptHeader) -> Result<Self, CkptError> {
        header.validate()?;
        Ok(Writer { header, entries: Vec::new(), payload: Vec::new(), crc: Crc32::new() })
    }

    pub fn header(&self) -> &CkptHeader {
        &self.header
    }

    /// Append one fp32 tensor. Rejects duplicate names, over-long names,
    /// rank > [`MAX_RANK`] and dims/data length mismatches.
    pub fn add_f32(&mut self, name: &str, dims: &[usize], data: &[f32]) -> Result<(), CkptError> {
        if name.is_empty() || name.len() > MAX_NAME_LEN {
            return Err(CkptError::BadDirectory(format!(
                "tensor name {name:?} length {} out of range 1..={MAX_NAME_LEN}",
                name.len()
            )));
        }
        if self.entries.iter().any(|e| e.name == name) {
            return Err(CkptError::BadDirectory(format!("duplicate tensor name {name:?}")));
        }
        if dims.len() > MAX_RANK {
            return Err(CkptError::BadDirectory(format!(
                "{name}: rank {} exceeds {MAX_RANK}",
                dims.len()
            )));
        }
        let count: usize = dims.iter().product();
        if count != data.len() {
            return Err(CkptError::DimsMismatch(format!(
                "{name}: dims {dims:?} imply {count} elements, got {}",
                data.len()
            )));
        }
        let offset = self.payload.len() as u64;
        self.payload.reserve(data.len() * 4);
        for &v in data {
            let b = v.to_le_bytes();
            self.crc.update(&b);
            self.payload.extend_from_slice(&b);
        }
        self.entries.push(DirEntry {
            name: name.to_string(),
            dtype: DTYPE_F32,
            dims: dims.to_vec(),
            offset,
            len: (data.len() * 4) as u64,
        });
        Ok(())
    }

    pub fn tensor_count(&self) -> usize {
        self.entries.len()
    }

    pub fn payload_bytes(&self) -> usize {
        self.payload.len()
    }

    /// Serialize the whole checkpoint to a byte vector.
    pub fn to_bytes(&self) -> Vec<u8> {
        let d = &self.header.dims;
        let dir_len: usize =
            self.entries.iter().map(|e| 2 + e.name.len() + 1 + 1 + 4 * e.dims.len() + 16).sum();
        let header_len = 4 + 4 + 7 * 4 + 4 + 4 * d.n_layers + 16 * d.n_layers;
        let mut out = Vec::with_capacity(header_len + dir_len + self.payload.len() + 4);

        out.extend_from_slice(&MAGIC);
        out.extend_from_slice(&VERSION.to_le_bytes());
        for v in [d.vocab, d.seq, d.n_layers, d.d_model, d.n_heads, d.d_ff, d.n_classes] {
            out.extend_from_slice(&(v as u32).to_le_bytes());
        }
        out.extend_from_slice(&(self.entries.len() as u32).to_le_bytes());
        for &b in &self.header.bits {
            out.extend_from_slice(&b.to_le_bytes());
        }
        for row in &self.header.act_scales {
            for &s in row {
                out.extend_from_slice(&s.to_le_bytes());
            }
        }
        for e in &self.entries {
            out.extend_from_slice(&(e.name.len() as u16).to_le_bytes());
            out.extend_from_slice(e.name.as_bytes());
            out.push(e.dtype);
            out.push(e.dims.len() as u8);
            for &dim in &e.dims {
                out.extend_from_slice(&(dim as u32).to_le_bytes());
            }
            out.extend_from_slice(&e.offset.to_le_bytes());
            out.extend_from_slice(&e.len.to_le_bytes());
        }
        out.extend_from_slice(&self.payload);
        out.extend_from_slice(&self.crc.finish().to_le_bytes());
        out
    }

    /// Write atomically: serialize to `<path>.tmp`, then rename over the
    /// target. The suffix is appended to the full file name (not swapped
    /// for the extension) so concurrent exports to distinct targets never
    /// share a temp file.
    pub fn write_to(&self, path: &Path) -> Result<(), CkptError> {
        let bytes = self.to_bytes();
        let mut tmp_name = path.as_os_str().to_os_string();
        tmp_name.push(".tmp");
        let tmp = std::path::PathBuf::from(tmp_name);
        std::fs::write(&tmp, &bytes)?;
        std::fs::rename(&tmp, path)?;
        Ok(())
    }
}

#[cfg(test)]
mod tests {
    use super::super::Checkpoint;
    use super::*;
    use crate::runtime::native::NativeDims;

    fn header() -> CkptHeader {
        let dims = NativeDims { vocab: 8, seq: 4, n_layers: 1, d_model: 4, n_heads: 2, d_ff: 8, n_classes: 2 };
        CkptHeader { dims, bits: vec![8], act_scales: vec![[0.1; 4]] }
    }

    #[test]
    fn writer_rejects_bad_tensors() {
        let mut w = Writer::new(header()).unwrap();
        w.add_f32("a", &[2, 2], &[1.0, 2.0, 3.0, 4.0]).unwrap();
        assert!(matches!(
            w.add_f32("a", &[1], &[0.0]),
            Err(CkptError::BadDirectory(_))
        ));
        assert!(matches!(
            w.add_f32("b", &[3], &[0.0]),
            Err(CkptError::DimsMismatch(_))
        ));
        assert!(matches!(
            w.add_f32("", &[1], &[0.0]),
            Err(CkptError::BadDirectory(_))
        ));
        let long = "x".repeat(MAX_NAME_LEN + 1);
        assert!(matches!(
            w.add_f32(&long, &[1], &[0.0]),
            Err(CkptError::BadDirectory(_))
        ));
    }

    #[test]
    fn writer_rejects_bad_header() {
        let mut h = header();
        h.bits = vec![5];
        assert!(matches!(Writer::new(h), Err(CkptError::BadHeader(_))));
    }

    #[test]
    fn bytes_roundtrip_through_reader() {
        let mut w = Writer::new(header()).unwrap();
        let a = vec![1.0f32, -2.5, 3.25, 0.0];
        let b = vec![9.0f32; 8];
        w.add_f32("a", &[2, 2], &a).unwrap();
        w.add_f32("b", &[8], &b).unwrap();
        assert_eq!(w.tensor_count(), 2);
        assert_eq!(w.payload_bytes(), 4 * (4 + 8));
        let ck = Checkpoint::from_bytes(w.to_bytes()).unwrap();
        assert_eq!(ck.header(), w.header());
        let (dims_a, data_a) = ck.f32_tensor("a").unwrap();
        assert_eq!(dims_a, &[2, 2]);
        assert_eq!(data_a, a);
        let (dims_b, data_b) = ck.f32_tensor("b").unwrap();
        assert_eq!(dims_b, &[8]);
        assert_eq!(data_b, b);
        assert!(matches!(ck.f32_tensor("zzz"), Err(CkptError::MissingTensor(_))));
    }
}
