//! MKQC — the MKQ-BERT flat-tensor checkpoint format.
//!
//! This is the on-disk contract between training (Rust QAT trainer or the
//! Python compile path) and native serving: a QAT run exports one `.mkqc`
//! file; [`crate::runtime::NativeModel::from_checkpoint`] loads it and
//! prepacks the int4/int8 column panels at load time. Weights are stored
//! as **fp32 master tensors** (the trainer's output); quantization grids
//! are derived at load from the per-layer bit vector and the per-output-
//! channel abs-max, exactly as the in-memory constructors do — so a saved
//! and reloaded model produces bit-for-bit identical logits.
//!
//! # Byte-level layout (versions 1 and 2, all fields little-endian)
//!
//! | offset            | size          | field                                         |
//! |-------------------|---------------|-----------------------------------------------|
//! | 0                 | 4             | magic `"MKQC"`                                |
//! | 4                 | 4             | `u32` format version (1 or 2)                 |
//! | 8                 | 28            | `7 x u32` NativeDims: vocab, seq, n_layers, d_model, n_heads, d_ff, n_classes |
//! | 36                | 4             | `u32` n_tensors (directory entry count)       |
//! | 40                | 4·L           | `u32 x n_layers` per-layer bit vector (4/8/32)|
//! | 40+4L             | 16·L          | `f32 x 4 x n_layers` calibrated per-tensor activation scales (qkv_in, attn_out_in, ffn1_in, ffn2_in per layer) |
//! | —                 | variable      | tensor directory, n_tensors entries (below)   |
//! | —                 | 4 (v2 only)   | `u32` CRC-32 over bytes `[0, directory end)` — the header/directory CRC |
//! | —                 | 0–15 (v2 only)| zero padding so the payload starts 16-byte-aligned in the file (computed, not stored) |
//! | —                 | variable      | payload: raw tensor bytes, directory order    |
//! | end−4             | 4             | `u32` CRC-32 (zlib/IEEE) over the payload     |
//!
//! Directory entry (the `layout` byte exists only in v2):
//!
//! | size      | field                                              |
//! |-----------|----------------------------------------------------|
//! | 2         | `u16` name length (UTF-8 bytes, ≤ 256)             |
//! | name_len  | tensor name                                        |
//! | 1         | `u8` dtype (see below)                             |
//! | 1 (v2)    | `u8` panel-layout version (0 for f32 entries, [`PANEL_LAYOUT`] for packed entries) |
//! | 1         | `u8` rank (≤ 8)                                    |
//! | 4·rank    | `u32 x rank` dims (always the *logical* shape)     |
//! | 8         | `u64` byte offset from payload start               |
//! | 8         | `u64` byte length (dtype-dependent, see below)     |
//!
//! dtypes:
//!
//! * [`DTYPE_F32`] (0) — raw little-endian fp32, `len = 4·Π dims`. The
//!   only dtype version 1 allows.
//! * [`DTYPE_I8_PANELS`] (1) — prepacked int8 column panels in the
//!   kernel layout ([`crate::kernels::PackedWeights`]): rank must be 2
//!   (`dims = [k, n]`), `len = ceil(n/NR)·k·NR`.
//! * [`DTYPE_I4_PANELS`] (2) — prepacked nibble int4 panels: rank 2,
//!   `k` even, `len = ceil(n/NR)·(k/2)·NR`.
//!
//! A packed weight entry keeps the *master tensor's name* (`l0_wq` …)
//! and logical dims, so the model-spec check is dtype-agnostic; its
//! per-output-channel scales ride in a sibling f32 entry named
//! `{name}.scales` with dims `[n]`. Packed entries replace the fp32
//! masters (`mkq-bert ckpt migrate` converts v1 → v2), which is both the
//! storage win and what lets [`crate::runtime::NativeModel::from_checkpoint`]
//! skip quantize+pack at load. The panel-layout byte pins the kernel
//! geometry (`NR`/`MR`, K-major nibble order, `INT4_OFFSET` bias): a
//! reader whose kernels use a different layout rejects the entry instead
//! of silently serving garbage — re-run `ckpt migrate` to repack.
//!
//! Payload byte lengths are multiples of 4 for every dtype, so payload
//! offsets stay 4-byte aligned; v2 additionally pads the payload start
//! to a 16-byte *file* offset, which makes `&[f32]` views into an
//! mmap'd file properly aligned (see `reader::Checkpoint::f32_view`).
//!
//! The reader rejects bad magic/version, header inconsistencies,
//! truncated files, out-of-bounds or overlapping directory entries, size
//! mismatches and CRC failures with typed [`CkptError`]s. In v1 the CRC
//! covers the payload only, so corrupt tensor bytes always surface as
//! [`CkptError::BadCrc`] while a semantically-plausible header bit flip
//! (e.g. inside a stored activation scale) can pass the structural
//! checks; v2 closes that hole with the header/directory CRC
//! ([`CkptError::BadHeaderCrc`]), verified before semantic validation so
//! any header/directory flip is caught.
//!
//! # Sharded checkpoints
//!
//! A checkpoint may also be a *directory* containing a manifest file
//! ([`MANIFEST_NAME`]) plus N shard files. The manifest is line-based
//! text: the tag line [`MANIFEST_TAG`], then one shard file name per
//! line (relative to the directory, `#` comments and blank lines
//! ignored). Every shard is a complete v2 single-file checkpoint with a
//! bit-identical header; tensors are distributed across shards with no
//! duplicate names. A manifest naming a missing file fails typed
//! ([`CkptError::ShardMissing`]); mismatched shard headers fail
//! [`CkptError::BadHeader`]. `Checkpoint::read` on a directory path
//! loads and merges all shards transparently.
//!
//! # Tensor naming contract
//!
//! Names mirror `python/compile/model.py::param_specs` (the flat ordering
//! contract with the compile path): `emb_word`, `emb_pos`, `emb_ln_g`,
//! `emb_ln_b`, then per layer `l{i}_wq`, `l{i}_bq`, … `l{i}_ln2_b`
//! (see [`LAYER_TENSOR_SUFFIXES`]), then `pool_w`, `pool_b`, `cls_w`,
//! `cls_b`. [`param_specs`] generates the full expected (name, dims) list
//! from a [`NativeDims`]; directory order is not significant — lookup is
//! by name — but both writers emit spec order. `.scales` siblings are
//! supplementary entries outside the spec list.

pub mod reader;
pub mod writer;

pub use reader::Checkpoint;
pub use writer::Writer;

use crate::runtime::native::NativeDims;

/// File magic: the first four bytes of every checkpoint.
pub const MAGIC: [u8; 4] = *b"MKQC";
/// The original fp32-masters-only format.
pub const VERSION_V1: u32 = 1;
/// Current write version: prepacked panels, header/directory CRC,
/// aligned payload, shardable.
pub const VERSION: u32 = 2;
/// dtype byte for fp32 tensors (the only payload dtype in version 1).
pub const DTYPE_F32: u8 = 0;
/// dtype byte for prepacked int8 column panels (v2).
pub const DTYPE_I8_PANELS: u8 = 1;
/// dtype byte for prepacked nibble int4 column panels (v2).
pub const DTYPE_I4_PANELS: u8 = 2;
/// Panel-layout version the current kernels consume: K-major `NR = 8`
/// column panels, int4 as two K-consecutive offset nibbles per byte
/// (`code + INT4_OFFSET`, even K in the low nibble). Bump when the pack
/// geometry changes (e.g. the ROADMAP NR=16 revision).
pub const PANEL_LAYOUT: u8 = 1;
/// Payload start alignment (file offset) in v2.
pub const PAYLOAD_ALIGN: usize = 16;
/// Manifest file name marking a directory as a sharded checkpoint.
pub const MANIFEST_NAME: &str = "manifest.mkqs";
/// First line of a shard manifest.
pub const MANIFEST_TAG: &str = "MKQS1";

/// Hard caps the reader enforces before trusting any length field.
pub const MAX_NAME_LEN: usize = 256;
pub const MAX_RANK: usize = 8;
pub const MAX_LAYERS: usize = 4096;
pub const MAX_TENSORS: usize = 1 << 20;

/// Per-layer tensor-name suffixes in spec order (full name: `l{i}_wq` …).
pub const LAYER_TENSOR_SUFFIXES: [&str; 16] = [
    "wq", "bq", "wk", "bk", "wv", "bv", "wo", "bo", "ln1_g", "ln1_b", "w1", "b1", "w2", "b2",
    "ln2_g", "ln2_b",
];

/// Typed checkpoint errors — every reader rejection is one of these, so
/// callers (and the corrupt-input tests) can match on the failure mode.
#[derive(Debug)]
pub enum CkptError {
    Io(std::io::Error),
    /// First four bytes are not `"MKQC"`.
    BadMagic { got: [u8; 4] },
    /// Unknown format version.
    BadVersion { got: u32 },
    /// The file ends before a required field/section.
    Truncated { what: &'static str, need: usize, have: usize },
    /// Header fields are structurally invalid (bit widths, zero dims, …).
    BadHeader(String),
    /// A directory entry is malformed (name/rank/dtype/size bounds).
    BadDirectory(String),
    /// Two directory entries claim overlapping payload ranges.
    Overlap { a: String, b: String },
    /// Payload CRC-32 does not match the stored trailer.
    BadCrc { stored: u32, computed: u32 },
    /// v2 header/directory CRC-32 does not match the stored field.
    BadHeaderCrc { stored: u32, computed: u32 },
    /// A shard manifest references a file that does not exist.
    ShardMissing { manifest: String, shard: String },
    /// A tensor exists but its shape contradicts the header dims.
    DimsMismatch(String),
    /// A tensor required by the model spec is absent.
    MissingTensor(String),
}

impl std::fmt::Display for CkptError {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        match self {
            CkptError::Io(e) => write!(f, "checkpoint i/o error: {e}"),
            CkptError::BadMagic { got } => {
                write!(f, "bad checkpoint magic {:02x?} (want \"MKQC\")", got)
            }
            CkptError::BadVersion { got } => {
                write!(
                    f,
                    "unsupported checkpoint version {got} (reader supports {VERSION_V1}..={VERSION})"
                )
            }
            CkptError::Truncated { what, need, have } => {
                write!(f, "truncated checkpoint: {what} needs {need} bytes, {have} available")
            }
            CkptError::BadHeader(m) => write!(f, "bad checkpoint header: {m}"),
            CkptError::BadDirectory(m) => write!(f, "bad checkpoint directory: {m}"),
            CkptError::Overlap { a, b } => {
                write!(f, "overlapping checkpoint directory entries: {a:?} and {b:?}")
            }
            CkptError::BadCrc { stored, computed } => write!(
                f,
                "checkpoint payload CRC mismatch: stored {stored:#010x}, computed {computed:#010x}"
            ),
            CkptError::BadHeaderCrc { stored, computed } => write!(
                f,
                "checkpoint header/directory CRC mismatch: stored {stored:#010x}, computed {computed:#010x}"
            ),
            CkptError::ShardMissing { manifest, shard } => {
                write!(f, "shard manifest {manifest} references missing shard file {shard:?}")
            }
            CkptError::DimsMismatch(m) => write!(f, "checkpoint dims mismatch: {m}"),
            CkptError::MissingTensor(n) => write!(f, "checkpoint is missing tensor {n:?}"),
        }
    }
}

impl std::error::Error for CkptError {
    fn source(&self) -> Option<&(dyn std::error::Error + 'static)> {
        match self {
            CkptError::Io(e) => Some(e),
            _ => None,
        }
    }
}

impl From<std::io::Error> for CkptError {
    fn from(e: std::io::Error) -> Self {
        CkptError::Io(e)
    }
}

/// The fixed header: model dims, per-layer bits, calibrated activation
/// scales. Everything [`crate::runtime::NativeModel::from_checkpoint`]
/// needs besides the tensors themselves.
#[derive(Debug, Clone, PartialEq)]
pub struct CkptHeader {
    pub dims: NativeDims,
    /// Per-layer bit widths (4, 8 or 32), length `dims.n_layers`.
    pub bits: Vec<u32>,
    /// Per-layer calibrated per-tensor activation scales, length
    /// `dims.n_layers`: qkv_in, attn_out_in, ffn1_in, ffn2_in.
    pub act_scales: Vec<[f32; 4]>,
}

impl CkptHeader {
    /// Structural validation shared by writer and reader.
    pub fn validate(&self) -> Result<(), CkptError> {
        let d = &self.dims;
        let bad = |m: String| Err(CkptError::BadHeader(m));
        if d.n_layers == 0 || d.n_layers > MAX_LAYERS {
            return bad(format!("n_layers {} out of range 1..={MAX_LAYERS}", d.n_layers));
        }
        for (name, v) in [
            ("vocab", d.vocab),
            ("seq", d.seq),
            ("d_model", d.d_model),
            ("n_heads", d.n_heads),
            ("d_ff", d.d_ff),
            ("n_classes", d.n_classes),
        ] {
            if v == 0 {
                return bad(format!("{name} is zero"));
            }
        }
        if d.d_model % d.n_heads != 0 {
            return bad(format!("n_heads {} does not divide d_model {}", d.n_heads, d.d_model));
        }
        if self.bits.len() != d.n_layers {
            return bad(format!("bit vector has {} entries, n_layers {}", self.bits.len(), d.n_layers));
        }
        if self.act_scales.len() != d.n_layers {
            return bad(format!(
                "act-scale table has {} rows, n_layers {}",
                self.act_scales.len(),
                d.n_layers
            ));
        }
        for (l, &b) in self.bits.iter().enumerate() {
            if !matches!(b, 4 | 8 | 32) {
                return bad(format!("layer {l}: unsupported bit width {b} (use 4, 8 or 32)"));
            }
            // int4 panels nibble-pack along K — both GEMM K dims must be even.
            if b == 4 && (d.d_model % 2 != 0 || d.d_ff % 2 != 0) {
                return bad(format!(
                    "layer {l} is int4 but d_model {} / d_ff {} are not both even (K-nibble packing)",
                    d.d_model, d.d_ff
                ));
            }
        }
        for (l, s) in self.act_scales.iter().enumerate() {
            if self.bits[l] != 32 && s.iter().any(|&v| !(v.is_finite() && v > 0.0)) {
                return bad(format!("layer {l}: activation scales {s:?} must be finite and positive"));
            }
        }
        Ok(())
    }
}

/// The full expected tensor list (name, dims) for a model of the given
/// dims, in the canonical spec order — mirrors
/// `python/compile/model.py::param_specs` exactly.
pub fn param_specs(d: &NativeDims) -> Vec<(String, Vec<usize>)> {
    let (dm, dff) = (d.d_model, d.d_ff);
    let mut specs: Vec<(String, Vec<usize>)> = vec![
        ("emb_word".into(), vec![d.vocab, dm]),
        ("emb_pos".into(), vec![d.seq, dm]),
        ("emb_ln_g".into(), vec![dm]),
        ("emb_ln_b".into(), vec![dm]),
    ];
    for l in 0..d.n_layers {
        for suffix in LAYER_TENSOR_SUFFIXES {
            let dims = match suffix {
                "wq" | "wk" | "wv" | "wo" => vec![dm, dm],
                "w1" => vec![dm, dff],
                "w2" => vec![dff, dm],
                "b1" => vec![dff],
                _ => vec![dm], // biases and LN params
            };
            specs.push((format!("l{l}_{suffix}"), dims));
        }
    }
    specs.push(("pool_w".into(), vec![dm, dm]));
    specs.push(("pool_b".into(), vec![dm]));
    specs.push(("cls_w".into(), vec![dm, d.n_classes]));
    specs.push(("cls_b".into(), vec![d.n_classes]));
    specs
}

/// Write a full model checkpoint from named tensors (spec naming). The
/// tensor list does not have to be in spec order, but every spec tensor
/// must be present with matching dims — this is the same contract the
/// reader-side model constructor enforces, applied at write time so a
/// broken checkpoint is never produced.
pub fn write_model_checkpoint(
    path: &std::path::Path,
    header: &CkptHeader,
    tensors: &[(String, Vec<usize>, Vec<f32>)],
) -> Result<(), CkptError> {
    write_model_checkpoint_with(path, header, tensors, VERSION)
}

/// [`write_model_checkpoint`] at an explicit format version — v1 exists
/// for the migration tests and the `export-random --format 1` CI path
/// (both formats store fp32 masters here; `ckpt migrate` is what
/// produces prepacked-panel payloads).
pub fn write_model_checkpoint_with(
    path: &std::path::Path,
    header: &CkptHeader,
    tensors: &[(String, Vec<usize>, Vec<f32>)],
    version: u32,
) -> Result<(), CkptError> {
    if version != VERSION_V1 && version != VERSION {
        return Err(CkptError::BadVersion { got: version });
    }
    let mut w =
        if version == VERSION_V1 { Writer::v1(header.clone())? } else { Writer::new(header.clone())? };
    for (name, dims, data) in tensors {
        w.add_f32(name, dims, data)?;
    }
    for (name, dims) in param_specs(&header.dims) {
        match tensors.iter().find(|(n, _, _)| *n == name) {
            None => return Err(CkptError::MissingTensor(name)),
            Some((_, got, _)) if *got != dims => {
                return Err(CkptError::DimsMismatch(format!("{name}: {got:?} != spec {dims:?}")))
            }
            Some(_) => {}
        }
    }
    w.write_to(path)
}

/// Export a random-init model checkpoint — the demo/CI path: the same
/// tensors [`crate::runtime::NativeModel::random`] builds from, so
/// loading the file reproduces that model bit-for-bit.
pub fn export_random(
    path: &std::path::Path,
    dims: NativeDims,
    bits: &[u32],
    seed: u64,
) -> Result<(), CkptError> {
    export_random_with(path, dims, bits, seed, VERSION)
}

/// [`export_random`] at an explicit format version (1 or 2).
pub fn export_random_with(
    path: &std::path::Path,
    dims: NativeDims,
    bits: &[u32],
    seed: u64,
    version: u32,
) -> Result<(), CkptError> {
    use crate::runtime::native;
    let header = CkptHeader {
        dims,
        bits: bits.to_vec(),
        act_scales: native::default_act_scales(bits),
    };
    let tensors = native::random_model_tensors(&dims, seed);
    write_model_checkpoint_with(path, &header, &tensors, version)
}

#[cfg(test)]
mod tests {
    use super::*;

    fn tiny_header() -> CkptHeader {
        let dims = NativeDims { vocab: 16, seq: 4, n_layers: 2, d_model: 8, n_heads: 2, d_ff: 16, n_classes: 2 };
        CkptHeader { dims, bits: vec![8, 4], act_scales: vec![[0.05; 4], [0.75; 4]] }
    }

    #[test]
    fn header_validation_accepts_and_rejects() {
        let h = tiny_header();
        assert!(h.validate().is_ok());

        let mut bad = h.clone();
        bad.bits = vec![8, 3];
        assert!(matches!(bad.validate(), Err(CkptError::BadHeader(_))));

        let mut bad = h.clone();
        bad.bits = vec![8];
        assert!(matches!(bad.validate(), Err(CkptError::BadHeader(_))));

        let mut bad = h.clone();
        bad.dims.n_heads = 3; // does not divide d_model=8
        assert!(matches!(bad.validate(), Err(CkptError::BadHeader(_))));

        let mut bad = h.clone();
        bad.act_scales[1] = [f32::NAN; 4];
        assert!(matches!(bad.validate(), Err(CkptError::BadHeader(_))));

        // fp32 layers may carry any scale value (it is ignored at 32 bits)
        let mut ok = h.clone();
        ok.bits = vec![32, 4];
        ok.act_scales[0] = [0.0; 4];
        assert!(ok.validate().is_ok());
    }

    #[test]
    fn param_specs_cover_model() {
        let h = tiny_header();
        let specs = param_specs(&h.dims);
        // 4 embedding + 16 per layer + 4 head tensors
        assert_eq!(specs.len(), 4 + 16 * h.dims.n_layers + 4);
        assert_eq!(specs[0].0, "emb_word");
        assert_eq!(specs[0].1, vec![16, 8]);
        assert_eq!(specs[4].0, "l0_wq");
        assert!(specs.iter().any(|(n, d)| n == "l1_w2" && *d == vec![16, 8]));
        assert_eq!(specs.last().unwrap().0, "cls_b");
        // names unique
        let mut names: Vec<&str> = specs.iter().map(|(n, _)| n.as_str()).collect();
        names.sort_unstable();
        names.dedup();
        assert_eq!(names.len(), specs.len());
    }
}
