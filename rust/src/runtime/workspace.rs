//! Reusable scratch arena for the native forward path.
//!
//! One [`Workspace`] holds every intermediate buffer an encoder forward
//! needs — hidden-state ping-pong, q/k/v projections, per-head attention
//! scratch (`qh`/`kt`/`vh`, probs, context), the FFN intermediate,
//! quantized-activation staging (`qx`/`rs`/`sx`), and the pooler/logits
//! tail — sized lazily by [`Workspace::ensure_layer`] /
//! [`Workspace::ensure_model`] and only ever *grown*. After the first
//! forward at a given shape, the steady-state hot path performs **zero
//! heap allocation**: buffers are reused across batches and across
//! `Server::pump` calls (`rust/tests/workspace_alloc.rs` enforces this
//! with a counting global allocator).
//!
//! The arena is deliberately dumb — plain `Vec`s plus two reusable
//! [`PackedF32`] slots for the per-`(batch, head)` attention packs — so
//! borrow-splitting stays trivial: callers slice disjoint fields
//! (`&ws.qx[..]` next to `&mut ws.q[..]`) and the compiler proves
//! disjointness field-by-field.

use crate::kernels::PackedF32;

/// Grow-only buffer resize: never shrinks, never reallocates once the
/// high-water shape has been seen.
fn grow<T: Copy + Default>(v: &mut Vec<T>, len: usize) {
    if v.len() < len {
        v.resize(len, T::default());
    }
}

/// Scratch arena for [`crate::runtime::NativeModel::forward_ws`] and
/// [`crate::runtime::NativeLayer::forward_ws`]. See the module docs.
#[derive(Default)]
pub struct Workspace {
    /// Hidden-state ping/pong (`bsz*t*d` each); taken out via
    /// `std::mem::take` during a model forward and restored after.
    pub(crate) h_a: Vec<f32>,
    pub(crate) h_b: Vec<f32>,
    /// q/k/v projections, `bsz*t*d` each.
    pub(crate) q: Vec<f32>,
    pub(crate) k: Vec<f32>,
    pub(crate) v: Vec<f32>,
    /// Attention context output, `bsz*t*d`.
    pub(crate) attn: Vec<f32>,
    /// Projection output staging (`wo` / `w2`), `bsz*t*d`.
    pub(crate) proj: Vec<f32>,
    /// FFN intermediate, `bsz*t*d_ff`.
    pub(crate) ffn: Vec<f32>,
    /// Per-head gathers: Q head `(t, dk)`, K head transposed `(dk, t)`,
    /// V head `(t, dk)`.
    pub(crate) qh: Vec<f32>,
    pub(crate) kt: Vec<f32>,
    pub(crate) vh: Vec<f32>,
    /// Attention probabilities `(t, t)` and per-head context `(t, dk)`.
    pub(crate) probs: Vec<f32>,
    pub(crate) oh: Vec<f32>,
    /// Reusable packs for the score/apply GEMM weights (K head, V head).
    pub(crate) pk: PackedF32,
    pub(crate) pv: PackedF32,
    /// Quantized-activation staging: codes `(m, max(d, d_ff))`, row sums
    /// and per-token scales `(m,)`.
    pub(crate) qx: Vec<i16>,
    pub(crate) rs: Vec<i32>,
    pub(crate) sx: Vec<f32>,
    /// Pooler/classifier tail: first-token gather and pooled `(bsz, d)`,
    /// logits `(bsz, n_classes)`.
    pub(crate) first: Vec<f32>,
    pub(crate) pooled: Vec<f32>,
    pub(crate) logits: Vec<f32>,
}

impl Workspace {
    pub fn new() -> Self {
        Self::default()
    }

    /// Grow every buffer a single encoder-layer forward touches for a
    /// `(bsz, t)` batch at width `d` / FFN width `dff` / `heads` heads.
    pub(crate) fn ensure_layer(&mut self, d: usize, dff: usize, heads: usize, bsz: usize, t: usize) {
        let m = bsz * t;
        let dk = d / heads;
        grow(&mut self.q, m * d);
        grow(&mut self.k, m * d);
        grow(&mut self.v, m * d);
        grow(&mut self.attn, m * d);
        grow(&mut self.proj, m * d);
        grow(&mut self.ffn, m * dff);
        grow(&mut self.qh, t * dk);
        grow(&mut self.kt, dk * t);
        grow(&mut self.vh, t * dk);
        grow(&mut self.probs, t * t);
        grow(&mut self.oh, t * dk);
        grow(&mut self.qx, m * d.max(dff));
        grow(&mut self.rs, m);
        grow(&mut self.sx, m);
    }

    /// [`Self::ensure_layer`] plus the model-level buffers (hidden-state
    /// ping-pong and the pooler/classifier tail).
    pub(crate) fn ensure_model(
        &mut self,
        d: usize,
        dff: usize,
        heads: usize,
        n_classes: usize,
        bsz: usize,
        t: usize,
    ) {
        self.ensure_layer(d, dff, heads, bsz, t);
        let m = bsz * t;
        grow(&mut self.h_a, m * d);
        grow(&mut self.h_b, m * d);
        grow(&mut self.first, bsz * d);
        grow(&mut self.pooled, bsz * d);
        grow(&mut self.logits, bsz * n_classes);
    }
}
