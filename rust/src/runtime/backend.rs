//! The execution-backend seam: one trait, two engines.
//!
//! [`Backend`] is what the serving coordinator and every benchmark binary
//! program against. Two implementations exist:
//!
//!   * [`NativeBackend`] — the pure-Rust path: prepacked quantized
//!     weights + the [`crate::kernels`] GEMM dispatcher. Always
//!     available; this is what tier-1 CI exercises.
//!   * [`ArtifactBackend`] (feature `xla`) — the AOT-artifact path:
//!     HLO-text executables on the PJRT engine, exactly as before.
//!
//! Benches construct both (artifact only when artifacts are present) and
//! report them side by side, which is how the native-vs-XLA speedup
//! numbers in `BENCH_kernels.json` are produced.

use std::cell::RefCell;
use std::sync::Arc;

use anyhow::{bail, Result};

use crate::coordinator::faults::{FaultPlan, Faults, SampledFault};
use crate::kernels::Dispatcher;
use crate::modelstore::{LoadStats, ModelVersion};

use super::native::{NativeLayer, NativeModel};
use super::workspace::Workspace;

/// Everything an execution worker needs to run one batch off the
/// front-door thread: the `Arc`-pinned model version (in-flight batches
/// hold the handle across reload/evict, exactly like the inline path)
/// plus any fault sampled off the backend's shared counter at dispatch
/// time, so fault ordering stays deterministic in dispatch order
/// regardless of worker count.
pub struct DispatchHandle {
    pub version: Arc<ModelVersion>,
    pub fault: Option<SampledFault>,
}

/// Serving-facing model dimensions.
#[derive(Debug, Clone, Copy)]
pub struct ServeDims {
    pub vocab: usize,
    pub seq: usize,
    pub n_classes: usize,
}

/// Layer precisions benchmarked side by side (Table 2's rows).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Precision {
    F32,
    Int8,
    Int4,
}

impl Precision {
    pub const ALL: [Precision; 3] = [Precision::F32, Precision::Int8, Precision::Int4];

    pub fn bits(self) -> u32 {
        match self {
            Precision::F32 => 32,
            Precision::Int8 => 8,
            Precision::Int4 => 4,
        }
    }

    pub fn name(self) -> &'static str {
        match self {
            Precision::F32 => "f32",
            Precision::Int8 => "int8",
            Precision::Int4 => "int4",
        }
    }
}

/// Per-model health, driven by consecutive forward-failure counts (see
/// [`Registry`](crate::modelstore::Registry)): `Serving` models admit
/// normally, `Degraded` models admit but are flagged in status surfaces,
/// `Quarantined`/`Evicted` models shed every request with a typed reject
/// while sibling models keep serving.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum ModelHealth {
    Loading,
    Serving,
    Degraded,
    Quarantined,
    Evicted,
}

impl ModelHealth {
    pub fn name(self) -> &'static str {
        match self {
            ModelHealth::Loading => "loading",
            ModelHealth::Serving => "serving",
            ModelHealth::Degraded => "degraded",
            ModelHealth::Quarantined => "quarantined",
            ModelHealth::Evicted => "evicted",
        }
    }

    pub fn as_u8(self) -> u8 {
        match self {
            ModelHealth::Loading => 0,
            ModelHealth::Serving => 1,
            ModelHealth::Degraded => 2,
            ModelHealth::Quarantined => 3,
            ModelHealth::Evicted => 4,
        }
    }

    pub fn from_u8(v: u8) -> Option<Self> {
        Some(match v {
            0 => ModelHealth::Loading,
            1 => ModelHealth::Serving,
            2 => ModelHealth::Degraded,
            3 => ModelHealth::Quarantined,
            4 => ModelHealth::Evicted,
            _ => return None,
        })
    }
}

/// One model's lifecycle snapshot, as surfaced over `INFO_RESP`/ADMIN
/// `STATUS` and in [`ServerSummary`](crate::coordinator::ServerSummary).
#[derive(Debug, Clone, Copy)]
pub struct ModelStatus {
    /// Monotonic per-slot version, bumped on every (re)load.
    pub version: u64,
    pub health: ModelHealth,
    /// Consecutive forward failures since the last success.
    pub consec_failures: u32,
    /// Bytes an eviction of this model would free (zero-copy accounting).
    pub resident_bytes: usize,
}

/// Execution backend behind the serving coordinator and benches.
///
/// A backend hosts one model by default; multi-model backends (the
/// model-store [`Registry`](crate::modelstore::Registry)) report
/// `n_models() > 1` and route through the `*_for` variants, which take a
/// model index `0..n_models()`. The index-free methods are the
/// single-model surface every existing backend keeps implementing — the
/// defaulted `*_for` twins delegate to them at index 0 and reject any
/// other index, so single-model backends need no changes.
pub trait Backend {
    fn name(&self) -> String;

    /// How many models this backend can route to (1 unless overridden).
    fn n_models(&self) -> usize {
        1
    }

    /// Display label for one model (the registry's registered name).
    fn model_label(&self, model: usize) -> String {
        let _ = model;
        self.name()
    }

    /// Serving dims; `Err` when no serving model is configured.
    fn serve_dims(&self) -> Result<ServeDims>;

    /// Per-model serving dims.
    fn serve_dims_for(&self, model: usize) -> Result<ServeDims> {
        self.only_model(model)?;
        self.serve_dims()
    }

    /// Fail fast if a batch bucket cannot be served (missing artifact /
    /// no model).
    fn check_bucket(&self, bucket: usize) -> Result<()>;

    fn check_bucket_for(&self, model: usize, bucket: usize) -> Result<()> {
        self.only_model(model)?;
        self.check_bucket(bucket)
    }

    /// Fail fast if a sequence-length bucket cannot be served. The
    /// default accepts only the full model `seq` — the fixed-shape
    /// contract of AOT backends; shape-generic backends override.
    fn check_seq_bucket(&self, t: usize) -> Result<()> {
        let dims = self.serve_dims()?;
        if t == dims.seq {
            Ok(())
        } else {
            bail!("backend serves fixed seq={} only (got seq bucket {t})", dims.seq)
        }
    }

    fn check_seq_bucket_for(&self, model: usize, t: usize) -> Result<()> {
        self.only_model(model)?;
        self.check_seq_bucket(t)
    }

    /// Forward a `(bucket, t)` batch to `(bucket, n_classes)` logits.
    /// `t` is the batch's token length — the seq bucket the dynamic
    /// batcher padded to, not necessarily the model's full `seq`;
    /// backends that validated the bucket via
    /// [`Backend::check_seq_bucket`] receive only values they accepted.
    fn serve_forward(&self, bucket: usize, t: usize, ids: &[i32], mask: &[f32]) -> Result<Vec<f32>>;

    /// Per-model [`Backend::serve_forward`] — what the multi-model
    /// server routes through.
    fn serve_forward_for(
        &self,
        model: usize,
        bucket: usize,
        t: usize,
        ids: &[i32],
        mask: &[f32],
    ) -> Result<Vec<f32>> {
        self.only_model(model)?;
        self.serve_forward(bucket, t, ids, mask)
    }

    /// One model's lifecycle snapshot. The default reports a permanently
    /// healthy version-1 model — right for backends without a lifecycle
    /// (a fixed in-memory model is never reloaded or evicted).
    fn model_status(&self, model: usize) -> Result<ModelStatus> {
        self.serve_dims_for(model)?;
        Ok(ModelStatus {
            version: 1,
            health: ModelHealth::Serving,
            consec_failures: 0,
            resident_bytes: 0,
        })
    }

    /// Atomically replace one model with a fresh load from its source,
    /// returning `(old_version, new_version)`. Callers must drain
    /// in-flight batches first (the server does) so nothing straddles
    /// the swap.
    fn reload_model(&self, model: usize) -> Result<(u64, u64)> {
        let _ = model;
        bail!("backend {} does not support model reload", self.name())
    }

    /// Drop one model's weights, returning `(version, freed_bytes)`.
    /// Subsequent requests for it shed with a typed reject until a
    /// reload brings it back.
    fn evict_model(&self, model: usize) -> Result<(u64, usize)> {
        let _ = model;
        bail!("backend {} does not support model eviction", self.name())
    }

    /// Observe a forward *panic* (caught by the server's isolation
    /// boundary, so the backend's own failure accounting never sees it
    /// return). Lifecycle backends count it like a forward error.
    fn record_forward_panic(&self, model: usize) {
        let _ = model;
    }

    /// Can batches be handed to execution workers via
    /// [`Backend::dispatch_handle`]? The default is inline-only — the
    /// fixed-shape artifact backend and model-less benches stay on the
    /// single-threaded path unchanged.
    fn supports_offthread(&self) -> bool {
        false
    }

    /// Pin one batch's execution state at dispatch time: `None` when
    /// off-thread execution is unsupported (caller falls back inline),
    /// `Some(Err(_))` when the model cannot serve right now (the batch
    /// fails typed without executing), `Some(Ok(_))` with the `Arc`'d
    /// version handle + sampled fault otherwise.
    fn dispatch_handle(&self, model: usize) -> Option<Result<DispatchHandle>> {
        let _ = model;
        None
    }

    /// A fresh dispatcher making identical kernel selections to the
    /// backend's own, for one execution worker to own (see
    /// [`Dispatcher::replicate`]).
    fn worker_dispatcher(&self) -> Option<Dispatcher> {
        None
    }

    /// Health bookkeeping for a batch that executed off-thread — the
    /// mirror of the success/failure accounting the inline
    /// `serve_forward_for` does internally. Panics are reported through
    /// [`Backend::record_forward_panic`] instead, never here.
    fn record_offthread_outcome(&self, model: usize, ok: bool) {
        let _ = (model, ok);
    }

    /// Guard for the defaulted `*_for` delegations.
    #[doc(hidden)]
    fn only_model(&self, model: usize) -> Result<()> {
        if model == 0 {
            Ok(())
        } else {
            bail!("backend {} hosts a single model (got model index {model})", self.name())
        }
    }

    /// One BERT-base encoder layer at the given precision over `(bsz*t, d)`
    /// hidden states (the Table-2 per-layer benchmark surface).
    fn layer_forward(
        &self,
        prec: Precision,
        bsz: usize,
        t: usize,
        h: &[f32],
        mask: &[f32],
    ) -> Result<Vec<f32>>;
}

/// The one native serve-forward body — request validation + workspace
/// forward — shared by [`NativeBackend`] and the model-store
/// [`Registry`](crate::modelstore::Registry), so the two serve paths
/// cannot drift apart on preconditions. `label` names the model in
/// error messages.
#[allow(clippy::too_many_arguments)]
pub(crate) fn native_serve_forward(
    label: &str,
    model: &NativeModel,
    disp: &Dispatcher,
    ws: &mut Workspace,
    bucket: usize,
    t: usize,
    ids: &[i32],
    mask: &[f32],
) -> Result<Vec<f32>> {
    if t < 1 || t > model.dims.seq {
        bail!("token length {t} out of range 1..={} for {label}", model.dims.seq);
    }
    let vocab = model.dims.vocab;
    if let Some(&bad) = ids.iter().find(|&&id| id < 0 || id as usize >= vocab) {
        bail!("token id {bad} out of range for {label} vocab {vocab}");
    }
    // The copy-out is the one remaining per-batch allocation (bucket *
    // n_classes floats); the forward itself is allocation-free at a
    // steady shape.
    Ok(model.forward_ws(disp, ws, ids, mask, bucket, t).to_vec())
}

/// Pure-Rust backend over the native kernels.
pub struct NativeBackend {
    pub disp: Dispatcher,
    bench_layers: Option<Box<[NativeLayer; 3]>>,
    /// `Arc`-held so execution workers can pin the model at dispatch
    /// time ([`Backend::dispatch_handle`]) exactly like the registry's
    /// versioned slots; a single-model backend is simply version 1
    /// forever.
    model: Option<Arc<ModelVersion>>,
    /// Reusable forward scratch: grown to the largest shape seen, then
    /// zero steady-state allocation across `serve_forward`/`layer_forward`
    /// calls. `RefCell` because the `Backend` trait takes `&self` and the
    /// serving event loop is single-threaded by design.
    ws: RefCell<Workspace>,
    /// Fault-injection hook (`MKQ_FAULT_*` env or [`NativeBackend::set_faults`]);
    /// inert by default.
    faults: Faults,
}

impl Default for NativeBackend {
    fn default() -> Self {
        Self::new()
    }
}

impl NativeBackend {
    pub fn new() -> Self {
        NativeBackend {
            disp: Dispatcher::new(),
            bench_layers: None,
            model: None,
            ws: RefCell::new(Workspace::new()),
            faults: Faults::from_env(),
        }
    }

    /// Model-load entry point: installs the model and runs the one-shot
    /// dispatcher autotune (skippable with `MKQ_AUTOTUNE=0`; a no-op under
    /// a forced `MKQ_KERNEL`). Selection only changes latency — every
    /// kernel variant is bit-for-bit identical.
    pub fn with_model(model: NativeModel) -> Self {
        let mut b = Self::new();
        b.set_model(model);
        b.autotune();
        b
    }

    /// Re-run the load-time kernel autotune (see
    /// [`Dispatcher::autotune`](crate::kernels::Dispatcher::autotune)).
    pub fn autotune(&mut self) {
        self.disp.autotune();
    }

    pub fn set_model(&mut self, model: NativeModel) {
        self.model =
            Some(Arc::new(ModelVersion { version: 1, model, stats: LoadStats::default() }));
    }

    pub fn model(&self) -> Option<&NativeModel> {
        self.model.as_ref().map(|v| &v.model)
    }

    /// Arm (or disarm, with an inert plan) fault injection on this
    /// backend instance — chaos tests use this instead of the env so
    /// parallel test threads never share fault state.
    pub fn set_faults(&mut self, plan: FaultPlan) {
        self.faults = Faults::with_plan(plan);
    }

    /// Install the three bench layers (f32 / int8 / int4 over the same
    /// fp32 weights) — see `bench_support::native_bench_layers`.
    pub fn set_bench_layers(&mut self, f32_layer: NativeLayer, i8_layer: NativeLayer, i4_layer: NativeLayer) {
        assert_eq!(f32_layer.bits, 32);
        assert_eq!(i8_layer.bits, 8);
        assert_eq!(i4_layer.bits, 4);
        self.bench_layers = Some(Box::new([f32_layer, i8_layer, i4_layer]));
    }
}

impl Backend for NativeBackend {
    fn name(&self) -> String {
        format!("native(threads={})", self.disp.threads())
    }

    fn serve_dims(&self) -> Result<ServeDims> {
        match &self.model {
            Some(v) => Ok(ServeDims {
                vocab: v.model.dims.vocab,
                seq: v.model.dims.seq,
                n_classes: v.model.dims.n_classes,
            }),
            None => bail!("native backend has no serving model configured"),
        }
    }

    fn check_bucket(&self, bucket: usize) -> Result<()> {
        if self.model.is_none() {
            bail!("native backend has no serving model configured");
        }
        if bucket == 0 {
            bail!("bucket size 0");
        }
        Ok(())
    }

    fn check_seq_bucket(&self, t: usize) -> Result<()> {
        let dims = self.serve_dims()?;
        if t >= 1 && t <= dims.seq {
            Ok(())
        } else {
            bail!("seq bucket {t} out of range 1..={}", dims.seq)
        }
    }

    fn serve_forward(&self, bucket: usize, t: usize, ids: &[i32], mask: &[f32]) -> Result<Vec<f32>> {
        match &self.model {
            Some(v) => {
                self.faults.before_forward()?;
                let mut ws = self.ws.borrow_mut();
                native_serve_forward(
                    "the native backend",
                    &v.model,
                    &self.disp,
                    &mut ws,
                    bucket,
                    t,
                    ids,
                    mask,
                )
            }
            None => bail!("native backend has no serving model configured"),
        }
    }

    fn supports_offthread(&self) -> bool {
        self.model.is_some()
    }

    fn dispatch_handle(&self, model: usize) -> Option<Result<DispatchHandle>> {
        if let Err(e) = self.only_model(model) {
            return Some(Err(e));
        }
        self.model.as_ref().map(|v| {
            Ok(DispatchHandle { version: Arc::clone(v), fault: self.faults.sample_forward() })
        })
    }

    fn worker_dispatcher(&self) -> Option<Dispatcher> {
        Some(self.disp.replicate())
    }

    fn layer_forward(
        &self,
        prec: Precision,
        bsz: usize,
        t: usize,
        h: &[f32],
        mask: &[f32],
    ) -> Result<Vec<f32>> {
        let layers = match &self.bench_layers {
            Some(l) => l,
            None => bail!("native backend has no bench layers installed"),
        };
        let layer = match prec {
            Precision::F32 => &layers[0],
            Precision::Int8 => &layers[1],
            Precision::Int4 => &layers[2],
        };
        let mut ws = self.ws.borrow_mut();
        let mut out = vec![0f32; bsz * t * layer.d];
        layer.forward_ws(&self.disp, &mut ws, h, &mut out, mask, bsz, t);
        Ok(out)
    }
}

#[cfg(feature = "xla")]
pub use artifact::{ArtifactBackend, ServeModel};

#[cfg(feature = "xla")]
mod artifact {
    use anyhow::{bail, Context, Result};
    use xla::Literal;

    use super::{Backend, Precision, ServeDims};
    use crate::bench_support as bs;
    use crate::runtime::{Engine, HostTensor};

    /// Deployed model for the artifact path: parameters + scales +
    /// per-layer bit codes, kept as literals so the hot loop never
    /// re-converts them.
    pub struct ServeModel {
        pub params_scales: Vec<Literal>,
        pub bits: Literal,
        pub label: String,
    }

    impl ServeModel {
        pub fn new(params_scales: Vec<Literal>, bits_f: &[f32], label: &str) -> Result<Self> {
            Ok(ServeModel {
                params_scales,
                bits: HostTensor::f32(&[bits_f.len()], bits_f.to_vec()).to_literal()?,
                label: label.to_string(),
            })
        }
    }

    /// AOT-artifact backend over the PJRT [`Engine`].
    pub struct ArtifactBackend<'e> {
        pub eng: &'e Engine,
        serve: Option<(ServeModel, ServeDims)>,
        /// Cached per-precision literal tails for the layer artifacts
        /// (weights/scales; `h`/`mask` are converted per call).
        layer_tails: Option<Box<[Vec<Literal>; 3]>>,
    }

    impl<'e> ArtifactBackend<'e> {
        pub fn new(eng: &'e Engine) -> Self {
            ArtifactBackend { eng, serve: None, layer_tails: None }
        }

        pub fn with_serve_model(mut self, model: ServeModel) -> Result<Self> {
            let dims = ServeDims {
                vocab: self.eng.manifest.cfg("vocab")?,
                seq: self.eng.manifest.cfg("seq")?,
                n_classes: self.eng.manifest.cfg("n_classes")?,
            };
            self.serve = Some((model, dims));
            Ok(self)
        }

        /// Convert the bench-layer weight sets to literals once.
        pub fn with_bench_weights(mut self, w: &bs::LayerWeights) -> Result<Self> {
            let to_lits = |v: Vec<HostTensor>| -> Result<Vec<Literal>> {
                v.iter().map(|t| t.to_literal()).collect()
            };
            let tails = Box::new([
                to_lits(bs::f32_tail(w))?,
                to_lits(bs::int_tail(w, 8)?)?,
                to_lits(bs::int_tail(w, 4)?)?,
            ]);
            self.layer_tails = Some(tails);
            Ok(self)
        }

    }

    impl Backend for ArtifactBackend<'_> {
        fn name(&self) -> String {
            match &self.serve {
                Some((m, _)) => format!("artifact({}, model={})", self.eng.platform(), m.label),
                None => format!("artifact({})", self.eng.platform()),
            }
        }

        fn serve_dims(&self) -> Result<ServeDims> {
            match &self.serve {
                Some((_, d)) => Ok(*d),
                None => bail!("artifact backend has no serving model configured"),
            }
        }

        fn check_bucket(&self, bucket: usize) -> Result<()> {
            self.eng.spec(&format!("serve_fwd_b{bucket}")).map(|_| ())
        }

        fn serve_forward(&self, bucket: usize, t: usize, ids: &[i32], mask: &[f32]) -> Result<Vec<f32>> {
            let (model, dims) = match &self.serve {
                Some(s) => s,
                None => bail!("artifact backend has no serving model configured"),
            };
            // AOT executables are fixed-shape: the batcher must pad to the
            // manifest seq (check_seq_bucket's default enforces this at
            // server construction; this is the per-call belt-and-braces).
            if t != dims.seq {
                bail!("artifact backend serves fixed seq={} only (got {t})", dims.seq);
            }
            let ids_l = HostTensor::i32(&[bucket, t], ids.to_vec()).to_literal()?;
            let mask_l = HostTensor::f32(&[bucket, t], mask.to_vec()).to_literal()?;
            let mut inputs: Vec<&Literal> = model.params_scales.iter().collect();
            inputs.push(&model.bits);
            inputs.push(&ids_l);
            inputs.push(&mask_l);
            let out = self.eng.execute_raw(&format!("serve_fwd_b{bucket}"), &inputs)?;
            Ok(HostTensor::from_literal(&out[0])?.as_f32()?.to_vec())
        }

        fn layer_forward(
            &self,
            prec: Precision,
            bsz: usize,
            t: usize,
            h: &[f32],
            mask: &[f32],
        ) -> Result<Vec<f32>> {
            let tails = match &self.layer_tails {
                Some(t) => t,
                None => bail!("artifact backend has no bench weights installed"),
            };
            let tail = match prec {
                Precision::F32 => &tails[0],
                Precision::Int8 => &tails[1],
                Precision::Int4 => &tails[2],
            };
            let name = format!("layer_{}_b{bsz}_t{t}", prec.name());
            let h_l = HostTensor::f32(&[bsz, t, bs::D], h.to_vec())
                .to_literal()
                .context("layer hidden states")?;
            let mask_l = HostTensor::f32(&[bsz, t], mask.to_vec()).to_literal()?;
            let mut inputs: Vec<&Literal> = vec![&h_l, &mask_l];
            inputs.extend(tail.iter());
            let out = self.eng.execute_raw(&name, &inputs)?;
            Ok(HostTensor::from_literal(&out[0])?.as_f32()?.to_vec())
        }
    }
}
